"""Gradient-sync microbenchmark + comm autotuner: per-leaf vs bucketed
compressed psum vs the ZeRO reduce-scatter + all-gather wire pattern vs
the hierarchical (intra-axis RS -> inter-axis AR -> intra-axis AG)
schedules, on an arbitrary 1- or 2-axis host-device mesh.

Measures the communication layer in isolation (DESIGN.md §6/§9/§14):
for each config's gradient pytree, time one explicit-DP sync step per
mode on the mesh and report the HLO-verified collective count, bytes
per collective, and wire dtype next to the wall-clock numbers.

    python benchmarks/comm_bench.py [--mesh 2x4] [--iters 20] \
        [--archs resnet50,llama3.2-1b] [--full] [--bucket-mib 64] \
        [--quick] [--out BENCH_comm.json]

``--sweep`` turns the benchmark into the comm autotuner: it sweeps
sync mode x wire dtype x bucket size (x hierarchy on a 2-axis mesh),
picks the fastest configuration, and persists it as a CommPlan
(``distributed/comm_plan.py``) that ``launch/train.py --comm-plan
auto`` picks up:

    python benchmarks/comm_bench.py --mesh 2x4 --sweep \
        [--plan-out results/comm_plan_resnet50_2x4.json]

``--quick`` is the CI smoke config (ResNet-50 only, few iterations,
small sweep grid) and ``--out`` writes the table as JSON so the run
leaves an artifact.

By default the LM configs are reduced (a 1.2B-param fp32 gradient tree
does not fit a CPU host); ResNet-50 runs at full size (25.5M params —
the paper's own workload). ``--full`` lifts the reduction everywhere;
``--reduced`` reduces every config (the round-trip tests use it).
"""
import argparse
import json
import math
import os
import time

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.experimental.shard_map import shard_map  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import get_config, reduced_config  # noqa: E402
from repro.core.compression import compressed_psum  # noqa: E402
from repro.distributed.bucketing import (  # noqa: E402
    bucketed_psum,
    make_hierarchy,
    plan_buckets,
)
from repro.distributed.comm_plan import (  # noqa: E402
    CommPlan,
    plan_path,
    save_plan,
)
from repro.launch.hlo_analysis import analyze_hlo, comm_report  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.training.specs import param_specs  # noqa: E402

#: sync modes the bench can time; hier* need a 2-axis mesh
ALL_MODES = ("per-leaf", "bucketed", "zero", "hier", "hier_zero")

#: bench mode -> the CommPlan sync_mode it corresponds to
PLAN_SYNC_MODE = {"bucketed": "bucketed", "zero": "zero",
                  "hier": "bucketed", "hier_zero": "zero"}


def parse_mesh(spec, n_dev):
    """``--mesh 2x4`` -> a named 2-axis mesh; default: all devices on
    one "data" axis (the old single-axis behavior)."""
    if not spec:
        return jax.make_mesh((n_dev,), ("data",))
    dims = tuple(int(x) for x in spec.split("x"))
    if math.prod(dims) != n_dev:
        raise SystemExit(f"--mesh {spec}: product {math.prod(dims)} != "
                         f"device count {n_dev} (set XLA_FLAGS "
                         f"--xla_force_host_platform_device_count)")
    if len(dims) > 2:
        raise SystemExit(f"--mesh {spec}: at most 2 axes supported")
    axes = ("data",) if len(dims) == 1 else ("data", "model")
    return jax.make_mesh(dims, axes)


def grad_tree(arch: str, full: bool, reduced: bool = False):
    cfg = get_config(arch)
    if reduced or (not full and cfg.family != "conv"):
        cfg = reduced_config(cfg)
    model = build_model(cfg, compute_dtype=jnp.float32)
    p_shapes, _ = param_specs(model, jnp.float32)
    key = iter(jax.random.split(jax.random.PRNGKey(0),
                                len(jax.tree.leaves(p_shapes))))
    return cfg, jax.tree.map(
        lambda s: jax.random.normal(next(key), s.shape, jnp.float32),
        p_shapes)


def build_sync(mode, mesh, grads, wire, bucket_bytes, hier_split=1):
    """jitted replicated-in/replicated-out sync step for one mode.

    DP spans every mesh axis (the paper's pure-DP ResNet regime), so a
    2-axis ``--mesh 2x4`` syncs over both axes — flat modes as one
    8-way group, hier modes as the two-stage schedule split at
    ``hier_split``."""
    dp_axes = tuple(mesh.axis_names)
    n_dev = 1
    for a in dp_axes:
        n_dev *= mesh.shape[a]
    hier = None
    if mode.startswith("hier"):
        hier = make_hierarchy(dp_axes, dict(mesh.shape), hier_split)

    def local(g):
        if mode in ("bucketed", "hier"):
            return bucketed_psum(g, dp_axes, wire=wire,
                                 bucket_bytes=bucket_bytes,
                                 use_kernel=False, hierarchy=hier)
        if mode in ("zero", "hier_zero"):
            # the ZeRO wire pattern in isolation (DESIGN.md §9/§14):
            # reduce-scatter each shard-aligned bucket, all-gather the
            # shards straight back (stand-in for the updated params),
            # unpack — numerically the same mean tree as bucketed
            from repro.distributed.bucketing import (
                hierarchical_all_gather,
                hierarchical_psum_scatter,
                pack,
                unpack,
            )
            plan = plan_buckets(g, bucket_bytes, wire, align=n_dev)
            bufs = pack(g, plan, use_kernel=False)
            if hier is not None:
                shards = [hierarchical_psum_scatter(b, hier)
                          for b in bufs]
                gathered = [hierarchical_all_gather(s, hier)
                            for s in shards]
            else:
                shards = [jax.lax.psum_scatter(b, dp_axes,
                                               scatter_dimension=0,
                                               tiled=True)
                          for b in bufs]
                gathered = [jax.lax.all_gather(s, dp_axes, tiled=True)
                            for s in shards]
            return unpack(gathered, plan, use_kernel=False,
                          denom=jax.lax.psum(1, dp_axes))
        return compressed_psum(g, dp_axes, wire, mean=True)

    specs = jax.tree.map(lambda _: P(), grads)
    fn = shard_map(local, mesh=mesh, in_specs=(specs,), out_specs=specs,
                   check_rep=False)
    return jax.jit(fn)


def bench(fn, grads, iters):
    out = fn(grads)  # compile + warm
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(grads)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3


def time_cell(arch_name, mode, mesh, grads, wire, bucket_mib, iters,
              hier_split, n_dev):
    """Build + lower + time one (mode, wire, bucket) cell -> row dict."""
    bucket_bytes = bucket_mib * 1024 * 1024
    fn = build_sync(mode, mesh, grads, wire, bucket_bytes,
                    hier_split=hier_split)
    hlo = fn.lower(grads).compile().as_text()
    cr = comm_report(analyze_hlo(hlo, n_dev))
    ms = bench(fn, grads, iters)
    return {
        "arch": arch_name,
        "mode": mode,
        "wire": wire,
        "bucket_mib": bucket_mib,
        "hier_split": hier_split if mode.startswith("hier") else None,
        "leaves": len(jax.tree.leaves(grads)),
        "collectives_per_step": cr["total_executions_per_step"],
        "mib_per_collective": round(
            cr["mean_bytes_per_collective"] / 2 ** 20, 3),
        "wire_dtypes": sorted({d for op in cr["per_op"].values()
                               for d in op["dtype_bytes"]}),
        "ms_per_sync": round(ms, 3),
    }


def print_rows(rows):
    hdr = (f"{'arch':<16} {'mode':<10} {'wire':<5} {'MiB':>4} "
           f"{'hier':>4} {'leaves':>6} {'colls':>6} {'MiB/coll':>9} "
           f"{'wire dtypes':<16} {'ms/sync':>8}")
    print()
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        h = "-" if r["hier_split"] is None else str(r["hier_split"])
        print(f"{r['arch']:<16} {r['mode']:<10} {r['wire']:<5} "
              f"{r['bucket_mib']:>4} {h:>4} {r['leaves']:>6} "
              f"{r['collectives_per_step']:>6.0f} "
              f"{r['mib_per_collective']:>9.2f} "
              f"{','.join(r['wire_dtypes']):<16} "
              f"{r['ms_per_sync']:>8.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default="resnet50,llama3.2-1b")
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--wire", default="bf16")
    ap.add_argument("--bucket-mib", type=int, default=64)
    ap.add_argument("--mesh", default=None,
                    help="AxB device mesh, e.g. 2x4 (hier modes need 2 "
                         "axes); default: all devices on one axis")
    ap.add_argument("--hier-split", type=int, default=1,
                    help="dp_axes split index for the hier modes "
                         "(DESIGN.md §14)")
    ap.add_argument("--modes", default=None,
                    help=f"comma list of {ALL_MODES} (default: all "
                         "that fit the mesh)")
    ap.add_argument("--full", action="store_true",
                    help="full-size LM configs (needs a lot of host RAM)")
    ap.add_argument("--reduced", action="store_true",
                    help="reduce every config, conv included (fast "
                         "round-trip tests)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke config: ResNet-50 only, 5 iterations")
    ap.add_argument("--sweep", action="store_true",
                    help="autotune: sweep mode x wire x bucket size "
                         "(x hierarchy) and persist the winning "
                         "CommPlan (DESIGN.md §14)")
    ap.add_argument("--sweep-wires", default="bf16,f16")
    ap.add_argument("--sweep-bucket-mibs", default="4,16,64")
    ap.add_argument("--plan-out", default=None,
                    help="CommPlan path for --sweep (default: "
                         "results/comm_plan_{arch}_{AxB}.json)")
    ap.add_argument("--out", default=None,
                    help="also write the table as JSON (CI artifact)")
    args = ap.parse_args()
    if args.quick:
        args.archs = "resnet50"
        args.iters = min(args.iters, 5)
        args.sweep_bucket_mibs = "4,64"

    n_dev = jax.device_count()
    mesh = parse_mesh(args.mesh, n_dev)
    mesh_shape = tuple(mesh.shape[a] for a in mesh.axis_names)
    multi_axis = len(mesh_shape) > 1
    dp_axes = tuple(mesh.axis_names)

    if args.modes:
        modes = [m.strip() for m in args.modes.split(",") if m.strip()]
        for m in modes:
            if m not in ALL_MODES:
                ap.error(f"unknown mode {m!r}; pick from {ALL_MODES}")
    else:
        modes = [m for m in ALL_MODES
                 if multi_axis or not m.startswith("hier")]
    if not multi_axis and any(m.startswith("hier") for m in modes):
        ap.error("hier modes need a 2-axis mesh: pass --mesh AxB")

    rows = []
    plan = None
    plan_file = None
    for arch in args.archs.split(","):
        cfg, grads = grad_tree(arch, args.full, args.reduced)
        plan0 = plan_buckets(grads, args.bucket_mib * 1024 * 1024,
                             args.wire)
        print(f"[{cfg.name}] {plan0.describe()}")
        if args.sweep:
            # autotuner: the flat per-leaf baseline is timed once for
            # the table; the sweep grid covers the tunable schedules
            rows.append(time_cell(cfg.name, "per-leaf", mesh, grads,
                                  args.wire, args.bucket_mib,
                                  args.iters, args.hier_split, n_dev))
            grid = [m for m in modes if m != "per-leaf"]
            wires = [w.strip() for w in args.sweep_wires.split(",")]
            mibs = [int(x) for x in args.sweep_bucket_mibs.split(",")]
            best = None
            for mode in grid:
                for wire in wires:
                    for mib in mibs:
                        row = time_cell(cfg.name, mode, mesh, grads,
                                        wire, mib, args.iters,
                                        args.hier_split, n_dev)
                        rows.append(row)
                        if best is None or \
                                row["ms_per_sync"] < best["ms_per_sync"]:
                            best = row
            if best is not None and arch == args.archs.split(",")[0]:
                plan = CommPlan(
                    mesh_shape=mesh_shape, dp_axes=dp_axes,
                    sync_mode=PLAN_SYNC_MODE[best["mode"]],
                    wire=best["wire"],
                    bucket_bytes=best["bucket_mib"] * 1024 * 1024,
                    hier_split=best["hier_split"],
                    source="autotuner")
                plan_file = args.plan_out or plan_path(cfg.name,
                                                       mesh_shape)
                save_plan(plan, plan_file)
                print(f"[{cfg.name}] winner: {best['mode']} "
                      f"{best['wire']} {best['bucket_mib']}MiB "
                      f"({best['ms_per_sync']:.2f} ms) -> {plan_file}")
        else:
            for mode in modes:
                rows.append(time_cell(cfg.name, mode, mesh, grads,
                                      args.wire, args.bucket_mib,
                                      args.iters, args.hier_split,
                                      n_dev))

    print_rows(rows)
    by = {}
    for r in rows:
        by.setdefault(r["arch"], {})[r["mode"]] = r["ms_per_sync"]
    for name, d in by.items():
        if "per-leaf" in d and "bucketed" in d:
            print(f"{name}: bucketed is {d['per-leaf'] / d['bucketed']:.2f}x"
                  f" per-leaf wall-clock on {n_dev} host devices")
        if "bucketed" in d and "zero" in d:
            print(f"{name}: zero (scatter+gather) is "
                  f"{d['bucketed'] / d['zero']:.2f}x bucketed wall-clock")
        if "bucketed" in d and "hier" in d:
            print(f"{name}: hier (RS+AR+AG) is "
                  f"{d['bucketed'] / d['hier']:.2f}x bucketed wall-clock")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({
                "bench": "comm_bench",
                "devices": n_dev,
                "mesh": list(mesh_shape),
                "mesh_axes": list(dp_axes),
                "wire": args.wire,
                "bucket_bytes": args.bucket_mib * 1024 * 1024,
                "sweep": bool(args.sweep),
                "plan_path": plan_file,
                "plan": (None if plan is None
                         else json.loads(open(plan_file).read())),
                "rows": rows,
            }, f, indent=1)
        print(f"wrote {args.out}")
    print("\nNOTE: host-mesh 'devices' share one memory system, so this "
          "measures the collective-count/launch structure, not real "
          "interconnect time: the HLO columns (colls, MiB/coll, dtype) "
          "are the transferable result. On TPU, per-collective launch "
          "latency x leaf count is what bucketing removes (DESIGN.md "
          "§6), and the hierarchical schedules trade one big flat ring "
          "for two short intra/inter-axis stages (DESIGN.md §14).")


if __name__ == "__main__":
    main()
