"""Gradient-sync microbenchmark: per-leaf vs bucketed compressed psum vs
the ZeRO reduce-scatter + all-gather wire pattern.

Measures the communication layer in isolation (DESIGN.md §6/§9): for
each config's gradient pytree, time one explicit-DP sync step per mode
on a host-device mesh and report the HLO-verified collective count,
bytes per collective, and wire dtype next to the wall-clock numbers.

    python benchmarks/comm_bench.py [--devices 8] [--iters 20] \
        [--archs resnet50,llama3.2-1b] [--full] [--bucket-mib 64] \
        [--quick] [--out BENCH_comm.json]

``--quick`` is the CI smoke config (ResNet-50 only, few iterations) and
``--out`` writes the table as JSON so the run leaves an artifact.

By default the LM configs are reduced (a 1.2B-param fp32 gradient tree
does not fit a CPU host); ResNet-50 runs at full size (25.5M params —
the paper's own workload). ``--full`` lifts the reduction everywhere.
"""
import argparse
import json
import os
import time

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.experimental.shard_map import shard_map  # noqa: E402
from jax.sharding import PartitionSpec as P  # noqa: E402

from repro.configs import get_config, reduced_config  # noqa: E402
from repro.core.compression import compressed_psum  # noqa: E402
from repro.distributed.bucketing import (  # noqa: E402
    bucketed_psum,
    plan_buckets,
)
from repro.launch.hlo_analysis import analyze_hlo, comm_report  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.training.specs import param_specs  # noqa: E402


def grad_tree(arch: str, full: bool):
    cfg = get_config(arch)
    if not full and cfg.family != "conv":
        cfg = reduced_config(cfg)
    model = build_model(cfg, compute_dtype=jnp.float32)
    p_shapes, _ = param_specs(model, jnp.float32)
    key = iter(jax.random.split(jax.random.PRNGKey(0),
                                len(jax.tree.leaves(p_shapes))))
    return cfg, jax.tree.map(
        lambda s: jax.random.normal(next(key), s.shape, jnp.float32),
        p_shapes)


def build_sync(mode, mesh, grads, wire, bucket_bytes):
    """jitted replicated-in/replicated-out sync step for one mode."""
    n_dev = mesh.shape["data"]

    def local(g):
        if mode == "bucketed":
            return bucketed_psum(g, ("data",), wire=wire,
                                 bucket_bytes=bucket_bytes,
                                 use_kernel=False)
        if mode == "zero":
            # the ZeRO wire pattern in isolation (DESIGN.md §9):
            # reduce-scatter each shard-aligned bucket, all-gather the
            # shards straight back (stand-in for the updated params),
            # unpack — numerically the same mean tree as bucketed
            from repro.distributed.bucketing import pack, unpack
            plan = plan_buckets(g, bucket_bytes, wire, align=n_dev)
            shards = [jax.lax.psum_scatter(b, "data",
                                           scatter_dimension=0,
                                           tiled=True)
                      for b in pack(g, plan, use_kernel=False)]
            gathered = [jax.lax.all_gather(s, "data", tiled=True)
                        for s in shards]
            return unpack(gathered, plan, use_kernel=False,
                          denom=jax.lax.psum(1, ("data",)))
        return compressed_psum(g, ("data",), wire, mean=True)

    specs = jax.tree.map(lambda _: P(), grads)
    fn = shard_map(local, mesh=mesh, in_specs=(specs,), out_specs=specs,
                   check_rep=False)
    return jax.jit(fn)


def bench(fn, grads, iters):
    out = fn(grads)  # compile + warm
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(grads)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--archs", default="resnet50,llama3.2-1b")
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--wire", default="bf16")
    ap.add_argument("--bucket-mib", type=int, default=64)
    ap.add_argument("--full", action="store_true",
                    help="full-size LM configs (needs a lot of host RAM)")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke config: ResNet-50 only, 5 iterations")
    ap.add_argument("--out", default=None,
                    help="also write the table as JSON (CI artifact)")
    args = ap.parse_args()
    if args.quick:
        args.archs = "resnet50"
        args.iters = min(args.iters, 5)

    n_dev = jax.device_count()
    mesh = jax.make_mesh((n_dev,), ("data",))
    bucket_bytes = args.bucket_mib * 1024 * 1024

    rows = []
    for arch in args.archs.split(","):
        cfg, grads = grad_tree(arch, args.full)
        n_leaves = len(jax.tree.leaves(grads))
        plan = plan_buckets(grads, bucket_bytes, args.wire)
        print(f"[{cfg.name}] {plan.describe()}")
        for mode in ("per-leaf", "bucketed", "zero"):
            fn = build_sync(mode, mesh, grads, args.wire, bucket_bytes)
            hlo = fn.lower(grads).compile().as_text()
            cr = comm_report(analyze_hlo(hlo, n_dev))
            ms = bench(fn, grads, args.iters)
            rows.append((cfg.name, mode, n_leaves,
                         cr["total_executions_per_step"],
                         cr["mean_bytes_per_collective"] / 2 ** 20,
                         sorted({d for op in cr["per_op"].values()
                                 for d in op["dtype_bytes"]}),
                         ms))

    hdr = (f"{'arch':<16} {'mode':<9} {'leaves':>6} {'colls':>6} "
           f"{'MiB/coll':>9} {'wire dtypes':<16} {'ms/sync':>8}")
    print()
    print(hdr)
    print("-" * len(hdr))
    for name, mode, leaves, colls, mib, dts, ms in rows:
        print(f"{name:<16} {mode:<9} {leaves:>6} {colls:>6.0f} "
              f"{mib:>9.2f} {','.join(dts):<16} {ms:>8.2f}")
    by = {}
    for name, mode, *_rest, ms in rows:
        by.setdefault(name, {})[mode] = ms
    for name, d in by.items():
        if "per-leaf" in d and "bucketed" in d:
            print(f"{name}: bucketed is {d['per-leaf'] / d['bucketed']:.2f}x"
                  f" per-leaf wall-clock on {n_dev} host devices")
        if "bucketed" in d and "zero" in d:
            print(f"{name}: zero (scatter+gather) is "
                  f"{d['bucketed'] / d['zero']:.2f}x bucketed wall-clock")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({
                "bench": "comm_bench",
                "devices": n_dev,
                "wire": args.wire,
                "bucket_bytes": bucket_bytes,
                "rows": [
                    {"arch": name, "mode": mode, "leaves": leaves,
                     "collectives_per_step": colls,
                     "mib_per_collective": round(mib, 3),
                     "wire_dtypes": dts, "ms_per_sync": round(ms, 3)}
                    for name, mode, leaves, colls, mib, dts, ms in rows],
            }, f, indent=1)
        print(f"wrote {args.out}")
    print("\nNOTE: host-mesh 'devices' share one memory system, so this "
          "measures the collective-count/launch structure, not real "
          "interconnect time: the HLO columns (colls, MiB/coll, dtype) "
          "are the transferable result. On TPU, per-collective launch "
          "latency x leaf count is what bucketing removes (DESIGN.md §6).")


if __name__ == "__main__":
    main()
