"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads results/dryrun/*.json, emits a per-(arch, shape, mesh) table of the
three roofline terms, the dominant bottleneck, MODEL_FLOPS/HLO_FLOPs and
the headline roofline fraction.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

_DEFAULT = ("results/dryrun_opt"
            if os.path.isdir("results/dryrun_opt") else "results/dryrun")
RESULTS = os.environ.get("DRYRUN_DIR", _DEFAULT)


def load_records(mesh_tag: str = "pod16x16") -> List[Dict]:
    out = []
    for p in sorted(glob.glob(os.path.join(RESULTS, f"*{mesh_tag}.json"))):
        r = json.load(open(p))
        r.setdefault("mesh_tag", mesh_tag)
        out.append(r)
    return out


def table_rows(mesh_tag: str = "pod16x16") -> List[Dict]:
    rows = []
    for r in load_records(mesh_tag):
        if r.get("status") == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": "skipped", "reason": r["reason"]})
            continue
        if r.get("status") != "ok":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "status": r.get("status")})
            continue
        rl = r["roofline"]
        rows.append({
            "arch": r["arch"], "shape": r["shape"], "status": "ok",
            "compute_s": rl["compute_s"], "memory_s": rl["memory_s"],
            "collective_s": rl["collective_s"],
            "dominant": rl["dominant"], "bound_s": rl["bound_s"],
            "useful_fraction": rl["useful_fraction"],
            "roofline_fraction": rl["achievable_mfu"],
            "fits_16g": r.get("fits_v5e_16g"),
            "collective_GB": round(r["collective_total_bytes"] / 1e9, 2),
        })
    return rows


def print_table(mesh_tag: str = "pod16x16"):
    rows = table_rows(mesh_tag)
    hdr = (f"{'arch':26s} {'shape':12s} {'comp_s':>8s} {'mem_s':>9s} "
           f"{'coll_s':>8s} {'dominant':>12s} {'useful':>7s} "
           f"{'roofl%':>7s} {'fits':>5s}")
    print(hdr)
    print("-" * len(hdr))
    for r in rows:
        if r["status"] != "ok":
            print(f"{r['arch']:26s} {r['shape']:12s} "
                  f"[{r['status']}] {r.get('reason','')[:60]}")
            continue
        print(f"{r['arch']:26s} {r['shape']:12s} {r['compute_s']:8.4f} "
              f"{r['memory_s']:9.4f} {r['collective_s']:8.4f} "
              f"{r['dominant']:>12s} {r['useful_fraction']:7.3f} "
              f"{100*r['roofline_fraction']:6.2f}% "
              f"{str(r['fits_16g']):>5s}")


if __name__ == "__main__":
    import sys
    print_table(sys.argv[1] if len(sys.argv) > 1 else "pod16x16")
