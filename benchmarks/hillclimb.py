import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

# Hillclimb driver: lower one cell (with optimization variants), print the
# three roofline terms + the top memory/collective ops, and append the
# record to results/hillclimb/. Used for the hypothesis->change->measure
# loop in EXPERIMENTS.md §Perf.
#
#   PYTHONPATH=src python -m benchmarks.hillclimb --arch mixtral-8x7b \
#       --shape train_4k --variant baseline --top 12

import argparse
import json
import re

import jax


def diagnose(arch, shape, variant="baseline", top=14, out_dir="results/hillclimb",
             attention_impl=None, save=True, sp=False, moe_group=None):
    import dataclasses

    from repro.configs import get_config, shapes_for
    from repro.launch import hlo_analysis as H
    from repro.launch.dryrun import lower_cell
    from repro.launch.mesh import cell_parallel, make_production_mesh

    mesh = make_production_mesh()
    kwargs = {}
    if os.environ.get("HILLCLIMB_MESH"):
        import jax as _jax
        d, m = (int(x) for x in os.environ["HILLCLIMB_MESH"].split("x"))
        mesh = _jax.make_mesh((d, m), ("data", "model"))
    if attention_impl:
        kwargs["attention_impl"] = attention_impl
    if moe_group:
        kwargs["moe_group"] = moe_group
    if sp:
        cfg = get_config(arch)
        shp = {s.name: s for s in shapes_for(cfg)}[shape]
        par = dataclasses.replace(cell_parallel(cfg, shp),
                                  sequence_sharding=True)
        kwargs["parallel"] = par
    rec, compiled = lower_cell(arch, shape, mesh, **kwargs)
    assert rec.get("status") == "ok", rec
    a = H.analyze_hlo(compiled.as_text(), total_devices=mesh.size)
    mem_rows = a.top_memory_ops
    coll_rows = a.top_collective_ops

    rl = rec["roofline"]
    print(f"=== {arch} {shape} [{variant}] ===")
    print(f"compute {rl['compute_s']:.4f}s  memory {rl['memory_s']:.4f}s  "
          f"collective {rl['collective_s']:.4f}s  dom={rl['dominant']}  "
          f"useful={rl['useful_fraction']}  "
          f"roofl={100*rl['achievable_mfu']:.2f}%")
    print("--- top memory ops (GB, accounted) ---")
    for r in mem_rows[:top]:
        print(f"  {r[0]/1e9:9.1f}  {r[1]:<22s} x{r[2]:<7g} {r[3]:<30s} "
              f"{r[4]} {r[5]}")
    print("--- top collectives (GB wire, accounted) ---")
    for r in coll_rows[:top]:
        print(f"  {r[0]/1e9:9.2f}  {r[1]:<18s} k={r[2]:<4d} x{r[3]:<7g} "
              f"{r[4]} {r[5]}")
    if save:
        os.makedirs(out_dir, exist_ok=True)
        path = os.path.join(out_dir, f"{arch}__{shape}__{variant}.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1, default=str)
    return rec


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--attention-impl", default=None)
    ap.add_argument("--top", type=int, default=14)
    ap.add_argument("--sp", action="store_true")
    ap.add_argument("--moe-group", type=int, default=None)
    args = ap.parse_args()
    diagnose(args.arch, args.shape, args.variant, args.top,
             attention_impl=args.attention_impl, sp=args.sp,
             moe_group=args.moe_group)
