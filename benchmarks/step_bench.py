"""End-to-end train-step benchmark across the six gradient-sync modes.

Times one full optimizer step (fwd + bwd + sync + update) of reduced
ResNet-50 on an 8-virtual-device host mesh for:

  gspmd                 jit + NamedShardings, XLA-placed collectives
  shardmap_perleaf      explicit DP, one bf16 psum per gradient leaf
  shardmap_bucketed     explicit DP, one psum per fixed-size bucket (§6)
  shardmap_overlap      bucketed + backward-overlapped launch (§8)
  shardmap_zero         bucketed + ZeRO reduce-scatter / sharded
                        update / param all-gather (§9)
  shardmap_zero_overlap zero + backward-overlapped scatter launch

and writes a top-level ``BENCH_step.json`` so every PR leaves a
steps/sec trajectory point behind (CI uploads it as an artifact; its
schema is pinned by tests/test_bench_schema.py).

    PYTHONPATH=src python benchmarks/step_bench.py [--quick] \
        [--out BENCH_step.json]

Host-mesh caveat (same as comm_bench): the 8 "devices" share one memory
system, so wall-clock differences measure collective count / launch
structure and scheduling, not real interconnect time. The transferable
claims — collective counts, interleaving — are HLO-verified in the test
suite; these numbers bound the *overhead* of each mechanism.
"""
import argparse
import json
import os
import time

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (  # noqa: E402
    OptimizerConfig,
    get_config,
    reduced_config,
)
from repro.data.pipeline import DataPipeline  # noqa: E402
from repro.launch.train import build_train_setup  # noqa: E402

MODES = {
    "gspmd": dict(dp_mode="gspmd", compression="bf16"),
    "shardmap_perleaf": dict(dp_mode="shardmap", compression="bf16"),
    "shardmap_bucketed": dict(dp_mode="shardmap",
                              compression="bf16+bucketed"),
    "shardmap_overlap": dict(dp_mode="shardmap",
                             compression="bf16+bucketed",
                             overlap_comm=True),
    "shardmap_zero": dict(dp_mode="shardmap",
                          compression="bf16+bucketed", zero_dp=True),
    "shardmap_zero_overlap": dict(dp_mode="shardmap",
                                  compression="bf16+bucketed",
                                  zero_dp=True, overlap_comm=True),
}


def bench_mode(name: str, kw: dict, *, arch: str, global_batch: int,
               bucket_bytes: int, iters: int, warmup: int,
               data_workers: int) -> dict:
    cfg = reduced_config(get_config(arch))
    mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    model, state, step, data, put, _ = build_train_setup(
        cfg, global_batch=global_batch, seq_len=16,
        opt_cfg=OptimizerConfig(), steps_per_epoch=10, mesh=mesh,
        seed=0, bucket_bytes=bucket_bytes, **kw)
    batch = put({k: jnp.asarray(v) for k, v in data.batch_at(0).items()})
    t0 = time.perf_counter()
    for _ in range(warmup):  # includes compile on the first call
        state, metrics = step(state, dict(batch))
    jax.block_until_ready(metrics["loss"])
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(iters):
        state, metrics = step(state, dict(batch))
    jax.block_until_ready(metrics["loss"])
    dt = (time.perf_counter() - t0) / iters
    # ---- input-boundedness attribution (DESIGN.md §15): re-run with the
    # live multi-worker feed, splitting each step into time blocked on
    # the prefetch buffer (data-starved) vs everything else
    # (compute-bound). Per-step block_until_ready keeps the attribution
    # honest — async dispatch would hide compute under the next wait.
    pipe = DataPipeline(data, start_step=0, depth=4,
                        num_workers=data_workers, put=put)
    try:
        t0 = time.perf_counter()
        for _ in range(iters):
            _, fed = next(pipe)
            state, metrics = step(state, fed)
            jax.block_until_ready(metrics["loss"])
        fed_dt = (time.perf_counter() - t0) / iters
        wait_s = pipe.wait_s_total / iters
    finally:
        pipe.close()
    row = {"ms_per_step": round(dt * 1e3, 3),
           "steps_per_sec": round(1.0 / dt, 3),
           "warmup_s": round(compile_s, 2),
           "data_wait_ms": round(wait_s * 1e3, 3),
           "compute_ms": round((fed_dt - wait_s) * 1e3, 3),
           "data_starved_frac": round(wait_s / fed_dt, 4)}
    print(f"{name:<20} {row['ms_per_step']:>9.1f} ms/step "
          f"{row['steps_per_sec']:>8.2f} steps/s  "
          f"starved {row['data_starved_frac']:.1%}", flush=True)
    return row


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="resnet50")
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--bucket-kib", type=int, default=16,
                    help="bucket size (KiB) — small so the reduced "
                         "gradient tree still spans several buckets")
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--warmup", type=int, default=3)
    ap.add_argument("--data-workers", type=int, default=2,
                    help="producer threads for the attribution pass")
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke settings (fewer iterations)")
    ap.add_argument("--out", default="BENCH_step.json")
    args = ap.parse_args()
    if args.quick:
        args.iters = min(args.iters, 8)
        args.warmup = min(args.warmup, 2)

    print(f"devices={jax.device_count()} arch={args.arch}(reduced) "
          f"batch={args.global_batch} bucket={args.bucket_kib}KiB")
    modes = {}
    for name, kw in MODES.items():
        modes[name] = bench_mode(
            name, kw, arch=args.arch, global_batch=args.global_batch,
            bucket_bytes=args.bucket_kib * 1024, iters=args.iters,
            warmup=args.warmup, data_workers=args.data_workers)

    overlap_speedup = (modes["shardmap_bucketed"]["ms_per_step"]
                       / modes["shardmap_overlap"]["ms_per_step"])
    zero_speedup = (modes["shardmap_bucketed"]["ms_per_step"]
                    / modes["shardmap_zero"]["ms_per_step"])
    result = {
        "bench": "step_bench",
        "devices": jax.device_count(),
        "backend": jax.default_backend(),
        "arch": f"{args.arch}-reduced",
        "global_batch": args.global_batch,
        "bucket_bytes": args.bucket_kib * 1024,
        "iters": args.iters,
        "data_workers": args.data_workers,
        "modes": modes,
        "overlap_vs_bucketed_speedup": round(overlap_speedup, 3),
        "zero_vs_bucketed_speedup": round(zero_speedup, 3),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"overlap vs bucketed: {overlap_speedup:.2f}x, "
          f"zero vs bucketed: {zero_speedup:.2f}x -> wrote {args.out}")


if __name__ == "__main__":
    main()
