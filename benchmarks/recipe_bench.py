"""Paper-recipe proxy: accuracy-vs-epoch on the synthetic ImageNet-like
task — the substrate every schedule / compression / optimizer ablation
reports against (the paper's Table 1 is a *validation accuracy* after a
fixed epoch budget, not a step count).

Runs the epoch-driven Trainer (DESIGN.md §7) for each recipe variant and
emits a JSON artifact:

    {"meta": {...}, "variants": {name: {"epochs": [...],
                                        "top1": [...], "val_loss": [...],
                                        "best_top1": float}}}

CI runs the reduced 2-epoch proxy and uploads the JSON so every PR's
accuracy trajectory is inspectable.

    PYTHONPATH=src python benchmarks/recipe_bench.py --reduced \
        --epochs 2 --out recipe_accuracy.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import OptimizerConfig, get_config, reduced_config  # noqa: E402
from repro.launch.train import build_eval_setup, build_train_setup  # noqa: E402
from repro.training import Trainer, TrainerConfig  # noqa: E402

# recipe variants: the paper's hybrid recipe vs the Goyal et al. baseline
# it improves on, on identical data/init/eval.
VARIANTS = {
    "paper_recipe": dict(kind="rmsprop_warmup", schedule="slow_start",
                         transition="elu"),
    "goyal_baseline": dict(kind="momentum_sgd", schedule="goyal"),
}


def run_variant(name: str, opt_kw: dict, args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    # beta/warmup epochs scaled to the proxy's tiny epoch budget
    opt_cfg = OptimizerConfig(beta_center=max(1.0, args.epochs / 3.0),
                              beta_period=1.0,
                              warmup_epochs=max(1.0, args.epochs / 3.0),
                              **opt_kw)
    model, state, train_step, data, put_batch, shardings = \
        build_train_setup(
            cfg, global_batch=args.global_batch, seq_len=16,
            opt_cfg=opt_cfg, steps_per_epoch=args.steps_per_epoch,
            seed=args.seed, data_noise=args.data_noise)
    eval_step, val_data, finalize = build_eval_setup(
        model, cfg, global_batch=args.global_batch, seq_len=16,
        seed=args.seed, data_noise=args.data_noise)
    tcfg = TrainerConfig(epochs=args.epochs,
                         steps_per_epoch=args.steps_per_epoch,
                         eval_every_epochs=1,
                         val_batches=args.val_batches,
                         checkpoint_every=0, checkpoint_dir=None,
                         log_every=max(1, args.steps_per_epoch))
    t0 = time.time()
    result = Trainer(train_step, state, data, tcfg, eval_step=eval_step,
                     val_data=val_data, finalize_state=finalize,
                     put_batch=put_batch).run()
    wall = time.time() - t0
    rec = {
        "epochs": [r["epoch"] for r in result.epoch_history],
        "top1": [r.get("top1") for r in result.epoch_history],
        "val_loss": [r["loss"] for r in result.epoch_history],
        "best_top1": result.best["top1"] if result.best else None,
        "wall_s": wall,
    }
    print(f"{name}: top1/epoch "
          f"{[('%.3f' % t) if t is not None else '-' for t in rec['top1']]}"
          f" ({wall:.1f}s)", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="resnet50")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--epochs", type=int, default=2)
    ap.add_argument("--steps-per-epoch", type=int, default=10)
    ap.add_argument("--global-batch", type=int, default=64)
    ap.add_argument("--val-batches", type=int, default=2)
    # hard enough that the proxy is not memorized before the schedule
    # transitions (mirrors the real-ImageNet regime; see
    # tests/test_paper_recipe.py)
    ap.add_argument("--data-noise", type=float, default=2.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="recipe_accuracy.json")
    args = ap.parse_args()

    out = {
        "meta": {"arch": args.arch, "reduced": args.reduced,
                 "epochs": args.epochs,
                 "steps_per_epoch": args.steps_per_epoch,
                 "global_batch": args.global_batch,
                 "data_noise": args.data_noise, "seed": args.seed},
        "variants": {name: run_variant(name, kw, args)
                     for name, kw in VARIANTS.items()},
    }
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
