"""Benchmark harness — one benchmark per paper table/figure + kernel
micro-benches. Prints ``name,us_per_call,derived`` CSV rows.

  table1_*    : the paper's Table 1 (time-to-train & accuracy) as a
                reduced-scale proxy — recipe vs momentum-SGD at scaled
                batch on the synthetic classification task.
  figure1_*   : the paper's Figure 1 (iteration & all-reduce time vs
                worker count), reproduced from the measured dry-run
                compute term + the ring-all-reduce wire model, fp32 vs
                the paper's fp16 compression.
  kernel_*    : Pallas kernels (interpret-mode wall time on CPU; the
                'derived' column is the modeled v5e time from HBM bytes).
  step_*      : end-to-end reduced train/decode steps on CPU.
"""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

ROWS: List[Tuple[str, float, str]] = []

PEAK = 197e12
HBM = 819e9
ICI = 50e9


def emit(name: str, us: float, derived: str = ""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def timeit(fn: Callable, n: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n * 1e6


# ---------------------------------------------------------------------------


def bench_kernels():
    from repro.core.optimizer import HybridHyper, hybrid_update
    from repro.kernels import ops
    from repro.kernels import ref as kref

    n = 1 << 20  # 1M params
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    g, p = jax.random.normal(ks[0], (n,)), jax.random.normal(ks[1], (n,))
    d, m = jnp.zeros(n), jnp.ones(n)
    h = HybridHyper(eta=jnp.float32(0.1), alpha_sgd=jnp.float32(0.5))

    ref_fn = jax.jit(lambda g, p, d, m: hybrid_update(g, p, d, m, h))
    us = timeit(lambda: ref_fn(g, p, d, m))
    t_model = 7 * n * 4 / HBM * 1e6  # 4 reads + 3 writes fp32, one pass
    emit("kernel_hybrid_update_xla_1M", us, f"v5e_model_us={t_model:.1f}")

    fused = jax.jit(
        lambda g, p, d, m: ops.fused_hybrid_update(g, p, d, m, h))
    us = timeit(lambda: fused(g, p, d, m), n=2, warmup=1)
    emit("kernel_hybrid_update_pallas_interp_1M", us,
         f"v5e_model_us={t_model:.1f}")

    b, s, hh, dh = 1, 1024, 4, 64
    q = jax.random.normal(ks[0], (b, s, hh, dh), jnp.bfloat16)
    k = jax.random.normal(ks[1], (b, s, 2, dh), jnp.bfloat16)
    v = jax.random.normal(ks[2], (b, s, 2, dh), jnp.bfloat16)
    naive = jax.jit(lambda q, k, v: kref.attention(q, k, v, causal=True))
    us = timeit(lambda: naive(q, k, v))
    flops = 4 * b * hh * s * s * dh
    emit("kernel_attention_naive_1k", us,
         f"v5e_compute_us={flops/PEAK*1e6:.2f}")
    us = timeit(lambda: ops.attention(q, k, v, causal=True), n=1, warmup=1)
    emit("kernel_attention_flash_interp_1k", us,
         f"v5e_compute_us={flops/PEAK*1e6:.2f}")

    x = jax.random.normal(ks[0], (4096, 1024), jnp.bfloat16)
    scale = jnp.ones((1024,))
    norm_ref = jax.jit(lambda x, s: kref.rmsnorm(x, s))
    us = timeit(lambda: norm_ref(x, scale))
    t_model = 2 * x.size * 2 / HBM * 1e6  # 1 bf16 read + 1 bf16 write
    emit("kernel_rmsnorm_xla_4M", us, f"v5e_model_us={t_model:.1f}")
    us = timeit(lambda: ops.rmsnorm(x, scale), n=1, warmup=1)
    emit("kernel_rmsnorm_pallas_interp_4M", us,
         f"v5e_model_us={t_model:.1f}")


def bench_steps():
    from repro.configs import OptimizerConfig, get_config, reduced_config
    from repro.launch.train import build_train_setup

    for arch in ("resnet50", "llama3.2-1b", "mixtral-8x7b"):
        cfg = reduced_config(get_config(arch))
        model, state, step_fn, data, _, _ = build_train_setup(
            cfg, global_batch=8, seq_len=64,
            opt_cfg=OptimizerConfig(), steps_per_epoch=10)
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
        holder = {"state": state}

        def run():
            s, m = step_fn(holder["state"], dict(batch))
            holder["state"] = s  # step donates its input state
            return m["loss"]

        us = timeit(run, n=3, warmup=2)
        tokens = 8 * (64 if cfg.family != "conv" else 1)
        emit(f"step_train_reduced_{arch}", us,
             f"items_per_s={tokens/(us/1e6):.0f}")

    from repro.launch.serve import serve
    cfg = reduced_config(get_config("llama3.2-1b"))
    res = serve(cfg, batch=2, prompt_len=32, decode_steps=8)
    emit("step_decode_reduced_llama3.2-1b", res["decode_s"] / 7 * 1e6,
         f"tok_per_s={res['decode_tok_per_s']:.1f}")


def bench_figure1():
    """Paper Figure 1: iteration & all-reduce time vs #workers (weak
    scaling, 32 images/worker), from the measured dry-run per-image
    compute term + ring all-reduce wire model; fp32 vs paper's fp16."""
    import json
    import os
    rec_path = "results/dryrun/resnet50__train_32k__pod16x16.json"
    if not os.path.exists(rec_path):
        print("figure1: dry-run record missing; run launch/dryrun first")
        return
    r = json.load(open(rec_path))
    per_img_flops = r["hlo_flops_per_device"] * 256 / 32768
    p_bytes = 25.6e6 * 4  # fp32 gradient bytes
    compute_s = per_img_flops * 32 / PEAK
    for workers in (8, 16, 32, 64, 128, 256, 512, 1024):
        for wire, wbytes in (("f32", 4), ("f16", 2)):
            wire_bytes = p_bytes * wbytes / 4
            ar = 2 * wire_bytes * (workers - 1) / workers / ICI
            emit(f"figure1_iter_{workers}w_{wire}",
                 (compute_s + ar) * 1e6,
                 f"comm_us={ar*1e6:.0f};comm_frac={ar/(compute_s+ar):.2f}")


def bench_table1_proxy():
    """Paper Table 1 proxy: steps-to-loss-threshold, recipe vs baseline,
    batch scaled 16x (512) with linear-scaled LR."""
    from repro.configs import OptimizerConfig, get_config, reduced_config
    from repro.launch.train import build_train_setup

    cfg = reduced_config(get_config("resnet50"))
    for name, kind, schedule in (
            ("recipe_rmsprop_warmup", "rmsprop_warmup", "slow_start"),
            ("baseline_momentum_sgd", "momentum_sgd", "goyal")):
        opt_cfg = OptimizerConfig(kind=kind, schedule=schedule,
                                  beta_center=1.0, beta_period=1.0,
                                  warmup_epochs=1.0)
        model, state, step_fn, data, _, _ = build_train_setup(
            cfg, global_batch=512, seq_len=16, opt_cfg=opt_cfg,
            steps_per_epoch=10)
        t0 = time.perf_counter()
        steps_to_target = None
        final = None
        for s in range(40):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
            state, metrics = step_fn(state, batch)
            final = float(metrics["loss"])
            if steps_to_target is None and final < 0.7:
                steps_to_target = s
        wall = time.perf_counter() - t0
        emit(f"table1_{name}_b512", wall / 40 * 1e6,
             f"steps_to_0.7={steps_to_target};final_loss={final:.3f}")


def bench_roofline_summary():
    import os
    import sys
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks.roofline import table_rows
    rows = [r for r in table_rows("pod16x16") if r["status"] == "ok"]
    for r in rows:
        emit(f"roofline_{r['arch']}_{r['shape']}", r["bound_s"] * 1e6,
             f"dom={r['dominant']};roofl={100*r['roofline_fraction']:.2f}%")


def main() -> None:
    print("name,us_per_call,derived")
    bench_kernels()
    bench_steps()
    bench_figure1()
    bench_table1_proxy()
    bench_roofline_summary()


if __name__ == "__main__":
    main()
