"""Fused vs unfused batch-norm benchmark per ResNet stage shape.

Times one BN site — stats + normalize + epilogue forward, and
forward+VJP — for the fused Pallas path (kernels/fused_bn.py,
DESIGN.md §10) against the unfused jnp oracle (core/batchnorm.py +
epilogue), at NHWC shapes representative of the ResNet-50 stem and
stage0..3 block outputs (the residual+ReLU epilogue, the busiest site
kind). Writes a top-level ``BENCH_bn.json`` trajectory point with the
wall-clocks, speedups, and the HLO ``fusion_report`` op-count collapse
proof (launch/hlo_analysis.py); CI uploads it as an artifact and
tests/test_bench_schema.py pins the schema.

    PYTHONPATH=src python benchmarks/bn_bench.py [--quick] \
        [--out BENCH_bn.json]

CPU-interpret caveat (same as BENCH_step.json): off-TPU the Pallas
kernels run in interpret mode, whose lowered program is semantically
identical but not Mosaic-scheduled — wall-clock differences here
measure pass structure and XLA:CPU fusion luck, not TPU HBM traffic.
The transferable claim — the per-site reduction/elementwise op-count
collapse — is taken from the compiled HLO (``fusion_report``), not from
the clock.
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.kernels import ops, ref  # noqa: E402
from repro.launch.hlo_analysis import fusion_report  # noqa: E402

# (batch, hw, channels) per stage at block-output width; --quick shrinks
STAGE_SHAPES = {
    "stem": (8, 32, 64),
    "stage0": (8, 16, 256),
    "stage1": (8, 8, 512),
    "stage2": (8, 4, 1024),
    "stage3": (8, 2, 2048),
}
QUICK_SHAPES = {
    "stem": (2, 16, 32),
    "stage0": (2, 8, 64),
    "stage1": (2, 4, 128),
    "stage2": (2, 2, 256),
    "stage3": (2, 1, 512),
}


def _fused_site(x, scale, bias, res):
    return ops.fused_bn_train(x, scale, bias, residual=res, relu=True)[0]


def _unfused_site(x, scale, bias, res):
    return ref.bn_forward(x, scale, bias, residual=res, relu=True)[0]


def _fwdbwd(site):
    def prog(x, scale, bias, res, dy):
        y, vjp = jax.vjp(site, x, scale, bias, res)
        return (y,) + vjp(dy)
    return prog


def _time(fn, args, iters, warmup):
    out = None
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e3  # ms


def bench_shape(name, shape, *, iters, warmup, dtype=jnp.float32):
    b, hw, c = shape
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    x = jax.random.normal(ks[0], (b, hw, hw, c), dtype)
    res = jax.random.normal(ks[1], (b, hw, hw, c), dtype)
    scale = 1.0 + 0.1 * jax.random.normal(ks[2], (c,))
    bias = 0.1 * jax.random.normal(ks[3], (c,))
    dy = jax.random.normal(ks[4], (b, hw, hw, c), dtype)

    row = {"shape": [b, hw, hw, c]}
    fwd_args = (x, scale, bias, res)
    row["fused_fwd_ms"] = _time(jax.jit(_fused_site), fwd_args,
                                iters, warmup)
    row["unfused_fwd_ms"] = _time(jax.jit(_unfused_site), fwd_args,
                                  iters, warmup)
    bwd_args = fwd_args + (dy,)
    row["fused_fwdbwd_ms"] = _time(jax.jit(_fwdbwd(_fused_site)),
                                   bwd_args, iters, warmup)
    row["unfused_fwdbwd_ms"] = _time(jax.jit(_fwdbwd(_unfused_site)),
                                     bwd_args, iters, warmup)
    row["fwd_speedup"] = round(
        row["unfused_fwd_ms"] / row["fused_fwd_ms"], 3)
    row["fwdbwd_speedup"] = round(
        row["unfused_fwdbwd_ms"] / row["fused_fwdbwd_ms"], 3)
    for k in ("fused_fwd_ms", "unfused_fwd_ms", "fused_fwdbwd_ms",
              "unfused_fwdbwd_ms"):
        row[k] = round(row[k], 3)
    print(f"{name:<8} {str(row['shape']):<20} "
          f"fwd {row['unfused_fwd_ms']:>8.2f} -> {row['fused_fwd_ms']:>8.2f} ms "
          f"({row['fwd_speedup']:.2f}x)   "
          f"fwd+bwd {row['unfused_fwdbwd_ms']:>8.2f} -> "
          f"{row['fused_fwdbwd_ms']:>8.2f} ms "
          f"({row['fwdbwd_speedup']:.2f}x)", flush=True)
    return row


def site_fusion_report(shape, dtype=jnp.float32):
    """Lower one fwd+VJP BN site both ways; compare compiled-HLO op
    counts per site (the transferable, clock-independent claim)."""
    b, hw, c = shape
    act = b * hw * hw * c
    xs = jax.ShapeDtypeStruct((b, hw, hw, c), dtype)
    ss = jax.ShapeDtypeStruct((c,), jnp.float32)

    def lower(site):
        return jax.jit(_fwdbwd(site)).lower(
            xs, ss, ss, xs, xs).compile().as_text()

    return fusion_report(lower(_fused_site), lower(_unfused_site), act)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--warmup", type=int, default=3)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke settings (small shapes, few iters)")
    ap.add_argument("--out", default="BENCH_bn.json")
    args = ap.parse_args()
    shapes = STAGE_SHAPES
    if args.quick:
        shapes = QUICK_SHAPES
        args.iters = min(args.iters, 8)
        args.warmup = min(args.warmup, 2)

    print(f"backend={jax.default_backend()} "
          f"devices={jax.device_count()} iters={args.iters}")
    rows = {}
    for name, shape in shapes.items():
        rows[name] = bench_shape(name, shape, iters=args.iters,
                                 warmup=args.warmup)

    report = site_fusion_report(shapes["stage1"])
    result = {
        "bench": "bn_bench",
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "iters": args.iters,
        "epilogue": "residual+relu",
        "shapes": rows,
        "fusion_report": report,
        "caveat": (
            "CPU-interpret: off-TPU the Pallas kernels run in interpret "
            "mode and XLA:CPU fuses the unfused chain aggressively, so "
            "wall-clock deltas measure pass structure, not TPU HBM "
            "traffic; the transferable claim is the compiled-HLO "
            "per-site op-count collapse in fusion_report."),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"fusion_report: reductions/site "
          f"{report['reduction_ops_per_site']['unfused']:.0f} -> "
          f"{report['reduction_ops_per_site']['fused']:.0f}, "
          f"activation writes {report['activation_writes_per_site']['unfused']:.0f}"
          f" -> {report['activation_writes_per_site']['fused']:.0f}, "
          f"collapsed={report['collapsed']} -> wrote {args.out}")


if __name__ == "__main__":
    main()
