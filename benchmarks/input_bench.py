"""Input-pipeline benchmark: host feed throughput and boundedness.

Measures the production input pipeline (DESIGN.md §15) in isolation:

  workers       batches/sec of the multi-worker host feed at 1..N
                producer threads. The step-claiming pool is
                embarrassingly parallel across steps, but the synthetic
                generator's per-sample Python loop holds the GIL, so on
                this source aggregate throughput stays ~flat; the pool's
                real win — overlapping host feed with device compute,
                which releases the GIL — is measured end-to-end by the
                data_starved_frac attribution in BENCH_step.json
  host_shard    per-host generation cost when each host produces only
                its 1/N slice of the global batch — the sharded source
                does ~1/N the work, which is what keeps host feed time
                flat as the paper's cluster scales to 1024 workers
  transform     host-side augment+normalize (AugmentedSource, numpy)
                vs the fused on-device Pallas pass per batch

and writes a top-level ``BENCH_input.json`` (CI uploads it as an
artifact; its schema is pinned by tests/test_bench_schema.py).

    PYTHONPATH=src python benchmarks/input_bench.py [--quick] \
        [--out BENCH_input.json]

Host caveat: on this container the fused kernel runs in Pallas
interpret mode (Python-executed kernel body), so ``transform.fused_ms``
measures dispatch structure, not TPU kernel time; the kernel's
correctness against ref.input_forward is what the test suite pins.
"""
import argparse
import json
import os
import sys
import time

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.data.pipeline import AugmentedSource, DataPipeline  # noqa: E402
from repro.data.synthetic import SyntheticImageData  # noqa: E402
from repro.kernels import ops  # noqa: E402

MEAN = (0.0, 0.0, 0.0)
STD = (1.0, 1.0, 1.0)


def _drain(pipe, n):
    for _ in range(n):
        next(pipe)


def bench_workers(batch, image_size, iters, worker_counts):
    src = SyntheticImageData(10, image_size, batch, seed=0)
    out = {}
    for w in worker_counts:
        pipe = DataPipeline(src, num_workers=w, depth=max(4, 2 * w))
        try:
            _drain(pipe, 4)  # warm threads; fill then re-drain the buffer
            # so the timed window measures steady-state producer rate,
            # not a one-time drain of the prefilled ring
            t0 = time.perf_counter()
            _drain(pipe, iters)
            dt = (time.perf_counter() - t0) / iters
        finally:
            pipe.close()
        out[str(w)] = {"ms_per_batch": round(dt * 1e3, 3),
                       "batches_per_s": round(1.0 / dt, 3)}
        print(f"workers={w:<2} {dt * 1e3:8.1f} ms/batch "
              f"{1.0 / dt:7.2f} batches/s", flush=True)
    out["note"] = ("synthetic generation is GIL-bound Python, so thread "
                   "workers do not raise aggregate host throughput here; "
                   "their benefit is overlap with device compute — see "
                   "data_starved_frac in BENCH_step.json")
    return out


def bench_host_shard(batch, image_size, iters, num_hosts):
    def time_source(b, offset):
        src = SyntheticImageData(10, image_size, b, seed=0,
                                 sample_offset=offset)
        src.batch_at(0)  # warm (templates already built in __init__)
        t0 = time.perf_counter()
        for i in range(iters):
            src.batch_at(i)
        return (time.perf_counter() - t0) / iters

    full = time_source(batch, 0)
    shard = time_source(batch // num_hosts, batch // num_hosts)
    print(f"host shard: full {full * 1e3:.1f} ms, 1/{num_hosts} shard "
          f"{shard * 1e3:.1f} ms", flush=True)
    return {"num_hosts": num_hosts,
            "global_ms_per_batch": round(full * 1e3, 3),
            "shard_ms_per_batch": round(shard * 1e3, 3),
            "shard_speedup": round(full / shard, 3)}


def bench_transform(batch, image_size, iters):
    src = SyntheticImageData(10, image_size, batch, seed=0)
    aug = AugmentedSource(src, seed=0, mean=MEAN, std=STD,
                          global_batch=batch)
    aug.batch_at(0)
    t0 = time.perf_counter()
    for i in range(iters):
        aug.batch_at(i)
    host_full = (time.perf_counter() - t0) / iters
    t0 = time.perf_counter()
    for i in range(iters):
        src.batch_at(i)
    raw = (time.perf_counter() - t0) / iters
    host_ms = max(0.0, host_full - raw)  # transform cost net of generation

    x = jnp.asarray(src.batch_at(0)["images"])
    mean = jnp.asarray(MEAN, jnp.float32)
    inv = 1.0 / jnp.asarray(STD, jnp.float32)
    params = ops.input_augment_params(0, 0, batch)

    def fused(step_x):
        return ops.fused_input_train(step_x, params, mean, inv,
                                     out_dtype=jnp.bfloat16)

    jax.block_until_ready(fused(x))  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fused(x))
    fused_ms = (time.perf_counter() - t0) / iters
    note = ("Pallas interpret mode on CPU: fused_ms measures dispatch, "
            "not TPU kernel time"
            if jax.default_backend() != "tpu" else "compiled TPU kernel")
    print(f"transform: host {host_ms * 1e3:.1f} ms, fused "
          f"{fused_ms * 1e3:.1f} ms ({note})", flush=True)
    return {"host_aug_ms": round(host_ms * 1e3, 3),
            "fused_ms": round(fused_ms * 1e3, 3),
            "note": note}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--image-size", type=int, default=128,
                    help="128 by default: large enough per-sample numpy "
                         "work that generation releases the GIL and "
                         "worker threads overlap (at toy 32px sizes the "
                         "per-sample Python loop serializes on the GIL)")
    ap.add_argument("--iters", type=int, default=20)
    ap.add_argument("--num-hosts", type=int, default=4)
    ap.add_argument("--max-workers", type=int, default=4)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke settings (fewer iterations)")
    ap.add_argument("--out", default="BENCH_input.json")
    args = ap.parse_args()
    if args.quick:
        args.iters = min(args.iters, 6)

    counts = [1]
    w = 2
    while w <= args.max_workers:
        counts.append(w)
        w *= 2
    print(f"backend={jax.default_backend()} batch={args.batch} "
          f"image={args.image_size} iters={args.iters}")
    workers = bench_workers(args.batch, args.image_size, args.iters,
                            counts)
    best = max(counts)
    result = {
        "bench": "input_bench",
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "batch": args.batch,
        "image_size": args.image_size,
        "iters": args.iters,
        "workers": workers,
        "multi_worker_speedup": round(
            workers["1"]["ms_per_batch"]
            / workers[str(best)]["ms_per_batch"], 3),
        "host_shard": bench_host_shard(args.batch, args.image_size,
                                       args.iters, args.num_hosts),
        "transform": bench_transform(args.batch, args.image_size,
                                     max(3, args.iters // 2)),
    }
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"multi-worker speedup {result['multi_worker_speedup']:.2f}x "
          f"({best} workers) -> wrote {args.out}")


if __name__ == "__main__":
    main()
