"""Chaos soak: drive every fault class through the full recovery path
and report recovery metrics (DESIGN.md §13).

Each scenario runs the epoch-driven Trainer on the reduced synthetic
proxy with a deterministic ``--chaos`` spec (resilience/chaos.py) and a
fresh checkpoint dir, all from the same init/data/jitted step, then
checks that the expected recovery events fired, the run completed, and
the final validation top-1 stayed within tolerance of the fault-free
baseline (a skipped batch or replayed window shifts the trajectory, so
parity is a tolerance, not an equality). Emits a JSON artifact:

    {"meta": {...}, "baseline_top1": float,
     "scenarios": {name: {"chaos", "completed", "final_top1",
                          "top1_delta", "within_tolerance",
                          "skipped_steps", "rollbacks", "wasted_steps",
                          "steps_to_recover", "events", "ok", ...}},
     "all_ok": bool}

Exits nonzero if any scenario fails — CI treats a recovery regression
like a test failure.

    PYTHONPATH=src python benchmarks/resilience_bench.py --quick \
        --out BENCH_resilience.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import OptimizerConfig, get_config, reduced_config  # noqa: E402
from repro.launch.train import build_eval_setup, build_train_setup  # noqa: E402
from repro.resilience import ResilienceConfig, parse_chaos  # noqa: E402
from repro.training import Trainer, TrainerConfig  # noqa: E402

K_BAD = 3  # max_consecutive_bad in every scenario


def scenarios(ckpt_every: int):
    """Chaos specs placed relative to the checkpoint cadence so each
    scenario exercises its intended path (E = ckpt_every):

    * rollback needs K_BAD consecutive NaN steps right after the save
      at 2E, so the rollback target is the step-2E checkpoint;
    * ckpt_corrupt additionally truncates that newest checkpoint (the
      trigger at 2E-1 fires on the save completing at 2E), forcing the
      restore to fall back to the step-E checkpoint.
    """
    e = ckpt_every
    return {
        "baseline": {"chaos": None,
                     "expect": [], "forbid": ["step_skipped", "rollback",
                                              "data_restart"]},
        "nan_bucket": {"chaos": f"nan_grad@{e + 2}",
                       "expect": ["chaos_injected", "step_skipped"],
                       "forbid": ["rollback"]},
        "rollback": {"chaos": f"nan_grad@{2 * e + 1}-{2 * e + K_BAD}",
                     "expect": ["step_skipped", "rollback"],
                     "forbid": []},
        "ckpt_corrupt": {"chaos": (f"ckpt_truncate@{2 * e - 1},"
                                   f"nan_grad@{2 * e + 1}-{2 * e + K_BAD}"),
                         "expect": ["corrupt_checkpoint_skipped",
                                    "rollback"],
                         "forbid": []},
        "data_crash": {"chaos": f"data_crash@{e + 1}",
                       "expect": ["data_restart"],
                       "forbid": ["rollback"]},
        "straggler": {"chaos": f"straggler@{e}:0.3,data_stall@{2 * e}:0.3",
                      "expect": ["chaos_injected"],
                      "forbid": ["step_skipped", "rollback"]},
    }


def run_scenario(name, spec, setup, args) -> dict:
    train_step, host_state0, data, put_batch, eval_pieces = setup
    eval_step, val_data, finalize = eval_pieces
    state = jax.tree.map(jnp.asarray, host_state0)  # fresh init per run
    t0 = time.time()
    rec = {"chaos": spec["chaos"], "completed": False,
           "final_top1": None, "skipped_steps": 0, "rollbacks": 0,
           "wasted_steps": 0, "steps_to_recover": 0, "events": {}}
    with tempfile.TemporaryDirectory() as ckpt_dir:
        tcfg = TrainerConfig(
            epochs=args.epochs, steps_per_epoch=args.steps_per_epoch,
            eval_every_epochs=1, val_batches=args.val_batches,
            checkpoint_every=args.ckpt_every, checkpoint_dir=ckpt_dir,
            log_every=args.steps_per_epoch)
        resilience = ResilienceConfig(max_consecutive_bad=K_BAD)
        chaos = (parse_chaos(spec["chaos"], seed=args.seed)
                 if spec["chaos"] else None)
        try:
            result = Trainer(train_step, state, data, tcfg,
                             eval_step=eval_step, val_data=val_data,
                             finalize_state=finalize, put_batch=put_batch,
                             resilience=resilience, chaos=chaos).run()
        except Exception as e:  # a scenario crash is a failed scenario
            rec["error"] = f"{type(e).__name__}: {e}"
            rec["ok"] = False
            rec["wall_s"] = time.time() - t0
            return rec
    rec["completed"] = True
    for r in result.events:
        rec["events"][r["kind"]] = rec["events"].get(r["kind"], 0) + 1
    rec["skipped_steps"] = rec["events"].get("step_skipped", 0)
    rollbacks = [r for r in result.events if r["kind"] == "rollback"]
    rec["rollbacks"] = len(rollbacks)
    rec["wasted_steps"] = sum(r["wasted_steps"] for r in rollbacks)
    # total extra step budget the faults cost: abandoned batches plus
    # replayed windows
    rec["steps_to_recover"] = rec["skipped_steps"] + rec["wasted_steps"]
    if result.epoch_history:
        rec["final_top1"] = result.epoch_history[-1].get("top1")
    missing = [k for k in spec["expect"] if k not in rec["events"]]
    fired = [k for k in spec["forbid"] if k in rec["events"]]
    rec["ok"] = not missing and not fired
    if missing:
        rec["missing_events"] = missing
    if fired:
        rec["forbidden_events"] = fired
    rec["wall_s"] = time.time() - t0
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="resnet50")
    ap.add_argument("--epochs", type=int, default=3)
    ap.add_argument("--steps-per-epoch", type=int, default=8)
    ap.add_argument("--ckpt-every", type=int, default=4)
    ap.add_argument("--global-batch", type=int, default=32)
    ap.add_argument("--val-batches", type=int, default=2)
    ap.add_argument("--data-noise", type=float, default=2.0)
    # a fault costs a minibatch or a replayed window, so final accuracy
    # is trajectory-shifted, not bit-equal; the soak asserts it stays
    # within this band of the fault-free run
    ap.add_argument("--tolerance", type=float, default=0.2)
    ap.add_argument("--quick", action="store_true",
                    help="2 epochs x 6 steps (CI fast lane)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_resilience.json")
    args = ap.parse_args()
    if args.quick:
        args.epochs, args.steps_per_epoch, args.ckpt_every = 2, 6, 3

    cfg = reduced_config(get_config(args.arch))
    opt_cfg = OptimizerConfig(kind="momentum_sgd", schedule="constant")
    model, state, train_step, data, put_batch, _ = build_train_setup(
        cfg, global_batch=args.global_batch, seq_len=16, opt_cfg=opt_cfg,
        steps_per_epoch=args.steps_per_epoch, seed=args.seed,
        data_noise=args.data_noise, sentinel=True)
    eval_pieces = build_eval_setup(
        model, cfg, global_batch=args.global_batch, seq_len=16,
        seed=args.seed, data_noise=args.data_noise)
    # one host snapshot of the init: the jitted step donates its input
    # state, so every scenario re-materializes fresh device buffers from
    # this copy (and reuses the compiled program)
    host_state0 = jax.tree.map(lambda x: np.array(x), state)
    setup = (train_step, host_state0, data, put_batch, eval_pieces)

    specs = scenarios(args.ckpt_every)
    out = {"meta": {"arch": args.arch, "epochs": args.epochs,
                    "steps_per_epoch": args.steps_per_epoch,
                    "ckpt_every": args.ckpt_every,
                    "global_batch": args.global_batch,
                    "data_noise": args.data_noise,
                    "tolerance": args.tolerance, "quick": args.quick,
                    "seed": args.seed, "max_consecutive_bad": K_BAD},
           "scenarios": {}}
    baseline_top1 = None
    for name, spec in specs.items():
        rec = run_scenario(name, spec, setup, args)
        if name == "baseline":
            baseline_top1 = rec["final_top1"]
            rec["ok"] = rec["ok"] and baseline_top1 is not None
        if baseline_top1 is not None and rec["final_top1"] is not None:
            rec["top1_delta"] = rec["final_top1"] - baseline_top1
            rec["within_tolerance"] = (abs(rec["top1_delta"])
                                       <= args.tolerance)
            rec["ok"] = rec["ok"] and rec["within_tolerance"]
        print(f"{name}: ok={rec['ok']} events={rec['events']} "
              f"top1={rec['final_top1']} ({rec['wall_s']:.1f}s)",
              flush=True)
        out["scenarios"][name] = rec
    out["baseline_top1"] = baseline_top1
    out["all_ok"] = all(r["ok"] for r in out["scenarios"].values())
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
    print(f"wrote {args.out} (all_ok={out['all_ok']})")
    if not out["all_ok"]:
        sys.exit(1)


if __name__ == "__main__":
    main()
