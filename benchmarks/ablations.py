"""Ablation suite for the paper's recipe components (Appendix A claims).

The paper justifies each ingredient qualitatively; this reproduces the
comparisons directionally at proxy scale (reduced ResNet-50, synthetic
classification, batch scaled with the linear rule):

  * transition shape: ELU (paper) vs sudden (paper: "severely impacts
    training") vs linear ("similar problem at the beginning") vs sigmoid
    ("performed similarly" to ELU)
  * optimizer family: rmsprop_warmup vs momentum SGD vs LARS ([10]'s
    approach at B=16k)
  * LR schedule: slow-start (paper) vs Goyal warmup

    PYTHONPATH=src python -m benchmarks.ablations
"""
from __future__ import annotations

import numpy as np

GLOBAL_BATCH = 256
LR_SCALE = 24.0
STEPS = 30
TRANSITION_STEP = 10  # beta_center=1.0 epoch x 10 steps/epoch


def train_once(kind="rmsprop_warmup", schedule="constant",
               transition="elu", steps=STEPS, seed=0):
    import jax.numpy as jnp

    from repro.configs import OptimizerConfig, get_config, reduced_config
    from repro.launch.train import build_train_setup

    cfg = reduced_config(get_config("resnet50"))
    opt_cfg = OptimizerConfig(
        kind=kind, schedule=schedule, transition=transition,
        base_lr_per_256=0.1 * LR_SCALE,
        beta_center=1.0, beta_period=1.0, warmup_epochs=1.0)
    model, state, step_fn, data, _, _ = build_train_setup(
        cfg, global_batch=GLOBAL_BATCH, seq_len=16, opt_cfg=opt_cfg,
        steps_per_epoch=10, seed=seed)
    losses = []
    for s in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    return losses


def _fmt(losses):
    tail = [l for l in losses[-5:] if np.isfinite(l)]
    worst = max((l for l in losses if np.isfinite(l)), default=float("inf"))
    # paper A.1: a sudden RMSprop->SGD switch shocks the optimization at
    # the transition point — measure the spike right after it
    pre = losses[TRANSITION_STEP - 1]
    post = [l for l in losses[TRANSITION_STEP:TRANSITION_STEP + 5]
            if np.isfinite(l)]
    spike = (max(post) - pre) if post and np.isfinite(pre) else float("inf")
    if not tail:
        return "diverged", worst, spike
    return f"{np.mean(tail):.3f}", worst, spike


def main():
    print(f"# ablations @ global_batch={GLOBAL_BATCH}, "
          f"lr_scale={LR_SCALE}x, {STEPS} steps")
    print(f"{'variant':38s} {'final':>9s} {'peak loss':>10s} "
          f"{'transition spike':>17s}")

    rows = [
        ("transition=elu (paper)", dict(transition="elu")),
        ("transition=sigmoid", dict(transition="sigmoid")),
        ("transition=linear", dict(transition="linear")),
        ("transition=sudden", dict(transition="sudden")),
        ("optimizer=momentum_sgd", dict(kind="momentum_sgd")),
        ("optimizer=lars", dict(kind="lars")),
        ("schedule=slow_start (paper)", dict(schedule="slow_start")),
        ("schedule=goyal_warmup", dict(schedule="goyal")),
    ]
    for name, kw in rows:
        final, worst, spike = _fmt(train_once(**kw))
        print(f"{name:38s} {final:>9s} {worst:10.3f} {spike:17.3f}")


if __name__ == "__main__":
    main()
