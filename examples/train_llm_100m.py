"""End-to-end driver: train a ~100M-parameter llama-style LM for a few
hundred steps on the synthetic token task with the paper's recipe +
compressed gradient communication, with checkpoint/resume.

    PYTHONPATH=src python examples/train_llm_100m.py --steps 200
"""
import argparse
import dataclasses
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402

from repro.configs import OptimizerConfig, get_config  # noqa: E402
from repro.launch.train import build_train_setup  # noqa: E402
from repro.training import LoopConfig, run_training  # noqa: E402


def lm_100m():
    """~100M params: llama3.2-style block at width 512."""
    base = get_config("llama3.2-1b")
    return dataclasses.replace(
        base, name="llama-100m", n_layers=8, d_model=512, n_heads=8,
        n_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32768,
        tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = lm_100m()
    opt_cfg = OptimizerConfig(kind="rmsprop_warmup", schedule="slow_start",
                              base_lr_per_256=3e-3,
                              beta_center=1.0, beta_period=1.0,
                              weight_decay=0.0)
    model, state, train_step, data, _, _ = build_train_setup(
        cfg, global_batch=args.global_batch, seq_len=args.seq_len,
        opt_cfg=opt_cfg, steps_per_epoch=50,
        compute_dtype=jnp.float32, attention_impl="chunked")
    from repro.models.common import count_params
    print(f"params: {count_params(state['params'])/1e6:.1f}M")

    ckpt = args.ckpt_dir or tempfile.mkdtemp(prefix="llm100m_ckpt_")
    result = run_training(
        train_step, state, data,
        LoopConfig(total_steps=args.steps, checkpoint_every=100,
                   checkpoint_dir=ckpt,
                   log_every=max(1, args.steps // 10)))
    for h in result.history:
        print(f"  step {h['step']:4d}  loss {h['loss']:.4f}  "
              f"({h['time']*1e3:.0f} ms)")
    print(f"checkpoints: {ckpt} (resume by re-running with --ckpt-dir)")


if __name__ == "__main__":
    main()
