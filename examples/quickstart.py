"""Quickstart: train a reduced ResNet-50 with the paper's full recipe
(RMSprop warm-up + slow-start LR + BN without moving averages) on the
synthetic ImageNet-like task, with held-out validation every epoch —
the paper's actual protocol (its headline claim is a validation top-1).

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import OptimizerConfig, get_config, reduced_config  # noqa: E402
from repro.launch.train import build_eval_setup, build_train_setup  # noqa: E402
from repro.training import Trainer, TrainerConfig  # noqa: E402


def main():
    cfg = reduced_config(get_config("resnet50"))
    opt_cfg = OptimizerConfig(
        kind="rmsprop_warmup",  # the paper's hybrid optimizer (A.1)
        schedule="slow_start",  # the paper's LR schedule (A.2)
        beta_center=2.0, beta_period=1.0,  # scaled to this tiny run
    )
    model, state, train_step, data, put_batch, shardings = \
        build_train_setup(cfg, global_batch=64, seq_len=16,
                          opt_cfg=opt_cfg, steps_per_epoch=10)
    # held-out split (disjoint from train by seed-space construction) +
    # the pre-validation BN finalize path (DESIGN.md §7)
    eval_step, val_data, finalize = build_eval_setup(
        model, cfg, global_batch=64, seq_len=16)

    ckpt_dir = tempfile.mkdtemp(prefix="quickstart_ckpt_")
    result = Trainer(
        train_step, state, data,
        TrainerConfig(epochs=6, steps_per_epoch=10, eval_every_epochs=1,
                      val_batches=2, checkpoint_every=30,
                      checkpoint_dir=ckpt_dir, log_every=10),
        eval_step=eval_step, val_data=val_data, finalize_state=finalize,
        put_batch=put_batch).run()

    print("held-out accuracy per epoch:")
    for r in result.epoch_history:
        print(f"  epoch {r['epoch']:2d}  top1 {r['top1']:.3f}  "
              f"val loss {r['loss']:.4f}")
    print(f"best: top1 {result.best['top1']:.3f} at epoch "
          f"{result.best['epoch']} (retained in {ckpt_dir}/best)")


if __name__ == "__main__":
    main()
