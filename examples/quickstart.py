"""Quickstart: train a reduced ResNet-50 with the paper's full recipe
(RMSprop warm-up + slow-start LR + BN without moving averages) on the
synthetic ImageNet-like task, checkpoint, and evaluate.

    PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402

from repro.configs import OptimizerConfig, get_config, reduced_config  # noqa: E402
from repro.launch.train import build_train_setup  # noqa: E402
from repro.training import LoopConfig, run_training  # noqa: E402


def main():
    cfg = reduced_config(get_config("resnet50"))
    opt_cfg = OptimizerConfig(
        kind="rmsprop_warmup",  # the paper's hybrid optimizer (A.1)
        schedule="slow_start",  # the paper's LR schedule (A.2)
        beta_center=2.0, beta_period=1.0,  # scaled to this tiny run
    )
    model, state, train_step, data, _, _ = build_train_setup(
        cfg, global_batch=64, seq_len=16, opt_cfg=opt_cfg,
        steps_per_epoch=10)

    ckpt_dir = tempfile.mkdtemp(prefix="quickstart_ckpt_")
    result = run_training(
        train_step, state, data,
        LoopConfig(total_steps=60, checkpoint_every=30,
                   checkpoint_dir=ckpt_dir, log_every=10))
    print("loss curve:")
    for h in result.history:
        print(f"  step {h['step']:3d}  loss {h['loss']:.4f}")

    # validation uses the last-minibatch BN stats (paper §2)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(999).items()}
    acc = model.eval_fn(result.state["params"],
                        result.state["model_state"], batch)
    print(f"eval accuracy on a fresh batch: {float(acc):.3f}")
    print(f"checkpoints in {ckpt_dir}")


if __name__ == "__main__":
    main()
