"""Serve a reduced LM with batched requests: prefill + KV-cache decode.
Demonstrates the serving substrate used by the decode_32k/long_500k
dry-run cells (ring-buffer SWA caches, SSM states, enc-dec caches).

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config, reduced_config  # noqa: E402
from repro.launch.serve import serve  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b",
                    help="any assigned arch id (reduced config)")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    res = serve(cfg, args.batch, args.prompt_len, args.decode_steps)
    print(f"arch={args.arch} (reduced)")
    print(f"prefill: {res['prefill_s']*1e3:8.1f} ms for "
          f"{args.batch}x{args.prompt_len} tokens")
    print(f"decode : {res['decode_tok_per_s']:8.1f} tok/s")
    for i, row in enumerate(res["generated"][:2]):
        print(f"  sample[{i}] tokens: {row[:10]}")


if __name__ == "__main__":
    main()
