"""Batch-scaling sweep: the paper's central claim as a measurement
harness. Scale the global batch with the linear LR rule and compare the
recipes per batch size:

  * ``paper_baseline`` — the paper's hybrid RMSprop warm-up +
    slow-start LR (arXiv:1711.04325 §2);
  * ``lars`` — layer-wise trust ratios (You et al., the paper's Table 1
    competitor [10] at B=16k), run through the packed-stream LARS path
    when a mesh is available (DESIGN.md §11);
  * ``lars_ls_poly`` — LARS + label smoothing + polynomial LR decay,
    the standard >=32k-batch recipe.

Each (recipe, batch) cell trains a reduced ResNet-50 on the synthetic
class-template task and records the tail loss/accuracy, emitting
``BENCH_scaling.json`` (schema pinned by tests/test_bench_schema.py;
``--quick`` runs the CI-sized grid).

    PYTHONPATH=src python examples/large_batch_sweep.py [--quick] \
        [--out BENCH_scaling.json]
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import OptimizerConfig, get_config, reduced_config  # noqa: E402
from repro.launch.train import build_train_setup  # noqa: E402

# recipe -> (optimizer kind, LR schedule, label smoothing). The batch
# points below proxy the paper's 256 -> 32k scaling range: lr_scale is
# the linear-rule multiplier on base_lr_per_256, so lr_scale ~ B/256 of
# the full-size run each point stands in for.
RECIPES = {
    "paper_baseline": ("rmsprop_warmup", "slow_start", 0.0),
    "lars": ("lars", "slow_start", 0.0),
    "lars_ls_poly": ("lars", "poly", 0.1),
}

# (global_batch, lr_scale): reduced-config proxies for 256 -> 32k
POINTS_FULL = ((32, 1.0), (64, 2.0), (128, 8.0), (256, 24.0))
POINTS_QUICK = ((32, 1.0), (64, 2.0), (128, 8.0))


def train_once(kind, schedule, label_smoothing, global_batch, lr_scale,
               steps, steps_per_epoch):
    cfg = reduced_config(get_config("resnet50"))
    opt_cfg = OptimizerConfig(kind=kind, schedule=schedule,
                              base_lr_per_256=0.1 * lr_scale,
                              beta_center=1.0, beta_period=1.0,
                              warmup_epochs=1.0,
                              total_epochs=max(1.0,
                                               steps / steps_per_epoch))
    model, state, step_fn, data, _, _ = build_train_setup(
        cfg, global_batch=global_batch, seq_len=16, opt_cfg=opt_cfg,
        steps_per_epoch=steps_per_epoch,
        label_smoothing=label_smoothing)
    losses, accs = [], []
    for s in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
        accs.append(float(metrics["accuracy"]))
    return losses, accs


def _tail(values, losses):
    """Mean over the last-5 finite-loss steps; None once diverged."""
    tail = [v for v, l in zip(values[-5:], losses[-5:]) if np.isfinite(l)]
    return float(np.mean(tail)) if tail else None


def run_sweep(quick: bool, steps: int, steps_per_epoch: int):
    points = POINTS_QUICK if quick else POINTS_FULL
    recipes = []
    print(f"{'recipe':>14s} {'batch':>6s} {'lr_scale':>9s} "
          f"{'final loss':>11s} {'final top1':>11s}")
    for name, (kind, schedule, ls_eps) in RECIPES.items():
        rows = []
        for batch, lr_scale in points:
            losses, accs = train_once(kind, schedule, ls_eps, batch,
                                      lr_scale, steps, steps_per_epoch)
            final_loss = _tail(losses, losses)
            final_acc = _tail(accs, losses)
            diverged = final_loss is None
            rows.append({"global_batch": batch, "lr_scale": lr_scale,
                         "final_loss": final_loss,
                         "final_accuracy": final_acc,
                         "diverged": diverged})
            fl = "diverged" if diverged else f"{final_loss:.3f}"
            fa = "-" if final_acc is None else f"{final_acc:.3f}"
            print(f"{name:>14s} {batch:6d} {lr_scale:9.1f} {fl:>11s} "
                  f"{fa:>11s}", flush=True)
        recipes.append({"recipe": name, "optimizer": kind,
                        "schedule": schedule,
                        "label_smoothing": ls_eps, "points": rows})
    return {
        "bench": "scaling_sweep",
        "arch": "resnet50-reduced",
        "backend": jax.default_backend(),
        "devices": jax.device_count(),
        "quick": quick,
        "steps": steps,
        "steps_per_epoch": steps_per_epoch,
        "batches": [b for b, _ in points],
        "recipes": recipes,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized grid: fewer points, fewer steps")
    ap.add_argument("--steps", type=int, default=None,
                    help="steps per cell (default: 30, or 10 w/ --quick)")
    ap.add_argument("--steps-per-epoch", type=int, default=10)
    ap.add_argument("--out", default="BENCH_scaling.json")
    args = ap.parse_args()
    steps = args.steps or (10 if args.quick else 30)

    result = run_sweep(args.quick, steps, args.steps_per_epoch)
    with open(args.out, "w") as f:
        json.dump(result, f, indent=1)
    print(f"\nwrote {args.out}")
    print("expected: at high lr_scale the trust-ratio recipes stay "
          "stable/lower while the warm-up-only baseline degrades first.")


if __name__ == "__main__":
    main()
