"""The paper's central claim, directionally: scale the global batch with
the linear LR rule and compare plain momentum SGD (Goyal recipe) against
the paper's RMSprop warm-up + slow-start — the hybrid stays stable where
SGD degrades (paper §2: 'optimization difficulty at the start of
training').

    PYTHONPATH=src python examples/large_batch_sweep.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import OptimizerConfig, get_config, reduced_config  # noqa: E402
from repro.launch.train import build_train_setup  # noqa: E402


def train_once(kind, schedule, global_batch, lr_scale, steps=30):
    cfg = reduced_config(get_config("resnet50"))
    opt_cfg = OptimizerConfig(kind=kind, schedule=schedule,
                              base_lr_per_256=0.1 * lr_scale,
                              beta_center=1.0, beta_period=1.0,
                              warmup_epochs=1.0)
    model, state, step_fn, data, _, _ = build_train_setup(
        cfg, global_batch=global_batch, seq_len=16, opt_cfg=opt_cfg,
        steps_per_epoch=10)
    losses = []
    for s in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    return losses


def main():
    print(f"{'batch':>6s} {'lr_scale':>9s} {'sgd final':>10s} "
          f"{'hybrid final':>13s}")
    for batch, lr_scale in ((32, 1.0), (128, 8.0), (256, 24.0)):
        sgd = train_once("momentum_sgd", "constant", batch, lr_scale)
        hyb = train_once("rmsprop_warmup", "constant", batch, lr_scale)

        def final(ls):
            tail = [l for l in ls[-5:] if np.isfinite(l)]
            return f"{np.mean(tail):.3f}" if tail else "diverged"

        print(f"{batch:6d} {lr_scale:9.1f} {final(sgd):>10s} "
              f"{final(hyb):>13s}")
    print("\nexpected: at high lr_scale the hybrid (paper recipe) stays "
          "stable/lower while plain SGD degrades or diverges.")


if __name__ == "__main__":
    main()
