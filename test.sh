#!/usr/bin/env bash
# Tier-1 test entrypoint (SNIPPETS.md idiom): virtual 8-device host
# platform + src on PYTHONPATH. Multi-device tests additionally spawn
# subprocesses with their own XLA_FLAGS, so they pass either way.
# Collects the whole tests/ tree — including the epoch-driven trainer /
# validation suite (tests/test_trainer.py) and the loop/prefetcher/
# checkpoint regression tests — as tier-1. CI splits this into a fast
# job (`./test.sh -m "not slow"`) and a mesh-parity job
# (`./test.sh -m slow`); a plain run still executes everything.
set -euo pipefail
cd "$(dirname "$0")"
export XLA_FLAGS="${XLA_FLAGS:---xla_force_host_platform_device_count=8}"
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
exec python -m pytest -x -q "$@"
