"""Backward-overlapped bucketed all-reduce (DESIGN.md §8).

Single-process tests cover the staged-apply oracle (chained per-segment
VJPs == monolithic AD, bitwise) and the ready-order BucketPlan
(hypothesis round-trip). The step-level equivalence — overlapped ==
non-overlapped bucketed, bitwise, plain + error-feedback — and the HLO
interleaving proof run in subprocesses on virtual host meshes, like
tests/test_bucketing.py.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.bucketing import (
    pack,
    pack_bucket,
    plan_ready_buckets,
    unpack,
)

ENV8 = {
    **os.environ,
    "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
    "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src"),
}
ENV2 = {**ENV8, "XLA_FLAGS": "--xla_force_host_platform_device_count=2"}


def run_py(body: str, env=ENV8, timeout=420) -> str:
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert res.returncode == 0, f"STDERR:\n{res.stderr[-4000:]}"
    return res.stdout


# ---------------------------------------------------------------------------
# staged apply == monolithic AD (single device, bitwise)
# ---------------------------------------------------------------------------


def _leaves_by_path(tree):
    return {jax.tree_util.keystr(k): np.asarray(v)
            for k, v in jax.tree_util.tree_leaves_with_path(tree)}


def _assert_trees_bitwise(t1, t2, what=""):
    d1, d2 = _leaves_by_path(t1), _leaves_by_path(t2)
    assert set(d1) == set(d2), (what, set(d1) ^ set(d2))
    for k in d1:
        np.testing.assert_array_equal(d1[k], d2[k], err_msg=f"{what}{k}")


@pytest.mark.parametrize("arch", ["resnet50", "llama3.2-1b"])
def test_staged_grads_bitwise_equal_monolithic(arch):
    """Chained per-segment VJPs must emit the same primitives as
    reverse-mode AD of the composite loss — loss, grads, and (for BN
    models) the new model_state all bitwise-equal. llama3.2-1b ties its
    embeddings, so this also pins the carry-passthrough gradient path
    for the shared table."""
    from repro.configs import get_config, reduced_config
    from repro.models import build_model, init_model_state
    from repro.models.common import staged_value_and_grad

    cfg = reduced_config(get_config(arch))
    model = build_model(cfg, compute_dtype=jnp.float32)
    params, _ = model.init_params(jax.random.PRNGKey(0))
    mstate = init_model_state(model)
    if cfg.family == "conv":
        batch = {"images": jax.random.normal(
            jax.random.PRNGKey(1), (8, 32, 32, 3)),
            "labels": jax.random.randint(
                jax.random.PRNGKey(2), (8,), 0, cfg.num_classes)}
    else:
        assert cfg.tie_embeddings  # the interesting case
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab_size),
            "targets": jax.random.randint(
                jax.random.PRNGKey(2), (4, 32), 0, cfg.vocab_size)}

    (l1, (ns1, _)), g1 = jax.jit(jax.value_and_grad(
        lambda p: model.loss_fn(p, mstate, batch, 0.1),
        has_aux=True))(params)
    l2, (ns2, _), g2 = jax.jit(lambda p: staged_value_and_grad(
        model.loss_segments(p, mstate, batch, 0.1)))(params)

    assert float(l1) == float(l2)
    _assert_trees_bitwise(g1, g2, "grad ")
    _assert_trees_bitwise(ns1, ns2, "state ")


def test_overlap_step_rejects_unstaged_model():
    from repro.configs import OptimizerConfig, ParallelConfig, TrainConfig
    from repro.training.step import make_dp_overlap_train_step

    class NoSegments:
        pass

    cfg = TrainConfig(optimizer=OptimizerConfig(),
                      parallel=ParallelConfig(compression="bf16+bucketed"))
    with pytest.raises(ValueError, match="loss_segments"):
        make_dp_overlap_train_step(NoSegments(), None, cfg, None, ("data",))


# ---------------------------------------------------------------------------
# ready-order BucketPlan: property round-trip
# ---------------------------------------------------------------------------

try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given, settings

    hypothesis.settings.register_profile(
        "ci", deadline=None, max_examples=20,
        suppress_health_check=list(hypothesis.HealthCheck))
    hypothesis.settings.load_profile("ci")
    HAVE_HYPOTHESIS = True

    @st.composite
    def stage_trees_and_bucket(draw):
        n_stages = draw(st.integers(1, 5))
        stages = []
        rng = np.random.default_rng(draw(st.integers(0, 2 ** 16)))
        for s in range(n_stages):
            n_leaves = draw(st.integers(0, 4))
            tree = {f"l{i}": jnp.asarray(
                rng.standard_normal(draw(st.integers(1, 40))),
                jnp.float32) for i in range(n_leaves)}
            stages.append(tree)
        if not any(jax.tree.leaves(t) for t in stages):
            stages[0] = {"l0": jnp.ones((3,), jnp.float32)}
        bucket_bytes = draw(st.integers(8, 256))
        return stages, bucket_bytes
except ImportError:  # hypothesis optional, like tests/test_properties.py
    HAVE_HYPOTHESIS = False

    def given(*a, **k):  # pragma: no cover - skip path
        return lambda fn: fn

    def settings(*a, **k):  # pragma: no cover
        return lambda fn: fn

    def stage_trees_and_bucket():  # pragma: no cover
        return None


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
@given(stage_trees_and_bucket())
@settings(max_examples=30)
def test_ready_order_plan_roundtrip_property(case):
    """Incremental pack_bucket over ready-ordered stages == whole-tree
    pack; every bucket closes exactly once, at its plan ready_stage;
    unpack restores the stage trees exactly (wire=None, f32)."""
    stages, bucket_bytes = case
    plan = plan_ready_buckets(stages, bucket_bytes=bucket_bytes, wire=None)
    total = sum(l.size for t in stages for l in jax.tree.leaves(t))
    assert plan.base.total_elems == total
    bucket_elems = max(1, bucket_bytes // 4)  # f32 stream (wire=None)
    assert plan.n_buckets == max(1, -(-total // bucket_elems))
    # ready stages non-decreasing, and within stage-feed bounds
    assert list(plan.ready_stage) == sorted(plan.ready_stage)

    whole = pack(tuple(stages), plan.base, use_kernel=False)
    seen = {}
    carry = None
    for s, tree in enumerate(stages):
        ready, carry = pack_bucket(plan, s, tree, carry, use_kernel=False)
        for b, arr in ready:
            assert b not in seen
            assert plan.ready_stage[b] == s
            seen[b] = arr
    assert carry.size == 0
    assert sorted(seen) == list(range(plan.n_buckets))
    for b in range(plan.n_buckets):
        np.testing.assert_array_equal(np.asarray(seen[b]),
                                      np.asarray(whole[b]))
    out = unpack([seen[b] for b in range(plan.n_buckets)], plan.base,
                 use_kernel=False)
    for a, b in zip(jax.tree.leaves(tuple(stages)), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ready_order_buckets_close_before_full_backward():
    """The point of ready order: with the backward-completion layout,
    early stages close buckets long before the last stage is fed —
    pytree order cannot do that when late-materializing leaves sit at
    the stream front."""
    stages = [{"a": jnp.ones((100,))}, {"b": jnp.ones((100,))},
              {"c": jnp.ones((100,))}]
    plan = plan_ready_buckets(stages, bucket_bytes=400, wire=None)
    assert plan.n_buckets == 3
    assert plan.ready_stage == (0, 1, 2)
    ready0, carry = pack_bucket(plan, 0, stages[0], None, use_kernel=False)
    assert [b for b, _ in ready0] == [0]  # closed after the FIRST stage


# ---------------------------------------------------------------------------
# step-level equivalence + HLO interleaving (subprocess, virtual mesh)
# ---------------------------------------------------------------------------

_STEP_PAIR = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import OptimizerConfig, get_config, reduced_config
    from repro.launch.train import build_train_setup
    cfg = reduced_config(get_config('resnet50'))
    mesh = jax.make_mesh((jax.device_count(), 1), ('data', 'model'))
    def build(overlap):
        return build_train_setup(
            cfg, global_batch=8, seq_len=16, opt_cfg=OptimizerConfig(),
            steps_per_epoch=5, mesh=mesh, dp_mode='shardmap', seed=0,
            compression='bf16+bucketed', bucket_bytes=8192,
            error_feedback={EF}, overlap_comm=overlap)
"""


def _parity_body(ef: bool) -> str:
    return textwrap.dedent(_STEP_PAIR).format(EF=ef) + textwrap.dedent("""
        results = {}
        for overlap in (False, True):
            model, state, step, data, put, _ = build(overlap)
            for s in range(2):
                batch = put({k: jnp.asarray(v)
                             for k, v in data.batch_at(s).items()})
                state, metrics = step(state, batch)
            results[overlap] = (state, metrics)
        s0, m0 = results[False]
        s1, m1 = results[True]
        assert float(m0['loss']) == float(m1['loss'])
        keys = ['params', 'opt', 'model_state']
        if %s:
            keys.append('ef_residual')
            nz = max(float(jnp.abs(x).max())
                     for x in jax.tree.leaves(s1['ef_residual']))
            assert nz > 0  # EF genuinely active
        for key in keys:
            for a, b in zip(jax.tree.leaves(s0[key]),
                            jax.tree.leaves(s1[key])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        print('PARITY_OK')
    """ % ef)


@pytest.mark.slow
def test_overlap_step_bitwise_equals_bucketed_8dev():
    """Acceptance: the overlapped step's gradients (hence params, opt
    state, BN stats after 2 steps) are bitwise-equal to the
    non-overlapped bucketed path on the 8-virtual-device mesh."""
    out = run_py(_parity_body(ef=False))
    assert "PARITY_OK" in out


@pytest.mark.slow
def test_overlap_step_bitwise_equals_bucketed_error_feedback_8dev():
    out = run_py(_parity_body(ef=True))
    assert "PARITY_OK" in out


def test_overlap_interleaves_collectives_in_hlo():
    """The comm_report interleave check must reject the non-overlapped
    program (collectives clustered after the whole backward) and accept
    the overlapped one (collectives separated by backward conv/dot
    compute). 2 virtual devices keep the compiles cheap — interleaving
    is a program-structure property, not a worker-count one."""
    out = run_py(textwrap.dedent(_STEP_PAIR).format(EF=False) +
                 textwrap.dedent("""
        from repro.launch.hlo_analysis import (analyze_hlo, comm_report,
                                               interleave_report)
        reports = {}
        for overlap in (False, True):
            model, state, step, data, put, _ = build(overlap)
            batch = put({k: jnp.asarray(v)
                         for k, v in data.batch_at(0).items()})
            txt = step.lower(state, batch).compile().as_text()
            reports[overlap] = interleave_report(txt)
            # comm_report embeds the same section when given the text
            cr = comm_report(analyze_hlo(txt, jax.device_count()),
                             hlo_text=txt)
            assert cr['interleave'] == reports[overlap]
        assert reports[False]['n_collectives'] >= 2, reports[False]
        assert not reports[False]['interleaved'], reports[False]
        assert reports[False]['compute_ops_after_first'] == 0
        assert reports[True]['interleaved'], reports[True]
        assert reports[True]['compute_ops_between_first_last'] > 0
        print('INTERLEAVE_OK', reports[True])
    """), env=ENV2)
    assert "INTERLEAVE_OK" in out


@pytest.mark.slow
def test_overlap_trains_same_as_perleaf_trajectory():
    """End-to-end: overlapped bucketed sync produces the same loss
    trajectory as the original per-leaf compressed psum (the seed
    path), tight tolerance — whole-program compile differences only."""
    out = run_py(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import OptimizerConfig, get_config, \\
            reduced_config
        from repro.launch.train import build_train_setup
        cfg = reduced_config(get_config('resnet50'))
        mesh = jax.make_mesh((2, 1), ('data', 'model'))
        losses = {}
        for comp, overlap in (('bf16', False), ('bf16+bucketed', True)):
            model, state, step, data, put, _ = build_train_setup(
                cfg, global_batch=8, seq_len=16,
                opt_cfg=OptimizerConfig(), steps_per_epoch=5, mesh=mesh,
                dp_mode='shardmap', seed=0, compression=comp,
                bucket_bytes=8192, overlap_comm=overlap)
            ls = []
            for s in range(3):
                batch = put({k: jnp.asarray(v)
                             for k, v in data.batch_at(s).items()})
                state, metrics = step(state, batch)
                ls.append(float(metrics['loss']))
            losses[comp] = ls
        np.testing.assert_allclose(losses['bf16'],
                                   losses['bf16+bucketed'],
                                   rtol=1e-5, atol=0)
        print('TRAJ_OK', losses['bf16'])
    """), env=ENV2)
    assert "TRAJ_OK" in out
