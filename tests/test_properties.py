"""Hypothesis property tests on the system's invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.compression import (
    apply_error_feedback,
    init_error_feedback,
    simulate_wire_cast,
)
from repro.core.optimizer import HybridHyper, hybrid_update
from repro.core.schedules import alpha_sgd_schedule, slow_start_lr
from repro.distributed.sharding import spec_for
from repro.optim.zero import zero_spec_for

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("ci")


@given(st.floats(0.0, 200.0))
def test_alpha_sgd_bounds(epoch):
    a = float(alpha_sgd_schedule(epoch))
    assert 0.0 <= a <= 1.0


@given(st.floats(0.0, 89.9), st.floats(1e-3, 100.0))
def test_slow_start_positive_decreasing_family(epoch, eta):
    lr = float(slow_start_lr(epoch, eta))
    assert 0 < lr <= 0.5 * eta * (1 + 1e-6)  # fp32 rounding headroom
    lr_later = float(slow_start_lr(min(epoch + 30.0, 89.9), eta))
    assert lr_later <= lr + 1e-9


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(["bf16", "f16"]))
def test_wire_cast_relative_error_bounded(seed, wire):
    g = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * 10.0
    q = simulate_wire_cast({"g": g}, wire)["g"]
    rel = np.abs(np.asarray(q - g)) / (np.abs(np.asarray(g)) + 1e-30)
    # bf16: 8 mantissa bits -> 2^-8; f16: 11 bits but limited range
    bound = 2 ** -8 if wire == "bf16" else 2 ** -10
    finite = np.isfinite(np.asarray(g))
    assert (rel[finite] <= bound + 1e-6).all()


@given(st.integers(0, 2 ** 31 - 1))
def test_error_feedback_reduces_accumulated_bias(seed):
    """Sum of EF-compressed gradients tracks the true sum better than
    naive repeated rounding (the EF invariant: residual stays bounded)."""
    key = jax.random.PRNGKey(seed)
    gs = jax.random.normal(key, (20, 128)) * 1e-3  # small => rounding bites
    resid = init_error_feedback({"g": gs[0]})
    acc_ef = np.zeros(128)
    acc_naive = np.zeros(128)
    acc_true = np.zeros(128)
    for i in range(20):
        q, resid = apply_error_feedback({"g": gs[i]}, resid, wire="bf16")
        acc_ef += np.asarray(q["g"], np.float64)
        acc_naive += np.asarray(
            simulate_wire_cast({"g": gs[i]}, "bf16")["g"], np.float64)
        acc_true += np.asarray(gs[i], np.float64)
    err_ef = np.abs(acc_ef - acc_true).max()
    # EF error is bounded by one quantization step of the *last* value,
    # independent of the number of steps
    assert err_ef <= np.abs(np.asarray(resid["g"])).max() + 1e-6


@given(st.integers(0, 2 ** 31 - 1),
       st.floats(0.0, 1.0),
       st.floats(1e-3, 20.0))
def test_hybrid_update_invariants(seed, alpha, eta):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 2)
    g = jax.random.normal(ks[0], (64,))
    p = jax.random.normal(ks[1], (64,))
    h = HybridHyper(eta=jnp.float32(eta), alpha_sgd=jnp.float32(alpha))
    p1, d1, m1 = hybrid_update(g, p, jnp.zeros(64), jnp.zeros(64), h)
    # second moment is nonnegative; zero gradient leaves params in place
    assert bool((m1 >= 0).all())
    p0, d0, m0 = hybrid_update(jnp.zeros(64), p, jnp.zeros(64),
                               jnp.zeros(64), h)
    np.testing.assert_allclose(p0, p, atol=1e-7)
    np.testing.assert_allclose(d0, 0.0, atol=1e-7)


@given(st.lists(st.sampled_from(["embed", "heads", "ffn", "vocab", None,
                                 "experts", "batch"]),
                min_size=1, max_size=4))
def test_spec_never_reuses_mesh_axis(axes):
    rules = {"embed": ("data",), "heads": "model", "ffn": "model",
             "vocab": "model", "experts": "model",
             "batch": ("pod", "data")}
    spec = spec_for(tuple(axes), rules)
    used = []
    for entry in spec:
        if entry is None:
            continue
        for a in ((entry,) if isinstance(entry, str) else entry):
            assert a not in used, f"axis {a} used twice in {spec}"
            used.append(a)


@given(st.tuples(st.integers(1, 64), st.integers(1, 64)),
       st.integers(1, 8))
def test_zero_spec_divisibility(shape, dp):
    import jax as _jax
    from jax.sharding import PartitionSpec as P
    if dp > 1 and len(_jax.devices()) < dp:
        # semantics only need the axis size; emulate via mesh dict
        class FakeMesh:
            def __init__(self):
                self.shape = {"data": dp}
        mesh = FakeMesh()
    else:
        class FakeMesh:
            def __init__(self):
                self.shape = {"data": dp}
        mesh = FakeMesh()
    spec = zero_spec_for(shape, P(), mesh, ("data",))
    for dim, entry in zip(shape, tuple(spec)):
        if entry is not None:
            assert dim % dp == 0


@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 8), st.integers(1, 2))
def test_moe_dispatch_invariants(seed, e, k):
    """Each token occupies <= k slots; gates are nonnegative; capacity is
    never exceeded (column sums <= 1 per slot)."""
    import dataclasses
    from repro.configs import get_config, reduced_config
    from repro.models import layers
    from repro.models.common import unbox
    k = min(k, e)
    cfg = dataclasses.replace(
        reduced_config(get_config("mixtral-8x7b")),
        d_model=8, d_ff=16, n_experts=e, experts_per_token=k)
    key = jax.random.PRNGKey(seed)
    p, _ = unbox(layers.moe_init(key, cfg))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 16, 8))

    # reproduce the dispatch construction via the public apply: capacity
    # semantics are observable through drop behaviour
    y_uncapped, _ = layers.moe_apply(p, x, cfg, capacity_factor=1000.0)
    y_capped, _ = layers.moe_apply(p, x, cfg, capacity_factor=0.01)
    # capped drops more (or equal) tokens than uncapped
    n_alive_un = (np.linalg.norm(np.asarray(y_uncapped), axis=-1) >
                  1e-9).sum()
    n_alive_cap = (np.linalg.norm(np.asarray(y_capped), axis=-1) >
                   1e-9).sum()
    assert n_alive_cap <= n_alive_un
    assert np.isfinite(np.asarray(y_capped)).all()


@given(st.integers(0, 2 ** 31 - 1))
def test_gla_decode_step_matches_chunked_tail(seed):
    """One gla_decode_step after a chunked prefix == chunked over S+1."""
    from repro.models import ssd
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    b, s, h, dk, dv = 1, 32, 2, 4, 4
    q = jax.random.normal(ks[0], (b, s + 1, h, dk))
    k = jax.random.normal(ks[1], (b, s + 1, h, dk))
    v = jax.random.normal(ks[2], (b, s + 1, h, dv))
    log_a = -jnp.abs(jax.random.normal(ks[3], (b, s + 1, h))) * 0.1
    # oracle over s+1 steps (no chunk-divisibility constraint)
    y_ref, _ = ssd.reference_gla(q, k, v, log_a)
    _, state = ssd.chunked_gla(q[:, :s], k[:, :s], v[:, :s],
                               log_a[:, :s], chunk=16)
    y_step, _ = ssd.gla_decode_step(q[:, s], k[:, s], v[:, s],
                                    log_a[:, s], state)
    np.testing.assert_allclose(np.asarray(y_step),
                               np.asarray(y_ref[:, s]), atol=1e-4)
