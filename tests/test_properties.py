"""Hypothesis property tests on the system's invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings

from repro.core.compression import (
    apply_error_feedback,
    init_error_feedback,
    simulate_wire_cast,
)
from repro.core.optimizer import HybridHyper, hybrid_update
from repro.core.schedules import alpha_sgd_schedule, slow_start_lr
from repro.distributed.sharding import spec_for
from repro.optim.zero import zero_spec_for

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("ci")


@given(st.floats(0.0, 200.0))
def test_alpha_sgd_bounds(epoch):
    a = float(alpha_sgd_schedule(epoch))
    assert 0.0 <= a <= 1.0


@given(st.floats(0.0, 89.9), st.floats(1e-3, 100.0))
def test_slow_start_positive_decreasing_family(epoch, eta):
    lr = float(slow_start_lr(epoch, eta))
    assert 0 < lr <= 0.5 * eta * (1 + 1e-6)  # fp32 rounding headroom
    lr_later = float(slow_start_lr(min(epoch + 30.0, 89.9), eta))
    assert lr_later <= lr + 1e-9


@given(st.integers(0, 2 ** 31 - 1), st.sampled_from(["bf16", "f16"]))
def test_wire_cast_relative_error_bounded(seed, wire):
    g = jax.random.normal(jax.random.PRNGKey(seed), (256,)) * 10.0
    q = simulate_wire_cast({"g": g}, wire)["g"]
    rel = np.abs(np.asarray(q - g)) / (np.abs(np.asarray(g)) + 1e-30)
    # bf16: 8 mantissa bits -> 2^-8; f16: 11 bits but limited range
    bound = 2 ** -8 if wire == "bf16" else 2 ** -10
    finite = np.isfinite(np.asarray(g))
    assert (rel[finite] <= bound + 1e-6).all()


@given(st.integers(0, 2 ** 31 - 1))
def test_error_feedback_reduces_accumulated_bias(seed):
    """Sum of EF-compressed gradients tracks the true sum better than
    naive repeated rounding (the EF invariant: residual stays bounded)."""
    key = jax.random.PRNGKey(seed)
    gs = jax.random.normal(key, (20, 128)) * 1e-3  # small => rounding bites
    resid = init_error_feedback({"g": gs[0]})
    acc_ef = np.zeros(128)
    acc_naive = np.zeros(128)
    acc_true = np.zeros(128)
    for i in range(20):
        q, resid = apply_error_feedback({"g": gs[i]}, resid, wire="bf16")
        acc_ef += np.asarray(q["g"], np.float64)
        acc_naive += np.asarray(
            simulate_wire_cast({"g": gs[i]}, "bf16")["g"], np.float64)
        acc_true += np.asarray(gs[i], np.float64)
    err_ef = np.abs(acc_ef - acc_true).max()
    # EF error is bounded by one quantization step of the *last* value,
    # independent of the number of steps
    assert err_ef <= np.abs(np.asarray(resid["g"])).max() + 1e-6


@given(st.integers(0, 2 ** 31 - 1),
       st.floats(0.0, 1.0),
       st.floats(1e-3, 20.0))
def test_hybrid_update_invariants(seed, alpha, eta):
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 2)
    g = jax.random.normal(ks[0], (64,))
    p = jax.random.normal(ks[1], (64,))
    h = HybridHyper(eta=jnp.float32(eta), alpha_sgd=jnp.float32(alpha))
    p1, d1, m1 = hybrid_update(g, p, jnp.zeros(64), jnp.zeros(64), h)
    # second moment is nonnegative; zero gradient leaves params in place
    assert bool((m1 >= 0).all())
    p0, d0, m0 = hybrid_update(jnp.zeros(64), p, jnp.zeros(64),
                               jnp.zeros(64), h)
    np.testing.assert_allclose(p0, p, atol=1e-7)
    np.testing.assert_allclose(d0, 0.0, atol=1e-7)


@given(st.lists(st.sampled_from(["embed", "heads", "ffn", "vocab", None,
                                 "experts", "batch"]),
                min_size=1, max_size=4))
def test_spec_never_reuses_mesh_axis(axes):
    rules = {"embed": ("data",), "heads": "model", "ffn": "model",
             "vocab": "model", "experts": "model",
             "batch": ("pod", "data")}
    spec = spec_for(tuple(axes), rules)
    used = []
    for entry in spec:
        if entry is None:
            continue
        for a in ((entry,) if isinstance(entry, str) else entry):
            assert a not in used, f"axis {a} used twice in {spec}"
            used.append(a)


@given(st.tuples(st.integers(1, 64), st.integers(1, 64)),
       st.integers(1, 8))
def test_zero_spec_divisibility(shape, dp):
    import jax as _jax
    from jax.sharding import PartitionSpec as P
    if dp > 1 and len(_jax.devices()) < dp:
        # semantics only need the axis size; emulate via mesh dict
        class FakeMesh:
            def __init__(self):
                self.shape = {"data": dp}
        mesh = FakeMesh()
    else:
        class FakeMesh:
            def __init__(self):
                self.shape = {"data": dp}
        mesh = FakeMesh()
    spec = zero_spec_for(shape, P(), mesh, ("data",))
    for dim, entry in zip(shape, tuple(spec)):
        if entry is not None:
            assert dim % dp == 0


@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 8), st.integers(1, 2))
def test_moe_dispatch_invariants(seed, e, k):
    """Each token occupies <= k slots; gates are nonnegative; capacity is
    never exceeded (column sums <= 1 per slot)."""
    import dataclasses
    from repro.configs import get_config, reduced_config
    from repro.models import layers
    from repro.models.common import unbox
    k = min(k, e)
    cfg = dataclasses.replace(
        reduced_config(get_config("mixtral-8x7b")),
        d_model=8, d_ff=16, n_experts=e, experts_per_token=k)
    key = jax.random.PRNGKey(seed)
    p, _ = unbox(layers.moe_init(key, cfg))
    x = jax.random.normal(jax.random.PRNGKey(seed + 1), (1, 16, 8))

    # reproduce the dispatch construction via the public apply: capacity
    # semantics are observable through drop behaviour
    y_uncapped, _ = layers.moe_apply(p, x, cfg, capacity_factor=1000.0)
    y_capped, _ = layers.moe_apply(p, x, cfg, capacity_factor=0.01)
    # capped drops more (or equal) tokens than uncapped
    n_alive_un = (np.linalg.norm(np.asarray(y_uncapped), axis=-1) >
                  1e-9).sum()
    n_alive_cap = (np.linalg.norm(np.asarray(y_capped), axis=-1) >
                   1e-9).sum()
    assert n_alive_cap <= n_alive_un
    assert np.isfinite(np.asarray(y_capped)).all()


@given(st.integers(0, 2 ** 31 - 1))
def test_gla_decode_step_matches_chunked_tail(seed):
    """One gla_decode_step after a chunked prefix == chunked over S+1."""
    from repro.models import ssd
    key = jax.random.PRNGKey(seed)
    ks = jax.random.split(key, 4)
    b, s, h, dk, dv = 1, 32, 2, 4, 4
    q = jax.random.normal(ks[0], (b, s + 1, h, dk))
    k = jax.random.normal(ks[1], (b, s + 1, h, dk))
    v = jax.random.normal(ks[2], (b, s + 1, h, dv))
    log_a = -jnp.abs(jax.random.normal(ks[3], (b, s + 1, h))) * 0.1
    # oracle over s+1 steps (no chunk-divisibility constraint)
    y_ref, _ = ssd.reference_gla(q, k, v, log_a)
    _, state = ssd.chunked_gla(q[:, :s], k[:, :s], v[:, :s],
                               log_a[:, :s], chunk=16)
    y_step, _ = ssd.gla_decode_step(q[:, s], k[:, s], v[:, s],
                                    log_a[:, s], state)
    np.testing.assert_allclose(np.asarray(y_step),
                               np.asarray(y_ref[:, s]), atol=1e-4)


# ---------------------------------------------------------------------------
# bucket codec (distributed/bucketing.py): pack/unpack round-trip,
# ready-order coverage, and the ZeRO shard-aligned padding (DESIGN.md §9)
# ---------------------------------------------------------------------------

from repro.distributed.bucketing import (  # noqa: E402
    pack,
    pack_bucket,
    plan_buckets,
    plan_ready_buckets,
    shard_chunks,
    shard_layout_to_stream,
    stream_layout,
    stream_to_shard_layout,
    unpack,
)


@st.composite
def codec_tree(draw):
    """A random gradient tree + bucket/align config. Leaves are bf16- and
    f16-representable fp32 (scaled powers of two), so the wire round-trip
    is exact and pack->psum-less->unpack must be bitwise identity."""
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 16)))
    n_leaves = draw(st.integers(1, 6))
    tree = {}
    for i in range(n_leaves):
        ndim = draw(st.integers(1, 3))
        shape = tuple(draw(st.integers(1, 5)) for _ in range(ndim))
        tree[f"l{i}"] = jnp.asarray(
            2.0 ** rng.integers(-3, 4, size=shape), jnp.float32)
    wire = draw(st.sampled_from([None, "bf16", "f16"]))
    bucket_bytes = draw(st.integers(4, 128))
    align = draw(st.sampled_from([1, 2, 3, 4, 8]))
    return tree, wire, bucket_bytes, align


@given(codec_tree())
@settings(max_examples=40)
def test_bucket_codec_roundtrip_with_alignment(case):
    """pack -> unpack restores every leaf bitwise for any shapes, wire
    dtype, bucket size and shard alignment; every bucket length is an
    ``align`` multiple; the pad tail is zero; leaf slots tile the
    unpadded stream exactly once."""
    tree, wire, bucket_bytes, align = case
    plan = plan_buckets(tree, bucket_bytes, wire, align=align)
    total = sum(l.size for l in jax.tree.leaves(tree))
    assert plan.total_elems == total
    assert plan.padded_total % align == 0
    assert plan.bucket_elems % align == 0
    # slots cover [0, total) exactly once, in tree-flatten order
    covered = 0
    for s in plan.slots:
        assert s.offset == covered
        covered += s.size
    assert covered == total
    buckets = pack(tree, plan, use_kernel=False)
    assert len(buckets) == plan.n_buckets
    sizes = [b.shape[0] for b in buckets]
    assert sum(sizes) == plan.padded_total
    assert all(sz % align == 0 for sz in sizes)
    if plan.pad_elems:
        tail = np.asarray(buckets[-1])[-plan.pad_elems:]
        np.testing.assert_array_equal(tail, 0.0)
    out = unpack(buckets, plan, use_kernel=False)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(tree[k]),
                                      np.asarray(out[k]), err_msg=k)


@st.composite
def ready_codec_case(draw):
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 16)))
    n_stages = draw(st.integers(1, 5))
    stages = []
    for s in range(n_stages):
        n_leaves = draw(st.integers(0, 3))
        stages.append({f"l{i}": jnp.asarray(
            2.0 ** rng.integers(-3, 4,
                                size=draw(st.integers(1, 40))),
            jnp.float32) for i in range(n_leaves)})
    if not any(jax.tree.leaves(t) for t in stages):
        stages[0] = {"l0": jnp.ones((3,), jnp.float32)}
    wire = draw(st.sampled_from([None, "bf16"]))
    bucket_bytes = draw(st.integers(8, 256))
    align = draw(st.sampled_from([1, 2, 4, 8]))
    return stages, wire, bucket_bytes, align


@given(ready_codec_case())
@settings(max_examples=40)
def test_ready_plan_coverage_and_incremental_pack(case):
    """plan_ready_buckets coverage with shard alignment: every bucket
    closes exactly once, at its plan ready_stage; ready order is
    non-decreasing; incremental pack_bucket over the stages equals the
    whole-tree pack bitwise (zero tail included); unpack restores the
    stage trees."""
    stages, wire, bucket_bytes, align = case
    plan = plan_ready_buckets(stages, bucket_bytes, wire, align=align)
    assert list(plan.ready_stage) == sorted(plan.ready_stage)
    assert plan.base.padded_total % align == 0
    whole = pack(tuple(stages), plan.base, use_kernel=False)
    seen = {}
    carry = None
    for s, tree in enumerate(stages):
        ready, carry = pack_bucket(plan, s, tree, carry, use_kernel=False)
        for b, arr in ready:
            assert b not in seen  # exactly once
            assert plan.ready_stage[b] == s  # at the planned stage
            seen[b] = arr
    assert carry.size == 0
    assert sorted(seen) == list(range(plan.n_buckets))  # all of them
    for b in range(plan.n_buckets):
        np.testing.assert_array_equal(np.asarray(seen[b]),
                                      np.asarray(whole[b]))
    out = unpack([seen[b] for b in range(plan.n_buckets)], plan.base,
                 use_kernel=False)
    for a, b in zip(jax.tree.leaves(tuple(stages)), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(1, 4000), st.integers(4, 4096), st.sampled_from([2, 4]),
       st.sampled_from([1, 2, 4, 8]))
def test_stream_layout_arithmetic_invariants(total, bucket_bytes,
                                             itemsize, align):
    bucket_elems, n_buckets, pad = stream_layout(total, bucket_bytes,
                                                 itemsize, align)
    assert bucket_elems >= 1 and bucket_elems % align == 0
    assert (total + pad) % align == 0
    assert 0 <= pad < align
    # buckets tile the padded stream
    assert (n_buckets - 1) * bucket_elems < total + pad
    assert n_buckets * bucket_elems >= total + pad


@given(codec_tree())
@settings(max_examples=40)
def test_segment_map_tiles_padded_stream(case):
    """The leaf-segment map (DESIGN.md §11) is a disjoint exact cover of
    the padded stream: element j belongs to segment i iff slot i's
    [offset, offset+size) contains j, and everything past the real
    elements carries the synthetic pad id len(slots)."""
    from repro.distributed.bucketing import segment_ids_stream

    tree, wire, bucket_bytes, align = case
    plan = plan_buckets(tree, bucket_bytes, wire, align=align)
    seg = segment_ids_stream(plan)
    assert seg.shape == (plan.padded_total,)
    counts = np.bincount(seg, minlength=len(plan.slots) + 1)
    # disjoint + covering: per-segment counts are exactly the slot sizes
    np.testing.assert_array_equal(
        counts[:len(plan.slots)], [s.size for s in plan.slots])
    assert counts[len(plan.slots)] == plan.padded_total - plan.total_elems
    for i, s in enumerate(plan.slots):
        np.testing.assert_array_equal(seg[s.offset:s.offset + s.size], i)


@given(codec_tree(), st.sampled_from([1, 2, 4, 8]))
@settings(max_examples=40)
def test_segment_partials_shard_sum_equals_full_norm(case, n):
    """psum-of-partials == full per-leaf squared norm, exactly: with
    power-of-two leaf values every square and partial sum is exactly
    representable, so summing each shard's ``segment_sq_partials`` over
    the shard-aligned splits must reproduce the whole-stream per-leaf
    norms with zero float error — the invariant the stream-LARS trust
    ratios ride on (DESIGN.md §11)."""
    from repro.distributed.bucketing import (
        local_shard,
        segment_ids_stream,
        segment_sq_partials,
    )

    tree, wire, bucket_bytes, _ = case
    plan = plan_buckets(tree, bucket_bytes, wire, align=n)
    seg = jnp.asarray(segment_ids_stream(plan))
    stream = jnp.concatenate(pack(tree, plan, use_kernel=False))
    if stream.dtype != jnp.float32:
        stream = stream.astype(jnp.float32)
    n_seg = len(plan.slots) + 1
    full = np.asarray(segment_sq_partials(stream, seg, n_seg),
                      np.float64)
    summed = np.zeros(n_seg, np.float64)
    for w in range(n):
        g_loc = local_shard(stream, plan, n, w)
        s_loc = local_shard(seg, plan, n, w)
        summed += np.asarray(segment_sq_partials(g_loc, s_loc, n_seg),
                             np.float64)
    np.testing.assert_array_equal(summed, full)
    # and both equal the per-leaf norms computed leaf-by-leaf
    leaves = plan.treedef.flatten_up_to(tree)
    for i, leaf in enumerate(leaves):
        x = np.asarray(leaf, np.float64).reshape(-1)
        np.testing.assert_array_equal(full[i], np.sum(x * x))
    assert full[-1] == 0.0  # the pad segment


@given(st.integers(0, 2 ** 16), st.sampled_from([2, 4, 8]),
       st.integers(8, 200))
def test_shard_layout_permutation_roundtrip(seed, n, bucket_bytes):
    rng = np.random.default_rng(seed)
    tree = {f"l{i}": jnp.asarray(rng.standard_normal(
        rng.integers(1, 50)), jnp.float32) for i in range(4)}
    plan = plan_buckets(tree, bucket_bytes, None, align=n)
    stream = rng.standard_normal(plan.padded_total).astype(np.float32)
    lay = stream_to_shard_layout(stream, plan, n)
    np.testing.assert_array_equal(
        shard_layout_to_stream(lay, plan, n), stream)
    # shard w = concat of its per-bucket chunks
    chunks = shard_chunks(plan, n)
    s = sum(chunks)
    for w in range(n):
        want = np.concatenate(
            [stream[plan.bucket_bounds(b)[0] + w * c:
                    plan.bucket_bounds(b)[0] + (w + 1) * c]
             for b, c in enumerate(chunks)])
        np.testing.assert_array_equal(lay[w * s:(w + 1) * s], want)


# ---------------------------------------------------------------------------
# HLO IR (repro.analysis.hlo_ir, DESIGN.md §12)
# ---------------------------------------------------------------------------

from repro.analysis.hlo_ir import (  # noqa: E402
    DTYPE_BYTES,
    compute_multipliers,
    parse_computations,
    parse_op_line,
    render_op,
    type_bytes,
)

_hlo_ident = st.from_regex(r"[A-Za-z][A-Za-z0-9_.\-]{0,12}",
                           fullmatch=True)
_hlo_opcode = st.from_regex(r"[a-z][a-z0-9]{0,8}(-[a-z0-9]{1,8}){0,2}",
                            fullmatch=True)
_hlo_dtype = st.sampled_from(sorted(DTYPE_BYTES))


@st.composite
def _hlo_type(draw):
    dt = draw(_hlo_dtype)
    dims = draw(st.lists(st.integers(1, 64), max_size=3))
    t = f"{dt}[{','.join(map(str, dims))}]"
    if dims and draw(st.booleans()):  # layout annotation
        t += "{" + ",".join(map(str, reversed(range(len(dims))))) + "}"
    if draw(st.booleans()):  # tuple result
        t2 = draw(_hlo_dtype) + "[]"
        t = f"({t}, {t2})"
    return t


_hlo_suffix = st.sampled_from([
    "", ", dimensions={0}", ", to_apply=%add.1",
    ", replica_groups={{0,1,2,3}}", ", sharding={replicated}",
    ", index=0", ", direction=LT",
    ", condition=%cond.2, body=%body.3",
])


@st.composite
def _hlo_op_line(draw):
    root = draw(st.booleans())
    name = draw(_hlo_ident)
    rtype = draw(_hlo_type())
    opcode = draw(_hlo_opcode)
    operands = draw(st.lists(_hlo_ident, max_size=4))
    args_raw = ", ".join(f"%{o}" for o in operands) \
        if operands else draw(st.sampled_from(["", "0", "42"]))
    head = "ROOT " if root else ""
    return f"  {head}%{name} = {rtype} {opcode}({args_raw})" + \
        draw(_hlo_suffix)


@given(_hlo_op_line())
def test_hlo_op_parse_render_parse_roundtrip(line):
    op = parse_op_line(line)
    assert op is not None, line
    rendered = render_op(op)
    op2 = parse_op_line(rendered)
    assert op2 == op
    assert render_op(op2) == rendered  # render is a fixpoint


@given(_hlo_type())
def test_hlo_type_bytes_strict_accepts_known_dtypes(t):
    # every generated type uses table dtypes: strict == lenient > 0
    # unless every component is a zero-byte token/opaque
    assert type_bytes(t, strict=True) == type_bytes(t)


def _loop_module_blocks(trip):
    add = ("%add.1 (a: f32[], b: f32[]) -> f32[] {\n"
           "  %a = f32[] parameter(0)\n"
           "  %b = f32[] parameter(1)\n"
           "  ROOT %sum = f32[] add(%a, %b)\n"
           "}\n")
    cond = ("%cond.2 (s: (s32[], f32[64])) -> pred[] {\n"
            "  %s = (s32[], f32[64]) parameter(0)\n"
            "  %i = s32[] get-tuple-element(%s), index=0\n"
            f"  %n = s32[] constant({trip})\n"
            "  ROOT %lt = pred[] compare(%i, %n), direction=LT\n"
            "}\n")
    body = ("%body.3 (s: (s32[], f32[64])) -> (s32[], f32[64]) {\n"
            "  %s.1 = (s32[], f32[64]) parameter(0)\n"
            "  %i.1 = s32[] get-tuple-element(%s.1), index=0\n"
            "  %x = f32[64]{0} get-tuple-element(%s.1), index=1\n"
            "  %one = s32[] constant(1)\n"
            "  %i.2 = s32[] add(%i.1, %one)\n"
            "  %x.2 = f32[64]{0} all-reduce(%x), "
            "replica_groups={{0,1}}, to_apply=%add.1\n"
            "  ROOT %t = (s32[], f32[64]) tuple(%i.2, %x.2)\n"
            "}\n")
    entry = ("ENTRY %main.4 (p0: f32[64]) -> f32[64] {\n"
             "  %p0 = f32[64]{0} parameter(0)\n"
             "  %zero = s32[] constant(0)\n"
             "  %init = (s32[], f32[64]) tuple(%zero, %p0)\n"
             "  %w = (s32[], f32[64]) while(%init), "
             "condition=%cond.2, body=%body.3\n"
             "  ROOT %x.3 = f32[64]{0} get-tuple-element(%w), index=1\n"
             "}\n")
    return [add, cond, body, entry]


@given(st.integers(1, 12), st.permutations([0, 1, 2, 3]))
def test_hlo_multipliers_invariant_under_computation_order(trip, perm):
    # trip-count weighting must depend on the call graph, not on the
    # textual order XLA happens to emit the computations in (ENTRY is
    # marked, so entry detection is order-independent)
    blocks = _loop_module_blocks(trip)
    text = "\n".join(blocks[i] for i in perm)
    mult, trips = compute_multipliers(parse_computations(text))
    assert mult["main.4"] == 1.0
    assert mult["body.3"] == float(trip)
    assert mult["cond.2"] == float(trip + 1)
    assert mult["add.1"] == float(trip)  # to_apply inside the loop body
    assert trips == {"body.3": trip}


# ---------------------------------------------------------------------------
# hierarchical collective routing (distributed/bucketing.py, DESIGN.md §14)
# ---------------------------------------------------------------------------

from repro.distributed.bucketing import (  # noqa: E402
    inner_major_perm,
    inner_major_unperm,
)


@given(st.integers(2, 5), st.integers(2, 5), st.integers(1, 7),
       st.integers(0, 2 ** 16))
def test_inner_major_perm_roundtrip(a, b, c, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal(a * b * c), jnp.float32)
    y = inner_major_unperm(inner_major_perm(x, a, b), a, b)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))


@st.composite
def hier_route_case(draw):
    """An (outer=a, inner=b) factorization + per-worker exact-integer
    streams: every reassociated fold of integers in [-64, 64] is exact
    in f32, so any correct routing must match the flat reference
    BITWISE (DESIGN.md §11 precedent: exactness pinned with
    power-of-two-safe data, fuzzy parity left to the e2e tests)."""
    a = draw(st.integers(2, 4))
    b = draw(st.integers(2, 4))
    c = draw(st.integers(1, 6))
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 16)))
    bufs = [rng.integers(-64, 65, size=a * b * c).astype(np.float32)
            for _ in range(a * b)]
    return a, b, bufs


def _np_flat_scatter(bufs, n):
    """Flat reduce-scatter reference: worker w owns chunk w of the sum."""
    tot = np.sum(bufs, axis=0)
    c = tot.size // n
    return [tot[w * c:(w + 1) * c] for w in range(n)]


def _np_hier_scatter(bufs, a, b):
    """Mirror hierarchical_psum_scatter's routing in numpy: inner-major
    pre-permutation, reduce-scatter over the inner axis (sum worker
    (o, *)'s group, keep chunk i), then over the outer axis (sum worker
    (*, i)'s group, keep chunk o). Returned in linear-rank order
    w = o*b + i — the row-major ``_dp_linear_index`` order the ZeRO
    param slicing uses (training/step.py)."""
    permed = [np.asarray(inner_major_perm(jnp.asarray(x), a, b))
              for x in bufs]
    ci = permed[0].size // b
    shard1 = {}
    for o in range(a):
        g = np.sum([permed[o * b + i2] for i2 in range(b)], axis=0)
        for i in range(b):
            shard1[(o, i)] = g[i * ci:(i + 1) * ci]
    co = ci // a
    final = []
    for o in range(a):
        for i in range(b):
            g = np.sum([shard1[(o2, i)] for o2 in range(a)], axis=0)
            final.append(g[o * co:(o + 1) * co])
    return final


def _np_hier_gather(final, a, b):
    """Mirror hierarchical_all_gather: all-gather over the outer axis
    (concat the column's shards), then the inner axis, then undo the
    inner-major permutation."""
    g1 = [np.concatenate([final[o2 * b + i] for o2 in range(a)])
          for i in range(b)]
    g2 = np.concatenate(g1)
    return np.asarray(inner_major_unperm(jnp.asarray(g2), a, b))


@given(hier_route_case())
@settings(max_examples=40)
def test_hier_double_scatter_owns_flat_chunks(case):
    """ZeRO shard ownership is hierarchy-invariant: the inner-major
    pre-permutation makes the double reduce-scatter hand worker
    w = o*inner + i exactly the chunk the flat reduce-scatter would —
    so param slicing, weight-decay masks, and optimizer-state layout
    (all keyed on ``_dp_linear_index``) need no changes under a
    hierarchical schedule."""
    a, b, bufs = case
    flat = _np_flat_scatter(bufs, a * b)
    hier = _np_hier_scatter(bufs, a, b)
    for w, (f, h) in enumerate(zip(flat, hier)):
        np.testing.assert_array_equal(f, h, err_msg=f"worker {w}")


@given(hier_route_case())
@settings(max_examples=40)
def test_hier_scatter_gather_roundtrip_is_psum(case):
    """Double-scatter then double-gather+unperm reconstructs the flat
    psum bitwise on exact data — the RS->AR->AG pipeline is a
    permutation-consistent psum, for every (a, b) factorization."""
    a, b, bufs = case
    full = _np_hier_gather(_np_hier_scatter(bufs, a, b), a, b)
    np.testing.assert_array_equal(full, np.sum(bufs, axis=0))


@st.composite
def hier_plan_case(draw):
    """A random gradient tree packed through a real BucketPlan, plus an
    (a, b) hierarchy whose n_workers is the plan alignment. Leaf values
    are powers of two so wire casts and sums stay exact."""
    a = draw(st.integers(2, 3))
    b = draw(st.integers(2, 4))
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 16)))
    n_leaves = draw(st.integers(1, 5))
    tree = {f"l{i}": jnp.asarray(
        2.0 ** rng.integers(-3, 4, size=draw(st.integers(1, 40))),
        jnp.float32) for i in range(n_leaves)}
    bucket_bytes = draw(st.integers(8, 256))
    return a, b, tree, bucket_bytes


@given(hier_plan_case())
@settings(max_examples=30)
def test_hier_schedule_over_packed_stream_matches_flat(case):
    """End-to-end over the real codec: pack per-worker trees with the
    shard-aligned plan (align = a*b, what the hierarchical paths use),
    route every bucket through the simulated double scatter + double
    gather, unpack — and every leaf equals the flat elementwise sum
    bitwise, for arbitrary plans, alignments and factorizations."""
    a, b, tree, bucket_bytes = case
    n = a * b
    plan = plan_buckets(tree, bucket_bytes, None, align=n)
    # per-worker variants: worker w's tree is w * tree (exact ints)
    worker_bufs = {}
    for w in range(n):
        wt = jax.tree.map(lambda x: x * float(w + 1), tree)
        worker_bufs[w] = [np.asarray(bk)
                          for bk in pack(wt, plan, use_kernel=False)]
    synced = []
    for bi in range(plan.n_buckets):
        bufs = [worker_bufs[w][bi] for w in range(n)]
        assert bufs[0].size % n == 0  # plan alignment guarantees this
        synced.append(_np_hier_gather(_np_hier_scatter(bufs, a, b),
                                      a, b))
    out = unpack([jnp.asarray(s) for s in synced], plan,
                 use_kernel=False)
    scale = float(sum(w + 1 for w in range(n)))
    for k in tree:
        np.testing.assert_array_equal(
            np.asarray(out[k]), np.asarray(tree[k]) * scale, err_msg=k)


# ---------------------------------------------------------------------------
# per-host input sharding (DESIGN.md §15)
# ---------------------------------------------------------------------------

from repro.data.pipeline import DataPipeline  # noqa: E402
from repro.data.synthetic import (  # noqa: E402
    SyntheticImageData,
    SyntheticLMData,
)
from repro.configs import get_config, reduced_config  # noqa: E402


@given(st.integers(0, 2 ** 31 - 1), st.integers(0, 200),
       st.sampled_from([2, 4, 8]), st.sampled_from(["train", "val"]))
@settings(max_examples=15)
def test_image_host_shards_partition_global_batch(seed, step, hosts,
                                                  split):
    """The concatenation of per-host shard batches is bitwise equal to
    the single-host global batch — every sample is generated, exactly
    once, by exactly one host, for any (seed, step, split)."""
    batch, size, classes = 8, 8, 4
    full = SyntheticImageData(classes, size, batch, seed=seed,
                              split=split).batch_at(step)
    per = batch // hosts
    shards = [SyntheticImageData(classes, size, per, seed=seed,
                                 split=split,
                                 sample_offset=h * per).batch_at(step)
              for h in range(hosts)]
    np.testing.assert_array_equal(
        np.concatenate([s["images"] for s in shards]), full["images"])
    np.testing.assert_array_equal(
        np.concatenate([s["labels"] for s in shards]), full["labels"])


@given(st.integers(0, 2 ** 31 - 1), st.integers(0, 200),
       st.sampled_from([2, 4]), st.sampled_from(["train", "val"]))
@settings(max_examples=10)
def test_lm_host_shards_partition_global_batch(seed, step, hosts, split):
    cfg = reduced_config(get_config("llama3.2-1b"))
    batch, seq = 4, 8
    full = SyntheticLMData(cfg, batch, seq, seed=seed,
                           split=split).batch_at(step)
    per = batch // hosts
    shards = [SyntheticLMData(cfg, per, seq, seed=seed, split=split,
                              sample_offset=h * per).batch_at(step)
              for h in range(hosts)]
    for k in full:
        np.testing.assert_array_equal(
            np.concatenate([s[k] for s in shards]), full[k], err_msg=k)


@given(st.integers(0, 2 ** 31 - 1), st.integers(0, 50),
       st.integers(1, 3))
@settings(max_examples=10)
def test_pipeline_restart_regenerates_bitwise(seed, start, workers):
    """(seed, split, step, host) fully determines the stream: a
    pipeline torn down and rebuilt at an arbitrary start step delivers
    bitwise-identical batches — the contract rollback recovery and
    elastic restarts lean on."""
    src = SyntheticImageData(4, 8, 4, seed=seed)
    p1 = DataPipeline(src, start_step=start, num_workers=workers)
    try:
        first = [next(p1) for _ in range(3)]
    finally:
        p1.close()
    p2 = DataPipeline(src, start_step=start, num_workers=1)
    try:
        again = [next(p2) for _ in range(3)]
    finally:
        p2.close()
    for (s1, b1), (s2, b2) in zip(first, again):
        assert s1 == s2
        for k in b1:
            np.testing.assert_array_equal(b1[k], b2[k], err_msg=str(s1))
