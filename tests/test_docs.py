"""Docs-integrity checks: every DESIGN.md reference in src/ resolves."""
import os
import re

REPO = os.path.join(os.path.dirname(__file__), "..")


def _design_sections():
    text = open(os.path.join(REPO, "DESIGN.md")).read()
    return set(re.findall(r"^## §(\d+)", text, flags=re.M))


def _src_references():
    refs = []  # (path, lineno, section or None)
    for root, _dirs, files in os.walk(os.path.join(REPO, "src")):
        for fn in files:
            if not fn.endswith(".py"):
                continue
            path = os.path.join(root, fn)
            for i, line in enumerate(open(path), 1):
                for m in re.finditer(
                        r"DESIGN\.md(?:\s*(?:§|section\s+)(\d+))?", line):
                    refs.append((os.path.relpath(path, REPO), i, m.group(1)))
    return refs


def test_design_md_exists_with_cited_sections():
    sections = _design_sections()
    # the sections modules cite must all exist
    assert {"2", "3", "4", "5", "6"} <= sections, sections


def test_every_design_reference_resolves():
    sections = _design_sections()
    refs = _src_references()
    assert refs, "expected DESIGN.md references in src/"
    dangling = [(p, ln) for p, ln, sec in refs if sec is None]
    missing = [(p, ln, sec) for p, ln, sec in refs
               if sec is not None and sec not in sections]
    assert not missing, f"references to nonexistent sections: {missing}"
    assert not dangling, (
        f"bare DESIGN.md references (cite a §N anchor): {dangling}")


def test_readme_exists_and_covers_basics():
    text = open(os.path.join(REPO, "README.md")).read()
    for needle in ("quickstart", "pytest", "src/repro"):
        assert needle in text, f"README.md missing {needle!r}"
