"""Bucketed gradient all-reduce (distributed/bucketing.py, DESIGN.md §6).

Single-device tests cover the pack/unpack layout and the Pallas
cast+copy kernel pair (interpret mode); the multi-device equivalence
tests (bucketed == per-leaf bitwise, EF residual parity) run in
subprocesses on a virtual host mesh, like tests/test_distributed.py.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compression import parse_compression
from repro.distributed.bucketing import (
    pack,
    plan_buckets,
    unpack,
)

ENV = {
    **os.environ,
    "XLA_FLAGS": "--xla_force_host_platform_device_count=2",
    "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src"),
}


def run_py(body: str, timeout=420) -> str:
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         env=ENV, capture_output=True, text=True,
                         timeout=timeout)
    assert res.returncode == 0, f"STDERR:\n{res.stderr[-4000:]}"
    return res.stdout


# ---------------------------------------------------------------------------
# plan / parse
# ---------------------------------------------------------------------------


def test_parse_compression():
    assert parse_compression(None) == (None, False)
    assert parse_compression("none") == (None, False)
    assert parse_compression("bf16") == ("bf16", False)
    assert parse_compression("f16") == ("f16", False)
    assert parse_compression("bf16+bucketed") == ("bf16", True)
    assert parse_compression("f16+bucketed") == ("f16", True)
    assert parse_compression("bucketed") == (None, True)
    with pytest.raises(ValueError):
        parse_compression("int8")
    with pytest.raises(ValueError, match="conflicting wire"):
        parse_compression("bf16+f16")
    with pytest.raises(ValueError, match="duplicate"):
        parse_compression("bucketed+bucketed")


def test_plan_collective_count_bound():
    """n_buckets == ceil(total_wire_bytes / bucket_bytes), no
    fragmentation waste even with many odd-size leaves."""
    leaves = {f"l{i}": jnp.zeros((97 + i,)) for i in range(50)}
    total = sum(x.size for x in jax.tree.leaves(leaves))
    for bucket_bytes in (256, 1024, 1 << 20):
        plan = plan_buckets(leaves, bucket_bytes=bucket_bytes, wire="bf16")
        expect = max(1, -(-total * 2 // bucket_bytes))
        assert plan.n_buckets == expect, (bucket_bytes, plan.n_buckets)
        lo, hi = plan.bucket_bounds(plan.n_buckets - 1)
        assert hi == total  # last bucket truncated, not zero-padded


def test_plan_no_wire_keeps_leaf_dtype():
    """wire=None must not upcast: the stream (and the psum) stay in the
    leaves' own dtype, and bucket sizing uses that itemsize."""
    tree = {"a": jnp.zeros((100,), jnp.bfloat16),
            "b": jnp.zeros((28,), jnp.bfloat16)}
    plan = plan_buckets(tree, bucket_bytes=64, wire=None)
    assert plan.stream_dtype == jnp.dtype(jnp.bfloat16)
    assert plan.bucket_elems == 32  # 64 B / 2 B, not / 4 B
    buckets = pack(tree, plan, use_kernel=False)
    assert all(b.dtype == jnp.bfloat16 for b in buckets)
    mixed = {"a": jnp.zeros((4,), jnp.float32),
             "b": jnp.zeros((4,), jnp.bfloat16)}
    with pytest.raises(ValueError, match="uniform leaf dtypes"):
        plan_buckets(mixed, bucket_bytes=64, wire=None)


def test_error_feedback_rejected_outside_shardmap():
    from repro.configs import OptimizerConfig, get_config, reduced_config
    from repro.launch.train import build_train_setup
    cfg = reduced_config(get_config("resnet50"))
    with pytest.raises(ValueError, match="shard_map"):
        build_train_setup(cfg, global_batch=8, seq_len=16,
                          opt_cfg=OptimizerConfig(), steps_per_epoch=5,
                          error_feedback=True)


# ---------------------------------------------------------------------------
# pack/unpack round-trip
# ---------------------------------------------------------------------------


ODD_TREE_SHAPES = [(3, 7), (129,), (1,), (), (50, 3, 2), (1000,)]


def _odd_tree(dtype):
    key = jax.random.PRNGKey(0)
    tree = {}
    for i, shp in enumerate(ODD_TREE_SHAPES):
        key, sub = jax.random.split(key)
        tree[f"leaf{i}"] = jax.random.normal(sub, shp).astype(dtype)
    return tree


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float16])
@pytest.mark.parametrize("use_kernel", [False, True])
def test_roundtrip_identity_per_leaf(dtype, use_kernel):
    """pack -> unpack restores every leaf exactly once the values are
    wire-representable (odd sizes, scalars, padding across buckets)."""
    tree = _odd_tree(dtype)
    # make values exactly representable in the wire dtype so the
    # round-trip is identity, not just close
    tree = jax.tree.map(
        lambda x: x.astype(jnp.bfloat16).astype(dtype), tree)
    plan = plan_buckets(tree, bucket_bytes=512, wire="bf16")
    assert plan.n_buckets > 1  # leaves genuinely span buckets
    buckets = pack(tree, plan, use_kernel=use_kernel)
    assert all(b.dtype == jnp.bfloat16 for b in buckets)
    out = unpack(buckets, plan, use_kernel=use_kernel)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert a.shape == b.shape and a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_roundtrip_no_wire_cast_is_exact():
    """wire=None: bucketing alone (collective fusion without
    compression) is bit-exact for arbitrary f32 values."""
    tree = _odd_tree(jnp.float32)
    plan = plan_buckets(tree, bucket_bytes=512, wire=None)
    out = unpack(pack(tree, plan, use_kernel=False), plan,
                 use_kernel=False)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_kernel_matches_ref_oracle():
    """Pallas cast+copy kernel (interpret mode) == ref.cast_copy on odd
    lengths that exercise the lane padding."""
    from repro.kernels import ref
    from repro.kernels.bucket_ops import pack_cast, unpack_cast
    key = jax.random.PRNGKey(1)
    for n in (1, 127, 128, 129, 1000, 4096):
        x = jax.random.normal(key, (n,), jnp.float32)
        got = pack_cast(x, jnp.bfloat16, interpret=True)
        want = ref.cast_copy(x, jnp.bfloat16)
        assert got.shape == (n,) and got.dtype == jnp.bfloat16
        np.testing.assert_array_equal(np.asarray(got, np.float32),
                                      np.asarray(want, np.float32))
        back = unpack_cast(got, jnp.float32, interpret=True)
        np.testing.assert_array_equal(
            np.asarray(back), np.asarray(want, np.float32))


# ---------------------------------------------------------------------------
# multi-device equivalence (2-device host mesh, subprocess)
# ---------------------------------------------------------------------------


def test_bucketed_psum_matches_per_leaf_bitwise():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core.compression import compressed_psum
        from repro.distributed.bucketing import bucketed_psum
        mesh = jax.make_mesh((2,), ('data',))
        key = jax.random.PRNGKey(0)
        grads = {'a': jax.random.normal(key, (2, 300, 7)),
                 'b': jax.random.normal(key, (2, 129)),
                 'c': jax.random.normal(key, (2,))}
        specs = jax.tree.map(lambda _: P('data'), grads)
        outs = {'a': P(), 'b': P(), 'c': P()}
        def leaf(g):
            local = jax.tree.map(lambda x: x[0] if x.ndim > 1 else x[0:1][0],
                                 g)
            return compressed_psum(local, ('data',), 'bf16')
        def bucket(g):
            local = jax.tree.map(lambda x: x[0] if x.ndim > 1 else x[0:1][0],
                                 g)
            return bucketed_psum(local, ('data',), wire='bf16',
                                 bucket_bytes=1024, use_kernel=False)
        kw = dict(mesh=mesh, in_specs=(specs,), out_specs=outs,
                  check_rep=False)
        r1 = shard_map(leaf, **kw)(grads)
        r2 = shard_map(bucket, **kw)(grads)
        for x, y in zip(jax.tree.leaves(r1), jax.tree.leaves(r2)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        print('BITWISE_OK')
    """)
    assert "BITWISE_OK" in out


def test_error_feedback_residuals_identical_both_paths():
    """EF happens before packing, so residuals (and synced grads) must
    accumulate identically over multiple steps in both paths."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core.compression import (compressed_psum_ef,
                                            init_error_feedback)
        from repro.distributed.bucketing import bucketed_psum_ef
        mesh = jax.make_mesh((2,), ('data',))
        key = jax.random.PRNGKey(0)
        grads = {'a': jax.random.normal(key, (2, 300, 7)),
                 'b': jax.random.normal(key, (2, 129))}
        specs = jax.tree.map(lambda _: P('data'), grads)
        gspec = {'a': P(), 'b': P()}
        def leaf(g, r):
            local = jax.tree.map(lambda x: x[0], g)
            return compressed_psum_ef(local, r, ('data',), 'bf16')
        def bucket(g, r):
            local = jax.tree.map(lambda x: x[0], g)
            return bucketed_psum_ef(local, r, ('data',), wire='bf16',
                                    bucket_bytes=1024, use_kernel=False)
        kw = dict(mesh=mesh,
                  in_specs=(specs, jax.tree.map(lambda _: P(), gspec)),
                  out_specs=(gspec, jax.tree.map(lambda _: P(), gspec)),
                  check_rep=False)
        r_leaf = init_error_feedback({'a': grads['a'][0],
                                      'b': grads['b'][0]})
        r_buck = jax.tree.map(lambda x: x, r_leaf)
        for step in range(4):
            g = jax.tree.map(lambda x: x * (1.0 + 0.37 * step), grads)
            s1, r_leaf = shard_map(leaf, **kw)(g, r_leaf)
            s2, r_buck = shard_map(bucket, **kw)(g, r_buck)
            for x, y in zip(jax.tree.leaves((s1, r_leaf)),
                            jax.tree.leaves((s2, r_buck))):
                np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
            # residuals are genuinely nonzero (EF is doing something)
            assert max(float(jnp.abs(x).max())
                       for x in jax.tree.leaves(r_leaf)) > 0
        print('EF_OK')
    """)
    assert "EF_OK" in out


def test_hlo_collective_count_and_dtype():
    """The fusion claim, verified from compiled HLO: bucketed mode
    issues <= ceil(total_wire_bytes/bucket_bytes) all-reduces for the
    gradients, vs one per leaf in per-leaf mode, at the wire dtype."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core.compression import compressed_psum
        from repro.distributed.bucketing import bucketed_psum, plan_buckets
        from repro.launch.hlo_analysis import analyze_hlo, comm_report
        mesh = jax.make_mesh((2,), ('data',))
        key = jax.random.PRNGKey(0)
        grads = {f'l{i}': jax.random.normal(key, (97 + i,))
                 for i in range(20)}
        specs = jax.tree.map(lambda _: P(), grads)
        BUCKET = 1024
        def leaf(g):
            return compressed_psum(g, ('data',), 'f16')
        def bucket(g):
            return bucketed_psum(g, ('data',), wire='f16',
                                 bucket_bytes=BUCKET, use_kernel=False)
        kw = dict(mesh=mesh, in_specs=(specs,), out_specs=specs,
                  check_rep=False)
        counts = {}
        for name, fn in (('leaf', leaf), ('bucket', bucket)):
            txt = jax.jit(shard_map(fn, **kw)).lower(grads)\
                .compile().as_text()
            cr = comm_report(analyze_hlo(txt, 2))
            ar = cr['per_op'].get('all-reduce', {})
            counts[name] = ar.get('executions_per_step', 0)
            assert any('f16' in d for d in ar.get('dtype_bytes', {})), ar
        plan = plan_buckets(grads, BUCKET, 'f16')
        total_wire = plan.total_elems * 2
        bound = -(-total_wire // BUCKET)
        assert counts['bucket'] <= bound, (counts, bound)
        assert counts['leaf'] == len(grads), counts
        assert counts['bucket'] < counts['leaf']
        print('HLO_OK', counts)
    """)
    assert "HLO_OK" in out


@pytest.mark.slow
def test_shardmap_bucketed_mode_trains_identically():
    """End-to-end: dp_mode=shardmap with compression='bf16+bucketed'
    produces the same loss trajectory as per-leaf 'bf16' (ResNet-50,
    2 workers). The sync primitive itself is bitwise-identical (tested
    above); at whole-program level XLA may still fuse/reorder *other*
    reductions (BN batch stats) differently between the two compiles,
    so the trajectory check uses a tight tolerance instead of ==."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import OptimizerConfig, get_config, reduced_config
        from repro.launch.train import build_train_setup
        cfg = reduced_config(get_config('resnet50'))
        mesh = jax.make_mesh((2, 1), ('data', 'model'))
        losses = {}
        for comp in ('bf16', 'bf16+bucketed'):
            model, state, step, data, put, _ = build_train_setup(
                cfg, global_batch=8, seq_len=16,
                opt_cfg=OptimizerConfig(), steps_per_epoch=5, mesh=mesh,
                dp_mode='shardmap', seed=0, sync_bn=True,
                compression=comp, bucket_bytes=4096)
            ls = []
            for s in range(3):
                batch = put({k: jnp.asarray(v)
                             for k, v in data.batch_at(s).items()})
                state, metrics = step(state, batch)
                ls.append(float(metrics['loss']))
            losses[comp] = ls
        np.testing.assert_allclose(losses['bf16'],
                                   losses['bf16+bucketed'],
                                   rtol=1e-5, atol=0)
        print('TRAIN_OK', losses['bf16'])
    """)
    assert "TRAIN_OK" in out
