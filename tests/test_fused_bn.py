"""Fused Pallas batch norm (kernels/fused_bn.py, DESIGN.md §10).

Fast lane: single-config fwd/bwd parity vs the jnp oracle, gradcheck,
multi-block accumulation, the given-stats (eval) variant with full
mean/var cotangents, and the real-lowering fusion_report collapse
proof. The full {train, eval} x {ReLU, identity, residual} x
{f32, bf16} parity matrix, the cross-replica (sync-BN) 8-virtual-device
check, and the 3-step fused-vs-unfused train-step parity run under the
``slow`` marker (subprocess compiles dominate), like the §9 sweeps.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.batchnorm import bn_apply_stats
from repro.kernels import fused_bn as fb
from repro.kernels import ops, ref

ENV8 = {
    **os.environ,
    "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
    "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src"),
}


def run_py(body: str, env=ENV8, timeout=600) -> str:
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert res.returncode == 0, f"STDERR:\n{res.stderr[-4000:]}"
    return res.stdout


def _data(key, shape, dtype, has_res):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], shape, dtype) * 2.0 + 0.5
    res = (jax.random.normal(ks[1], shape, dtype) if has_res else None)
    scale = 1.0 + 0.1 * jax.random.normal(ks[2], (shape[-1],))
    bias = 0.1 * jax.random.normal(ks[3], (shape[-1],))
    dy = jax.random.normal(ks[4], shape, dtype)
    return x, res, scale, bias, dy


def _assert_train_parity(shape, dtype, relu, has_res, key):
    """Fused fwd (y, mean, var) + VJP vs the jnp oracle. bf16 tolerances
    are loose for the reduced param grads: the oracle accumulates its
    reductions through bf16 intermediates while the kernel accumulates
    in fp32 (the kernel is the *more* accurate side); ReLU-boundary
    elements may also flip mask under bf16 rounding of the
    pre-activation."""
    x, res, scale, bias, dy = _data(key, shape, dtype, has_res)

    def fused(x, s, b, r):
        return ops.fused_bn_train(x, s, b, residual=r, relu=relu)

    def oracle(x, s, b, r):
        return ref.bn_forward(x, s, b, residual=r, relu=relu)

    (y1, m1, v1), vjp1 = jax.vjp(fused, x, scale, bias, res)
    (y2, m2, v2), vjp2 = jax.vjp(oracle, x, scale, bias, res)
    f32 = dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               atol=1e-4 if f32 else 5e-2,
                               rtol=1e-6 if f32 else 2e-2)
    np.testing.assert_allclose(m1, m2, atol=1e-4 if f32 else 5e-3)
    np.testing.assert_allclose(v1, v2, atol=1e-4 if f32 else 5e-3)
    cts = (dy, jnp.zeros_like(m1), jnp.zeros_like(v1))
    g1, g2 = vjp1(cts), vjp2(cts)
    for a, b, name in zip(g1, g2, ("dx", "dscale", "dbias", "dres")):
        if a is None and b is None:
            continue
        aa = np.asarray(a, np.float32)
        bb = np.asarray(b, np.float32)
        if f32:
            np.testing.assert_allclose(aa, bb, atol=5e-4, err_msg=name)
        elif name in ("dx", "dres"):
            np.testing.assert_allclose(aa, bb, atol=0.1, err_msg=name)
        else:
            np.testing.assert_allclose(aa, bb, rtol=0.2, atol=0.2,
                                       err_msg=name)


def _assert_eval_parity(shape, dtype, relu, has_res, key):
    """Given-stats variant vs oracle, with cotangents for every input
    including mean/var (the fused op stays differentiable everywhere)."""
    x, res, scale, bias, dy = _data(key, shape, dtype, has_res)
    ks = jax.random.split(jax.random.fold_in(key, 7), 2)
    mean = jax.random.normal(ks[0], (shape[-1],))
    var = jnp.abs(jax.random.normal(ks[1], (shape[-1],))) + 0.5

    def fused(x, m, v, s, b, r):
        return ops.fused_bn_apply(x, m, v, s, b, residual=r, relu=relu)

    def oracle(x, m, v, s, b, r):
        y = bn_apply_stats(x, m, v, s, b)
        if r is not None:
            y = y + r
        return jax.nn.relu(y) if relu else y

    y1, vjp1 = jax.vjp(fused, x, mean, var, scale, bias, res)
    y2, vjp2 = jax.vjp(oracle, x, mean, var, scale, bias, res)
    f32 = dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32),
                               atol=1e-4 if f32 else 5e-2,
                               rtol=1e-6 if f32 else 2e-2)
    names = ("dx", "dmean", "dvar", "dscale", "dbias", "dres")
    for a, b, name in zip(vjp1(dy), vjp2(dy), names):
        if a is None and b is None:
            continue
        aa = np.asarray(a, np.float32)
        bb = np.asarray(b, np.float32)
        if f32:
            np.testing.assert_allclose(aa, bb, atol=2e-3, err_msg=name)
        elif name in ("dx", "dres"):
            np.testing.assert_allclose(aa, bb, atol=0.1, err_msg=name)
        else:
            np.testing.assert_allclose(aa, bb, rtol=0.2, atol=0.2,
                                       err_msg=name)


# ---------------------------------------------------------------------------
# fast lane: smoke parity + kernel mechanics
# ---------------------------------------------------------------------------


def test_train_parity_smoke(key):
    """One representative cell of the matrix stays in the fast lane:
    f32, ReLU + residual epilogue (the ResNet block-output site)."""
    _assert_train_parity((4, 6, 5, 19), jnp.float32, True, True, key)


def test_eval_parity_smoke(key):
    _assert_eval_parity((8, 3, 3, 7), jnp.float32, True, True, key)


def test_gradcheck_identity_epilogue(key):
    """Numerical gradcheck on the custom VJP (identity epilogue: ReLU
    kinks would poison finite differences)."""
    from jax import test_util as jtu
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (2, 4, 4, 5))
    scale = 1.0 + 0.1 * jax.random.normal(ks[1], (5,))
    bias = 0.1 * jax.random.normal(ks[2], (5,))
    jtu.check_grads(lambda x, s, b: ops.fused_bn_train(x, s, b)[0],
                    (x, scale, bias), order=1, modes=["rev"],
                    atol=2e-2, rtol=2e-2)


def test_multiblock_accumulation(key):
    """Forcing a small row_block exercises the grid-accumulation path
    (the compiled-TPU tiling) against the same oracle; 105 rows over
    16-row blocks also hits the zero-pad tail."""
    x = jax.random.normal(key, (3, 5, 7, 11)) * 1.5 + 1.0
    dy = jax.random.normal(jax.random.fold_in(key, 1), x.shape)
    scale, bias = jnp.ones(11), jnp.zeros(11)

    def fused(x):
        return fb.fused_bn_train(x, scale, bias, relu=True,
                                 interpret=True, row_block=16)

    (y1, m1, v1), vjp1 = jax.vjp(fused, x)
    (y2, m2, v2), vjp2 = jax.vjp(lambda x: ref.bn_forward(
        x, scale, bias, relu=True), x)
    np.testing.assert_allclose(y1, y2, atol=1e-5)
    np.testing.assert_allclose(m1, m2, atol=1e-5)
    np.testing.assert_allclose(v1, v2, atol=1e-5)
    cts = (dy, jnp.zeros_like(m1), jnp.zeros_like(v1))
    np.testing.assert_allclose(np.asarray(vjp1(cts)[0]),
                               np.asarray(vjp2(cts)[0]), atol=1e-4)


def test_stats_output_cotangents(key):
    """The mean/var outputs carry real cotangents (zero in the training
    step, where new BN state is value_and_grad aux — but the op must
    stay correct when they are used)."""
    x = jax.random.normal(key, (3, 5, 7, 11))
    s, b = jnp.ones(11), jnp.zeros(11)

    def through_stats(f):
        def g(x):
            y, m, v = f(x)
            return jnp.sum(y) + 2.0 * jnp.sum(m) + 3.0 * jnp.sum(v)
        return g

    g1 = jax.grad(through_stats(
        lambda x: ops.fused_bn_train(x, s, b)))(x)
    g2 = jax.grad(through_stats(
        lambda x: ref.bn_forward(x, s, b)))(x)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4)


def test_large_mean_variance(key):
    """The stats kernel's block-centered + Chan-combined variance must
    match the centered oracle on the same large-mean bf16 data that
    breaks the uncentered E[x^2]-mu^2 form (see
    test_core_batchnorm.py::test_variance_large_mean_bf16_vs_f64_oracle)
    — in both the single-block and multi-block grid regimes."""
    k = jax.random.randint(key, (64, 4, 4, 8), -2, 3).astype(jnp.float32)
    x = (1024.0 + 4.0 * k).astype(jnp.bfloat16)
    x64 = np.asarray(x, np.float64)
    var64 = ((x64 - x64.mean((0, 1, 2))) ** 2).mean((0, 1, 2))
    for rb in (None, 16):  # whole-array block / 64-step grid
        _, mean, var = fb.fused_bn_train(
            x, jnp.ones(8), jnp.zeros(8), interpret=True, row_block=rb)
        np.testing.assert_allclose(np.asarray(var), var64, rtol=1e-3,
                                   err_msg=f"row_block={rb}")
        np.testing.assert_allclose(np.asarray(mean),
                                   x64.mean((0, 1, 2)), rtol=1e-6)


def test_resnet_apply_fused_matches_unfused(key):
    """Model level: the fused ResNet50 forward (train + eval paths)
    matches the unfused model on the same params/state."""
    from repro.configs import get_config, reduced_config
    from repro.models.resnet import ResNet50
    import dataclasses

    cfg = reduced_config(get_config("resnet50"))
    m0 = ResNet50(cfg, compute_dtype=jnp.float32)
    m1 = ResNet50(dataclasses.replace(cfg, fused_bn=True),
                  compute_dtype=jnp.float32)
    assert not m0.fused_bn and m1.fused_bn
    params = m0.init_params(key)[0]
    state = m0.init_state()
    x = jax.random.normal(jax.random.fold_in(key, 1), (4, 32, 32, 3))
    logits0, ns0 = m0.apply(params, state, x, train=True)
    logits1, ns1 = m1.apply(params, state, x, train=True)
    np.testing.assert_allclose(np.asarray(logits0), np.asarray(logits1),
                               atol=1e-3)
    for (k0, a), (k1, b) in zip(
            sorted(ns0.items()), sorted(ns1.items())):
        assert k0 == k1
        np.testing.assert_allclose(np.asarray(a["mean"]),
                                   np.asarray(b["mean"]), atol=1e-4,
                                   err_msg=k0)
        np.testing.assert_allclose(np.asarray(a["var"]),
                                   np.asarray(b["var"]), atol=1e-4,
                                   err_msg=k0)
    # eval path (given stats) through the fused apply kernel
    e0, _ = m0.apply(params, ns0, x, train=False)
    e1, _ = m1.apply(params, ns1, x, train=False)
    np.testing.assert_allclose(np.asarray(e0), np.asarray(e1), atol=1e-3)


def test_fusion_report_real_lowering():
    """The §10 claim from compiled HLO: per site, the fused fwd+VJP
    performs strictly fewer reduction passes than the unfused chain
    (2 stats + 2 backward sums vs XLA's mean/var/dscale/dbias/... set)
    and no more activation-sized writes."""
    from repro.launch.hlo_analysis import fusion_report

    shape = (4, 8, 8, 32)
    act = int(np.prod(shape))
    xs = jax.ShapeDtypeStruct(shape, jnp.float32)
    ss = jax.ShapeDtypeStruct((shape[-1],), jnp.float32)

    def prog(site):
        def p(x, scale, bias, res, dy):
            y, vjp = jax.vjp(site, x, scale, bias, res)
            return (y,) + vjp(dy)
        return jax.jit(p).lower(xs, ss, ss, xs, xs).compile().as_text()

    fused = prog(lambda x, s, b, r: ops.fused_bn_train(
        x, s, b, residual=r, relu=True)[0])
    unfused = prog(lambda x, s, b, r: ref.bn_forward(
        x, s, b, residual=r, relu=True)[0])
    rep = fusion_report(fused, unfused, act)
    assert rep["collapsed"], rep
    assert rep["fused"]["reduction_ops"] == 4.0, rep  # 2 fwd + 2 bwd
    assert rep["fused"]["reduction_ops"] < rep["unfused"]["reduction_ops"]


# ---------------------------------------------------------------------------
# slow lane: the full parity matrix + mesh tests
# ---------------------------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("relu", [False, True])
@pytest.mark.parametrize("has_res", [False, True])
def test_train_parity_matrix(dtype, relu, has_res, key):
    _assert_train_parity((4, 6, 5, 19), dtype, relu, has_res, key)


@pytest.mark.slow
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("relu", [False, True])
@pytest.mark.parametrize("has_res", [False, True])
def test_eval_parity_matrix(dtype, relu, has_res, key):
    _assert_eval_parity((8, 3, 3, 7), dtype, relu, has_res, key)


@pytest.mark.slow
def test_cross_replica_parity_8dev():
    """Sync-BN on the 8-virtual-device mesh: the fused kernel's local
    moments + pmean combine and its psum'd backward must match the
    oracle (bn_batch_stats cross_replica + apply + epilogue) — outputs,
    global statistics, and grads for x (per-worker) and scale/bias
    (replicated, cotangents psum'd by shard_map AD)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from functools import partial
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core.batchnorm import bn_apply_stats, bn_batch_stats
        from repro.kernels.fused_bn import fused_bn_train

        mesh = jax.make_mesh((8,), ("data",))
        ks = jax.random.split(jax.random.PRNGKey(0), 4)
        x = jax.random.normal(ks[0], (16, 4, 4, 12)) * 2.0 + 1.0
        cot = jax.random.normal(ks[1], x.shape)
        scale = 1.0 + 0.1 * jax.random.normal(ks[2], (12,))
        bias = 0.1 * jax.random.normal(ks[3], (12,))

        def make_loss(fused):
            def local(x, scale, bias, cot):
                if fused:
                    y, m, v = fused_bn_train(
                        x, scale, bias, relu=True,
                        cross_replica=("data",), interpret=True)
                else:
                    m, v = bn_batch_stats(x, cross_replica=("data",))
                    y = jax.nn.relu(
                        bn_apply_stats(x, m, v, scale, bias))
                loss = jax.lax.psum(jnp.sum(y * cot), ("data",))
                return loss, m, v
            sm = shard_map(local, mesh=mesh,
                           in_specs=(P("data"), P(), P(), P("data")),
                           out_specs=(P(), P(), P()),
                           check_rep=False)
            def loss(x, scale, bias):
                l, m, v = sm(x, scale, bias, cot)
                return l, (m, v)
            return loss

        outs = {}
        for fused in (False, True):
            (l, (m, v)), g = jax.jit(jax.value_and_grad(
                make_loss(fused), argnums=(0, 1, 2),
                has_aux=True))(x, scale, bias)
            outs[fused] = (l, m, v) + g
        names = ("loss", "mean", "var", "dx", "dscale", "dbias")
        for a, b, n in zip(outs[False], outs[True], names):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=5e-4, err_msg=n)
        print("CROSS_REPLICA_OK")
    """)
    assert "CROSS_REPLICA_OK" in out


@pytest.mark.slow
def test_fused_composes_with_overlap_and_zero_8dev():
    """The fused sites live inside the staged stem/stage0..3 segment
    forwards/VJPs and change no gradient leaf structure, so --fused-bn
    must compose with the backward-overlapped ZeRO step (§8/§9):
    2 steps of the fused overlap+zero step match the fused plain
    bucketed step within tolerance."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import OptimizerConfig, get_config, \\
            reduced_config
        from repro.launch.train import build_train_setup

        cfg = reduced_config(get_config("resnet50"))
        mesh = jax.make_mesh((8, 1), ("data", "model"))

        def run(**kw):
            model, state, step, data, put, _ = build_train_setup(
                cfg, global_batch=16, seq_len=16,
                opt_cfg=OptimizerConfig(), steps_per_epoch=10,
                mesh=mesh, dp_mode="shardmap",
                compression="bf16+bucketed", bucket_bytes=16 * 1024,
                seed=0, fused_bn=True, **kw)
            batch = put({k: jnp.asarray(v)
                         for k, v in data.batch_at(0).items()})
            for _ in range(2):
                state, metrics = step(state, dict(batch))
            return state

        s0 = run()
        s1 = run(overlap_comm=True, zero_dp=True)
        for part in ("params", "model_state"):
            l0 = sorted(jax.tree_util.tree_leaves_with_path(s0[part]),
                        key=lambda t: str(t[0]))
            l1 = sorted(jax.tree_util.tree_leaves_with_path(s1[part]),
                        key=lambda t: str(t[0]))
            assert len(l0) == len(l1) and l0
            for (k0, a), (k1, b) in zip(l0, l1):
                np.testing.assert_allclose(
                    np.asarray(a, np.float32),
                    np.asarray(b, np.float32), atol=1e-5,
                    err_msg=f"{part}{k0}")
        print("COMPOSE_OK")
    """)
    assert "COMPOSE_OK" in out


@pytest.mark.slow
@pytest.mark.parametrize("sync_bn", [False, True],
                         ids=["plain", "cross_replica"])
def test_fused_step_matches_unfused_3steps_8dev(sync_bn):
    """Acceptance: the fused-BN training step (shardmap bucketed, 8
    virtual devices, --fused-bn) matches the unfused step's params and
    BN state within tolerance after 3 steps, plain and sync-BN."""
    out = run_py(f"""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import OptimizerConfig, get_config, \\
            reduced_config
        from repro.launch.train import build_train_setup

        cfg = reduced_config(get_config("resnet50"))
        mesh = jax.make_mesh((8, 1), ("data", "model"))

        def run(fused):
            model, state, step, data, put, _ = build_train_setup(
                cfg, global_batch=16, seq_len=16,
                opt_cfg=OptimizerConfig(), steps_per_epoch=10,
                mesh=mesh, dp_mode="shardmap",
                compression="bf16+bucketed",
                bucket_bytes=16 * 1024, sync_bn={sync_bn},
                seed=0, fused_bn=fused)
            batch = put({{k: jnp.asarray(v)
                          for k, v in data.batch_at(0).items()}})
            for _ in range(3):
                state, metrics = step(state, dict(batch))
            return state

        s0, s1 = run(False), run(True)
        for part in ("params", "model_state"):
            l0 = sorted(jax.tree_util.tree_leaves_with_path(s0[part]),
                        key=lambda t: str(t[0]))
            l1 = sorted(jax.tree_util.tree_leaves_with_path(s1[part]),
                        key=lambda t: str(t[0]))
            assert len(l0) == len(l1) and l0
            for (k0, a), (k1, b) in zip(l0, l1):
                np.testing.assert_allclose(
                    np.asarray(a, np.float32),
                    np.asarray(b, np.float32), atol=5e-4,
                    err_msg=f"{{part}}{{k0}}")
        print("STEP_PARITY_OK")
    """)
    assert "STEP_PARITY_OK" in out
