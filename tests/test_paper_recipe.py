"""The paper's equations, verified exactly (Appendix A)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.optimizer import (
    HybridHyper,
    alpha_rmsprop,
    hybrid_update,
    momentum_sgd_update,
)
from repro.core.schedules import (
    alpha_sgd_schedule,
    goyal_lr,
    linear_scaling_lr,
    slow_start_lr,
)


class TestHybridRule:
    def test_alpha_sgd_1_is_momentum_sgd(self, key):
        """a_sgd=1, eta_rmsprop contribution vanishes => exact momentum SGD."""
        g, p, d = [jax.random.normal(k, (64,)) for k in
                   jax.random.split(key, 3)]
        m = jnp.abs(jax.random.normal(key, (64,)))
        h = HybridHyper(eta=jnp.float32(0.1), alpha_sgd=jnp.float32(1.0),
                        eta_rmsprop=0.0)
        p1, d1, m1 = hybrid_update(g, p, d, m, h)
        p2, d2 = momentum_sgd_update(g, p, d, h)
        np.testing.assert_allclose(p1, p2, rtol=1e-6)
        np.testing.assert_allclose(d1, d2, rtol=1e-6)
        # m still accumulates (it's the RMSprop second moment)
        np.testing.assert_allclose(m1, 0.99 * m + 0.01 * g * g, rtol=1e-6)

    def test_alpha_sgd_0_is_rmsprop_with_momentum(self, key):
        """a_sgd=0: Delta = mu1*Delta - (eta_rms/eta)/(sqrt(m)+eps) * g."""
        g, p, d = [jax.random.normal(k, (64,)) for k in
                   jax.random.split(key, 3)]
        m = jnp.abs(jax.random.normal(key, (64,)))
        eta, eta_rms = 0.4, 3e-4
        h = HybridHyper(eta=jnp.float32(eta), alpha_sgd=jnp.float32(0.0),
                        eta_rmsprop=eta_rms)
        p1, d1, m1 = hybrid_update(g, p, d, m, h)
        m_ref = 0.99 * m + 0.01 * g * g
        d_ref = 0.9 * d - (eta_rms / eta) / (jnp.sqrt(m_ref) + 1e-8) * g
        np.testing.assert_allclose(d1, d_ref, rtol=1e-5)
        np.testing.assert_allclose(p1, p + eta * d_ref, rtol=1e-5)

    def test_momentum_correction_coupling(self):
        """Paper A.1: a_rms = (1-a_sgd) * eta_rms / eta_sgd, so the
        *effective* RMSprop step eta*a_rms/sqrt(m) is eta-independent."""
        for eta in (0.1, 1.0, 12.8):
            h = HybridHyper(eta=jnp.float32(eta),
                            alpha_sgd=jnp.float32(0.25))
            eff = float(h.eta * alpha_rmsprop(h))
            np.testing.assert_allclose(eff, 0.75 * 3e-4, rtol=1e-6)

    def test_update_is_fp32_and_finite(self, key):
        g = jax.random.normal(key, (128,), jnp.bfloat16)
        p = jax.random.normal(key, (128,), jnp.bfloat16)
        h = HybridHyper(eta=jnp.float32(1.0), alpha_sgd=jnp.float32(0.5))
        p1, d1, m1 = hybrid_update(g, p, jnp.zeros(128), jnp.zeros(128), h)
        assert p1.dtype == jnp.bfloat16  # params keep their dtype
        assert d1.dtype == jnp.float32 and m1.dtype == jnp.float32
        assert bool(jnp.isfinite(d1).all())


class TestTransitionSchedule:
    def test_paper_anchor_points(self):
        # 1/2 at beta_center=10
        np.testing.assert_allclose(alpha_sgd_schedule(10.0), 0.5, rtol=1e-6)
        # 1 at beta_center + beta_period/2 = 12.5, and stays 1
        np.testing.assert_allclose(alpha_sgd_schedule(12.5), 1.0, rtol=1e-6)
        assert float(alpha_sgd_schedule(50.0)) == 1.0
        # exponential region: a(10 - 2.5) = 0.5 * exp(-1)
        np.testing.assert_allclose(alpha_sgd_schedule(7.5),
                                   0.5 * np.exp(-1.0), rtol=1e-5)

    def test_monotone_and_continuous(self):
        e = jnp.linspace(0.0, 20.0, 2001)
        a = alpha_sgd_schedule(e)
        assert bool(jnp.all(jnp.diff(a) >= -1e-7))
        # max slope is the linear segment's 2/beta_period = 0.4/epoch;
        # at 0.01-epoch resolution a jump would show as diff >> 0.004
        assert bool(jnp.all(jnp.abs(jnp.diff(a)) < 6e-3))
        assert float(a[0]) < 0.01 and float(a[-1]) == 1.0


class TestLRSchedules:
    def test_linear_scaling_paper_value(self):
        # paper: n=1024, b_local=32 => eta_base = 12.8
        assert linear_scaling_lr(32768) == pytest.approx(12.8)

    def test_slow_start_piecewise(self):
        eta = 12.8
        assert float(slow_start_lr(0.0, eta)) == pytest.approx(0.5 * eta)
        assert float(slow_start_lr(39.9, eta)) == pytest.approx(0.5 * eta)
        assert float(slow_start_lr(40.1, eta)) == pytest.approx(0.075 * eta)
        assert float(slow_start_lr(70.1, eta)) == pytest.approx(0.01 * eta)
        assert float(slow_start_lr(85.1, eta)) == pytest.approx(0.001 * eta)

    def test_slow_start_lower_than_goyal_at_start(self):
        """The 'slow start': initial LR is half of Goyal's target."""
        eta = 12.8
        assert float(slow_start_lr(0.0, eta)) < eta

    def test_goyal_warmup(self):
        eta = 12.8
        assert float(goyal_lr(0.0, eta)) == pytest.approx(0.1)
        assert float(goyal_lr(5.0, eta)) == pytest.approx(eta)
        assert float(goyal_lr(29.0, eta)) == pytest.approx(eta)
        assert float(goyal_lr(30.5, eta)) == pytest.approx(0.1 * eta)
        assert float(goyal_lr(60.5, eta)) == pytest.approx(0.01 * eta)
        assert float(goyal_lr(80.5, eta)) == pytest.approx(0.001 * eta)


class TestTransitionAblation:
    """Paper A.1's design rationale: a sudden RMSprop->SGD switch shocks
    training; the smooth ELU transition does not (reduced-scale repro)."""

    @staticmethod
    def _train(transition):
        import numpy as np

        from repro.configs import (
            OptimizerConfig,
            get_config,
            reduced_config,
        )
        from repro.launch.train import build_train_setup
        cfg = reduced_config(get_config("resnet50"))
        # the proxy regime must mirror the paper's: training still in
        # progress (O(1) loss, O(1) gradients) when the transition epoch
        # arrives. data_noise=2.0 keeps the synthetic task unmemorized at
        # step 10, and lr=1.2 is stable for steady-state SGD yet large
        # enough that suddenly dropping the RMSprop preconditioner
        # shocks the loss (paper A.1).
        opt_cfg = OptimizerConfig(kind="rmsprop_warmup",
                                  schedule="constant",
                                  transition=transition,
                                  base_lr_per_256=0.1 * 12.0,
                                  beta_center=1.0, beta_period=1.0)
        model, state, step_fn, data, _, _ = build_train_setup(
            cfg, global_batch=256, seq_len=16, opt_cfg=opt_cfg,
            steps_per_epoch=10, data_noise=2.0)
        losses = []
        for s in range(20):
            batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
            state, metrics = step_fn(state, batch)
            losses.append(float(metrics["loss"]))
        return losses

    def test_sudden_transition_shocks_elu_does_not(self):
        import numpy as np
        elu = self._train("elu")
        sudden = self._train("sudden")

        def spike(ls):
            post = [l for l in ls[10:15] if np.isfinite(l)]
            return (max(post) - ls[9]) if post else float("inf")

        assert spike(elu) < 0.5, elu
        assert spike(sudden) > 1.0, sudden
