"""Production input pipeline (repro.data.pipeline, DESIGN.md §15).

Covers the DataPipeline delivery/error/close/backpressure contracts,
the device-staging double buffer, the legacy Prefetcher raise-once
port, and the SyntheticImageData allocation regression.
"""
import threading
import time
import tracemalloc

import numpy as np
import pytest

from repro.data.pipeline import DataPipeline, StepStampSource
from repro.data.synthetic import Prefetcher, SyntheticImageData


class CountingSource:
    """batch_at returns a recognizable payload and records every step
    (thread-safely), with an optional per-step delay/failure."""

    def __init__(self, batch=4, delay=0.0, fail_at=None,
                 delays=None):
        self.batch = batch
        self.delay = delay
        self.fail_at = fail_at
        self.delays = delays or {}
        self.calls = []
        self._lock = threading.Lock()

    def batch_at(self, step):
        with self._lock:
            self.calls.append(step)
        time.sleep(self.delays.get(step, self.delay))
        if self.fail_at is not None and step == self.fail_at:
            raise RuntimeError(f"boom at {step}")
        return {"x": np.full((self.batch,), step, np.int64)}


# ---------------------------------------------------------------------------
# ordered delivery and determinism
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("workers", [1, 4])
def test_ordered_delivery(workers):
    src = CountingSource()
    pipe = DataPipeline(src, num_workers=workers, depth=4)
    try:
        for want in range(10):
            step, batch = next(pipe)
            assert step == want
            np.testing.assert_array_equal(batch["x"], want)
    finally:
        pipe.close()


def test_multi_worker_bitwise_equals_single_worker():
    """Worker count is a throughput knob, not a semantic one: the
    delivered stream is bitwise identical for any num_workers."""
    src = SyntheticImageData(4, 8, 4, seed=3)
    ref = [src.batch_at(s) for s in range(6)]
    pipe = DataPipeline(src, num_workers=3, depth=4)
    try:
        for s in range(6):
            step, batch = next(pipe)
            assert step == s
            for k in ref[s]:
                np.testing.assert_array_equal(batch[k], ref[s][k])
    finally:
        pipe.close()


def test_start_step_and_restart_stability():
    """A pipeline rebuilt at step k (elastic restart / rollback seek)
    delivers exactly what the original stream had at step k."""
    src = SyntheticImageData(4, 8, 4, seed=0)
    p1 = DataPipeline(src, num_workers=2)
    try:
        seen = {s: b for s, b in (next(p1) for _ in range(5))}
    finally:
        p1.close()
    p2 = DataPipeline(src, start_step=3, num_workers=2)
    try:
        step, batch = next(p2)
        assert step == 3
        np.testing.assert_array_equal(batch["images"], seen[3]["images"])
        np.testing.assert_array_equal(batch["labels"], seen[3]["labels"])
    finally:
        p2.close()


def test_transform_applied_by_workers():
    src = CountingSource()
    pipe = DataPipeline(src, num_workers=2,
                        transform=lambda b: {"x": b["x"] * 10})
    try:
        for want in range(4):
            _, batch = next(pipe)
            np.testing.assert_array_equal(batch["x"], want * 10)
    finally:
        pipe.close()


# ---------------------------------------------------------------------------
# backpressure
# ---------------------------------------------------------------------------


def test_backpressure_bounds_claim_horizon():
    """Producers may claim at most ``depth`` steps past the last
    delivered one — a stalled consumer stalls the pool instead of
    buffering unboundedly."""
    src = CountingSource()
    depth = 3
    pipe = DataPipeline(src, num_workers=4, depth=depth)
    try:
        next(pipe)  # consumer at step 1 now
        time.sleep(0.3)  # give the pool every chance to overrun
        assert max(src.calls) <= depth  # claims < next_out(1) + depth
    finally:
        pipe.close()


# ---------------------------------------------------------------------------
# error contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("workers", [1, 3])
def test_error_raised_once_at_its_step_then_stopiteration(workers):
    src = CountingSource(fail_at=2)
    pipe = DataPipeline(src, num_workers=workers, depth=4)
    try:
        for want in range(2):  # earlier steps still arrive
            step, _ = next(pipe)
            assert step == want
        with pytest.raises(RuntimeError, match="boom at 2"):
            next(pipe)
        # exactly once; afterwards the stream is closed, not a loop of
        # re-raises of the same exception object
        with pytest.raises(StopIteration):
            next(pipe)
    finally:
        pipe.close()


def test_error_attributed_to_smallest_failed_step():
    """With concurrent workers, a fast-failing later step must not
    mask (or get masked by) the error the consumer hits first."""
    src = CountingSource(fail_at=1, delays={0: 0.2})
    pipe = DataPipeline(src, num_workers=4, depth=4)
    try:
        step, _ = next(pipe)  # step 0, despite being the slowest
        assert step == 0
        with pytest.raises(RuntimeError, match="boom at 1"):
            next(pipe)
    finally:
        pipe.close()


def test_close_unblocks_waiting_consumer():
    src = CountingSource(delay=60.0)  # nothing will ever be ready
    pipe = DataPipeline(src, num_workers=2, depth=2)
    got = {}

    def consume():
        try:
            next(pipe)
        except StopIteration:
            got["stopped"] = True

    t = threading.Thread(target=consume)
    t.start()
    time.sleep(0.2)
    pipe.close()
    t.join(timeout=5)
    assert not t.is_alive(), "consumer stayed parked across close()"
    assert got.get("stopped")


def test_close_idempotent_and_joins_workers():
    src = CountingSource()
    pipe = DataPipeline(src, num_workers=3)
    next(pipe)
    pipe.close()
    pipe.close()
    assert all(not t.is_alive() for t in pipe._threads)


# ---------------------------------------------------------------------------
# device staging
# ---------------------------------------------------------------------------


def test_device_staging_orders_and_stages_each_step_once():
    staged = []

    def put(batch):
        staged.append(int(batch["x"][0]))
        return {"x": batch["x"] + 1000}

    src = CountingSource()
    pipe = DataPipeline(src, num_workers=2, depth=4, put=put,
                        device_ahead=2)
    try:
        for want in range(8):
            step, batch = next(pipe)
            assert step == want
            np.testing.assert_array_equal(batch["x"], want + 1000)
        # each step staged exactly once, in order
        assert staged[:8] == list(range(8))
        assert len(staged) == len(set(staged))
    finally:
        pipe.close()


def test_device_staging_never_swallows_error_attribution():
    """Opportunistic staging for step k+1 must not raise step k+1's
    error while the caller is still consuming step k."""
    src = CountingSource(fail_at=1)
    pipe = DataPipeline(src, num_workers=2, depth=4,
                        put=lambda b: b, device_ahead=2)
    try:
        step, _ = next(pipe)  # stages ahead; error at 1 already pending
        assert step == 0
        with pytest.raises(RuntimeError, match="boom at 1"):
            next(pipe)
    finally:
        pipe.close()


def test_wait_attribution_counters():
    src = CountingSource(delays={3: 0.25})
    pipe = DataPipeline(src, num_workers=1, depth=2)
    try:
        waits = []
        for _ in range(5):
            next(pipe)
            waits.append(pipe.last_wait_s)
        assert pipe.batches_delivered == 5
        assert pipe.wait_s_total == pytest.approx(sum(waits))
        assert max(waits) >= 0.1  # the slow step shows up as wait
    finally:
        pipe.close()


def test_step_stamp_source():
    src = StepStampSource(CountingSource())
    b = src.batch_at(7)
    assert b["input_step"] == np.int32(7)
    assert b["input_step"].dtype == np.int32
    np.testing.assert_array_equal(b["x"], 7)


# ---------------------------------------------------------------------------
# per-host shard partition (deterministic twin of the hypothesis
# properties in test_properties.py, which skip when hypothesis is absent)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("hosts", [2, 4])
@pytest.mark.parametrize("split", ["train", "val"])
def test_host_shards_union_is_bitwise_global_batch(hosts, split):
    batch = 8
    full = SyntheticImageData(4, 8, batch, seed=11, split=split)
    per = batch // hosts
    for step in (0, 3, 17):
        want = full.batch_at(step)
        shards = [SyntheticImageData(4, 8, per, seed=11, split=split,
                                     sample_offset=h * per).batch_at(step)
                  for h in range(hosts)]
        np.testing.assert_array_equal(
            np.concatenate([s["images"] for s in shards]), want["images"])
        np.testing.assert_array_equal(
            np.concatenate([s["labels"] for s in shards]), want["labels"])


# ---------------------------------------------------------------------------
# legacy Prefetcher: raise-once port
# ---------------------------------------------------------------------------


def test_prefetcher_raises_once_then_stopiteration():
    src = CountingSource(fail_at=0)
    pf = Prefetcher(src)
    try:
        with pytest.raises(RuntimeError, match="boom at 0"):
            next(pf)
        with pytest.raises(StopIteration):
            next(pf)
        with pytest.raises(StopIteration):
            next(pf)
    finally:
        pf.close()


# ---------------------------------------------------------------------------
# SyntheticImageData allocation regression
# ---------------------------------------------------------------------------


def test_batch_at_peak_allocation_near_one_batch():
    """batch_at must fill one preallocated float32 buffer in place.

    The seed-era path generated float64 noise per sample and then
    ``astype``-copied the whole summed batch a second time — peak well
    above 2x the batch. The rewrite's peak is the output buffer plus
    one per-sample float32 noise tile (~1/batch extra)."""
    src = SyntheticImageData(4, 32, 16, seed=0)
    batch_bytes = 16 * 32 * 32 * 3 * 4
    src.batch_at(0)  # warm any lazy machinery outside the trace
    tracemalloc.start()
    src.batch_at(1)
    _, peak = tracemalloc.get_traced_memory()
    tracemalloc.stop()
    assert peak < 1.5 * batch_bytes, (
        f"batch_at peak {peak} vs batch {batch_bytes}: an extra "
        "batch-sized temporary is back")
