"""Hierarchical (two-level) collective schedules + persisted comm
plans (DESIGN.md §14).

Fast tests pin the Hierarchy factorization rules and the ``--comm-plan``
grammar / persistence / fallback behavior (a wrong plan silently
applied would reshape every collective in the compiled step, so the
fallback paths are regression-tested explicitly). The slow battery
proves the acceptance claims on real 8-virtual-device host meshes:

- the collective primitives (hierarchical psum / psum_scatter /
  all_gather) are BITWISE equal to their flat counterparts on exact
  data, for both (2, 4) and (4, 2) factorizations and both wire dtypes;
- the end-to-end parity matrix — {bucketed, overlap, zero,
  zero_overlap} x {momentum_sgd, lars} — is bitwise vs the flat
  schedule on bf16 wire (the round-once f32 pipeline reassociates
  nothing the flat f32-promoted psum didn't), and on f16 wire is
  bitwise split-invariant (hier on 2x4 == hier on 4x2) and close to
  flat (flat f16 folds sequentially; hier re-rounds once);
- an autotuner-persisted plan round-trips through ``--comm-plan auto``
  into a compiled step whose HLO schedule matches the plan.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.distributed.bucketing import make_hierarchy
from repro.distributed.comm_plan import (
    PLAN_VERSION,
    CommPlan,
    CommPlanWarning,
    StaleCommPlan,
    load_plan,
    plan_path,
    resolve_comm_plan,
    save_plan,
)

ENV8 = {
    **os.environ,
    "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
    "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src"),
}

REPO = os.path.join(os.path.dirname(__file__), "..")


def run_py(body: str, env=ENV8, timeout=900) -> str:
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert res.returncode == 0, f"STDERR:\n{res.stderr[-4000:]}"
    return res.stdout


# ---------------------------------------------------------------------------
# Hierarchy factorization rules
# ---------------------------------------------------------------------------


def test_make_hierarchy_splits_axes_row_major():
    h = make_hierarchy(("data", "model"), {"data": 2, "model": 4}, 1)
    assert h.outer == ("data",) and h.inner == ("model",)
    assert (h.outer_size, h.inner_size) == (2, 4)
    assert h.n_workers == 8


def test_make_hierarchy_multi_axis_split():
    sizes = {"a": 2, "b": 2, "c": 2}
    h = make_hierarchy(("a", "b", "c"), sizes, 2)
    assert h.outer == ("a", "b") and h.inner == ("c",)
    assert (h.outer_size, h.inner_size) == (4, 2)


@pytest.mark.parametrize("split", [0, 2, -1])
def test_make_hierarchy_split_out_of_range(split):
    with pytest.raises(ValueError, match="hier_split"):
        make_hierarchy(("data", "model"), {"data": 2, "model": 4}, split)


def test_make_hierarchy_rejects_size_one_stage():
    # a size-1 stage is a flat collective wearing a costume: callers
    # must fall back to the flat schedule instead
    with pytest.raises(ValueError, match="stages >= 2"):
        make_hierarchy(("data", "model"), {"data": 1, "model": 8}, 1)
    with pytest.raises(ValueError, match="stages >= 2"):
        make_hierarchy(("data", "model"), {"data": 8, "model": 1}, 1)


def test_hier_split_rejected_outside_shardmap():
    from repro.configs import OptimizerConfig, get_config, reduced_config
    from repro.launch.train import build_train_setup

    cfg = reduced_config(get_config("resnet50"))
    with pytest.raises(ValueError, match="shard"):
        build_train_setup(cfg, global_batch=8, seq_len=16,
                          opt_cfg=OptimizerConfig(), steps_per_epoch=5,
                          dp_mode="gspmd", hier_split=1,
                          compression="bf16+bucketed")


# ---------------------------------------------------------------------------
# --comm-plan grammar + persistence + fallback
# ---------------------------------------------------------------------------

_RUN = dict(arch="resnet50", mesh_shape=(2, 4),
            dp_axes=("data", "model"))


def _plan(**kw) -> CommPlan:
    base = dict(mesh_shape=(2, 4), dp_axes=("data", "model"),
                sync_mode="zero_overlap", wire="f16",
                bucket_bytes=4 << 20, hier_split=1, source="autotuner")
    base.update(kw)
    return CommPlan(**base)


def test_comm_plan_flat_resolves_to_none():
    assert resolve_comm_plan("flat", **_RUN) is None


def test_comm_plan_hier_grammar():
    plan = resolve_comm_plan("hier", **_RUN)
    assert plan.hier_split == 1
    # grammar form only reschedules: no wire-config override
    assert plan.bucket_bytes == 0
    assert resolve_comm_plan("hier:1", **_RUN).hier_split == 1


def test_comm_plan_hier_invalid_split_raises():
    # the user named an exact schedule: no silent fallback
    with pytest.raises(ValueError, match="hier_split"):
        resolve_comm_plan("hier:2", **_RUN)


def test_comm_plan_save_load_roundtrip(tmp_path):
    plan = _plan()
    path = save_plan(plan, str(tmp_path / "p.json"))
    assert load_plan(path) == plan
    assert resolve_comm_plan(path, **_RUN) == plan


def test_comm_plan_auto_finds_canonical_path(tmp_path):
    plan = _plan()
    save_plan(plan, plan_path("resnet50", (2, 4), str(tmp_path)))
    got = resolve_comm_plan("auto", out_dir=str(tmp_path), **_RUN)
    assert got == plan
    assert got.compression == "f16+bucketed"


def test_comm_plan_auto_missing_warns_and_falls_back(tmp_path):
    with pytest.warns(CommPlanWarning, match="no plan"):
        got = resolve_comm_plan("auto", out_dir=str(tmp_path), **_RUN)
    assert got is None


def test_comm_plan_stale_version_warns_and_falls_back(tmp_path):
    import dataclasses
    raw = dataclasses.asdict(_plan())
    raw["version"] = PLAN_VERSION + 999
    path = tmp_path / "stale.json"
    path.write_text(json.dumps(raw))
    with pytest.raises(StaleCommPlan, match="version"):
        load_plan(str(path))
    with pytest.warns(CommPlanWarning, match="version"):
        assert resolve_comm_plan(str(path), **_RUN) is None


def test_comm_plan_malformed_warns_and_falls_back(tmp_path):
    path = tmp_path / "bad.json"
    path.write_text(json.dumps({"version": PLAN_VERSION,
                                "sync_mode": "nope"}))
    with pytest.warns(CommPlanWarning, match="malformed"):
        assert resolve_comm_plan(str(path), **_RUN) is None


def test_comm_plan_mesh_mismatch_warns_and_falls_back(tmp_path):
    # tuned on 4x2, this run is 2x4: same device count, different
    # topology — the plan's split/bucket choices do not transfer
    path = save_plan(_plan(mesh_shape=(4, 2)), str(tmp_path / "p.json"))
    with pytest.warns(CommPlanWarning, match="tuned for mesh"):
        assert resolve_comm_plan(path, **_RUN) is None


def test_comm_plan_axes_mismatch_warns_and_falls_back(tmp_path):
    path = save_plan(_plan(dp_axes=("x", "y")), str(tmp_path / "p.json"))
    with pytest.warns(CommPlanWarning, match="DP axes"):
        assert resolve_comm_plan(path, **_RUN) is None


# ---------------------------------------------------------------------------
# collective primitives: bitwise vs flat on exact data (slow, 8 dev)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_hier_primitives_bitwise_vs_flat_8dev():
    """hierarchical_psum == flat psum and hierarchical double-scatter ==
    flat psum_scatter, BITWISE, on exact integer data — for both mesh
    factorizations and both wire dtypes; the double all-gather is pure
    data movement so it is bitwise on any data."""
    out = run_py("""
        import os
        os.environ['XLA_FLAGS'] = \\
            '--xla_force_host_platform_device_count=8'
        import functools
        import numpy as np
        import jax
        from jax.sharding import PartitionSpec as P
        from jax.experimental.shard_map import shard_map
        from repro.distributed.bucketing import (
            make_hierarchy, hierarchical_psum, hierarchical_psum_scatter,
            hierarchical_all_gather)
        L = 512
        rng = np.random.default_rng(0)
        for shape in [(2, 4), (4, 2)]:
            mesh = jax.make_mesh(shape, ('data', 'model'))
            dp = ('data', 'model')
            hier = make_hierarchy(dp, dict(zip(dp, shape)), 1)
            N = hier.n_workers
            for wire in ('bfloat16', 'float16'):
                exact = rng.integers(-256, 257, size=(N, L)).astype(wire)
                fuzzy = rng.standard_normal((N, L)).astype(wire)

                @functools.partial(
                    shard_map, mesh=mesh, in_specs=P(dp),
                    out_specs=P(dp), check_rep=False)
                def both(x):
                    b = x.reshape(-1)
                    flat = jax.lax.psum(b, dp)
                    h = hierarchical_psum(b, hier)
                    sc_flat = jax.lax.psum_scatter(
                        b, dp, scatter_dimension=0, tiled=True)
                    sc_h = hierarchical_psum_scatter(b, hier)
                    ag_flat = jax.lax.all_gather(
                        sc_h, dp, axis=0, tiled=True)
                    ag_h = hierarchical_all_gather(sc_h, hier)
                    return (flat[None], h[None], sc_flat[None],
                            sc_h[None], ag_flat[None], ag_h[None])

                for name, data in (('exact', exact), ('fuzzy', fuzzy)):
                    r = [np.asarray(v) for v in jax.jit(both)(data)]
                    flat, h, sc_flat, sc_h, ag_flat, ag_h = r
                    tag = f'{shape} {wire} {name}'
                    if name == 'exact':
                        np.testing.assert_array_equal(
                            flat.view(np.uint16), h.view(np.uint16),
                            err_msg=tag + ' psum')
                        np.testing.assert_array_equal(
                            sc_flat.view(np.uint16),
                            sc_h.view(np.uint16),
                            err_msg=tag + ' scatter')
                    # gather is pure data movement: bitwise always
                    np.testing.assert_array_equal(
                        ag_flat.view(np.uint16), ag_h.view(np.uint16),
                        err_msg=tag + ' gather')
                    np.testing.assert_allclose(
                        flat.astype(np.float32), h.astype(np.float32),
                        rtol=2e-2, atol=1e-2, err_msg=tag)
        print('PRIMS_OK')
    """)
    assert "PRIMS_OK" in out


# ---------------------------------------------------------------------------
# end-to-end parity matrix (slow, 8 dev)
# ---------------------------------------------------------------------------

_PARITY_HEADER = """
    OPT = '{opt}'
    WIRE = '{wire}'
"""

_PARITY_BODY = """
    import os
    os.environ['XLA_FLAGS'] = '--xla_force_host_platform_device_count=8'
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs import (OptimizerConfig, ParallelConfig,
                               TrainConfig, get_config, reduced_config)
    from repro.models import build_model, init_model_state
    from repro.optim import make_optimizer
    from repro.optim.stream import make_stream_optimizer, zero_padded_total
    from repro.training.step import (make_dp_shardmap_train_step,
                                     make_dp_overlap_train_step,
                                     replicate_model_state)

    cfg = reduced_config(get_config('resnet50'))
    N, BB = 8, 8192
    opt_cfg = OptimizerConfig(kind=OPT)
    model = build_model(cfg, compute_dtype=jnp.float32)
    rng = np.random.default_rng(0)
    batches = [
        {'images': jnp.asarray(rng.standard_normal((16, 32, 32, 3)),
                               jnp.float32),
         'labels': jnp.asarray(rng.integers(0, cfg.num_classes, 16))}
        for _ in range(2)]

    def run(shape, overlap, zero, hier_split):
        mesh = jax.make_mesh(shape, ('data', 'model'))
        DP = ('data', 'model')
        bshard = NamedSharding(mesh, P(DP))
        parallel = ParallelConfig(
            dp_axes=DP, tp_axis=None, zero_1=False,
            compression=WIRE + '+bucketed', bucket_bytes=BB,
            zero_dp=zero, overlap_comm=overlap, hier_split=hier_split)
        tcfg = TrainConfig(optimizer=opt_cfg, parallel=parallel)
        params, _ = model.init_params(jax.random.PRNGKey(0))
        if zero or OPT == 'lars':
            opt = make_stream_optimizer(opt_cfg, 5, 16)
            ostate = opt.init(zero_padded_total(
                params, WIRE + '+bucketed', BB, N))
        else:
            opt = make_optimizer(opt_cfg, 5, 16)
            ostate = opt.init(params)
        mstate = replicate_model_state(init_model_state(model), N)
        state = {'params': params, 'opt': ostate, 'model_state': mstate}
        builder = (make_dp_overlap_train_step if overlap
                   else make_dp_shardmap_train_step)
        step = jax.jit(builder(model, opt, tcfg, mesh, DP))
        for b in batches:
            state, metrics = step(state, {k: jax.device_put(v, bshard)
                                          for k, v in b.items()})
        return state, metrics

    def leaves(s):
        return [np.asarray(x) for x in jax.tree.leaves(s['params'])]

    for overlap, zero, name in ((False, False, 'bucketed'),
                                (True, False, 'overlap'),
                                (False, True, 'zero'),
                                (True, True, 'zero_overlap')):
        s_flat, m_flat = run((2, 4), overlap, zero, None)
        s_h24, m_h24 = run((2, 4), overlap, zero, 1)
        s_h42, m_h42 = run((4, 2), overlap, zero, 1)
        # split-invariance: 2x4 and 4x2 round identically (the shard
        # boundaries differ, the round-once arithmetic does not)
        for a, b in zip(leaves(s_h24), leaves(s_h42)):
            np.testing.assert_array_equal(a, b,
                                          err_msg=name + ':split-inv')
        if WIRE == 'bf16':
            # bf16 psum promotes to f32 on this backend: the
            # hierarchical round-once pipeline reassociates nothing, so
            # parity vs flat is BITWISE — the acceptance criterion
            assert float(m_flat['loss']) == float(m_h24['loss']), name
            for a, b in zip(leaves(s_flat), leaves(s_h24)):
                np.testing.assert_array_equal(a, b,
                                              err_msg=name + ':flat')
        else:
            # f16 flat folds sequentially in f16; hier rounds once from
            # f32 — numerically close, not bitwise (measured worst
            # rel diff ~4.5e-2 after 2 steps on this config)
            for a, b in zip(leaves(s_flat), leaves(s_h24)):
                np.testing.assert_allclose(
                    a, b, rtol=1.5e-1, atol=1e-4,
                    err_msg=name + ':flat')
    print('PARITY_OK')
"""


@pytest.mark.slow
@pytest.mark.parametrize("opt", ["momentum_sgd", "lars"])
@pytest.mark.parametrize("wire", ["bf16", "f16"])
def test_hier_parity_matrix_8dev(opt, wire):
    """Acceptance: the hierarchical schedule bitwise-matches the flat
    schedule in all four bucketed sync modes (bf16 wire), and is
    bitwise split-invariant ((2,4) vs (4,2)) on both wires, after
    multi-step training on the 8-virtual-device mesh."""
    body = (textwrap.dedent(_PARITY_HEADER).format(opt=opt, wire=wire)
            + textwrap.dedent(_PARITY_BODY))
    out = run_py(body)
    assert "PARITY_OK" in out


# ---------------------------------------------------------------------------
# autotuner plan -> --comm-plan auto -> compiled HLO (slow, 8 dev)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_comm_plan_autotune_roundtrip_hlo_8dev(tmp_path):
    """The full persistence loop: the comm autotuner sweep writes a
    plan; ``--comm-plan auto`` resolution loads it; a train step built
    from the plan's configuration lowers to HLO whose gradient-sync
    schedule matches what the plan promises."""
    plan_file = str(tmp_path / "comm_plan_resnet50_2x4.json")
    out_file = str(tmp_path / "BENCH_comm.json")
    res = subprocess.run(
        [sys.executable, os.path.join(REPO, "benchmarks",
                                      "comm_bench.py"),
         "--mesh", "2x4", "--reduced", "--quick", "--sweep",
         "--plan-out", plan_file, "--out", out_file],
        env=ENV8, capture_output=True, text=True, timeout=900)
    assert res.returncode == 0, f"STDERR:\n{res.stderr[-4000:]}"
    plan = load_plan(plan_file)
    assert plan.source == "autotuner"
    assert tuple(plan.mesh_shape) == (2, 4)
    assert plan.bucket_bytes > 0
    # the sweep artifact embeds the winning plan it persisted
    bench = json.loads(open(out_file).read())
    assert bench["plan"]["sync_mode"] == plan.sync_mode
    assert bench["plan"]["hier_split"] == plan.hier_split

    out = run_py(f"""
        import os
        os.environ['XLA_FLAGS'] = \\
            '--xla_force_host_platform_device_count=8'
        import jax, jax.numpy as jnp
        from repro.configs import (OptimizerConfig, get_config,
                                   reduced_config)
        from repro.distributed.comm_plan import resolve_comm_plan
        from repro.launch.hlo_analysis import analyze_hlo, comm_report
        from repro.launch.train import build_train_setup

        plan = resolve_comm_plan(
            'auto', arch='resnet50', mesh_shape=(2, 4),
            dp_axes=('data', 'model'), out_dir={str(tmp_path)!r})
        assert plan is not None, 'auto must find the tuned plan'
        # apply the plan the way launch/train.py main() does
        mesh = jax.make_mesh((2, 4), ('data', 'model'))
        dp_axes = (plan.dp_axes if plan.hier_split is not None
                   else ('data',))
        model, state, step, data, put, _ = build_train_setup(
            reduced_config(get_config('resnet50')), global_batch=8,
            seq_len=16, opt_cfg=OptimizerConfig(), steps_per_epoch=5,
            mesh=mesh, dp_mode='shardmap', seed=0,
            compression=plan.compression,
            bucket_bytes=plan.bucket_bytes,
            overlap_comm=plan.sync_mode in ('overlap', 'zero_overlap'),
            zero_dp=plan.sync_mode in ('zero', 'zero_overlap'),
            dp_axes=dp_axes, hier_split=plan.hier_split)
        batch = put({{k: jnp.asarray(v)
                     for k, v in data.batch_at(0).items()}})
        txt = step.lower(state, batch).compile().as_text()
        rep = comm_report(analyze_hlo(txt, 8), hlo_text=txt)
        if plan.sync_mode in ('zero', 'zero_overlap'):
            want = 'reduce_scatter+all_gather'
        elif plan.hier_split is not None:
            want = 'hierarchical'
        else:
            want = 'all_reduce'
        assert rep['gradient_sync'] == want, (
            rep['gradient_sync'], want, plan.describe())
        print('ROUNDTRIP_OK', plan.describe(), rep['gradient_sync'])
    """)
    assert "ROUNDTRIP_OK" in out
