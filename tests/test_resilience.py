"""Fault-tolerant training (DESIGN.md §13).

Fast single-process tests cover the chaos spec grammar, the event log,
the recovery state machine, checkpoint integrity (atomic replace,
crc32, corrupted-newest fallback), the sentinel's no-fault bitwise
parity and NaN/spike skip gates on the GSPMD path, and the Trainer's
skip / rollback / data-retry / abort flows driven by injected chaos.
The six-sync-mode parity matrix runs in subprocesses on a virtual
8-device host mesh (marked ``slow``).
"""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import checkpointer as ck
from repro.checkpoint.checkpointer import (
    ARRAYS,
    AsyncCheckpointer,
    CheckpointCorruptError,
    MANIFEST,
    gc_stale_tmpdirs,
    list_checkpoints,
    restore,
    save,
)
from repro.configs import OptimizerConfig, get_config, reduced_config
from repro.launch.train import build_train_setup
from repro.resilience import (
    Action,
    ChaosError,
    EventLog,
    RecoveryManager,
    ResilienceConfig,
    parse_chaos,
    sentinel_controls,
)
from repro.training import Trainer, TrainerConfig

ENV8 = {
    **os.environ,
    "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
    "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src"),
}


def run_py(body: str, env=ENV8, timeout=600) -> str:
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert res.returncode == 0, f"STDERR:\n{res.stderr[-4000:]}"
    return res.stdout


# ---------------------------------------------------------------------------
# chaos spec grammar
# ---------------------------------------------------------------------------


def test_chaos_grammar_parses_kinds_ranges_and_seed():
    eng = parse_chaos("nan_grad@3,data_stall@5-7:0.25,seed=9,straggler@2")
    assert eng.seed == 9
    kinds = [(t.kind, t.step, t.arg) for t in eng.triggers]
    assert ("nan_grad", 3, None) in kinds
    assert ("data_stall", 5, 0.25) in kinds and ("data_stall", 7, 0.25) \
        in kinds
    assert ("straggler", 2, 0.5) in kinds  # default arg


@pytest.mark.parametrize("spec", [
    "bogus@3",            # unknown kind
    "nan_grad",           # missing @step
    "nan_grad@7-3",       # inverted range
    "nan_grad@x",         # non-integer step
])
def test_chaos_grammar_rejects_malformed(spec):
    with pytest.raises(ValueError):
        parse_chaos(spec)


def test_chaos_triggers_fire_once_and_deterministically():
    batch = {"images": np.zeros((2, 4, 4, 3), np.float32),
             "labels": np.zeros((2,), np.int32)}
    poisoned = []
    for _ in range(2):
        eng = parse_chaos("nan_grad@1", seed=5)
        out = eng.inject_batch(1, dict(batch))
        poisoned.append(int(np.flatnonzero(np.isnan(out["images"]))[0]))
        # one-shot: a post-rollback replay of the same step is clean
        again = eng.inject_batch(1, dict(batch))
        assert not np.isnan(again["images"]).any()
    assert poisoned[0] == poisoned[1]  # seed-keyed position
    assert not np.isnan(batch["images"]).any()  # source never mutated


def test_chaos_data_crash_raises_chaos_error():
    eng = parse_chaos("data_crash@2")
    src = eng.wrap_source(_ArraySource())
    _ = src.batch_at(1)
    with pytest.raises(ChaosError):
        src.batch_at(2)
    _ = src.batch_at(2)  # one-shot: retry succeeds


class _ArraySource:
    def batch_at(self, step):
        return {"images": np.full((2, 2), float(step), np.float32),
                "labels": np.zeros((2,), np.int32)}


# ---------------------------------------------------------------------------
# event log
# ---------------------------------------------------------------------------


def test_event_log_jsonl_roundtrip(tmp_path):
    path = str(tmp_path / "events.jsonl")
    with EventLog(path) as log:
        log.emit("rollback", to_step=4, wasted=np.int64(3),
                 loss=jnp.float32(1.5))
        log.emit("abort", step=9)
    lines = [json.loads(x) for x in open(path)]
    assert [r["kind"] for r in lines] == ["rollback", "abort"]
    assert lines[0]["wasted"] == 3  # numpy/jax scalars serialized plain
    assert lines[0]["loss"] == 1.5
    assert log.of_kind("abort")[0]["step"] == 9
    assert [r["seq"] for r in lines] == [0, 1]


# ---------------------------------------------------------------------------
# recovery state machine (host-side, no training)
# ---------------------------------------------------------------------------


def _mgr(**kw):
    return RecoveryManager(ResilienceConfig(**kw), EventLog())


def test_recovery_skip_then_rollback_then_abort():
    mgr = _mgr(max_consecutive_bad=2, max_rollbacks=1)
    bad = {"bad_step": 1.0, "nonfinite_step": 1.0}
    assert mgr.observe(5, bad) is Action.SKIPPED
    assert mgr.observe(6, bad) is Action.ROLLBACK
    mgr.on_rollback(from_step=6, to_step=4)
    assert mgr.observe(4, {"bad_step": 0.0}) is Action.CONTINUE
    assert mgr.consecutive_bad == 0
    assert mgr.observe(5, bad) is Action.SKIPPED
    assert mgr.observe(6, bad) is Action.ABORT  # budget of 1 spent
    assert mgr.events.kinds().count("step_skipped") == 4
    assert "abort" in mgr.events.kinds()


def test_recovery_spike_threshold_arms_after_warmup():
    mgr = _mgr(spike_factor=3.0, warmup_steps=3, ema_decay=0.5)
    assert mgr.spike_threshold() == float("inf")
    for s in range(3):
        mgr.observe(s, {"bad_step": 0.0, "grad_norm": 2.0})
    assert mgr.spike_threshold() == pytest.approx(6.0)  # 3.0 * EMA(2.0)
    # a skipped step must NOT poison the EMA
    mgr.observe(3, {"bad_step": 1.0, "grad_norm": float("nan")})
    assert mgr.spike_threshold() == pytest.approx(6.0)


def test_recovery_lr_backoff_window():
    mgr = _mgr(lr_backoff=0.5, backoff_steps=4)
    assert mgr.lr_scale(10) == 1.0
    mgr.on_rollback(from_step=12, to_step=10)
    assert mgr.lr_scale(10) == 0.5
    assert mgr.lr_scale(13) == 0.5
    assert mgr.lr_scale(14) == 1.0  # window expired
    ctl = mgr.controls(10)
    assert float(ctl["lr_scale"]) == 0.5
    assert float(ctl["spike_threshold"]) == float("inf")


# ---------------------------------------------------------------------------
# checkpoint integrity + atomic replace
# ---------------------------------------------------------------------------


def _tree(v=0.0):
    return {"params": {"w": np.arange(6, dtype=np.float32) + v,
                       "b": np.ones((2,), np.float32) * v},
            "opt": {"step": np.int32(int(v))}}


def test_list_checkpoints_requires_payload(tmp_path):
    d = str(tmp_path)
    save(d, 1, _tree(1.0))
    os.makedirs(os.path.join(d, "step_0000000002"))
    with open(os.path.join(d, "step_0000000002", MANIFEST), "w") as f:
        json.dump({"step": 2, "keys": []}, f)  # manifest, no arrays.npz
    assert list_checkpoints(d) == [1]


def test_restore_falls_back_on_truncated_newest(tmp_path):
    d = str(tmp_path)
    save(d, 1, _tree(1.0))
    save(d, 2, _tree(2.0))
    payload = os.path.join(d, "step_0000000002", ARRAYS)
    with open(payload, "r+b") as f:
        f.truncate(os.path.getsize(payload) // 2)
    seen = []
    arrays, manifest = restore(d, on_corrupt=lambda s, e: seen.append(s))
    assert manifest["step"] == 1
    assert seen == [2]
    np.testing.assert_array_equal(arrays["['params']['w']"],
                                  _tree(1.0)["params"]["w"])


def test_restore_falls_back_on_bitflipped_newest(tmp_path):
    # regression: a single flipped byte mid-file (silent media
    # corruption) must be caught, not loaded as garbage weights
    d = str(tmp_path)
    save(d, 1, _tree(1.0))
    save(d, 2, _tree(2.0))
    payload = os.path.join(d, "step_0000000002", ARRAYS)
    # flip a byte inside the stored array payload itself (a flip in zip
    # header slack would be harmless); npz members are ZIP_STORED, so
    # the raw array bytes appear verbatim in the file
    needle = _tree(2.0)["params"]["w"].tobytes()
    blob = open(payload, "rb").read()
    pos = blob.find(needle)
    assert pos > 0, "stored array bytes not found in npz"
    with open(payload, "r+b") as f:
        f.seek(pos + 2)
        byte = f.read(1)
        f.seek(pos + 2)
        f.write(bytes([byte[0] ^ 0xFF]))
    _, manifest = restore(d)
    assert manifest["step"] == 1


def test_restore_explicit_step_still_raises_on_corrupt(tmp_path):
    d = str(tmp_path)
    save(d, 1, _tree(1.0))
    save(d, 2, _tree(2.0))
    payload = os.path.join(d, "step_0000000002", ARRAYS)
    with open(payload, "r+b") as f:
        f.truncate(10)
    with pytest.raises(CheckpointCorruptError):
        restore(d, step=2)
    _, manifest = restore(d, step=1)  # older one untouched
    assert manifest["step"] == 1


def test_restore_crc_mismatch_detected(tmp_path):
    # a VALID zip whose array bytes changed after the manifest was
    # written: only the crc32 check can catch this
    d = str(tmp_path)
    save(d, 1, _tree(1.0))
    save(d, 2, _tree(2.0))
    payload = os.path.join(d, "step_0000000002", ARRAYS)
    with np.load(payload) as z:
        arrays = {k: z[k] for k in z.files}
    key = "['params']['w']"
    arrays[key] = arrays[key] + 1.0
    np.savez(payload, **arrays)
    _, manifest = restore(d)
    assert manifest["step"] == 1
    with pytest.raises(CheckpointCorruptError, match="crc32"):
        restore(d, step=2)


def test_restore_raises_when_every_candidate_corrupt(tmp_path):
    d = str(tmp_path)
    save(d, 1, _tree(1.0))
    with open(os.path.join(d, "step_0000000001", ARRAYS), "r+b") as f:
        f.truncate(4)
    with pytest.raises(CheckpointCorruptError, match="every candidate"):
        restore(d)


def test_atomic_resave_preserves_old_when_rename_fails(tmp_path,
                                                       monkeypatch):
    # crash in the replace window: the old data must come back, not be
    # rmtree'd first (the pre-fix save deleted old THEN renamed)
    d = str(tmp_path)
    save(d, 1, _tree(1.0))
    real_rename = os.rename

    def failing_rename(src, dst):
        if os.path.basename(src).startswith(".tmp_ckpt_"):
            raise OSError("simulated crash at rename")
        return real_rename(src, dst)

    monkeypatch.setattr(ck.os, "rename", failing_rename)
    with pytest.raises(OSError, match="simulated"):
        save(d, 1, _tree(99.0))
    monkeypatch.undo()
    arrays, manifest = restore(d)
    assert manifest["step"] == 1
    np.testing.assert_array_equal(arrays["['params']['w']"],
                                  _tree(1.0)["params"]["w"])
    assert gc_stale_tmpdirs(d) == 0  # failed save left no litter


def test_save_failure_before_replace_keeps_old(tmp_path, monkeypatch):
    d = str(tmp_path)
    save(d, 1, _tree(1.0))

    def failing_savez(*a, **kw):
        raise OSError("disk full")

    monkeypatch.setattr(ck.np, "savez", failing_savez)
    with pytest.raises(OSError, match="disk full"):
        save(d, 1, _tree(99.0))
    monkeypatch.undo()
    arrays, _ = restore(d)
    np.testing.assert_array_equal(arrays["['params']['w']"],
                                  _tree(1.0)["params"]["w"])


def test_async_checkpointer_gcs_stale_tmpdirs(tmp_path):
    d = str(tmp_path)
    os.makedirs(os.path.join(d, ".tmp_ckpt_dead"))
    os.makedirs(os.path.join(d, ".old_ckpt_dead"))
    save(d, 1, _tree(1.0))
    AsyncCheckpointer(d)
    names = set(os.listdir(d))
    assert ".tmp_ckpt_dead" not in names
    assert ".old_ckpt_dead" not in names
    assert "step_0000000001" in names


def test_async_save_snapshots_host_arrays_exactly_once(tmp_path,
                                                       monkeypatch):
    calls = []
    real_flatten = ck._flatten

    def counting_flatten(tree):
        calls.append(1)
        return real_flatten(tree)

    monkeypatch.setattr(ck, "_flatten", counting_flatten)
    ac = AsyncCheckpointer(str(tmp_path))
    ac.save(3, _tree(3.0), block=True)
    assert len(calls) == 1, "async save must not re-copy on the worker"
    _, manifest = restore(str(tmp_path))
    assert manifest["step"] == 3


def test_manifest_carries_crc32_per_array(tmp_path):
    d = str(tmp_path)
    path = save(d, 1, _tree(1.0))
    manifest = json.load(open(os.path.join(path, MANIFEST)))
    assert set(manifest["crc32"]) == set(manifest["keys"])
    for v in manifest["crc32"].values():
        assert isinstance(v, int)


# ---------------------------------------------------------------------------
# sentinel + Trainer integration (GSPMD fast path)
# ---------------------------------------------------------------------------


def _build(sentinel: bool):
    cfg = reduced_config(get_config("resnet50"))
    opt_cfg = OptimizerConfig(kind="momentum_sgd", schedule="constant")
    return build_train_setup(cfg, global_batch=8, seq_len=16,
                             opt_cfg=opt_cfg, steps_per_epoch=4, seed=0,
                             sentinel=sentinel)


@pytest.fixture(scope="module")
def sent():
    """Sentinel-enabled GSPMD setup; host snapshot of the init so every
    test re-materializes fresh state (the jitted step donates)."""
    model, state, train_step, data, put_batch, _ = _build(sentinel=True)
    host0 = jax.tree.map(np.array, state)
    return {"train_step": train_step, "host0": host0, "data": data}


def _fresh(host0):
    return jax.tree.map(jnp.asarray, host0)


def _assert_trees_bitwise_equal(a, b):
    fa = jax.tree_util.tree_flatten_with_path(a)[0]
    fb = jax.tree.leaves(b)
    assert len(fa) == len(fb)
    for (path, la), lb in zip(fa, fb):
        assert np.asarray(la).tobytes() == np.asarray(lb).tobytes(), \
            jax.tree_util.keystr(path)


def test_sentinel_disabled_vs_enabled_bitwise_parity(sent):
    """The no-fault contract: with default controls the wrapped step's
    select gates pass every leaf through bitwise-unchanged."""
    _, state, plain_step, data, _, _ = _build(sentinel=False)
    controls = sentinel_controls()
    wrapped = _fresh(sent["host0"])
    for s in range(3):
        batch = data.batch_at(s)
        state, _ = plain_step(state, batch)
        wrapped, metrics = sent["train_step"](wrapped, batch, controls)
        assert float(metrics["bad_step"]) == 0.0
    _assert_trees_bitwise_equal(state, wrapped)


def test_nan_batch_skipped_state_bitwise_unchanged(sent):
    batch = sent["data"].batch_at(0)
    batch = dict(batch)
    poisoned = np.array(batch["images"])
    poisoned.reshape(-1)[7] = np.nan
    batch["images"] = poisoned
    state, metrics = sent["train_step"](_fresh(sent["host0"]), batch,
                                        sentinel_controls())
    assert float(metrics["bad_step"]) == 1.0
    assert float(metrics["nonfinite_step"]) == 1.0
    # params, optimizer state (incl. step counter) and BN statistics all
    # carried over untouched — as if the step never ran
    _assert_trees_bitwise_equal(state, _fresh(sent["host0"]))


def test_spike_gate_skips_but_flags_finite(sent):
    batch = sent["data"].batch_at(0)
    state, metrics = sent["train_step"](
        _fresh(sent["host0"]), batch,
        sentinel_controls(spike_threshold=1e-12))
    assert float(metrics["grad_spike"]) == 1.0
    assert float(metrics["nonfinite_step"]) == 0.0
    assert float(metrics["bad_step"]) == 1.0
    _assert_trees_bitwise_equal(state, _fresh(sent["host0"]))


def _run_trainer(sent, tmp_path, chaos_spec=None, resilience=None,
                 epochs=2, ckpt_every=2, **res_kw):
    tcfg = TrainerConfig(epochs=epochs, steps_per_epoch=4,
                         eval_every_epochs=0, val_batches=0,
                         checkpoint_every=ckpt_every,
                         checkpoint_dir=str(tmp_path) if ckpt_every
                         else None, log_every=1)
    if resilience is None:
        resilience = ResilienceConfig(**res_kw)
    chaos = parse_chaos(chaos_spec) if chaos_spec else None
    return Trainer(sent["train_step"], _fresh(sent["host0"]),
                   sent["data"], tcfg, resilience=resilience,
                   chaos=chaos).run()


def test_trainer_skips_nan_step_and_completes(sent, tmp_path):
    res = _run_trainer(sent, tmp_path, chaos_spec="nan_grad@3")
    kinds = [r["kind"] for r in res.events]
    assert kinds.count("step_skipped") == 1
    assert "rollback" not in kinds
    skipped = [r for r in res.events if r["kind"] == "step_skipped"][0]
    assert skipped["step"] == 3 and skipped["nonfinite"]
    assert res.history[-1]["step"] == 7  # ran to completion


def test_trainer_rollback_restores_last_good(sent, tmp_path):
    res = _run_trainer(sent, tmp_path, chaos_spec="nan_grad@4-6",
                       max_consecutive_bad=3)
    rb = [r for r in res.events if r["kind"] == "rollback"]
    assert len(rb) == 1
    # checkpoints at 2 and 4; bad streak 4-6 -> restore the step-4 save
    # (mid-streak saves are suppressed, so the target did not advance)
    assert rb[0] == {**rb[0], "from_step": 6, "to_step": 4,
                     "wasted_steps": 2}
    assert res.history[-1]["step"] == 7
    losses = [r["loss"] for r in res.history if r["step"] == 7]
    assert np.isfinite(losses[-1])


def test_trainer_rollback_falls_back_past_corrupt_newest(sent, tmp_path):
    res = _run_trainer(sent, tmp_path, epochs=3,
                       chaos_spec="ckpt_truncate@7,nan_grad@8-9",
                       max_consecutive_bad=2)
    kinds = [r["kind"] for r in res.events]
    assert "corrupt_checkpoint_skipped" in kinds
    rb = [r for r in res.events if r["kind"] == "rollback"][0]
    assert rb["to_step"] == 6  # newest (8) was truncated -> next-newest
    assert res.history[-1]["step"] == 11


def test_trainer_abort_after_rollback_budget(sent, tmp_path):
    with pytest.raises(RuntimeError, match="aborted"):
        _run_trainer(sent, tmp_path, chaos_spec="nan_grad@3-5",
                     max_consecutive_bad=3, max_rollbacks=0)


def test_trainer_rollback_without_ckpt_dir_raises(sent, tmp_path):
    with pytest.raises(RuntimeError, match="checkpoint_dir"):
        _run_trainer(sent, tmp_path, chaos_spec="nan_grad@2-4",
                     ckpt_every=0, max_consecutive_bad=3)


def test_trainer_data_crash_recovers_with_resilience(sent, tmp_path):
    res = _run_trainer(sent, tmp_path, chaos_spec="data_crash@5")
    restarts = [r for r in res.events if r["kind"] == "data_restart"]
    assert len(restarts) == 1 and restarts[0]["step"] == 5
    assert res.history[-1]["step"] == 7


def test_prefetcher_crash_propagates_without_resilience(sent, tmp_path):
    """The pre-existing error contract is unchanged when resilience is
    off: a dead input worker kills the run."""
    tcfg = TrainerConfig(epochs=1, steps_per_epoch=8,
                         eval_every_epochs=0, val_batches=0,
                         checkpoint_every=0, log_every=1)
    chaos = parse_chaos("data_crash@3")
    # no resilience: 2-arg step required, so wrap data only
    _, state, plain_step, data, _, _ = _build(sentinel=False)
    with pytest.raises(ChaosError):
        Trainer(plain_step, state, chaos.wrap_source(data), tcfg).run()


def test_step_misalignment_raises_runtime_error(sent, monkeypatch):
    import repro.training.loop as loop_mod

    class _Skewed:
        def __init__(self, source, start_step=0, depth=2, transform=None,
                     num_workers=1, put=None, device_ahead=1):
            self._step = start_step
            self.last_wait_s = 0.0

        def __next__(self):
            return self._step + 1, None  # off by one

        def close(self):
            pass

    monkeypatch.setattr(loop_mod, "DataPipeline", _Skewed)
    tcfg = TrainerConfig(epochs=1, steps_per_epoch=4,
                         eval_every_epochs=0, val_batches=0,
                         checkpoint_every=0, log_every=1)
    with pytest.raises(RuntimeError, match="misalignment"):
        Trainer(sent["train_step"], _fresh(sent["host0"]), sent["data"],
                tcfg, resilience=ResilienceConfig()).run()


def test_event_log_written_to_disk(sent, tmp_path):
    path = str(tmp_path / "events.jsonl")
    res = _run_trainer(
        sent, tmp_path / "ckpt", chaos_spec="nan_grad@3",
        resilience=ResilienceConfig(event_log=path))
    lines = [json.loads(x) for x in open(path)]
    assert [r["kind"] for r in lines] == [r["kind"] for r in res.events]
    assert any(r["kind"] == "step_skipped" for r in lines)


# ---------------------------------------------------------------------------
# six-sync-mode no-fault parity matrix (subprocess, virtual 8-dev host)
# ---------------------------------------------------------------------------

MODE_KW = {
    "gspmd": "dict(dp_mode='gspmd')",
    "perleaf": "dict(dp_mode='shardmap', compression='none')",
    "bucketed": "dict(dp_mode='shardmap', compression='bf16+bucketed')",
    "overlap": ("dict(dp_mode='shardmap', compression='bf16+bucketed', "
                "overlap_comm=True)"),
    "zero": ("dict(dp_mode='shardmap', compression='bf16+bucketed', "
             "zero_dp=True)"),
    "zero_overlap": ("dict(dp_mode='shardmap', "
                     "compression='bf16+bucketed', zero_dp=True, "
                     "overlap_comm=True)"),
}

_PARITY_BODY = """
import jax, numpy as np
from repro.configs import OptimizerConfig, get_config, reduced_config
from repro.launch.train import build_train_setup
from repro.resilience.sentinel import sentinel_controls

cfg = reduced_config(get_config("resnet50"))
opt = OptimizerConfig(kind="momentum_sgd", schedule="constant")
mesh = jax.make_mesh((8, 1), ("data", "model"))
finals = []
for sentinel in (False, True):
    _, state, step, data, put_batch, _ = build_train_setup(
        cfg, global_batch=16, seq_len=16, opt_cfg=opt,
        steps_per_epoch=4, mesh=mesh, seed=0, sentinel=sentinel,
        **{kw})
    controls = sentinel_controls()
    for s in range(2):
        batch = put_batch(data.batch_at(s))
        if sentinel:
            state, m = step(state, batch, controls)
            assert float(m["bad_step"]) == 0.0
        else:
            state, m = step(state, batch)
    finals.append(jax.tree.map(np.array, state))
plain, sent = finals
fp = jax.tree_util.tree_flatten_with_path(plain)[0]
fs = jax.tree.leaves(sent)
assert len(fp) == len(fs)
for (path, lp), ls in zip(fp, fs):
    assert np.asarray(lp).tobytes() == np.asarray(ls).tobytes(), \\
        ("{mode}", jax.tree_util.keystr(path))
print("PARITY_OK {mode}")
"""


@pytest.mark.slow
@pytest.mark.parametrize("mode", list(MODE_KW))
def test_sentinel_parity_all_sync_modes(mode):
    """Acceptance: with no fault injected, the sentinel-enabled step is
    bitwise-equal to the current step in every sync mode."""
    out = run_py(_PARITY_BODY.format(kw=MODE_KW[mode], mode=mode))
    assert f"PARITY_OK {mode}" in out
