"""MoE layer semantics: routing, capacity, load-balance aux, sharding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import layers
from repro.models.common import unbox


def _moe_setup(key, e=4, k=2, d=16, ff=32, tokens=8):
    import dataclasses
    cfg = dataclasses.replace(
        reduced_config(get_config("mixtral-8x7b")),
        d_model=d, d_ff=ff, n_experts=e, experts_per_token=k)
    p_boxed = layers.moe_init(key, cfg)
    p, _ = unbox(p_boxed)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, tokens, d))
    return cfg, p, x


def test_topk_selects_highest_prob_experts(key):
    cfg, p, x = _moe_setup(key)
    y, aux = layers.moe_apply(p, x, cfg, capacity_factor=100.0)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    assert float(aux) > 0


def test_capacity_drops_tokens(key):
    """With capacity_factor so low that only `cap` slots exist, outputs
    for dropped tokens are exactly zero (GShard dropping semantics)."""
    cfg, p, x = _moe_setup(key, e=4, k=1, tokens=64)
    y_full, _ = layers.moe_apply(p, x, cfg, capacity_factor=100.0)
    y_tight, _ = layers.moe_apply(p, x, cfg, capacity_factor=0.1)
    # some token rows must be zeroed by the tight capacity
    norms = np.linalg.norm(np.asarray(y_tight), axis=-1).ravel()
    assert (norms < 1e-7).any()
    # and the surviving rows agree with the uncapped computation
    alive = norms > 1e-7
    nf = np.linalg.norm(np.asarray(y_full), axis=-1).ravel()
    assert alive.sum() > 0 and (nf[alive] > 0).all()


def test_top1_equals_manual_expert_eval(key):
    """top-1 routing with huge capacity == dense per-token expert eval."""
    cfg, p, x = _moe_setup(key, e=4, k=1, tokens=4)
    y, _ = layers.moe_apply(p, x, cfg, capacity_factor=100.0)
    logits = jnp.einsum("bsd,de->bse", x, p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    idx = jnp.argmax(probs, axis=-1)
    gate = jnp.take_along_axis(probs, idx[..., None], -1)[..., 0]
    manual = []
    for b in range(2):
        rows = []
        for t in range(4):
            e = int(idx[b, t])
            h = jax.nn.silu(x[b, t] @ p["w_gate"][e]) * (x[b, t] @ p["w_up"][e])
            rows.append(gate[b, t] * (h @ p["w_down"][e]))
        manual.append(jnp.stack(rows))
    manual = jnp.stack(manual)
    np.testing.assert_allclose(np.asarray(y), np.asarray(manual),
                               rtol=2e-3, atol=2e-3)


def test_aux_loss_uniform_router_is_one(key):
    """Switch aux loss normalizes to ~1.0 for a perfectly uniform router."""
    cfg, p, x = _moe_setup(key, e=4, k=1, tokens=256)
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])  # uniform probs
    _, aux = layers.moe_apply(p, x, cfg, capacity_factor=100.0)
    # density_proxy = 1/e; density: argmax of uniform = expert 0 always
    # => aux = e*e * mean(density * 1/e) = e * mean(density) = e * (1/e) = 1
    np.testing.assert_allclose(float(aux), 1.0, rtol=1e-2)


def test_shared_expert_added(key):
    import dataclasses
    cfg = dataclasses.replace(
        reduced_config(get_config("llama4-maverick-400b-a17b")),
        d_model=16, d_ff=32, n_experts=4, experts_per_token=1)
    p, _ = unbox(layers.moe_init(jax.random.PRNGKey(0), cfg))
    assert "shared" in p
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y, _ = layers.moe_apply(p, x, cfg)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())
