"""Fused Pallas augment+normalize input kernel (DESIGN.md §15).

Fast tests pin the kernel against the pure-jnp reference across
{f32, bf16} x {train, eval}, the determinism of the parameter stream
(eager == traced, host AugmentedSource == device ref path), and the
fused-input validation errors. The 3-step end-to-end parity — fused
on-device input vs host-path augmentation, bitwise, in bucketed and
zero sync modes on an 8-device virtual mesh — runs in subprocesses
(marked ``slow``).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import AugmentedSource
from repro.data.synthetic import SyntheticImageData
from repro.kernels import ops, ref

ENV8 = {
    **os.environ,
    "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
    "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src"),
}

MEAN = (0.1, -0.2, 0.3)
STD = (0.9, 1.1, 1.3)


def _batch(b=8, s=16, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), (b, s, s, 3),
                          jnp.float32)
    return x


@pytest.mark.parametrize("train", [True, False])
@pytest.mark.parametrize("out_dtype", [jnp.float32, jnp.bfloat16])
def test_fused_matches_ref(train, out_dtype):
    x = _batch()
    params = ops.input_augment_params(0, 5, x.shape[0])
    mean = jnp.asarray(MEAN, jnp.float32)
    std = jnp.asarray(STD, jnp.float32)
    want = ref.input_forward(x, params, mean, std, train=train,
                             out_dtype=out_dtype)
    if train:
        got = ops.fused_input_train(x, params, mean, 1.0 / std,
                                    out_dtype=out_dtype)
    else:
        got = ops.fused_input_eval(x, mean, 1.0 / std,
                                   out_dtype=out_dtype)
    assert got.dtype == out_dtype
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


def test_augment_params_shape_and_ranges():
    p = np.asarray(ops.input_augment_params(0, 0, 64, max_shift=4))
    assert p.shape == (64, 4) and p.dtype == np.int32
    assert set(np.unique(p[:, 0])) <= {0, 1}
    assert p[:, 1:3].min() >= -4 and p[:, 1:3].max() <= 4
    # both flip outcomes and several distinct shifts actually occur
    assert len(set(p[:, 0])) == 2
    assert len(set(p[:, 1])) > 2


def test_augment_params_traced_step_equals_eager():
    """fold_in with a traced step must give the same stream as eager —
    the property that lets the kernel path derive params in-jit from
    the batch's input_step stamp."""
    eager = ops.input_augment_params(7, 3, 16)
    traced = jax.jit(
        lambda s: ops.input_augment_params(7, s, 16))(jnp.int32(3))
    np.testing.assert_array_equal(np.asarray(eager), np.asarray(traced))


def test_augment_params_vary_by_step_and_seed():
    a = np.asarray(ops.input_augment_params(0, 0, 32))
    b = np.asarray(ops.input_augment_params(0, 1, 32))
    c = np.asarray(ops.input_augment_params(1, 0, 32))
    assert not np.array_equal(a, b)
    assert not np.array_equal(a, c)


def test_host_augmented_source_matches_device_ref_path():
    """AugmentedSource (numpy host path) and ref.input_forward (the
    device semantics the kernel is pinned to) produce identical f32
    pixels from the same (seed, step) — the bridge that makes host-path
    and fused-input training runs comparable."""
    src = SyntheticImageData(4, 12, 6, seed=2)
    aug = AugmentedSource(src, seed=9, mean=MEAN, std=STD,
                          global_batch=6)
    for step in (0, 4):
        host = aug.batch_at(step)["images"]
        x = jnp.asarray(src.batch_at(step)["images"])
        params = ops.input_augment_params(9, step, 6)
        dev = ref.input_forward(x, params, jnp.asarray(MEAN, jnp.float32),
                                jnp.asarray(STD, jnp.float32),
                                train=True, out_dtype=jnp.float32)
        np.testing.assert_array_equal(host.astype(np.float32),
                                      np.asarray(dev))


def test_augmented_source_shard_slices_global_param_stream():
    """Per-host AugmentedSource must draw params at the global batch
    size and slice — threefry draws are not prefix-stable across draw
    sizes, so drawing at the shard size would desync hosts."""
    batch, hosts = 8, 2
    full_src = SyntheticImageData(4, 8, batch, seed=0)
    full = AugmentedSource(full_src, seed=5, mean=MEAN, std=STD,
                           global_batch=batch).batch_at(3)["images"]
    per = batch // hosts
    parts = []
    for h in range(hosts):
        shard_src = SyntheticImageData(4, 8, per, seed=0,
                                       sample_offset=h * per)
        parts.append(AugmentedSource(
            shard_src, seed=5, mean=MEAN, std=STD,
            global_batch=batch).batch_at(3)["images"])
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_eval_variant_is_pure_normalize():
    """The eval kernel takes no augment params at all: output is
    exactly (x - mean) * inv_std, cast."""
    x = _batch(4, 8)
    mean = jnp.asarray(MEAN, jnp.float32)
    inv = 1.0 / jnp.asarray(STD, jnp.float32)
    got = ops.fused_input_eval(x, mean, inv, out_dtype=jnp.float32)
    want = (np.asarray(x) - np.asarray(mean)) * np.asarray(inv)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_fused_input_requires_conv_and_shardmap():
    from repro.configs import (InputConfig, OptimizerConfig, get_config,
                               reduced_config)
    from repro.launch.train import build_train_setup
    cfg = reduced_config(get_config("llama3.2-1b"))
    with pytest.raises(ValueError, match="image batches"):
        build_train_setup(
            cfg, global_batch=4, seq_len=8,
            opt_cfg=OptimizerConfig(), steps_per_epoch=5, seed=0,
            input_cfg=InputConfig(fused=True))
    cfg = reduced_config(get_config("resnet50"))
    with pytest.raises(ValueError, match="shard_map"):
        build_train_setup(
            cfg, global_batch=4, seq_len=8,
            opt_cfg=OptimizerConfig(), steps_per_epoch=5, seed=0,
            dp_mode="gspmd", input_cfg=InputConfig(fused=True))


# ---------------------------------------------------------------------------
# 3-step end-to-end parity: fused device input vs host-path augmentation
# (subprocess, 8-device virtual mesh, slow)
# ---------------------------------------------------------------------------

_PARITY_BODY = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import (InputConfig, OptimizerConfig, get_config,
                               reduced_config)
    from repro.data.pipeline import DataPipeline
    from repro.launch.train import build_train_setup
    cfg = reduced_config(get_config('resnet50'))
    mesh = jax.make_mesh((jax.device_count(), 1), ('data', 'model'))

    def run(fused, workers):
        model, state, step, data, put, _ = build_train_setup(
            cfg, global_batch=8, seq_len=16, opt_cfg=OptimizerConfig(),
            steps_per_epoch=5, mesh=mesh, dp_mode='shardmap', seed=0,
            compression='bf16+bucketed', bucket_bytes=8192,
            zero_dp=ZERO,
            input_cfg=InputConfig(fused=fused, mean=(0.1, -0.2, 0.3),
                                  std=(0.9, 1.1, 1.3)))
        pipe = DataPipeline(data, depth=4, num_workers=workers, put=put)
        losses = []
        try:
            for _ in range(3):
                _, batch = next(pipe)
                state, metrics = step(state, batch)
                losses.append(float(metrics['loss']))
        finally:
            pipe.close()
        return state, losses

    sh, lh = run(fused=False, workers=1)   # host-path augmentation
    sf, lf = run(fused=True, workers=3)    # fused on-device kernel
    assert lh == lf, (lh, lf)
    for a, b in zip(jax.tree.leaves(sh['params']),
                    jax.tree.leaves(sf['params'])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    print('OK', lh)
"""


@pytest.mark.slow
@pytest.mark.parametrize("zero", [False, True],
                         ids=["bucketed", "zero"])
def test_fused_vs_host_path_training_parity(zero):
    """Training with the fused on-device input kernel (multi-worker,
    device-staged feed) is bitwise equivalent to host-path numpy
    augmentation: identical per-step losses and final params after 3
    steps. The model casts images to its compute dtype on entry, so the
    fused path's bf16 output and the host path's f32 pixels converge
    exactly."""
    body = f"    ZERO = {zero}\n" + _PARITY_BODY
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(body)], env=ENV8,
        capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, f"STDERR:\n{res.stderr[-4000:]}"
    assert "OK" in res.stdout
