"""Schema guard for the committed benchmark trajectory file.

``BENCH_step.json`` is the per-PR steps/sec trajectory point
(benchmarks/step_bench.py, uploaded by CI). Refactors that touch the
bench emitter must not silently drop a sync-mode column or rename a
field — downstream trajectory tooling keys on this exact schema, so the
shape is pinned here, including the ``zero`` modes (DESIGN.md §9).
"""
import json
import os

REPO = os.path.join(os.path.dirname(__file__), "..")

EXPECTED_MODES = (
    "gspmd",
    "shardmap_perleaf",
    "shardmap_bucketed",
    "shardmap_overlap",
    "shardmap_zero",
    "shardmap_zero_overlap",
)

MODE_FIELDS = ("ms_per_step", "steps_per_sec", "warmup_s", "compute_ms")

# input-boundedness attribution (DESIGN.md §15): legitimately 0.0 when
# the feed never starves the step, so guarded as >= 0 rather than > 0
MODE_WAIT_FIELDS = ("data_wait_ms", "data_starved_frac")

TOP_FIELDS = ("bench", "devices", "backend", "arch", "global_batch",
              "bucket_bytes", "iters", "data_workers", "modes",
              "overlap_vs_bucketed_speedup", "zero_vs_bucketed_speedup")


def _load():
    with open(os.path.join(REPO, "BENCH_step.json")) as f:
        return json.load(f)


def test_bench_step_json_has_all_sync_mode_columns():
    data = _load()
    assert data["bench"] == "step_bench"
    missing = [m for m in EXPECTED_MODES if m not in data["modes"]]
    assert not missing, f"BENCH_step.json lost sync-mode columns: {missing}"


def test_bench_step_json_mode_fields_and_types():
    data = _load()
    for top in TOP_FIELDS:
        assert top in data, f"BENCH_step.json lost top-level field {top!r}"
    for mode, row in data["modes"].items():
        for field in MODE_FIELDS:
            assert field in row, (mode, field)
            assert isinstance(row[field], (int, float)), (mode, field)
            assert row[field] > 0, (mode, field, row[field])
        for field in MODE_WAIT_FIELDS:
            assert field in row, (mode, field)
            assert isinstance(row[field], (int, float)), (mode, field)
            assert row[field] >= 0, (mode, field, row[field])
        assert row["data_starved_frac"] <= 1.0, mode
    assert isinstance(data["devices"], int) and data["devices"] >= 1
    assert isinstance(data["data_workers"], int) and data["data_workers"] >= 1


def test_bench_step_json_speedups_consistent_with_modes():
    data = _load()
    modes = data["modes"]
    want = round(modes["shardmap_bucketed"]["ms_per_step"]
                 / modes["shardmap_zero"]["ms_per_step"], 3)
    assert abs(data["zero_vs_bucketed_speedup"] - want) < 1e-6
    want = round(modes["shardmap_bucketed"]["ms_per_step"]
                 / modes["shardmap_overlap"]["ms_per_step"], 3)
    assert abs(data["overlap_vs_bucketed_speedup"] - want) < 1e-6


# ---------------------------------------------------------------------------
# BENCH_input.json (benchmarks/input_bench.py, DESIGN.md §15)
# ---------------------------------------------------------------------------

INPUT_TOP_FIELDS = ("bench", "backend", "devices", "batch", "image_size",
                    "iters", "workers", "multi_worker_speedup",
                    "host_shard", "transform")

INPUT_WORKER_FIELDS = ("ms_per_batch", "batches_per_s")

INPUT_SHARD_FIELDS = ("num_hosts", "global_ms_per_batch",
                      "shard_ms_per_batch", "shard_speedup")


def _load_input():
    with open(os.path.join(REPO, "BENCH_input.json")) as f:
        return json.load(f)


def test_bench_input_json_schema():
    data = _load_input()
    assert data["bench"] == "input_bench"
    for top in INPUT_TOP_FIELDS:
        assert top in data, f"BENCH_input.json lost top-level field {top!r}"
    counts = [k for k in data["workers"] if k != "note"]
    assert "1" in counts, "single-thread baseline row missing"
    assert len(counts) >= 2, "need at least one multi-worker row"
    for k in counts:
        row = data["workers"][k]
        for field in INPUT_WORKER_FIELDS:
            assert field in row, (k, field)
            assert row[field] > 0, (k, field, row[field])
    assert data["workers"]["note"], \
        "GIL-bound-source caveat must stay documented"
    assert data["multi_worker_speedup"] > 0


def test_bench_input_json_host_shard_does_fractional_work():
    """The per-host sharded source must actually generate ~1/N the
    batch — the property that keeps host feed time flat at scale."""
    shard = _load_input()["host_shard"]
    for field in INPUT_SHARD_FIELDS:
        assert field in shard, field
    assert shard["num_hosts"] >= 2
    assert shard["shard_ms_per_batch"] < shard["global_ms_per_batch"]
    assert shard["shard_speedup"] > 1.5


def test_bench_input_json_transform_rows():
    tr = _load_input()["transform"]
    for field in ("host_aug_ms", "fused_ms", "note"):
        assert field in tr, field
    assert tr["host_aug_ms"] >= 0
    assert tr["fused_ms"] > 0
    assert tr["note"], "interpret-mode caveat must stay documented"


# ---------------------------------------------------------------------------
# BENCH_bn.json (benchmarks/bn_bench.py, DESIGN.md §10)
# ---------------------------------------------------------------------------

BN_TOP_FIELDS = ("bench", "backend", "devices", "iters", "epilogue",
                 "shapes", "fusion_report", "caveat")

BN_SHAPE_FIELDS = ("fused_fwd_ms", "unfused_fwd_ms", "fused_fwdbwd_ms",
                   "unfused_fwdbwd_ms", "fwd_speedup", "fwdbwd_speedup")


def _load_bn():
    with open(os.path.join(REPO, "BENCH_bn.json")) as f:
        return json.load(f)


def test_bench_bn_json_schema():
    data = _load_bn()
    assert data["bench"] == "bn_bench"
    for top in BN_TOP_FIELDS:
        assert top in data, f"BENCH_bn.json lost top-level field {top!r}"
    assert data["caveat"], "CPU-interpret caveat must stay documented"
    assert data["shapes"], "per-stage shape rows missing"
    for name, row in data["shapes"].items():
        assert isinstance(row.get("shape"), list) and len(row["shape"]) == 4
        for field in BN_SHAPE_FIELDS:
            assert field in row, (name, field)
            assert isinstance(row[field], (int, float)), (name, field)
            assert row[field] > 0, (name, field, row[field])


def test_bench_bn_json_fusion_report_proves_collapse():
    """The committed trajectory point must carry the HLO op-count
    collapse proof, not just wall-clocks (the clock is a CPU-interpret
    proxy; the per-site collapse is the transferable claim)."""
    rep = _load_bn()["fusion_report"]
    for section in ("fused", "unfused"):
        assert rep[section]["reduction_ops"] > 0
    assert rep["fused"]["reduction_ops"] < rep["unfused"]["reduction_ops"]
    assert rep["collapsed"] is True


# ---------------------------------------------------------------------------
# BENCH_scaling.json (examples/large_batch_sweep.py, DESIGN.md §11)
# ---------------------------------------------------------------------------

SCALING_TOP_FIELDS = ("bench", "arch", "backend", "devices", "quick",
                      "steps", "steps_per_epoch", "batches", "recipes")

SCALING_POINT_FIELDS = ("global_batch", "lr_scale", "final_loss",
                        "final_accuracy", "diverged")


def _load_scaling():
    with open(os.path.join(REPO, "BENCH_scaling.json")) as f:
        return json.load(f)


def test_bench_scaling_json_schema():
    data = _load_scaling()
    assert data["bench"] == "scaling_sweep"
    for top in SCALING_TOP_FIELDS:
        assert top in data, \
            f"BENCH_scaling.json lost top-level field {top!r}"
    assert isinstance(data["steps"], int) and data["steps"] > 0
    # acceptance: >= 2 recipes x >= 3 batch sizes
    assert len(data["recipes"]) >= 2
    assert len(data["batches"]) >= 3
    names = [r["recipe"] for r in data["recipes"]]
    assert len(set(names)) == len(names), f"duplicate recipes: {names}"


def test_bench_scaling_json_points_and_divergence_contract():
    data = _load_scaling()
    for rec in data["recipes"]:
        for field in ("recipe", "optimizer", "schedule",
                      "label_smoothing", "points"):
            assert field in rec, (rec.get("recipe"), field)
        # every recipe sweeps exactly the advertised batch grid, in order
        assert [p["global_batch"] for p in rec["points"]] == \
            data["batches"], rec["recipe"]
        assert len(rec["points"]) >= 3
        for p in rec["points"]:
            for field in SCALING_POINT_FIELDS:
                assert field in p, (rec["recipe"], field)
            assert p["lr_scale"] > 0
            # final metrics are None exactly when the cell diverged
            for metric in ("final_loss", "final_accuracy"):
                if p["diverged"]:
                    assert p[metric] is None, (rec["recipe"], p)
                else:
                    assert isinstance(p[metric], (int, float)), \
                        (rec["recipe"], metric, p)


def test_bench_scaling_covers_lars_and_baseline():
    """The sweep's point: the paper baseline vs the trust-ratio recipes
    on the same grid. Both optimizer kinds must be present."""
    kinds = {r["optimizer"] for r in _load_scaling()["recipes"]}
    assert "rmsprop_warmup" in kinds
    assert "lars" in kinds


# ---------------------------------------------------------------------------
# AUDIT.json (the compiled-program audit report, DESIGN.md §12)
# ---------------------------------------------------------------------------

AUDIT_PASSES = ("comm", "interleave", "precision", "donation", "memory",
                "collectives", "determinism")

AUDIT_CELL_FIELDS = ("mode", "optimizer", "contract", "ok", "violations",
                     "expectations", "info", "passes")

AUDIT_EXPECTATION_KEYS = ("n_buckets", "n_buckets_planned",
                          "collective_budget", "n_batch_params",
                          "metric_bytes_floor", "schedule_min_bytes",
                          "min_gradient_wire_bytes")


def _load_audit():
    with open(os.path.join(REPO, "AUDIT.json")) as f:
        return json.load(f)


def test_audit_json_covers_full_mode_matrix():
    data = _load_audit()
    assert data["ok"] is True, "committed AUDIT.json must be green"
    cells = {(c["mode"], c["optimizer"]) for c in data["cells"]}
    want = {(m, o)
            for m in ("gspmd", "perleaf", "bucketed", "overlap", "zero",
                      "zero_overlap", "hier", "hier_overlap",
                      "hier_zero", "hier_zero_overlap")
            for o in ("sgd", "lars")}
    assert cells == want, f"AUDIT.json lost cells: {want - cells}"
    # the hierarchical cells lower on their own 2-axis mesh
    assert len(data["hier_mesh"]) == 2
    assert all(s >= 2 for s in data["hier_mesh"])


def test_audit_json_cell_schema():
    data = _load_audit()
    for cell in data["cells"]:
        for field in AUDIT_CELL_FIELDS:
            assert field in cell, (cell["mode"], field)
        assert cell["ok"] is True and cell["violations"] == []
        missing = [p for p in AUDIT_PASSES if p not in cell["passes"]]
        assert not missing, (cell["mode"], missing)
        for pname, rec in cell["passes"].items():
            assert {"pass", "ok", "findings", "summary"} <= set(rec), \
                (cell["mode"], pname)
        for k in AUDIT_EXPECTATION_KEYS:
            assert k in cell["expectations"], (cell["mode"], k)


def test_audit_json_relations():
    data = _load_audit()
    rels = {(r["relation"], r["optimizer"]) for r in data["relations"]}
    assert rels == {("zero_shrinks_optimizer_residency", "sgd"),
                    ("zero_shrinks_optimizer_residency", "lars")}
    for r in data["relations"]:
        assert r["ok"] is True
        assert r["actual_shrink_bytes"] > 0


# ---------------------------------------------------------------------------
# BENCH_resilience.json (benchmarks/resilience_bench.py, DESIGN.md §13)
# ---------------------------------------------------------------------------

RESILIENCE_SCENARIOS = ("baseline", "nan_bucket", "rollback",
                        "ckpt_corrupt", "data_crash", "straggler")

RESILIENCE_FIELDS = ("chaos", "completed", "final_top1", "skipped_steps",
                     "rollbacks", "wasted_steps", "steps_to_recover",
                     "events", "ok", "wall_s")


def _load_resilience():
    with open(os.path.join(REPO, "BENCH_resilience.json")) as f:
        return json.load(f)


def test_bench_resilience_json_covers_all_fault_classes():
    data = _load_resilience()
    assert data["all_ok"] is True, "committed soak must be green"
    missing = [s for s in RESILIENCE_SCENARIOS
               if s not in data["scenarios"]]
    assert not missing, f"BENCH_resilience.json lost scenarios: {missing}"
    assert isinstance(data["baseline_top1"], (int, float))


def test_bench_resilience_json_scenario_schema():
    data = _load_resilience()
    for name, rec in data["scenarios"].items():
        for field in RESILIENCE_FIELDS:
            assert field in rec, (name, field)
        assert rec["completed"] is True and rec["ok"] is True, name
        if name != "baseline":
            assert rec["within_tolerance"] is True, name


def test_bench_resilience_json_recovery_contracts():
    """Each fault class must have driven its intended recovery path."""
    sc = _load_resilience()["scenarios"]
    assert sc["baseline"]["events"] == {}
    assert sc["nan_bucket"]["skipped_steps"] >= 1
    assert sc["nan_bucket"]["rollbacks"] == 0
    assert sc["rollback"]["rollbacks"] >= 1
    assert sc["rollback"]["wasted_steps"] >= 1
    assert sc["ckpt_corrupt"]["events"].get(
        "corrupt_checkpoint_skipped", 0) >= 1
    assert sc["ckpt_corrupt"]["rollbacks"] >= 1
    assert sc["data_crash"]["events"].get("data_restart", 0) >= 1
    assert sc["straggler"]["events"].get("chaos_injected", 0) >= 1


# ---------------------------------------------------------------------------
# BENCH_comm.json (benchmarks/comm_bench.py sweep artifact, DESIGN.md §14)
# ---------------------------------------------------------------------------

COMM_TOP_FIELDS = ("bench", "devices", "mesh", "mesh_axes", "wire",
                   "bucket_bytes", "sweep", "plan_path", "plan", "rows")

COMM_ROW_FIELDS = ("arch", "mode", "wire", "bucket_mib", "hier_split",
                   "leaves", "collectives_per_step", "mib_per_collective",
                   "wire_dtypes", "ms_per_sync")

COMM_PLAN_FIELDS = ("mesh_shape", "dp_axes", "sync_mode", "wire",
                    "bucket_bytes", "hier_split", "source", "version")


def _load_comm():
    path = os.path.join(REPO, "BENCH_comm.json")
    if not os.path.exists(path):
        import pytest
        pytest.skip("BENCH_comm.json not present (CI writes it right "
                    "before running this guard)")
    with open(path) as f:
        return json.load(f)


def test_bench_comm_json_schema():
    data = _load_comm()
    assert data["bench"] == "comm_bench"
    for top in COMM_TOP_FIELDS:
        assert top in data, f"BENCH_comm.json lost top-level field {top!r}"
    import math
    assert math.prod(data["mesh"]) == data["devices"]
    assert len(data["mesh"]) == len(data["mesh_axes"])
    assert data["rows"], "sweep produced no rows"
    for row in data["rows"]:
        for field in COMM_ROW_FIELDS:
            assert field in row, (row.get("mode"), field)
        assert row["ms_per_sync"] > 0, row
        assert row["collectives_per_step"] >= 1, row
        # hierarchical rows carry their split; flat rows carry None
        if row["mode"].startswith("hier"):
            assert row["hier_split"] is not None, row
        else:
            assert row["hier_split"] is None, row


def test_bench_comm_json_sweep_persists_winning_plan():
    """A --sweep run must leave a loadable CommPlan whose schedule is
    one of the swept rows — the artifact `--comm-plan auto` consumes."""
    data = _load_comm()
    if not data["sweep"]:
        import pytest
        pytest.skip("not a sweep artifact: no plan to check")
    plan = data["plan"]
    assert plan is not None, "sweep artifact lost the embedded plan"
    for field in COMM_PLAN_FIELDS:
        assert field in plan, field
    assert plan["source"] == "autotuner"
    assert list(plan["mesh_shape"]) == list(data["mesh"])
    assert plan["bucket_bytes"] > 0
    from repro.distributed.comm_plan import PLAN_VERSION, load_plan
    assert plan["version"] == PLAN_VERSION
    loaded = load_plan(os.path.join(REPO, data["plan_path"])
                       if not os.path.isabs(data["plan_path"])
                       else data["plan_path"])
    assert loaded.sync_mode == plan["sync_mode"]
    assert loaded.hier_split == plan["hier_split"]
