"""BN-without-moving-averages semantics (paper §2)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.batchnorm import (
    bn_apply_stats,
    bn_batch_stats,
    combine_worker_bn_stats,
    finalize_bn_stats,
    merge_bn_stats,
)


def test_batch_stats_match_numpy(key):
    x = jax.random.normal(key, (8, 6, 6, 16)) * 3.0 + 1.5
    mean, var = bn_batch_stats(x)
    np.testing.assert_allclose(mean, np.asarray(x).mean((0, 1, 2)),
                               rtol=1e-5)
    np.testing.assert_allclose(var, np.asarray(x).var((0, 1, 2)),
                               rtol=1e-4)


def test_apply_normalizes(key):
    x = jax.random.normal(key, (32, 4, 4, 8)) * 5.0 - 2.0
    mean, var = bn_batch_stats(x)
    y = bn_apply_stats(x, mean, var, jnp.ones(8), jnp.zeros(8))
    np.testing.assert_allclose(np.asarray(y).mean((0, 1, 2)), 0.0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(y).std((0, 1, 2)), 1.0, atol=1e-3)


def test_finalize_identity_without_axes(key):
    state = {"bn": {"mean": jax.random.normal(key, (4,)),
                    "var": jnp.ones(4)}}
    out = finalize_bn_stats(state, axis_names=None)
    np.testing.assert_array_equal(out["bn"]["mean"], state["bn"]["mean"])


def test_merge_bn_stats_host_side(key):
    ks = jax.random.split(key, 3)
    states = [{"m": jax.random.normal(k, (4,))} for k in ks]
    merged = merge_bn_stats(states)
    np.testing.assert_allclose(
        merged["m"], sum(np.asarray(s["m"]) for s in states) / 3, rtol=1e-6)


def test_combine_worker_stats_reconstructs_global(key):
    """The pre-validation all-reduce must yield the statistics of the
    *concatenated* global minibatch, not a naive average of variances:
    E[x^2] is reconstructed per worker before combining (paper §2,
    DESIGN.md §7)."""
    x = jax.random.normal(key, (8, 4, 6, 6, 16)) * 2.0 + 1.0  # 8 workers
    per_worker = [bn_batch_stats(x[w]) for w in range(8)]
    state = {"bn": {
        "mean": jnp.stack([m for m, _ in per_worker]),
        "var": jnp.stack([v for _, v in per_worker]),
        "count": jnp.ones((8,)),
    }}
    combined = combine_worker_bn_stats(state)
    gmean, gvar = bn_batch_stats(x.reshape(-1, 6, 6, 16))
    np.testing.assert_allclose(combined["bn"]["mean"], gmean, rtol=1e-5)
    np.testing.assert_allclose(combined["bn"]["var"], gvar,
                               rtol=1e-4, atol=1e-6)
    # naive variance averaging would lose the spread of worker means
    naive = np.asarray(state["bn"]["var"]).mean(0)
    assert np.abs(naive - np.asarray(gvar)).max() > 1e-3
    np.testing.assert_allclose(combined["bn"]["count"], 1.0)


def test_variance_large_mean_bf16_vs_f64_oracle(key):
    """Centered-variance regression (the E[x^2]-E[x]^2 cancellation
    fix): for a bf16 activation with mean ~1000 and spread ~2, the
    uncentered form loses the variance to catastrophic cancellation
    (both terms ~10^6, their gap ~4, fp32 spacing at 10^6 is 0.0625),
    while the centered E[(x-mu)^2] form stays accurate. Oracle: numpy
    float64 over the exact bf16-representable values."""
    # steps of 2 around 1000 are exactly representable in bf16
    # (spacing at 1024 is 8... use 1000 where spacing is 4; k*4 steps)
    k = jax.random.randint(key, (64, 4, 4, 8), -2, 3).astype(jnp.float32)
    x = (1024.0 + 4.0 * k).astype(jnp.bfloat16)
    x64 = np.asarray(x, np.float64)
    mean64 = x64.mean(axis=(0, 1, 2))
    var64 = ((x64 - mean64) ** 2).mean(axis=(0, 1, 2))
    mean, var = bn_batch_stats(x)
    np.testing.assert_allclose(np.asarray(mean), mean64, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(var), var64, rtol=1e-4)
    # the uncentered fp32 form measurably degrades on the same data —
    # the regression this test exists to pin
    x32 = np.asarray(x, np.float32)
    uncentered = (x32 ** 2).mean(axis=(0, 1, 2), dtype=np.float32) \
        - x32.mean(axis=(0, 1, 2), dtype=np.float32) ** 2
    assert np.abs(uncentered - var64).max() > \
        10 * np.abs(np.asarray(var) - var64).max()


def test_no_moving_average_semantics(key):
    """State after a step holds exactly the LAST minibatch's stats — not
    an EMA blend (the paper's central BN change)."""
    from repro.configs import get_config, reduced_config
    from repro.models import build_model, init_model_state
    cfg = reduced_config(get_config("resnet50"))
    model = build_model(cfg, compute_dtype=jnp.float32)
    params, _ = model.init_params(key)
    state0 = init_model_state(model)
    x1 = jax.random.normal(jax.random.PRNGKey(1), (4, 32, 32, 3))
    x2 = jax.random.normal(jax.random.PRNGKey(2), (4, 32, 32, 3)) + 7.0
    _, s1 = model.apply(params, state0, x1, train=True)
    _, s2 = model.apply(params, s1, x2, train=True)
    # recompute step-2 stats from scratch (state-independent)
    _, s2b = model.apply(params, state0, x2, train=True)
    for a, b in zip(jax.tree.leaves(s2), jax.tree.leaves(s2b)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5)
