"""ZeRO reduce-scatter sync mode (DESIGN.md §9).

Fast single-process tests cover the decay-mask regression, the
wd-stream codec, the per-element-decay fused kernel, the shard-layout
permutation, and the mode's validation errors. The step-level parity
matrix — zero vs bucketed and zero-overlap vs overlap, bitwise, across
{plain, error-feedback} x {bf16, f16} — plus the checkpoint boundary
round-trip and the HLO reduce-scatter proof run in subprocesses on
virtual host meshes (marked ``slow``; the fast CI job skips them, the
``-m slow`` job runs them).
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import OptimizerConfig, ParallelConfig, TrainConfig
from repro.distributed.bucketing import (
    local_shard,
    plan_buckets,
    shard_chunks,
    shard_layout_to_stream,
    shard_size,
    stream_to_shard_layout,
)
from repro.optim.rmsprop_warmup import _decay_mask
from repro.optim.stream import decay_wd_stream, make_stream_optimizer

ENV8 = {
    **os.environ,
    "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
    "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src"),
}
ENV2 = {**ENV8, "XLA_FLAGS": "--xla_force_host_platform_device_count=2"}


def run_py(body: str, env=ENV8, timeout=600) -> str:
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert res.returncode == 0, f"STDERR:\n{res.stderr[-4000:]}"
    return res.stdout


# ---------------------------------------------------------------------------
# decay mask: substring-safe exact-key matching (regression)
# ---------------------------------------------------------------------------


def test_decay_mask_exact_key_not_substring():
    """NO_DECAY entries match path fragments by exact equality only: a
    param literally named 'Dense_bias_proj' (contains 'bias') or
    'Dscale' (contains both 'D' and 'scale') must stay decayed, while
    exact 'bias'/'scale'/'D' keys are exempt wherever they sit."""
    params = {
        "fc": {"w": jnp.zeros(3), "bias": jnp.zeros(3),
               "Dense_bias_proj": jnp.zeros(3)},
        "norm": {"scale": jnp.zeros(3), "Dscale": jnp.zeros(3),
                 "scales": jnp.zeros(3)},
        "ssm": {"D": jnp.zeros(3), "blockD": jnp.zeros(3)},
    }
    mask = _decay_mask(params)
    assert mask["fc"]["w"] is True
    assert mask["fc"]["bias"] is False
    assert mask["fc"]["Dense_bias_proj"] is True  # the regression
    assert mask["norm"]["scale"] is False
    assert mask["norm"]["Dscale"] is True
    assert mask["norm"]["scales"] is True
    assert mask["ssm"]["D"] is False
    assert mask["ssm"]["blockD"] is True


def test_decay_mask_outer_module_named_bias_exempts_subtree():
    # any exact NO_DECAY fragment on the path exempts the leaf — the
    # longstanding per-component semantics, now pinned
    params = {"bias": {"w": jnp.zeros(2)}, "layer": {"w": jnp.zeros(2)}}
    mask = _decay_mask(params)
    assert mask["bias"]["w"] is False
    assert mask["layer"]["w"] is True


def test_wd_stream_places_decay_and_zero_pad():
    tree = {"a": {"w": jnp.zeros((5,)), "bias": jnp.zeros((3,))},
            "z": jnp.zeros((6,))}
    plan = plan_buckets(tree, bucket_bytes=4 * 4, wire=None, align=4)
    wd = decay_wd_stream(tree, plan, 0.5)
    assert wd.shape == (plan.padded_total,)
    # tree order: a/bias (3), a/w (5), z (6) = 14 elems, pad to align
    assert plan.total_elems == 14
    np.testing.assert_array_equal(wd[:3], 0.0)  # bias exempt
    np.testing.assert_array_equal(wd[3:14], 0.5)
    np.testing.assert_array_equal(wd[14:], 0.0)  # alignment pad


# ---------------------------------------------------------------------------
# shard layout: permutation round-trip + local_shard agreement
# ---------------------------------------------------------------------------


def test_shard_layout_roundtrip_and_local_shard():
    tree = {f"l{i}": jnp.arange(i * 7 + 1, dtype=jnp.float32)
            for i in range(6)}
    n = 4
    plan = plan_buckets(tree, bucket_bytes=13 * 4, wire=None, align=n)
    total = plan.padded_total
    assert total % n == 0
    stream = np.arange(total, dtype=np.float32)
    lay = stream_to_shard_layout(stream, plan, n)
    np.testing.assert_array_equal(
        shard_layout_to_stream(lay, plan, n), stream)
    s = shard_size(plan, n)
    assert s * n == total
    for w in range(n):
        got = np.asarray(local_shard(jnp.asarray(stream), plan, n, w))
        np.testing.assert_array_equal(got, lay[w * s:(w + 1) * s])
    # chunks tile each bucket exactly
    for b, c in enumerate(shard_chunks(plan, n)):
        lo, hi = plan.bucket_bounds(b)
        assert c * n == hi - lo


# ---------------------------------------------------------------------------
# fused kernel: per-element wd array == scalar wd, bitwise
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("wd", [0.0, 1e-4])
def test_fused_update_wd_array_matches_scalar(wd):
    from repro.core.optimizer import HybridHyper
    from repro.kernels import ops

    rng = np.random.default_rng(0)
    shape = (3, 130)  # non-multiple of 128 lanes: exercises padding
    g = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    p = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    d = jnp.asarray(rng.standard_normal(shape), jnp.float32)
    m = jnp.abs(jnp.asarray(rng.standard_normal(shape), jnp.float32))
    h = HybridHyper(eta=jnp.float32(0.1), alpha_sgd=jnp.float32(0.4))
    ref = ops.fused_hybrid_update(g, p, d, m, h, wd)
    got = ops.fused_hybrid_update(g, p, d, m, h,
                                  jnp.full(shape, wd, jnp.float32))
    for a, b in zip(ref, got):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_stream_optimizer_matches_tree_optimizer_elementwise():
    """One update on a packed stream == the per-leaf tree update packed
    afterwards, bitwise — the single-process core of the mode's parity
    claim (8-device step-level parity runs in the slow sweep)."""
    from repro.optim import make_optimizer

    cfg = OptimizerConfig()
    rng = np.random.default_rng(1)
    params = {"w": jnp.asarray(rng.standard_normal((7, 3)), jnp.float32),
              "bias": jnp.asarray(rng.standard_normal((5,)), jnp.float32)}
    grads = jax.tree.map(
        lambda p: jnp.asarray(rng.standard_normal(p.shape), jnp.float32),
        params)
    tree_opt = make_optimizer(cfg, steps_per_epoch=5, global_batch=32)
    st = tree_opt.init(params)
    new_p, new_st, _ = tree_opt.update(params, grads, st)

    plan = plan_buckets(params, bucket_bytes=16, wire=None, align=2)
    sopt = make_stream_optimizer(cfg, steps_per_epoch=5, global_batch=32)
    zst = sopt.init(plan.padded_total)

    def to_stream(tree):
        flat = np.concatenate([np.asarray(l).reshape(-1)
                               for l in plan.treedef.flatten_up_to(tree)])
        return jnp.asarray(np.concatenate(
            [flat, np.zeros(plan.pad_elems, np.float32)]))

    wd = jnp.asarray(sopt.wd_stream(params, plan))
    p2, d2, m2, _ = sopt.update_shard(
        to_stream(params), to_stream(grads), zst["delta"], zst["m"],
        zst["step"], wd)
    np.testing.assert_array_equal(np.asarray(p2),
                                  np.asarray(to_stream(new_p)))
    np.testing.assert_array_equal(np.asarray(d2[:plan.total_elems]),
                                  np.asarray(to_stream(new_st["delta"])
                                             )[:plan.total_elems])
    np.testing.assert_array_equal(np.asarray(m2[:plan.total_elems]),
                                  np.asarray(to_stream(new_st["m"])
                                             )[:plan.total_elems])


# ---------------------------------------------------------------------------
# validation errors
# ---------------------------------------------------------------------------


def test_zero_requires_bucketed_compression():
    from repro.training.step import make_dp_shardmap_train_step

    cfg = TrainConfig(optimizer=OptimizerConfig(),
                      parallel=ParallelConfig(compression="bf16",
                                              zero_dp=True))
    with pytest.raises(ValueError, match="bucketed"):
        make_dp_shardmap_train_step(object(), object(), cfg, None,
                                    ("data",))


def test_zero_requires_stream_optimizer():
    from repro.optim import make_optimizer
    from repro.training.step import make_dp_shardmap_train_step

    opt = make_optimizer(OptimizerConfig(), 5, 32)
    cfg = TrainConfig(optimizer=OptimizerConfig(),
                      parallel=ParallelConfig(
                          compression="bf16+bucketed", zero_dp=True))
    mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    with pytest.raises(ValueError, match="stream optimizer"):
        make_dp_shardmap_train_step(object(), opt, cfg, mesh, ("data",))


def test_stream_optimizer_rejects_unsupported_kind():
    # momentum_sgd is stream-supported now (the zero x sgd audit cells,
    # DESIGN.md §12); kinds outside the stream family still raise
    with pytest.raises(ValueError, match="rmsprop_warmup"):
        make_stream_optimizer(OptimizerConfig(kind="adamw"), 5, 32)


def test_zero_rejected_outside_shardmap():
    from repro.configs import get_config, reduced_config
    from repro.launch.train import build_train_setup

    cfg = reduced_config(get_config("resnet50"))
    with pytest.raises(ValueError, match="shard_map"):
        build_train_setup(cfg, global_batch=8, seq_len=16,
                          opt_cfg=OptimizerConfig(), steps_per_epoch=5,
                          dp_mode="gspmd", zero_dp=True,
                          compression="bf16+bucketed")


def test_zero_without_mesh_raises_cleanly():
    from repro.configs import get_config, reduced_config
    from repro.launch.train import build_train_setup

    cfg = reduced_config(get_config("resnet50"))
    with pytest.raises(ValueError, match="mesh"):
        build_train_setup(cfg, global_batch=8, seq_len=16,
                          opt_cfg=OptimizerConfig(), steps_per_epoch=5,
                          dp_mode="shardmap", mesh=None, zero_dp=True,
                          compression="bf16+bucketed")


def test_zero_padded_total_rejects_unbucketed():
    from repro.optim.stream import zero_padded_total

    with pytest.raises(ValueError, match="bucketed"):
        zero_padded_total({"w": jnp.zeros((4,))}, "bf16", 8192, 8)


# ---------------------------------------------------------------------------
# step-level parity matrix (subprocess, 8-device virtual mesh, slow)
# ---------------------------------------------------------------------------

_PARITY_HEADER = """
    WIRE = '{wire}'
    EF = {ef}
"""

_PARITY_BODY = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import OptimizerConfig, get_config, reduced_config
    from repro.distributed.bucketing import (plan_buckets,
                                             plan_ready_buckets,
                                             stream_to_shard_layout)
    from repro.launch.train import build_train_setup
    cfg = reduced_config(get_config('resnet50'))
    mesh = jax.make_mesh((jax.device_count(), 1), ('data', 'model'))
    N = jax.device_count()
    BB = 8192

    def run(overlap, zero):
        model, state, step, data, put, _ = build_train_setup(
            cfg, global_batch=8, seq_len=16, opt_cfg=OptimizerConfig(),
            steps_per_epoch=5, mesh=mesh, dp_mode='shardmap', seed=0,
            compression=WIRE + '+bucketed', bucket_bytes=BB,
            error_feedback=EF, overlap_comm=overlap, zero_dp=zero)
        for s in range(3):
            batch = put({k: jnp.asarray(v)
                         for k, v in data.batch_at(s).items()})
            state, metrics = step(state, batch)
        return model, state, metrics

    def to_shard_layout(tree, plan):
        flat = np.concatenate([np.asarray(l).reshape(-1)
                               for l in plan.treedef.flatten_up_to(tree)])
        flat = np.concatenate([flat,
                               np.zeros(plan.pad_elems, flat.dtype)])
        return stream_to_shard_layout(flat, plan, N)

    def check(name, ref, zro, plan, to_plan_tree):
        s0, m0 = ref
        s1, m1 = zro
        assert float(m0['loss']) == float(m1['loss']), name
        keys = ['params', 'model_state'] + (['ef_residual'] if EF else [])
        for key in keys:
            for a, b in zip(jax.tree.leaves(s0[key]),
                            jax.tree.leaves(s1[key])):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=name + ':' + key)
        if EF:
            nz = max(float(jnp.abs(x).max())
                     for x in jax.tree.leaves(s1['ef_residual']))
            assert nz > 0, name  # EF genuinely active
        assert int(s1['opt']['step']) == int(s0['opt']['step']) == 3
        # opt state: tree layout -> the zero run's shard layout, bitwise
        for f in ('delta', 'm'):
            want = to_shard_layout(to_plan_tree(s0['opt'][f]), plan)
            np.testing.assert_array_equal(
                want, np.asarray(s1['opt'][f]),
                err_msg=name + ':opt.' + f)

    # ---- plain bucketed vs zero ----
    model, sb, mb = run(overlap=False, zero=False)
    _, sz, mz = run(overlap=False, zero=True)
    plan_p = plan_buckets(sb['params'], BB, WIRE, align=N)
    check('plain', (sb, mb), (sz, mz), plan_p, lambda t: t)

    # ---- overlap vs zero-overlap ----
    model, so, mo = run(overlap=True, zero=False)
    _, szo, mzo = run(overlap=True, zero=True)
    mstate0 = jax.tree.map(lambda x: x[0], so['model_state'])
    dummy = {'images': jnp.zeros((8, 32, 32, 3)),
             'labels': jnp.zeros((8,), jnp.int32)}
    staged = model.loss_segments(so['params'], mstate0, dummy, 0.0)

    def split_rev(tree):
        return tuple(reversed(staged.split_tree(tree)))

    plan_o = plan_ready_buckets(list(split_rev(so['params'])), BB, WIRE,
                                align=N).base
    check('overlap', (so, mo), (szo, mzo), plan_o, split_rev)
    print('ZERO_PARITY_OK')
"""


@pytest.mark.slow
@pytest.mark.parametrize("ef", [False, True])
@pytest.mark.parametrize("wire", ["bf16", "f16"])
def test_zero_bitwise_parity_matrix_8dev(ef, wire):
    """Acceptance: --zero end state (params, opt incl. the shard-layout
    delta/m, BN stats, EF residuals) bitwise-equals the all-reduce
    bucketed path after 3 steps on the 8-virtual-device mesh — for both
    the plain bucketed and the backward-overlapped variant."""
    body = (textwrap.dedent(_PARITY_HEADER).format(ef=ef, wire=wire)
            + textwrap.dedent(_PARITY_BODY))
    out = run_py(body)
    assert "ZERO_PARITY_OK" in out


@pytest.mark.slow
def test_zero_bitwise_parity_two_dp_axes_8dev():
    """The dryrun conv cell runs pure DP over BOTH mesh axes: the zero
    step's row-major rank linearization (`_dp_linear_index`) must match
    psum_scatter/all_gather's group order over an axis tuple, or every
    worker updates the wrong shard. Verified by bitwise parity vs the
    all-reduce path on a (4, 2) mesh with dp_axes=('data', 'model')."""
    out = run_py(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs import (OptimizerConfig, ParallelConfig,
                                   TrainConfig, get_config,
                                   reduced_config)
        from repro.distributed.bucketing import (plan_buckets,
                                                 stream_to_shard_layout)
        from repro.models import build_model, init_model_state
        from repro.optim import make_optimizer
        from repro.optim.stream import (make_stream_optimizer,
                                        zero_padded_total)
        from repro.training.step import (make_dp_shardmap_train_step,
                                         replicate_model_state)
        cfg = reduced_config(get_config('resnet50'))
        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        DP = ('data', 'model')
        N, BB = 8, 8192
        opt_cfg = OptimizerConfig()
        model = build_model(cfg, compute_dtype=jnp.float32)
        rng = np.random.default_rng(0)
        batches = [
            {'images': jnp.asarray(rng.standard_normal((16, 32, 32, 3)),
                                   jnp.float32),
             'labels': jnp.asarray(rng.integers(0, cfg.num_classes, 16))}
            for _ in range(2)]
        bshard = NamedSharding(mesh, P(DP))

        def run(zero):
            parallel = ParallelConfig(
                dp_axes=DP, tp_axis=None, zero_1=False,
                compression='bf16+bucketed', bucket_bytes=BB,
                zero_dp=zero)
            tcfg = TrainConfig(optimizer=opt_cfg, parallel=parallel)
            params, _ = model.init_params(jax.random.PRNGKey(0))
            mstate = replicate_model_state(init_model_state(model), N)
            if zero:
                opt = make_stream_optimizer(opt_cfg, 5, 16)
                ostate = opt.init(zero_padded_total(
                    params, 'bf16+bucketed', BB, N))
            else:
                opt = make_optimizer(opt_cfg, 5, 16)
                ostate = opt.init(params)
            state = {'params': params, 'opt': ostate,
                     'model_state': mstate}
            step = jax.jit(make_dp_shardmap_train_step(
                model, opt, tcfg, mesh, DP))
            for b in batches:
                state, metrics = step(
                    state, {k: jax.device_put(v, bshard)
                            for k, v in b.items()})
            return state, metrics

        s0, m0 = run(False)
        s1, m1 = run(True)
        assert float(m0['loss']) == float(m1['loss'])
        for key in ('params', 'model_state'):
            for a, b in zip(jax.tree.leaves(s0[key]),
                            jax.tree.leaves(s1[key])):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b),
                                              err_msg=key)
        plan = plan_buckets(s0['params'], BB, 'bf16', align=N)
        for f in ('delta', 'm'):
            flat = np.concatenate(
                [np.asarray(l).reshape(-1)
                 for l in plan.treedef.flatten_up_to(s0['opt'][f])])
            flat = np.concatenate(
                [flat, np.zeros(plan.pad_elems, flat.dtype)])
            np.testing.assert_array_equal(
                stream_to_shard_layout(flat, plan, N),
                np.asarray(s1['opt'][f]), err_msg=f)
        print('TWO_AXIS_OK')
    """))
    assert "TWO_AXIS_OK" in out


# ---------------------------------------------------------------------------
# checkpoint round-trip across the zero/non-zero boundary (slow)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_zero_checkpoint_crosses_layout_boundary_8dev(tmp_path):
    out = run_py(textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np, tempfile, os
        from repro.checkpoint.checkpointer import restore, save
        from repro.configs import (OptimizerConfig, get_config,
                                   reduced_config)
        from repro.distributed.bucketing import plan_buckets
        from repro.launch.train import build_train_setup
        from repro.optim.stream import (make_zero_restore_transform,
                                        param_key_tree)
        cfg = reduced_config(get_config('resnet50'))
        mesh = jax.make_mesh((jax.device_count(), 1), ('data', 'model'))
        N = jax.device_count()
        BB = 8192

        def run(zero):
            model, state, step, data, put, _ = build_train_setup(
                cfg, global_batch=8, seq_len=16,
                opt_cfg=OptimizerConfig(), steps_per_epoch=5, mesh=mesh,
                dp_mode='shardmap', seed=0,
                compression='bf16+bucketed', bucket_bytes=BB,
                zero_dp=zero)
            for s in range(2):
                batch = put({k: jnp.asarray(v)
                             for k, v in data.batch_at(s).items()})
                state, _ = step(state, batch)
            return state, step, data, put

        state_b, step_b, data, put = run(zero=False)
        state_z, step_z, _, _ = run(zero=True)
        plan = plan_buckets(state_b['params'], BB, 'bf16', align=N)
        key_tree = param_key_tree(state_b['params'])
        root = tempfile.mkdtemp()
        dir_b, dir_z = os.path.join(root, 'b'), os.path.join(root, 'z')
        save(dir_b, 2, state_b, metadata={'opt_layout': 'tree'})
        save(dir_z, 2, state_z, metadata={'opt_layout': 'zero_stream'})

        def assert_equal(t1, t2, what):
            for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
                np.testing.assert_array_equal(np.asarray(a),
                                              np.asarray(b),
                                              err_msg=what)

        # zero checkpoint -> tree-layout run
        to_tree = make_zero_restore_transform(plan, key_tree, N,
                                              to_zero=False)
        restored_b, _ = restore(dir_z, target=state_b,
                                transform=to_tree)
        assert_equal(restored_b, state_b, 'zero->tree')
        # tree checkpoint -> zero run, then keep training: one more step
        # from either restore path stays bitwise-identical
        to_zero = make_zero_restore_transform(plan, key_tree, N,
                                              to_zero=True)
        restored_z, _ = restore(dir_b, target=state_z,
                                transform=to_zero)
        assert_equal(restored_z, state_z, 'tree->zero')
        batch = put({k: jnp.asarray(v)
                     for k, v in data.batch_at(2).items()})
        cont_b, _ = step_b(state_b, dict(batch))
        cont_z, _ = step_z(restored_z, dict(batch))
        assert_equal(cont_b['params'], cont_z['params'],
                     'continued params')
        print('ZERO_CKPT_OK')
    """))
    assert "ZERO_CKPT_OK" in out


# ---------------------------------------------------------------------------
# HLO: the full-gradient all-reduce is gone; scatter+gather interleave
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_zero_hlo_reduce_scatter_no_allreduce():
    """comm_report must classify the zero step as
    reduce_scatter+all_gather (every surviving all-reduce is
    metric-sized) and the bucketed step as all_reduce; the zero-overlap
    step's scatters must interleave with backward conv/dot compute."""
    out = run_py(textwrap.dedent("""
        import jax, jax.numpy as jnp
        from repro.configs import (OptimizerConfig, get_config,
                                   reduced_config)
        from repro.launch.hlo_analysis import analyze_hlo, comm_report
        from repro.launch.train import build_train_setup
        cfg = reduced_config(get_config('resnet50'))
        mesh = jax.make_mesh((jax.device_count(), 1), ('data', 'model'))
        reports = {}
        for name, kw in (('bucketed', {}),
                         ('zero', dict(zero_dp=True)),
                         ('zero_overlap', dict(zero_dp=True,
                                               overlap_comm=True))):
            model, state, step, data, put, _ = build_train_setup(
                cfg, global_batch=8, seq_len=16,
                opt_cfg=OptimizerConfig(), steps_per_epoch=5, mesh=mesh,
                dp_mode='shardmap', seed=0,
                compression='bf16+bucketed', bucket_bytes=8192, **kw)
            batch = put({k: jnp.asarray(v)
                         for k, v in data.batch_at(0).items()})
            txt = step.lower(state, batch).compile().as_text()
            reports[name] = comm_report(
                analyze_hlo(txt, jax.device_count()), hlo_text=txt)
        b = reports['bucketed']
        assert b['gradient_sync'] == 'all_reduce', b['gradient_sync']
        assert 'reduce-scatter' not in b['per_op']
        for name in ('zero', 'zero_overlap'):
            r = reports[name]
            assert r['gradient_sync'] == 'reduce_scatter+all_gather', (
                name, r['gradient_sync'])
            assert r['per_op']['reduce-scatter'][
                'executions_per_step'] >= 2, name
            assert r['per_op']['all-gather'][
                'executions_per_step'] >= 2, name
            ar = r['per_op'].get('all-reduce')
            assert ar is None or \\
                ar['max_bytes_per_collective'] < 1024, (name, ar)
        assert not reports['zero']['interleave']['interleaved']
        assert reports['zero_overlap']['interleave']['interleaved'], \\
            reports['zero_overlap']['interleave']
        print('ZERO_HLO_OK')
    """), env=ENV2)
    assert "ZERO_HLO_OK" in out
