"""End-to-end behaviour of the paper's system at reduced scale.

The paper's claim chain: extreme minibatch + (RMSprop warm-up, slow-start,
BN w/o moving averages, compressed all-reduce) => stable training with
accuracy comparable to small-batch baselines. These tests reproduce the
claim *directionally* on a synthetic classification task (no ImageNet in
this container — see EXPERIMENTS.md §Paper-claims).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import OptimizerConfig, get_config, reduced_config
from repro.launch.train import build_train_setup


def _train(optimizer_kind, schedule, steps, global_batch,
           steps_per_epoch, seed=0, lr_scale=1.0):
    cfg = reduced_config(get_config("resnet50"))
    opt_cfg = OptimizerConfig(kind=optimizer_kind, schedule=schedule,
                              base_lr_per_256=0.1 * lr_scale,
                              beta_center=1.0, beta_period=1.0)
    model, state, step_fn, data, _, _ = build_train_setup(
        cfg, global_batch=global_batch, seq_len=16, opt_cfg=opt_cfg,
        steps_per_epoch=steps_per_epoch, seed=seed)
    losses = []
    for s in range(steps):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(s).items()}
        state, metrics = step_fn(state, batch)
        losses.append(float(metrics["loss"]))
    return losses


def test_large_batch_rmsprop_warmup_stable():
    """At a 16x-scaled batch (linear-scaled LR), the paper's recipe must
    train stably and reach a low loss."""
    losses = _train("rmsprop_warmup", "slow_start", steps=40,
                    global_batch=128, steps_per_epoch=10)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < 0.6 * np.mean(losses[:3])


def test_rmsprop_warmup_beats_pure_sgd_at_extreme_lr():
    """The warm-up's raison d'etre: at aggressive linear-scaled LRs,
    momentum SGD destabilizes early while the hybrid stays finite/lower
    (paper: 'optimization difficulty at the start of training')."""
    sgd = _train("momentum_sgd", "constant", steps=25, global_batch=128,
                 steps_per_epoch=10, lr_scale=24.0)
    hyb = _train("rmsprop_warmup", "constant", steps=25, global_batch=128,
                 steps_per_epoch=10, lr_scale=24.0)
    hyb_ok = np.isfinite(hyb).all()
    assert hyb_ok
    sgd_bad = (not np.isfinite(sgd).all()) or np.mean(sgd[-5:]) > 1.5
    assert sgd_bad or np.mean(hyb[-5:]) < np.mean(
        [l for l in sgd[-5:] if np.isfinite(l)] or [np.inf])


def test_eval_uses_finalized_bn_stats():
    """Validation path consumes the last-minibatch BN stats (paper §2)."""
    cfg = reduced_config(get_config("resnet50"))
    from repro.models import build_model, init_model_state
    model = build_model(cfg, compute_dtype=jnp.float32)
    params, _ = model.init_params(jax.random.PRNGKey(0))
    state = init_model_state(model)
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 32, 32, 3)) * 2 + 3
    _, state_after = model.apply(params, state, x, train=True)
    l_fresh, _ = model.apply(params, state, x, train=False)
    l_fit, _ = model.apply(params, state_after, x, train=False)
    assert not np.allclose(np.asarray(l_fresh), np.asarray(l_fit))
    assert bool(jnp.isfinite(l_fit).all())
