"""Per-architecture smoke tests: REDUCED config of the same family, one
forward + one full train step on CPU; asserts output shapes and no NaNs.
(The FULL configs are exercised only by the dry-run.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import (
    ASSIGNED_ARCHS,
    OptimizerConfig,
    get_config,
    reduced_config,
)
from repro.data import make_data
from repro.launch.train import build_train_setup
from repro.models import build_model, init_model_state
from repro.models.common import count_params

ALL_ARCHS = list(ASSIGNED_ARCHS) + ["resnet50"]


def _batch_for(cfg, b=2, s=16, seed=0):
    rng = np.random.RandomState(seed)
    if cfg.family == "conv":
        return {
            "images": jnp.asarray(
                rng.randn(b, cfg.image_size, cfg.image_size, 3), jnp.float32),
            "labels": jnp.asarray(rng.randint(0, cfg.num_classes, b)),
        }
    batch = {
        "tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s))),
        "targets": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s))),
    }
    if cfg.vision is not None:
        batch["patches"] = jnp.asarray(
            rng.randn(b, cfg.vision.num_patches, cfg.vision.patch_dim),
            jnp.float32)
    if cfg.audio is not None:
        batch["frames"] = jnp.asarray(
            rng.randn(b, cfg.audio.num_frames, cfg.audio.frame_dim),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_forward_and_loss(arch, key):
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg, compute_dtype=jnp.float32,
                        attention_impl="naive")
    params, axes = model.init_params(key)
    assert count_params(params) > 0
    state = init_model_state(model)
    batch = _batch_for(cfg)
    loss, (new_state, metrics) = model.loss_fn(params, state, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    if cfg.family != "conv":
        logits, _, _ = (model.forward(params, batch["tokens"])
                        if cfg.family in ("dense", "moe")
                        else (None, None, None))
        if logits is not None:
            assert logits.shape == (2, 16, cfg.vocab_size)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_one_train_step(arch):
    cfg = reduced_config(get_config(arch))
    opt_cfg = OptimizerConfig(kind="rmsprop_warmup")
    model, state, train_step, data, _, _ = build_train_setup(
        cfg, global_batch=4, seq_len=16, opt_cfg=opt_cfg,
        steps_per_epoch=10)
    batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
    before = jax.tree.leaves(state["params"])[0].copy()
    new_state, metrics = train_step(state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    after = jax.tree.leaves(new_state["params"])[0]
    assert not np.allclose(before, after), f"{arch}: params did not move"
    assert int(new_state["opt"]["step"]) == 1


def test_train_loss_decreases_resnet():
    """End-to-end learnability: the paper's arch on the synthetic task."""
    cfg = reduced_config(get_config("resnet50"))
    opt_cfg = OptimizerConfig(kind="rmsprop_warmup")
    model, state, train_step, data, _, _ = build_train_setup(
        cfg, global_batch=16, seq_len=16, opt_cfg=opt_cfg,
        steps_per_epoch=5)
    losses = []
    for step in range(30):
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(step).items()}
        state, metrics = train_step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < 0.5 * np.mean(losses[:5])
