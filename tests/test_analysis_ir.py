"""Typed HLO IR unit tests (repro.analysis.hlo_ir, DESIGN.md §12).

Covers the type-table hardening (pred / s8 / u8 / f8 family / scalar
``[]`` / sub-byte packing — the seed table silently sized these as 0
bytes), the parse -> render -> parse roundtrip, and module-level facts
(entry selection, ``input_output_alias``, entry params, trip-count
multipliers) on a synthetic module written in XLA's emitted grammar.
"""
import pytest

from repro.analysis.hlo_ir import (
    AliasEntry,
    DTYPE_BYTES,
    Op,
    compute_multipliers,
    op_consumers,
    parse_computations,
    parse_input_output_alias,
    parse_module,
    parse_op_line,
    render_op,
    type_bytes,
    type_shape,
)

# ---------------------------------------------------------------------------
# type table
# ---------------------------------------------------------------------------


def test_type_bytes_seed_cases_unchanged():
    # the three shapes the seed-era tests pinned — must keep holding
    assert type_bytes("f32[4,8]{1,0}") == 128
    assert type_bytes("bf16[10]") == 20
    assert type_bytes("(f32[2,2]{1,0}, s32[])") == 20


def test_type_bytes_hardened_dtypes():
    assert type_bytes("pred[8]") == 8
    assert type_bytes("s8[4]") == 4
    assert type_bytes("u8[16]{0}") == 16
    assert type_bytes("f8e4m3[8]") == 8
    assert type_bytes("f8e4m3fn[8]") == 8
    assert type_bytes("f8e5m2[16]") == 16
    assert type_bytes("f16[3]") == 6


def test_type_bytes_scalar_and_subbyte():
    assert type_bytes("f32[]") == 4
    assert type_bytes("pred[]") == 1
    assert type_bytes("s4[8]") == 4.0  # packed two per byte
    assert type_bytes("u4[2]") == 1.0
    assert type_bytes("s2[8]") == 2.0


def test_type_bytes_zero_size_types():
    assert type_bytes("token[]") == 0
    assert type_bytes("(f32[4], token[])") == 16


def test_type_bytes_strict_raises_on_unknown_dtype():
    with pytest.raises(ValueError, match="unknown HLO dtype"):
        type_bytes("f6e3m2[8]", strict=True)
    # non-strict keeps the lenient seed behaviour: skip, don't crash
    assert type_bytes("f6e3m2[8]") == 0


def test_dtype_table_covers_f8_family():
    for dt in ("f8e4m3", "f8e4m3fn", "f8e4m3fnuz", "f8e5m2",
               "f8e5m2fnuz", "f8e3m4"):
        assert DTYPE_BYTES[dt] == 1, dt


def test_type_shape():
    assert type_shape("f32[4,8]{1,0}") == ("f32", (4, 8))
    assert type_shape("pred[]") == ("pred", ())
    assert type_shape("(s32[], f32[128])") == ("s32", ())
    assert type_shape("no-type-here") == ("", ())


# ---------------------------------------------------------------------------
# op parse / render roundtrip
# ---------------------------------------------------------------------------

OP_LINES = [
    "  %p0 = f32[128]{0} parameter(0), sharding={replicated}",
    "  ROOT %sum = f32[] add(%a, %b)",
    "  %t = (s32[], f32[128]) tuple(%i.2, %x.2)",
    ("  %ar = f32[4096]{0} all-reduce(%g), "
     "replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add.1"),
    ("  %w = (s32[], f32[128]) while(%init), condition=%cond.2, "
     "body=%body.3"),
    ("  %d = f32[64,64]{1,0} dot(%a, %b), lhs_contracting_dims={1}, "
     "rhs_contracting_dims={0}"),
    "  %c = s32[] constant(42)",
    ("  %f = f32[16]{0} fusion(%x, %y), kind=kLoop, "
     "calls=%fused_computation.1"),
    "  %pred.1 = pred[] compare(%i, %n), direction=LT",
]


@pytest.mark.parametrize("line", OP_LINES)
def test_parse_render_parse_is_identity(line):
    op = parse_op_line(line)
    assert op is not None, line
    op2 = parse_op_line(render_op(op))
    assert op2 == op


def test_parse_op_line_fields():
    op = parse_op_line(OP_LINES[3])
    assert op.name == "ar"
    assert op.opcode == "all-reduce"
    assert op.result == "f32[4096]{0}"
    assert op.operands == ["g"]
    assert op.args_raw == "%g"
    assert op.suffix.startswith(", replica_groups=")
    assert "to_apply=%add.1" in op.suffix
    assert not op.root


def test_parse_op_line_root_and_tuple_result():
    op = parse_op_line(OP_LINES[1])
    assert op.root and op.opcode == "add" and op.operands == ["a", "b"]
    op = parse_op_line(OP_LINES[2])
    assert op.result == "(s32[], f32[128])"
    assert op.operands == ["i.2", "x.2"]


def test_parse_op_line_rejects_non_ops():
    assert parse_op_line("}") is None
    assert parse_op_line("ENTRY %main (p: f32[4]) -> f32[4] {") is None
    assert parse_op_line("") is None


def test_render_op_canonical_text():
    op = Op(name="x", opcode="add", result="f32[4]",
            operands=["a", "b"], attrs="%a, %b)", root=True,
            args_raw="%a, %b", suffix="")
    assert render_op(op) == "  ROOT %x = f32[4] add(%a, %b)"


# ---------------------------------------------------------------------------
# module-level facts on a synthetic module
# ---------------------------------------------------------------------------

MODULE = """\
HloModule jit_step, input_output_alias={ {0}: (0, {}, may-alias), \
{1}: (1, {}, must-alias) }, entry_computation_layout=whatever

%add.1 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %sum = f32[] add(%a, %b)
}

%cond.2 (s: (s32[], f32[128])) -> pred[] {
  %s = (s32[], f32[128]) parameter(0)
  %i = s32[] get-tuple-element(%s), index=0
  %n = s32[] constant(4)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body.3 (s: (s32[], f32[128])) -> (s32[], f32[128]) {
  %s.1 = (s32[], f32[128]) parameter(0)
  %i.1 = s32[] get-tuple-element(%s.1), index=0
  %x = f32[128]{0} get-tuple-element(%s.1), index=1
  %one = s32[] constant(1)
  %i.2 = s32[] add(%i.1, %one)
  %x.2 = f32[128]{0} all-reduce(%x), \
replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%add.1
  ROOT %t = (s32[], f32[128]) tuple(%i.2, %x.2)
}

ENTRY %main.4 (p0: f32[128], p1: f32[4096], p2: f32[16]) -> \
(f32[128], f32[4096]) {
  %p0 = f32[128]{0} parameter(0), sharding={replicated}
  %p1 = f32[4096]{0} parameter(1)
  %p2 = f32[16]{0} parameter(2)
  %zero = s32[] constant(0)
  %init = (s32[], f32[128]) tuple(%zero, %p0)
  %w = (s32[], f32[128]) while(%init), condition=%cond.2, body=%body.3
  %x.3 = f32[128]{0} get-tuple-element(%w), index=1
  %p1.2 = f32[4096]{0} add(%p1, %p1)
  ROOT %out = (f32[128], f32[4096]) tuple(%x.3, %p1.2)
}
"""


def test_parse_computations_entry_alias():
    comps = parse_computations(MODULE)
    assert set(comps) == {"add.1", "cond.2", "body.3", "main.4",
                          "__entry__"}
    assert comps["__entry__"] is comps["main.4"]
    assert [o.opcode for o in comps["add.1"]] == \
        ["parameter", "parameter", "add"]


def test_parse_module_entry_and_alias():
    mod = parse_module(MODULE)
    assert mod.entry_name == "main.4"
    assert "__entry__" not in mod.computations
    assert mod.input_output_alias == [
        AliasEntry(output_index=(0,), param_number=0, param_index=(),
                   kind="may-alias"),
        AliasEntry(output_index=(1,), param_number=1, param_index=(),
                   kind="must-alias"),
    ]
    assert mod.entry_ops[-1].root


def test_parse_module_no_computations_raises():
    with pytest.raises(ValueError, match="no computations"):
        parse_module("")


def test_parse_input_output_alias_absent():
    assert parse_input_output_alias("HloModule bare\n") == []


def test_entry_params_sorted_by_number():
    mod = parse_module(MODULE)
    params = mod.entry_params()
    assert [n for n, _ in params] == [0, 1, 2]
    assert [op.result for _, op in params] == \
        ["f32[128]{0}", "f32[4096]{0}", "f32[16]{0}"]


def test_op_consumers():
    mod = parse_module(MODULE)
    users = op_consumers(mod.entry_ops)
    assert [u.opcode for u in users["init"]] == ["while"]
    assert [u.name for u in users["p1"]] == ["p1.2", "p1.2"]
    assert "out" not in users  # root has no consumers


def test_trip_count_multipliers():
    mod = parse_module(MODULE)
    mult = mod.multipliers
    assert mult["main.4"] == 1.0
    assert mult["body.3"] == 4.0          # trip count from constant(4)
    assert mult["cond.2"] == 5.0          # trips + 1
    assert mult["add.1"] == 4.0           # to_apply from the loop body
    assert mod.trip_counts == {"body.3": 4}


def test_compute_multipliers_fallback_last_computation():
    # no ENTRY marker: the last computation is treated as entry
    text = MODULE.replace("ENTRY %main.4", "%main.4")
    comps = parse_computations(text)
    assert "__entry__" not in comps
    mult, _ = compute_multipliers(comps)
    assert mult["main.4"] == 1.0
    assert mult["body.3"] == 4.0
