"""Serving correctness: prefill+decode_step logits must match the full
(teacher-forced) forward pass at every position, per cached family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import build_model

# mixtral excluded here: capacity-based MoE dropping depends on grouping,
# so prefill/decode can differ by design; covered in test_moe.py instead.
FAMILIES = ["llama3.2-1b", "qwen2-72b", "zamba2-7b", "xlstm-350m",
            "whisper-tiny"]


@pytest.mark.parametrize("arch", FAMILIES)
def test_decode_matches_full_forward(arch, key):
    cfg = reduced_config(get_config(arch))
    model = build_model(cfg, compute_dtype=jnp.float32,
                        attention_impl="naive", remat=False)
    params, _ = model.init_params(key)
    b, prompt, total = 2, 8, 14
    rng = np.random.RandomState(0)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (b, total)))
    kw = {}
    if cfg.audio is not None:
        kw["frames"] = jnp.asarray(
            rng.randn(b, cfg.audio.num_frames, cfg.audio.frame_dim),
            jnp.float32)

    # reference: full forward (teacher forcing)
    full_logits, _, _ = model.forward(params, toks, mode="train", **kw) \
        if cfg.family != "audio" else model.forward(
            params, toks, frames=kw["frames"], mode="train")

    # prefill on the prompt, then decode the rest token by token
    cache, _ = model.cache_shape(b, total, jnp.float32)
    last, cache = model.prefill(params, toks[:, :prompt], cache, **kw)
    np.testing.assert_allclose(
        np.asarray(last[:, 0]), np.asarray(full_logits[:, prompt - 1]),
        rtol=5e-4, atol=5e-4)
    for t in range(prompt, total):
        logits, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                          jnp.int32(t))
        if t + 1 < total:
            np.testing.assert_allclose(
                np.asarray(logits[:, 0]), np.asarray(full_logits[:, t]),
                rtol=5e-4, atol=5e-4,
                err_msg=f"{arch}: decode mismatch at position {t}")


def test_sliding_window_ring_decode(key, monkeypatch):
    """Mixtral-style SWA ring cache: decode must match full forward with
    window masking even past the window size."""
    from repro.models import layers
    # capacity drops depend on token grouping; disable them so the
    # prefill and decode paths route identically
    monkeypatch.setattr(layers, "CAPACITY_FACTOR", 1000.0)
    cfg = reduced_config(get_config("mixtral-8x7b"))
    model = build_model(cfg, compute_dtype=jnp.float32,
                        attention_impl="naive", remat=False)
    params, _ = model.init_params(key)
    b = 1
    total = cfg.sliding_window + 24  # exceed the window (ring wraps)
    rng = np.random.RandomState(1)
    toks = jnp.asarray(rng.randint(0, cfg.vocab_size, (b, total)))
    full_logits, _, _ = model.forward(params, toks, mode="train")
    cache, _ = model.cache_shape(b, total, jnp.float32)
    prompt = 4
    _, cache = model.prefill(params, toks[:, :prompt], cache)
    for t in range(prompt, total):
        logits, cache = model.decode_step(params, cache, toks[:, t:t + 1],
                                          jnp.int32(t))
        if t + 1 < total:
            np.testing.assert_allclose(
                np.asarray(logits[:, 0]), np.asarray(full_logits[:, t]),
                rtol=2e-3, atol=2e-3,
                err_msg=f"ring decode mismatch at {t}")
