"""Checkpointer: atomicity, async, corruption tolerance, restore."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import AsyncCheckpointer, list_checkpoints, restore, save


def _state(key, scale=1.0):
    ks = jax.random.split(key, 2)
    return {
        "params": {"w": scale * jax.random.normal(ks[0], (8, 4)),
                   "b": jnp.zeros((4,))},
        "opt": {"step": jnp.int32(3),
                "delta": {"w": scale * jax.random.normal(ks[1], (8, 4)),
                          "b": jnp.zeros((4,))}},
    }


def test_save_restore_roundtrip(tmp_path, key):
    state = _state(key)
    save(str(tmp_path), 7, state, metadata={"arch": "x"})
    got, manifest = restore(str(tmp_path), target=jax.tree.map(
        lambda x: jnp.zeros_like(x), state))
    assert manifest["step"] == 7 and manifest["metadata"]["arch"] == "x"
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_corrupt_checkpoint_skipped(tmp_path, key):
    state = _state(key)
    save(str(tmp_path), 1, state)
    save(str(tmp_path), 2, state)
    # corrupt the newest manifest (simulates crash mid-save)
    with open(tmp_path / "step_0000000002" / "manifest.json", "w") as f:
        f.write("{truncated")
    assert list_checkpoints(str(tmp_path)) == [1]
    got, manifest = restore(str(tmp_path), target=state)
    assert manifest["step"] == 1


def test_restore_shape_mismatch_raises(tmp_path, key):
    save(str(tmp_path), 1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError, match="shape mismatch"):
        restore(str(tmp_path), target={"w": jnp.zeros((5,))})


def test_async_checkpointer_gc_and_wait(tmp_path, key):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    state = _state(key)
    for step in (10, 20, 30):
        ck.save(step, state)
    ck.wait()
    assert list_checkpoints(str(tmp_path)) == [20, 30]


def test_restore_strict_shardings_tree(tmp_path, key):
    """Regression: a shardings tree with fewer leaves than the target
    used to be zip-truncated, silently device_putting the tail of the
    state unsharded. It must error instead."""
    import pytest
    from jax.sharding import NamedSharding, PartitionSpec as P
    state = {"a": jnp.zeros((3,)), "b": jnp.zeros((3,))}
    save(str(tmp_path), 1, state)
    mesh = jax.make_mesh((1,), ("data",))
    short = {"a": NamedSharding(mesh, P())}  # missing "b"
    with pytest.raises(ValueError, match="shardings tree"):
        restore(str(tmp_path), target=state, shardings=short)
    # congruent shardings still restore fine
    full = {"a": NamedSharding(mesh, P()), "b": NamedSharding(mesh, P())}
    got, _ = restore(str(tmp_path), target=state, shardings=full)
    assert jax.tree.leaves(got)[0].sharding == full["a"]


def test_save_best_single_retained(tmp_path, key):
    from repro.checkpoint import restore_best, save_best
    state = _state(key)
    save_best(str(tmp_path), 5, state, metadata={"top1": 0.4})
    save_best(str(tmp_path), 9, _state(key, scale=2.0),
              metadata={"top1": 0.7})
    got, manifest = restore_best(str(tmp_path), target=state)
    assert manifest["step"] == 9
    assert manifest["metadata"]["top1"] == 0.7
    assert list_checkpoints(str(tmp_path / "best")) == [9]
    # best lives outside the rotating window: untouched by main-dir GC
    ck = AsyncCheckpointer(str(tmp_path), keep=1)
    for step in (10, 20):
        ck.save(step, state)
    ck.wait()
    assert list_checkpoints(str(tmp_path)) == [20]
    assert list_checkpoints(str(tmp_path / "best")) == [9]


def test_async_snapshot_isolated_from_donation(tmp_path, key):
    """The snapshot must capture values at call time even if the caller
    mutates/replaces buffers right after (donation semantics)."""
    ck = AsyncCheckpointer(str(tmp_path), keep=1)
    state = {"w": jnp.ones((4,))}
    ck.save(1, state)
    state = {"w": jnp.zeros((4,))}  # overwritten immediately
    ck.wait()
    got, _ = restore(str(tmp_path), target=state)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.ones(4))
