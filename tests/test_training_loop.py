"""Fault tolerance at the loop level: resume, determinism, stragglers,
prefetcher failure propagation, checkpoint-save dedup."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import OptimizerConfig, get_config, reduced_config
from repro.launch.train import build_train_setup
from repro.training import LoopConfig, run_training


def _setup(steps_per_epoch=5, seed=0):
    cfg = reduced_config(get_config("resnet50"))
    opt_cfg = OptimizerConfig(kind="rmsprop_warmup")
    return build_train_setup(cfg, global_batch=8, seq_len=16,
                             opt_cfg=opt_cfg,
                             steps_per_epoch=steps_per_epoch, seed=seed)


def test_checkpoint_restart_bitwise_continuation(tmp_path):
    """Crash after step 10, restart => identical final state as an
    uninterrupted 20-step run (determinism contract of DESIGN.md §5)."""
    ckpt = str(tmp_path / "ck")

    # uninterrupted reference run
    model, state, step_fn, data, _, _ = _setup()
    ref = run_training(step_fn, state, data,
                       LoopConfig(total_steps=20, checkpoint_dir=None))

    # interrupted run: 10 steps (checkpointing), then a fresh process-like
    # resume for the remaining 10
    model, state, step_fn, data, _, _ = _setup()
    run_training(step_fn, state, data,
                 LoopConfig(total_steps=10, checkpoint_every=5,
                            checkpoint_dir=ckpt))
    model, state2, step_fn2, data2, _, _ = _setup()  # fresh init
    res = run_training(step_fn2, state2, data2,
                       LoopConfig(total_steps=20, checkpoint_every=100,
                                  checkpoint_dir=ckpt))
    assert res.resumed_from == 10
    for a, b in zip(jax.tree.leaves(ref.state["params"]),
                    jax.tree.leaves(res.state["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-5, atol=1e-6)


def test_straggler_event_detection(tmp_path):
    model, state, step_fn, data, _, _ = _setup()

    class SlowData:
        def __init__(self, inner):
            self.inner = inner

        def batch_at(self, step):
            if step == 15:
                time.sleep(1.0)  # simulated straggling host
            return self.inner.batch_at(step)

    res = run_training(step_fn, state, SlowData(data),
                       LoopConfig(total_steps=20, deadline_factor=3.0))
    assert any(e["step"] == 15 for e in res.straggler_events)


def test_prefetcher_propagates_worker_error():
    """Regression: a raising batch_at used to kill the daemon silently,
    leaving the consumer blocked forever on Queue.get()."""
    from repro.data import Prefetcher

    class Bad:
        def batch_at(self, step):
            if step >= 3:
                raise ValueError("boom at step 3")
            return {"x": np.zeros(2, np.float32)}

    p = Prefetcher(Bad())
    try:
        with pytest.raises(ValueError, match="boom at step 3"):
            for _ in range(10):
                next(p)
    finally:
        p.close()


def test_prefetcher_transform_error_propagates():
    from repro.data import Prefetcher

    class Ok:
        def batch_at(self, step):
            return {"x": np.zeros(2, np.float32)}

    def bad_transform(batch):
        raise RuntimeError("device_put failed")

    p = Prefetcher(Ok(), transform=bad_transform)
    try:
        with pytest.raises(RuntimeError, match="device_put failed"):
            next(p)
    finally:
        p.close()


def test_prefetcher_close_unblocks_pending_next():
    """Regression: close() must not race a consumer parked in next()."""
    from repro.data import Prefetcher

    class Slow:
        def batch_at(self, step):
            time.sleep(30.0)  # never yields a batch in test time
            return {}

    p = Prefetcher(Slow())
    got = {}

    def consume():
        try:
            next(p)
            got["out"] = "batch"
        except StopIteration:
            got["out"] = "stopped"

    t = threading.Thread(target=consume)
    t.start()
    time.sleep(0.2)  # consumer is now blocked waiting for a batch
    p.close()
    t.join(timeout=5)
    assert not t.is_alive()
    assert got["out"] == "stopped"


def test_no_duplicate_final_checkpoint_save(tmp_path, monkeypatch):
    """Regression: when total_steps %% checkpoint_every == 0 the final
    step was saved async then immediately re-saved blocking (rmtree-ing
    the fresh directory). Each step must be serialized exactly once —
    counted at _write_checkpoint, the choke point both the sync save()
    and the AsyncCheckpointer worker funnel through."""
    import repro.checkpoint.checkpointer as cp
    saved = []
    real_write = cp._write_checkpoint

    def counting_write(directory, step, arrays, metadata=None):
        saved.append(step)
        return real_write(directory, step, arrays, metadata)

    monkeypatch.setattr(cp, "_write_checkpoint", counting_write)
    model, state, step_fn, data, _, _ = _setup()
    run_training(step_fn, state, data,
                 LoopConfig(total_steps=10, checkpoint_every=5,
                            checkpoint_dir=str(tmp_path / "ck")))
    assert sorted(saved) == [5, 10], saved
    from repro.checkpoint import list_checkpoints
    assert list_checkpoints(str(tmp_path / "ck")) == [5, 10]


def test_data_determinism():
    from repro.data import SyntheticImageData, SyntheticLMData
    a = SyntheticImageData(10, 16, 4, seed=3).batch_at(7)
    b = SyntheticImageData(10, 16, 4, seed=3).batch_at(7)
    np.testing.assert_array_equal(a["images"], b["images"])
    cfg = reduced_config(get_config("llama3.2-1b"))
    x = SyntheticLMData(cfg, 4, 32, seed=3).batch_at(9)
    y = SyntheticLMData(cfg, 4, 32, seed=3).batch_at(9)
    np.testing.assert_array_equal(x["tokens"], y["tokens"])
    # targets are next-token shifted tokens
    z = SyntheticLMData(cfg, 4, 32, seed=3)
    b0 = z.batch_at(0)
    assert (b0["tokens"][:, 1:] == b0["targets"][:, :-1]).mean() > 0.99
