"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
real (single) device; multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves."""
import os
import sys

import jax
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

jax.config.update("jax_enable_x64", False)


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


SUBPROCESS_ENV_8DEV = {
    **os.environ,
    "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
    "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src"),
}
