"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
real (single) device; multi-device tests spawn subprocesses that set
--xla_force_host_platform_device_count themselves."""
import os
import sys

import jax
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

jax.config.update("jax_enable_x64", False)


def pytest_configure(config):
    # CI runs a fast tier-1 job with `-m "not slow"` and a separate
    # `-m slow` job for the multi-step mesh parity sweeps (subprocess
    # compiles dominate); a plain `pytest` run still collects everything.
    config.addinivalue_line(
        "markers",
        "slow: multi-step virtual-mesh parity tests (subprocess compiles;"
        " run via `pytest -m slow` / excluded from the fast CI job)")


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


SUBPROCESS_ENV_8DEV = {
    **os.environ,
    "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
    "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src"),
}
