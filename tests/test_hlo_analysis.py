"""Loop-aware HLO analyzer: trip counts, FLOPs, collective accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import (
    analyze_hlo,
    parse_computations,
    type_bytes,
)


def test_type_bytes():
    assert type_bytes("f32[4,8]{1,0}") == 128
    assert type_bytes("bf16[10]") == 20
    assert type_bytes("(f32[2,2]{1,0}, s32[])") == 20
    assert type_bytes("pred[]") == 1


def _scanned_grad_program(n_layers):
    def f(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(x)

    return jax.jit(jax.grad(f, argnums=1)).lower(
        jax.ShapeDtypeStruct((32, 64), jnp.float32),
        jax.ShapeDtypeStruct((n_layers, 64, 64), jnp.float32)).compile()


def test_trip_count_weighted_flops_scale_with_layers():
    """cost_analysis() is loop-blind; the analyzer must not be."""
    flops = {}
    for n in (3, 6):
        comp = _scanned_grad_program(n)
        a = analyze_hlo(comp.as_text())
        flops[n] = a.flops
        assert n in a.trip_counts.values()
    ratio = flops[6] / flops[3]
    assert 1.8 < ratio < 2.2, flops
    # absolute: fwd+2bwd dots per layer = 3 * 2*32*64*64
    expected = 3 * 2 * 32 * 64 * 64 * 6
    np.testing.assert_allclose(flops[6], expected, rtol=0.15)


def test_memory_counts_dus_as_slice():
    """Scan residual stacks must be charged per-slice, not per-buffer."""
    comp = _scanned_grad_program(8)
    a = analyze_hlo(comp.as_text())
    # the x-stack buffer is 8*32*64*4B = 64KB; if DUS were charged at
    # full size per iteration it would contribute 8*64KB = 512KB alone.
    # Sanity band for the whole program:
    assert a.memory_bytes < 6e6, a.memory_bytes


def test_parse_computations_finds_entry():
    comp = _scanned_grad_program(2)
    comps = parse_computations(comp.as_text())
    assert "__entry__" in comps
    opcodes = {o.opcode for ops in comps.values() for o in ops}
    assert "while" in opcodes and "dot" in opcodes
