"""Loop-aware HLO analyzer: trip counts, FLOPs, collective accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import (
    analyze_hlo,
    bn_pass_counts,
    comm_report,
    fusion_report,
    interleave_report,
    parse_computations,
    type_bytes,
)


def test_type_bytes():
    assert type_bytes("f32[4,8]{1,0}") == 128
    assert type_bytes("bf16[10]") == 20
    assert type_bytes("(f32[2,2]{1,0}, s32[])") == 20
    assert type_bytes("pred[]") == 1


def _scanned_grad_program(n_layers):
    def f(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        x, _ = jax.lax.scan(body, x, ws)
        return jnp.sum(x)

    return jax.jit(jax.grad(f, argnums=1)).lower(
        jax.ShapeDtypeStruct((32, 64), jnp.float32),
        jax.ShapeDtypeStruct((n_layers, 64, 64), jnp.float32)).compile()


def test_trip_count_weighted_flops_scale_with_layers():
    """cost_analysis() is loop-blind; the analyzer must not be."""
    flops = {}
    for n in (3, 6):
        comp = _scanned_grad_program(n)
        a = analyze_hlo(comp.as_text())
        flops[n] = a.flops
        assert n in a.trip_counts.values()
    ratio = flops[6] / flops[3]
    assert 1.8 < ratio < 2.2, flops
    # absolute: fwd+2bwd dots per layer = 3 * 2*32*64*64
    expected = 3 * 2 * 32 * 64 * 64 * 6
    np.testing.assert_allclose(flops[6], expected, rtol=0.15)


def test_memory_counts_dus_as_slice():
    """Scan residual stacks must be charged per-slice, not per-buffer."""
    comp = _scanned_grad_program(8)
    a = analyze_hlo(comp.as_text())
    # the x-stack buffer is 8*32*64*4B = 64KB; if DUS were charged at
    # full size per iteration it would contribute 8*64KB = 512KB alone.
    # Sanity band for the whole program:
    assert a.memory_bytes < 6e6, a.memory_bytes


def test_parse_computations_finds_entry():
    comp = _scanned_grad_program(2)
    comps = parse_computations(comp.as_text())
    assert "__entry__" in comps
    opcodes = {o.opcode for ops in comps.values() for o in ops}
    assert "while" in opcodes and "dot" in opcodes


# ---------------------------------------------------------------------------
# interleave_report (DESIGN.md §8): synthetic scheduled programs
# ---------------------------------------------------------------------------


def _program(op_lines):
    body = "\n".join(f"  {line}" for line in op_lines)
    return ("HloModule m\n\n"
            "ENTRY %main (p0: f32[1024]) -> f32[1024] {\n"
            f"{body}\n"
            "}\n")


_CONV = ("%conv{i} = f32[1024]{{0}} convolution(%p0, %p0), "
         "dim_labels=b0f_0io->b0f")
_AR = ("%ar{i} = f32[1024]{{0}} all-reduce(%conv{j}), "
       "replica_groups={{{{0,1}}}}, to_apply=%add")
_TINY_AR = ("%tiny = f32[2]{{0}} all-reduce(%small), "
            "replica_groups={{{{0,1}}}}, to_apply=%add")


def test_interleave_report_rejects_tail_clustered():
    """All collectives after all compute = the non-overlapped layout."""
    lines = ["%p0 = f32[1024]{0} parameter(0)"]
    lines += [_CONV.format(i=i) for i in range(4)]
    lines += [_AR.format(i=i, j=i).replace("%ar", "%gar")
              for i in range(3)]
    lines += ["ROOT %out = f32[1024]{0} add(%gar0, %gar1)"]
    r = interleave_report(_program(lines))
    assert r["n_collectives"] == 3
    assert r["compute_ops_after_first"] == 0
    assert not r["interleaved"], r


def test_interleave_report_accepts_interleaved():
    """Collectives separated by conv compute = the overlapped layout;
    sub-threshold metric pmeans must not count as gradient collectives."""
    lines = ["%p0 = f32[1024]{0} parameter(0)",
             "%small = f32[2]{0} slice(%p0), slice={[0:2]}"]
    for i in range(3):
        lines.append(_CONV.format(i=i))
        lines.append(_AR.format(i=i, j=i))
    lines.append(_TINY_AR.format())
    lines.append("ROOT %out = f32[1024]{0} add(%ar0, %ar1)")
    r = interleave_report(_program(lines))
    assert r["n_collectives"] == 3  # tiny pmean excluded by byte floor
    assert r["interleaved"], r
    assert r["compute_ops_between_first_last"] == 2
    assert r["gaps_with_compute"] == 2


def test_interleave_report_no_collectives():
    r = interleave_report(_program(
        ["%p0 = f32[1024]{0} parameter(0)",
         _CONV.format(i=0),
         "ROOT %out = f32[1024]{0} add(%conv0, %conv0)"]))
    assert r["n_collectives"] == 0 and not r["interleaved"]


def test_comm_report_embeds_interleave_section():
    txt = _program(
        ["%p0 = f32[1024]{0} parameter(0)",
         _CONV.format(i=0),
         _AR.format(i=0, j=0),
         _CONV.format(i=1).replace("%conv1", "%convlate"),
         _AR.format(i=1, j=0),
         "ROOT %out = f32[1024]{0} add(%ar0, %ar1)"])
    cr = comm_report(analyze_hlo(txt, 2), hlo_text=txt)
    assert cr["interleave"]["interleaved"]
    assert "interleave" not in comm_report(analyze_hlo(txt, 2))


# ---------------------------------------------------------------------------
# fusion_report (fused BN, DESIGN.md §10): synthetic programs
# ---------------------------------------------------------------------------


def _bn_program(n_act_reduces, n_act_writes, hierarchical=False):
    """Synthetic BN-site HLO: activation f32[4096], stats f32[16].
    ``hierarchical`` splits each reduction into the CPU backend's
    reduce-window(big) -> reduce(small) chain — which must still count
    as ONE logical reduction pass."""
    lines = ["%p0 = f32[4096]{0} parameter(0)",
             "%c0 = f32[] constant(0)"]
    for i in range(n_act_reduces):
        if hierarchical:
            lines.append(f"%rw{i} = f32[16]{{0}} reduce-window(%p0, %c0),"
                         f" window={{size=256}}, to_apply=%add")
            lines.append(f"%red{i} = f32[] reduce(%rw{i}, %c0), "
                         f"dimensions={{0}}, to_apply=%add")
        else:
            lines.append(f"%red{i} = f32[] reduce(%p0, %c0), "
                         f"dimensions={{0}}, to_apply=%add")
    for i in range(n_act_writes):
        lines.append(f"%ew{i} = f32[4096]{{0}} multiply(%p0, %p0)")
    lines.append("ROOT %out = f32[4096]{0} add(%p0, %p0)")
    body = "\n".join(f"  {line}" for line in lines)
    return ("HloModule m\n\n"
            "%add (a: f32[], b: f32[]) -> f32[] {\n"
            "  %a = f32[] parameter(0)\n"
            "  %b = f32[] parameter(1)\n"
            "  ROOT %s = f32[] add(%a, %b)\n"
            "}\n\n"
            "ENTRY %main (p0: f32[4096]) -> f32[4096] {\n"
            f"{body}\n"
            "}\n")


def test_bn_pass_counts_basic():
    c = bn_pass_counts(_bn_program(4, 2), act_elems=4096)
    assert c["reduction_ops"] == 4.0
    # 2 multiplies + the ROOT add are activation-sized writes
    assert c["activation_writes"] == 3.0


def test_bn_pass_counts_hierarchical_reduction_counts_once():
    """A reduce-window(act) -> reduce(tiny) chain is one pass over the
    activation, not two: only the activation-sized stage counts."""
    flat = bn_pass_counts(_bn_program(3, 0), act_elems=4096)
    hier = bn_pass_counts(_bn_program(3, 0, hierarchical=True),
                          act_elems=4096)
    assert flat["reduction_ops"] == hier["reduction_ops"] == 3.0


def test_fusion_report_collapse_verdict():
    fused = _bn_program(4, 2)      # 2 fwd stats + 2 bwd sums
    unfused = _bn_program(6, 4)    # mean/var/dscale/dbias/dmean/dvar
    rep = fusion_report(fused, unfused, act_elems=4096, n_sites=2)
    assert rep["reduction_collapse"] and rep["elementwise_collapse"]
    assert rep["collapsed"]
    assert rep["reduction_ops_per_site"] == {"fused": 2.0,
                                             "unfused": 3.0}
    # no collapse -> no verdict
    rep2 = fusion_report(unfused, fused, act_elems=4096)
    assert not rep2["collapsed"]
