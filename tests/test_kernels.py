"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True
on CPU per the validation contract)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.optimizer import HybridHyper
from repro.kernels import ops, ref


class TestFusedUpdate:
    @pytest.mark.parametrize("shape", [(7,), (128,), (1000,), (33, 65),
                                       (512, 128), (3, 5, 7)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, shape, dtype, key):
        ks = jax.random.split(key, 4)
        g = jax.random.normal(ks[0], shape, dtype)
        p = jax.random.normal(ks[1], shape, dtype)
        d = jax.random.normal(ks[2], shape, jnp.float32)
        m = jnp.abs(jax.random.normal(ks[3], shape, jnp.float32))
        h = HybridHyper(eta=jnp.float32(0.7), alpha_sgd=jnp.float32(0.3))
        got = ops.fused_hybrid_update(g, p, d, m, h, weight_decay=1e-4)
        want = ref.hybrid_update(g, p, d, m, eta=0.7, alpha_sgd=0.3,
                                 weight_decay=1e-4)
        tol = 1e-5 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(got[0], np.float32),
                                   np.asarray(want[0].astype(dtype),
                                              np.float32), atol=tol)
        np.testing.assert_allclose(got[1], want[1], atol=1e-5)
        np.testing.assert_allclose(got[2], want[2], atol=1e-5)
        assert got[0].shape == shape and got[0].dtype == dtype

    @pytest.mark.parametrize("rows", [513, 1021])
    def test_non_block_multiple_rows(self, rows, key):
        """fused_update_2d pads the row stream to a block multiple and
        slices the outputs, so arbitrary parameter counts keep
        full-width tiles instead of asserting (or degrading to 1-row
        blocks). 513 and 1021 share no factor with block_rows=512."""
        from repro.kernels import fused_update as fu
        ks = jax.random.split(key, 4)
        g = jax.random.normal(ks[0], (rows, fu.LANES))
        p = jax.random.normal(ks[1], (rows, fu.LANES))
        d = jax.random.normal(ks[2], (rows, fu.LANES))
        m = jnp.abs(jax.random.normal(ks[3], (rows, fu.LANES)))
        h = HybridHyper(eta=jnp.float32(0.7), alpha_sgd=jnp.float32(0.3))
        scalars = jnp.stack([h.eta, h.alpha_sgd]).reshape(1, 2)
        outs = fu.fused_update_2d(
            g, p, d, m, scalars, mu1=h.mu1, mu2=h.mu2, eps=h.eps,
            eta_rmsprop=h.eta_rmsprop, weight_decay=1e-4, interpret=True)
        want = ref.hybrid_update(g, p, d, m, eta=0.7, alpha_sgd=0.3,
                                 weight_decay=1e-4)
        for got_x, want_x in zip(outs, want):
            assert got_x.shape == (rows, fu.LANES)
            assert np.all(np.isfinite(np.asarray(got_x)))
            np.testing.assert_allclose(np.asarray(got_x),
                                       np.asarray(want_x), atol=1e-5)

    def test_alpha_one_is_sgd(self, key):
        g = jax.random.normal(key, (256,))
        p = jnp.zeros((256,))
        h = HybridHyper(eta=jnp.float32(0.5), alpha_sgd=jnp.float32(1.0),
                        eta_rmsprop=0.0)
        p1, d1, _ = ops.fused_hybrid_update(g, p, jnp.zeros(256),
                                            jnp.zeros(256), h)
        np.testing.assert_allclose(d1, -g, rtol=1e-6)
        np.testing.assert_allclose(p1, -0.5 * g, rtol=1e-6)


class TestFlashAttention:
    @pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
    @pytest.mark.parametrize("causal", [True, False])
    def test_gqa_sweep(self, hq, hkv, causal, key):
        ks = jax.random.split(key, 3)
        b, s, dh = 2, 256, 32
        q = jax.random.normal(ks[0], (b, s, hq, dh))
        k = jax.random.normal(ks[1], (b, s, hkv, dh))
        v = jax.random.normal(ks[2], (b, s, hkv, dh))
        got = ops.attention(q, k, v, causal=causal, block_q=64, block_k=64)
        want = ref.attention(q, k, v, causal=causal)
        np.testing.assert_allclose(got, want, atol=2e-5)

    @pytest.mark.parametrize("window", [32, 128])
    def test_sliding_window(self, window, key):
        ks = jax.random.split(key, 3)
        b, s, h, dh = 1, 256, 2, 16
        q = jax.random.normal(ks[0], (b, s, h, dh))
        k = jax.random.normal(ks[1], (b, s, h, dh))
        v = jax.random.normal(ks[2], (b, s, h, dh))
        got = ops.attention(q, k, v, causal=True, window=window,
                            block_q=64, block_k=64)
        want = ref.attention(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(got, want, atol=2e-5)

    def test_bf16(self, key):
        ks = jax.random.split(key, 3)
        b, s, h, dh = 1, 128, 2, 64
        q = jax.random.normal(ks[0], (b, s, h, dh), jnp.bfloat16)
        k = jax.random.normal(ks[1], (b, s, h, dh), jnp.bfloat16)
        v = jax.random.normal(ks[2], (b, s, h, dh), jnp.bfloat16)
        got = ops.attention(q, k, v, causal=True, block_q=64, block_k=64)
        want = ref.attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), atol=0.05)

    def test_rectangular_and_uneven_blocks(self, key):
        ks = jax.random.split(key, 3)
        q = jax.random.normal(ks[0], (2, 384, 4, 32))
        k = jax.random.normal(ks[1], (2, 384, 4, 32))
        v = jax.random.normal(ks[2], (2, 384, 4, 32))
        got = ops.attention(q, k, v, causal=True, block_q=128, block_k=128)
        want = ref.attention(q, k, v, causal=True)
        np.testing.assert_allclose(got, want, atol=2e-5)


class TestChunkedGLA:
    """The SSD/mLSTM engine vs its sequential oracle."""

    @pytest.mark.parametrize("chunk", [16, 64, 256])
    @pytest.mark.parametrize("s", [256, 512])
    def test_chunk_sweep(self, chunk, s, key):
        from repro.models import ssd
        ks = jax.random.split(key, 4)
        b, h, dk, dv = 2, 3, 16, 8
        q = jax.random.normal(ks[0], (b, s, h, dk))
        k = jax.random.normal(ks[1], (b, s, h, dk))
        v = jax.random.normal(ks[2], (b, s, h, dv))
        log_a = -jnp.abs(jax.random.normal(ks[3], (b, s, h))) * 0.2
        y1, s1 = ssd.chunked_gla(q, k, v, log_a, chunk=chunk)
        y2, s2 = ssd.reference_gla(q, k, v, log_a)
        np.testing.assert_allclose(y1, y2, atol=1e-4)
        np.testing.assert_allclose(s1, s2, atol=1e-4)

    def test_gradients_finite(self, key):
        from repro.models import ssd
        ks = jax.random.split(key, 4)
        b, s, h, dk, dv = 1, 128, 2, 8, 8
        q = jax.random.normal(ks[0], (b, s, h, dk))
        k = jax.random.normal(ks[1], (b, s, h, dk))
        v = jax.random.normal(ks[2], (b, s, h, dv))
        log_a = -jnp.abs(jax.random.normal(ks[3], (b, s, h))) * 0.1

        def loss(q, k, v, la):
            y, _ = ssd.chunked_gla(q, k, v, la, chunk=32)
            return jnp.sum(jnp.square(y))

        grads = jax.grad(loss, argnums=(0, 1, 2, 3))(q, k, v, log_a)
        for g in grads:
            assert bool(jnp.isfinite(g).all())


class TestRMSNorm:
    @pytest.mark.parametrize("shape", [(4, 64), (2, 7, 128), (300, 256)])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_oracle(self, shape, dtype, key):
        ks = jax.random.split(key, 2)
        x = jax.random.normal(ks[0], shape, dtype) * 3.0
        scale = 1.0 + 0.1 * jax.random.normal(ks[1], (shape[-1],))
        got = ops.rmsnorm(x, scale)
        want = ref.rmsnorm(x, scale)
        tol = 1e-5 if dtype == jnp.float32 else 5e-2
        np.testing.assert_allclose(np.asarray(got, np.float32),
                                   np.asarray(want, np.float32), atol=tol)
        assert got.shape == shape and got.dtype == dtype

    def test_unit_rms(self, key):
        x = jax.random.normal(key, (32, 128)) * 10.0
        y = ops.rmsnorm(x, jnp.ones(128))
        rms = np.sqrt(np.mean(np.square(np.asarray(y)), axis=-1))
        np.testing.assert_allclose(rms, 1.0, rtol=1e-3)
