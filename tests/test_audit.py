"""Audit-driver tests (repro.analysis.audit, DESIGN.md §12).

Fast tier: the driver's expectation arithmetic (shared with
``distributed/bucketing.py:stream_layout``), CLI validation, exit
codes, and stream-vs-tree momentum-SGD parity (the optimizer added so
the zero x sgd audit cells lower).

Slow tier: the real thing — AOT-lower the train step on the 8-virtual-
device mesh for a bucketed and a zero cell, run every pass, gate the
contracts, and cross-check that a zero-mode contract rejects the
bucketed program (fails loudly on a real, not synthetic, mismatch).
"""
import json
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.audit import (
    MODES,
    OPTIMIZERS,
    _cell_expectations,
    main,
)
from repro.analysis.contracts import contract_for, evaluate

from conftest import SUBPROCESS_ENV_8DEV


# ---------------------------------------------------------------------------
# expectation arithmetic (no compile)
# ---------------------------------------------------------------------------

INFO = {"total_param_elems": 32794, "n_workers": 8,
        "n_state_leaves": 86, "n_batch_params": 2}


def test_mode_table_covers_claimed_matrix():
    assert set(MODES) == {"gspmd", "perleaf", "bucketed", "overlap",
                          "zero", "zero_overlap", "hier", "hier_overlap",
                          "hier_zero", "hier_zero_overlap"}
    assert set(OPTIMIZERS) == {"sgd", "lars"}
    for spec in MODES.values():
        assert spec["compression"].startswith("f16")  # CPU-surviving wire
    # every hierarchical cell lowers on the 2-axis hier mesh with a
    # valid split; flat cells carry no hierarchy
    for mode, spec in MODES.items():
        assert (spec.get("hier") is not None) == mode.startswith("hier")


def test_cell_expectations_bucketed_drops_tiny_tail():
    # 32794 f16 elems / 8 KiB buckets -> 9 planned cuts, but the 26-elem
    # tail (52 B) is under the 2 KiB qualifying floor
    exp = _cell_expectations(INFO, "bucketed", "sgd", bucket_bytes=8192)
    assert exp["n_buckets_planned"] == 9
    assert exp["n_buckets"] == 8
    assert exp["collective_budget"] == 8 + 2
    assert exp["n_batch_params"] == 2


def test_cell_expectations_zero_doubles_budget():
    # zero runs reduce-scatter in + all-gather out per bucket
    exp = _cell_expectations(INFO, "zero", "sgd", bucket_bytes=8192)
    assert exp["collective_budget"] == 2 * exp["n_buckets"] + 2


def test_cell_expectations_single_bucket():
    exp = _cell_expectations(INFO, "bucketed", "sgd",
                             bucket_bytes=1 << 30)
    assert exp["n_buckets"] == 1
    assert exp["collective_budget"] == 3


def test_cell_expectations_wire_floor():
    exp = _cell_expectations(INFO, "perleaf", "sgd", bucket_bytes=8192)
    # ring all-reduce: 2 * bytes * (n-1)/n, with 10% slack
    want = 2 * (32794 * 2) * (7 / 8) * 0.9
    assert exp["min_gradient_wire_bytes"] == pytest.approx(want)


# ---------------------------------------------------------------------------
# CLI surface
# ---------------------------------------------------------------------------


def test_cli_rejects_unknown_mode_and_optimizer(tmp_path):
    with pytest.raises(SystemExit):
        main(["--modes", "bogus", "--out", str(tmp_path / "a.json")])
    with pytest.raises(SystemExit):
        main(["--optimizers", "adamw", "--out", str(tmp_path / "a.json")])


def test_cli_exit_codes_follow_report(monkeypatch, tmp_path):
    import repro.analysis.audit as audit_mod

    def fake_run_audit(*a, **k):
        return {"cells": [{"ok": False, "violations": [
            {"kind": "check_failed"}]}], "relations": [], "ok": False}

    monkeypatch.setattr(audit_mod, "run_audit", fake_run_audit)
    out = tmp_path / "AUDIT.json"
    assert audit_mod.main(["--out", str(out)]) == 1
    assert json.loads(out.read_text())["ok"] is False

    monkeypatch.setattr(
        audit_mod, "run_audit",
        lambda *a, **k: {"cells": [{"ok": True, "violations": []}],
                         "relations": [], "ok": True})
    assert audit_mod.main(["--out", str(out)]) == 0


# ---------------------------------------------------------------------------
# stream momentum SGD == tree momentum SGD (the zero x sgd cell's math)
# ---------------------------------------------------------------------------


def test_stream_momentum_sgd_matches_tree_update(key):
    import jax

    from repro.configs.base import OptimizerConfig
    from repro.distributed.bucketing import pack, plan_buckets, unpack
    from repro.optim import make_optimizer
    from repro.optim.stream import make_stream_optimizer

    cfg = OptimizerConfig(kind="momentum_sgd")
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {"conv": {"kernel": jax.random.normal(k1, (3, 3, 4))},
              "bn": {"scale": jax.random.normal(k2, (4,)) + 1.0,
                     "bias": jax.random.normal(k3, (4,))}}
    grads = jax.tree.map(
        lambda p: jax.random.normal(k4, p.shape) * 0.1, params)

    tree_opt = make_optimizer(cfg, steps_per_epoch=10, global_batch=256)
    new_p, new_state, metrics = tree_opt.update(
        params, grads, tree_opt.init(params))

    stream_opt = make_stream_optimizer(cfg, steps_per_epoch=10,
                                       global_batch=256)
    plan = plan_buckets(params, bucket_bytes=1 << 20, wire=None)
    assert plan.n_buckets == 1
    (p_stream,) = pack(params, plan)
    (g_stream,) = pack(grads, plan)
    wd = jnp.asarray(stream_opt.wd_stream(params, plan))
    # the decay mask must actually discriminate (kernel decays, bias/
    # scale exempt) or this parity test proves nothing
    assert 0 < float((wd > 0).sum()) < wd.size
    opt = stream_opt.init(p_stream.size)
    p2, d2, m2, metrics2 = stream_opt.update_shard(
        p_stream, g_stream, opt["delta"], opt["m"], opt["step"], wd)

    stream_p = unpack([p2], plan)
    stream_d = unpack([d2], plan)
    for a, b in zip(jax.tree.leaves(stream_p), jax.tree.leaves(new_p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(stream_d),
                    jax.tree.leaves(new_state["delta"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(metrics2["lr"]) == float(metrics["lr"])
    assert np.all(np.asarray(m2) == 0)  # m rides along untouched


# ---------------------------------------------------------------------------
# the real thing: lower + audit on the 8-device mesh (slow)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_audit_driver_bucketed_and_zero_cells(tmp_path):
    out = tmp_path / "AUDIT.json"
    res = subprocess.run(
        [sys.executable, "-m", "repro.analysis.audit",
         "--model", "resnet50", "--modes", "bucketed,zero",
         "--optimizers", "sgd", "--out", str(out)],
        env=SUBPROCESS_ENV_8DEV, capture_output=True, text=True,
        timeout=900)
    assert res.returncode == 0, res.stdout + res.stderr

    report = json.loads(out.read_text())
    assert report["ok"] is True
    assert [c["mode"] for c in report["cells"]] == ["bucketed", "zero"]
    for cell in report["cells"]:
        assert cell["ok"], cell["violations"]
        assert cell["violations"] == []
        assert set(cell["passes"]) == {
            "comm", "interleave", "precision", "donation", "memory",
            "collectives", "determinism"}
        assert cell["expectations"]["n_buckets"] >= 2
    # the ZeRO residency relation ran and held
    assert [r["ok"] for r in report["relations"]] == [True]

    # fails loudly: the zero contract must reject the *real* bucketed
    # program (all-reduce carries the gradient; no reduce-scatter)
    bucketed = report["cells"][0]
    zero_contract = contract_for("resnet50", "zero", "sgd")
    violations = evaluate(zero_contract, bucketed["passes"],
                          bucketed["expectations"])
    assert violations, "zero contract accepted a bucketed program"
    fields = {v.get("field") for v in violations
              if v["kind"] == "check_failed"}
    assert "collectives.gradient_sync" in fields
