"""Packed-stream LARS (DESIGN.md §11).

Fast single-process tests cover the reference LARS bias/BN trust
exemption (regression), the leaf-segment map, the trust mask, the
single-process stream == reference bitwise equivalence (the shared
``segment_sum`` primitive contract), the stream-optimizer wiring, the
fused Pallas segment-norm/update kernels (allclose — MXU dot fold order
differs), and the polynomial-decay schedule. The step-level parity
matrix — {bucketed, overlap} x {zero, non-zero} x {bf16, f16} wire,
plain + error-feedback — runs in subprocesses on an 8-virtual-device
mesh (marked ``slow``), mirroring tests/test_zero.py: within a family
the decomposition is identical, so bucketed == zero and overlap ==
zero-overlap are asserted *bitwise*; across families (and vs the
per-leaf reference) the norm fold order legitimately differs, so those
are tight allclose only.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import OptimizerConfig
from repro.distributed.bucketing import (
    pack,
    plan_buckets,
    segment_ids_stream,
    segment_sq_partials,
    unpack,
)
from repro.optim import make_optimizer
from repro.optim.lars import leaf_sq_norm, trust_from_sq
from repro.optim.stream import make_stream_optimizer, trust_mask_segments

ENV8 = {
    **os.environ,
    "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
    "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src"),
}


def run_py(body: str, env=ENV8, timeout=900) -> str:
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         env=env, capture_output=True, text=True,
                         timeout=timeout)
    assert res.returncode == 0, f"STDERR:\n{res.stderr[-4000:]}"
    return res.stdout


def _tree(rng):
    """Small mixed tree: decayed weights + NO_DECAY bias/scale leaves."""
    mk = lambda *s: jnp.asarray(rng.standard_normal(s), jnp.float32)
    return {"blk": {"w": mk(7, 3), "bias": mk(3)},
            "norm": {"scale": mk(4)},
            "head": {"w": mk(3, 5)}}


# ---------------------------------------------------------------------------
# reference LARS: bias/BN leaves exempt from the trust ratio (regression)
# ---------------------------------------------------------------------------


def test_reference_lars_exempts_bias_bn_from_trust():
    """You et al. exempt bias/BN params from the layer-wise trust ratio:
    on a NO_DECAY leaf the update must be plain momentum (trust = 1),
    bitwise — not a norm-scaled step."""
    cfg = OptimizerConfig(kind="lars", schedule="constant",
                          base_lr_per_256=0.4)
    rng = np.random.default_rng(3)
    params = _tree(rng)
    grads = jax.tree.map(
        lambda p: jnp.asarray(rng.standard_normal(p.shape), jnp.float32),
        params)
    opt = make_optimizer(cfg, steps_per_epoch=5, global_batch=32)
    state = opt.init(params)
    new_p, new_st, metrics = opt.update(params, grads, state)
    eta = float(metrics["lr"])

    # bias/scale: d = -g, p' = p - eta*g exactly (trust 1, no decay)
    for path in (("blk", "bias"), ("norm", "scale")):
        p0, g = params[path[0]][path[1]], grads[path[0]][path[1]]
        got = new_p[path[0]][path[1]]
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(p0 - eta * g),
                                      err_msg=str(path))
    # weight leaf: trust-scaled, and the ratio matches trust_from_sq on
    # the decayed gradient
    p0, g = params["blk"]["w"], grads["blk"]["w"]
    g_eff = g + cfg.weight_decay * p0
    trust = trust_from_sq(leaf_sq_norm(p0), leaf_sq_norm(g_eff),
                          cfg.trust_coef, True)
    assert 0 < float(trust) < 1
    np.testing.assert_array_equal(
        np.asarray(new_p["blk"]["w"]),
        np.asarray(p0 - eta * trust * g_eff))


# ---------------------------------------------------------------------------
# leaf-segment map + trust mask
# ---------------------------------------------------------------------------


def test_segment_ids_stream_tiles_plan():
    rng = np.random.default_rng(4)
    tree = _tree(rng)
    plan = plan_buckets(tree, bucket_bytes=64, wire=None, align=4)
    seg = segment_ids_stream(plan)
    assert seg.shape == (plan.padded_total,)
    assert seg.dtype == np.int32
    for i, slot in enumerate(plan.slots):
        np.testing.assert_array_equal(
            seg[slot.offset:slot.offset + slot.size], i)
    # pad elements map to the synthetic trailing segment
    n_pad = int(np.sum(seg == len(plan.slots)))
    assert n_pad == plan.padded_total - plan.total_elems


def test_trust_mask_matches_decay_mask_and_exempts_pad():
    rng = np.random.default_rng(5)
    tree = _tree(rng)
    plan = plan_buckets(tree, bucket_bytes=64, wire=None, align=4)
    mask = trust_mask_segments(tree, plan)
    assert mask.shape == (len(plan.slots) + 1,)
    assert mask[-1] == False  # noqa: E712 — the pad segment
    # slots are in treedef leaf order; bias/scale exempt, weights not
    names = ["blk/bias", "blk/w", "head/w", "norm/scale"]
    want = {"blk/bias": False, "blk/w": True, "head/w": True,
            "norm/scale": False}
    assert list(mask[:-1]) == [want[n] for n in names]


# ---------------------------------------------------------------------------
# single-process stream == reference, bitwise (3 steps)
# ---------------------------------------------------------------------------


def test_stream_lars_matches_reference_bitwise_single_process():
    """The core of the parity claim: with one worker (no psum, no shard
    decomposition) the packed-stream LARS step reproduces the per-leaf
    reference bitwise over 3 steps — both compute norms through the same
    ``segment_sum`` primitive and the same ``trust_from_sq`` ratio."""
    cfg = OptimizerConfig(kind="lars", schedule="poly", warmup_epochs=1.0,
                          total_epochs=4.0, base_lr_per_256=0.4)
    rng = np.random.default_rng(6)
    params = _tree(rng)
    ref = make_optimizer(cfg, steps_per_epoch=5, global_batch=32)
    sopt = make_stream_optimizer(cfg, steps_per_epoch=5, global_batch=32)
    assert sopt.kind == "lars"

    plan = plan_buckets(params, bucket_bytes=48, wire=None, align=1)
    seg = jnp.asarray(segment_ids_stream(plan))
    wd = jnp.asarray(sopt.wd_stream(params, plan))
    tmask = jnp.asarray(trust_mask_segments(params, plan))
    n_seg = len(plan.slots) + 1

    ref_state = ref.init(params)
    sstate = sopt.init(plan.padded_total)
    ref_params = stream_params = params
    for step in range(3):
        grads = jax.tree.map(
            lambda p: jnp.asarray(rng.standard_normal(p.shape),
                                  jnp.float32), params)
        ref_params, ref_state, _ = ref.update(ref_params, grads,
                                              ref_state)
        p_stream = jnp.concatenate(pack(stream_params, plan))
        g_stream = jnp.concatenate(pack(grads, plan))
        partials = sopt.segment_partials(p_stream, g_stream, wd, seg,
                                         n_seg)
        assert partials.shape == (2, n_seg)
        trust = sopt.trust_ratios(partials, tmask)  # n=1: psum == id
        p_new, d_new, _ = sopt.update_shard(
            p_stream, g_stream, sstate["delta"], sstate["step"], wd,
            seg, trust)
        sstate = {"step": sstate["step"] + 1, "delta": d_new}
        stream_params = unpack([p_new], plan)
        # exempt segments (bias/scale/pad) got trust exactly 1
        t = np.asarray(trust)
        np.testing.assert_array_equal(t[~np.asarray(tmask)], 1.0)
        assert np.all(t[np.asarray(tmask)] < 1.0)

    for (ka, a), (kb, b) in zip(
            jax.tree_util.tree_leaves_with_path(ref_params),
            jax.tree_util.tree_leaves_with_path(stream_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=jax.tree_util.keystr(ka))
    d_ref = jnp.concatenate(pack(ref_state["delta"], plan))
    np.testing.assert_array_equal(
        np.asarray(sstate["delta"])[:plan.total_elems],
        np.asarray(d_ref)[:plan.total_elems])


def test_stream_optimizer_lars_wiring():
    sopt = make_stream_optimizer(OptimizerConfig(kind="lars"), 5, 32)
    assert sopt.kind == "lars"
    assert sopt.state_fields == ("delta",)
    assert sopt.segment_partials is not None
    assert sopt.trust_ratios is not None
    st = sopt.init(16)
    assert set(st) == {"step", "delta"}
    assert st["delta"].shape == (16,)


def test_stream_optimizer_still_rejects_unknown_kind():
    # momentum_sgd joined the stream family (the audit matrix lowers
    # every mode x optimizer cell, tests/test_audit.py pins parity);
    # anything outside {rmsprop_warmup, momentum_sgd, lars} still raises
    with pytest.raises(ValueError, match="rmsprop_warmup"):
        make_stream_optimizer(OptimizerConfig(kind="adamw"), 5, 32)
    sopt = make_stream_optimizer(OptimizerConfig(kind="momentum_sgd"), 5, 32)
    assert set(sopt.init(16)) == {"step", "delta", "m"}


def test_stream_checks_require_bucketed_and_lars():
    from repro.configs import ParallelConfig, TrainConfig
    from repro.training.step import make_dp_shardmap_train_step

    sopt = make_stream_optimizer(OptimizerConfig(kind="lars"), 5, 32)
    cfg = TrainConfig(optimizer=OptimizerConfig(kind="lars"),
                      parallel=ParallelConfig(compression="bf16"))
    mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))
    with pytest.raises(ValueError, match="bucketed"):
        make_dp_shardmap_train_step(object(), sopt, cfg, mesh, ("data",))


# ---------------------------------------------------------------------------
# fused Pallas kernels (allclose: the MXU one-hot dot folds differently)
# ---------------------------------------------------------------------------


def test_fused_segment_sq_partials_matches_segment_sum():
    from repro.kernels import ops as kops

    rng = np.random.default_rng(7)
    n, n_seg = 300, 4
    seg_np = np.repeat(np.arange(n_seg), [100, 80, 70, 50]).astype(
        np.int32)
    g = jnp.asarray(rng.standard_normal(n), jnp.float32)
    p = jnp.asarray(rng.standard_normal(n), jnp.float32)
    wd = jnp.asarray(rng.uniform(0, 1e-3, n), jnp.float32)
    seg = jnp.asarray(seg_np)
    got = kops.fused_segment_sq_partials(p, g, wd, seg, n_seg)
    want = jnp.stack([
        segment_sq_partials(p, seg, n_seg),
        segment_sq_partials(g + wd * p, seg, n_seg)])
    assert got.shape == (2, n_seg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6)


def test_fused_lars_update_matches_reference():
    from repro.kernels import ops as kops

    rng = np.random.default_rng(8)
    n, n_seg = 300, 3
    seg_np = np.repeat(np.arange(n_seg), [150, 100, 50]).astype(np.int32)
    g = jnp.asarray(rng.standard_normal(n), jnp.float32)
    p = jnp.asarray(rng.standard_normal(n), jnp.float32)
    d = jnp.asarray(rng.standard_normal(n), jnp.float32)
    wd = jnp.asarray(rng.uniform(0, 1e-3, n), jnp.float32)
    trust = jnp.asarray([1.0, 0.5, 2.0], jnp.float32)
    seg = jnp.asarray(seg_np)
    eta, mu1 = jnp.float32(0.3), 0.9
    p2, d2 = kops.fused_lars_update(g, p, d, wd, seg, trust, eta, mu1)
    g_eff = g + wd * p
    d_ref = mu1 * d - trust[seg] * g_eff
    p_ref = p + eta * d_ref
    np.testing.assert_allclose(np.asarray(d2), np.asarray(d_ref),
                               rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(np.asarray(p2), np.asarray(p_ref),
                               rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# polynomial-decay schedule
# ---------------------------------------------------------------------------


def test_poly_schedule_warmup_and_decay():
    from repro.core.schedules import make_lr_schedule

    lr = make_lr_schedule("poly", global_batch=256, base_lr_per_256=0.1,
                          warmup_epochs=1.0, total_epochs=4.0,
                          poly_power=2.0)
    # batch 256: eta_base == base, so warmup is flat at 0.1
    for e, want in ((0.0, 0.1), (0.5, 0.1), (1.0, 0.1),
                    (2.5, 0.1 * 0.25), (4.0, 0.0), (5.0, 0.0)):
        np.testing.assert_allclose(float(lr(jnp.float32(e))), want,
                                   rtol=1e-6, atol=1e-9,
                                   err_msg=f"epoch {e}")
    # linear scaling: batch 512 doubles the post-warmup LR
    lr2 = make_lr_schedule("poly", global_batch=512, base_lr_per_256=0.1,
                           warmup_epochs=1.0, total_epochs=4.0)
    np.testing.assert_allclose(float(lr2(jnp.float32(1.0))), 0.2,
                               rtol=1e-6)
    # warmup ramps from base_lr_per_256 toward eta_base
    np.testing.assert_allclose(float(lr2(jnp.float32(0.0))), 0.1,
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# step-level parity matrix (subprocess, 8-device virtual mesh, slow)
# ---------------------------------------------------------------------------

_PARITY_BODY = """
    WIRE = @WIRE@
    EF = @EF@
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import OptimizerConfig, get_config, reduced_config
    from repro.distributed.bucketing import (plan_buckets,
                                             plan_ready_buckets,
                                             stream_to_shard_layout)
    from repro.launch.train import build_train_setup
    cfg = reduced_config(get_config('resnet50'))
    mesh = jax.make_mesh((jax.device_count(), 1), ('data', 'model'))
    N = jax.device_count()
    BB = 8192
    opt_cfg = OptimizerConfig(kind='lars', schedule='poly',
                              warmup_epochs=1.0, total_epochs=4.0,
                              base_lr_per_256=0.3)

    def run(compression, overlap, zero):
        model, state, step, data, put, _ = build_train_setup(
            cfg, global_batch=8, seq_len=16, opt_cfg=opt_cfg,
            steps_per_epoch=5, mesh=mesh, dp_mode='shardmap', seed=0,
            compression=compression, bucket_bytes=BB,
            error_feedback=EF, overlap_comm=overlap, zero_dp=zero,
            label_smoothing=0.1)
        losses = []
        for s in range(3):
            batch = put({k: jnp.asarray(v)
                         for k, v in data.batch_at(s).items()})
            state, metrics = step(state, batch)
            losses.append(float(metrics['loss']))
        return model, state, losses

    def leaves(tree):
        return sorted(((jax.tree_util.keystr(k), np.asarray(v))
                       for k, v in
                       jax.tree_util.tree_leaves_with_path(tree)),
                      key=lambda kv: kv[0])

    def assert_state(name, s0, s1, exact):
        # ef_residual is compared bitwise within a family only: it IS
        # the wire-rounding LSB of the gradient, so across families
        # (slightly different gradients -> different rounding) it has
        # no meaningful tolerance.
        keys = ['params', 'model_state'] + (
            ['ef_residual'] if (EF and exact) else [])
        for key in keys:
            for (ka, a), (kb, b) in zip(leaves(s0[key]), leaves(s1[key])):
                if exact:
                    np.testing.assert_array_equal(
                        a, b, err_msg=name + ':' + key + ka)
                else:
                    # fold-order noise across stream layouts: relative
                    # for normal-sized params, absolute floor for
                    # near-zero elements (BN biases ~1e-4 after 3 steps)
                    np.testing.assert_allclose(
                        a, b, rtol=1e-2, atol=1e-4,
                        err_msg=name + ':' + key + ka)

    def shard_layout(stream, plan):
        return stream_to_shard_layout(np.asarray(stream), plan, N)

    # ---- the four packed-stream sync modes ----
    model, sb, lb = run(WIRE + '+bucketed', False, False)
    _, sz, lz = run(WIRE + '+bucketed', False, True)
    _, so, lo = run(WIRE + '+bucketed', True, False)
    _, szo, lzo = run(WIRE + '+bucketed', True, True)
    # within a family the norm decomposition is identical: bitwise
    assert lb == lz, (lb, lz)
    assert lo == lzo, (lo, lzo)
    assert_state('bucketed_vs_zero', sb, sz, exact=True)
    assert_state('overlap_vs_zero_overlap', so, szo, exact=True)
    if EF:
        nz = max(float(jnp.abs(x).max())
                 for x in jax.tree.leaves(sz['ef_residual']))
        assert nz > 0  # EF genuinely active

    # delta layout: non-zero keeps the full stream, zero the shard
    # layout of the same plan — bitwise-equal values either way
    assert all(int(s['opt']['step']) == 3 for s in (sb, sz, so, szo))
    plan_p = plan_buckets(sb['params'], BB, WIRE, align=N)
    np.testing.assert_array_equal(
        shard_layout(sb['opt']['delta'], plan_p),
        np.asarray(sz['opt']['delta']), err_msg='delta:bucketed/zero')
    mstate0 = jax.tree.map(lambda x: x[0], so['model_state'])
    dummy = {'images': jnp.zeros((8, 32, 32, 3)),
             'labels': jnp.zeros((8,), jnp.int32)}
    staged = model.loss_segments(so['params'], mstate0, dummy, 0.0)
    plan_o = plan_ready_buckets(
        [jax.tree.map(lambda x: x, t)
         for t in reversed(staged.seg_params)], BB, WIRE, align=N).base
    np.testing.assert_array_equal(
        shard_layout(so['opt']['delta'], plan_o),
        np.asarray(szo['opt']['delta']), err_msg='delta:overlap/zero')

    # across families the norm fold order differs: tight allclose
    assert_state('bucketed_vs_overlap', sb, so, exact=False)

    # ---- vs the per-leaf reference (tree LARS, unbucketed wire) ----
    _, sr, lr_ = run(WIRE, False, False)
    assert np.allclose(lb, lr_, rtol=1e-3), (lb, lr_)
    assert_state('bucketed_vs_reference', sb, sr, exact=False)
    print('LARS_PARITY_OK')
"""


@pytest.mark.slow
@pytest.mark.parametrize("ef", [False, True])
@pytest.mark.parametrize("wire", ["bf16", "f16"])
def test_lars_stream_parity_matrix_8dev(ef, wire):
    """Acceptance: kind='lars' runs through the packed-stream path in
    all four sync modes on the 8-virtual-device mesh. Bucketed == zero
    and overlap == zero-overlap bitwise (identical shard-decomposed norm
    program); cross-family and vs the per-leaf tree reference are tight
    allclose (the fold order across different stream layouts legitimately
    differs)."""
    body = _PARITY_BODY.replace("@WIRE@", repr(wire)).replace(
        "@EF@", str(ef))
    out = run_py(textwrap.dedent(body))
    assert "LARS_PARITY_OK" in out
