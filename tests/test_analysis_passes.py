"""Audit-pass unit tests (repro.analysis.passes, DESIGN.md §12).

Each pass gets a positive program (clean HLO -> no errors) and a
seeded-violation program (the defect the pass exists to catch -> error
finding), written in XLA's emitted grammar. Also covers the pass
registry/framework and contract evaluation (repro.analysis.contracts)
including ``$``-expectation resolution and every violation kind.
"""
import pytest

from repro.analysis import quick_audit
from repro.analysis.contracts import (
    BASE_FORBID,
    Check,
    Contract,
    contract_for,
    evaluate,
    lookup,
    resolve,
)
from repro.analysis.passes import (
    AuditContext,
    PassResult,
    available_passes,
    get_pass,
    run_pass,
)

ADD_COMP = """\
%add.1 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %sum = f32[] add(%a, %b)
}
"""


def ctx_for(text, **expectations):
    return AuditContext(hlo_text=text, total_devices=2,
                        expectations=dict(expectations))


# ---------------------------------------------------------------------------
# framework / registry
# ---------------------------------------------------------------------------


def test_registry_has_all_builtin_passes():
    assert {"comm", "interleave", "precision", "donation", "memory",
            "collectives", "determinism"} <= set(available_passes())


def test_get_pass_unknown_raises():
    with pytest.raises(KeyError, match="unknown audit pass"):
        get_pass("no_such_pass")


def test_run_pass_turns_crash_into_error_finding():
    # empty HLO makes parse_module raise; the audit must not die mid-run
    res = run_pass("donation", ctx_for(""))
    assert not res.as_dict()["ok"]
    assert any("pass crashed" in f.message for f in res.errors)


def test_pass_result_shape():
    res = PassResult(name="x")
    res.add("warn", "something", op="op.1", extra=3)
    d = res.as_dict()
    assert d["pass"] == "x" and d["ok"] is True
    assert d["findings"][0] == {
        "severity": "warn", "message": "something", "op": "op.1",
        "data": {"extra": 3}}
    res.add("error", "bad")
    assert res.as_dict()["ok"] is False
    with pytest.raises(AssertionError):
        res.add("fatal", "not a severity")


# ---------------------------------------------------------------------------
# precision pass
# ---------------------------------------------------------------------------

PRECISION_BAD_REDUCE = ADD_COMP + """
ENTRY %main (p: f32[4096]) -> bf16[] {
  %p = f32[4096]{0} parameter(0)
  %c = bf16[4096]{0} convert(%p)
  %z = bf16[] constant(0)
  ROOT %r = bf16[] reduce(%c, %z), dimensions={0}, to_apply=%add.1
}
"""

PRECISION_GOOD_REDUCE = ADD_COMP + """
ENTRY %main (p: f32[4096]) -> f32[] {
  %p = f32[4096]{0} parameter(0)
  %z = f32[] constant(0)
  ROOT %r = f32[] reduce(%p, %z), dimensions={0}, to_apply=%add.1
}
"""


def test_precision_flags_narrow_big_reduction():
    res = run_pass("precision", ctx_for(PRECISION_BAD_REDUCE))
    assert len(res.errors) == 1
    assert "accumulates in bf16" in res.errors[0].message
    assert res.summary["narrow_reductions"] == 1


def test_precision_accepts_f32_reduction():
    res = run_pass("precision", ctx_for(PRECISION_GOOD_REDUCE))
    assert not res.errors
    assert res.summary["big_reductions_checked"] == 1
    assert res.summary["narrow_reductions"] == 0


def test_precision_small_reduction_below_floor_ignored():
    small = PRECISION_BAD_REDUCE.replace("4096", "16")
    res = run_pass("precision", ctx_for(small))
    assert not res.errors
    assert res.summary["big_reductions_checked"] == 0


PRECISION_ROUNDTRIP = """\
ENTRY %main (p: f32[4096]) -> f32[4096] {
  %p = f32[4096]{0} parameter(0)
  %down = bf16[4096]{0} convert(%p)
  %up = f32[4096]{0} convert(%down)
  ROOT %u = f32[4096]{0} add(%up, %up)
}
"""

PRECISION_ROUNDTRIP_COLLECTIVE = ADD_COMP + """
ENTRY %main (p: f32[4096]) -> f32[4096] {
  %p = f32[4096]{0} parameter(0)
  %down = bf16[4096]{0} convert(%p)
  %up = f32[4096]{0} convert(%down)
  ROOT %ar = f32[4096]{0} all-reduce(%up), \
replica_groups={{0,1}}, to_apply=%add.1
}
"""


def test_precision_warns_on_narrow_roundtrip():
    res = run_pass("precision", ctx_for(PRECISION_ROUNDTRIP))
    assert not res.errors
    assert len(res.warnings) == 1
    assert "round-trip" in res.warnings[0].message
    assert res.summary["roundtrips"] == 1


def test_precision_suppresses_roundtrip_feeding_collective():
    # the CPU backend promotes bf16 collectives to f32; that inserted
    # cast pair is a backend artifact, not a policy violation
    res = run_pass("precision", ctx_for(PRECISION_ROUNDTRIP_COLLECTIVE))
    assert not res.errors and not res.warnings
    assert res.summary["roundtrips_suppressed_collective"] == 1
    assert res.summary["roundtrips"] == 0


# ---------------------------------------------------------------------------
# donation pass
# ---------------------------------------------------------------------------

def donation_module(alias_entries):
    return (f"HloModule jit_step, input_output_alias={{ {alias_entries} }}, "
            "frontend_attributes={}\n\n" + """\
ENTRY %main (p0: f32[4096], p1: f32[4096], p2: f32[1024]) -> \
(f32[4096], f32[4096]) {
  %p0 = f32[4096]{0} parameter(0)
  %p1 = f32[4096]{0} parameter(1)
  %p2 = f32[1024]{0} parameter(2)
  %u0 = f32[4096]{0} add(%p0, %p0)
  %u1 = f32[4096]{0} add(%p1, %p1)
  ROOT %out = (f32[4096], f32[4096]) tuple(%u0, %u1)
}
""")


DONATION_GOOD = donation_module(
    "{0}: (0, {}, may-alias), {1}: (1, {}, may-alias)")
DONATION_BAD = donation_module("{0}: (0, {}, may-alias)")


def test_donation_full_coverage_passes():
    res = run_pass("donation", ctx_for(DONATION_GOOD, n_batch_params=1))
    assert not res.errors
    s = res.summary
    assert s["n_entry_params"] == 3
    assert s["n_state_params"] == 2      # trailing batch leaf excluded
    assert s["n_aliased"] == 2
    assert s["state_alias_fraction"] == 1.0
    assert s["wasted_bytes"] == 0


def test_donation_lost_alias_is_error():
    res = run_pass("donation", ctx_for(DONATION_BAD, n_batch_params=1))
    assert len(res.errors) == 1
    assert "donation lost" in res.errors[0].message
    assert res.summary["wasted_bytes"] == 16384.0
    # plus the per-parameter warning naming the culprit
    assert any("parameter 1" in w.message for w in res.warnings)


def test_donation_ungated_without_expectation():
    # no n_batch_params -> info-level coverage report only, never errors
    res = run_pass("donation", ctx_for(DONATION_BAD))
    assert not res.errors
    assert any(f.severity == "info" for f in res.findings)


# ---------------------------------------------------------------------------
# determinism pass
# ---------------------------------------------------------------------------

DETERMINISM_RNG = """\
ENTRY %main (p: u64[2]) -> u32[128] {
  %p = u64[2]{0} parameter(0)
  ROOT %r = u32[128]{0} rng-bit-generator(%p), algorithm=rng_default
}
"""

DETERMINISM_SCATTER = ADD_COMP + """
ENTRY %main (p: f32[128], i: s32[4,1], u: f32[4]) -> f32[128] {
  %p = f32[128]{0} parameter(0)
  %i = s32[4,1]{1,0} parameter(1)
  %u = f32[4]{0} parameter(2)
  ROOT %sc = f32[128]{0} scatter(%p, %i, %u), \
update_window_dims={}, inserted_window_dims={0}, \
scatter_dims_to_operand_dims={0}, index_vector_dim=1, to_apply=%add.1
}
"""


def test_determinism_rng_is_error_by_default():
    res = run_pass("determinism", ctx_for(DETERMINISM_RNG))
    assert len(res.errors) == 1
    assert "rng" in res.errors[0].message
    assert res.summary["clean"] is False


def test_determinism_allow_rng_expectation():
    res = run_pass("determinism", ctx_for(DETERMINISM_RNG, allow_rng=True))
    assert not res.errors
    assert res.summary["op_counts"] == {"rng-bit-generator": 1.0}


def test_determinism_scatter_warns_then_errors_when_forbidden():
    res = run_pass("determinism", ctx_for(DETERMINISM_SCATTER))
    assert not res.errors and len(res.warnings) == 1
    res = run_pass("determinism",
                   ctx_for(DETERMINISM_SCATTER, forbid_scatter=True))
    assert len(res.errors) == 1


def test_determinism_clean_program():
    res = run_pass("determinism", ctx_for(PRECISION_GOOD_REDUCE))
    assert not res.findings
    assert res.summary["clean"] is True


# ---------------------------------------------------------------------------
# collectives (schedule) pass
# ---------------------------------------------------------------------------

SCHEDULE_PROGRAM = ADD_COMP + """
ENTRY %main (g0: f32[4096], g1: f32[4096], m: f32[2]) -> \
(f32[4096], f32[4096], f32[2]) {
  %g0 = f32[4096]{0} parameter(0)
  %g1 = f32[4096]{0} parameter(1)
  %m = f32[2]{0} parameter(2)
  %ar0 = f32[4096]{0} all-reduce(%g0), \
replica_groups={{0,1}}, to_apply=%add.1
  %ar1 = f32[4096]{0} all-reduce(%g1), \
replica_groups={{0,1}}, to_apply=%add.1
  %arm = f32[2]{0} all-reduce(%m), \
replica_groups={{0,1}}, to_apply=%add.1
  ROOT %out = (f32[4096], f32[4096], f32[2]) tuple(%ar0, %ar1, %arm)
}
"""


def test_schedule_counts_qualifying_collectives():
    res = run_pass("collectives", ctx_for(SCHEDULE_PROGRAM))
    s = res.summary
    assert s["per_op"]["all-reduce"]["execs"] == 2     # metric psum below floor
    assert s["per_op"]["all-reduce"]["max_bytes"] == 16384
    assert s["qualifying_execs_total"] == 2
    assert s["small_execs_total"] == 1
    assert s["gradient_sync"] == "all_reduce"
    assert not res.errors


def test_schedule_launch_budget_gate():
    res = run_pass("collectives",
                   ctx_for(SCHEDULE_PROGRAM, max_collectives_per_step=2))
    assert not res.errors
    res = run_pass("collectives",
                   ctx_for(SCHEDULE_PROGRAM, max_collectives_per_step=1))
    assert len(res.errors) == 1
    assert "exceeds the contract cap" in res.errors[0].message


def test_schedule_forbid_allreduce_gate():
    # the ZeRO promise: no all-reduce above metric size survives
    res = run_pass("collectives",
                   ctx_for(SCHEDULE_PROGRAM,
                           forbid_allreduce_above_bytes=1024))
    assert len(res.errors) == 1
    assert "this mode promises none above" in res.errors[0].message
    res = run_pass("collectives",
                   ctx_for(SCHEDULE_PROGRAM,
                           forbid_allreduce_above_bytes=65536))
    assert not res.errors


# ---------------------------------------------------------------------------
# memory pass
# ---------------------------------------------------------------------------

MEMORY_PROGRAM = """\
ENTRY %main (p0: f32[1024]) -> f32[1024] {
  %p0 = f32[1024]{0} parameter(0)
  %a = f32[1024]{0} multiply(%p0, %p0)
  %b = f32[1024]{0} add(%a, %p0)
  ROOT %c = f32[1024]{0} add(%b, %b)
}
"""


def test_memory_liveness_estimate():
    res = run_pass("memory", ctx_for(MEMORY_PROGRAM))
    s = res.summary
    assert s["entry_param_bytes"] == 4096
    # %a (4 KiB) and %b (4 KiB) are simultaneously live at %b's def
    assert s["temp_peak_bytes"] == 8192
    assert s["peak_bytes"] == 12288
    assert s["n_buffers"] == 3
    assert not res.errors


def test_memory_peak_cap_gate():
    res = run_pass("memory", ctx_for(MEMORY_PROGRAM, max_peak_bytes=16384))
    assert not res.errors
    res = run_pass("memory", ctx_for(MEMORY_PROGRAM, max_peak_bytes=8192))
    assert len(res.errors) == 1
    assert "exceeds contract cap" in res.errors[0].message


# ---------------------------------------------------------------------------
# interleave pass
# ---------------------------------------------------------------------------

def interleave_module(schedule):
    return ADD_COMP + f"""
ENTRY %main (a: f32[64,64], b: f32[64,64]) -> f32[64,64] {{
{schedule}
}}
"""


_DOT = ("%{n} = f32[64,64]{{1,0}} dot({a}, {b}), "
        "lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}")
_AR = ("%{n} = f32[64,64]{{1,0}} all-reduce({a}), "
       "replica_groups={{{{0,1}}}}, to_apply=%add.1")

INTERLEAVED = interleave_module("\n".join("  " + ln for ln in [
    "%a = f32[64,64]{1,0} parameter(0)",
    "%b = f32[64,64]{1,0} parameter(1)",
    _DOT.format(n="d1", a="%a", b="%b"),
    _AR.format(n="ar1", a="%d1"),
    _DOT.format(n="d2", a="%ar1", b="%b"),
    _AR.format(n="ar2", a="%d2"),
    _DOT.format(n="d3", a="%ar2", b="%a"),
    "ROOT %s = f32[64,64]{1,0} add(%d3, %d3)",
]))

CLUSTERED = interleave_module("\n".join("  " + ln for ln in [
    "%a = f32[64,64]{1,0} parameter(0)",
    "%b = f32[64,64]{1,0} parameter(1)",
    _DOT.format(n="d1", a="%a", b="%b"),
    _DOT.format(n="d2", a="%d1", b="%b"),
    _AR.format(n="ar1", a="%d1"),
    _AR.format(n="ar2", a="%d2"),
    "ROOT %s = f32[64,64]{1,0} add(%ar1, %ar2)",
]))


def test_interleave_detects_overlap():
    res = run_pass("interleave", ctx_for(INTERLEAVED))
    assert res.summary["interleaved"] is True
    assert res.summary["n_collectives"] == 2
    assert not res.errors


def test_interleave_clustered_tail_fails_when_required():
    res = run_pass("interleave", ctx_for(CLUSTERED))
    assert res.summary["interleaved"] is False
    assert not res.errors  # informational unless the contract arms it
    res = run_pass("interleave",
                   ctx_for(CLUSTERED, require_interleaved=True))
    assert len(res.errors) == 1
    assert "clustered at the tail" in res.errors[0].message


# ---------------------------------------------------------------------------
# comm pass (informational)
# ---------------------------------------------------------------------------


def test_comm_pass_summary():
    res = run_pass("comm", ctx_for(SCHEDULE_PROGRAM))
    assert not res.errors
    ar = res.summary["per_op"]["all-reduce"]
    assert ar["executions_per_step"] == 3
    assert ar["max_bytes_per_collective"] == 16384


# ---------------------------------------------------------------------------
# quick_audit (the dryrun embedding)
# ---------------------------------------------------------------------------


def test_quick_audit_clean_program():
    rec = quick_audit(DONATION_GOOD, total_devices=2, n_batch_params=1)
    assert rec["ok"] is True
    assert set(rec) == {"precision", "donation", "determinism",
                        "collectives", "ok"}
    assert all(rec[p]["ok"] for p in
               ("precision", "donation", "determinism", "collectives"))


def test_quick_audit_flags_seeded_violation():
    rec = quick_audit(DONATION_BAD, total_devices=2, n_batch_params=1)
    assert rec["ok"] is False
    assert rec["donation"]["ok"] is False


# ---------------------------------------------------------------------------
# contracts
# ---------------------------------------------------------------------------


def test_resolve_expectations():
    assert resolve(7, {}) == 7
    assert resolve("$n", {"n": 9}) == 9
    with pytest.raises(KeyError, match="driver did not compute"):
        resolve("$missing", {"n": 9})


def test_lookup_dotted_path():
    rec = {"collectives": {"summary": {"per_op": {"all-reduce":
                                                  {"execs": 8}}}}}
    assert lookup(rec, "collectives.per_op.all-reduce.execs") == 8
    with pytest.raises(KeyError, match="no pass record"):
        lookup(rec, "memory.peak_bytes")
    with pytest.raises(KeyError, match="missing"):
        lookup(rec, "collectives.per_op.all-gather.execs")


def _fake_record(execs=8, sync="all_reduce", with_error=False):
    findings = ([{"severity": "error", "message": "seeded"}]
                if with_error else [])
    return {
        "collectives": {"pass": "collectives", "ok": not with_error,
                        "findings": findings,
                        "summary": {"qualifying_execs_total": execs,
                                    "gradient_sync": sync,
                                    "per_op": {"all-reduce":
                                               {"execs": execs}}}},
    }


def test_evaluate_clean_contract():
    c = Contract(name="t", forbid_errors=("collectives",), checks=(
        Check("collectives.per_op.all-reduce.execs", "==", "$n_buckets"),
        Check("collectives.gradient_sync", "==", "all_reduce"),
    ))
    assert evaluate(c, _fake_record(), {"n_buckets": 8}) == []


def test_evaluate_check_failed():
    c = Contract(name="t", forbid_errors=(), checks=(
        Check("collectives.per_op.all-reduce.execs", "==", "$n_buckets",
              label="one all-reduce per bucket"),))
    v = evaluate(c, _fake_record(execs=9), {"n_buckets": 8})
    assert [x["kind"] for x in v] == ["check_failed"]
    assert v[0]["expected"] == 8 and v[0]["actual"] == 9
    assert v[0]["check"] == "one all-reduce per bucket"


def test_evaluate_pass_error_and_missing_pass():
    c = Contract(name="t", forbid_errors=("collectives", "memory"),
                 checks=())
    v = evaluate(c, _fake_record(with_error=True), {})
    kinds = sorted(x["kind"] for x in v)
    assert kinds == ["missing_pass", "pass_error"]


def test_evaluate_check_error_on_bad_field():
    c = Contract(name="t", forbid_errors=(), checks=(
        Check("collectives.per_op.reduce-scatter.execs", ">=", 1),))
    v = evaluate(c, _fake_record(), {})
    assert v[0]["kind"] == "check_error"


def test_evaluate_is_true_ops():
    c = Contract(name="t", forbid_errors=(), checks=(
        Check("interleave.interleaved", "is_true"),))
    rec = {"interleave": {"summary": {"interleaved": False},
                          "findings": []}}
    v = evaluate(c, rec, {})
    assert v and v[0]["kind"] == "check_failed"
    rec["interleave"]["summary"]["interleaved"] = True
    assert evaluate(c, rec, {}) == []


def test_contract_table_per_mode():
    gspmd = contract_for("resnet50", "gspmd", "sgd")
    assert gspmd.forbid_errors == BASE_FORBID
    assert not gspmd.expectations

    bucketed = contract_for("resnet50", "bucketed", "sgd")
    assert bucketed.expectations["max_collectives_per_step"] == \
        "$collective_budget"
    assert any(c.value == "$n_buckets" for c in bucketed.checks)

    overlap = contract_for("resnet50", "overlap", "lars")
    assert overlap.expectations["require_interleaved"] is True

    zero = contract_for("resnet50", "zero", "sgd")
    assert zero.expectations["forbid_allreduce_above_bytes"] == \
        "$metric_bytes_floor"
    fields = [c.field for c in zero.checks]
    assert "collectives.per_op.reduce-scatter.execs" in fields
    assert "collectives.per_op.all-gather.execs" in fields

    with pytest.raises(ValueError, match="no contract for mode"):
        contract_for("resnet50", "nope", "sgd")


def test_zero_contract_rejects_bucketed_style_record():
    # cross-check: a bucketed-looking program must violate the zero
    # contract (gradient carried by all-reduce, no reduce-scatter)
    zero = contract_for("resnet50", "zero", "sgd")
    zero = Contract(name=zero.name, passes=zero.passes,
                    expectations=zero.expectations, checks=zero.checks,
                    forbid_errors=())
    v = evaluate(zero, _fake_record(execs=8, sync="all_reduce"),
                 {"n_buckets": 8, "metric_bytes_floor": 2048,
                  "collective_budget": 10})
    kinds = {x["kind"] for x in v}
    assert "check_failed" in kinds or "check_error" in kinds
    # specifically: gradient_sync mismatch is among the violations
    assert any(x.get("field") == "collectives.gradient_sync"
               for x in v if x["kind"] == "check_failed")
