"""Epoch-driven Trainer: validation actually runs (DESIGN.md §7).

Covers the paper's eval protocol — held-out split, pre-validation BN
all-reduce, best-checkpoint retention, eval-state resume — plus the
GSPMD/shard_map eval-logits parity the protocol guarantees.
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import OptimizerConfig, get_config, reduced_config
from repro.launch.train import build_eval_setup, build_train_setup
from repro.training import Trainer, TrainerConfig

from conftest import SUBPROCESS_ENV_8DEV


def _setup(steps_per_epoch=5, seed=0, global_batch=16):
    cfg = reduced_config(get_config("resnet50"))
    opt_cfg = OptimizerConfig(kind="rmsprop_warmup")
    model, state, step_fn, data, put, sh = build_train_setup(
        cfg, global_batch=global_batch, seq_len=16, opt_cfg=opt_cfg,
        steps_per_epoch=steps_per_epoch, seed=seed)
    eval_step, val_data, finalize = build_eval_setup(
        model, cfg, global_batch=global_batch, seq_len=16, seed=seed)
    return model, state, step_fn, data, eval_step, val_data, finalize


def _trainer_cfg(**kw):
    base = dict(epochs=3, steps_per_epoch=5, eval_every_epochs=1,
                val_batches=2, checkpoint_every=0, checkpoint_dir=None,
                log_every=100)
    base.update(kw)
    return TrainerConfig(**base)


class TestEpochEval:
    def test_per_epoch_top1_history(self):
        model, state, step_fn, data, ev, vd, fin = _setup()
        res = Trainer(step_fn, state, data, _trainer_cfg(),
                      eval_step=ev, val_data=vd, finalize_state=fin).run()
        assert [r["epoch"] for r in res.epoch_history] == [1, 2, 3]
        for r in res.epoch_history:
            assert 0.0 <= r["top1"] <= 1.0
            assert np.isfinite(r["loss"])
            assert r["step"] == r["epoch"] * 5
        # the synthetic task is learnable: accuracy must improve
        assert res.epoch_history[-1]["top1"] > res.epoch_history[0]["top1"] \
            or res.epoch_history[0]["top1"] == 1.0
        assert res.best is not None and 0.0 <= res.best["top1"] <= 1.0

    def test_eval_every_epochs_cadence_includes_final(self):
        model, state, step_fn, data, ev, vd, fin = _setup()
        res = Trainer(step_fn, state, data,
                      _trainer_cfg(epochs=3, eval_every_epochs=2),
                      eval_step=ev, val_data=vd, finalize_state=fin).run()
        # epoch 2 (cadence) and epoch 3 (final epoch always evaluated)
        assert [r["epoch"] for r in res.epoch_history] == [2, 3]

    def test_val_split_disjoint_and_deterministic(self):
        from repro.data import SyntheticImageData
        tr = SyntheticImageData(10, 16, 4, seed=3, split="train")
        va = SyntheticImageData(10, 16, 4, seed=3, split="val")
        va2 = SyntheticImageData(10, 16, 4, seed=3, split="val")
        # deterministic: same (seed, split, step) -> same batch
        np.testing.assert_array_equal(va.batch_at(5)["images"],
                                      va2.batch_at(5)["images"])
        # disjoint: no val batch equals any train batch over a horizon
        val0 = va.batch_at(0)["images"]
        for step in range(50):
            assert not np.array_equal(tr.batch_at(step)["images"], val0)

    def test_legacy_run_training_unchanged(self):
        from repro.training import LoopConfig, run_training
        model, state, step_fn, data, *_ = _setup()
        res = run_training(step_fn, state, data,
                           LoopConfig(total_steps=6, log_every=2))
        assert [h["step"] for h in res.history] == [0, 2, 4, 5]
        assert res.resumed_from is None


class TestBestCheckpointRetention:
    def _fake_pieces(self, top1s):
        """Scripted eval so best-tracking logic is exercised without
        depending on a real accuracy trajectory."""
        state = {"params": {"w": jnp.zeros(2)},
                 "model_state": {"s": jnp.zeros(2)},
                 "opt": {"step": jnp.zeros((), jnp.int32)}}

        def train_step(s, batch):
            return s, {"loss": jnp.float32(0.0)}

        calls = iter(top1s)

        def eval_step(params, mstate, batch):
            return {"top1": jnp.float32(next(calls)),
                    "loss": jnp.float32(1.0)}

        class Data:
            def batch_at(self, step):
                return {"x": np.zeros(2, np.float32)}

        return state, train_step, eval_step, Data()

    def test_best_is_retained_not_last(self, tmp_path):
        from repro.checkpoint import restore_best
        ck = str(tmp_path / "ck")
        state, tstep, estep, data = self._fake_pieces([0.2, 0.8, 0.5])
        res = Trainer(tstep, state, data,
                      _trainer_cfg(epochs=3, steps_per_epoch=2,
                                   val_batches=1, checkpoint_dir=ck,
                                   checkpoint_every=2),
                      eval_step=estep, val_data=data).run()
        assert res.best == {"top1": pytest.approx(0.8), "epoch": 2,
                            "step": 4}
        _, manifest = restore_best(ck)
        assert manifest["step"] == 4
        assert manifest["metadata"]["best"]["top1"] == pytest.approx(0.8)
        # exactly one best checkpoint on disk
        from repro.checkpoint import list_checkpoints
        import os
        assert list_checkpoints(os.path.join(ck, "best")) == [4]

    def test_eval_history_in_checkpoint_metadata(self, tmp_path):
        from repro.checkpoint import restore
        ck = str(tmp_path / "ck")
        state, tstep, estep, data = self._fake_pieces([0.2, 0.8, 0.5])
        Trainer(tstep, state, data,
                _trainer_cfg(epochs=3, steps_per_epoch=2, val_batches=1,
                             checkpoint_dir=ck, checkpoint_every=2),
                eval_step=estep, val_data=data).run()
        _, manifest = restore(ck)
        hist = manifest["metadata"]["eval_history"]
        assert [r["epoch"] for r in hist] == [1, 2, 3]
        assert hist[1]["top1"] == pytest.approx(0.8)


class TestResumeEval:
    def test_resume_then_eval_matches_uninterrupted(self, tmp_path):
        """Determinism contract (DESIGN.md §5+§7): crash after epoch 2,
        resume, and the epoch-3/4 evals equal the uninterrupted run's."""
        spe = 5
        # uninterrupted 4-epoch reference
        model, state, step_fn, data, ev, vd, fin = _setup(spe)
        ref = Trainer(step_fn, state, data, _trainer_cfg(epochs=4),
                      eval_step=ev, val_data=vd, finalize_state=fin).run()

        ck = str(tmp_path / "ck")
        model, state, step_fn, data, ev, vd, fin = _setup(spe)
        Trainer(step_fn, state, data,
                _trainer_cfg(epochs=2, checkpoint_dir=ck,
                             checkpoint_every=spe),
                eval_step=ev, val_data=vd, finalize_state=fin).run()
        model, state2, step_fn2, data2, ev2, vd2, fin2 = _setup(spe)
        res = Trainer(step_fn2, state2, data2,
                      _trainer_cfg(epochs=4, checkpoint_dir=ck,
                                   checkpoint_every=spe),
                      eval_step=ev2, val_data=vd2,
                      finalize_state=fin2).run()
        assert res.resumed_from == 2 * spe
        # restored epochs 1-2 + fresh 3-4 == reference trajectory
        assert [r["epoch"] for r in res.epoch_history] == [1, 2, 3, 4]
        for a, b in zip(ref.epoch_history, res.epoch_history):
            np.testing.assert_allclose(a["top1"], b["top1"], rtol=1e-6)
            np.testing.assert_allclose(a["loss"], b["loss"], rtol=1e-5)


def run_py(body: str, timeout=420) -> str:
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         env=SUBPROCESS_ENV_8DEV, capture_output=True,
                         text=True, timeout=timeout)
    assert res.returncode == 0, f"STDERR:\n{res.stderr[-4000:]}"
    return res.stdout


def test_eval_logits_parity_gspmd_vs_shardmap():
    """Acceptance: after the paper's pre-validation BN all-reduce, the
    shard_map DP mode produces the same eval logits as GSPMD (same data,
    same init, uncompressed sync to isolate the BN path)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import OptimizerConfig, get_config, \\
            reduced_config
        from repro.data import make_data
        from repro.configs import ShapeConfig
        from repro.launch.train import build_train_setup
        from repro.training.step import finalize_worker_bn_stats
        cfg = reduced_config(get_config('resnet50'))
        mesh = jax.make_mesh((8, 1), ('data', 'model'))
        logits = {}
        vb = make_data(cfg, ShapeConfig('val', 16, 16, 'train'), seed=0,
                       split='val').batch_at(0)
        for mode in ('gspmd', 'shardmap'):
            model, state, step, data, put, _ = build_train_setup(
                cfg, global_batch=16, seq_len=16,
                opt_cfg=OptimizerConfig(), steps_per_epoch=5,
                mesh=mesh, dp_mode=mode, seed=0, sync_bn=True,
                compression='none')
            for s in range(3):
                batch = put({k: jnp.asarray(v)
                             for k, v in data.batch_at(s).items()})
                state, _ = step(state, batch)
            mstate = state['model_state']
            if mode == 'shardmap':
                assert jax.tree.leaves(
                    mstate)[0].shape[0] == 8  # per-worker stats
                mstate = finalize_worker_bn_stats(mstate)
            out_logits, _ = model.apply(
                state['params'], mstate, jnp.asarray(vb['images']),
                train=False)
            logits[mode] = np.asarray(jax.device_get(out_logits),
                                      np.float32)
        diff = np.abs(logits['gspmd'] - logits['shardmap']).max()
        print('LOGIT_DIFF', diff)
        assert diff < 1e-4, diff
    """)
    assert "LOGIT_DIFF" in out


def test_cli_epoch_driven_both_modes():
    """Acceptance: the train CLI prints per-epoch held-out top-1 in both
    --dp-mode gspmd and --dp-mode shardmap."""
    for mode in ("gspmd", "shardmap"):
        res = subprocess.run(
            [sys.executable, "-m", "repro.launch.train", "--arch",
             "resnet50", "--reduced", "--epochs", "2",
             "--eval-every-epochs", "1", "--steps-per-epoch", "3",
             "--global-batch", "16", "--val-batches", "1",
             "--dp-mode", mode],
            env=SUBPROCESS_ENV_8DEV, capture_output=True, text=True,
            timeout=420)
        assert res.returncode == 0, f"STDERR:\n{res.stderr[-4000:]}"
        lines = [l for l in res.stdout.splitlines() if "val top1" in l]
        assert len(lines) == 2, res.stdout
