"""Microbatching (gradient accumulation) and bf16 optimizer state."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import OptimizerConfig, get_config, reduced_config
from repro.models import build_model, init_model_state
from repro.optim import make_optimizer
from repro.training.step import make_train_step


def _setup(opt_cfg=None, microbatches=1):
    cfg = reduced_config(get_config("llama3.2-1b"))
    model = build_model(cfg, compute_dtype=jnp.float32,
                        attention_impl="naive", remat=False)
    opt_cfg = opt_cfg or OptimizerConfig()
    optimizer = make_optimizer(opt_cfg, steps_per_epoch=10, global_batch=8)
    params, _ = model.init_params(jax.random.PRNGKey(0))
    state = {"params": params, "opt": optimizer.init(params),
             "model_state": init_model_state(model)}
    from repro.configs import TrainConfig
    step = make_train_step(model, optimizer,
                           TrainConfig(optimizer=opt_cfg),
                           microbatches=microbatches)
    return cfg, state, jax.jit(step)


def _batch(cfg, b=8, s=32):
    rng = np.random.RandomState(0)
    return {"tokens": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s))),
            "targets": jnp.asarray(rng.randint(0, cfg.vocab_size, (b, s)))}


def test_microbatched_step_matches_full_batch():
    """mean-of-microbatch-grads == full-batch grad (mean loss)."""
    cfg, state1, step1 = _setup(microbatches=1)
    _, state4, step4 = _setup(microbatches=4)
    batch = _batch(cfg)
    new1, m1 = step1(state1, batch)
    new4, m4 = step4(state4, batch)
    # fp32 reduction-order noise amplified by the optimizer's rsqrt on
    # near-zero second moments: allow ~1% relative on rare elements
    for a, b in zip(jax.tree.leaves(new1["params"]),
                    jax.tree.leaves(new4["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-2, atol=2e-4)


def test_microbatch_metrics_are_full_batch_average():
    """Regression: the accumulation scan used to report only the LAST
    microbatch's metrics, so the logged loss depended on the microbatch
    count. Mean-of-equal-microbatch-means == full-batch mean."""
    cfg, s1, step1 = _setup(microbatches=1)
    _, s4, step4 = _setup(microbatches=4)
    batch = _batch(cfg)
    _, m1 = step1(s1, batch)
    _, m4 = step4(s4, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5)
    np.testing.assert_allclose(float(m1["moe_aux"]), float(m4["moe_aux"]),
                               rtol=1e-4, atol=1e-7)


def test_bf16_optimizer_state_trains():
    opt_cfg = OptimizerConfig(state_dtype="bfloat16")
    cfg, state, step = _setup(opt_cfg=opt_cfg)
    assert jax.tree.leaves(state["opt"]["m"])[0].dtype == jnp.bfloat16
    batch = _batch(cfg)
    losses = []
    for i in range(4):
        state, metrics = step(state, dict(batch))
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert losses[-1] < losses[0]  # memorizes the repeated batch


def test_bf16_state_close_to_f32_state():
    cfg, s32, step32 = _setup(OptimizerConfig())
    _, s16, step16 = _setup(OptimizerConfig(state_dtype="bfloat16"))
    batch = _batch(cfg)
    n32, _ = step32(s32, batch)
    n16, _ = step16(s16, batch)
    # one step from zero state: bf16 rounding only
    for a, b in zip(jax.tree.leaves(n32["params"]),
                    jax.tree.leaves(n16["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-2, atol=2e-3)
