"""Buffer donation on the jitted train steps (training/step.py:
jit_train_step): donating the state argument lets the updated
params/opt-state/BN-state reuse the input buffers — it must change
buffer lifetimes only, never results. Parity is checked in both DP
modes (GSPMD single-device jit; explicit shard_map DP on the 8-virtual-
device mesh in a subprocess)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

ENV8 = {
    **os.environ,
    "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
    "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src"),
}

_BODY = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import OptimizerConfig, get_config, reduced_config
    from repro.launch.train import build_train_setup
    from repro.training.step import jit_train_step

    cfg = reduced_config(get_config("resnet50"))
    mesh = {mesh}
    def run(donate):
        model, state, _step, data, put, _ = build_train_setup(
            cfg, global_batch=8, seq_len=16, opt_cfg=OptimizerConfig(),
            steps_per_epoch=10, mesh=mesh, dp_mode={dp_mode!r}, seed=0,
            compression={compression!r})
        # re-jit the underlying step with/without donation: the
        # build path donates by default, so rebuild the un-jitted fn
        from repro.training.step import (
            make_dp_shardmap_train_step, make_train_step)
        from repro.configs import ParallelConfig, TrainConfig
        from repro.optim import make_optimizer
        opt = make_optimizer(OptimizerConfig(), 10, 8)
        tc = TrainConfig(optimizer=OptimizerConfig(),
                         parallel=ParallelConfig(
                             dp_axes=("data",),
                             compression={compression!r}, zero_1=False))
        if {dp_mode!r} == "shardmap":
            raw = make_dp_shardmap_train_step(model, opt, tc, mesh,
                                              ("data",))
        else:
            raw = make_train_step(model, opt, tc)
        step = jit_train_step(raw, donate=donate)
        batch = data.batch_at(0)
        batch = {{k: jnp.asarray(v) for k, v in batch.items()}}
        if put is not None:
            batch = put(batch)
        for _ in range(2):
            state, metrics = step(state, dict(batch))
        return state, metrics

    s0, m0 = run(False)
    s1, m1 = run(True)
    for (k0, a), (k1, b) in zip(
            jax.tree_util.tree_leaves_with_path(s0),
            jax.tree_util.tree_leaves_with_path(s1)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=str(k0))
    np.testing.assert_array_equal(np.asarray(m0["loss"]),
                                  np.asarray(m1["loss"]))
    print("DONATION_PARITY_OK")
"""


def test_donation_parity_gspmd_single_device():
    """GSPMD mode: donated vs non-donated step, bitwise-equal state
    after 2 steps (no mesh: plain jit path)."""
    body = _BODY.format(mesh="None", dp_mode="gspmd",
                        compression="bf16")
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         env=ENV8, capture_output=True, text=True,
                         timeout=600)
    assert res.returncode == 0, f"STDERR:\n{res.stderr[-4000:]}"
    assert "DONATION_PARITY_OK" in res.stdout


def test_donation_parity_shardmap_8dev():
    """Explicit shard_map DP mode (bucketed sync) on 8 virtual devices:
    donation changes buffers only, never results."""
    body = _BODY.format(
        mesh='jax.make_mesh((8, 1), ("data", "model"))',
        dp_mode="shardmap", compression="bf16+bucketed")
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         env=ENV8, capture_output=True, text=True,
                         timeout=600)
    assert res.returncode == 0, f"STDERR:\n{res.stderr[-4000:]}"
    assert "DONATION_PARITY_OK" in res.stdout
