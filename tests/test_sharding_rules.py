"""Logical-axis sharding rules: per-arch divisibility fallbacks."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ParallelConfig, get_config
from repro.distributed.sharding import make_rules, spec_for


class FakeMesh:
    """Only .shape is consulted by the rules."""

    def __init__(self, shape):
        self.shape = dict(shape)


MESH = FakeMesh({"data": 16, "model": 16})
POD_MESH = FakeMesh({"pod": 2, "data": 16, "model": 16})
PAR = ParallelConfig(dp_axes=("data",), tp_axis="model")


def test_qwen2_full_tp():
    rules = make_rules(get_config("qwen2-72b"), MESH, PAR)
    assert rules["vocab"] == "model"  # 152064 % 16 == 0
    assert rules["heads"] == "model"  # 64 % 16
    assert rules["kv_heads"] is None  # 8 kv heads < 16 => replicate
    assert rules["ffn"] == "model"  # 29568 % 16


def test_whisper_vocab_fallback():
    rules = make_rules(get_config("whisper-tiny"), MESH, PAR)
    assert rules["vocab"] is None  # 51865 is odd
    assert rules["heads"] is None  # 6 heads < 16


def test_llama4_heads_fallback_to_embed():
    """40 heads don't divide 16 => attention weights shard on embed."""
    rules = make_rules(get_config("llama4-maverick-400b-a17b"), MESH, PAR)
    assert rules["heads"] is None
    assert rules["experts"] == "model"  # 128 % 16 == 0 => EP
    emb = rules["embed"]
    assert emb == "model" or (isinstance(emb, tuple) and "model" in emb)


def test_mixtral_experts_fallback_to_ffn_tp():
    rules = make_rules(get_config("mixtral-8x7b"), MESH, PAR)
    assert rules["experts"] is None  # 8 % 16 != 0 => TP inside experts
    assert rules["ffn"] == "model"  # 14336 % 16 == 0


def test_pod_axis_prepended():
    rules = make_rules(get_config("yi-9b"), POD_MESH, PAR)
    assert rules["batch"] == ("pod", "data")


def test_spec_for_drops_duplicate_axis():
    rules = {"experts": "model", "ffn": "model", "embed": None}
    spec = spec_for(("experts", "embed", "ffn"), rules)
    # ffn's duplicate 'model' dropped; trailing Nones trimmed
    assert tuple(spec) in ((("model",)), ("model", None))
    assert tuple(spec)[0] == "model"
    assert all(e != "model" for e in tuple(spec)[1:])


def test_granite_mqa_kv_replicated():
    rules = make_rules(get_config("granite-34b"), MESH, PAR)
    assert rules["kv_heads"] is None  # kv=1
    assert rules["heads"] == "model"  # 48 % 16


def test_fsdp_embed_rule():
    par = ParallelConfig(dp_axes=("data",), tp_axis="model",
                         fsdp_params=True)
    rules = make_rules(get_config("qwen2-72b"), MESH, par)
    assert rules["embed"] == ("data",)  # 8192 % 16 == 0
