"""Multi-(virtual-)device tests, run in subprocesses so the main test
process keeps its single-device view (XLA locks device count at init)."""
import os
import subprocess
import sys
import textwrap

import pytest

ENV = {
    **os.environ,
    "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
    "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src"),
}


def run_py(body: str, timeout=420) -> str:
    res = subprocess.run([sys.executable, "-c", textwrap.dedent(body)],
                         env=ENV, capture_output=True, text=True,
                         timeout=timeout)
    assert res.returncode == 0, f"STDERR:\n{res.stderr[-4000:]}"
    return res.stdout


def test_gspmd_train_step_sharded():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import OptimizerConfig, get_config, reduced_config
        from repro.launch.train import build_train_setup
        cfg = reduced_config(get_config('llama3.2-1b'))
        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        model, state, step, data, put, _ = build_train_setup(
            cfg, global_batch=8, seq_len=32,
            opt_cfg=OptimizerConfig(), steps_per_epoch=5, mesh=mesh)
        batch = put({k: jnp.asarray(v) for k, v in data.batch_at(0).items()})
        new_state, metrics = step(state, batch)
        assert bool(jnp.isfinite(metrics['loss']))
        print('LOSS', float(metrics['loss']))
    """)
    assert "LOSS" in out


def test_paper_faithful_shardmap_dp_matches_gspmd():
    """The explicit shard_map DP step (compressed psum) must produce the
    same training trajectory as the GSPMD step (up to wire rounding)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import OptimizerConfig, get_config, reduced_config
        from repro.launch.train import build_train_setup
        cfg = reduced_config(get_config('resnet50'))
        mesh = jax.make_mesh((8, 1), ('data', 'model'))
        losses = {}
        for mode in ('gspmd', 'shardmap'):
            # sync_bn isolates the gradient-sync comparison: without it
            # shard_map workers normalize with local-batch stats
            # (paper-faithful) and the forward passes differ by design
            model, state, step, data, put, _ = build_train_setup(
                cfg, global_batch=16, seq_len=16,
                opt_cfg=OptimizerConfig(), steps_per_epoch=5,
                mesh=mesh, dp_mode=mode, seed=0, sync_bn=True)
            ls = []
            for s in range(5):
                batch = put({k: jnp.asarray(v)
                             for k, v in data.batch_at(s).items()})
                state, metrics = step(state, batch)
                ls.append(float(metrics['loss']))
            losses[mode] = ls
        diff = max(abs(a - b) for a, b in
                   zip(losses['gspmd'], losses['shardmap']))
        print('DIFF', diff)
        assert diff < 0.05, (losses, diff)
    """)
    assert "DIFF" in out


def test_bn_stats_per_worker_and_finalize():
    """Paper §2: per-worker last-minibatch BN stats differ; the
    pre-validation all-reduce (mean over workers) equals global stats."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import OptimizerConfig, get_config, reduced_config
        from repro.launch.train import build_train_setup
        from repro.training.step import finalize_worker_bn_stats
        cfg = reduced_config(get_config('resnet50'))
        mesh = jax.make_mesh((8, 1), ('data', 'model'))
        model, state, step, data, put, _ = build_train_setup(
            cfg, global_batch=16, seq_len=16, opt_cfg=OptimizerConfig(),
            steps_per_epoch=5, mesh=mesh, dp_mode='shardmap')
        batch = put({k: jnp.asarray(v) for k, v in data.batch_at(0).items()})
        state, _ = step(state, batch)
        stats = jax.device_get(state['model_state'])
        leaf = stats['stem/bn']['mean']  # (n_workers, C)
        assert leaf.shape[0] == 8
        per_worker_var = np.var(np.asarray(leaf), axis=0).max()
        print('WORKER_VARIANCE', per_worker_var)
        assert per_worker_var > 0  # stats genuinely differ per worker
        final = finalize_worker_bn_stats(state['model_state'])
        f_leaf = final['stem/bn']['mean']
        np.testing.assert_allclose(np.asarray(f_leaf),
                                   np.asarray(leaf).mean(0), rtol=1e-6)
        print('FINALIZE_OK')
    """)
    assert "FINALIZE_OK" in out


def test_compressed_psum_wire_dtype_and_value():
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.core.compression import compressed_psum
        mesh = jax.make_mesh((8,), ('data',))
        x = jnp.linspace(-1.0, 1.0, 8 * 64).reshape(8, 64)

        def f(local):
            return compressed_psum({'g': local[0]}, ('data',),
                                   wire='f16')['g']

        fn = shard_map(f, mesh=mesh, in_specs=P('data'), out_specs=P(),
                       check_rep=False)
        got = fn(x)
        want = np.asarray(x, np.float32).mean(0)
        err = np.abs(np.asarray(got) - want).max()
        print('ERR', err)
        assert err < 2e-3  # f16 wire rounding only
        # HLO must carry the all-reduce in f16 (the paper's mechanism)
        txt = jax.jit(fn).lower(x).compile().as_text()
        ars = [l for l in txt.splitlines() if 'all-reduce' in l
               and '= f16' in l.replace(' ', ' ')]
        found_f16 = any('f16[' in l and 'all-reduce' in l
                        for l in txt.splitlines())
        print('F16_ALLREDUCE', found_f16)
        assert found_f16
    """)
    assert "F16_ALLREDUCE True" in out


def test_elastic_restore_different_dp():
    """Checkpoint at dp=8, restore and continue at dp=4 (elastic restart
    after losing nodes)."""
    out = run_py("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from repro.configs import OptimizerConfig, get_config, reduced_config
        from repro.launch.train import build_train_setup
        from repro.training import LoopConfig, run_training
        cfg = reduced_config(get_config('llama3.2-1b'))
        tmp = tempfile.mkdtemp()
        mesh8 = jax.make_mesh((4, 2), ('data', 'model'))
        model, state, step, data, put, sh = build_train_setup(
            cfg, global_batch=8, seq_len=32, opt_cfg=OptimizerConfig(),
            steps_per_epoch=5, mesh=mesh8)
        run_training(step, state, data,
                     LoopConfig(total_steps=4, checkpoint_every=2,
                                checkpoint_dir=tmp), put_batch=put)
        # 'lose half the nodes': rebuild on a (2,2) mesh and resume
        mesh4 = jax.make_mesh((2, 2), ('data', 'model'))
        model, state, step, data, put, sh = build_train_setup(
            cfg, global_batch=8, seq_len=32, opt_cfg=OptimizerConfig(),
            steps_per_epoch=5, mesh=mesh4)
        res = run_training(step, state, data,
                           LoopConfig(total_steps=8, checkpoint_every=100,
                                      checkpoint_dir=tmp),
                           put_batch=put, state_shardings=sh)
        assert res.resumed_from == 4, res.resumed_from
        print('ELASTIC_OK', res.history[-1]['loss'])
    """)
    assert "ELASTIC_OK" in out


def test_dryrun_entry_on_small_mesh():
    """The dry-run builder lowers + compiles + analyzes on a small mesh
    (full 512-device runs are exercised by launch/dryrun.py itself)."""
    out = run_py("""
        import jax, jax.numpy as jnp
        from repro.configs import get_config, reduced_config
        import repro.configs.base as base
        import dataclasses
        # register a reduced variant under a test id
        cfg = reduced_config(get_config('llama3.2-1b'))
        base._REGISTRY['test-tiny'] = lambda: dataclasses.replace(
            cfg, name='test-tiny')
        from repro.launch.dryrun import lower_cell
        mesh = jax.make_mesh((4, 2), ('data', 'model'))
        rec, compiled = lower_cell('test-tiny', 'train_4k', mesh)
        assert rec['status'] == 'ok', rec
        assert rec['roofline']['bound_s'] > 0
        assert rec['collective_total_bytes'] > 0
        print('DRYRUN_OK', rec['roofline']['dominant'])
    """, timeout=560)
    assert "DRYRUN_OK" in out
