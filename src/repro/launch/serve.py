"""Batched serving driver: prefill + decode with a KV/SSM cache.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --reduced --batch 4 --prompt-len 32 --decode-steps 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import build_model
from repro.training.step import make_decode_step, make_prefill_step


def serve(cfg, batch: int, prompt_len: int, decode_steps: int,
          seed: int = 0, compute_dtype=jnp.float32,
          greedy: bool = True):
    model = build_model(cfg, compute_dtype=compute_dtype,
                        attention_impl="naive", remat=False)
    key = jax.random.PRNGKey(seed)
    params, _ = model.init_params(key)
    max_seq = prompt_len + decode_steps
    cache, _ = model.cache_shape(batch, max_seq, compute_dtype)

    rng = np.random.RandomState(seed)
    prompts = rng.randint(0, cfg.vocab_size, size=(batch, prompt_len))
    batch_in = {"tokens": jnp.asarray(prompts, jnp.int32)}
    if cfg.audio is not None:
        batch_in["frames"] = jnp.asarray(
            rng.randn(batch, cfg.audio.num_frames, cfg.audio.frame_dim),
            compute_dtype)
    if cfg.vision is not None:
        batch_in["patches"] = jnp.asarray(
            rng.randn(batch, cfg.vision.num_patches, cfg.vision.patch_dim),
            compute_dtype)

    prefill = jax.jit(make_prefill_step(model), donate_argnums=(1,))
    decode = jax.jit(make_decode_step(model), donate_argnums=(1,))

    t0 = time.time()
    logits, cache = prefill(params, cache, batch_in)
    logits.block_until_ready()
    t_prefill = time.time() - t0

    tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out = [tokens]
    t0 = time.time()
    for i in range(decode_steps - 1):
        step_batch = {"tokens": tokens,
                      "cache_index": jnp.int32(prompt_len + i)}
        logits, cache = decode(params, cache, step_batch)
        tokens = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(tokens)
    jax.block_until_ready(tokens)
    t_decode = time.time() - t0
    generated = jnp.concatenate(out, axis=1)
    return {
        "generated": np.asarray(generated),
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "decode_tok_per_s": batch * (decode_steps - 1) / max(t_decode, 1e-9),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-steps", type=int, default=16)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    res = serve(cfg, args.batch, args.prompt_len, args.decode_steps)
    print(f"prefill: {res['prefill_s']*1e3:.1f} ms   "
          f"decode: {res['decode_tok_per_s']:.1f} tok/s")
    print("sample tokens:", res["generated"][0][:12])


if __name__ == "__main__":
    main()
