"""End-to-end training driver.

Runs real training (synthetic data) on whatever devices exist — reduced
configs on CPU for the examples/tests, full configs on a TPU pod with the
same code path. Demonstrates the paper's full recipe: hybrid RMSprop
warm-up, slow-start LR, compressed gradient sync, BN handling, async
checkpointing and resume.

    PYTHONPATH=src python -m repro.launch.train --arch resnet50 --reduced \
        --steps 100 --global-batch 64 --optimizer rmsprop_warmup
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (
    OptimizerConfig,
    ParallelConfig,
    ShapeConfig,
    TrainConfig,
    get_config,
    reduced_config,
)
from repro.data import make_data
from repro.distributed.sharding import make_rules, tree_shardings
from repro.models import build_model, init_model_state
from repro.models.common import unbox
from repro.optim import make_optimizer
from repro.training import LoopConfig, run_training
from repro.training.step import (
    make_dp_shardmap_train_step,
    make_eval_step,
    make_train_step,
)


def build_train_setup(cfg, *, global_batch: int, seq_len: int,
                      opt_cfg: OptimizerConfig, steps_per_epoch: int,
                      mesh=None, dp_mode: str = "gspmd",
                      compute_dtype=jnp.float32, attention_impl="naive",
                      seed: int = 0, use_fused_kernel: bool = False,
                      sync_bn: bool = False, compression: str = "bf16",
                      bucket_bytes: int = 64 * 1024 * 1024,
                      error_feedback: bool = False):
    """Returns (state, train_step, data, put_batch, state_shardings)."""
    shape = ShapeConfig("train", seq_len, global_batch, "train")
    parallel = ParallelConfig(
        dp_axes=("data",), tp_axis="model" if mesh is not None else None,
        compression=compression, bucket_bytes=bucket_bytes,
        error_feedback=error_feedback, zero_1=False)
    if cfg.family == "conv" and dp_mode == "shardmap" and sync_bn:
        from repro.models.resnet import ResNet50
        model = ResNet50(cfg, compute_dtype=compute_dtype,
                         cross_replica_bn=parallel.dp_axes)
    else:
        model = build_model(cfg, compute_dtype=compute_dtype,
                            attention_impl=attention_impl,
                            remat=cfg.n_layers > 8)
    train_cfg = TrainConfig(optimizer=opt_cfg, parallel=parallel)
    optimizer = make_optimizer(opt_cfg, steps_per_epoch, global_batch,
                               use_fused=use_fused_kernel)

    key = jax.random.PRNGKey(seed)
    params, axes = model.init_params(key)
    mstate = init_model_state(model)
    ef_residual = None
    if dp_mode == "shardmap" and mesh is not None:
        from repro.training.step import replicate_model_state
        n_workers = 1
        for a in parallel.dp_axes:
            n_workers *= mesh.shape[a]
        mstate = replicate_model_state(mstate, n_workers)
        if error_feedback:
            from repro.core.compression import init_error_feedback
            # per-worker residuals, leading worker dim like the BN stats
            ef_residual = replicate_model_state(
                init_error_feedback(params), n_workers)
    elif error_feedback:
        raise ValueError(
            "error_feedback is only implemented for the explicit "
            "shard_map DP mode on a mesh (dp_mode='shardmap'); the "
            "GSPMD path has no worker-local gradients to correct")
    opt_state = optimizer.init(params)
    state = {"params": params, "opt": opt_state, "model_state": mstate}
    if ef_residual is not None:
        state["ef_residual"] = ef_residual

    rules = None
    state_shardings = None
    put_batch = None
    if mesh is not None:
        rules = make_rules(cfg, mesh, parallel)
        if dp_mode == "shardmap":
            step = make_dp_shardmap_train_step(model, optimizer, train_cfg,
                                               mesh, parallel.dp_axes)
            batch_sharding = NamedSharding(mesh, P(parallel.dp_axes))

            def put_batch(batch):
                return {k: jax.device_put(v, batch_sharding if
                                          np.ndim(v) else None)
                        for k, v in batch.items()}

            train_step = jax.jit(step, donate_argnums=(0,))
        else:
            p_shard = tree_shardings(axes, mesh, rules)
            state_shardings = {
                "params": p_shard,
                "opt": {"step": NamedSharding(mesh, P()),
                        **{f: p_shard for f in optimizer.state_fields}},
                "model_state": jax.tree.map(
                    lambda _: NamedSharding(mesh, P()), mstate),
            }
            state = jax.device_put(state, state_shardings)
            step = make_train_step(model, optimizer, train_cfg, mesh, rules)
            batch_sharding = NamedSharding(mesh, P(parallel.dp_axes))

            def put_batch(batch):
                return {k: jax.device_put(v, batch_sharding if
                                          np.ndim(v) else None)
                        for k, v in batch.items()}

            train_step = jax.jit(step, donate_argnums=(0,))
    else:
        step = make_train_step(model, optimizer, train_cfg)
        train_step = jax.jit(step, donate_argnums=(0,))

    data = make_data(cfg, shape, seed=seed)
    return model, state, train_step, data, put_batch, state_shardings


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="resnet50")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--global-batch", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--optimizer", default="rmsprop_warmup",
                    choices=["rmsprop_warmup", "momentum_sgd", "lars"])
    ap.add_argument("--schedule", default="slow_start",
                    choices=["slow_start", "goyal", "constant"])
    ap.add_argument("--steps-per-epoch", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default=None,
                    help="DxM virtual mesh, e.g. 4x2 (needs XLA_FLAGS)")
    ap.add_argument("--dp-mode", default="gspmd",
                    choices=["gspmd", "shardmap"])
    ap.add_argument("--compression", default="bf16",
                    help="gradient sync wire format: none|bf16|f16|"
                         "bf16+bucketed|f16+bucketed (DESIGN.md §2/§6)")
    ap.add_argument("--bucket-mib", type=int, default=64,
                    help="bucket size in MiB for the +bucketed modes")
    ap.add_argument("--error-feedback", action="store_true")
    ap.add_argument("--use-fused-kernel", action="store_true")
    ap.add_argument("--log-json", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))

    opt_cfg = OptimizerConfig(kind=args.optimizer, schedule=args.schedule)
    model, state, train_step, data, put_batch, shardings = \
        build_train_setup(
            cfg, global_batch=args.global_batch, seq_len=args.seq_len,
            opt_cfg=opt_cfg, steps_per_epoch=args.steps_per_epoch,
            mesh=mesh, dp_mode=args.dp_mode, seed=args.seed,
            use_fused_kernel=args.use_fused_kernel,
            compression=args.compression,
            bucket_bytes=args.bucket_mib * 1024 * 1024,
            error_feedback=args.error_feedback)

    loop_cfg = LoopConfig(total_steps=args.steps,
                          checkpoint_every=args.ckpt_every,
                          checkpoint_dir=args.ckpt_dir,
                          log_every=max(1, args.steps // 20))
    t0 = time.time()
    result = run_training(train_step, state, data, loop_cfg,
                          put_batch=put_batch,
                          metadata={"arch": args.arch,
                                    "optimizer": args.optimizer},
                          state_shardings=shardings)
    wall = time.time() - t0
    print(f"trained {args.steps} steps in {wall:.1f}s "
          f"(resumed_from={result.resumed_from})")
    for h in result.history:
        print(f"  step {h['step']:5d} loss {h['loss']:.4f} "
              f"({h['time']*1e3:.0f} ms)")
    if result.straggler_events:
        print(f"straggler events: {len(result.straggler_events)}")
    if args.log_json:
        with open(args.log_json, "w") as f:
            json.dump({"history": result.history, "wall": wall,
                       "resumed_from": result.resumed_from}, f)


if __name__ == "__main__":
    main()
