"""End-to-end training driver.

Runs real training (synthetic data) on whatever devices exist — reduced
configs on CPU for the examples/tests, full configs on a TPU pod with the
same code path. Demonstrates the paper's full recipe: hybrid RMSprop
warm-up, slow-start LR, compressed gradient sync, BN handling, async
checkpointing and resume.

    PYTHONPATH=src python -m repro.launch.train --arch resnet50 --reduced \
        --steps 100 --global-batch 64 --optimizer rmsprop_warmup
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import (
    InputConfig,
    OptimizerConfig,
    ParallelConfig,
    ShapeConfig,
    TrainConfig,
    get_config,
    reduced_config,
)
from repro.data import AugmentedSource, StepStampSource, make_data
from repro.distributed.sharding import make_rules, tree_shardings
from repro.models import build_model, init_model_state
from repro.models.common import unbox
from repro.optim import make_optimizer
from repro.training import (
    LoopConfig,
    Trainer,
    TrainerConfig,
    run_training,
)
from repro.training.step import (
    finalize_worker_bn_stats,
    jit_train_step,
    make_dp_shardmap_train_step,
    make_eval_step,
    make_train_step,
)


def build_train_setup(cfg, *, global_batch: int, seq_len: int,
                      opt_cfg: OptimizerConfig, steps_per_epoch: int,
                      mesh=None, dp_mode: str = "gspmd",
                      compute_dtype=jnp.float32, attention_impl="naive",
                      seed: int = 0, use_fused_kernel: bool = False,
                      sync_bn: bool = False, compression: str = "bf16",
                      bucket_bytes: int = 64 * 1024 * 1024,
                      error_feedback: bool = False,
                      overlap_comm: bool = False,
                      zero_dp: bool = False,
                      fused_bn: bool = False,
                      label_smoothing: float = 0.0,
                      data_noise: Optional[float] = None,
                      sentinel: bool = False,
                      dp_axes=("data",),
                      hier_split: Optional[int] = None,
                      input_cfg: Optional[InputConfig] = None):
    """Returns (model, state, train_step, data, put_batch,
    state_shardings).

    ``data_noise``: difficulty of the synthetic image task (None = the
    pipeline default); the recipe/ablation proxies raise it so training
    is still in progress at the schedule-transition epochs.

    ``input_cfg``: production input pipeline (DESIGN.md §15). Selects
    this host's shard of the global batch (``num_hosts``/``host_id``),
    turns on per-sample augmentation, and with ``fused=True`` moves
    augment+normalize+cast onto the device as one Pallas pass inside
    the shard_map local step (shard_map DP + conv only; the host
    AugmentedSource path covers every other mode).

    ``sentinel``: wrap the train step with the divergence sentinel
    (resilience/sentinel.py, DESIGN.md §13) — the jitted step becomes
    the 3-arg ``(state, batch, controls)`` form that the Trainer's
    recovery state machine drives. On the GSPMD path this forces
    ``log_grad_norm`` on (the one extra tree reduction documented
    there); the shard_map modes already get the norm free from the
    packed gradient stream.
    """
    if fused_bn:
        if cfg.family != "conv":
            raise ValueError(
                "--fused-bn fuses the ResNet BN sites (Pallas kernels, "
                f"DESIGN.md §10); arch family {cfg.family!r} has no BN")
        cfg = dataclasses.replace(cfg, fused_bn=True)
    shape = ShapeConfig("train", seq_len, global_batch, "train")
    dp_axes = tuple(dp_axes)
    if hier_split is not None and dp_mode != "shardmap":
        raise ValueError(
            "hier_split reschedules explicit per-bucket collectives, "
            "which only exist in the shard_map DP mode "
            "(dp_mode='shardmap', DESIGN.md §14)")
    # pure DP spans every mesh axis under a hierarchical schedule (the
    # paper's ResNet regime); otherwise "model" stays the TP axis
    tp_axis = ("model" if mesh is not None and "model" not in dp_axes
               else None)
    parallel = ParallelConfig(
        dp_axes=dp_axes, tp_axis=tp_axis,
        compression=compression, bucket_bytes=bucket_bytes,
        error_feedback=error_feedback, overlap_comm=overlap_comm,
        zero_dp=zero_dp, zero_1=False, hier_split=hier_split)
    if overlap_comm and dp_mode != "shardmap":
        raise ValueError(
            "overlap_comm launches explicit per-bucket collectives inside "
            "the backward pass, which only exists in the shard_map DP "
            "mode (dp_mode='shardmap', DESIGN.md §8)")
    if zero_dp and dp_mode != "shardmap":
        raise ValueError(
            "--zero reduce-scatters explicit per-bucket collectives, "
            "which only exist in the shard_map DP mode "
            "(dp_mode='shardmap'; GSPMD has zero_1 sharding constraints "
            "instead, DESIGN.md §9)")
    if cfg.family == "conv" and dp_mode == "shardmap" and sync_bn:
        from repro.models.resnet import ResNet50
        model = ResNet50(cfg, compute_dtype=compute_dtype,
                         cross_replica_bn=parallel.dp_axes)
    else:
        model = build_model(cfg, compute_dtype=compute_dtype,
                            attention_impl=attention_impl,
                            remat=cfg.n_layers > 8)
    if input_cfg is not None and input_cfg.fused:
        if cfg.family != "conv":
            raise ValueError(
                "fused input (Pallas augment+normalize+cast) transforms "
                f"image batches; arch family {cfg.family!r} has none "
                "(DESIGN.md §15)")
        if dp_mode != "shardmap" or mesh is None:
            raise ValueError(
                "fused input slices per-worker augmentation parameters "
                "with lax.axis_index, which only exists inside the "
                "shard_map DP step (dp_mode='shardmap', DESIGN.md §15); "
                "use the host AugmentedSource path (fused=False) "
                "elsewhere")
    train_cfg = TrainConfig(optimizer=opt_cfg, parallel=parallel,
                            label_smoothing=label_smoothing,
                            input=input_cfg,
                            # sentinel needs grad_norm as its whole-
                            # gradient health flag; GSPMD is the only
                            # mode where it is not already free
                            log_grad_norm=sentinel and dp_mode != "shardmap")
    from repro.core.compression import parse_compression
    _, bucketed = parse_compression(compression)
    # packed-stream optimizer layout: always under --zero; also for LARS
    # on the explicit bucketed DP paths (stream-LARS, DESIGN.md §11)
    use_stream = zero_dp or (opt_cfg.kind == "lars"
                             and dp_mode == "shardmap"
                             and mesh is not None and bucketed)
    if use_stream:
        from repro.optim.stream import make_stream_optimizer
        optimizer = make_stream_optimizer(opt_cfg, steps_per_epoch,
                                          global_batch,
                                          use_fused=use_fused_kernel)
    else:
        optimizer = make_optimizer(opt_cfg, steps_per_epoch, global_batch,
                                   use_fused=use_fused_kernel)

    key = jax.random.PRNGKey(seed)
    params, axes = model.init_params(key)
    mstate = init_model_state(model)
    ef_residual = None
    if dp_mode == "shardmap" and mesh is not None:
        from repro.training.step import replicate_model_state
        n_workers = 1
        for a in parallel.dp_axes:
            n_workers *= mesh.shape[a]
        mstate = replicate_model_state(mstate, n_workers)
        if error_feedback:
            from repro.core.compression import init_error_feedback
            # per-worker residuals, leading worker dim like the BN stats
            ef_residual = replicate_model_state(
                init_error_feedback(params), n_workers)
    elif error_feedback:
        raise ValueError(
            "error_feedback is only implemented for the explicit "
            "shard_map DP mode on a mesh (dp_mode='shardmap'); the "
            "GSPMD path has no worker-local gradients to correct")
    if zero_dp and mesh is None:
        raise ValueError(
            "--zero shards the optimizer update over a DP mesh; "
            "pass a mesh (dp_mode='shardmap' builds a pure-DP one "
            "by default in the CLI)")
    if hasattr(optimizer, "update_shard"):
        # flat stream state (optim/stream.py): shard layout under --zero
        # (DESIGN.md §9), full replicated stream for stream-LARS — the
        # padded length is the same either way
        from repro.optim.stream import zero_padded_total
        opt_state = optimizer.init(zero_padded_total(
            params, compression, bucket_bytes, n_workers))
    else:
        opt_state = optimizer.init(params)
    state = {"params": params, "opt": opt_state, "model_state": mstate}
    if ef_residual is not None:
        state["ef_residual"] = ef_residual

    def _finalize_step(step):
        # sentinel wraps OUTSIDE the sync-mode builder and INSIDE jit:
        # the skip gate must live in the compiled program because the
        # jitted step donates its input state (DESIGN.md §13)
        if sentinel:
            from repro.resilience.sentinel import wrap_step_with_sentinel
            step = wrap_step_with_sentinel(step)
        return jit_train_step(step)

    rules = None
    state_shardings = None
    put_batch = None
    if mesh is not None:
        rules = make_rules(cfg, mesh, parallel)
        batch_sharding = NamedSharding(mesh, P(parallel.dp_axes))

        def put_batch(batch):
            return {k: jax.device_put(v, batch_sharding if
                                      np.ndim(v) else None)
                    for k, v in batch.items()}

        if dp_mode == "shardmap":
            from repro.training.step import make_batch_input_transform
            input_transform = make_batch_input_transform(
                input_cfg, seed, model, mesh, parallel.dp_axes)
            if overlap_comm:
                from repro.training.step import make_dp_overlap_train_step
                step = make_dp_overlap_train_step(
                    model, optimizer, train_cfg, mesh, parallel.dp_axes,
                    input_transform=input_transform)
            else:
                step = make_dp_shardmap_train_step(
                    model, optimizer, train_cfg, mesh, parallel.dp_axes,
                    input_transform=input_transform)
            train_step = _finalize_step(step)
        else:
            p_shard = tree_shardings(axes, mesh, rules)
            state_shardings = {
                "params": p_shard,
                "opt": {"step": NamedSharding(mesh, P()),
                        **{f: p_shard for f in optimizer.state_fields}},
                "model_state": jax.tree.map(
                    lambda _: NamedSharding(mesh, P()), mstate),
            }
            state = jax.device_put(state, state_shardings)
            step = make_train_step(model, optimizer, train_cfg, mesh, rules)
            train_step = _finalize_step(step)
    else:
        step = make_train_step(model, optimizer, train_cfg)
        train_step = _finalize_step(step)

    data = _wrap_train_source(
        make_data(cfg, shape, seed=seed, noise=data_noise,
                  num_hosts=input_cfg.num_hosts if input_cfg else 1,
                  host_id=input_cfg.host_id if input_cfg else 0),
        input_cfg, seed=seed, global_batch=global_batch,
        is_conv=cfg.family == "conv")
    return model, state, train_step, data, put_batch, state_shardings


def _wrap_train_source(data, input_cfg, *, seed, global_batch, is_conv):
    """Apply the input pipeline's host-side wrappers (DESIGN.md §15):
    fused -> stamp each batch with its step (the kernel's seed material);
    host augmentation -> numpy mirror of the fused transform."""
    if input_cfg is None or not is_conv:
        return data
    if input_cfg.fused:
        return StepStampSource(data)
    if input_cfg.augment:
        return AugmentedSource(data, seed=seed, mean=input_cfg.mean,
                               std=input_cfg.std,
                               max_shift=input_cfg.max_shift, train=True,
                               global_batch=global_batch)
    return AugmentedSource(data, seed=seed, mean=input_cfg.mean,
                           std=input_cfg.std, train=False,
                           global_batch=global_batch)


def build_eval_setup(model, cfg, *, global_batch: int, seq_len: int,
                     dp_mode: str = "gspmd", mesh=None, seed: int = 0,
                     data_noise: Optional[float] = None,
                     input_cfg: Optional[InputConfig] = None):
    """Validation pieces for ``Trainer``: (eval_step, val_data, finalize).

    The eval step is one plain-jit program for both execution modes
    (DESIGN.md §7): under GSPMD the model_state statistics are already
    global, under shard_map DP ``finalize_worker_bn_stats`` performs the
    paper's pre-validation all-reduce first, and either way the step
    sees worker-free statistics. ``val_data`` is the deterministic
    held-out split (seed-space disjoint from train by construction).

    With ``input_cfg``, validation applies the eval input variant
    (normalize+cast, no augmentation — DESIGN.md §15): on device via the
    fused Pallas kernel when ``fused=True``, else on the host feed.
    """
    shape = ShapeConfig("val", seq_len, global_batch, "train")
    val_data = make_data(cfg, shape, seed=seed, split="val",
                         noise=data_noise)
    fused_input = (input_cfg is not None and input_cfg.fused
                   and cfg.family == "conv")
    if input_cfg is not None and cfg.family == "conv" and not fused_input:
        val_data = AugmentedSource(val_data, seed=seed,
                                   mean=input_cfg.mean, std=input_cfg.std,
                                   train=False, global_batch=global_batch)
    rules = None
    eval_mesh = None
    finalize = None
    if mesh is not None:
        if dp_mode == "shardmap":
            # params/stats replicated after finalize: plain jit evals
            finalize = jax.jit(finalize_worker_bn_stats)
        else:
            # GSPMD: keep the activation-sharding hints so validation
            # stays partitioned like training (TP models especially)
            parallel = ParallelConfig(dp_axes=("data",), tp_axis="model",
                                      zero_1=False)
            rules = make_rules(cfg, mesh, parallel)
            eval_mesh = mesh
    base_eval = make_eval_step(model, mesh=eval_mesh, rules=rules)
    if fused_input:
        from repro.kernels import ops
        mean = jnp.asarray(input_cfg.mean, jnp.float32)
        inv_std = 1.0 / jnp.asarray(input_cfg.std, jnp.float32)
        out_dtype = getattr(model, "compute_dtype", jnp.bfloat16)

        def eval_with_input(params, model_state, batch):
            batch = dict(batch)
            batch["images"] = ops.fused_input_eval(
                batch["images"], mean, inv_std, out_dtype=out_dtype)
            return base_eval(params, model_state, batch)

        eval_step = jax.jit(eval_with_input)
    else:
        eval_step = jax.jit(base_eval)
    return eval_step, val_data, finalize


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="resnet50")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50,
                    help="legacy step-driven run (no validation); "
                         "ignored when --epochs is given")
    ap.add_argument("--epochs", type=int, default=None,
                    help="epoch-driven run: train "
                         "epochs*steps-per-epoch steps with held-out "
                         "validation at epoch boundaries (DESIGN.md §7)")
    ap.add_argument("--eval-every-epochs", type=int, default=1)
    ap.add_argument("--val-batches", type=int, default=4)
    ap.add_argument("--global-batch", type=int, default=32)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--optimizer", default="rmsprop_warmup",
                    choices=["rmsprop_warmup", "momentum_sgd", "lars"])
    ap.add_argument("--schedule", default="slow_start",
                    choices=["slow_start", "goyal", "poly", "constant"])
    ap.add_argument("--label-smoothing", type=float, default=0.0,
                    help="label smoothing epsilon (large-batch recipes "
                         "pair it with --schedule poly)")
    ap.add_argument("--steps-per-epoch", type=int, default=20)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh", default=None,
                    help="DxM virtual mesh, e.g. 4x2 (needs XLA_FLAGS)")
    ap.add_argument("--dp-mode", default="gspmd",
                    choices=["gspmd", "shardmap"])
    ap.add_argument("--compression", default="bf16",
                    help="gradient sync wire format: none|bf16|f16|"
                         "bf16+bucketed|f16+bucketed (DESIGN.md §2/§6)")
    ap.add_argument("--bucket-mib", type=int, default=64,
                    help="bucket size in MiB for the +bucketed modes")
    ap.add_argument("--error-feedback", action="store_true")
    ap.add_argument("--overlap-comm", action="store_true",
                    help="launch each gradient bucket's all-reduce as "
                         "soon as the backward pass produces its leaves "
                         "(shard_map DP only, DESIGN.md §8)")
    ap.add_argument("--zero", action="store_true",
                    help="ZeRO sync: reduce-scatter each packed bucket, "
                         "shard the optimizer update over the DP ranks, "
                         "all-gather the updated params (shard_map DP + "
                         "bucketed compression, DESIGN.md §9; composes "
                         "with --overlap-comm)")
    ap.add_argument("--comm-plan", default="flat",
                    help="collective schedule: flat | hier[:k] | auto | "
                         "<path>. 'hier:k' splits dp_axes at k into an "
                         "intra-axis reduce-scatter -> inter-axis "
                         "all-reduce -> intra-axis all-gather pipeline; "
                         "'auto' loads the autotuner's persisted plan "
                         "for this mesh (results/comm_plan_*.json, "
                         "benchmarks/comm_bench.py) and applies its "
                         "full wire config (DESIGN.md §14)")
    ap.add_argument("--use-fused-kernel", action="store_true")
    ap.add_argument("--fused-bn", action="store_true",
                    help="fused Pallas BN at every ResNet BN site: "
                         "one-pass stats + normalize/ReLU/residual "
                         "epilogue + fused custom-VJP backward "
                         "(kernels/fused_bn.py, DESIGN.md §10)")
    ap.add_argument("--data-workers", type=int, default=1,
                    help="host input-producer threads feeding the "
                         "step-ordered prefetch buffer (data/pipeline.py,"
                         " DESIGN.md §15)")
    ap.add_argument("--fused-input", action="store_true",
                    help="one-pass Pallas augment+normalize+cast on "
                         "device instead of the host feed "
                         "(kernels/fused_input.py; shard_map DP + conv "
                         "archs, DESIGN.md §15)")
    ap.add_argument("--host-shard", default=None, metavar="H/N",
                    help="per-host input sharding: this host generates "
                         "only shard H of N of every global batch, e.g. "
                         "0/4 (deterministic slice of the (seed, split, "
                         "step) contract, DESIGN.md §15)")
    ap.add_argument("--sentinel", action="store_true",
                    help="divergence sentinel + recovery state machine: "
                         "skip non-finite/spiking steps in-jit, roll "
                         "back to the last good checkpoint after "
                         "repeated bad steps (DESIGN.md §13; needs "
                         "--epochs and, for rollback, --ckpt-dir)")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="deterministic fault injection, e.g. "
                         "'nan_grad@6,ckpt_truncate@10,seed=3' "
                         "(resilience/chaos.py grammar; implies "
                         "--sentinel)")
    ap.add_argument("--event-log", default=None,
                    help="JSONL path for resilience events")
    ap.add_argument("--log-json", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.chaos:
        args.sentinel = True
    if args.sentinel and args.epochs is None:
        ap.error("--sentinel/--chaos need the epoch-driven loop: "
                 "pass --epochs")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    mesh = None
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
        mesh = jax.make_mesh((d, m), ("data", "model"))
    elif args.dp_mode == "shardmap":
        # explicit DP needs a mesh; default to pure-DP over all devices
        mesh = jax.make_mesh((jax.device_count(), 1), ("data", "model"))

    opt_cfg = OptimizerConfig(kind=args.optimizer, schedule=args.schedule)
    input_cfg = None
    if args.fused_input or args.host_shard:
        num_hosts, host_id = 1, 0
        if args.host_shard:
            try:
                host_id, num_hosts = (int(x)
                                      for x in args.host_shard.split("/"))
            except ValueError:
                ap.error("--host-shard expects H/N, e.g. 0/4")
        input_cfg = InputConfig(fused=args.fused_input,
                                num_workers=args.data_workers,
                                num_hosts=num_hosts, host_id=host_id)
    # --comm-plan: resolve the collective schedule (DESIGN.md §14).
    # Grammar forms (flat / hier[:k]) only reschedule; a plan loaded
    # from disk (auto / path) carries the autotuner's full wire config.
    dp_axes = ("data",)
    hier_split = None
    compression = args.compression
    bucket_bytes = args.bucket_mib * 1024 * 1024
    overlap_comm, zero_dp = args.overlap_comm, args.zero
    if args.comm_plan != "flat":
        if mesh is None:
            ap.error("--comm-plan needs a mesh (--mesh DxM, or "
                     "--dp-mode shardmap's default pure-DP mesh)")
        if args.dp_mode != "shardmap":
            ap.error("--comm-plan reschedules explicit per-bucket "
                     "collectives: pass --dp-mode shardmap")
        from repro.distributed.comm_plan import resolve_comm_plan
        mesh_shape = tuple(mesh.shape[a] for a in mesh.axis_names)
        plan = resolve_comm_plan(args.comm_plan, arch=args.arch,
                                 mesh_shape=mesh_shape,
                                 dp_axes=tuple(mesh.axis_names))
        if plan is not None:
            hier_split = plan.hier_split
            if hier_split is not None:
                dp_axes = plan.dp_axes  # pure DP over the whole mesh
            if plan.bucket_bytes:  # loaded plan: apply its wire config
                compression = plan.compression
                bucket_bytes = plan.bucket_bytes
                overlap_comm = plan.sync_mode in ("overlap",
                                                  "zero_overlap")
                zero_dp = plan.sync_mode in ("zero", "zero_overlap")
            print(f"comm plan: {plan.describe()}")

    model, state, train_step, data, put_batch, shardings = \
        build_train_setup(
            cfg, global_batch=args.global_batch, seq_len=args.seq_len,
            opt_cfg=opt_cfg, steps_per_epoch=args.steps_per_epoch,
            mesh=mesh, dp_mode=args.dp_mode, seed=args.seed,
            use_fused_kernel=args.use_fused_kernel,
            compression=compression,
            bucket_bytes=bucket_bytes,
            error_feedback=args.error_feedback,
            overlap_comm=overlap_comm, zero_dp=zero_dp,
            fused_bn=args.fused_bn,
            label_smoothing=args.label_smoothing,
            sentinel=args.sentinel,
            dp_axes=dp_axes, hier_split=hier_split,
            input_cfg=input_cfg)

    metadata = {"arch": args.arch, "optimizer": args.optimizer,
                "opt_layout": "zero_stream" if zero_dp else "tree"}
    t0 = time.time()
    if args.epochs is not None:
        # ---- epoch-driven train/eval (the paper's actual protocol) ----
        eval_step, val_data, finalize = build_eval_setup(
            model, cfg, global_batch=args.global_batch,
            seq_len=args.seq_len, dp_mode=args.dp_mode, mesh=mesh,
            seed=args.seed, input_cfg=input_cfg)
        total_steps = args.epochs * args.steps_per_epoch
        tcfg = TrainerConfig(
            epochs=args.epochs, steps_per_epoch=args.steps_per_epoch,
            eval_every_epochs=args.eval_every_epochs,
            val_batches=args.val_batches,
            checkpoint_every=args.ckpt_every if args.ckpt_dir else 0,
            checkpoint_dir=args.ckpt_dir,
            data_workers=args.data_workers,
            log_every=max(1, total_steps // 20))
        resilience = chaos = None
        if args.sentinel:
            from repro.resilience import ResilienceConfig, parse_chaos
            resilience = ResilienceConfig(event_log=args.event_log)
            if args.chaos:
                chaos = parse_chaos(args.chaos, seed=args.seed)
        result = Trainer(train_step, state, data, tcfg,
                         eval_step=eval_step, val_data=val_data,
                         finalize_state=finalize, put_batch=put_batch,
                         metadata=metadata,
                         state_shardings=shardings,
                         resilience=resilience, chaos=chaos).run()
        wall = time.time() - t0
        print(f"trained {args.epochs} epochs x {args.steps_per_epoch} "
              f"steps in {wall:.1f}s (dp_mode={args.dp_mode}, "
              f"resumed_from={result.resumed_from})")
        if result.events:
            kinds: Dict[str, int] = {}
            for r in result.events:
                kinds[r["kind"]] = kinds.get(r["kind"], 0) + 1
            print("resilience events: " + ", ".join(
                f"{k}={v}" for k, v in sorted(kinds.items())))
        for r in result.epoch_history:
            top1 = r.get("top1")  # LM archs eval loss only
            t = f"val top1 {top1:.4f} " if top1 is not None else ""
            print(f"  epoch {r['epoch']:3d} {t}"
                  f"val loss {r['loss']:.4f}")
        if result.best:
            print(f"best: top1 {result.best['top1']:.4f} at epoch "
                  f"{result.best['epoch']}")
        if args.log_json:
            with open(args.log_json, "w") as f:
                json.dump({"history": result.history,
                           "epoch_history": result.epoch_history,
                           "best": result.best, "wall": wall,
                           "resumed_from": result.resumed_from,
                           "events": result.events}, f)
        return

    # ---- legacy step-driven run (no validation) ----
    loop_cfg = LoopConfig(total_steps=args.steps,
                          checkpoint_every=args.ckpt_every,
                          checkpoint_dir=args.ckpt_dir,
                          data_workers=args.data_workers,
                          log_every=max(1, args.steps // 20))
    result = run_training(train_step, state, data, loop_cfg,
                          put_batch=put_batch, metadata=metadata,
                          state_shardings=shardings)
    wall = time.time() - t0
    print(f"trained {args.steps} steps in {wall:.1f}s "
          f"(resumed_from={result.resumed_from})")
    for h in result.history:
        print(f"  step {h['step']:5d} loss {h['loss']:.4f} "
              f"({h['time']*1e3:.0f} ms)")
    if result.straggler_events:
        print(f"straggler events: {len(result.straggler_events)}")
    if args.log_json:
        with open(args.log_json, "w") as f:
            json.dump({"history": result.history, "wall": wall,
                       "resumed_from": result.resumed_from}, f)


if __name__ == "__main__":
    main()
