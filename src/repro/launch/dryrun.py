import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST be the first two lines: jax locks the device count on first init.
# Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell
# on the production meshes, record memory/cost/collective analyses for the
# roofline (EXPERIMENTS.md section Dry-run / Roofline).
#
#     PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b \
#         --shape train_4k [--multi-pod] [--out results/dryrun]
#
# Results are cached per cell as JSON; reruns skip completed cells unless
# --force.

import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import (
    ASSIGNED_ARCHS,
    OptimizerConfig,
    ParallelConfig,
    TrainConfig,
    get_config,
    shapes_for,
)
from repro.distributed.sharding import make_rules, spec_for, tree_shardings
from repro.analysis import quick_audit
from repro.launch.hlo_analysis import Analysis, analyze_hlo, comm_report
from repro.launch.mesh import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    cell_parallel,
    make_production_mesh,
)
from repro.models import build_model, init_model_state
from repro.optim import make_optimizer
from repro.optim.zero import zero_shardings
from repro.training.specs import cache_specs, input_specs, param_specs
from repro.training.step import (
    make_decode_step,
    make_prefill_step,
    make_train_step,
)

V5E_HBM_BYTES = 16 * 1024 ** 3


def batch_shardings(batch_specs, mesh, rules):
    def shard(leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        spec = spec_for(("batch",), rules)
        entry = spec[0] if len(spec) else None
        axes = (() if entry is None else
                ((entry,) if isinstance(entry, str) else tuple(entry)))
        # progressive divisibility fallback (e.g. batch=128 on 256 chips
        # shards over data only; batch=1 long-context stays replicated)
        while axes:
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if leaf.shape[0] % size == 0:
                break
            axes = axes[:-1]
        if not axes:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(axes if len(axes) > 1 else axes[0]))

    return jax.tree.map(shard, batch_specs)


def bytes_per_device(tree, shardings, mesh) -> float:
    total = 0.0
    for leaf, sh in zip(jax.tree.leaves(tree), jax.tree.leaves(
            shardings, is_leaf=lambda x: isinstance(x, NamedSharding))):
        n = leaf.size * leaf.dtype.itemsize
        spec = sh.spec
        shards = 1
        for entry in spec:
            if entry is None:
                continue
            for a in ((entry,) if isinstance(entry, str) else entry):
                shards *= mesh.shape[a]
        total += n / shards
    return total


def lower_cell(arch: str, shape_name: str, mesh: Mesh, *,
               parallel: Optional[ParallelConfig] = None,
               attention_impl: str = "chunked",
               moe_group: Optional[int] = None,
               donate: bool = True,
               dp_mode: str = "gspmd",
               opt_cfg: Optional[OptimizerConfig] = None,
               microbatches: int = 1,
               compression: Optional[str] = "__default__",
               overlap_comm: bool = False,
               zero_dp: bool = False,
               fused_bn: bool = False,
               optimizer_kind: str = "rmsprop_warmup",
               hier_split: Optional[int] = None):
    """Build + lower + compile one cell. Returns (record, compiled)."""
    cfg = get_config(arch)
    if fused_bn:
        if cfg.family != "conv":
            raise ValueError(
                "--fused-bn fuses the ResNet BN sites (Pallas kernels, "
                f"DESIGN.md §10); arch family {cfg.family!r} has no BN")
        cfg = dataclasses.replace(cfg, fused_bn=True)
    shp = {s.name: s for s in shapes_for(cfg)}[shape_name]
    if shp.skip_reason:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": shp.skip_reason}, None
    parallel = parallel or cell_parallel(cfg, shp)
    if compression != "__default__":
        from repro.core.compression import parse_compression
        if parse_compression(compression)[1] and dp_mode != "shardmap":
            # refuse to write a record that claims a mode that never ran:
            # under GSPMD the bucketed flag is ignored (DESIGN.md §6)
            raise ValueError(
                "bucketed compression requires --dp-mode shardmap; "
                f"got dp_mode={dp_mode!r} with {compression!r}")
        parallel = dataclasses.replace(parallel, compression=compression)
    if overlap_comm:
        if dp_mode != "shardmap":
            raise ValueError("--overlap-comm requires --dp-mode shardmap "
                             "(DESIGN.md §8)")
        parallel = dataclasses.replace(parallel, overlap_comm=True)
    if zero_dp:
        from repro.core.compression import parse_compression
        if dp_mode != "shardmap":
            raise ValueError("--zero requires --dp-mode shardmap "
                             "(DESIGN.md §9)")
        if not parse_compression(parallel.compression)[1]:
            raise ValueError(
                "--zero reduce-scatters packed buckets: pass a bucketed "
                f"--compression (got {parallel.compression!r})")
        parallel = dataclasses.replace(parallel, zero_dp=True)
    if hier_split is not None:
        from repro.core.compression import parse_compression
        if dp_mode != "shardmap":
            raise ValueError("--hier-split requires --dp-mode shardmap "
                             "(DESIGN.md §14)")
        if not parse_compression(parallel.compression)[1]:
            raise ValueError(
                "--hier-split reschedules packed buckets: pass a "
                f"bucketed --compression (got {parallel.compression!r})")
        parallel = dataclasses.replace(parallel, hier_split=hier_split)
    rules = make_rules(cfg, mesh, parallel)
    compute_dtype = jnp.bfloat16

    if moe_group is not None:
        from repro.models import layers as _layers
        _layers.MOE_GROUP = moe_group

    t0 = time.time()
    if shp.kind == "train" and dp_mode == "shardmap":
        # paper-faithful explicit DP: per-worker fwd/bwd + compressed
        # psum of gradients + replicated optimizer (pure-DP models)
        from repro.training.step import (
            make_dp_overlap_train_step,
            make_dp_shardmap_train_step,
            replicate_model_state,
        )
        model = build_model(cfg, compute_dtype=compute_dtype,
                            attention_impl=attention_impl,
                            remat=parallel.remat == "block")
        p_shapes, p_axes = param_specs(model, jnp.float32)
        opt_cfg = opt_cfg or OptimizerConfig(kind=optimizer_kind)
        train_cfg = TrainConfig(optimizer=opt_cfg, parallel=parallel)
        n_workers = 1
        for a in parallel.dp_axes:
            n_workers *= mesh.shape[a]
        repl = NamedSharding(mesh, P())
        dp_shard = NamedSharding(mesh, P(parallel.dp_axes))
        from repro.core.compression import parse_compression as _pc
        # stream layout: always under --zero; also LARS on the bucketed
        # explicit-DP paths (stream-LARS, DESIGN.md §11)
        use_stream = parallel.zero_dp or (
            opt_cfg.kind == "lars" and _pc(parallel.compression)[1])
        if use_stream:
            # flat stream state: shard layout (dp-sharded) under --zero,
            # full replicated stream otherwise (optim/stream.py)
            from repro.optim.stream import (
                make_stream_optimizer,
                zero_padded_total,
            )
            optimizer = make_stream_optimizer(
                opt_cfg, steps_per_epoch=40,
                global_batch=shp.global_batch)
            padded_total = zero_padded_total(
                p_shapes, parallel.compression, parallel.bucket_bytes,
                n_workers)
            opt_shapes = jax.eval_shape(
                lambda: optimizer.init(padded_total))
            field_shard = dp_shard if parallel.zero_dp else repl
            opt_shard = {"step": repl,
                         **{f: field_shard
                            for f in optimizer.state_fields}}
        else:
            optimizer = make_optimizer(opt_cfg, steps_per_epoch=40,
                                       global_batch=shp.global_batch)
            opt_shapes = jax.eval_shape(optimizer.init, p_shapes)
            opt_shard = jax.tree.map(lambda _: repl, opt_shapes)
        mstate_shapes = jax.eval_shape(
            lambda: replicate_model_state(init_model_state(model),
                                          n_workers))
        state_shapes = {"params": p_shapes, "opt": opt_shapes,
                        "model_state": mstate_shapes}
        batch = input_specs(cfg, shp, compute_dtype)
        state_shard = {
            "params": jax.tree.map(lambda _: repl, p_shapes),
            "opt": opt_shard,
            "model_state": jax.tree.map(lambda _: dp_shard,
                                        mstate_shapes),
        }
        b_shard = jax.tree.map(
            lambda v: dp_shard if v.ndim else repl, batch)
        step_builder = (make_dp_overlap_train_step if parallel.overlap_comm
                        else make_dp_shardmap_train_step)
        step = step_builder(model, optimizer, train_cfg, mesh,
                            parallel.dp_axes)
        jitted = jax.jit(step, in_shardings=(state_shard, b_shard),
                         out_shardings=(state_shard, None),
                         donate_argnums=(0,) if donate else ())
        lowered = jitted.lower(state_shapes, batch)
        resident = {"state": (state_shapes, state_shard)}
    elif shp.kind == "train":
        model = build_model(cfg, compute_dtype=compute_dtype,
                            attention_impl=attention_impl,
                            remat=parallel.remat == "block")
        p_shapes, p_axes = param_specs(model, jnp.float32)
        p_shard = tree_shardings(p_axes, mesh, rules)
        opt_cfg = opt_cfg or OptimizerConfig()
        train_cfg = TrainConfig(optimizer=opt_cfg, parallel=parallel)
        optimizer = make_optimizer(opt_cfg, steps_per_epoch=1000,
                                   global_batch=shp.global_batch)
        opt_shapes = jax.eval_shape(optimizer.init, p_shapes)
        if parallel.zero_1:
            state_opt_shard = {
                "step": NamedSharding(mesh, P()),
                **{f: zero_shardings(opt_shapes[f],
                                     jax.tree.map(lambda s: s.spec, p_shard,
                                                  is_leaf=lambda x: isinstance(
                                                      x, NamedSharding)),
                                     mesh, parallel.dp_axes)
                   for f in optimizer.state_fields},
            }
            grad_shardings = zero_shardings(
                p_shapes, jax.tree.map(
                    lambda s: s.spec, p_shard,
                    is_leaf=lambda x: isinstance(x, NamedSharding)),
                mesh, parallel.dp_axes)

            def grad_constraint(grads):
                return jax.lax.with_sharding_constraint(grads,
                                                        grad_shardings)
        else:
            state_opt_shard = {
                "step": NamedSharding(mesh, P()),
                **{f: p_shard for f in optimizer.state_fields},
            }
            grad_constraint = None

        model_state_shapes = jax.eval_shape(
            lambda: init_model_state(model))
        mstate_shard = jax.tree.map(
            lambda _: NamedSharding(mesh, P()), model_state_shapes)
        state_shapes = {"params": p_shapes, "opt": opt_shapes,
                        "model_state": model_state_shapes}
        state_shard = {"params": p_shard, "opt": state_opt_shard,
                       "model_state": mstate_shard}
        batch = input_specs(cfg, shp, compute_dtype)
        b_shard = batch_shardings(batch, mesh, rules)
        step = make_train_step(model, optimizer, train_cfg, mesh, rules,
                               grad_constraint,
                               param_shardings=p_shard,
                               microbatches=microbatches)
        jitted = jax.jit(step, in_shardings=(state_shard, b_shard),
                         out_shardings=(state_shard, None),
                         donate_argnums=(0,) if donate else ())
        lowered = jitted.lower(state_shapes, batch)
        resident = {"state": (state_shapes, state_shard)}
    else:
        model = build_model(cfg, compute_dtype=compute_dtype,
                            attention_impl=attention_impl, remat=False)
        p_shapes, p_axes = param_specs(model, jnp.bfloat16)
        p_shard = tree_shardings(p_axes, mesh, rules)
        cache_vals, cache_axes = cache_specs(model, shp.global_batch,
                                             shp.seq_len, jnp.bfloat16)
        cache_shard = tree_shardings(cache_axes, mesh, rules)
        # per-dim divisibility pruning (e.g. batch=128 on a 256-way dp)
        from repro.distributed.sharding import prune_spec
        cache_shard = jax.tree.map(
            lambda v, s: NamedSharding(mesh, prune_spec(v.shape, s.spec,
                                                        mesh)),
            cache_vals, cache_shard,
            is_leaf=lambda x: isinstance(x, NamedSharding))
        batch = input_specs(cfg, shp, compute_dtype)
        b_shard = batch_shardings(batch, mesh, rules)
        if shp.kind == "prefill":
            step = make_prefill_step(model, mesh, rules)
        else:
            step = make_decode_step(model, mesh, rules)
        jitted = jax.jit(step,
                         in_shardings=(p_shard, cache_shard, b_shard),
                         out_shardings=(None, cache_shard),
                         donate_argnums=(1,) if donate else ())
        lowered = jitted.lower(p_shapes, cache_vals, batch)
        resident = {"params": (p_shapes, p_shard),
                    "cache": (cache_vals, cache_shard)}

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    # spec requirement: surface the compiled analyses directly
    try:
        print(f"  memory_analysis: {compiled.memory_analysis()}")
    except Exception as e:
        print(f"  memory_analysis: unavailable ({e})")
    try:
        ca = dict(compiled.cost_analysis())
        print("  cost_analysis: flops=%s bytes=%s" % (
            ca.get("flops"), ca.get("bytes accessed")))
    except Exception as e:
        print(f"  cost_analysis: unavailable ({e})")

    record = analyze_compiled(arch, shp, cfg, mesh, compiled, resident)
    record.update({
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "parallel": dataclasses.asdict(parallel),
        "attention_impl": attention_impl,
    })
    return record, compiled


def _spec_size(mesh, entry):
    if entry is None:
        return 1
    n = 1
    for a in ((entry,) if isinstance(entry, str) else entry):
        n *= mesh.shape[a]
    return n


def analyze_compiled(arch, shp, cfg, mesh, compiled, resident
                     ) -> Dict[str, Any]:
    n_dev = mesh.size
    try:
        mem = compiled.memory_analysis()
        mem_info = {
            "argument_size": getattr(mem, "argument_size_in_bytes", None),
            "output_size": getattr(mem, "output_size_in_bytes", None),
            "temp_size": getattr(mem, "temp_size_in_bytes", None),
            "generated_code_size": getattr(
                mem, "generated_code_size_in_bytes", None),
        }
    except Exception as e:  # CPU backend may not implement it
        mem_info = {"error": str(e)}
    try:
        cost = dict(compiled.cost_analysis())
        cost = {k: v for k, v in cost.items()
                if k in ("flops", "bytes accessed", "transcendentals")}
    except Exception as e:
        cost = {"error": str(e)}

    hlo = compiled.as_text()
    a: Analysis = analyze_hlo(hlo, total_devices=n_dev)

    # resident bytes per device (params + opt + cache), from shardings
    resident_bytes = {k: bytes_per_device(v[0], v[1], mesh)
                      for k, v in resident.items()}

    # analytic MODEL_FLOPS (the "useful compute" yardstick)
    n_active = cfg.active_param_count()
    if cfg.family == "conv":
        # ResNet-50: ~4.09 GFLOP/image fwd (He et al.); x3 for train
        per_image = 2 * 4.089e9 / 2  # fwd MACs*2
        factor = 3.0 if shp.kind == "train" else 1.0
        model_flops = factor * per_image * shp.global_batch
    else:
        tokens = shp.global_batch * (shp.seq_len if shp.kind != "decode"
                                     else 1)
        factor = 6.0 if shp.kind == "train" else 2.0
        model_flops = factor * n_active * tokens

    compute_s = a.flops / PEAK_FLOPS_BF16  # a.flops is per-device (SPMD)
    memory_s = a.memory_bytes / HBM_BW
    collective_s = a.total_collective_bytes / ICI_BW
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    bound_s = max(terms.values())
    step_tokens_or_images = (shp.global_batch if cfg.family == "conv"
                             else shp.global_batch * (
                                 1 if shp.kind == "decode" else shp.seq_len))

    return {
        "arch": arch,
        "shape": shp.name,
        "kind": shp.kind,
        "mesh": dict(mesh.shape),
        "status": "ok",
        "hlo_flops_per_device": a.flops,
        "hlo_dot_flops": a.dot_flops,
        "hlo_conv_flops": a.conv_flops,
        "hlo_memory_bytes_per_device": a.memory_bytes,
        "hlo_parameter_bytes_per_device": a.parameter_bytes,
        "collective_bytes_per_device": a.collective_bytes,
        "collective_dtypes": a.collective_dtypes,
        "collective_total_bytes": a.total_collective_bytes,
        # collective count / bytes-per-collective / wire dtype — verifies
        # the bucketed sync fusion from HLO (DESIGN.md §6); the embedded
        # interleave section proves (or refutes) that collectives overlap
        # the backward compute in scheduled program order (DESIGN.md §8)
        "comm_report": comm_report(a, hlo_text=hlo),
        # context-free audit passes (repro.analysis, DESIGN.md §12):
        # precision / donation / determinism / collective-schedule
        # findings for this cell. Train cells donate their state arg,
        # so the trailing batch leaves arm the donation coverage gate.
        "audit": quick_audit(
            hlo, total_devices=n_dev,
            n_batch_params=(len(jax.tree.leaves(
                input_specs(cfg, shp, jnp.bfloat16)))
                if shp.kind == "train" else None)),
        "trip_counts_found": len(a.trip_counts),
        "resident_bytes_per_device": resident_bytes,
        "fits_v5e_16g": sum(resident_bytes.values()) < V5E_HBM_BYTES,
        "memory_analysis": mem_info,
        "cost_analysis_raw": cost,
        "roofline": {
            **{k: round(v, 6) for k, v in terms.items()},
            "dominant": dominant,
            "bound_s": round(bound_s, 6),
            "model_flops_global": model_flops,
            "hlo_flops_global": a.flops * n_dev,
            "useful_fraction": round(
                model_flops / max(a.flops * n_dev, 1.0), 4),
            "achievable_mfu": round(
                (model_flops / n_dev / PEAK_FLOPS_BF16) / max(bound_s, 1e-12),
                4),
            "tokens_or_images_per_step": step_tokens_or_images,
        },
    }


def run_cells(archs, shapes, *, multi_pod=False, out_dir="results/dryrun",
              force=False, attention_impl="chunked", dp_mode="gspmd",
              compression="__default__", overlap_comm=False,
              zero_dp=False, fused_bn=False,
              optimizer_kind="rmsprop_warmup", hier_split=None):
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    if dp_mode != "gspmd":
        mesh_tag += f"__{dp_mode}"
    if compression != "__default__":
        mesh_tag += f"__{compression or 'nowire'}"
    if overlap_comm:
        mesh_tag += "__overlap"
    if zero_dp:
        mesh_tag += "__zero"
    if hier_split is not None:
        mesh_tag += f"__hier{hier_split}"
    if fused_bn:
        mesh_tag += "__fusedbn"
    if optimizer_kind != "rmsprop_warmup":
        mesh_tag += f"__{optimizer_kind}"
    os.makedirs(out_dir, exist_ok=True)
    results = []
    for arch in archs:
        cfg = get_config(arch)
        all_shapes = {s.name: s for s in shapes_for(cfg)}
        for shape_name in (shapes or all_shapes):
            if shape_name not in all_shapes:
                continue
            path = os.path.join(out_dir,
                                f"{arch}__{shape_name}__{mesh_tag}.json")
            if os.path.exists(path) and not force:
                results.append(json.load(open(path)))
                print(f"[cached] {arch} {shape_name} {mesh_tag}")
                continue
            print(f"[lower]  {arch} {shape_name} {mesh_tag} ...",
                  flush=True)
            try:
                rec, compiled = lower_cell(arch, shape_name, mesh,
                                           attention_impl=attention_impl,
                                           dp_mode=dp_mode,
                                           compression=compression,
                                           overlap_comm=overlap_comm,
                                           zero_dp=zero_dp,
                                           fused_bn=fused_bn,
                                           optimizer_kind=optimizer_kind,
                                           hier_split=hier_split)
                del compiled
            except Exception as e:
                rec = {"arch": arch, "shape": shape_name, "status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-3000:]}
            rec["mesh_tag"] = mesh_tag
            with open(path, "w") as f:
                json.dump(rec, f, indent=1, default=str)
            status = rec.get("status")
            extra = ""
            if status == "ok":
                r = rec["roofline"]
                extra = (f"dom={r['dominant']} bound={r['bound_s']:.4f}s "
                         f"compile={rec['compile_s']}s")
                cr = rec.get("comm_report", {})
                if cr:
                    print("  comm: %.0f collectives/step, "
                          "%.2f MiB/collective mean, sync=%s" % (
                              cr["total_executions_per_step"],
                              cr["mean_bytes_per_collective"] / 2**20,
                              cr.get("gradient_sync", "?")))
                    il = cr.get("interleave", {})
                    if il.get("n_collectives"):
                        print("  interleave: %s (%d/%d conv+dot after "
                              "first collective)" % (
                                  il["interleaved"],
                                  il.get("compute_ops_after_first", 0),
                                  il.get("compute_ops_total", 0)))
            print(f"[done]   {arch} {shape_name} {mesh_tag}: {status} "
                  f"{extra}", flush=True)
            results.append(rec)
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id, comma list, or 'all'")
    ap.add_argument("--shape", default=None,
                    help="shape name or comma list (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--attention-impl", default="chunked")
    ap.add_argument("--dp-mode", default="gspmd",
                    choices=["gspmd", "shardmap"])
    ap.add_argument("--compression", default="__default__",
                    help="override gradient sync: none|bf16|f16|"
                         "bf16+bucketed|f16+bucketed (DESIGN.md §2/§6)")
    ap.add_argument("--overlap-comm", action="store_true",
                    help="backward-overlapped bucketed sync (needs "
                         "--dp-mode shardmap, DESIGN.md §8)")
    ap.add_argument("--zero", action="store_true",
                    help="ZeRO reduce-scatter sync + sharded update "
                         "(needs --dp-mode shardmap and a bucketed "
                         "--compression, DESIGN.md §9)")
    ap.add_argument("--fused-bn", action="store_true",
                    help="fused Pallas BN at every ResNet BN site "
                         "(conv archs only; kernels/fused_bn.py, "
                         "DESIGN.md §10)")
    ap.add_argument("--optimizer", default="rmsprop_warmup",
                    choices=["rmsprop_warmup", "momentum_sgd", "lars"],
                    help="optimizer kind for the shardmap train cells "
                         "(lars + bucketed compression lowers the "
                         "packed-stream LARS path, DESIGN.md §11)")
    ap.add_argument("--hier-split", type=int, default=None,
                    help="hierarchical collective schedule: split "
                         "dp_axes at this index into intra-axis "
                         "reduce-scatter -> inter-axis all-reduce -> "
                         "intra-axis all-gather (needs --dp-mode "
                         "shardmap + bucketed --compression, "
                         "DESIGN.md §14)")
    args = ap.parse_args()

    if args.arch == "all":
        archs = list(ASSIGNED_ARCHS) + ["resnet50"]
    else:
        archs = args.arch.split(",")
    shapes = args.shape.split(",") if args.shape else None
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        run_cells(archs, shapes, multi_pod=mp, out_dir=args.out,
                  force=args.force, attention_impl=args.attention_impl,
                  dp_mode=args.dp_mode, compression=args.compression,
                  overlap_comm=args.overlap_comm, zero_dp=args.zero,
                  fused_bn=args.fused_bn,
                  optimizer_kind=args.optimizer,
                  hier_split=args.hier_split)


if __name__ == "__main__":
    main()
