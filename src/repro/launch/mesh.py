"""Production meshes. Functions, never module-level constants, so
importing this module never touches jax device state."""
from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.configs.base import ModelConfig, ParallelConfig, ShapeConfig

# v5e hardware constants for the roofline (per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_small_mesh(data: int = 4, model: int = 2):
    """Virtual-device mesh for tests (XLA_FLAGS host device count)."""
    return jax.make_mesh((data, model), ("data", "model"))


def preferred_mesh(cfg: ModelConfig, *, multi_pod: bool = False):
    """Per-arch mesh-shape selection over the same chips.

    §Perf llama4 iteration 4: 40 heads % 16 != 0 makes attention
    replicate on a (16,16) mesh (11x slower); (data=32, model=8) shards
    heads/experts/ffn/vocab evenly. Archs that divide 16 keep the
    standard production mesh.
    """
    if cfg.n_heads and cfg.n_heads % 16 != 0 and cfg.n_heads % 8 == 0 \
            and cfg.param_count() > 3e9:
        shape = (2, 32, 8) if multi_pod else (32, 8)
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")
        return jax.make_mesh(shape, axes)
    return make_production_mesh(multi_pod=multi_pod)


def cell_parallel(cfg: ModelConfig, shape: ShapeConfig) -> ParallelConfig:
    """Default parallelism policy for one (arch, shape) cell.

    conv (ResNet-50)   : pure DP over every mesh axis — the paper's regime,
                         fp16 wire compression (paper-faithful), replicated
                         optimizer (the paper's workers update redundantly).
    LM train           : DP over data(+pod), Megatron TP over model,
                         ZeRO-1 (+FSDP for >=6B params), bf16 wire.
    LM prefill/decode  : TP over model, batch over data, bf16 params, and
                         sequence sharding when the batch can't shard
                         (long-context B=1 cells).
    """
    if cfg.family == "conv":
        return ParallelConfig(
            dp_axes=("data", "model"), tp_axis=None, zero_1=False,
            fsdp_params=False, compression="f16", remat="none")
    n = cfg.param_count()
    tiny = n < 3e9  # pure-DP below Megatron-worthwhile size (paper regime)
    big = n > 6e9
    if shape.kind == "train":
        if tiny:
            return ParallelConfig(
                dp_axes=("data", "model"), tp_axis=None, zero_1=True,
                fsdp_params=False, compression="bf16", remat="block")
        return ParallelConfig(
            dp_axes=("data",), tp_axis="model", zero_1=True,
            fsdp_params=big, compression="bf16", remat="block")
    if tiny:
        return ParallelConfig(
            dp_axes=("data", "model"), tp_axis=None, zero_1=False,
            fsdp_params=False, compression=None, remat="none",
            kv_seq_sharding=True)
    # serve of very large models: bf16 params exceed TP-sharded HBM
    # (llama4 400B: 795 GB/16 = 50 GB/chip) => weight-gather FSDP serving
    serve_fsdp = n * 2 / 16 > 12e9
    return ParallelConfig(
        dp_axes=("data",), tp_axis="model", zero_1=False,
        fsdp_params=serve_fsdp, compression=None, remat="none",
        sequence_sharding=shape.global_batch == 1,
        kv_seq_sharding=True)
