"""Optimized-HLO analyzer: loop-aware FLOPs / bytes / collective accounting.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified
in this container), which under-reports scanned models by a factor of
n_layers. This module parses ``compiled.as_text()`` into computations +
ops, recovers while trip counts from loop-condition constants, and
multiplies costs through the (possibly nested) loop structure.

Outputs per program:
  flops            dot + convolution FLOPs, trip-count weighted
  collectives      per-op-kind wire bytes (ring-model factors), dtypes
  memory_bytes     ~HBM traffic: sum of materialized buffer sizes x2
                   (write + read) + parameter bytes (approximation,
                   documented in EXPERIMENTS.md §Roofline)
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast")

# Ops counted as HBM-materializing for the memory-traffic model. The
# CPU backend fuses far less than TPU, so raw elementwise/convert/
# broadcast/transpose ops in CPU HLO are *excluded* — on TPU they fuse
# into their consumers. What remains (matmuls, fusions, gathers,
# reductions, copies, collectives, scan-stack slice updates) is the
# traffic a TPU execution would actually see. Documented approximation
# (EXPERIMENTS.md §Roofline).
# (iota/rng excluded: XLA:TPU generates them in-register / fuses them;
# the CPU backend materializes them — a backend artifact.)
MATERIALIZING = {
    "dot", "convolution", "fusion", "copy", "gather", "scatter",
    "dynamic-slice", "dynamic-update-slice", "reduce", "reduce-window",
    "sort", "cholesky", "triangular-solve", "pad", "concatenate",
    "select-and-scatter",
} | set(COLLECTIVES)


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result: str  # raw type string
    operands: List[str]
    attrs: str
    root: bool = False


@dataclasses.dataclass
class Analysis:
    flops: float
    dot_flops: float
    conv_flops: float
    memory_bytes: float
    parameter_bytes: float
    collective_bytes: Dict[str, float]  # opcode -> wire bytes (per device)
    collective_dtypes: Dict[str, Dict[str, float]]  # opcode -> dtype -> bytes
    collective_count: int
    trip_counts: Dict[str, int]
    op_histogram: Dict[str, int]
    top_memory_ops: List[tuple] = dataclasses.field(default_factory=list)
    top_collective_ops: List[tuple] = dataclasses.field(
        default_factory=list)
    # opcode -> trip-count-weighted executions per step (a collective
    # inside a scanned layer counts n_layers times) — what the bucketing
    # fusion claim (DESIGN.md §6) is verified against
    collective_exec_counts: Dict[str, float] = dataclasses.field(
        default_factory=dict)
    # opcode -> largest single-execution wire bytes — what the ZeRO
    # "the full-gradient all-reduce is gone" claim (DESIGN.md §9) is
    # verified against (a metric pmean stays tiny; a gradient bucket
    # does not)
    collective_max_exec_bytes: Dict[str, float] = dataclasses.field(
        default_factory=dict)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def type_bytes(type_str: str) -> float:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0.0
    for dtype, dims in _TYPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def type_shape(type_str: str) -> Tuple[str, Tuple[int, ...]]:
    m = _TYPE_RE.search(type_str)
    if not m:
        return ("", ())
    dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) else ()
    return m.group(1), dims


_COMP_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_OP_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^()]*\))|(?:[\w\[\],{}.]+))\s+"
    r"([\w\-]+)\((.*)$"
)


_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")


def parse_computations(text: str) -> Dict[str, List[Op]]:
    """Column-0 lines open computations (headers may wrap over several
    lines); indented lines are ops; a column-0 '}' closes."""
    comps: Dict[str, List[Op]] = {}
    current: Optional[str] = None
    entry_marked: Optional[str] = None
    for line in text.splitlines():
        if line.startswith("}"):
            current = None
            continue
        if line and not line[0].isspace():
            m = _HEADER_RE.match(line)
            if m:
                current = m.group(2)
                comps[current] = []
                if m.group(1):
                    entry_marked = current
            continue
        if current is None:
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        root, name, rtype, opcode, rest = m.groups()
        # operands: the leading %names inside the first paren group
        operands = re.findall(r"%([\w.\-]+)", rest.split("),", 1)[0])
        comps[current].append(Op(name=name, opcode=opcode, result=rtype,
                                 operands=operands, attrs=rest,
                                 root=bool(root)))
    if entry_marked:
        comps["__entry__"] = comps[entry_marked]
    return comps


def _op_defs(ops: List[Op]) -> Dict[str, Op]:
    return {o.name: o for o in ops}


def _trip_count(cond_ops: List[Op]) -> int:
    """Trip count heuristic: the max scalar s32/u32/s64 constant in the
    loop-condition computation (jax scans compare a counter against the
    length constant)."""
    best = 1
    for o in cond_ops:
        if o.opcode != "constant":
            continue
        dtype, dims = type_shape(o.result)
        if dims != () or dtype not in ("s32", "u32", "s64", "u64"):
            continue
        m = re.search(r"constant\((-?\d+)\)", "constant(" + o.attrs)
        if m:
            best = max(best, int(m.group(1)))
    return best


_CALLED_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def compute_multipliers(comps: Dict[str, List[Op]]
                        ) -> Tuple[Dict[str, float], Dict[str, int]]:
    entry = comps.get("__entry__")
    if entry is None:  # fall back: last computation is usually ENTRY
        entry_name = list(comps)[-1]
    else:
        entry_name = [k for k, v in comps.items()
                      if v is entry and k != "__entry__"][0]
    mult: Dict[str, float] = defaultdict(float)
    mult[entry_name] = 1.0
    trips: Dict[str, int] = {}

    # iterate to fixpoint (call graph is a DAG; few passes suffice)
    for _ in range(20):
        changed = False
        new_mult = defaultdict(float)
        new_mult[entry_name] = 1.0
        for cname, ops in comps.items():
            if cname == "__entry__" or mult.get(cname, 0) == 0:
                continue
            m_c = mult[cname]
            for op in ops:
                if op.opcode == "while":
                    body = cond = None
                    bm = re.search(r"body=%?([\w.\-]+)", op.attrs)
                    cm = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                    if bm:
                        body = bm.group(1)
                    if cm:
                        cond = cm.group(1)
                    trip = _trip_count(comps.get(cond, [])) if cond else 1
                    if body:
                        trips[body] = trip
                        new_mult[body] += m_c * trip
                    if cond:
                        new_mult[cond] += m_c * (trip + 1)
                elif op.opcode == "conditional":
                    bs = _BRANCHES_RE.search(op.attrs)
                    names = []
                    if bs:
                        names = re.findall(r"%?([\w.\-]+)", bs.group(1))
                    for nm in names:
                        new_mult[nm] += m_c  # upper bound: every branch
                else:
                    for target in _CALLED_RE.findall(op.attrs):
                        if target in comps and target != cname:
                            new_mult[target] += m_c
        if dict(new_mult) != {k: v for k, v in mult.items() if v}:
            changed = True
        mult = new_mult
        if not changed:
            break
    return dict(mult), trips


def _dot_flops(op: Op, defs: Dict[str, Op]) -> float:
    _, out_dims = type_shape(op.result)
    out_elems = math.prod(out_dims) if out_dims else 1
    lhs = defs.get(op.operands[0]) if op.operands else None
    contract = 1
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.attrs)
    if m and lhs is not None:
        _, lhs_dims = type_shape(lhs.result)
        for idx in m.group(1).split(","):
            if idx != "" and int(idx) < len(lhs_dims):
                contract *= lhs_dims[int(idx)]
    return 2.0 * out_elems * contract


def _conv_flops(op: Op, defs: Dict[str, Op]) -> float:
    _, out_dims = type_shape(op.result)
    out_elems = math.prod(out_dims) if out_dims else 1
    rhs = defs.get(op.operands[1]) if len(op.operands) > 1 else None
    if rhs is None:
        return 0.0
    _, k_dims = type_shape(rhs.result)
    m = re.search(r"dim_labels=\S+?_(\w+?)->", op.attrs)
    kernel_mult = 1
    if m and k_dims:
        labels = m.group(1)
        for ch, d in zip(labels, k_dims):
            if ch != "o":  # spatial digits and 'i' contribute; 'o' doesn't
                kernel_mult *= d
    else:
        kernel_mult = math.prod(k_dims[:-1]) if k_dims else 1
    return 2.0 * out_elems * kernel_mult


def _group_size(op: Op, total_devices: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", op.attrs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([^}]*)\}", op.attrs)
    if m:
        return len(m.group(1).split(","))
    return total_devices


def _wire_bytes(op: Op, defs: Dict[str, Op], k: int) -> float:
    """Ring-model per-device wire bytes for one collective execution."""
    if k <= 1:
        return 0.0
    frac = (k - 1) / k
    out_b = type_bytes(op.result)
    in_b = sum(type_bytes(defs[o].result) for o in op.operands if o in defs)
    if op.opcode == "all-reduce":
        return 2.0 * in_b * frac
    if op.opcode == "all-gather":
        return out_b * frac
    if op.opcode == "reduce-scatter":
        return in_b * frac
    if op.opcode == "all-to-all":
        return in_b * frac
    if op.opcode in ("collective-permute", "collective-broadcast"):
        return max(in_b, out_b)
    return in_b


def analyze_hlo(text: str, total_devices: int = 1) -> Analysis:
    comps = parse_computations(text)
    comps.pop("__entry__", None)
    mult, trips = compute_multipliers(comps)

    flops = dot_flops = conv_flops = 0.0
    mem = 0.0
    param_bytes = 0.0
    coll_bytes: Dict[str, float] = defaultdict(float)
    coll_dtypes: Dict[str, Dict[str, float]] = defaultdict(
        lambda: defaultdict(float))
    coll_count = 0
    coll_execs: Dict[str, float] = defaultdict(float)
    coll_max: Dict[str, float] = defaultdict(float)
    histogram: Dict[str, int] = defaultdict(int)
    top_mem: List[tuple] = []
    top_coll: List[tuple] = []

    entry_name = None
    for cname, ops in comps.items():
        for o in ops:
            if o.opcode == "parameter" and mult.get(cname, 0) == 1.0:
                pass
        # entry params counted below

    # computations that are fusion bodies: their internals don't
    # materialize — only the fusion op's output does.
    fusion_bodies = set()
    fusion_target = {}
    for ops in comps.values():
        for op in ops:
            if op.opcode == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                if m:
                    fusion_bodies.add(m.group(1))
                    fusion_target[op.name] = m.group(1)

    # pure dtype-cast fusions (no layout movement): CPU artifacts — the
    # TPU MXU consumes bf16 directly and these don't exist there.
    CAST_ONLY = {"parameter", "convert", "bitcast", "get-tuple-element",
                 "tuple"}
    # + layout movement: still real traffic, but at the semantic dtype.
    # slice/concatenate cover the bucketed gradient path (DESIGN.md §6),
    # whose bucket is a slice of a concatenated bf16 stream.
    PASSTHROUGH = CAST_ONLY | {"copy", "transpose", "reshape", "slice",
                               "concatenate"}

    def _convert_only(cname: str) -> bool:
        return all(o.opcode in CAST_ONLY for o in comps.get(cname, []))

    def _body_mentions_bf16(cname: str) -> bool:
        return any(type_shape(o.result)[0] == "bf16"
                   for o in comps.get(cname, []))

    def _bf16_roundtrip(name: str, defs: Dict[str, Op],
                        hops: int = 5) -> bool:
        """True if the (f32) value named ``name`` is a converted bf16
        value — semantically 2 bytes/element on TPU. Follows copy/
        bitcast/transpose/convert-only-fusion chains."""
        while hops > 0:
            hops -= 1
            d = defs.get(name)
            if d is None:
                return False
            if type_shape(d.result)[0] == "bf16":
                return True
            if d.opcode == "convert":
                src = defs.get(d.operands[0]) if d.operands else None
                if src and type_shape(src.result)[0] == "bf16":
                    return True
                name = d.operands[0] if d.operands else None
                continue
            if d.opcode == "fusion" and d.name in fusion_target:
                fops = comps.get(fusion_target[d.name], [])
                # CPU promotes bf16 reductions to f32 by a convert that
                # gets fused into the producer: a fusion whose ROOT
                # converts a bf16 value is a bf16 round-trip regardless
                # of what else the fusion computes (the bucketed
                # gradient pack hits this).
                froot = next((o for o in fops if o.root), None)
                if froot is not None and froot.opcode == "convert" \
                        and froot.operands:
                    fdefs = _op_defs(fops)
                    src = fdefs.get(froot.operands[0])
                    if src is not None and \
                            type_shape(src.result)[0] == "bf16":
                        return True
                if all(o.opcode in PASSTHROUGH for o in fops):
                    if _body_mentions_bf16(fusion_target[d.name]):
                        return True
                    name = d.operands[0] if d.operands else None
                    continue
            if d.opcode == "call":
                # outlined computation (XLA outlines the big gradient
                # pack): the value is whatever the callee's root is
                cm = re.search(r"to_apply=%?([\w.\-]+)", d.attrs)
                if cm and cm.group(1) in comps:
                    sub = comps[cm.group(1)]
                    sroot = next((o for o in sub if o.root), None)
                    if sroot is not None:
                        return _bf16_roundtrip(sroot.name, _op_defs(sub),
                                               hops)
                return False
            if d.opcode in ("copy", "bitcast", "transpose", "reshape",
                            "all-reduce", "reduce-scatter", "all-gather",
                            "slice", "dynamic-slice", "concatenate"):
                name = d.operands[0] if d.operands else None
                continue
            return False
        return False

    def materialized_bytes(op: Op, defs: Dict[str, Op]) -> float:
        """HBM write bytes for one op execution. dynamic-update-slice is
        in-place in XLA: traffic = the updated slice, not the full array
        (this is what makes scan stacks cheap per iteration)."""
        if op.opcode == "dynamic-update-slice":
            upd = defs.get(op.operands[1]) if len(op.operands) > 1 else None
            return type_bytes(upd.result) if upd else type_bytes(op.result)
        if op.opcode == "fusion":
            m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
            if m and m.group(1) in comps:
                fops = comps[m.group(1)]
                fbytes = type_bytes(op.result)
                # in-place scan-stack update fused behind (bit)casts:
                # count the update slice, not the whole stack buffer
                for fo in fops:
                    if fo.opcode == "dynamic-update-slice" and \
                            type_bytes(fo.result) >= 0.5 * fbytes:
                        fdefs = _op_defs(fops)
                        upd = (fdefs.get(fo.operands[1])
                               if len(fo.operands) > 1 else None)
                        if upd is not None:
                            return type_bytes(upd.result)
        return type_bytes(op.result)

    for cname, ops in comps.items():
        m_c = mult.get(cname, 0.0)
        if m_c == 0.0:
            continue
        in_fusion = cname in fusion_bodies
        defs = _op_defs(ops)
        for op in ops:
            histogram[op.opcode] += 1
            if op.opcode == "dot":
                f = _dot_flops(op, defs) * m_c
                dot_flops += f
                flops += f
            elif op.opcode == "convolution":
                f = _conv_flops(op, defs) * m_c
                conv_flops += f
                flops += f
            elif op.opcode in COLLECTIVES or (
                    op.opcode.endswith("-start") and
                    op.opcode[:-6] in COLLECTIVES):
                base = op.opcode[:-6] if op.opcode.endswith("-start") \
                    else op.opcode
                k = _group_size(op, total_devices)
                wb = _wire_bytes(op, defs, k) * m_c
                dtype, _ = type_shape(op.result)
                # semantic-dtype correction, per tuple element: each
                # operand that is a bf16->f32 round-trip runs in bf16 on
                # TPU. Factor = weighted by operand sizes.
                if dtype == "f32" or op.result.startswith("("):
                    tot = corr = 0.0
                    for o in op.operands:
                        d = defs.get(o)
                        if d is None:
                            continue
                        ob = type_bytes(d.result)
                        tot += ob
                        if type_shape(d.result)[0] == "f32" and \
                                _bf16_roundtrip(o, defs):
                            corr += ob / 2
                    if tot > 0 and corr > 0:
                        wb *= (tot - corr) / tot
                        dtype = "bf16*" if corr >= tot / 2 else "mixed*"
                coll_bytes[base] += wb
                coll_dtypes[base][dtype] += wb
                coll_count += 1
                coll_execs[base] += m_c
                coll_max[base] = max(coll_max[base],
                                     wb / m_c if m_c else wb)
                top_coll.append((wb, base, k, m_c, cname[:30],
                                 op.result[:46]))
            if op.opcode in MATERIALIZING and not in_fusion:
                b = materialized_bytes(op, defs) * m_c
                if op.opcode == "fusion" and op.name in fusion_target \
                        and _convert_only(fusion_target[op.name]):
                    b = 0.0  # CPU dtype/layout artifact; fused on TPU
                elif op.opcode in ("dot", "convolution") and op.operands \
                        and all(_bf16_roundtrip(o, defs)
                                for o in op.operands[:2]):
                    b *= 0.5  # bf16 dot/conv upcast by the CPU backend
                elif op.opcode in COLLECTIVES and op.operands and \
                        type_shape(op.result)[0] == "f32" and \
                        _bf16_roundtrip(op.operands[0], defs):
                    b *= 0.5  # collective carries a bf16 value on TPU
                elif op.opcode == "fusion" and type_shape(
                        op.result)[0] == "f32" and \
                        op.name in fusion_target and \
                        _body_mentions_bf16(fusion_target[op.name]):
                    b *= 0.5  # f32 fusion of bf16-origin values (CPU
                    # upcast artifact; TPU keeps the chain in bf16)
                mem += b
                if b > 0:
                    top_mem.append((b, op.opcode, m_c, cname[:30],
                                    op.result[:42], op.name[:34]))

    # entry parameters = resident inputs (params/opt state/batch), read once
    entry = None
    for cname, ops in comps.items():
        if mult.get(cname) == 1.0 and any(
                o.opcode == "parameter" for o in ops):
            if entry is None or len(ops) > len(comps.get(entry, [])):
                entry = cname
    if entry:
        for op in comps[entry]:
            if op.opcode == "parameter":
                param_bytes += type_bytes(op.result)

    top_mem.sort(reverse=True)
    top_coll.sort(reverse=True)
    return Analysis(
        flops=flops,
        dot_flops=dot_flops,
        conv_flops=conv_flops,
        memory_bytes=2.0 * mem + param_bytes,
        parameter_bytes=param_bytes,
        collective_bytes=dict(coll_bytes),
        collective_dtypes={k: dict(v) for k, v in coll_dtypes.items()},
        collective_count=coll_count,
        trip_counts=trips,
        op_histogram=dict(histogram),
        top_memory_ops=top_mem[:40],
        top_collective_ops=top_coll[:40],
        collective_exec_counts=dict(coll_execs),
        collective_max_exec_bytes=dict(coll_max),
    )


def gradient_sync_mode(a: Analysis,
                       metric_bytes_floor: int = 1024) -> str:
    """Classify the program's gradient-sync mechanism from its
    collective mix — the check the ZeRO mode (DESIGN.md §9) is accepted
    by: ``"reduce_scatter+all_gather"`` means scatter+gather carry the
    gradient volume AND every all-reduce is metric-sized (below
    ``metric_bytes_floor`` per execution) — i.e. the full-gradient
    all-reduce is gone; ``"all_reduce"`` means all-reduces carry it;
    ``"none"`` means no substantial collectives at all."""
    rs = a.collective_bytes.get("reduce-scatter", 0.0)
    ag = a.collective_bytes.get("all-gather", 0.0)
    ar = a.collective_bytes.get("all-reduce", 0.0)
    ar_max = a.collective_max_exec_bytes.get("all-reduce", 0.0)
    if rs > 0 and ag > 0 and ar_max < metric_bytes_floor:
        return "reduce_scatter+all_gather"
    if ar >= max(rs, ag) and ar_max >= metric_bytes_floor:
        return "all_reduce"
    if max(rs, ag, ar) == 0.0:
        return "none"
    return "mixed"


def comm_report(a: Analysis, hlo_text: Optional[str] = None,
                min_collective_bytes: int = 512) -> Dict[str, object]:
    """Communication summary for one compiled program — the numbers the
    bucketed sync mode (DESIGN.md §6) is *verified* by, rather than
    assumed: how many collectives actually execute per step, how many
    wire bytes each one moves, and in which dtype.

    When ``hlo_text`` is given, the report also carries an
    ``interleave`` section (``interleave_report``) proving — or
    refuting — that the collectives overlap the backward compute in the
    scheduled program order (DESIGN.md §8).
    """
    per_op = {}
    for op, execs in sorted(a.collective_exec_counts.items()):
        byts = a.collective_bytes.get(op, 0.0)
        per_op[op] = {
            "executions_per_step": round(execs, 2),
            "wire_bytes_per_device": byts,
            "bytes_per_collective": byts / execs if execs else 0.0,
            "max_bytes_per_collective": a.collective_max_exec_bytes.get(
                op, 0.0),
            "dtype_bytes": dict(a.collective_dtypes.get(op, {})),
        }
    total_execs = sum(a.collective_exec_counts.values())
    total_bytes = a.total_collective_bytes
    report: Dict[str, object] = {
        "per_op": per_op,
        "total_executions_per_step": round(total_execs, 2),
        "total_wire_bytes_per_device": total_bytes,
        "mean_bytes_per_collective": (total_bytes / total_execs
                                      if total_execs else 0.0),
        # the claim the --zero acceptance test pins down: a ZeRO step
        # must classify as reduce_scatter+all_gather, i.e. no all-reduce
        # above metric size survives (DESIGN.md §9)
        "gradient_sync": gradient_sync_mode(a),
    }
    if hlo_text is not None:
        report["interleave"] = interleave_report(
            hlo_text, min_collective_bytes=min_collective_bytes)
    return report


# ---------------------------------------------------------------------------
# BN fusion accounting (fused Pallas batch norm, DESIGN.md §10)
# ---------------------------------------------------------------------------

_BN_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "convolution", "dot", "while", "call",
                "conditional", "iota", "rng", "rng-bit-generator"}


def bn_pass_counts(text: str, act_elems: int) -> Dict[str, float]:
    """Count the passes one lowered BN-site program makes over its
    activation: trip-weighted ``reduction_ops`` — reduce/reduce-window
    ops that consume an activation-sized (>= ``act_elems``) operand,
    fusion bodies included; counting only the activation-sized stage
    makes a backend's hierarchical reduce-window -> reduce chain one
    logical reduction, not several — and ``activation_writes``
    (top-level materializing ops whose result is at least
    ``act_elems`` elements — the elementwise normalize/ReLU/residual/
    mask chains). Convolutions/dots are excluded: they are the useful
    compute, identical on the fused and unfused paths."""
    comps = parse_computations(text)
    comps.pop("__entry__", None)
    mult, _ = compute_multipliers(comps)
    fusion_bodies = set()
    for ops in comps.values():
        for op in ops:
            if op.opcode == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                if m:
                    fusion_bodies.add(m.group(1))
    reduction = 0.0
    writes = 0.0
    for cname, ops in comps.items():
        m_c = mult.get(cname, 0.0)
        if not m_c:
            continue
        in_fusion = cname in fusion_bodies
        defs = _op_defs(ops)
        for op in ops:
            if op.opcode in ("reduce", "reduce-window"):
                big_in = False
                for o in op.operands:
                    d = defs.get(o)
                    if d is None:
                        continue
                    _, dims = type_shape(d.result)
                    if dims and math.prod(dims) >= act_elems:
                        big_in = True
                if big_in:
                    reduction += m_c
                continue
            if in_fusion or op.opcode in _BN_SKIP_OPS:
                continue
            _, dims = type_shape(op.result)
            if dims and math.prod(dims) >= act_elems:
                writes += m_c
    return {"reduction_ops": reduction, "activation_writes": writes}


def fusion_report(fused_text: str, unfused_text: str, act_elems: int,
                  n_sites: int = 1) -> Dict[str, object]:
    """Per-BN-site op-count comparison the fused-BN claim
    (DESIGN.md §10) is *verified* by, rather than assumed: the fused
    fwd+bwd must
    perform strictly fewer reduction ops than the unfused jnp path
    (one stats pass + one dy/x-hat pass vs XLA's
    mean/var/dscale/dbias/dmean/dvar chain) and no more activation-sized
    materializing writes. Feed it the compiled HLO of the same
    fwd(+vjp) program lowered both ways; the booleans are what
    tests/test_fused_bn.py and benchmarks/bn_bench.py assert."""
    fused = bn_pass_counts(fused_text, act_elems)
    unfused = bn_pass_counts(unfused_text, act_elems)
    n = max(n_sites, 1)
    report: Dict[str, object] = {
        "act_elems": act_elems,
        "n_sites": n_sites,
        "fused": fused,
        "unfused": unfused,
        "reduction_ops_per_site": {
            "fused": fused["reduction_ops"] / n,
            "unfused": unfused["reduction_ops"] / n,
        },
        "activation_writes_per_site": {
            "fused": fused["activation_writes"] / n,
            "unfused": unfused["activation_writes"] / n,
        },
        "reduction_collapse":
            fused["reduction_ops"] < unfused["reduction_ops"],
        "elementwise_collapse":
            fused["activation_writes"] <= unfused["activation_writes"],
    }
    report["collapsed"] = bool(report["reduction_collapse"]
                               and report["elementwise_collapse"])
    return report


# ---------------------------------------------------------------------------
# Collective/compute interleaving (backward-overlapped sync, DESIGN.md §8)
# ---------------------------------------------------------------------------

_COMPUTE_OPS = ("convolution", "dot")
_CALLING_OPS = ("call", "fusion", "while", "conditional")


def _transitive_compute_counts(comps: Dict[str, List[Op]]) -> Dict[str, int]:
    """conv+dot ops per computation, following call/fusion/while bodies
    (counted once, not trip-weighted — presence is what the interleave
    check needs)."""
    memo: Dict[str, int] = {}

    def count(cname: str, seen) -> int:
        if cname in memo:
            return memo[cname]
        if cname in seen:
            return 0
        seen = seen | {cname}
        total = 0
        for op in comps.get(cname, []):
            if op.opcode in _COMPUTE_OPS:
                total += 1
            elif op.opcode in _CALLING_OPS:
                for target in _CALLED_RE.findall(op.attrs):
                    if target in comps:
                        total += count(target, seen)
                bs = _BRANCHES_RE.search(op.attrs)
                if bs:
                    for nm in re.findall(r"%?([\w.\-]+)", bs.group(1)):
                        if nm in comps:
                            total += count(nm, seen)
        memo[cname] = total
        return total

    for cname in comps:
        count(cname, frozenset())
    return memo


def _op_compute_weight(op: Op, memo: Dict[str, int]) -> int:
    if op.opcode in _COMPUTE_OPS:
        return 1
    if op.opcode in _CALLING_OPS:
        total = 0
        for target in _CALLED_RE.findall(op.attrs):
            total += memo.get(target, 0)
        bs = _BRANCHES_RE.search(op.attrs)
        if bs:
            for nm in re.findall(r"%?([\w.\-]+)", bs.group(1)):
                total += memo.get(nm, 0)
        return total
    return 0


def _collective_bytes_of(op: Op, defs: Dict[str, Op]) -> float:
    in_b = sum(type_bytes(defs[o].result) for o in op.operands if o in defs)
    return max(type_bytes(op.result), in_b)


def interleave_report(text: str,
                      min_collective_bytes: int = 512) -> Dict[str, object]:
    """Verify from the *scheduled* HLO whether the gradient collectives
    are interleaved with backward compute or clustered at the tail.

    The XLA text is emitted in scheduled program order, so position is
    evidence: in the non-overlapped step every gradient all-reduce
    depends on the full backward and must sit after the last backward
    convolution/dot; in the overlapped step (DESIGN.md §8) the
    ``optimization_barrier`` pipeline pins each bucket's collective
    between backward segments, so substantial conv/dot compute appears
    between the first and last collective and after the first one.

    A program counts as ``interleaved`` when it has >= 2 qualifying
    (>= ``min_collective_bytes``) collectives, at least one conv/dot
    between the first and the last of them, and at least one conv/dot
    after the first one. Tiny metric pmeans fall under the byte floor.
    """
    comps = parse_computations(text)
    comps.pop("__entry__", None)
    memo = _transitive_compute_counts(comps)

    # the computation carrying the gradient sync = the one with the most
    # qualifying collective bytes
    best_name = None
    best_bytes = -1.0
    for cname, ops in comps.items():
        defs = _op_defs(ops)
        tot = 0.0
        for op in ops:
            base = op.opcode[:-6] if op.opcode.endswith("-start") \
                else op.opcode
            if base in COLLECTIVES:
                b = _collective_bytes_of(op, defs)
                if b >= min_collective_bytes:
                    tot += b
        if tot > best_bytes:
            best_bytes, best_name = tot, cname

    if best_name is None or best_bytes <= 0:
        return {"n_collectives": 0, "interleaved": False,
                "reason": "no qualifying collectives"}

    ops = comps[best_name]
    defs = _op_defs(ops)
    coll_pos: List[int] = []
    weights: List[int] = []
    for idx, op in enumerate(ops):
        weights.append(_op_compute_weight(op, memo))
        base = op.opcode[:-6] if op.opcode.endswith("-start") else op.opcode
        if base in COLLECTIVES and \
                _collective_bytes_of(op, defs) >= min_collective_bytes:
            coll_pos.append(idx)

    total = sum(weights)
    first, last = coll_pos[0], coll_pos[-1]
    after_first = sum(weights[first + 1:])
    between = sum(weights[first + 1:last])
    gaps_with_compute = sum(
        1 for lo, hi in zip(coll_pos, coll_pos[1:])
        if sum(weights[lo + 1:hi]) > 0)
    n = len(coll_pos)
    interleaved = n >= 2 and between >= 1 and after_first >= 1
    return {
        "computation": best_name,
        "n_collectives": n,
        "compute_ops_total": total,
        "compute_ops_before_first": sum(weights[:first]),
        "compute_ops_after_first": after_first,
        "compute_ops_between_first_last": between,
        "gaps_with_compute": gaps_with_compute,
        "interleaved": interleaved,
    }
