"""Back-compat shim: the HLO static analyzer grew into the
``repro.analysis`` subsystem (DESIGN.md §12) — typed IR in
``analysis/hlo_ir.py``, cost engine in ``analysis/cost.py``, reports as
audit passes under ``analysis/passes/``. This module re-exports the
original public surface so existing importers (tests, benchmarks,
dryrun) and doc references keep resolving. New code should import
from ``repro.analysis`` directly.
"""
from repro.analysis.cost import (  # noqa: F401
    MATERIALIZING,
    Analysis,
    analyze_hlo,
    gradient_sync_mode,
)
from repro.analysis.hlo_ir import (  # noqa: F401
    COLLECTIVES,
    DTYPE_BYTES,
    Op,
    _op_defs,
    compute_multipliers,
    parse_computations,
    type_bytes,
    type_shape,
)
from repro.analysis.passes.comm import comm_report  # noqa: F401
from repro.analysis.passes.fusion import (  # noqa: F401
    bn_pass_counts,
    fusion_report,
)
from repro.analysis.passes.interleave import (  # noqa: F401
    interleave_report,
)
