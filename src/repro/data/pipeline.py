"""Production input pipeline: multi-worker host feed + device prefetch.

DESIGN.md §15. Three stages, each independently bounded:

1. **Host producers** — ``num_workers`` threads claim step numbers from a
   shared counter and call ``source.batch_at(step)`` concurrently.
   Because every sample is counter-keyed by ``(seed, split, step,
   global_index)`` (synthetic.py), steps are embarrassingly parallel and
   ordering is purely a delivery concern.
2. **Ordered reorder buffer** — completed batches park in a dict keyed
   by step; the consumer takes them strictly in step order. Backpressure
   bounds the claim horizon to ``depth`` steps past the last delivered
   one, so a stuck consumer stalls producers instead of buffering
   unboundedly.
3. **Device double-buffer** — when a ``put`` callable is given
   (``jax.device_put`` with the step's input sharding), the *next*
   step's host batch is staged onto device while the caller consumes the
   current one, overlapping H2D transfer with compute. JAX dispatch is
   async, so ``put`` returns immediately and the transfer proceeds in
   the background.

Error contract (ported from the legacy ``Prefetcher``): a worker
exception is tagged with its step and delivered from ``next()`` when the
consumer *reaches* that step — batches for earlier steps still arrive,
later claims are cancelled. The exception is raised exactly once;
subsequent ``next()`` calls raise ``StopIteration`` (re-raising one
exception object repeatedly accumulates traceback frames). ``close()``
is race-free against concurrently blocked consumers and producers: both
wait on the same condition variable and re-check the closed flag.

Boundedness attribution (§15): ``next()`` accrues the time the consumer
spent blocked waiting for the host stage into ``wait_s_total`` /
``last_wait_s``. A compute-bound run shows ~zero wait (the buffer is
always ahead); a data-starved run shows wait ≈ step-time gap. The
trainer and step_bench surface this as ``data_wait_ms`` per step.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Callable, Dict, Iterator, Optional

import numpy as np

import jax


class DataPipeline:
    """Multi-worker, step-ordered, optionally device-staged prefetcher.

    Drop-in for the legacy ``Prefetcher`` (same ``(step, batch)``
    iteration and error/close contract) with ``num_workers`` host
    producer threads and an optional device stage.

    Args:
      source: object with ``batch_at(step) -> pytree of np.ndarray``.
      start_step: first step to produce.
      depth: reorder-buffer bound — producers may run at most ``depth``
        steps ahead of the consumer.
      transform: host-side callable applied by the producing worker
        (e.g. augmentation); runs concurrently across workers.
      num_workers: producer thread count (>= 1).
      put: optional device-staging callable (``jax.device_put`` bound to
        the input sharding); applied on the consumer thread one step
        ahead of delivery so transfer overlaps the caller's compute.
      device_ahead: how many steps to stage through ``put`` beyond the
        one being returned (0 disables staging even if ``put`` is set).
    """

    def __init__(self, source, start_step: int = 0, depth: int = 4,
                 transform: Optional[Callable[[Any], Any]] = None,
                 *, num_workers: int = 1,
                 put: Optional[Callable[[Any], Any]] = None,
                 device_ahead: int = 1):
        if depth < 1:
            raise ValueError(f"depth must be >= 1, got {depth}")
        if num_workers < 1:
            raise ValueError(f"num_workers must be >= 1, got {num_workers}")
        self.source = source
        self.transform = transform
        self.num_workers = num_workers
        self._put = put
        self._device_ahead = max(0, device_ahead) if put is not None else 0
        self._depth = depth
        self._cv = threading.Condition()
        self._ready: Dict[int, Any] = {}      # step -> host batch
        self._next_claim = start_step         # next step a worker takes
        self._next_out = start_step           # next step the consumer needs
        self._closed = False
        self._error: Optional[BaseException] = None
        self._error_step: Optional[int] = None
        self._raised = False
        # device stage: (step, staged batch) in step order
        self._staged: deque = deque()
        # attribution counters (host-wait only; device stage is async)
        self.wait_s_total = 0.0
        self.last_wait_s = 0.0
        self.batches_delivered = 0
        self._threads = [
            threading.Thread(target=self._worker, daemon=True,
                             name=f"data-worker-{i}")
            for i in range(num_workers)
        ]
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------- workers

    def _worker(self) -> None:
        while True:
            with self._cv:
                while True:
                    if self._closed or self._error is not None:
                        return
                    if self._next_claim < self._next_out + self._depth:
                        step = self._next_claim
                        self._next_claim += 1
                        break
                    self._cv.wait(timeout=0.1)
            try:
                batch = self.source.batch_at(step)
                if self.transform is not None:
                    batch = self.transform(batch)
            except BaseException as e:
                with self._cv:
                    # keep the error of the smallest step: it is the one
                    # the consumer will hit first, and later steps may
                    # only have failed as a consequence of it
                    if (self._error is None
                            or step < self._error_step):  # type: ignore
                        self._error = e
                        self._error_step = step
                    self._cv.notify_all()
                return
            with self._cv:
                if self._closed:
                    return
                self._ready[step] = batch
                self._cv.notify_all()

    # ------------------------------------------------------------ consumer

    def _host_get(self, step: int, block: bool):
        """Take ``step``'s host batch from the reorder buffer.

        Raises the worker error only when the consumer has *reached* the
        failed step. Non-blocking mode returns None when not ready and
        never raises — used for opportunistic device staging, where a
        pending error must stay attributed to its own step."""
        with self._cv:
            while True:
                if step in self._ready:
                    batch = self._ready.pop(step)
                    self._cv.notify_all()  # frees a claim slot
                    return batch
                if not block:
                    return None
                if self._error is not None and self._error_step <= step:
                    if self._raised:
                        raise StopIteration
                    self._raised = True
                    raise self._error
                if self._closed:
                    raise StopIteration
                self._cv.wait(timeout=0.1)

    def _stage_through(self, step: int) -> None:
        """Opportunistically push host batches for steps up to and
        including ``step`` through the device stage (non-blocking)."""
        while self._staged and self._staged[0][0] < self._next_out:
            self._staged.popleft()  # dropped by a restart seek; unreachable
        last = self._staged[-1][0] if self._staged else self._next_out - 1
        while last < step:
            nxt = last + 1
            host = self._host_get(nxt, block=False)
            if host is None:
                return
            self._staged.append((nxt, self._put(host)))
            last = nxt

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        step = self._next_out
        t0 = time.perf_counter()
        if self._put is not None:
            if not (self._staged and self._staged[0][0] == step):
                # cold start / staging fell behind: block for this step
                host = self._host_get(step, block=True)
                self._staged.append((step, self._put(host)))
            wait = time.perf_counter() - t0
            _, batch = self._staged.popleft()
            self._next_out = step + 1
            with self._cv:
                self._cv.notify_all()
            # stage ahead for future steps while compute runs
            self._stage_through(step + self._device_ahead)
        else:
            batch = self._host_get(step, block=True)
            wait = time.perf_counter() - t0
            self._next_out = step + 1
            with self._cv:
                self._cv.notify_all()
        self.last_wait_s = wait
        self.wait_s_total += wait
        self.batches_delivered += 1
        return step, batch

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        for t in self._threads:
            t.join(timeout=2)
        with self._cv:
            self._ready.clear()
            self._staged.clear()


class AugmentedSource:
    """Host-path reference augmentation (numpy mirror of the fused
    kernel, DESIGN.md §15): per-sample horizontal flip + cyclic
    translation (crop proxy) + per-channel normalize, with parameters
    drawn from the *same* ``jax.random`` stream as the on-device path
    (``ops.input_augment_params``), so host-path and fused-input runs
    consume identical augmented pixels up to dtype rounding.

    ``train=False`` applies normalization only (the eval variant)."""

    def __init__(self, source, seed: int, mean, std, max_shift: int = 4,
                 train: bool = True, global_batch: Optional[int] = None):
        self.source = source
        self.seed = seed
        self.mean = np.asarray(mean, np.float32).reshape(1, 1, 1, -1)
        self.inv_std = (1.0 /
                        np.asarray(std, np.float32)).reshape(1, 1, 1, -1)
        self.max_shift = max_shift
        self.train = train
        # shard bookkeeping for parameter slicing: params are always
        # drawn at the *global* batch size and sliced, because threefry
        # draws are not prefix-stable across different draw sizes — all
        # hosts (and the on-device kernel path) must use the same total
        self.sample_offset = getattr(source, "sample_offset", 0)
        self.global_batch = (global_batch if global_batch is not None
                             else self.sample_offset + source.batch)

    @property
    def batch(self) -> int:
        return self.source.batch

    def batch_at(self, step: int) -> Dict[str, Any]:
        batch = dict(self.source.batch_at(step))
        x = batch["images"].astype(np.float32, copy=True)
        if self.train:
            from repro.kernels import ops  # lazy: keeps data/ jax-light
            b = x.shape[0]
            params = np.asarray(ops.input_augment_params(
                self.seed, step, self.global_batch,
                max_shift=self.max_shift))
            params = params[self.sample_offset:self.sample_offset + b]
            for j in range(b):
                flip, dy, dx, _ = (int(v) for v in params[j])
                img = x[j]
                if flip:
                    img = img[:, ::-1, :]
                img = np.roll(img, (dy, dx), axis=(0, 1))
                x[j] = img
        x = (x - self.mean) * self.inv_std
        batch["images"] = x
        return batch


class StepStampSource:
    """Wraps a source so each batch carries its step number as an
    ``input_step`` scalar — the seed material the fused input kernel
    needs to derive per-step augmentation parameters on device
    (DESIGN.md §15). The scalar rides the batch pytree so donation,
    prefetch and restart logic need no side-channel."""

    def __init__(self, source):
        self.source = source
        self.sample_offset = getattr(source, "sample_offset", 0)

    @property
    def batch(self) -> int:
        return self.source.batch

    def batch_at(self, step: int) -> Dict[str, Any]:
        batch = dict(self.source.batch_at(step))
        batch["input_step"] = np.int32(step)
        return batch
