"""Deterministic synthetic data pipelines.

Determinism contract: batch(step) depends only on (seed, split, step) —
this is what makes straggler backup-steps and elastic restarts possible:
any host can regenerate any step's shard without coordination
(DESIGN.md §5).

Held-out split (DESIGN.md §7): every pipeline takes ``split`` — the
train split draws from seed-space indices ``{base + step}``, the val
split from ``{base - (step + 1)}``. The two index sets are disjoint by
construction (non-negative vs strictly negative offsets), so validation
batches can never alias training batches, for any number of training
steps below 2**30. Image class templates depend only on ``seed``, so
both splits sample the *same* underlying task.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig

SPLITS = ("train", "val")


def _split_index(split: str, step: int) -> int:
    """Disjoint seed-space offsets: train >= 0, val < 0."""
    return step if split == "train" else -(step + 1)


class SyntheticLMData:
    """Language-model token stream with learnable structure (a noisy
    copy/induction task) so loss curves are meaningful, not flat."""

    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int,
                 seed: int = 0, structured: bool = True,
                 split: str = "train"):
        assert split in SPLITS, split
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.structured = structured
        self.split = split

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        idx = _split_index(self.split, step)
        rng = np.random.RandomState((self.seed * 1_000_003 + idx) %
                                    (2 ** 31 - 1))
        v = self.cfg.vocab_size
        b, s = self.batch, self.seq_len
        if self.structured:
            period = 8
            base = rng.randint(0, v, size=(b, period))
            reps = int(np.ceil((s + 1) / period))
            toks = np.tile(base, (1, reps))[:, :s + 1]
            noise = rng.rand(b, s + 1) < 0.05
            toks = np.where(noise, rng.randint(0, v, size=(b, s + 1)), toks)
        else:
            toks = rng.randint(0, v, size=(b, s + 1))
        out: Dict[str, Any] = {
            "tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
        }
        if self.cfg.vision is not None:
            out["patches"] = rng.randn(
                b, self.cfg.vision.num_patches,
                self.cfg.vision.patch_dim).astype(np.float32)
        if self.cfg.audio is not None:
            out["frames"] = rng.randn(
                b, self.cfg.audio.num_frames,
                self.cfg.audio.frame_dim).astype(np.float32)
        return out


class SyntheticImageData:
    """ImageNet-like classification with class-dependent structure:
    images = class template + noise, so a ConvNet can actually learn —
    the substrate for the paper-claims proxy experiment. ``noise``
    controls difficulty (SNR): the quickstart default memorizes in a few
    steps; the recipe/ablation proxies raise it so training is still in
    progress at the schedule-transition epochs, like real ImageNet."""

    def __init__(self, num_classes: int, image_size: int, batch: int,
                 seed: int = 0, noise: float = 0.5,
                 template_rank: int = 8, split: str = "train"):
        assert split in SPLITS, split
        self.num_classes = num_classes
        self.image_size = image_size
        self.batch = batch
        self.seed = seed
        self.noise = noise
        self.split = split
        rng = np.random.RandomState(seed)
        # low-rank smooth class templates (seed-only: shared across splits)
        r = template_rank
        u = rng.randn(num_classes, image_size, r).astype(np.float32)
        w = rng.randn(num_classes, r, image_size * 3).astype(np.float32)
        self.templates = np.einsum("cir,crj->cij", u, w).reshape(
            num_classes, image_size, image_size, 3)
        self.templates /= (self.templates.std() + 1e-6)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        idx = _split_index(self.split, step)
        rng = np.random.RandomState((self.seed * 7_000_003 + idx) %
                                    (2 ** 31 - 1))
        labels = rng.randint(0, self.num_classes, size=(self.batch,))
        imgs = self.templates[labels] + self.noise * rng.randn(
            self.batch, self.image_size, self.image_size, 3).astype(
            np.float32)
        return {"images": imgs.astype(np.float32),
                "labels": labels.astype(np.int32)}


def make_data(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
              split: str = "train", noise: Optional[float] = None):
    if cfg.family == "conv":
        kw = {} if noise is None else {"noise": noise}
        return SyntheticImageData(cfg.num_classes, cfg.image_size,
                                  shape.global_batch, seed, split=split,
                                  **kw)
    return SyntheticLMData(cfg, shape.global_batch, shape.seq_len, seed,
                           split=split)


class Prefetcher:
    """Double-buffered background prefetch of batch_at(step) results.

    Failure contract: if ``batch_at`` or ``transform`` raises, the
    exception is captured and re-raised from the *consumer's* ``next()``
    call (the daemon never dies silently, so ``__next__`` can't block
    forever). ``close()`` is race-free against a concurrently blocked
    ``next()``: consumers poll with a timeout and observe the closed
    flag instead of parking indefinitely on ``Queue.get()``.
    """

    _POLL_S = 0.1

    def __init__(self, source, start_step: int = 0, depth: int = 2,
                 transform=None):
        self.source = source
        self.transform = transform
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        try:
            while not self._stop.is_set():
                batch = self.source.batch_at(step)
                if self.transform is not None:
                    batch = self.transform(batch)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, batch), timeout=self._POLL_S)
                        break
                    except queue.Full:
                        continue
                step += 1
        except BaseException as e:  # re-raised from __next__
            self._error = e

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        while True:
            try:
                return self._q.get(timeout=self._POLL_S)
            except queue.Empty:
                if self._error is not None:
                    err = self._error
                    raise err
                if self._stop.is_set():
                    raise StopIteration
                # daemon alive and healthy: keep waiting

    def close(self):
        self._stop.set()  # wakes blocked consumers (-> StopIteration)
        # drain so a producer blocked on a full queue can observe _stop
        deadline = time.monotonic() + 2.0
        while self._thread.is_alive() and time.monotonic() < deadline:
            try:
                self._q.get_nowait()
            except queue.Empty:
                time.sleep(0.01)
        self._thread.join(timeout=2)
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
