"""Deterministic synthetic data pipelines.

Determinism contract (DESIGN.md §5/§15): every *sample* depends only on
``(seed, split, step, global_index)`` — the batch is just a stack of
independently keyed samples. This is what makes straggler backup-steps,
elastic restarts AND per-host input sharding possible: any host can
regenerate any contiguous slice of any step's batch without
coordination, and the concatenation of the per-host shards is bitwise
identical to the batch a single host would generate
(tests/test_properties.py pins the partition/union/bitwise contract).

Counter-based keying: each sample draws from its own
``np.random.Generator(Philox(key=(mix(seed, split, step), index)))`` —
the production analog of keying an augmentation RNG by record id, and
the host analog of the fused input kernel's seed-per-step derivation
(kernels/fused_input.py).

Held-out split (DESIGN.md §7): every pipeline takes ``split`` — the
train split draws from seed-space indices ``{base + step}``, the val
split from ``{base - (step + 1)}``. The two index sets are disjoint by
construction (non-negative vs strictly negative offsets), so validation
batches can never alias training batches, for any number of training
steps below 2**30. Image class templates depend only on ``seed``, so
both splits sample the *same* underlying task.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig

SPLITS = ("train", "val")

_MASK64 = (1 << 64) - 1


def _split_index(split: str, step: int) -> int:
    """Disjoint seed-space offsets: train >= 0, val < 0."""
    return step if split == "train" else -(step + 1)


def _sample_rng(mix: int, seed: int, idx: int, index: int):
    """Counter-based per-sample generator: Philox keyed by
    ``(mix(seed, split, step), global sample index)``. Two key words,
    so the (seed, step) stream and the sample index are independent
    axes — regenerating sample ``i`` never requires drawing samples
    ``0..i-1`` first (the per-host shard contract)."""
    k = np.uint64((seed * mix + idx) & _MASK64)
    return np.random.Generator(
        np.random.Philox(key=np.array([k, index & _MASK64],
                                      dtype=np.uint64)))


def _check_shard(batch: int, sample_offset: int) -> None:
    if batch <= 0:
        raise ValueError(f"per-host batch must be positive, got {batch}")
    if sample_offset < 0:
        raise ValueError(f"sample_offset must be >= 0, got {sample_offset}")


class SyntheticLMData:
    """Language-model token stream with learnable structure (a noisy
    copy/induction task) so loss curves are meaningful, not flat.

    ``sample_offset``: index of this pipeline's first sample in the
    *global* batch — a per-host shard generates only rows
    ``[sample_offset, sample_offset + batch)`` of the global batch
    (bitwise equal to that slice of a single-host pipeline)."""

    _MIX = 1_000_003

    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int,
                 seed: int = 0, structured: bool = True,
                 split: str = "train", sample_offset: int = 0):
        assert split in SPLITS, split
        _check_shard(batch, sample_offset)
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.structured = structured
        self.split = split
        self.sample_offset = sample_offset

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        idx = _split_index(self.split, step)
        v = self.cfg.vocab_size
        b, s = self.batch, self.seq_len
        toks = np.empty((b, s + 1), np.int32)
        patches = frames = None
        if self.cfg.vision is not None:
            vf = self.cfg.vision
            patches = np.empty((b, vf.num_patches, vf.patch_dim),
                               np.float32)
        if self.cfg.audio is not None:
            af = self.cfg.audio
            frames = np.empty((b, af.num_frames, af.frame_dim), np.float32)
        for j in range(b):
            rng = _sample_rng(self._MIX, self.seed, idx,
                              self.sample_offset + j)
            if self.structured:
                period = 8
                base = rng.integers(0, v, size=(period,))
                reps = int(np.ceil((s + 1) / period))
                row = np.tile(base, reps)[:s + 1]
                noise = rng.random(s + 1) < 0.05
                row = np.where(noise, rng.integers(0, v, size=(s + 1,)),
                               row)
            else:
                row = rng.integers(0, v, size=(s + 1,))
            toks[j] = row
            if patches is not None:
                patches[j] = rng.standard_normal(patches.shape[1:],
                                                 dtype=np.float32)
            if frames is not None:
                frames[j] = rng.standard_normal(frames.shape[1:],
                                                dtype=np.float32)
        out: Dict[str, Any] = {
            "tokens": np.ascontiguousarray(toks[:, :-1]),
            "targets": np.ascontiguousarray(toks[:, 1:]),
        }
        if patches is not None:
            out["patches"] = patches
        if frames is not None:
            out["frames"] = frames
        return out


class SyntheticImageData:
    """ImageNet-like classification with class-dependent structure:
    images = class template + noise, so a ConvNet can actually learn —
    the substrate for the paper-claims proxy experiment. ``noise``
    controls difficulty (SNR): the quickstart default memorizes in a few
    steps; the recipe/ablation proxies raise it so training is still in
    progress at the schedule-transition epochs, like real ImageNet.

    Allocation contract (tests/test_pipeline.py): ``batch_at`` fills one
    preallocated float32 batch buffer in place — noise is generated
    directly in float32 (``Generator.standard_normal(dtype=...)``) and
    scaled/added with ``out=`` ufuncs, so peak host memory stays ~1x the
    batch (the seed-era path materialized a float64 noise tensor and
    then ``astype``-copied the summed image a second time)."""

    _MIX = 7_000_003

    def __init__(self, num_classes: int, image_size: int, batch: int,
                 seed: int = 0, noise: float = 0.5,
                 template_rank: int = 8, split: str = "train",
                 sample_offset: int = 0):
        assert split in SPLITS, split
        _check_shard(batch, sample_offset)
        self.num_classes = num_classes
        self.image_size = image_size
        self.batch = batch
        self.seed = seed
        self.noise = noise
        self.split = split
        self.sample_offset = sample_offset
        rng = np.random.RandomState(seed)
        # low-rank smooth class templates (seed-only: shared across splits)
        r = template_rank
        u = rng.randn(num_classes, image_size, r).astype(np.float32)
        w = rng.randn(num_classes, r, image_size * 3).astype(np.float32)
        self.templates = np.einsum("cir,crj->cij", u, w).reshape(
            num_classes, image_size, image_size, 3)
        self.templates /= (self.templates.std() + 1e-6)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        idx = _split_index(self.split, step)
        b, s = self.batch, self.image_size
        labels = np.empty((b,), np.int32)
        imgs = np.empty((b, s, s, 3), np.float32)
        scale = np.float32(self.noise)
        for j in range(b):
            rng = _sample_rng(self._MIX, self.seed, idx,
                              self.sample_offset + j)
            lab = int(rng.integers(0, self.num_classes))
            labels[j] = lab
            out = imgs[j]
            out[...] = self.templates[lab]
            noise = rng.standard_normal((s, s, 3), dtype=np.float32)
            np.multiply(noise, scale, out=noise)
            np.add(out, noise, out=out)
        return {"images": imgs, "labels": labels}


def make_data(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0,
              split: str = "train", noise: Optional[float] = None,
              num_hosts: int = 1, host_id: int = 0):
    """Build the pipeline for this host's shard of the global batch.

    ``num_hosts``/``host_id`` select a per-host shard: host ``h``
    generates only rows ``[h * B/N, (h+1) * B/N)`` of the global batch
    (DESIGN.md §15). ``num_hosts=1`` (the default) is the full batch."""
    if not 0 <= host_id < num_hosts:
        raise ValueError(f"host_id {host_id} not in [0, {num_hosts})")
    if shape.global_batch % num_hosts:
        raise ValueError(
            f"global batch {shape.global_batch} must divide evenly over "
            f"{num_hosts} hosts")
    per_host = shape.global_batch // num_hosts
    offset = host_id * per_host
    if cfg.family == "conv":
        kw = {} if noise is None else {"noise": noise}
        return SyntheticImageData(cfg.num_classes, cfg.image_size,
                                  per_host, seed, split=split,
                                  sample_offset=offset, **kw)
    return SyntheticLMData(cfg, per_host, shape.seq_len, seed,
                           split=split, sample_offset=offset)


class Prefetcher:
    """Single-worker double-buffered prefetch of batch_at(step) results.

    Legacy path — the production multi-worker pipeline is
    ``repro.data.pipeline.DataPipeline`` (same contract, DESIGN.md §15).

    Failure contract: if ``batch_at`` or ``transform`` raises, the
    exception is captured and re-raised from the *consumer's* ``next()``
    call exactly once (the daemon never dies silently, so ``__next__``
    can't block forever); every subsequent ``next()`` raises
    ``StopIteration`` — re-raising the same exception object repeatedly
    would append a new traceback frame chain on every raise.
    ``close()`` is race-free against a concurrently blocked ``next()``:
    consumers poll with a timeout and observe the closed flag instead of
    parking indefinitely on ``Queue.get()``.
    """

    _POLL_S = 0.1

    def __init__(self, source, start_step: int = 0, depth: int = 2,
                 transform=None):
        self.source = source
        self.transform = transform
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._error: Optional[BaseException] = None
        self._raised = False
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        try:
            while not self._stop.is_set():
                batch = self.source.batch_at(step)
                if self.transform is not None:
                    batch = self.transform(batch)
                while not self._stop.is_set():
                    try:
                        self._q.put((step, batch), timeout=self._POLL_S)
                        break
                    except queue.Full:
                        continue
                step += 1
        except BaseException as e:  # re-raised from __next__
            self._error = e

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        while True:
            try:
                return self._q.get(timeout=self._POLL_S)
            except queue.Empty:
                if self._error is not None:
                    if self._raised:  # raise once, then StopIteration
                        raise StopIteration
                    self._raised = True
                    raise self._error
                if self._stop.is_set():
                    raise StopIteration
                # daemon alive and healthy: keep waiting

    def close(self):
        self._stop.set()  # wakes blocked consumers (-> StopIteration)
        # drain so a producer blocked on a full queue can observe _stop
        deadline = time.monotonic() + 2.0
        while self._thread.is_alive() and time.monotonic() < deadline:
            try:
                self._q.get_nowait()
            except queue.Empty:
                time.sleep(0.01)
        self._thread.join(timeout=2)
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
