"""Deterministic synthetic data pipelines.

Determinism contract: batch(step) depends only on (seed, step) — this is
what makes straggler backup-steps and elastic restarts possible: any host
can regenerate any step's shard without coordination (DESIGN.md §5).
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


class SyntheticLMData:
    """Language-model token stream with learnable structure (a noisy
    copy/induction task) so loss curves are meaningful, not flat."""

    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int,
                 seed: int = 0, structured: bool = True):
        self.cfg = cfg
        self.batch = batch
        self.seq_len = seq_len
        self.seed = seed
        self.structured = structured

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState((self.seed * 1_000_003 + step) %
                                    (2 ** 31 - 1))
        v = self.cfg.vocab_size
        b, s = self.batch, self.seq_len
        if self.structured:
            period = 8
            base = rng.randint(0, v, size=(b, period))
            reps = int(np.ceil((s + 1) / period))
            toks = np.tile(base, (1, reps))[:, :s + 1]
            noise = rng.rand(b, s + 1) < 0.05
            toks = np.where(noise, rng.randint(0, v, size=(b, s + 1)), toks)
        else:
            toks = rng.randint(0, v, size=(b, s + 1))
        out: Dict[str, Any] = {
            "tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
        }
        if self.cfg.vision is not None:
            out["patches"] = rng.randn(
                b, self.cfg.vision.num_patches,
                self.cfg.vision.patch_dim).astype(np.float32)
        if self.cfg.audio is not None:
            out["frames"] = rng.randn(
                b, self.cfg.audio.num_frames,
                self.cfg.audio.frame_dim).astype(np.float32)
        return out


class SyntheticImageData:
    """ImageNet-like classification with class-dependent structure:
    images = class template + noise, so a ConvNet can actually learn —
    the substrate for the paper-claims proxy experiment."""

    def __init__(self, num_classes: int, image_size: int, batch: int,
                 seed: int = 0, noise: float = 0.5,
                 template_rank: int = 8):
        self.num_classes = num_classes
        self.image_size = image_size
        self.batch = batch
        self.seed = seed
        self.noise = noise
        rng = np.random.RandomState(seed)
        # low-rank smooth class templates
        r = template_rank
        u = rng.randn(num_classes, image_size, r).astype(np.float32)
        w = rng.randn(num_classes, r, image_size * 3).astype(np.float32)
        self.templates = np.einsum("cir,crj->cij", u, w).reshape(
            num_classes, image_size, image_size, 3)
        self.templates /= (self.templates.std() + 1e-6)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.RandomState((self.seed * 7_000_003 + step) %
                                    (2 ** 31 - 1))
        labels = rng.randint(0, self.num_classes, size=(self.batch,))
        imgs = self.templates[labels] + self.noise * rng.randn(
            self.batch, self.image_size, self.image_size, 3).astype(
            np.float32)
        return {"images": imgs.astype(np.float32),
                "labels": labels.astype(np.int32)}


def make_data(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0):
    if cfg.family == "conv":
        return SyntheticImageData(cfg.num_classes, cfg.image_size,
                                  shape.global_batch, seed)
    return SyntheticLMData(cfg, shape.global_batch, shape.seq_len, seed)


class Prefetcher:
    """Double-buffered background prefetch of batch_at(step) results."""

    def __init__(self, source, start_step: int = 0, depth: int = 2,
                 transform=None):
        self.source = source
        self.transform = transform
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        step = self._step
        while not self._stop.is_set():
            batch = self.source.batch_at(step)
            if self.transform is not None:
                batch = self.transform(batch)
            while not self._stop.is_set():
                try:
                    self._q.put((step, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            step += 1

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2)
