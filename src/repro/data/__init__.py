from repro.data.pipeline import (  # noqa: F401
    AugmentedSource,
    DataPipeline,
    StepStampSource,
)
from repro.data.synthetic import (  # noqa: F401
    Prefetcher,
    SyntheticImageData,
    SyntheticLMData,
    make_data,
)
