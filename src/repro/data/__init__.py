from repro.data.synthetic import (  # noqa: F401
    Prefetcher,
    SyntheticImageData,
    SyntheticLMData,
    make_data,
)
