"""ResNet-50 in JAX (NHWC) — the paper's own benchmark architecture.

BatchNorm follows the paper's §2 variant: **no moving averages**. The BN
statistics of the *last minibatch* are kept as model state; before
validation they are all-reduced (pmean over the data axes) by
``core.batchnorm.finalize_bn_stats``. During training, normalization uses
the current minibatch's (optionally cross-replica) statistics.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.batchnorm import bn_apply_stats, bn_batch_stats
from repro.distributed.sharding import constrain
from repro.models import common
from repro.models.common import Boxed, unbox

Params = Dict[str, Any]


def conv_init(key, kh, kw, c_in, c_out) -> Boxed:
    fan_in = kh * kw * c_in
    return Boxed(common.he_init(key, (kh, kw, c_in, c_out), fan_in),
                 (None, None, "conv_in", "conv_out"))


def bn_init(c: int) -> Params:
    return {"scale": common.ones((c,), ("conv_out",)),
            "bias": common.zeros((c,), ("conv_out",))}


def conv(x, w, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, w.astype(x.dtype), (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


class ResNet50:
    """Bottleneck ResNet. ``model_state`` carries last-minibatch BN stats."""

    def __init__(self, cfg: ModelConfig, compute_dtype=jnp.bfloat16,
                 cross_replica_bn: bool = False,
                 fused_bn: Optional[bool] = None, **_):
        self.cfg = cfg
        self.compute_dtype = compute_dtype
        self.cross_replica_bn = cross_replica_bn
        # fused Pallas BN (kernels/fused_bn.py, DESIGN.md §10): cfg flag
        # by default, overridable per-instance for A/B tests
        self.fused_bn = (bool(getattr(cfg, "fused_bn", False))
                         if fused_bn is None else bool(fused_bn))
        self._bn_names: List[str] = []

    # ------------------------------------------------------------- init
    def init(self, key) -> Params:
        cfg = self.cfg
        w = cfg.conv_width
        ks = iter(jax.random.split(key, 256))
        p: Params = {"stem": {"conv": conv_init(next(ks), 7, 7, 3, w),
                              "bn": bn_init(w)}}
        c_in = w
        for si, blocks in enumerate(cfg.conv_stages):
            mid = w * (2 ** si)
            c_out = mid * 4
            stage: Params = {}
            for bi in range(blocks):
                blk: Params = {
                    "conv1": conv_init(next(ks), 1, 1, c_in, mid),
                    "bn1": bn_init(mid),
                    "conv2": conv_init(next(ks), 3, 3, mid, mid),
                    "bn2": bn_init(mid),
                    "conv3": conv_init(next(ks), 1, 1, mid, c_out),
                    "bn3": bn_init(c_out),
                }
                if bi == 0:
                    blk["proj"] = conv_init(next(ks), 1, 1, c_in, c_out)
                    blk["proj_bn"] = bn_init(c_out)
                stage[f"block{bi}"] = blk
                c_in = c_out
            p[f"stage{si}"] = stage
        p["fc"] = {
            "w": common.dense(next(ks), c_in, cfg.num_classes,
                              ("conv_in", None)),
            "b": common.zeros((cfg.num_classes,), (None,)),
        }
        return p

    def init_params(self, key):
        return unbox(self.init(key))

    def init_state(self) -> Params:
        """BN last-minibatch stats, zero-initialized (mean 0 / var 1)."""
        cfg = self.cfg
        w = cfg.conv_width
        state: Params = {"stem/bn": _stat(w)}
        c_in = w
        for si, blocks in enumerate(cfg.conv_stages):
            mid = w * (2 ** si)
            c_out = mid * 4
            for bi in range(blocks):
                state[f"stage{si}/block{bi}/bn1"] = _stat(mid)
                state[f"stage{si}/block{bi}/bn2"] = _stat(mid)
                state[f"stage{si}/block{bi}/bn3"] = _stat(c_out)
                if bi == 0:
                    state[f"stage{si}/block{bi}/proj_bn"] = _stat(c_out)
            c_in = c_out
        return state

    # -------------------------------------------------------------- fwd
    def _bn(self, p_bn, x, name, state, new_state, train: bool,
            relu: bool = False, residual=None):
        """One BN site with its epilogue (optional ReLU / residual add).

        The epilogue lives here — not at the call sites — so the fused
        Pallas path (``fused_bn``, DESIGN.md §10) can fold it into the
        normalize pass and its custom-VJP backward; the unfused jnp path
        applies the identical ops sequentially (the oracle)."""
        scale, bias = p_bn["scale"], p_bn["bias"]
        if train:
            if self.fused_bn:
                from repro.kernels.ops import fused_bn_train
                y, mean, var = fused_bn_train(
                    x, scale, bias, residual=residual, relu=relu,
                    cross_replica=self.cross_replica_bn or None)
                new_state[name] = {"mean": mean, "var": var,
                                   "count": jnp.array(1.0, jnp.float32)}
                return y
            mean, var = bn_batch_stats(x, cross_replica=self.cross_replica_bn)
            new_state[name] = {"mean": mean, "var": var,
                               "count": jnp.array(1.0, jnp.float32)}
        else:
            mean = state[name]["mean"]
            var = state[name]["var"]
            if self.fused_bn:
                from repro.kernels.ops import fused_bn_apply
                return fused_bn_apply(x, mean, var, scale, bias,
                                      residual=residual, relu=relu)
        y = bn_apply_stats(x, mean, var, scale, bias)
        if residual is not None:
            y = y + residual
        if relu:
            y = jax.nn.relu(y)
        return y

    # Per-segment forwards: apply() composes them sequentially; the
    # overlap train step VJPs them independently (loss_segments below,
    # DESIGN.md §8) — one source of truth for both execution paths.
    def _stem_fwd(self, p_stem, images, state, train: bool):
        x = images.astype(self.compute_dtype)
        x = constrain(x, ("batch", None, None, None))
        new_state: Params = {}
        x = conv(x, p_stem["conv"], stride=2)
        x = self._bn(p_stem["bn"], x, "stem/bn", state, new_state, train,
                     relu=True)
        x = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1), "SAME")
        return x, new_state

    def _stage_fwd(self, si: int, p_stage, x, state, train: bool):
        new_state: Params = {}
        for bi in range(self.cfg.conv_stages[si]):
            blk = p_stage[f"block{bi}"]
            pre = f"stage{si}/block{bi}"
            stride = 2 if (bi == 0 and si > 0) else 1
            if bi == 0:
                sc = conv(x, blk["proj"], stride=stride)
                sc = self._bn(blk["proj_bn"], sc, f"{pre}/proj_bn",
                              state, new_state, train)
            else:
                sc = x
            out = conv(x, blk["conv1"])
            out = self._bn(blk["bn1"], out, f"{pre}/bn1", state,
                           new_state, train, relu=True)
            out = conv(out, blk["conv2"], stride=stride)
            out = self._bn(blk["bn2"], out, f"{pre}/bn2", state,
                           new_state, train, relu=True)
            out = conv(out, blk["conv3"])
            # block output: BN + residual add + ReLU, one fused site
            x = self._bn(blk["bn3"], out, f"{pre}/bn3", state, new_state,
                         train, relu=True, residual=sc)
        return x, new_state

    def _head_logits(self, p_fc, x):
        x = jnp.mean(x, axis=(1, 2))
        logits = x @ p_fc["w"].astype(x.dtype) + p_fc["b"].astype(x.dtype)
        return logits.astype(jnp.float32)

    @staticmethod
    def _softmax_xent(logits, labels, label_smoothing: float):
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        if label_smoothing:
            nll = (1 - label_smoothing) * nll - label_smoothing * jnp.mean(
                logp, axis=-1)
        loss = jnp.mean(nll)
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        return loss, acc

    def apply(self, p: Params, state: Params, images: jax.Array,
              train: bool = True) -> Tuple[jax.Array, Params]:
        new_state: Params = {}
        x, frag = self._stem_fwd(p["stem"], images, state, train)
        new_state.update(frag)
        for si in range(len(self.cfg.conv_stages)):
            x, frag = self._stage_fwd(si, p[f"stage{si}"], x, state, train)
            new_state.update(frag)
        logits = self._head_logits(p["fc"], x)
        return logits, (new_state if train else state)

    # ------------------------------------------------------------ losses
    def loss_fn(self, p, model_state, batch, label_smoothing=0.0):
        logits, new_state = self.apply(p, model_state, batch["images"],
                                       train=True)
        loss, acc = self._softmax_xent(logits, batch["labels"],
                                       label_smoothing)
        return loss, (new_state, {"loss": loss, "accuracy": acc})

    # ----------------------------------------------------- staged apply
    def loss_segments(self, params: Params, model_state: Params,
                      batch, label_smoothing: float = 0.0
                      ) -> common.StagedLoss:
        """K = 2 + n_stages segments: stem / stage0..stageN / fc+loss.

        Segment boundaries coincide with the top-level parameter keys,
        so split/merge are plain dict projections (DESIGN.md §8). Each
        segment is the same helper ``apply`` composes, so the staged
        forward traces the identical primitive sequence.
        """
        n_stages = len(self.cfg.conv_stages)
        names = ("stem",) + tuple(f"stage{si}" for si in range(n_stages)) \
            + ("fc",)

        def stem_fn(sp, images):
            x, frag = self._stem_fwd(sp, images, model_state, True)
            return x, frag

        def make_stage_fn(si):
            def stage_fn(sp, x):
                return self._stage_fwd(si, sp, x, model_state, True)
            return stage_fn

        def head_fn(sp, x):
            logits = self._head_logits(sp, x)
            loss, acc = self._softmax_xent(logits, batch["labels"],
                                           label_smoothing)
            return loss, ({}, {"loss": loss, "accuracy": acc})

        seg_fns = (stem_fn,) + tuple(make_stage_fn(si)
                                     for si in range(n_stages)) + (head_fn,)

        def split_tree(tree):
            return [tree[k] for k in names]

        def merge_grads(seg_grads):
            return dict(zip(names, seg_grads))

        def finalize_aux(auxes):
            new_state: Params = {}
            for frag in auxes[:-1]:
                new_state.update(frag)
            state_frag, metrics = auxes[-1]
            new_state.update(state_frag)
            return new_state, metrics

        return common.StagedLoss(
            names=names, seg_params=tuple(split_tree(params)),
            seg_fns=seg_fns, x0=batch["images"], merge_grads=merge_grads,
            split_tree=split_tree, finalize_aux=finalize_aux)

    def eval_fn(self, p, model_state, batch):
        """Validation metrics with frozen (finalized) BN statistics."""
        logits, _ = self.apply(p, model_state, batch["images"], train=False)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
        top1 = jnp.mean((jnp.argmax(logits, -1) == labels).astype(
            jnp.float32))
        return {"top1": top1, "loss": jnp.mean(nll)}


def _stat(c: int) -> Params:
    return {"mean": jnp.zeros((c,), jnp.float32),
            "var": jnp.ones((c,), jnp.float32),
            "count": jnp.array(0.0, jnp.float32)}
