"""Model registry: arch family -> model class; uniform Model interface.

Model protocol (duck-typed):
  init_params(key) -> (params, logical_axes_tree)
  loss_fn(params, model_state, batch, label_smoothing) -> (loss, (state', metrics))
  cache_shape(batch, max_seq, dtype) -> (cache_zeros, cache_axes)   [LMs]
  prefill(params, tokens, cache, **frontend) -> (last_logits, cache)
  decode_step(params, cache, tokens, cache_index) -> (logits, cache)
"""
from __future__ import annotations

from typing import Any

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.mamba import Zamba2Model
from repro.models.resnet import ResNet50
from repro.models.transformer import TransformerLM
from repro.models.whisper import WhisperModel
from repro.models.xlstm import XLSTMModel

_FAMILIES = {
    "dense": TransformerLM,
    "moe": TransformerLM,
    "vlm": TransformerLM,
    "hybrid": Zamba2Model,
    "ssm": XLSTMModel,
    "audio": WhisperModel,
    "conv": ResNet50,
}


def build_model(cfg: ModelConfig, compute_dtype=jnp.bfloat16,
                attention_impl: str = "chunked", remat: bool = True) -> Any:
    cls = _FAMILIES[cfg.family]
    return cls(cfg, compute_dtype=compute_dtype,
               attention_impl=attention_impl, remat=remat)


def init_model_state(model) -> Any:
    """BN-bearing models carry last-minibatch stats; others empty."""
    if hasattr(model, "init_state"):
        return model.init_state()
    return {}
