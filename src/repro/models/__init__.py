"""Pure-JAX model zoo (no flax): transformer (dense/moe/vlm), Mamba2
hybrid, xLSTM, Whisper enc-dec, ResNet-50."""
from repro.models.registry import build_model, init_model_state  # noqa: F401
