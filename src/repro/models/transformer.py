"""Decoder-only transformer LM covering the dense / moe / vlm families.

Layers are scanned (params stacked on a leading "layers" dim) so the HLO
stays compact at 80+ layers. MoE archs with ``moe_layer_every=k`` scan over
layer *groups* of k sub-layers (k-1 dense + 1 MoE), matching llama4's
alternating pattern. VLM (phi-3-vision) does early fusion: projected patch
embeddings are prepended to the token sequence.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import common, layers
from repro.models.common import Boxed, apply_norm, norm_init, unbox

Params = Dict[str, Any]


class TransformerLM:
    def __init__(self, cfg: ModelConfig, compute_dtype=jnp.bfloat16,
                 attention_impl: str = "chunked", remat: bool = True,
                 comm_stages: int = 4):
        self.cfg = cfg
        self.compute_dtype = compute_dtype
        self.attention_impl = attention_impl
        self.remat = remat
        # how many slices loss_segments cuts the layer scan into — the
        # granularity of backward-overlapped gradient sync (DESIGN.md §8)
        self.comm_stages = comm_stages
        self.group = cfg.moe_layer_every if cfg.n_experts else 1
        assert cfg.n_layers % self.group == 0
        self.n_groups = cfg.n_layers // self.group

    # ------------------------------------------------------------------ init
    def init(self, key) -> Params:
        cfg = self.cfg
        ks = iter(jax.random.split(key, 8 + 4 * self.group))
        p: Params = {"embed": layers.embedding_init(next(ks), cfg)}
        if cfg.vision is not None:
            p["vision_proj"] = common.dense(
                next(ks), cfg.vision.patch_dim, cfg.d_model,
                (None, "embed"))
        for j in range(self.group):
            sub: Params = {
                "norm1": norm_init(cfg.norm, cfg.d_model, self.n_groups),
                "attn": layers.attention_init(next(ks), cfg, self.n_groups),
                "norm2": norm_init(cfg.norm, cfg.d_model, self.n_groups),
            }
            if cfg.is_moe_layer(j):
                sub["moe"] = layers.moe_init(next(ks), cfg, self.n_groups)
            else:
                sub["mlp"] = layers.mlp_init(next(ks), cfg, self.n_groups)
            p[f"sub{j}"] = sub
        p["final_norm"] = norm_init(cfg.norm, cfg.d_model)
        if not cfg.tie_embeddings:
            p["head"] = common.dense(next(ks), cfg.d_model, cfg.vocab_size,
                                     ("embed", "vocab"))
        return p

    def init_params(self, key):
        """Returns (params, logical_axes_tree)."""
        return unbox(self.init(key))

    # ------------------------------------------------------------- sub-layer
    def _block(self, sub_p: Params, x, positions, mode: str, sub_idx: int,
               cache: Optional[Params], cache_index) -> Tuple:
        cfg = self.cfg
        h = apply_norm(sub_p["norm1"], x, cfg.norm, cfg.norm_eps)
        attn_out, new_cache = layers.attention_apply(
            sub_p["attn"], h, cfg,
            positions=positions,
            causal=True,
            window=cfg.sliding_window,
            impl=self.attention_impl,
            cache=cache,
            cache_index=cache_index,
        )
        x = x + attn_out
        h = apply_norm(sub_p["norm2"], x, cfg.norm, cfg.norm_eps)
        if "moe" in sub_p:
            mlp_out, aux = layers.moe_apply(sub_p["moe"], h, cfg)
        else:
            mlp_out, aux = layers.mlp_apply(sub_p["mlp"], h, cfg), 0.0
        return x + mlp_out, new_cache, aux

    def _scan_layers(self, sub_params: Params, x, positions, mode: str,
                     cache: Optional[Params], cache_index, aux0=0.0):
        """lax.scan over layer groups. cache leaves: (G, B, S, KV, Dh).

        ``sub_params`` is the stacked {"sub{j}": ...} dict — the full
        stack in the monolithic forward, a leading-dim slice of it in a
        staged segment (loss_segments, DESIGN.md §8). ``aux0`` seeds the
        MoE aux accumulator so it threads across segment boundaries."""

        def group_fn(carry, scanned):
            x, aux_acc = carry
            sub_p, sub_caches = scanned
            new_caches = {}
            for j in range(self.group):
                c = sub_caches[f"sub{j}"] if sub_caches is not None else None
                x, nc, aux = self._block(sub_p[f"sub{j}"], x, positions,
                                         mode, j, c, cache_index)
                if nc is not None:
                    new_caches[f"sub{j}"] = nc
            return (x, aux_acc + aux), (new_caches if new_caches else None)

        fn = group_fn
        if self.remat and mode == "train":
            fn = jax.checkpoint(
                group_fn, policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux), new_cache = jax.lax.scan(
            fn, (x, aux0), (sub_params, cache))
        return x, aux, new_cache

    # ---------------------------------------------------------------- fwd
    def forward(self, p: Params, tokens: jax.Array, *,
                patches: Optional[jax.Array] = None,
                mode: str = "train",
                cache: Optional[Params] = None,
                cache_index=None) -> Tuple[jax.Array, Any, Optional[Params]]:
        """Returns (logits, moe_aux, new_cache).

        tokens: (B, S) int32. In decode mode S==1 and cache_index is the
        write position. patches: (B, P, patch_dim) for VLM early fusion.
        """
        cfg = self.cfg
        x = layers.embed(p["embed"], tokens, self.compute_dtype)
        n_patches = 0
        if patches is not None:
            pe = patches.astype(self.compute_dtype) @ p["vision_proj"].astype(
                self.compute_dtype)
            x = jnp.concatenate([pe, x], axis=1)
            n_patches = pe.shape[1]
        b, s, _ = x.shape
        if mode == "decode":
            positions = jnp.broadcast_to(cache_index, (b,))[:, None]
        else:
            positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
            if cache is not None and cache_index is None:
                cache_index = 0
        sub_params = {f"sub{j}": p[f"sub{j}"] for j in range(self.group)}
        x, aux, new_cache = self._scan_layers(sub_params, x, positions,
                                              mode, cache, cache_index)
        x = apply_norm(p["final_norm"], x, cfg.norm, cfg.norm_eps)
        if n_patches:
            x = x[:, n_patches:, :]
        w = p["embed"]["table"] if cfg.tie_embeddings else p["head"]
        logits = layers.lm_head(w, x, cfg.tie_embeddings)
        return logits, aux, new_cache

    # --------------------------------------------------------------- losses
    def loss_fn(self, p: Params, model_state: Params, batch: Dict,
                label_smoothing: float = 0.0):
        logits, moe_aux, _ = self.forward(
            p, batch["tokens"], patches=batch.get("patches"), mode="train")
        loss, n_tok = common.cross_entropy_loss(
            logits, batch["targets"], label_smoothing=label_smoothing)
        total = loss + 0.01 * moe_aux
        metrics = {"loss": loss, "moe_aux": moe_aux, "tokens": n_tok}
        return total, (model_state, metrics)

    # ----------------------------------------------------- staged apply
    def loss_segments(self, params: Params, model_state: Params,
                      batch: Dict, label_smoothing: float = 0.0
                      ) -> common.StagedLoss:
        """Segments: embed / <=``comm_stages`` layer-group slices / head.

        The layer scan is cut into leading-dim slices of the stacked
        "sub{j}" params — each segment scans its slice with the same
        (remat'd) group body, so the staged forward computes exactly the
        monolithic forward's per-layer ops (DESIGN.md §8). The carry is
        ``(x, moe_aux)``; with tied embeddings the shared table rides in
        the carry too, so its two gradient contributions (token lookup +
        LM head) sum through the VJP chain exactly as in the monolithic
        backward — every param leaf stays owned by exactly one segment.
        """
        cfg = self.cfg
        tied = cfg.tie_embeddings
        tokens = batch["tokens"]
        patches = batch.get("patches")
        n_patches = 0 if patches is None else patches.shape[1]
        n_lseg = max(1, min(self.comm_stages, self.n_groups))
        bounds = [round(i * self.n_groups / n_lseg)
                  for i in range(n_lseg + 1)]
        emb_keys = ["embed"] + (["vision_proj"] if "vision_proj" in params
                                else [])
        head_keys = ["final_norm"] + ([] if tied else ["head"])
        names = ("embed",) + tuple(f"layers{lo}_{hi}" for lo, hi in
                                   zip(bounds, bounds[1:])) + ("head",)

        def embed_fn(sp, _x0):
            x = layers.embed(sp["embed"], tokens, self.compute_dtype)
            if patches is not None:
                pe = patches.astype(self.compute_dtype) @ \
                    sp["vision_proj"].astype(self.compute_dtype)
                x = jnp.concatenate([pe, x], axis=1)
            carry = (x, jnp.zeros((), jnp.float32))
            if tied:
                carry += (sp["embed"]["table"],)
            return carry, None

        def make_layer_fn():
            def layer_fn(sp, carry):
                x, aux = carry[0], carry[1]
                b, s, _ = x.shape
                positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
                x, aux, _ = self._scan_layers(sp, x, positions, "train",
                                              None, None, aux0=aux)
                return (x, aux) + carry[2:], None
            return layer_fn

        def head_fn(sp, carry):
            x, moe_aux = carry[0], carry[1]
            x = apply_norm(sp["final_norm"], x, cfg.norm, cfg.norm_eps)
            if n_patches:
                x = x[:, n_patches:, :]
            w = carry[2] if tied else sp["head"]
            logits = layers.lm_head(w, x, tied)
            loss, n_tok = common.cross_entropy_loss(
                logits, batch["targets"], label_smoothing=label_smoothing)
            total = loss + 0.01 * moe_aux
            return total, ({}, {"loss": loss, "moe_aux": moe_aux,
                                "tokens": n_tok})

        seg_fns = (embed_fn,) + tuple(make_layer_fn()
                                      for _ in range(n_lseg)) + (head_fn,)

        def split_tree(tree):
            segs = [{k: tree[k] for k in emb_keys}]
            for lo, hi in zip(bounds, bounds[1:]):
                segs.append({
                    f"sub{j}": jax.tree.map(lambda a: a[lo:hi],
                                            tree[f"sub{j}"])
                    for j in range(self.group)})
            segs.append({k: tree[k] for k in head_keys})
            return segs

        def merge_grads(seg_grads):
            full = dict(seg_grads[0])
            full.update(seg_grads[-1])
            for j in range(self.group):
                full[f"sub{j}"] = jax.tree.map(
                    lambda *s: jnp.concatenate(s, axis=0),
                    *[sg[f"sub{j}"] for sg in seg_grads[1:-1]])
            return full

        def finalize_aux(auxes):
            _state_frag, metrics = auxes[-1]
            return model_state, metrics

        return common.StagedLoss(
            names=names, seg_params=tuple(split_tree(params)),
            seg_fns=seg_fns, x0=jnp.zeros((), jnp.float32),
            merge_grads=merge_grads, split_tree=split_tree,
            finalize_aux=finalize_aux)

    # ---------------------------------------------------------------- serve
    def cache_shape(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        # SWA archs (mixtral) keep a ring buffer of window size only.
        s = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
        kv = {
            "k": ((self.n_groups, batch, s, cfg.n_kv_heads,
                   cfg.head_dim), ("layers", "batch", "kv_seq", "kv_heads",
                                   None)),
            "v": ((self.n_groups, batch, s, cfg.n_kv_heads,
                   cfg.head_dim), ("layers", "batch", "kv_seq", "kv_heads",
                                   None)),
        }
        shapes = {f"sub{j}": dict(kv) for j in range(self.group)}
        vals = jax.tree.map(lambda sa: jnp.zeros(sa[0], dtype), shapes,
                            is_leaf=lambda x: isinstance(x, tuple))
        axes = jax.tree.map(lambda sa: sa[1], shapes,
                            is_leaf=lambda x: isinstance(x, tuple))
        return vals, axes

    def prefill(self, p: Params, tokens, cache, *, patches=None):
        logits, _, new_cache = self.forward(
            p, tokens, patches=patches, mode="prefill", cache=cache,
            cache_index=0)
        return logits[:, -1:, :], new_cache

    def decode_step(self, p: Params, cache, tokens, cache_index):
        logits, _, new_cache = self.forward(
            p, tokens, mode="decode", cache=cache, cache_index=cache_index)
        return logits, new_cache
