"""Whisper-tiny encoder-decoder backbone. The conv/mel frontend is a STUB
per the assignment spec: ``input_specs()`` supplies precomputed frame
embeddings (B, n_frames, frame_dim); a learned projector lifts them to
d_model and sinusoidal positions are added (standing in for the conv
stack, whose BN would be the paper's sync-BN integration point — noted in
DESIGN.md section 4).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common, layers
from repro.models.common import Boxed, apply_norm, norm_init, unbox

Params = Dict[str, Any]


def _sinusoid(n: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(n)[:, None].astype(jnp.float32)
    dim = jnp.arange(d // 2)[None, :].astype(jnp.float32)
    angle = pos / jnp.power(10000.0, 2 * dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


class WhisperModel:
    def __init__(self, cfg: ModelConfig, compute_dtype=jnp.bfloat16,
                 attention_impl: str = "chunked", remat: bool = True,
                 max_target_positions: int = 448):
        self.cfg = cfg
        self.compute_dtype = compute_dtype
        self.attention_impl = attention_impl
        self.remat = remat
        self.max_target_positions = max_target_positions

    def init(self, key) -> Params:
        cfg = self.cfg
        ks = iter(jax.random.split(key, 12))
        enc_l, dec_l = cfg.n_encoder_layers, cfg.n_layers
        p: Params = {
            "frame_proj": common.dense(next(ks), cfg.audio.frame_dim,
                                       cfg.d_model, (None, "embed")),
            "embed": layers.embedding_init(next(ks), cfg),
            # semantically whisper caps at 448 positions; sized for the
            # assignment's shape-faithful 32k decode cell (DESIGN.md §4)
            "pos_dec": Boxed(
                common.normal_init(next(ks), (32768, cfg.d_model), 0.01),
                ("seq", "embed")),
            "enc": {
                "norm1": norm_init(cfg.norm, cfg.d_model, enc_l),
                "attn": layers.attention_init(next(ks), cfg, enc_l),
                "norm2": norm_init(cfg.norm, cfg.d_model, enc_l),
                "mlp": layers.mlp_init(next(ks), cfg, enc_l),
            },
            "enc_norm": norm_init(cfg.norm, cfg.d_model),
            "dec": {
                "norm1": norm_init(cfg.norm, cfg.d_model, dec_l),
                "self_attn": layers.attention_init(next(ks), cfg, dec_l),
                "norm_x": norm_init(cfg.norm, cfg.d_model, dec_l),
                "cross_attn": layers.attention_init(next(ks), cfg, dec_l),
                "norm2": norm_init(cfg.norm, cfg.d_model, dec_l),
                "mlp": layers.mlp_init(next(ks), cfg, dec_l),
            },
            "dec_norm": norm_init(cfg.norm, cfg.d_model),
        }
        return p

    def init_params(self, key):
        return unbox(self.init(key))

    # ----------------------------------------------------------- encoder
    def encode(self, p: Params, frames: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = frames.astype(self.compute_dtype) @ p["frame_proj"].astype(
            self.compute_dtype)
        x = x + _sinusoid(x.shape[1], cfg.d_model).astype(x.dtype)[None]
        b = x.shape[0]
        positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None], x.shape[:2])

        def block(x, lp):
            h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
            a, _ = layers.attention_apply(
                lp["attn"], h, cfg, positions=positions, causal=False,
                impl=self.attention_impl, use_rope=False)
            x = x + a
            h = apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
            return x + layers.mlp_apply(lp["mlp"], h, cfg), None

        fn = jax.checkpoint(block) if self.remat else block
        x, _ = jax.lax.scan(fn, x, p["enc"])
        return apply_norm(p["enc_norm"], x, cfg.norm, cfg.norm_eps)

    # ----------------------------------------------------------- decoder
    def decode(self, p: Params, tokens, enc_out, *, mode="train",
               cache=None, cache_index=None):
        cfg = self.cfg
        x = layers.embed(p["embed"], tokens, self.compute_dtype)
        b, s, _ = x.shape
        if mode == "decode":
            positions = jnp.broadcast_to(cache_index, (b,))[:, None]
            pos_emb = jax.lax.dynamic_slice_in_dim(
                p["pos_dec"].astype(x.dtype), cache_index, 1, 0)[None]
        else:
            positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
            pos_emb = p["pos_dec"][:s].astype(x.dtype)[None]
        x = x + pos_emb
        enc_positions = jnp.broadcast_to(
            jnp.arange(enc_out.shape[1])[None], enc_out.shape[:2])

        def block(carry, scanned):
            x = carry
            lp, c = scanned
            h = apply_norm(lp["norm1"], x, cfg.norm, cfg.norm_eps)
            a, new_c = layers.attention_apply(
                lp["self_attn"], h, cfg, positions=positions, causal=True,
                impl=self.attention_impl, cache=c, cache_index=cache_index,
                use_rope=False)
            x = x + a
            h = apply_norm(lp["norm_x"], x, cfg.norm, cfg.norm_eps)
            a, _ = layers.attention_apply(
                lp["cross_attn"], h, cfg, positions=positions,
                kv_x=enc_out, kv_positions=enc_positions,
                impl=self.attention_impl, use_rope=False)
            x = x + a
            h = apply_norm(lp["norm2"], x, cfg.norm, cfg.norm_eps)
            return x + layers.mlp_apply(lp["mlp"], h, cfg), new_c

        fn = block
        if self.remat and mode == "train":
            fn = jax.checkpoint(
                block, policy=jax.checkpoint_policies.nothing_saveable)
        x, new_cache = jax.lax.scan(fn, x, (p["dec"], cache))
        x = apply_norm(p["dec_norm"], x, cfg.norm, cfg.norm_eps)
        logits = layers.lm_head(p["embed"]["table"], x, tied=True)
        return logits, new_cache

    # ------------------------------------------------------------- api
    def forward(self, p, tokens, *, frames=None, mode="train", cache=None,
                cache_index=None):
        if cache is not None and "enc_out" in cache and mode == "decode":
            enc_out = cache["enc_out"].astype(self.compute_dtype)
        else:
            enc_out = self.encode(p, frames)
        logits, new_kv = self.decode(p, tokens, enc_out, mode=mode,
                                     cache=cache["kv"] if cache else None,
                                     cache_index=cache_index)
        new_cache = None
        if cache is not None:
            new_cache = {"enc_out": enc_out.astype(cache["enc_out"].dtype),
                         "kv": new_kv}
        return logits, 0.0, new_cache

    def loss_fn(self, p, model_state, batch, label_smoothing=0.0):
        logits, _, _ = self.forward(p, batch["tokens"],
                                    frames=batch["frames"], mode="train")
        loss, n_tok = common.cross_entropy_loss(
            logits, batch["targets"], label_smoothing=label_smoothing)
        return loss, (model_state, {"loss": loss, "tokens": n_tok})

    def cache_shape(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        kv = {
            "k": ((cfg.n_layers, batch, max_seq, cfg.n_kv_heads,
                   cfg.head_dim),
                  ("layers", "batch", "kv_seq", "kv_heads", None)),
            "v": ((cfg.n_layers, batch, max_seq, cfg.n_kv_heads,
                   cfg.head_dim),
                  ("layers", "batch", "kv_seq", "kv_heads", None)),
        }
        shapes = {
            "kv": kv,
            "enc_out": ((batch, cfg.audio.num_frames, cfg.d_model),
                        ("batch", "seq", "embed")),
        }
        is_leaf = lambda x: isinstance(x, tuple) and isinstance(x[0], tuple)
        vals = jax.tree.map(lambda t: jnp.zeros(t[0], dtype), shapes,
                            is_leaf=is_leaf)
        axes = jax.tree.map(lambda t: t[1], shapes, is_leaf=is_leaf)
        return vals, axes

    def prefill(self, p, tokens, cache, *, frames=None):
        logits, _, new_cache = self.forward(
            p, tokens, frames=frames, mode="prefill", cache=cache,
            cache_index=0)
        return logits[:, -1:, :], new_cache

    def decode_step(self, p, cache, tokens, cache_index):
        logits, _, new_cache = self.forward(
            p, tokens, mode="decode", cache=cache, cache_index=cache_index)
        return logits, new_cache
