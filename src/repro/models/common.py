"""Functional module machinery: boxed params with logical axes, inits, norms.

Params are nested dicts of ``Boxed(value, axes)`` during init; ``unbox``
splits them into a value tree and a parallel logical-axes tree. The axes
tree is consumed by ``repro.distributed.sharding`` to build PartitionSpecs
(MaxText-style logical axis rules), so models never hard-code mesh axes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass
class Boxed:
    """A parameter value tagged with logical axis names (one per dim).

    Registered as a pytree node (axes = static aux data) so Boxed trees
    flow through jit / eval_shape — which is how the dry-run derives the
    (shapes, logical-axes) pair without allocating anything.
    """

    value: jax.Array
    axes: Tuple[Optional[str], ...]

    def __post_init__(self):
        assert len(self.axes) == self.value.ndim, (self.axes, self.value.shape)


jax.tree_util.register_pytree_node(
    Boxed,
    lambda b: ((b.value,), b.axes),
    lambda axes, ch: Boxed.__new__(Boxed) if False else _boxed_make(axes, ch),
)


def _boxed_make(axes, children):
    b = Boxed.__new__(Boxed)
    b.value = children[0]
    b.axes = axes
    return b


def unbox(tree: PyTree) -> Tuple[PyTree, PyTree]:
    """Split a Boxed tree into (values, axes) trees of identical structure."""
    is_boxed = lambda x: isinstance(x, Boxed)
    values = jax.tree.map(lambda b: b.value, tree, is_leaf=is_boxed)
    axes = jax.tree.map(lambda b: b.axes, tree, is_leaf=is_boxed)
    return values, axes


def axes_tree_of(tree: PyTree) -> PyTree:
    return unbox(tree)[1]


# ---------------------------------------------------------------------------
# Initializers (fan-in scaled normal, as used by the reference models)
# ---------------------------------------------------------------------------


def normal_init(key, shape, stddev=0.02, dtype=jnp.float32):
    return stddev * jax.random.normal(key, shape, dtype)


def fan_in_init(key, shape, fan_in_dims: Sequence[int] = (-2,), dtype=jnp.float32):
    fan_in = 1
    for d in fan_in_dims:
        fan_in *= shape[d]
    return jax.random.normal(key, shape, dtype) / math.sqrt(max(fan_in, 1))


def he_init(key, shape, fan_in, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * math.sqrt(2.0 / fan_in)


def dense(key, d_in: int, d_out: int, axes: Tuple[Optional[str], str],
          stacked: int = 0, dtype=jnp.float32) -> Boxed:
    """A (stacked?, d_in, d_out) weight, fan-in initialized."""
    shape = (d_in, d_out) if not stacked else (stacked, d_in, d_out)
    full_axes = axes if not stacked else ("layers",) + tuple(axes)
    return Boxed(fan_in_init(key, shape, (-2,), dtype), tuple(full_axes))


def zeros(shape, axes, dtype=jnp.float32) -> Boxed:
    return Boxed(jnp.zeros(shape, dtype), tuple(axes))


def ones(shape, axes, dtype=jnp.float32) -> Boxed:
    return Boxed(jnp.ones(shape, dtype), tuple(axes))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, stacked: int = 0) -> Dict[str, Boxed]:
    shape = (d,) if not stacked else (stacked, d)
    axes = ("embed",) if not stacked else ("layers", "embed")
    return {"scale": ones(shape, axes)}


def layernorm_init(d: int, stacked: int = 0) -> Dict[str, Boxed]:
    shape = (d,) if not stacked else (stacked, d)
    axes = ("embed",) if not stacked else ("layers", "embed")
    return {"scale": ones(shape, axes), "bias": zeros(shape, axes)}


def apply_norm(p: Dict[str, jax.Array], x: jax.Array, kind: str,
               eps: float = 1e-5) -> jax.Array:
    """Normalize in the compute dtype with fp32 *statistics* only.

    The statistics reductions accumulate in fp32 (``dtype=`` arg) without
    materializing an fp32 copy of the activation — on TPU this is the
    difference between one bf16 stream and an extra fp32 stream per norm
    (measured in EXPERIMENTS.md §Perf iteration 1).
    """
    dtype = x.dtype
    if kind == "rmsnorm":
        var = jnp.mean(jnp.square(x), axis=-1, keepdims=True,
                       dtype=jnp.float32)
        inv = jax.lax.rsqrt(var + eps).astype(dtype)
        y = x * inv * p["scale"].astype(dtype)
    elif kind == "layernorm":
        mean = jnp.mean(x, axis=-1, keepdims=True, dtype=jnp.float32)
        mean_sq = jnp.mean(jnp.square(x), axis=-1, keepdims=True,
                           dtype=jnp.float32)
        var = jnp.maximum(mean_sq - jnp.square(mean), 0.0)
        inv = jax.lax.rsqrt(var + eps).astype(dtype)
        y = (x - mean.astype(dtype)) * inv
        y = y * p["scale"].astype(dtype) + p["bias"].astype(dtype)
    else:
        raise ValueError(kind)
    return y.astype(dtype)


def norm_init(kind: str, d: int, stacked: int = 0) -> Dict[str, Boxed]:
    return rmsnorm_init(d, stacked) if kind == "rmsnorm" else layernorm_init(d, stacked)


# ---------------------------------------------------------------------------
# Staged apply (backward-overlapped gradient sync, DESIGN.md §8)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StagedLoss:
    """A model's loss decomposed into K chained segments.

    The overlap train step (``training/step.py:make_dp_overlap_train_step``)
    takes the VJP of each segment independently so gradients materialize
    in reverse-segment order, letting it launch a gradient bucket's
    all-reduce the moment the bucket's last leaf exists instead of after
    the full backward pass (DESIGN.md §8).

    Contract:
      * ``seg_fns[i](seg_params[i], carry) -> (carry', aux)`` — the carry
        is an arbitrary differentiable pytree threaded between segments
        (activations, accumulated aux losses, and — for tied embeddings —
        the shared table, so its gradient sums across uses exactly as in
        the monolithic backward). The final segment's carry' is the
        scalar loss.
      * every parameter leaf lives in exactly ONE segment, so
        ``merge_grads`` is a pure structural inverse of ``split_tree``
        (no cross-segment additions — that is what the carry is for).
      * ``finalize_aux(aux_list) -> (new_model_state, metrics)``.
    """

    names: Tuple[str, ...]
    seg_params: Tuple[PyTree, ...]
    seg_fns: Tuple[Callable, ...]
    x0: Any
    merge_grads: Callable  # list of per-segment grad trees (fwd order) -> full
    split_tree: Callable  # full params-structured tree -> list of seg trees
    finalize_aux: Callable  # list of aux (fwd order) -> (new_state, metrics)

    def __len__(self) -> int:
        return len(self.seg_fns)


def staged_forward(staged: StagedLoss):
    """Forward pass as a chain of per-segment VJPs.

    Returns ``(loss, vjp_fns, aux_list)``; ``vjp_fns[i](ct)`` yields
    ``(seg_param_grads, carry_cotangent)``. Chaining these from the last
    segment backwards reproduces exactly the primitives reverse-mode AD
    emits for the monolithic loss — same ops, same order per segment —
    which is why the overlapped step's gradients are bitwise-identical
    to the monolithic path (asserted in tests/test_overlap.py).
    """
    carry = staged.x0
    vjps = []
    auxes = []
    for sp, fn in zip(staged.seg_params, staged.seg_fns):
        carry, vjp_fn, aux = jax.vjp(fn, sp, carry, has_aux=True)
        vjps.append(vjp_fn)
        auxes.append(aux)
    return carry, vjps, auxes


def staged_value_and_grad(staged: StagedLoss):
    """Reference driver: run the chained VJPs without overlap.

    Returns ``(loss, (new_state, metrics), grads)`` with ``grads`` in the
    full parameter structure — the oracle the overlap step is verified
    against segment-by-segment.
    """
    loss, vjps, auxes = staged_forward(staged)
    ct: Any = jnp.ones_like(loss)
    seg_grads = [None] * len(vjps)
    for i in reversed(range(len(vjps))):
        g_seg, ct = vjps[i](ct)
        seg_grads[i] = g_seg
    new_state, metrics = staged.finalize_aux(auxes)
    return loss, (new_state, metrics), staged.merge_grads(seg_grads)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------


def split_keys(key, n: int):
    return jax.random.split(key, n)


def cast_tree(tree: PyTree, dtype) -> PyTree:
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def count_params(tree: PyTree) -> int:
    return sum(x.size for x in jax.tree.leaves(tree))


def gelu(x):
    return jax.nn.gelu(x, approximate=True)


def cross_entropy_loss(logits: jax.Array, targets: jax.Array,
                       ignore_id: int = -1,
                       label_smoothing: float = 0.0) -> Tuple[jax.Array, jax.Array]:
    """Token-mean softmax cross entropy. logits (..., V) fp; targets int."""
    logits = logits.astype(jnp.float32)
    v = logits.shape[-1]
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    target_logit = jnp.take_along_axis(
        logits, targets[..., None].clip(0), axis=-1
    )[..., 0]
    nll = lse - target_logit
    if label_smoothing:
        mean_logit = jnp.mean(logits, axis=-1)
        smooth_nll = lse - mean_logit
        nll = (1 - label_smoothing) * nll + label_smoothing * smooth_nll
    mask = (targets != ignore_id).astype(jnp.float32)
    total = jnp.maximum(mask.sum(), 1.0)
    return (nll * mask).sum() / total, mask.sum()
