"""Chunked gated linear attention / SSD engine.

Mamba2's SSD and xLSTM's mLSTM are both gated linear-attention recurrences

    S_t = a_t * S_{t-1} + v_t k_t^T          (state: (H, Dv, Dk))
    y_t = S_t q_t                            (readout)

with per-(head, step) scalar decay ``a_t``. The chunked formulation below
is the TPU-native adaptation (matmul-heavy => MXU-friendly; the state is
materialized once per chunk instead of per step, and the (chunk x chunk)
score matrix is the only quadratic object). A single ``lax.scan`` over
chunks carries the state and emits per-chunk outputs, so peak memory is
O(B * chunk^2 * H) regardless of sequence length.

All math in fp32 for stability; inputs/outputs in the compute dtype.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG = -1e30


def chunked_gla(
    q: jax.Array,  # (B, S, H, Dk)
    k: jax.Array,  # (B, S, H, Dk)
    v: jax.Array,  # (B, S, H, Dv)
    log_a: jax.Array,  # (B, S, H) per-step log decay (<= 0)
    *,
    chunk: int = 128,
    initial_state: Optional[jax.Array] = None,  # (B, H, Dv, Dk)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y: (B,S,H,Dv), final_state: (B,H,Dv,Dk))."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    n = s // chunk
    assert s % chunk == 0, (s, chunk)
    f32 = jnp.float32

    def chunk_of(x):
        r = x.reshape(b, n, chunk, *x.shape[2:])
        return r.transpose(1, 0, *range(2, r.ndim)).astype(f32)

    qs, ks, vs = chunk_of(q), chunk_of(k), chunk_of(v)
    ls = chunk_of(log_a)  # (n, b, chunk, h)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    s0 = (jnp.zeros((b, h, dv, dk), f32) if initial_state is None
          else initial_state.astype(f32))

    def step(state, inp):
        qc, kc, vc, lc = inp  # (b, chunk, ...)
        lcum = jnp.cumsum(lc, axis=1)  # inclusive within-chunk cum log decay
        # intra-chunk: weight(t,τ) = exp(l_t - l_τ) for τ <= t
        rel = lcum[:, :, None, :] - lcum[:, None, :, :]  # (b, t, τ, h)
        rel = jnp.where(tri[None, :, :, None], rel, NEG)
        decay = jnp.exp(rel)
        scores = jnp.einsum("bthd,bshd->btsh", qc, kc)
        y = jnp.einsum("btsh,bshv->bthv", scores * decay, vc)
        # inter-chunk: y += exp(l_t) * S_prev q_t
        qd = qc * jnp.exp(lcum)[..., None]
        y = y + jnp.einsum("bthd,bhvd->bthv", qd, state)
        # state update: S = exp(l_Q) S_prev + Σ_τ exp(l_Q - l_τ) v_τ k_τ^T
        tail = jnp.exp(lcum[:, -1:, :] - lcum)  # (b, chunk, h)
        new_state = state * jnp.exp(lcum[:, -1, :])[..., None, None] \
            + jnp.einsum("bthv,bthd->bhvd", vc, kc * tail[..., None])
        return new_state, y

    final_state, ys = jax.lax.scan(step, s0, (qs, ks, vs, ls))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dv)
    return y.astype(q.dtype), final_state


def gla_decode_step(
    q: jax.Array,  # (B, H, Dk)
    k: jax.Array,
    v: jax.Array,  # (B, H, Dv)
    log_a: jax.Array,  # (B, H)
    state: jax.Array,  # (B, H, Dv, Dk)
) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrence step. Returns (y: (B,H,Dv), new_state)."""
    f32 = jnp.float32
    a = jnp.exp(log_a.astype(f32))[..., None, None]
    new_state = state.astype(f32) * a + jnp.einsum(
        "bhv,bhd->bhvd", v.astype(f32), k.astype(f32))
    y = jnp.einsum("bhvd,bhd->bhv", new_state, q.astype(f32))
    return y.astype(q.dtype), new_state


def reference_gla(q, k, v, log_a, initial_state=None):
    """O(S) sequential oracle for tests (pure scan over time)."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    s0 = (jnp.zeros((b, h, dv, dk), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(state, inp):
        qt, kt, vt, lt = inp
        y, state = gla_decode_step(qt, kt, vt, lt, state)
        return state, y

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), log_a.transpose(1, 0, 2))
    state, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), state
