"""Chunked gated linear attention / SSD engine.

Mamba2's SSD and xLSTM's mLSTM are both gated linear-attention recurrences

    S_t = a_t * S_{t-1} + v_t k_t^T          (state: (H, Dv, Dk))
    y_t = S_t q_t                            (readout)

with per-(head, step) scalar decay ``a_t``. The chunked formulation below
is the TPU-native adaptation (matmul-heavy => MXU-friendly; the state is
materialized once per chunk instead of per step, and the (chunk x chunk)
score matrix is the only quadratic object). A single ``lax.scan`` over
chunks carries the state and emits per-chunk outputs, so peak memory is
O(B * chunk^2 * H) regardless of sequence length.

All math in fp32 for stability; inputs/outputs in the compute dtype.
Accumulation is tightened two ways so large chunks (256+) stay within
~1e-4 of the sequential oracle: the within-chunk log-decay prefix sum is
carried in doubled fp32 (Kahan compensation, so differences of nearby
large cumulative decays don't cancel catastrophically), and the two long
reductions over the chunk axis (scores @ V and the K^T V state update)
are split into sub-blocks summed pairwise instead of one flat
``chunk``-term accumulation.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

NEG = -1e30

_SUB = 64  # pairwise-accumulation sub-block for the chunk-axis reductions


def _kahan_cumsum(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Compensated inclusive cumsum over axis 1.

    Returns ``(total, comp)`` with the running sum represented as the
    doubled-fp32 value ``total - comp``; using both halves when forming
    differences keeps the within-chunk decay exponents accurate even
    when the absolute cumulative log decay is large.
    """

    def step(carry, xi):
        total, comp = carry
        y = xi - comp
        t = total + y
        comp = (t - total) - y
        return (t, comp), (t, comp)

    zero = jnp.zeros_like(x[:, 0])
    _, (total, comp) = jax.lax.scan(step, (zero, zero),
                                    jnp.moveaxis(x, 1, 0))
    return jnp.moveaxis(total, 0, 1), jnp.moveaxis(comp, 0, 1)


def _pairwise_sum(parts: jax.Array) -> jax.Array:
    """Tree-sum over the leading axis (error ~log n instead of ~n)."""
    while parts.shape[0] > 1:
        m = parts.shape[0] // 2
        head = parts[:m] + parts[m:2 * m]
        parts = (head if parts.shape[0] % 2 == 0
                 else jnp.concatenate([head, parts[2 * m:]], axis=0))
    return parts[0]


def chunked_gla(
    q: jax.Array,  # (B, S, H, Dk)
    k: jax.Array,  # (B, S, H, Dk)
    v: jax.Array,  # (B, S, H, Dv)
    log_a: jax.Array,  # (B, S, H) per-step log decay (<= 0)
    *,
    chunk: int = 128,
    initial_state: Optional[jax.Array] = None,  # (B, H, Dv, Dk)
) -> Tuple[jax.Array, jax.Array]:
    """Returns (y: (B,S,H,Dv), final_state: (B,H,Dv,Dk))."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, s)
    n = s // chunk
    assert s % chunk == 0, (s, chunk)
    f32 = jnp.float32

    def chunk_of(x):
        r = x.reshape(b, n, chunk, *x.shape[2:])
        return r.transpose(1, 0, *range(2, r.ndim)).astype(f32)

    qs, ks, vs = chunk_of(q), chunk_of(k), chunk_of(v)
    ls = chunk_of(log_a)  # (n, b, chunk, h)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))

    s0 = (jnp.zeros((b, h, dv, dk), f32) if initial_state is None
          else initial_state.astype(f32))

    sub = _SUB if chunk % _SUB == 0 else chunk
    nsub = chunk // sub

    def step(state, inp):
        qc, kc, vc, lc = inp  # (b, chunk, ...)
        # inclusive within-chunk cum log decay, doubled fp32 (hi, comp)
        lhi, lco = _kahan_cumsum(lc)
        lcum = lhi - lco
        # intra-chunk: weight(t,τ) = exp(l_t - l_τ) for τ <= t. Form the
        # difference from both Kahan halves: the hi parts cancel exactly
        # for nearby positions, the comp parts restore the low bits.
        rel = (lhi[:, :, None, :] - lhi[:, None, :, :]) \
            - (lco[:, :, None, :] - lco[:, None, :, :])  # (b, t, τ, h)
        rel = jnp.where(tri[None, :, :, None], rel, NEG)
        decay = jnp.exp(rel)
        scores = jnp.einsum("bthd,bshd->btsh", qc, kc)
        # Σ_τ (scores·decay) v_τ, accumulated pairwise over sub-blocks
        w = (scores * decay).reshape(b, chunk, nsub, sub, h)
        vt = vc.reshape(b, nsub, sub, h, dv)
        y = _pairwise_sum(jnp.einsum("btnsh,bnshv->nbthv", w, vt))
        # inter-chunk: y += exp(l_t) * S_prev q_t
        qd = qc * jnp.exp(lcum)[..., None]
        y = y + jnp.einsum("bthd,bhvd->bthv", qd, state)
        # state update: S = exp(l_Q) S_prev + Σ_τ exp(l_Q - l_τ) v_τ k_τ^T
        tail = jnp.exp(lcum[:, -1:, :] - lcum)  # (b, chunk, h)
        kt = (kc * tail[..., None]).reshape(b, nsub, sub, h, dk)
        outer = _pairwise_sum(jnp.einsum("bnshv,bnshd->nbhvd", vt, kt))
        new_state = state * jnp.exp(lcum[:, -1, :])[..., None, None] + outer
        return new_state, y

    final_state, ys = jax.lax.scan(step, s0, (qs, ks, vs, ls))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dv)
    return y.astype(q.dtype), final_state


def gla_decode_step(
    q: jax.Array,  # (B, H, Dk)
    k: jax.Array,
    v: jax.Array,  # (B, H, Dv)
    log_a: jax.Array,  # (B, H)
    state: jax.Array,  # (B, H, Dv, Dk)
) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrence step. Returns (y: (B,H,Dv), new_state)."""
    f32 = jnp.float32
    a = jnp.exp(log_a.astype(f32))[..., None, None]
    new_state = state.astype(f32) * a + jnp.einsum(
        "bhv,bhd->bhvd", v.astype(f32), k.astype(f32))
    y = jnp.einsum("bhvd,bhd->bhv", new_state, q.astype(f32))
    return y.astype(q.dtype), new_state


def reference_gla(q, k, v, log_a, initial_state=None):
    """O(S) sequential oracle for tests (pure scan over time)."""
    b, s, h, dk = q.shape
    dv = v.shape[-1]
    s0 = (jnp.zeros((b, h, dv, dk), jnp.float32) if initial_state is None
          else initial_state.astype(jnp.float32))

    def step(state, inp):
        qt, kt, vt, lt = inp
        y, state = gla_decode_step(qt, kt, vt, lt, state)
        return state, y

    xs = (q.transpose(1, 0, 2, 3), k.transpose(1, 0, 2, 3),
          v.transpose(1, 0, 2, 3), log_a.transpose(1, 0, 2))
    state, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 0, 2, 3), state
