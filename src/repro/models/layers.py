"""Transformer building blocks: RoPE, GQA attention (train/prefill/decode),
MLPs, and a GShard-style capacity-based MoE layer.

All functions are pure; params come from the matching ``*_init``. Logical
sharding axes are declared at init (see distributed/sharding.py) and
activations are pinned via ``constrain``.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import common
from repro.models.common import Boxed, dense, gelu, zeros

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# Rotary position embedding (llama split-half convention)
# ---------------------------------------------------------------------------


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    half = x.shape[-1] // 2
    freqs = theta ** (-jnp.arange(half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def attention_init(key, cfg: ModelConfig, stacked: int = 0,
                   kv_dim: Optional[int] = None) -> Params:
    """QKV + output projection. Weights shaped (d, H, Dh) so the heads dim
    carries a logical axis the sharding rules can map to the model axis."""
    d, h, dh = cfg.d_model, cfg.n_heads, cfg.head_dim
    kv = cfg.n_kv_heads
    kd = kv_dim or d
    ks = jax.random.split(key, 4)
    L = (stacked,) if stacked else ()
    la = ("layers",) if stacked else ()

    def w(k, d_in, n_heads, name):
        arr = common.fan_in_init(k, L + (d_in, n_heads, dh), (-3,))
        return Boxed(arr, la + ("embed", name, "head_dim"))

    p: Params = {
        "wq": w(ks[0], d, h, "heads"),
        "wk": w(ks[1], kd, kv, "kv_heads"),
        "wv": w(ks[2], kd, kv, "kv_heads"),
        "wo": Boxed(
            common.fan_in_init(ks[3], L + (h, dh, d), (-3, -2)),
            la + ("heads", "head_dim", "embed"),
        ),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros(L + (h, dh), la + ("heads", "head_dim"))
        p["bk"] = zeros(L + (kv, dh), la + ("kv_heads", "head_dim"))
        p["bv"] = zeros(L + (kv, dh), la + ("kv_heads", "head_dim"))
    return p


def _qkv(p: Params, x: jax.Array, kv_x: jax.Array, cfg: ModelConfig,
         positions: Optional[jax.Array], kv_positions: Optional[jax.Array],
         use_rope: bool):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", kv_x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv_x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if use_rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, kv_positions, cfg.rope_theta)
    # "attn_batch" == "batch" normally; when heads can't shard it also
    # carries the model axis (batch-parallel attention fallback)
    q = constrain(q, ("attn_batch", "seq", "heads", None))
    k = constrain(k, ("attn_batch", "kv_seq", "kv_heads", None))
    v = constrain(v, ("attn_batch", "kv_seq", "kv_heads", None))
    return q, k, v


def _expand_kv(k: jax.Array, n_heads: int) -> jax.Array:
    """GQA: repeat kv heads to match query heads (reference path)."""
    b, s, kv, dh = k.shape
    if kv == n_heads:
        return k
    return jnp.repeat(k, n_heads // kv, axis=2)


def naive_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                    q_offset=0) -> jax.Array:
    """Materializes (B,H,Sq,Sk) scores. Reference / smoke-test path."""
    b, sq, h, dh = q.shape
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    scale = 1.0 / math.sqrt(dh)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qi = jnp.arange(sq)[:, None] + q_offset
    kj = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= qi - kj < window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def chunked_attention(q, k, v, *, causal: bool, window: Optional[int] = None,
                      q_chunk: int = 1024, kv_chunk: int = 1024,
                      precision: str = "f32",
                      inner_checkpoint: bool = False) -> jax.Array:
    """Online-softmax attention, O(S * chunk) memory (TPU-native flash
    equivalent in pure jnp; the Pallas kernel in kernels/flash_attention.py
    is the hot-path twin validated against this).

    precision="bf16" keeps q/k/v tiles in the compute dtype and uses fp32
    only for the softmax statistics and accumulator (halves the score
    traffic — §Perf). inner_checkpoint=True wraps each q-block in
    jax.checkpoint so the backward pass recomputes p-tiles instead of
    stacking them across the whole sequence (flash-backward memory).
    """
    b, sq, h, dh = q.shape
    sk = k.shape[1]
    k = _expand_kv(k, h)
    v = _expand_kv(v, h)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, sk)
    sq_real, sk_real = sq, sk
    pad_q = (-sq) % q_chunk
    pad_k = (-sk) % kv_chunk
    if pad_q:  # e.g. VLM early fusion: seq = text + n_patches
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        sq += pad_q
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        sk += pad_k
    n_q, n_k = sq // q_chunk, sk // kv_chunk
    scale = 1.0 / math.sqrt(dh)

    tile_dtype = q.dtype if precision == "bf16" else jnp.float32
    qr = q.reshape(b, n_q, q_chunk, h, dh).astype(tile_dtype)
    kr = k.reshape(b, n_k, kv_chunk, h, dh).astype(tile_dtype)
    vr = v.reshape(b, n_k, kv_chunk, h, dh).astype(tile_dtype)

    def q_block(qi, q_blk):
        m0 = jnp.full((b, h, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, h, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, q_chunk, h, dh), jnp.float32)

        def kv_step(carry, inputs):
            m, l, acc = carry
            kj, k_blk, v_blk = inputs
            s = jnp.einsum("bqhd,bkhd->bhqk", q_blk, k_blk,
                           preferred_element_type=jnp.float32)
            s = s * scale
            qpos = qi * q_chunk + jnp.arange(q_chunk)[:, None]
            kpos = kj * kv_chunk + jnp.arange(kv_chunk)[None, :]
            mask = kpos < sk_real  # exclude kv padding
            if causal:
                mask &= kpos <= qpos
            if window is not None:
                mask &= qpos - kpos < window
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # exp in the tile dtype: p lives (and is saved for backward)
            # at 2 bytes/elem; statistics accumulate in fp32 (§Perf)
            p = jnp.exp((s - m_new[..., None]).astype(tile_dtype))
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(axis=-1, dtype=jnp.float32)
            acc = acc * corr.transpose(0, 2, 1)[..., None] + jnp.einsum(
                "bhqk,bkhd->bqhd", p, v_blk,
                preferred_element_type=jnp.float32)
            return (m_new, l, acc), None

        ks_idx = jnp.arange(n_k)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (ks_idx, kr.transpose(1, 0, 2, 3, 4), vr.transpose(1, 0, 2, 3, 4)),
        )
        denom = jnp.maximum(l, 1e-30)  # fully-padded q rows: avoid 0/0
        return acc / denom.transpose(0, 2, 1)[..., None]

    if inner_checkpoint:
        q_block = jax.checkpoint(
            q_block, policy=jax.checkpoint_policies.nothing_saveable,
            static_argnums=())
    out = jax.lax.map(
        lambda args: q_block(args[0], args[1]),
        (jnp.arange(n_q), qr.transpose(1, 0, 2, 3, 4)),
    )
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, dh)
    if pad_q:
        out = out[:, :sq_real]
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, valid_len: jax.Array,
                     window: Optional[int] = None) -> jax.Array:
    """Single-token query vs cache. q: (B,1,H,Dh); cache: (B,S,KV,Dh).

    GQA is handled by a grouped einsum — the KV cache is never
    materialized at H heads (an 8x copy for qwen2-72b; §Perf bonus cell).
    """
    b, one, h, dh = q.shape
    s, kv = k_cache.shape[1], k_cache.shape[2]
    g = h // kv
    qg = q.reshape(b, one, kv, g, dh)
    scale = 1.0 / math.sqrt(dh)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache).astype(
        jnp.float32) * scale
    kj = jnp.arange(s)[None, None, None, None, :]
    mask = kj < valid_len.reshape(-1, 1, 1, 1, 1)
    if window is not None:
        mask &= kj >= valid_len.reshape(-1, 1, 1, 1, 1) - window
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs, v_cache)
    return out.reshape(b, one, h, dh)


def attention_apply(
    p: Params,
    x: jax.Array,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    causal: bool = True,
    window: Optional[int] = None,
    impl: str = "chunked",
    kv_x: Optional[jax.Array] = None,  # cross-attention source
    kv_positions: Optional[jax.Array] = None,
    cache: Optional[Params] = None,  # {"k","v"} (B,Smax,KV,Dh)
    cache_index: Optional[jax.Array] = None,
    use_rope: bool = True,
) -> Tuple[jax.Array, Optional[Params]]:
    """Returns (output, updated_cache).

    If the cache is *smaller* than the position index it behaves as a ring
    buffer (sliding-window serving): writes go to ``index % cache_len`` and
    the whole ring is valid once full. RoPE phases are absolute, so scores
    are storage-order independent and the ring needs no unrotation.
    """
    cross = kv_x is not None
    kv_x = x if kv_x is None else kv_x
    kv_positions = positions if kv_positions is None else kv_positions
    q, k, v = _qkv(p, x, kv_x, cfg, positions, kv_positions,
                   use_rope and not cross and cfg.pos_embedding == "rope")

    opt = impl == "chunked_opt"
    chunked = functools.partial(
        chunked_attention, precision="bf16" if opt else "f32",
        inner_checkpoint=opt)

    new_cache = None
    if cache is not None and not cross:
        cache_len = cache["k"].shape[1]
        idx = cache_index
        if x.shape[1] == 1:  # decode
            write = idx % cache_len if window else idx
            k_cache = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, write, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, write, 0, 0))
            new_cache = {"k": k_cache, "v": v_cache}
            valid = jnp.full((x.shape[0],), jnp.minimum(idx + 1, cache_len))
            out = decode_attention(q, k_cache.astype(q.dtype),
                                   v_cache.astype(q.dtype), valid,
                                   None)  # ring IS the window
        else:  # prefill into cache (keep the last cache_len positions)
            k_in, v_in = k, v
            if k.shape[1] > cache_len:
                k_in, v_in = k[:, -cache_len:], v[:, -cache_len:]
            k_cache = jax.lax.dynamic_update_slice(
                cache["k"], k_in.astype(cache["k"].dtype), (0, idx, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(
                cache["v"], v_in.astype(cache["v"].dtype), (0, idx, 0, 0))
            new_cache = {"k": k_cache, "v": v_cache}
            out = chunked(q, k, v, causal=causal, window=window) \
                if impl.startswith("chunked") else \
                naive_attention(q, k, v, causal=causal, window=window)
    else:
        fn = chunked if impl.startswith("chunked") else naive_attention
        if impl.startswith("chunked") and (x.shape[1] < 128 or
                                           kv_x.shape[1] < 128):
            fn = naive_attention  # smoke shapes
        out = fn(q, k, v, causal=causal and not cross, window=window)

    out = constrain(out, ("attn_batch", "seq", "heads", None))
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    return constrain(y, ("batch", "seq", "embed")), new_cache


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GELU)
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, stacked: int = 0,
             d_ff: Optional[int] = None) -> Params:
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_variant == "swiglu":
        return {
            "w_gate": dense(ks[0], d, ff, ("embed", "ffn"), stacked),
            "w_up": dense(ks[1], d, ff, ("embed", "ffn"), stacked),
            "w_down": dense(ks[2], ff, d, ("ffn", "embed"), stacked),
        }
    return {
        "w_up": dense(ks[0], d, ff, ("embed", "ffn"), stacked),
        "w_down": dense(ks[1], ff, d, ("ffn", "embed"), stacked),
    }


def mlp_apply(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    if "w_gate" in p:
        h = jax.nn.silu(x @ p["w_gate"].astype(x.dtype)) * (
            x @ p["w_up"].astype(x.dtype))
    else:
        h = gelu(x @ p["w_up"].astype(x.dtype))
    h = constrain(h, ("batch", "seq", "ffn"))
    y = h @ p["w_down"].astype(x.dtype)
    return constrain(y, ("batch", "seq", "embed"))


# ---------------------------------------------------------------------------
# MoE: GShard/GLaM-style grouped capacity dispatch (EP over "experts")
# ---------------------------------------------------------------------------

# Dispatch-tensor size per device is G_local*S_g*E_local*C; S_g=256 keeps
# it in the tens-of-MB range for every assigned MoE arch (DESIGN.md §3).
MOE_GROUP = 256  # tokens per dispatch group
CAPACITY_FACTOR = 1.25


def moe_init(key, cfg: ModelConfig, stacked: int = 0) -> Params:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 5)
    L = (stacked,) if stacked else ()
    la = ("layers",) if stacked else ()

    def ew(k, d_in, d_out, ax):
        arr = common.fan_in_init(k, L + (e, d_in, d_out), (-2,))
        return Boxed(arr, la + ("experts",) + ax)

    p: Params = {
        "router": dense(ks[0], d, e, ("embed", "experts_router"), stacked),
        "w_up": ew(ks[2], d, ff, ("embed", "ffn")),
        "w_down": ew(ks[3], ff, d, ("ffn", "embed")),
    }
    if cfg.mlp_variant == "swiglu":
        p["w_gate"] = ew(ks[1], d, ff, ("embed", "ffn"))
    if cfg.n_shared_experts:
        p["shared"] = mlp_init(ks[4], cfg, stacked,
                               d_ff=cfg.d_ff * cfg.n_shared_experts)
    return p


def moe_apply(p: Params, x: jax.Array, cfg: ModelConfig,
              capacity_factor: Optional[float] = None
              ) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, load_balance_aux_loss)."""
    if capacity_factor is None:
        capacity_factor = CAPACITY_FACTOR  # read at call time (testable)
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_token
    n_tokens = b * s
    g_size = min(MOE_GROUP, n_tokens)
    n_groups = n_tokens // g_size
    xg = x.reshape(n_groups, g_size, d)
    xg = constrain(xg, ("batch", None, "embed"))

    logits = jnp.einsum("gsd,de->gse", xg, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)

    # Switch-style load-balance aux loss.
    density = jnp.mean(
        jax.nn.one_hot(jnp.argmax(probs, -1), e, dtype=jnp.float32), axis=1)
    density_proxy = jnp.mean(probs, axis=1)
    aux = jnp.mean(density * density_proxy) * (e * e)

    cap = max(4, int(g_size * k * capacity_factor / e))
    # dispatch is 0/1 placement; combine = dispatch * per-token gate, so
    # only ONE (g,s,e,c) tensor is built (the gate rides a (g,s,e) tensor)
    # — §Perf MoE iteration: halves the one-hot construction traffic and
    # keeps everything in the compute dtype.
    dispatch = jnp.zeros((n_groups, g_size, e, cap), dtype=x.dtype)
    gates_full = jnp.zeros((n_groups, g_size, e), dtype=jnp.float32)
    remaining = probs
    position_in_expert = jnp.zeros((n_groups, e), jnp.int32)
    for _ in range(k):
        idx = jnp.argmax(remaining, axis=-1)  # (g, s)
        gate = jnp.take_along_axis(remaining, idx[..., None], -1)[..., 0]
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)
        pos = position_in_expert[:, None, :] + jnp.cumsum(onehot, axis=1) - onehot
        pos = jnp.sum(pos * onehot, axis=-1)  # (g, s) slot within expert
        keep = pos < cap
        dispatch = dispatch + (
            jax.nn.one_hot(idx, e, dtype=x.dtype)[..., None]
            * jax.nn.one_hot(pos, cap, dtype=x.dtype)[:, :, None, :]
            * keep[..., None, None]
        )
        gates_full = gates_full + onehot * (gate * keep)[..., None]
        position_in_expert = position_in_expert + jnp.sum(onehot, axis=1)
        remaining = remaining * (1.0 - jax.nn.one_hot(idx, e,
                                                      dtype=jnp.float32))

    dispatch = constrain(dispatch, ("batch", None, "experts", None))
    combine = dispatch * gates_full[..., None].astype(x.dtype)
    combine = constrain(combine, ("batch", None, "experts", None))
    # dispatch: (g, s, e, c) x (g, s, d) -> (g, e, c, d); EP all-to-all here
    xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg)
    xe = constrain(xe, ("batch", "experts", None, "embed"))
    if "w_gate" in p:
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe,
                                   p["w_gate"].astype(x.dtype)))
        h = h * jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(x.dtype))
    else:
        h = gelu(jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(x.dtype)))
    h = constrain(h, ("batch", "experts", None, "ffn"))
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(x.dtype))
    # NOTE (§Perf mixtral iter 4): ye is partial-summed over the model
    # axis when the ffn dim is TP-sharded; do NOT constrain it here — the
    # combine einsum is linear in ye, so the partitioner can delay the
    # all-reduce past it onto y, which is capacity_factor*k times smaller.
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye)
    y = constrain(y, ("batch", None, "embed"))

    if "shared" in p:
        y = y + mlp_apply(p["shared"], xg, cfg)
    return y.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Embedding + LM head
# ---------------------------------------------------------------------------


def embedding_init(key, cfg: ModelConfig) -> Params:
    p: Params = {
        "table": Boxed(
            common.normal_init(key, (cfg.vocab_size, cfg.d_model)),
            ("vocab", "embed"),
        )
    }
    return p


def embed(p: Params, tokens: jax.Array, compute_dtype) -> jax.Array:
    x = p["table"].astype(compute_dtype)[tokens]
    return constrain(x, ("batch", "seq", "embed"))


def lm_head(table_or_w: jax.Array, x: jax.Array, tied: bool) -> jax.Array:
    w = table_or_w.astype(x.dtype)
    logits = x @ (w.T if tied else w)
    return constrain(logits, ("batch", "seq", "vocab"))
