"""Mamba2 blocks and the Zamba2-style hybrid model.

Zamba2: a backbone of Mamba2 blocks with a small set of *shared*
(attention + MLP) transformer blocks cycled in every ``shared_attn_every``
layers. Each shared application takes concat(hidden, initial_embedding)
through a learned 2d->d projection (the Zamba "shared transformer"
pattern), so the shared weights are reused with fresh inputs.

TPU adaptation (documented in DESIGN.md §4): in serve mode the shared
attention uses a sliding window (SHARED_ATTN_SERVE_WINDOW) so the decode
state stays O(window) — the Mamba backbone already gives O(1)/token.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import common, layers, ssd
from repro.models.common import Boxed, apply_norm, norm_init, unbox

Params = Dict[str, Any]

SHARED_ATTN_SERVE_WINDOW = 4096


# ---------------------------------------------------------------------------
# Mamba2 block
# ---------------------------------------------------------------------------


def mamba2_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    n_heads = d_in // cfg.ssm_head_dim
    conv_ch = d_in + 2 * cfg.ssm_state
    return d_in, n_heads, conv_ch


def mamba2_init(key, cfg: ModelConfig, stacked: int = 0) -> Params:
    d = cfg.d_model
    d_in, n_h, conv_ch = mamba2_dims(cfg)
    ks = jax.random.split(key, 4)
    L = (stacked,) if stacked else ()
    la = ("layers",) if stacked else ()
    return {
        "norm": norm_init(cfg.norm, d, stacked),
        # in_proj -> [z(d_in), x(d_in), B(ds), C(ds), dt(n_h)]
        "w_in": Boxed(
            common.fan_in_init(ks[0], L + (d, 2 * d_in + 2 * cfg.ssm_state + n_h),
                               (-2,)),
            la + ("embed", "inner")),
        "conv_w": Boxed(
            common.normal_init(ks[1], L + (cfg.ssm_conv_width, conv_ch), 0.1),
            la + ("conv_spatial", "inner")),
        "conv_b": common.zeros(L + (conv_ch,), la + ("inner",)),
        "A_log": Boxed(jnp.zeros(L + (n_h,)), la + ("ssm_heads",)),
        "dt_bias": common.zeros(L + (n_h,), la + ("ssm_heads",)),
        "D": common.ones(L + (n_h,), la + ("ssm_heads",)),
        "out_norm": norm_init("rmsnorm", d_in, stacked),
        "w_out": Boxed(common.fan_in_init(ks[2], L + (d_in, d), (-2,)),
                       la + ("inner", "embed")),
    }


def _split_in(cfg: ModelConfig, proj: jax.Array):
    d_in, n_h, _ = mamba2_dims(cfg)
    ds = cfg.ssm_state
    z = proj[..., :d_in]
    xbc = proj[..., d_in:2 * d_in + 2 * ds]
    dt = proj[..., 2 * d_in + 2 * ds:]
    return z, xbc, dt


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array,
                 state: Optional[jax.Array] = None):
    """Depthwise causal conv along seq. xbc: (B,S,C); w: (W,C).

    Returns (out, new_state) where state holds the last W-1 inputs.
    """
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], width - 1, xbc.shape[2]), xbc.dtype)
    else:
        pad = state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(
        xp[:, i:i + xbc.shape[1], :] * w[i].astype(xbc.dtype)
        for i in range(width)
    ) + b.astype(xbc.dtype)
    new_state = xp[:, -(width - 1):, :]
    return jax.nn.silu(out), new_state


def mamba2_apply(p: Params, x: jax.Array, cfg: ModelConfig,
                 conv_state=None, ssm_state=None,
                 decode: bool = False) -> Tuple[jax.Array, Any, Any]:
    """Returns (out, new_conv_state, new_ssm_state)."""
    d_in, n_h, _ = mamba2_dims(cfg)
    ds = cfg.ssm_state
    dh = cfg.ssm_head_dim
    h_res = apply_norm(p["norm"], x, cfg.norm, cfg.norm_eps)
    proj = h_res @ p["w_in"].astype(x.dtype)
    z, xbc, dt = _split_in(cfg, proj)
    xbc, new_conv = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs = xbc[..., :d_in]
    B = xbc[..., d_in:d_in + ds]
    C = xbc[..., d_in + ds:]
    b, s, _ = x.shape

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,) negative
    log_decay = dt * a  # (B,S,H)

    xh = xs.reshape(b, s, n_h, dh)
    xbar = xh * dt[..., None].astype(x.dtype)
    # B/C shared across heads (single group)
    Bh = jnp.broadcast_to(B[:, :, None, :], (b, s, n_h, ds)).astype(x.dtype)
    Ch = jnp.broadcast_to(C[:, :, None, :], (b, s, n_h, ds)).astype(x.dtype)

    if decode:
        y, new_ssm = ssd.gla_decode_step(
            Ch[:, 0], Bh[:, 0], xbar[:, 0], log_decay[:, 0], ssm_state)
        y = y[:, None]
    else:
        y, new_ssm = ssd.chunked_gla(
            Ch, Bh, xbar, log_decay, initial_state=ssm_state)
    y = y + xh * p["D"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(b, s, d_in)
    y = apply_norm(p["out_norm"], y * jax.nn.silu(z), "rmsnorm", cfg.norm_eps)
    out = y @ p["w_out"].astype(x.dtype)
    return constrain(out, ("batch", "seq", "embed")), new_conv, new_ssm


# ---------------------------------------------------------------------------
# Zamba2 hybrid model
# ---------------------------------------------------------------------------


def shared_block_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "concat_proj": common.dense(ks[0], 2 * cfg.d_model, cfg.d_model,
                                    ("embed", "embed")),
        "norm1": norm_init(cfg.norm, cfg.d_model),
        "attn": layers.attention_init(ks[1], cfg),
        "norm2": norm_init(cfg.norm, cfg.d_model),
        "mlp": layers.mlp_init(ks[2], cfg),
    }


class Zamba2Model:
    def __init__(self, cfg: ModelConfig, compute_dtype=jnp.bfloat16,
                 attention_impl: str = "chunked", remat: bool = True):
        self.cfg = cfg
        self.compute_dtype = compute_dtype
        self.attention_impl = attention_impl
        self.remat = remat
        k = cfg.shared_attn_every
        self.n_full_groups = cfg.n_layers // k  # groups ending in shared attn
        self.tail = cfg.n_layers - self.n_full_groups * k

    def init(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 4 + cfg.n_shared_attn_blocks)
        p: Params = {
            "embed": layers.embedding_init(ks[0], cfg),
            "mamba": mamba2_init(ks[1], cfg, cfg.n_layers),
            "final_norm": norm_init(cfg.norm, cfg.d_model),
            "head": common.dense(ks[2], cfg.d_model, cfg.vocab_size,
                                 ("embed", "vocab")),
        }
        for j in range(cfg.n_shared_attn_blocks):
            p[f"shared{j}"] = shared_block_init(ks[3 + j], cfg)
        return p

    def init_params(self, key):
        return unbox(self.init(key))

    def _mamba_span(self, p_mamba, x, lo, hi, caches, decode):
        """Scan mamba layers [lo, hi) (params statically sliced)."""
        span = jax.tree.map(lambda a: a[lo:hi], p_mamba)
        conv0 = ssm0 = None
        if caches is not None:
            conv0 = jax.tree.map(lambda a: a[lo:hi], caches["conv"])
            ssm0 = jax.tree.map(lambda a: a[lo:hi], caches["ssm"])

        has_cache = caches is not None

        def body(carry, scanned):
            x = carry
            lp, conv_c, ssm_c = scanned
            out, nc, ns = mamba2_apply(lp, x, self.cfg, conv_c, ssm_c,
                                       decode=decode)
            return x + out, ((nc, ns) if has_cache else None)

        fn = body
        if self.remat and caches is None:
            fn = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, updates = jax.lax.scan(fn, x, (span, conv0, ssm0))
        return x, updates

    def _shared(self, p, j, x, emb0, positions, mode, cache, cache_index,
                window):
        sp = p[f"shared{j % self.cfg.n_shared_attn_blocks}"]
        h = jnp.concatenate([x, emb0], axis=-1) @ sp["concat_proj"].astype(
            x.dtype)
        h = apply_norm(sp["norm1"], h, self.cfg.norm, self.cfg.norm_eps)
        attn_out, new_cache = layers.attention_apply(
            sp["attn"], h, self.cfg, positions=positions, causal=True,
            window=window, impl=self.attention_impl, cache=cache,
            cache_index=cache_index)
        x = x + attn_out
        h = apply_norm(sp["norm2"], x, self.cfg.norm, self.cfg.norm_eps)
        return x + layers.mlp_apply(sp["mlp"], h, self.cfg), new_cache

    def forward(self, p: Params, tokens, *, mode="train", cache=None,
                cache_index=None):
        cfg = self.cfg
        k = cfg.shared_attn_every
        x = layers.embed(p["embed"], tokens, self.compute_dtype)
        emb0 = x
        b, s, _ = x.shape
        decode = mode == "decode"
        if decode:
            positions = jnp.broadcast_to(cache_index, (b,))[:, None]
        else:
            positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        window = None if mode == "train" else SHARED_ATTN_SERVE_WINDOW

        new_cache: Optional[Params] = None
        if cache is not None:
            new_cache = {"conv": [], "ssm": [], "attn": []}
        for g in range(self.n_full_groups):
            lo, hi = g * k, (g + 1) * k
            x, upd = self._mamba_span(p["mamba"], x, lo, hi, cache, decode)
            if cache is not None:
                new_cache["conv"].append(upd[0])
                new_cache["ssm"].append(upd[1])
            attn_cache = None
            if cache is not None:
                attn_cache = jax.tree.map(lambda a: a[g], cache["attn"])
            x, nac = self._shared(p, g, x, emb0, positions, mode, attn_cache,
                                  cache_index, window)
            if cache is not None:
                new_cache["attn"].append(nac)
        if self.tail:
            lo = self.n_full_groups * k
            x, upd = self._mamba_span(p["mamba"], x, lo, cfg.n_layers, cache,
                                      decode)
            if cache is not None:
                new_cache["conv"].append(upd[0])
                new_cache["ssm"].append(upd[1])
        if cache is not None:
            new_cache["conv"] = jnp.concatenate(new_cache["conv"], axis=0)
            new_cache["ssm"] = jnp.concatenate(new_cache["ssm"], axis=0)
            new_cache["attn"] = jax.tree.map(
                lambda *xs: jnp.stack(xs, 0), *new_cache["attn"])

        x = apply_norm(p["final_norm"], x, cfg.norm, cfg.norm_eps)
        logits = layers.lm_head(p["head"], x, tied=False)
        return logits, 0.0, new_cache

    def loss_fn(self, p, model_state, batch, label_smoothing=0.0):
        logits, _, _ = self.forward(p, batch["tokens"], mode="train")
        loss, n_tok = common.cross_entropy_loss(
            logits, batch["targets"], label_smoothing=label_smoothing)
        return loss, (model_state, {"loss": loss, "tokens": n_tok})

    def cache_shape(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        d_in, n_h, conv_ch = mamba2_dims(cfg)
        attn_window = min(max_seq, SHARED_ATTN_SERVE_WINDOW)
        L = cfg.n_layers
        G = self.n_full_groups
        shapes = {
            "conv": ((L, batch, cfg.ssm_conv_width - 1, conv_ch),
                     ("layers", "batch", None, "inner"), dtype),
            "ssm": ((L, batch, n_h, cfg.ssm_head_dim, cfg.ssm_state),
                    ("layers", "batch", "ssm_heads", None, None),
                    jnp.float32),
            "attn": {
                "k": ((G, batch, attn_window, cfg.n_kv_heads, cfg.head_dim),
                      ("layers", "batch", "kv_seq", "kv_heads", None), dtype),
                "v": ((G, batch, attn_window, cfg.n_kv_heads, cfg.head_dim),
                      ("layers", "batch", "kv_seq", "kv_heads", None), dtype),
            },
        }
        is_leaf = lambda x: isinstance(x, tuple) and isinstance(x[0], tuple)
        vals = jax.tree.map(lambda t: jnp.zeros(t[0], t[2]), shapes,
                            is_leaf=is_leaf)
        axes = jax.tree.map(lambda t: t[1], shapes, is_leaf=is_leaf)
        return vals, axes

    def prefill(self, p, tokens, cache, **_):
        logits, _, new_cache = self.forward(
            p, tokens, mode="prefill", cache=cache, cache_index=0)
        return logits[:, -1:, :], new_cache

    def decode_step(self, p, cache, tokens, cache_index):
        logits, _, new_cache = self.forward(
            p, tokens, mode="decode", cache=cache,
            cache_index=cache_index)
        return logits, new_cache
