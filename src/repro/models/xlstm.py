"""xLSTM: mLSTM (matrix memory, parallelizable) + sLSTM (scalar memory,
sequential) blocks in a ``slstm_every`` pattern (7:1 for xlstm-350m).

mLSTM is gated linear attention with an exponential input gate and a
normalizer n — implemented on the shared chunked GLA engine (ssd.py) by
augmenting v with a ones channel: state carries [i*v; i] so the readout
gives numerator and denominator in one pass (TPU adaptation: one
matmul-heavy kernel instead of two).

sLSTM has a recurrent nonlinearity => inherently sequential lax.scan over
time with the stabilized exponential-gate formulation.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import common, layers, ssd
from repro.models.common import Boxed, apply_norm, norm_init, unbox

Params = Dict[str, Any]

CONV_W = 4


# ---------------------------------------------------------------------------
# mLSTM block
# ---------------------------------------------------------------------------


def _mlstm_dims(cfg: ModelConfig):
    d_in = int(cfg.d_model * cfg.mlstm_proj_factor)
    n_h = cfg.n_heads
    return d_in, n_h, d_in // n_h


def mlstm_init(key, cfg: ModelConfig, stacked: int = 0) -> Params:
    d = cfg.d_model
    d_in, n_h, dh = _mlstm_dims(cfg)
    ks = jax.random.split(key, 7)
    L = (stacked,) if stacked else ()
    la = ("layers",) if stacked else ()

    def headwise(k, name):  # block-diagonal per-head projection
        arr = common.fan_in_init(k, L + (n_h, dh, dh), (-2,))
        return Boxed(arr, la + ("heads", None, None))

    return {
        "norm": norm_init(cfg.norm, d, stacked),
        "w_up": Boxed(common.fan_in_init(ks[0], L + (d, 2 * d_in), (-2,)),
                      la + ("embed", "inner")),
        "conv_w": Boxed(common.normal_init(ks[1], L + (CONV_W, d_in), 0.1),
                        la + ("conv_spatial", "inner")),
        "conv_b": common.zeros(L + (d_in,), la + ("inner",)),
        "wq": headwise(ks[2], "q"),
        "wk": headwise(ks[3], "k"),
        "wv": headwise(ks[4], "v"),
        "w_if": Boxed(common.fan_in_init(ks[5], L + (d_in, 2 * n_h), (-2,)),
                      la + ("inner", "heads")),
        # input-gate bias 0, forget-gate bias +3 (standard xLSTM init)
        "b_if": Boxed(
            jnp.broadcast_to(
                jnp.concatenate([jnp.zeros(n_h), jnp.full((n_h,), 3.0)]),
                L + (2 * n_h,)).copy() if L else
            jnp.concatenate([jnp.zeros(n_h), jnp.full((n_h,), 3.0)]),
            la + ("heads",)),
        "out_norm": norm_init("rmsnorm", d_in, stacked),
        "w_down": Boxed(common.fan_in_init(ks[6], L + (d_in, d), (-2,)),
                        la + ("inner", "embed")),
    }


def mlstm_apply(p: Params, x: jax.Array, cfg: ModelConfig,
                conv_state=None, gla_state=None,
                decode: bool = False) -> Tuple[jax.Array, Any, Any]:
    d_in, n_h, dh = _mlstm_dims(cfg)
    b, s, _ = x.shape
    h = apply_norm(p["norm"], x, cfg.norm, cfg.norm_eps)
    up = h @ p["w_up"].astype(x.dtype)
    inner, z = up[..., :d_in], up[..., d_in:]
    from repro.models.mamba import _causal_conv  # shared depthwise conv
    conv_out, new_conv = _causal_conv(inner, p["conv_w"], p["conv_b"],
                                      conv_state)
    qk_src = conv_out.reshape(b, s, n_h, dh)
    v_src = inner.reshape(b, s, n_h, dh)
    q = jnp.einsum("bshd,hde->bshe", qk_src, p["wq"].astype(x.dtype))
    k = jnp.einsum("bshd,hde->bshe", qk_src, p["wk"].astype(x.dtype)) / (
        dh ** 0.5)
    v = jnp.einsum("bshd,hde->bshe", v_src, p["wv"].astype(x.dtype))

    gates = conv_out @ p["w_if"].astype(x.dtype) + p["b_if"].astype(x.dtype)
    gates = gates.astype(jnp.float32)
    i_gate = jnp.exp(jnp.minimum(gates[..., :n_h], 10.0))  # capped exp gate
    log_a = jax.nn.log_sigmoid(gates[..., n_h:])  # forget gate

    v_aug = jnp.concatenate(
        [v, jnp.ones((b, s, n_h, 1), v.dtype)], axis=-1
    ) * i_gate[..., None].astype(v.dtype)

    if decode:
        y, new_state = ssd.gla_decode_step(
            q[:, 0], k[:, 0], v_aug[:, 0], log_a[:, 0], gla_state)
        y = y[:, None]
    else:
        y, new_state = ssd.chunked_gla(q, k, v_aug, log_a,
                                       initial_state=gla_state)
    num, den = y[..., :dh], y[..., dh:]
    y = num / jnp.maximum(jnp.abs(den), 1.0).astype(num.dtype)
    y = y.reshape(b, s, d_in)
    y = apply_norm(p["out_norm"], y, "rmsnorm", cfg.norm_eps)
    y = y * jax.nn.silu(z)
    out = y @ p["w_down"].astype(x.dtype)
    return constrain(out, ("batch", "seq", "embed")), new_conv, new_state


# ---------------------------------------------------------------------------
# sLSTM block
# ---------------------------------------------------------------------------


def slstm_init(key, cfg: ModelConfig, stacked: int = 0) -> Params:
    d, n_h = cfg.d_model, cfg.n_heads
    dh = d // n_h
    d_ffn = int(d * cfg.slstm_proj_factor)
    ks = jax.random.split(key, 4)
    L = (stacked,) if stacked else ()
    la = ("layers",) if stacked else ()
    return {
        "norm": norm_init(cfg.norm, d, stacked),
        "w_gates": Boxed(common.fan_in_init(ks[0], L + (d, 4 * d), (-2,)),
                         la + ("embed", "inner")),
        "r_gates": Boxed(  # block-diagonal recurrent, per head, 4 gates
            common.fan_in_init(ks[1], L + (4, n_h, dh, dh), (-2,)) * 0.1,
            la + (None, "heads", None, None)),
        "b_gates": common.zeros(L + (4 * d,), la + ("inner",)),
        "w_up": Boxed(common.fan_in_init(ks[2], L + (d, 2 * d_ffn), (-2,)),
                      la + ("embed", "ffn")),
        "w_down": Boxed(common.fan_in_init(ks[3], L + (d_ffn, d), (-2,)),
                        la + ("ffn", "embed")),
    }


def slstm_apply(p: Params, x: jax.Array, cfg: ModelConfig,
                state=None, decode: bool = False) -> Tuple[jax.Array, Any]:
    """state: dict h,c,n,m each (B, d) fp32."""
    d, n_h = cfg.d_model, cfg.n_heads
    dh = d // n_h
    b, s, _ = x.shape
    xin = apply_norm(p["norm"], x, cfg.norm, cfg.norm_eps)
    wx = (xin @ p["w_gates"].astype(x.dtype) + p["b_gates"].astype(x.dtype))
    wx = wx.astype(jnp.float32).reshape(b, s, 4, n_h, dh)
    r = p["r_gates"].astype(jnp.float32)

    if state is None:
        zeros = jnp.zeros((b, n_h, dh), jnp.float32)
        state = {"h": zeros, "c": zeros, "n": zeros,
                 "m": jnp.zeros((b, n_h, dh), jnp.float32)}

    def cell(st, wx_t):
        rh = jnp.einsum("bhd,ghde->bghe", st["h"], r)  # (b,4,h,dh)
        pre = wx_t + rh
        zt = jnp.tanh(pre[:, 0])
        it = pre[:, 1]
        ft = pre[:, 2]
        ot = jax.nn.sigmoid(pre[:, 3])
        m_new = jnp.maximum(ft + st["m"], it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(ft + st["m"] - m_new)
        c = f_p * st["c"] + i_p * zt
        n = f_p * st["n"] + i_p
        h = ot * c / jnp.maximum(jnp.abs(n), 1e-6)
        new = {"h": h, "c": c, "n": n, "m": m_new}
        return new, h

    if decode:
        new_state, h = cell(state, wx[:, 0])
        ys = h[:, None]
    else:
        new_state, hs = jax.lax.scan(cell, state, wx.transpose(1, 0, 2, 3, 4))
        ys = hs.transpose(1, 0, 2, 3)
    y = ys.reshape(b, s, d).astype(x.dtype)
    # gated FFN
    up = y @ p["w_up"].astype(x.dtype)
    d_ffn = up.shape[-1] // 2
    y = jax.nn.silu(up[..., :d_ffn]) * up[..., d_ffn:]
    out = y @ p["w_down"].astype(x.dtype)
    return constrain(out, ("batch", "seq", "embed")), new_state


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


class XLSTMModel:
    def __init__(self, cfg: ModelConfig, compute_dtype=jnp.bfloat16,
                 attention_impl: str = "chunked", remat: bool = True):
        self.cfg = cfg
        self.compute_dtype = compute_dtype
        self.remat = remat
        every = cfg.slstm_every
        assert cfg.n_layers % every == 0
        self.n_segments = cfg.n_layers // every
        self.m_per_seg = every - 1
        self.n_mlstm = self.n_segments * self.m_per_seg

    def init(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 5)
        return {
            "embed": layers.embedding_init(ks[0], cfg),
            "mlstm": mlstm_init(ks[1], cfg, self.n_mlstm),
            "slstm": slstm_init(ks[2], cfg, self.n_segments),
            "final_norm": norm_init(cfg.norm, cfg.d_model),
            "head": common.dense(ks[3], cfg.d_model, cfg.vocab_size,
                                 ("embed", "vocab")),
        }

    def init_params(self, key):
        return unbox(self.init(key))

    def _mlstm_span(self, p_m, x, lo, hi, caches, decode):
        span = jax.tree.map(lambda a: a[lo:hi], p_m)
        conv0 = gla0 = None
        if caches is not None:
            conv0 = caches["conv"][lo:hi]
            gla0 = caches["gla"][lo:hi]
        has_cache = caches is not None

        def body(carry, scanned):
            x = carry
            lp, conv_c, gla_c = scanned
            out, nc, ns = mlstm_apply(lp, x, self.cfg, conv_c, gla_c,
                                      decode=decode)
            return x + out, ((nc, ns) if has_cache else None)

        fn = body
        if self.remat and caches is None:
            fn = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x, updates = jax.lax.scan(fn, x, (span, conv0, gla0))
        return x, updates

    def forward(self, p: Params, tokens, *, mode="train", cache=None,
                cache_index=None):
        cfg = self.cfg
        x = layers.embed(p["embed"], tokens, self.compute_dtype)
        decode = mode == "decode"
        new_cache: Optional[Params] = None
        if cache is not None:
            new_cache = {"conv": [], "gla": [], "slstm": []}
        for seg in range(self.n_segments):
            lo = seg * self.m_per_seg
            x, upd = self._mlstm_span(p["mlstm"], x, lo, lo + self.m_per_seg,
                                      cache, decode)
            s_params = jax.tree.map(lambda a: a[seg], p["slstm"])
            s_state = None
            if cache is not None:
                new_cache["conv"].append(upd[0])
                new_cache["gla"].append(upd[1])
                s_state = jax.tree.map(lambda a: a[seg], cache["slstm"])
            out, new_s = slstm_apply(s_params, x, cfg, s_state, decode)
            x = x + out
            if cache is not None:
                new_cache["slstm"].append(new_s)
        if cache is not None:
            new_cache["conv"] = jnp.concatenate(new_cache["conv"], 0)
            new_cache["gla"] = jnp.concatenate(new_cache["gla"], 0)
            new_cache["slstm"] = jax.tree.map(
                lambda *xs: jnp.stack(xs, 0), *new_cache["slstm"])
        x = apply_norm(p["final_norm"], x, cfg.norm, cfg.norm_eps)
        logits = layers.lm_head(p["head"], x, tied=False)
        return logits, 0.0, new_cache

    def loss_fn(self, p, model_state, batch, label_smoothing=0.0):
        logits, _, _ = self.forward(p, batch["tokens"], mode="train")
        loss, n_tok = common.cross_entropy_loss(
            logits, batch["targets"], label_smoothing=label_smoothing)
        return loss, (model_state, {"loss": loss, "tokens": n_tok})

    def cache_shape(self, batch: int, max_seq: int, dtype=jnp.bfloat16):
        cfg = self.cfg
        d_in, n_h, dh = _mlstm_dims(cfg)
        d_head = cfg.d_model // cfg.n_heads
        shapes = {
            "conv": ((self.n_mlstm, batch, CONV_W - 1, d_in),
                     ("layers", "batch", None, "inner"), dtype),
            "gla": ((self.n_mlstm, batch, n_h, dh + 1, dh),
                    ("layers", "batch", "heads", None, None), jnp.float32),
            "slstm": {
                k: ((self.n_segments, batch, n_h, d_head),
                    ("layers", "batch", "heads", None), jnp.float32)
                for k in ("h", "c", "n", "m")
            },
        }
        is_leaf = lambda x: isinstance(x, tuple) and isinstance(x[0], tuple)
        vals = jax.tree.map(lambda t: jnp.zeros(t[0], t[2]), shapes,
                            is_leaf=is_leaf)
        axes = jax.tree.map(lambda t: t[1], shapes, is_leaf=is_leaf)
        return vals, axes

    def prefill(self, p, tokens, cache, **_):
        logits, _, new_cache = self.forward(
            p, tokens, mode="prefill", cache=cache, cache_index=0)
        return logits[:, -1:, :], new_cache

    def decode_step(self, p, cache, tokens, cache_index):
        logits, _, new_cache = self.forward(
            p, tokens, mode="decode", cache=cache, cache_index=cache_index)
        return logits, new_cache
