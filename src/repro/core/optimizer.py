"""The paper's hybrid RMSprop-warm-up update rule (Appendix A.1), as pure
per-leaf math. ``optim/`` wires it into the GradientTransformation
interface; ``kernels/fused_update.py`` is the fused Pallas twin.

    m_t     = mu2 * m_{t-1} + (1 - mu2) * g_t^2
    Delta_t = mu1 * Delta_{t-1} - (a_sgd + a_rms / (sqrt(m_t) + eps)) * g_t
    theta_t = theta_{t-1} + eta * Delta_t

with  a_rms = (1 - a_sgd) * eta_rmsprop / eta_sgd  so that Delta stays
learning-rate independent (Goyal momentum correction, paper A.1).

At a_sgd = 1 this is exactly momentum SGD (Delta = mu1*Delta - g);
at a_sgd = 0 it is RMSprop-with-momentum.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax.numpy as jnp


class HybridHyper(NamedTuple):
    """Per-step scalars (traced inside the train step)."""

    eta: jnp.ndarray  # eta_SGD(t) from the LR schedule
    alpha_sgd: jnp.ndarray  # transition schedule value in [0, 1]
    mu1: float = 0.9
    mu2: float = 0.99
    eps: float = 1e-8
    eta_rmsprop: float = 3e-4


def alpha_rmsprop(h: HybridHyper):
    """Paper A.1 coupling: a_rms = (1 - a_sgd) * eta_rms / eta_sgd."""
    return (1.0 - h.alpha_sgd) * h.eta_rmsprop / h.eta


def hybrid_update(g, theta, delta, m, h: HybridHyper,
                  weight_decay: float = 0.0) -> Tuple:
    """One leaf update. Returns (theta', delta', m'). fp32 math."""
    g = g.astype(jnp.float32)
    theta32 = theta.astype(jnp.float32)
    if weight_decay:
        g = g + weight_decay * theta32  # L2-in-gradient (Goyal baseline)
    m_new = h.mu2 * m + (1.0 - h.mu2) * jnp.square(g)
    coef = h.alpha_sgd + alpha_rmsprop(h) / (jnp.sqrt(m_new) + h.eps)
    delta_new = h.mu1 * delta - coef * g
    theta_new = theta32 + h.eta * delta_new
    return theta_new.astype(theta.dtype), delta_new, m_new


def momentum_sgd_update(g, theta, delta, h: HybridHyper,
                        weight_decay: float = 0.0) -> Tuple:
    """Goyal et al. baseline: the a_sgd = 1 special case, no m state."""
    g = g.astype(jnp.float32)
    theta32 = theta.astype(jnp.float32)
    if weight_decay:
        g = g + weight_decay * theta32
    delta_new = h.mu1 * delta - g
    theta_new = theta32 + h.eta * delta_new
    return theta_new.astype(theta.dtype), delta_new
