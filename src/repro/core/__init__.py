"""The paper's contribution: extremely-large-minibatch training recipe.

  optimizer.py    hybrid RMSprop-warm-up update rule (Appendix A.1)
  schedules.py    ELU transition + slow-start LR + linear scaling (A.1/A.2)
  batchnorm.py    BN without moving averages + pre-validation all-reduce
  compression.py  half-precision gradient all-reduce (+ error feedback)
  recipe.py       LargeBatchRecipe bundling the above per TrainConfig
"""
from repro.core.batchnorm import (  # noqa: F401
    bn_apply_stats,
    bn_batch_stats,
    finalize_bn_stats,
)
from repro.core.compression import (  # noqa: F401
    compressed_psum,
    simulate_wire_cast,
)
from repro.core.optimizer import HybridHyper, hybrid_update  # noqa: F401
from repro.core.schedules import (  # noqa: F401
    alpha_sgd_schedule,
    goyal_lr,
    linear_scaling_lr,
    make_lr_schedule,
    slow_start_lr,
)
