"""Paper schedules (Appendix A): ELU-shaped RMSprop->SGD transition,
slow-start LR, linear scaling; plus the Goyal et al. baseline schedule.

All functions take a (possibly traced) float ``epoch`` and return scalars,
so they can live inside the jitted train step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def alpha_sgd_schedule(epoch, beta_center: float = 10.0,
                       beta_period: float = 5.0, kind: str = "elu"):
    """Paper A.1: exponential rise to 1/2 at beta_center, linear to 1 at
    beta_center + beta_period/2, then 1.

    ``kind`` also provides the transition shapes the paper *rejected*
    (A.1: "sudden transition severely impacts training", "linear
    functions have a similar problem at the beginning") for the ablation
    suite: "sudden" (step at beta_center), "linear" (ramp over the same
    span), "sigmoid" (reported comparable to ELU).
    """
    epoch = jnp.asarray(epoch, jnp.float32)
    if kind == "elu":
        exp_part = 0.5 * jnp.exp(2.0 * (epoch - beta_center) / beta_period)
        lin_part = 0.5 + 2.0 * (epoch - beta_center) / beta_period
        out = jnp.where(epoch < beta_center, exp_part, lin_part)
        return jnp.minimum(out, 1.0)
    if kind == "sudden":
        return jnp.where(epoch < beta_center, 0.0, 1.0)
    if kind == "linear":
        start = beta_center - beta_period
        return jnp.clip((epoch - start) / (1.5 * beta_period), 0.0, 1.0)
    if kind == "sigmoid":
        return jax.nn.sigmoid(4.0 * (epoch - beta_center) / beta_period)
    raise ValueError(kind)


def linear_scaling_lr(global_batch: int, base_lr_per_256: float = 0.1):
    """Goyal linear-scaling rule: eta_base = 0.1 * B / 256."""
    return base_lr_per_256 * global_batch / 256.0


def slow_start_lr(epoch, eta_base: float):
    """Paper A.2: 0.5x for 40 epochs, 0.075x for 30, 0.01x for 15,
    0.001x for the last 5."""
    epoch = jnp.asarray(epoch, jnp.float32)
    return eta_base * jnp.where(
        epoch < 40.0, 0.5,
        jnp.where(epoch < 70.0, 0.075,
                  jnp.where(epoch < 85.0, 0.01, 0.001)))


def goyal_lr(epoch, eta_base: float, warmup_epochs: float = 5.0,
             base_lr_per_256: float = 0.1):
    """Goyal et al. baseline: gradual warmup from the single-worker LR to
    eta_base over ``warmup_epochs``, then steps at 30/60/80 epochs."""
    epoch = jnp.asarray(epoch, jnp.float32)
    start = base_lr_per_256  # = 0.1, the B=256 reference LR
    frac = jnp.clip(epoch / warmup_epochs, 0.0, 1.0)
    warm = start + (eta_base - start) * frac
    stepped = eta_base * jnp.where(
        epoch < 30.0, 1.0,
        jnp.where(epoch < 60.0, 0.1,
                  jnp.where(epoch < 80.0, 0.01, 0.001)))
    return jnp.where(epoch < warmup_epochs, warm, stepped)


def poly_lr(epoch, eta_base: float, total_epochs: float = 90.0,
            power: float = 2.0, warmup_epochs: float = 5.0,
            base_lr_per_256: float = 0.1):
    """LARS-recipe schedule (You et al.; Yamazaki et al. pair it with
    label smoothing): gradual warmup from the single-worker LR to
    eta_base over ``warmup_epochs``, then polynomial decay
    ``eta_base * (1 - progress)**power`` to zero at ``total_epochs``
    (power=2 in both papers)."""
    epoch = jnp.asarray(epoch, jnp.float32)
    start = base_lr_per_256  # = 0.1, the B=256 reference LR
    frac = jnp.clip(epoch / warmup_epochs, 0.0, 1.0)
    warm = start + (eta_base - start) * frac
    span = max(total_epochs - warmup_epochs, 1e-6)
    t = jnp.clip((epoch - warmup_epochs) / span, 0.0, 1.0)
    decayed = eta_base * (1.0 - t) ** power
    return jnp.where(epoch < warmup_epochs, warm, decayed)


def make_lr_schedule(kind: str, global_batch: int, *,
                     base_lr_per_256: float = 0.1,
                     warmup_epochs: float = 5.0,
                     total_epochs: float = 90.0,
                     poly_power: float = 2.0):
    eta_base = linear_scaling_lr(global_batch, base_lr_per_256)
    if kind == "slow_start":
        return lambda epoch: slow_start_lr(epoch, eta_base)
    if kind == "goyal":
        return lambda epoch: goyal_lr(epoch, eta_base, warmup_epochs,
                                      base_lr_per_256)
    if kind == "poly":
        return lambda epoch: poly_lr(epoch, eta_base, total_epochs,
                                     poly_power, warmup_epochs,
                                     base_lr_per_256)
    if kind == "constant":
        return lambda epoch: jnp.asarray(eta_base, jnp.float32)
    raise ValueError(kind)
