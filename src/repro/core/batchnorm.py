"""Batch normalization *without moving averages* (paper §2).

The model keeps only the **last minibatch's** statistics as state. Before
validation, those statistics are all-reduced across workers (the paper's
"all-reduce communication on these statistics ... before validation").

Two execution modes:
  * GSPMD jit (default): the batch dim is sharded over the data axes, so
    ``jnp.mean`` over it is already a global (cross-replica) statistic —
    sync-BN comes out of the partitioner for free.
  * Explicit shard_map DP (paper-faithful mode): stats are per-worker;
    ``finalize_bn_stats`` performs the paper's pre-validation all-reduce
    (and is also usable per-step for sync-BN).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def bn_batch_stats(x: jax.Array,
                   cross_replica: Optional[Sequence[str]] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Mean/var over all but the channel (last) axis, fp32 accumulation
    (no fp32 copy of the activation is materialized).

    ``cross_replica``: axis names when running under shard_map — stats are
    then psum-averaged across those axes (sync-BN). Under GSPMD jit leave
    it None; the partitioner already makes the reduction global.
    """
    axes = tuple(range(x.ndim - 1))
    mean = jnp.mean(x, axis=axes, dtype=jnp.float32)
    mean_sq = jnp.mean(jnp.square(x), axis=axes, dtype=jnp.float32)
    if cross_replica:
        mean = jax.lax.pmean(mean, cross_replica)
        mean_sq = jax.lax.pmean(mean_sq, cross_replica)
    var = jnp.maximum(mean_sq - jnp.square(mean), 0.0)
    return mean, var


def bn_apply_stats(x: jax.Array, mean, var, scale, bias,
                   eps: float = 1e-5) -> jax.Array:
    """Normalize in the compute dtype; only the per-channel scale/offset
    are folded in fp32 (one bf16 stream instead of two fp32 streams —
    EXPERIMENTS.md §Perf resnet iteration)."""
    inv = (jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32))
    off = bias.astype(jnp.float32) - mean * inv
    return (x * inv.astype(x.dtype) + off.astype(x.dtype)).astype(x.dtype)


def finalize_bn_stats(state: PyTree,
                      axis_names: Optional[Sequence[str]] = None) -> PyTree:
    """The paper's pre-validation all-reduce of last-minibatch statistics.

    Inside shard_map: pmean over ``axis_names``. Under GSPMD (or single
    process) the stats are already global and this is the identity —
    kept as an explicit step so the serving/validation path is the same
    program in both modes.
    """
    if not axis_names:
        return state

    def reduce(leaf):
        return jax.lax.pmean(leaf, axis_names)

    return jax.tree.map(reduce, state)


def merge_bn_stats(states: Sequence[PyTree]) -> PyTree:
    """Host-side helper: average stats across a list of per-worker states
    (used by elastic restore when re-sharding a checkpoint)."""
    def avg(*leaves):
        return sum(leaves) / len(leaves)

    return jax.tree.map(avg, *states)
