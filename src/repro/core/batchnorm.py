"""Batch normalization *without moving averages* (paper §2).

The model keeps only the **last minibatch's** statistics as state. Before
validation, those statistics are all-reduced across workers (the paper's
"all-reduce communication on these statistics ... before validation").

Two execution modes:
  * GSPMD jit (default): the batch dim is sharded over the data axes, so
    ``jnp.mean`` over it is already a global (cross-replica) statistic —
    sync-BN comes out of the partitioner for free.
  * Explicit shard_map DP (paper-faithful mode): stats are per-worker;
    ``finalize_bn_stats`` performs the paper's pre-validation all-reduce
    (and is also usable per-step for sync-BN).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def bn_batch_stats(x: jax.Array,
                   cross_replica: Optional[Sequence[str]] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Mean/var over all but the channel (last) axis, fp32 accumulation.

    The variance uses the **centered** form E[(x - mu)^2], not
    E[x^2] - E[x]^2: for a large-mean bf16/fp16 activation the
    uncentered difference cancels almost all significant bits (both
    terms ~mean^2, their gap ~var), while the centered second moment is
    computed on values of magnitude ~sigma and stays accurate — the
    f64-oracle regression in tests/test_core_batchnorm.py pins this.
    The fp32 upcast of ``x - mu`` feeds only the square-reduce, so XLA
    fuses it into the reduction (no fp32 activation copy in HBM).

    ``cross_replica``: axis names when running under shard_map — the
    mean is psum-averaged first, then the per-worker second moments
    about the *global* mean are psum-averaged (sync-BN; equal to the
    statistics of the concatenated global batch). Under GSPMD jit leave
    it None; the partitioner already makes the reduction global.
    """
    axes = tuple(range(x.ndim - 1))
    mean = jnp.mean(x, axis=axes, dtype=jnp.float32)
    if cross_replica:
        mean = jax.lax.pmean(mean, cross_replica)
    var = jnp.mean(jnp.square(x.astype(jnp.float32) - mean), axis=axes)
    if cross_replica:
        var = jax.lax.pmean(var, cross_replica)
    return mean, var


def bn_apply_stats(x: jax.Array, mean, var, scale, bias,
                   eps: float = 1e-5) -> jax.Array:
    """Normalize in the compute dtype; only the per-channel scale/offset
    are folded in fp32 (one bf16 stream instead of two fp32 streams —
    EXPERIMENTS.md §Perf resnet iteration)."""
    inv = (jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32))
    off = bias.astype(jnp.float32) - mean * inv
    return (x * inv.astype(x.dtype) + off.astype(x.dtype)).astype(x.dtype)


def _is_stat(node) -> bool:
    """A BN statistics record: dict carrying mean + var leaves."""
    return isinstance(node, dict) and "mean" in node and "var" in node


def _combine_moments(mean_w, var_w, reduce_mean):
    """Average per-worker (mean, var) pairs moment-correctly.

    Averaging variances directly drops the spread of the per-worker
    means; reconstructing E[x^2] = var + mean^2 first makes the combined
    statistics *exactly* the global-minibatch statistics when every
    worker saw an equal shard — which is what makes shard_map-DP eval
    logits match GSPMD eval logits (DESIGN.md §7). ``reduce_mean``
    abstracts over host-side mean (leading worker axis) vs in-program
    pmean.
    """
    mean = reduce_mean(mean_w)
    ex2 = reduce_mean(var_w + jnp.square(mean_w))
    var = jnp.maximum(ex2 - jnp.square(mean), 0.0)
    return mean, var


def _reduce_stats(state: PyTree, reduce) -> PyTree:
    """Apply ``reduce`` to every leaf, combining (mean, var) stat
    records moment-correctly along the way."""

    def combine(d):
        mean, var = _combine_moments(d["mean"], d["var"], reduce)
        out = dict(d)
        out.update(mean=mean, var=var)
        for k in out:
            if k not in ("mean", "var"):
                out[k] = reduce(out[k])
        return out

    def visit(node):
        if _is_stat(node):
            return combine(node)
        return jax.tree.map(reduce, node)

    return jax.tree.map(visit, state, is_leaf=_is_stat)


def combine_worker_bn_stats(state: PyTree) -> PyTree:
    """Paper §2's pre-validation all-reduce, host/jit form: statistics
    carry a leading per-worker axis (the shard_map DP layout); returns
    the global statistics with that axis reduced. ``mean`` leaves are
    plain-averaged; ``var`` leaves are combined via E[x^2] so the result
    equals the statistics of the concatenated (global) minibatch."""
    return _reduce_stats(state, lambda x: jnp.mean(x, axis=0))


def finalize_bn_stats(state: PyTree,
                      axis_names: Optional[Sequence[str]] = None) -> PyTree:
    """The paper's pre-validation all-reduce of last-minibatch statistics.

    Inside shard_map: pmean over ``axis_names`` (moment-correct for
    mean/var stat records, see ``combine_worker_bn_stats``). Under GSPMD
    (or single process) the stats are already global and this is the
    identity — kept as an explicit step so the serving/validation path
    is the same program in both modes.
    """
    if not axis_names:
        return state

    return _reduce_stats(state,
                         lambda leaf: jax.lax.pmean(leaf, axis_names))


def merge_bn_stats(states: Sequence[PyTree]) -> PyTree:
    """Host-side helper: average stats across a list of per-worker states
    (used by elastic restore when re-sharding a checkpoint)."""
    def avg(*leaves):
        return sum(leaves) / len(leaves)

    return jax.tree.map(avg, *states)
