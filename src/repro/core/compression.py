"""Half-precision gradient communication (paper §3) + error feedback.

The paper casts gradients to fp16 for the NCCL all-reduce and observed a
negligible accuracy effect. TPU adaptation (DESIGN.md §2): bf16 is the
default wire format (fp32 exponent range => no loss scaling), fp16 is
available for paper-faithfulness.

Three sync modes (selected by ``ParallelConfig.compression``):
  * ``compressed_psum`` — explicit shard_map DP mode (``"bf16"``/``"f16"``):
    cast -> psum -> cast, one collective per gradient leaf — exactly the
    paper's mechanism.
  * bucketed (``"bf16+bucketed"`` etc., DESIGN.md §6) — the per-leaf cast
    feeds ``distributed/bucketing.py``, which packs the gradient stream
    into fixed-size contiguous buckets and issues one collective per
    bucket instead of one per leaf.
  * ``simulate_wire_cast`` — GSPMD mode: gradients are cast to the wire
    dtype and back *at the sync boundary*, so the numerics match the
    compressed collective even when XLA chooses where the all-reduce
    lives. The dry-run HLO parse reports actual collective dtypes.

Beyond paper: error feedback (residual accumulation) removes the bias of
repeated rounding at very large scale; ``compressed_psum_ef`` threads the
residuals through either explicit sync path (the bucketed variant lives
in ``distributed/bucketing.py``).
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

WIRE_DTYPES = {"bf16": jnp.bfloat16, "f16": jnp.float16, None: None,
               "none": None}


def _wire(dtype_name: Optional[str]):
    if dtype_name not in WIRE_DTYPES:
        raise ValueError(f"unknown wire dtype {dtype_name}")
    return WIRE_DTYPES[dtype_name]


def parse_compression(spec: Optional[str]) -> Tuple[Optional[str], bool]:
    """Split a ``ParallelConfig.compression`` string into
    ``(wire_dtype_name, bucketed)``.

    ``None``/"none" -> (None, False); "bf16" -> ("bf16", False);
    "bf16+bucketed" -> ("bf16", True); "bucketed" -> (None, True) —
    bucketing without a wire cast still fuses the per-leaf collectives.
    """
    if spec is None:
        return None, False
    wire: Optional[str] = None
    bucketed = False
    seen_wire = False
    for part in spec.split("+"):
        if part == "bucketed":
            if bucketed:
                raise ValueError(f"duplicate 'bucketed' in {spec!r}")
            bucketed = True
        elif part in WIRE_DTYPES:
            if seen_wire:
                raise ValueError(
                    f"conflicting wire dtypes in {spec!r}")
            seen_wire = True
            wire = None if part == "none" else part
        else:
            raise ValueError(f"unknown compression spec part {part!r} "
                             f"in {spec!r}")
    return wire, bucketed


def compressed_psum(grads: PyTree, axis_names: Sequence[str],
                    wire: Optional[str] = "bf16",
                    mean: bool = True) -> PyTree:
    """Paper-faithful compressed all-reduce (shard_map mode).

    Cast each gradient leaf to the wire dtype, psum over the data axes,
    cast back to the accumulation dtype. ``mean=True`` divides by the
    number of workers (the paper averages per-worker gradients).
    """
    wdt = _wire(wire)
    # static axis-size product; psum of a python constant folds at trace
    # time (no collective is emitted), unlike lax.axis_size which does
    # not exist on this jax version
    n = jax.lax.psum(1, tuple(axis_names))

    def sync(g):
        acc_dtype = g.dtype
        if wdt is not None:
            g = g.astype(wdt)
        g = jax.lax.psum(g, tuple(axis_names))
        g = g.astype(acc_dtype)
        return g / n if mean else g

    return jax.tree.map(sync, grads)


def simulate_wire_cast(grads: PyTree, wire: Optional[str] = "bf16") -> PyTree:
    """GSPMD mode: round-trip gradients through the wire dtype so the
    numerics of compressed communication are applied; XLA's collective
    then carries the low-precision value when it can sink the cast."""
    wdt = _wire(wire)
    if wdt is None:
        return grads
    return jax.tree.map(lambda g: g.astype(wdt).astype(g.dtype), grads)


# ---------------------------------------------------------------------------
# Error feedback (beyond paper)
# ---------------------------------------------------------------------------


def init_error_feedback(grads: PyTree) -> PyTree:
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def apply_error_feedback(grads: PyTree, residual: PyTree,
                         wire: str = "bf16") -> Tuple[PyTree, PyTree]:
    """q = Q(g + r);  r' = (g + r) - q.  Returns (quantized, new_residual)."""
    wdt = _wire(wire)

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q = corrected.astype(wdt).astype(jnp.float32)
        return q.astype(g.dtype), corrected - q

    pairs = jax.tree.map(one, grads, residual)
    quant = jax.tree.map(lambda t: t[0], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree.map(lambda t: t[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return quant, resid


def compressed_psum_ef(grads: PyTree, residual: PyTree,
                       axis_names: Sequence[str], wire: str = "bf16",
                       mean: bool = True) -> Tuple[PyTree, PyTree]:
    """Per-leaf compressed psum with error feedback threaded through.

    The residual update is worker-local (it sees the *local* gradient, so
    every worker's rounding error is corrected on its next step); only
    the wire-rounded value crosses the interconnect. The subsequent wire
    cast inside ``compressed_psum`` is exact because ``q`` is already
    wire-representable.
    """
    quant, new_residual = apply_error_feedback(grads, residual, wire)
    synced = compressed_psum(quant, axis_names, wire, mean=mean)
    return synced, new_residual


def compression_error(grads: PyTree, wire: str = "bf16") -> jax.Array:
    """Relative L2 rounding error of the wire cast — logged as a training
    metric so the paper's 'effect ... was relatively small' claim is
    checkable per run."""
    def err(g):
        g32 = g.astype(jnp.float32)
        q = g32.astype(_wire(wire)).astype(jnp.float32)
        return jnp.sum(jnp.square(q - g32)), jnp.sum(jnp.square(g32))

    num = sum(jax.tree.leaves(jax.tree.map(lambda g: err(g)[0], grads)))
    den = sum(jax.tree.leaves(jax.tree.map(lambda g: err(g)[1], grads)))
    return jnp.sqrt(num / jnp.maximum(den, 1e-30))
