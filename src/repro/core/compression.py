"""Half-precision gradient communication (paper §3) + error feedback.

The paper casts gradients to fp16 for the NCCL all-reduce and observed a
negligible accuracy effect. TPU adaptation (DESIGN.md §2): bf16 is the
default wire format (fp32 exponent range => no loss scaling), fp16 is
available for paper-faithfulness.

Two integration points:
  * ``compressed_psum`` — explicit shard_map DP mode: cast -> psum -> cast,
    exactly the paper's mechanism.
  * ``simulate_wire_cast`` — GSPMD mode: gradients are cast to the wire
    dtype and back *at the sync boundary*, so the numerics match the
    compressed collective even when XLA chooses where the all-reduce
    lives. The dry-run HLO parse reports actual collective dtypes.

Beyond paper: error feedback (residual accumulation) removes the bias of
repeated rounding at very large scale.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

WIRE_DTYPES = {"bf16": jnp.bfloat16, "f16": jnp.float16, None: None,
               "none": None}


def _wire(dtype_name: Optional[str]):
    if dtype_name not in WIRE_DTYPES:
        raise ValueError(f"unknown wire dtype {dtype_name}")
    return WIRE_DTYPES[dtype_name]


def compressed_psum(grads: PyTree, axis_names: Sequence[str],
                    wire: Optional[str] = "bf16",
                    mean: bool = True) -> PyTree:
    """Paper-faithful compressed all-reduce (shard_map mode).

    Cast each gradient leaf to the wire dtype, psum over the data axes,
    cast back to the accumulation dtype. ``mean=True`` divides by the
    number of workers (the paper averages per-worker gradients).
    """
    wdt = _wire(wire)
    n = 1
    for a in axis_names:
        n *= jax.lax.axis_size(a)

    def sync(g):
        acc_dtype = g.dtype
        if wdt is not None:
            g = g.astype(wdt)
        g = jax.lax.psum(g, tuple(axis_names))
        g = g.astype(acc_dtype)
        return g / n if mean else g

    return jax.tree.map(sync, grads)


def simulate_wire_cast(grads: PyTree, wire: Optional[str] = "bf16") -> PyTree:
    """GSPMD mode: round-trip gradients through the wire dtype so the
    numerics of compressed communication are applied; XLA's collective
    then carries the low-precision value when it can sink the cast."""
    wdt = _wire(wire)
    if wdt is None:
        return grads
    return jax.tree.map(lambda g: g.astype(wdt).astype(g.dtype), grads)


# ---------------------------------------------------------------------------
# Error feedback (beyond paper)
# ---------------------------------------------------------------------------


def init_error_feedback(grads: PyTree) -> PyTree:
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def apply_error_feedback(grads: PyTree, residual: PyTree,
                         wire: str = "bf16") -> Tuple[PyTree, PyTree]:
    """q = Q(g + r);  r' = (g + r) - q.  Returns (quantized, new_residual)."""
    wdt = _wire(wire)

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q = corrected.astype(wdt).astype(jnp.float32)
        return q.astype(g.dtype), corrected - q

    pairs = jax.tree.map(one, grads, residual)
    quant = jax.tree.map(lambda t: t[0], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree.map(lambda t: t[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return quant, resid


def compression_error(grads: PyTree, wire: str = "bf16") -> jax.Array:
    """Relative L2 rounding error of the wire cast — logged as a training
    metric so the paper's 'effect ... was relatively small' claim is
    checkable per run."""
    def err(g):
        g32 = g.astype(jnp.float32)
        q = g32.astype(_wire(wire)).astype(jnp.float32)
        return jnp.sum(jnp.square(q - g32)), jnp.sum(jnp.square(g32))

    num = sum(jax.tree.leaves(jax.tree.map(lambda g: err(g)[0], grads)))
    den = sum(jax.tree.leaves(jax.tree.map(lambda g: err(g)[1], grads)))
    return jnp.sqrt(num / jnp.maximum(den, 1e-30))
