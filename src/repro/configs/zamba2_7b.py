"""Zamba2-7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242].

81 Mamba2 blocks; two distinct shared (attention+MLP) blocks are cycled and
applied every 6 backbone layers, each taking concat(hidden, residual) via a
learned down-projection (the Zamba2 "shared transformer" pattern).
"""
from repro.configs.base import ModelConfig, register


@register("zamba2-7b")
def zamba2_7b() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b",
        family="hybrid",
        n_layers=81,
        d_model=3584,
        n_heads=32,
        n_kv_heads=32,
        head_dim=112,
        d_ff=14336,
        vocab_size=32000,
        norm="rmsnorm",
        ssm_state=64,
        ssm_conv_width=4,
        ssm_expand=2,
        ssm_head_dim=64,
        shared_attn_every=6,
        n_shared_attn_blocks=2,
        source="arXiv:2411.15242; unverified",
    )
