"""Granite-34B-Code — llama-arch MQA (kv=1) [arXiv:2405.04324; hf]."""
from repro.configs.base import ModelConfig, register


@register("granite-34b")
def granite_34b() -> ModelConfig:
    return ModelConfig(
        name="granite-34b",
        family="dense",
        n_layers=88,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        head_dim=128,
        d_ff=24576,
        vocab_size=49152,
        norm="layernorm",
        mlp_variant="gelu",  # GPT-BigCode style 2-matrix MLP
        rope_theta=10000.0,
        source="arXiv:2405.04324; hf",
    )
