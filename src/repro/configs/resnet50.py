"""ResNet-50 — the paper's own benchmark architecture (He et al. 2016).

Bottleneck stages [3,4,6,3], width 64, BatchNorm (the paper's
no-moving-average variant with cross-replica sync), 1000 classes,
224x224 input. Trained at global minibatch 32,768 per the paper.
"""
from repro.configs.base import ModelConfig, register


@register("resnet50")
def resnet50() -> ModelConfig:
    return ModelConfig(
        name="resnet50",
        family="conv",
        n_layers=50,
        d_model=0,
        n_heads=0,
        n_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=0,
        conv_stages=(3, 4, 6, 3),
        conv_width=64,
        num_classes=1000,
        image_size=224,
        source="CVPR16 He et al.; paper's own benchmark",
    )
