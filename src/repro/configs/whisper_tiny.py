"""Whisper-tiny — enc-dec audio backbone; conv frontend is a STUB.

Per assignment spec: ``input_specs()`` provides precomputed frame
embeddings (post-conv). 4 encoder + 4 decoder layers. Decoder uses learned
positional embeddings and cross-attention. [arXiv:2212.04356; unverified]
"""
from repro.configs.base import AudioFrontend, ModelConfig, register


@register("whisper-tiny")
def whisper_tiny() -> ModelConfig:
    return ModelConfig(
        name="whisper-tiny",
        family="audio",
        n_layers=4,  # decoder layers
        n_encoder_layers=4,
        d_model=384,
        n_heads=6,
        n_kv_heads=6,
        head_dim=64,
        d_ff=1536,
        vocab_size=51865,
        norm="layernorm",
        mlp_variant="gelu",
        pos_embedding="learned",
        audio=AudioFrontend(num_frames=1500, frame_dim=80),
        source="arXiv:2212.04356; unverified",
    )
