"""Arch config registry. Importing this package registers every config."""
from repro.configs.base import (  # noqa: F401
    InputConfig,
    ModelConfig,
    OptimizerConfig,
    ParallelConfig,
    ShapeConfig,
    TrainConfig,
    get_config,
    list_archs,
    reduced_config,
    shapes_for,
)

# Register all architectures (10 assigned + the paper's own ResNet-50).
from repro.configs import (  # noqa: F401,E402
    granite_34b,
    llama3_2_1b,
    llama4_maverick_400b,
    mixtral_8x7b,
    phi_3_vision_4_2b,
    qwen2_72b,
    resnet50,
    whisper_tiny,
    xlstm_350m,
    yi_9b,
    zamba2_7b,
)

ASSIGNED_ARCHS = (
    "qwen2-72b",
    "yi-9b",
    "llama3.2-1b",
    "granite-34b",
    "phi-3-vision-4.2b",
    "zamba2-7b",
    "whisper-tiny",
    "llama4-maverick-400b-a17b",
    "mixtral-8x7b",
    "xlstm-350m",
)
