"""Config system: dataclasses + registry for architectures, shapes, meshes.

Every assigned architecture is a ``ModelConfig`` produced by a factory in
``src/repro/configs/<arch>.py`` and registered under its public id
(``--arch <id>``). Shapes are the per-arch input-shape cells from the
assignment; meshes are the production meshes from launch/mesh.py.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Model configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class VisionFrontend:
    """Stub modality frontend (VLM): precomputed patch embeddings."""

    num_patches: int = 576
    patch_dim: int = 1024  # CLIP-L hidden size feeding the projector


@dataclass(frozen=True)
class AudioFrontend:
    """Stub modality frontend (audio): precomputed mel-frame embeddings."""

    num_frames: int = 1500  # 30 s of audio after 2x conv subsampling
    frame_dim: int = 80  # mel bins (pre-conv); stub supplies post-conv embeds


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters. One instance per assigned arch."""

    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm | conv
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: Optional[int] = None  # default: d_model // n_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    sliding_window: Optional[int] = None  # SWA width (mixtral)
    pos_embedding: str = "rope"  # rope | learned | none
    mlp_variant: str = "swiglu"  # swiglu (3 mats) | gelu (2 mats)

    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_layer_every: int = 1  # MoE on layers where (i % every == every-1)
    n_shared_experts: int = 0  # llama4-style always-on shared expert

    # --- SSM / hybrid (zamba2-style Mamba2 backbone) ---
    ssm_state: int = 0
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    shared_attn_every: int = 0  # hybrid: insert shared attn block every k
    n_shared_attn_blocks: int = 0  # number of distinct shared blocks cycled

    # --- xLSTM ---
    slstm_every: int = 0  # sLSTM at layers i % every == every-1; rest mLSTM
    mlstm_proj_factor: float = 2.0
    slstm_proj_factor: float = 4.0 / 3.0

    # --- encoder-decoder (whisper) ---
    n_encoder_layers: int = 0  # >0 => enc-dec; n_layers = decoder layers

    # --- conv net (resnet50, the paper's own arch) ---
    conv_stages: Tuple[int, ...] = ()  # bottleneck block counts per stage
    conv_width: int = 64
    num_classes: int = 0
    image_size: int = 224
    # fused Pallas BN at every BN site: one-pass stats + fused
    # normalize/ReLU/residual epilogue + fused custom-VJP backward
    # (kernels/fused_bn.py, --fused-bn, DESIGN.md §10)
    fused_bn: bool = False

    # --- modality frontends (stubs per assignment spec) ---
    vision: Optional[VisionFrontend] = None
    audio: Optional[AudioFrontend] = None

    # notes for DESIGN/EXPERIMENTS provenance
    source: str = ""

    def __post_init__(self):
        if self.head_dim is None and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # ---- derived quantities -------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def is_moe_layer(self, layer_idx: int) -> bool:
        if not self.n_experts:
            return False
        return layer_idx % self.moe_layer_every == self.moe_layer_every - 1

    @property
    def n_moe_layers(self) -> int:
        return sum(self.is_moe_layer(i) for i in range(self.n_layers))

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        if self.family == "conv":
            return _resnet_param_count(self)
        d, h = self.d_model, self.head_dim
        n_emb = self.vocab_size * d
        n_head = 0 if self.tie_embeddings else self.vocab_size * d
        per_attn = d * self.n_heads * h + 2 * d * self.n_kv_heads * h \
            + self.n_heads * h * d
        if self.qkv_bias:
            per_attn += (self.n_heads + 2 * self.n_kv_heads) * h
        mlp_mats = 3 if self.mlp_variant == "swiglu" else 2
        per_dense_mlp = mlp_mats * d * self.d_ff
        blocks = 0
        if self.family == "ssm":  # xLSTM
            blocks = self.n_layers * _xlstm_block_params(self)
        elif self.family == "hybrid":
            blocks = self.n_layers * _mamba2_block_params(self)
            shared = per_attn + per_dense_mlp + 2 * d
            blocks += self.n_shared_attn_blocks * shared
            # projections from concat(residual, hidden) into shared block
            blocks += self.n_shared_attn_blocks * (2 * d) * d
        else:
            for i in range(self.n_layers):
                blocks += per_attn + 2 * d  # attn + 2 norms
                if self.is_moe_layer(i):
                    blocks += self.n_experts * mlp_mats * d * self.d_ff
                    blocks += d * self.n_experts  # router
                    blocks += self.n_shared_experts * mlp_mats * d * self.d_ff
                else:
                    blocks += per_dense_mlp
        if self.n_encoder_layers:
            enc = self.n_encoder_layers * (per_attn + per_dense_mlp + 2 * d)
            dec_cross = self.n_layers * (per_attn + d)  # cross-attn + norm
            blocks += enc + dec_cross
        return n_emb + n_head + blocks + d  # final norm

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k experts only)."""
        if not self.n_experts:
            return self.param_count()
        total = self.param_count()
        inactive_per_layer = (
            (self.n_experts - self.experts_per_token) * 3 * self.d_model * self.d_ff
        )
        return total - self.n_moe_layers * inactive_per_layer


def _xlstm_block_params(cfg: ModelConfig) -> int:
    """Average block size over the mLSTM/sLSTM mix (block-diag projections)."""
    d, n_h = cfg.d_model, cfg.n_heads
    d_in = int(d * cfg.mlstm_proj_factor)
    # mLSTM: up (h+gate), block-diagonal per-head qkv, i/f scalar gates, down
    mlstm = d * 2 * d_in + 3 * d_in * d_in // n_h + d_in * 2 * n_h + d_in * d + 2 * d
    # sLSTM: 4 gates input + 4 recurrent (block-diag) + gated FFN
    d_ffn = int(d * cfg.slstm_proj_factor)
    slstm = 8 * d * d // n_h + 3 * d * d_ffn + 2 * d
    if not cfg.slstm_every:
        return mlstm
    frac_s = 1.0 / cfg.slstm_every
    return int(mlstm * (1 - frac_s) + slstm * frac_s)


def _mamba2_block_params(cfg: ModelConfig) -> int:
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    n_h = d_in // cfg.ssm_head_dim
    in_proj = d * (2 * d_in + 2 * cfg.ssm_state + n_h)
    conv = cfg.ssm_conv_width * (d_in + 2 * cfg.ssm_state)
    out = d_in * d
    return in_proj + conv + out + 2 * n_h + d_in + 2 * d


def _resnet_param_count(cfg: ModelConfig) -> int:
    w = cfg.conv_width
    total = 3 * 7 * 7 * w + 2 * w  # stem
    c_in = w
    for stage, blocks in enumerate(cfg.conv_stages):
        mid = w * (2 ** stage)
        c_out = mid * 4
        for b in range(blocks):
            total += c_in * mid + 3 * 3 * mid * mid + mid * c_out
            total += 2 * (mid + mid + c_out)  # BN scale/offset
            if b == 0:
                total += c_in * c_out + 2 * c_out  # projection shortcut
            c_in = c_out
    total += c_in * cfg.num_classes + cfg.num_classes
    return total


# ---------------------------------------------------------------------------
# Shapes (the per-arch input-shape cells)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode
    skip_reason: Optional[str] = None  # e.g. long_500k on full-attention archs


LM_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4096, 256, "train"),
    ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    ShapeConfig("decode_32k", 32768, 128, "decode"),
    ShapeConfig("long_500k", 524288, 1, "decode"),
)

RESNET_SHAPES: Tuple[ShapeConfig, ...] = (
    # The paper's headline cell: 32k global minibatch.
    ShapeConfig("train_32k", 224, 32768, "train"),
    ShapeConfig("train_8k", 224, 8192, "train"),
)

# archs whose every attention layer is full/dense => long_500k is skipped
FULL_ATTENTION_SKIP = (
    "long_500k needs sub-quadratic attention; this arch is pure "
    "full-attention (see DESIGN.md section 4)"
)


def shapes_for(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    if cfg.family == "conv":
        return RESNET_SHAPES
    out: List[ShapeConfig] = []
    subquadratic = (
        cfg.family in ("ssm", "hybrid") or cfg.sliding_window is not None
    )
    for s in LM_SHAPES:
        if s.name == "long_500k" and not subquadratic:
            s = dataclasses.replace(s, skip_reason=FULL_ATTENTION_SKIP)
        if cfg.name == "whisper-tiny" and s.name == "long_500k":
            s = dataclasses.replace(
                s, skip_reason="enc-dec audio decoder caps at 448 positions"
            )
        out.append(s)
    return tuple(out)


# ---------------------------------------------------------------------------
# Training / parallelism configuration (the paper's recipe knobs)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class OptimizerConfig:
    """Paper Appendix A hyper-parameters (defaults are the paper's)."""

    kind: str = "rmsprop_warmup"  # rmsprop_warmup | momentum_sgd | lars
    mu1: float = 0.9  # momentum
    mu2: float = 0.99  # second-moment EMA
    eps: float = 1e-8
    eta_rmsprop: float = 3e-4
    beta_center: float = 10.0  # epochs; alpha_sgd = 1/2 here
    beta_period: float = 5.0
    transition: str = "elu"  # elu (paper) | sudden | linear | sigmoid
    weight_decay: float = 1e-4  # Goyal baseline WD (applied as L2-in-grad)
    base_lr_per_256: float = 0.1  # linear-scaling constant
    schedule: str = "slow_start"  # slow_start | goyal | poly | constant
    warmup_epochs: float = 5.0  # gradual warmup (goyal/poly schedules)
    total_epochs: float = 90.0
    # LARS (You et al.): layer-wise trust-ratio coefficient; poly_power
    # is the "poly" schedule's decay exponent (2 in You/Yamazaki et al.)
    trust_coef: float = 0.001
    poly_power: float = 2.0
    use_fused_kernel: bool = False  # Pallas fused_update on TPU
    # beyond paper: bf16 optimizer state halves m/Delta residency (the
    # update math stays fp32) — what lets 400B fp32-master training fit
    # a single 256-chip pod (EXPERIMENTS.md §Dry-run)
    state_dtype: str = "float32"  # float32 | bfloat16


@dataclass(frozen=True)
class ParallelConfig:
    """How a (arch x shape) cell maps onto the mesh."""

    dp_axes: Tuple[str, ...] = ("data",)  # + ("pod",) on multi-pod
    tp_axis: Optional[str] = "model"
    zero_1: bool = True  # shard optimizer state over dp axes (beyond paper)
    fsdp_params: bool = False  # shard params over dp axes too
    # gradient sync: None | bf16 | f16 (paper: f16) | "<wire>+bucketed"
    # (one collective per fixed-size bucket instead of per leaf,
    # DESIGN.md §2/§6; bucketed applies to the shard_map DP mode)
    compression: Optional[str] = "bf16"
    bucket_bytes: int = 64 * 1024 * 1024  # bucketed sync: bytes/collective
    error_feedback: bool = False  # thread EF residuals through explicit sync
    # launch each bucket's all-reduce as soon as its leaves are produced
    # by the backward pass (ready-order bucketing + staged VJP,
    # DESIGN.md §8); shard_map DP only, requires a staged model
    overlap_comm: bool = False
    # ZeRO reduce-scatter sync (--zero, DESIGN.md §9): psum_scatter each
    # packed bucket, run the optimizer update only on the worker-owned
    # shard of the stream (delta/m sharded over dp), all-gather the
    # updated param slices back. shard_map DP + bucketed compression
    # only; composes with overlap_comm. Distinct from zero_1, which is
    # the GSPMD-mode sharding-constraint flavor of the same idea.
    zero_dp: bool = False
    # hierarchical collective schedule (DESIGN.md §14): split dp_axes at
    # this index into outer (inter-node) / inner (intra-node) stages and
    # run each bucket as intra reduce-scatter -> inter all-reduce ->
    # intra all-gather instead of one flat psum. None = flat. Needs a
    # multi-axis DP mesh with both factors >= 2 and bucketed compression;
    # usually set via launch/train.py --comm-plan (distributed/comm_plan).
    hier_split: Optional[int] = None
    remat: str = "block"  # none | block  (activation checkpoint per layer)
    sequence_sharding: bool = False  # shard seq dim of activations (SP)
    kv_seq_sharding: bool = False  # serve: shard KV cache seq on model


@dataclass(frozen=True)
class InputConfig:
    """Production input-pipeline knobs (DESIGN.md §15).

    ``fused`` moves augmentation + normalize + compute-dtype cast into a
    single on-device Pallas pass (kernels/fused_input.py) applied inside
    the shard_map step; off, the same transform runs on the host feed
    workers (pipeline.AugmentedSource) — the two paths are parity-tested
    (tests/test_fused_input.py)."""

    augment: bool = True  # per-sample flip + shift (crop proxy) on train
    fused: bool = False  # on-device Pallas augment+normalize+cast
    num_workers: int = 1  # host producer threads (--data-workers)
    depth: int = 4  # reorder-buffer bound, steps ahead of consumer
    device_ahead: int = 1  # steps staged on device past the current one
    num_hosts: int = 1  # per-host input sharding (--host-shard h/N)
    host_id: int = 0
    max_shift: int = 4  # translation-augmentation radius, pixels
    # ImageNet-style per-channel normalization (unit scale for the
    # synthetic task, whose pixels are already ~N(0, 1))
    mean: Tuple[float, float, float] = (0.0, 0.0, 0.0)
    std: Tuple[float, float, float] = (1.0, 1.0, 1.0)


@dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = field(default_factory=OptimizerConfig)
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    input: Optional[InputConfig] = None  # None = seed-era raw feed
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    steps_per_epoch: int = 40  # ImageNet@32k: 1.28M/32768 = 40 (paper)
    seed: int = 0
    label_smoothing: float = 0.0
    # GSPMD-path grad-norm logging costs a full extra tree reduction per
    # step, so it is opt-in; the explicit bucketed/overlapped sync paths
    # get the norm for free from the packed stream (DESIGN.md §8)
    log_grad_norm: bool = False


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Callable[[], ModelConfig]] = {}


def register(arch_id: str):
    def deco(fn: Callable[[], ModelConfig]):
        _REGISTRY[arch_id] = fn
        return fn

    return deco


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _REGISTRY:
        raise KeyError(
            f"unknown arch {arch_id!r}; available: {sorted(_REGISTRY)}"
        )
    return _REGISTRY[arch_id]()


def list_archs() -> List[str]:
    return sorted(_REGISTRY)


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test variant: same family/topology, tiny dims."""
    changes: Dict[str, object] = dict(
        n_layers=min(cfg.n_layers, 4 if cfg.family != "hybrid" else 7),
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
    )
    if cfg.n_experts:
        changes.update(n_experts=4, experts_per_token=min(cfg.experts_per_token, 2))
    if cfg.ssm_state:
        changes.update(ssm_state=16, ssm_head_dim=32)
    if cfg.shared_attn_every:
        changes.update(shared_attn_every=3, n_shared_attn_blocks=2)
    if cfg.slstm_every:
        changes.update(slstm_every=2)
    if cfg.n_encoder_layers:
        changes.update(n_encoder_layers=2)
    if cfg.family == "conv":
        changes = dict(conv_stages=(1, 1), conv_width=16, num_classes=10,
                       image_size=32, n_layers=2, d_model=0, n_heads=0,
                       n_kv_heads=0, head_dim=0, d_ff=0, vocab_size=0)
    if cfg.vision is not None:
        changes["vision"] = VisionFrontend(num_patches=16, patch_dim=64)
    if cfg.audio is not None:
        changes["audio"] = AudioFrontend(num_frames=32, frame_dim=16)
    if cfg.sliding_window:
        changes["sliding_window"] = 64
    return dataclasses.replace(cfg, **changes)
