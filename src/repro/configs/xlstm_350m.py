"""xLSTM-350M — sLSTM + mLSTM blocks, 7:1 ratio [arXiv:2405.04517].

d_ff=0 per spec: xLSTM blocks carry their own up/down projections
(mLSTM proj factor 2, sLSTM gated-FFN factor 4/3); there is no separate
transformer FFN.
"""
from repro.configs.base import ModelConfig, register


@register("xlstm-350m")
def xlstm_350m() -> ModelConfig:
    return ModelConfig(
        name="xlstm-350m",
        family="ssm",
        n_layers=24,
        d_model=1024,
        n_heads=4,
        n_kv_heads=4,
        head_dim=256,
        d_ff=0,
        vocab_size=50304,
        norm="layernorm",
        pos_embedding="none",
        slstm_every=8,  # ~7:1 mLSTM:sLSTM
        mlstm_proj_factor=2.0,
        slstm_proj_factor=4.0 / 3.0,
        source="arXiv:2405.04517; unverified",
    )
