"""Llama-4-Maverick-400B-A17B — MoE 128 experts top-1, early fusion.

MoE layers alternate with dense layers (interleave step 2, matching the
400B-total / 17B-active budget) and each MoE layer adds a shared expert,
per the Llama-4 architecture. [hf:meta-llama/Llama-4-Scout-17B-16E;
unverified]
"""
from repro.configs.base import ModelConfig, register


@register("llama4-maverick-400b-a17b")
def llama4_maverick() -> ModelConfig:
    return ModelConfig(
        name="llama4-maverick-400b-a17b",
        family="moe",
        n_layers=48,
        d_model=5120,
        n_heads=40,
        n_kv_heads=8,
        head_dim=128,
        d_ff=8192,
        vocab_size=202048,
        norm="rmsnorm",
        rope_theta=500000.0,
        n_experts=128,
        experts_per_token=1,
        moe_layer_every=2,
        n_shared_experts=1,
        source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
    )
