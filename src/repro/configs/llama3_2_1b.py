"""Llama-3.2-1B — small llama3 GQA [hf:meta-llama/Llama-3.2-1B; unverified]."""
from repro.configs.base import ModelConfig, register


@register("llama3.2-1b")
def llama3_2_1b() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-1b",
        family="dense",
        n_layers=16,
        d_model=2048,
        n_heads=32,
        n_kv_heads=8,
        head_dim=64,
        d_ff=8192,
        vocab_size=128256,
        tie_embeddings=True,
        norm="rmsnorm",
        rope_theta=500000.0,
        source="hf:meta-llama/Llama-3.2-1B; unverified",
    )
