"""Mixtral-8x7B — 8 experts top-2 MoE, sliding-window attention
[arXiv:2401.04088; hf]."""
from repro.configs.base import ModelConfig, register


@register("mixtral-8x7b")
def mixtral_8x7b() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        n_layers=32,
        d_model=4096,
        n_heads=32,
        n_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        norm="rmsnorm",
        rope_theta=1_000_000.0,
        sliding_window=4096,
        n_experts=8,
        experts_per_token=2,
        moe_layer_every=1,
        source="arXiv:2401.04088; hf",
    )
