"""Phi-3-vision-4.2B — phi3-mini backbone + CLIP patch frontend (STUB).

Per assignment spec the modality frontend is a stub: ``input_specs()``
supplies precomputed patch embeddings; the projector + LM backbone are
real. [hf:microsoft/Phi-3-vision-128k-instruct; hf]
"""
from repro.configs.base import ModelConfig, VisionFrontend, register


@register("phi-3-vision-4.2b")
def phi_3_vision() -> ModelConfig:
    return ModelConfig(
        name="phi-3-vision-4.2b",
        family="vlm",
        n_layers=32,
        d_model=3072,
        n_heads=32,
        n_kv_heads=32,  # MHA
        head_dim=96,
        d_ff=8192,
        vocab_size=32064,
        norm="rmsnorm",
        rope_theta=10000.0,
        vision=VisionFrontend(num_patches=576, patch_dim=1024),
        source="hf:microsoft/Phi-3-vision-128k-instruct; hf",
    )
