"""Fused input kernel: augment + normalize + cast in one device pass.

DESIGN.md §15. The host feed ships raw uint8/f32 pixels; this kernel
performs the whole per-sample input transform on device in a single
VMEM-resident pass per image:

  train: horizontal flip (Bernoulli) -> cyclic translation by
         (dy, dx) in [-max_shift, max_shift] (the crop proxy: synthetic
         templates are translation-structured, so a cyclic shift plays
         the role random-resized-crop plays on real JPEGs)
         -> per-channel ``(x - mean) * inv_std`` -> cast to compute dtype
  eval:  normalize + cast only (no augmentation), matching the
         deterministic center-crop eval convention.

Unfused, these are three+ HBM round-trips (flip, roll, normalize/cast)
over the largest tensor a ResNet step touches (B*224*224*3); fused they
are one read + one write at the *compute* dtype, which also halves the
H2D-adjacent HBM traffic when compute_dtype is bf16.

Determinism: augmentation parameters are NOT drawn inside the kernel.
They are derived from ``(seed, step)`` via the counter-based threefry
stream in ops.input_augment_params — identical whether evaluated eagerly
on host (the AugmentedSource reference path) or traced on device, so the
fused and host paths consume bitwise-identical parameters and the
transform itself is the only difference under test. Grid is one program
per sample; each program reads its (4,) parameter row.

CPU caveat: on this container the kernel runs in Pallas interpret mode
(ops._interpret()); on TPU it compiles. Parity vs ref.input_forward is
pinned in tests/test_fused_input.py for {f32, bf16} x {train, eval}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _train_kernel(params_ref, mean_ref, inv_ref, x_ref, o_ref):
    x = x_ref[0].astype(jnp.float32)  # (H, W, C)
    p = params_ref[0]  # (4,) int32: [flip, dy, dx, reserved]
    flipped = jnp.where(p[0] > 0, x[:, ::-1, :], x)
    shifted = jnp.roll(flipped, (p[1], p[2]), axis=(0, 1))
    y = (shifted - mean_ref[0]) * inv_ref[0]
    o_ref[0] = y.astype(o_ref.dtype)


def _eval_kernel(mean_ref, inv_ref, x_ref, o_ref):
    x = x_ref[0].astype(jnp.float32)
    y = (x - mean_ref[0]) * inv_ref[0]
    o_ref[0] = y.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def fused_input_train(x, params, mean, inv_std, *, out_dtype,
                      interpret=False):
    """(B, H, W, C) raw pixels -> augmented/normalized ``out_dtype``.

    ``params`` is (B, 4) int32 from ops.input_augment_params; ``mean``
    and ``inv_std`` are (C,) f32 (inv_std precomputed so the kernel is
    multiply-only on the hot path)."""
    b, h, w, c = x.shape
    mean = jnp.broadcast_to(mean.astype(jnp.float32), (1, c))
    inv_std = jnp.broadcast_to(inv_std.astype(jnp.float32), (1, c))
    return pl.pallas_call(
        _train_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, 4), lambda i: (i, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, w, c), out_dtype),
        interpret=interpret,
    )(params.astype(jnp.int32), mean, inv_std, x)


@functools.partial(jax.jit, static_argnames=("out_dtype", "interpret"))
def fused_input_eval(x, mean, inv_std, *, out_dtype, interpret=False):
    """Eval variant: per-channel normalize + cast, no augmentation."""
    b, h, w, c = x.shape
    mean = jnp.broadcast_to(mean.astype(jnp.float32), (1, c))
    inv_std = jnp.broadcast_to(inv_std.astype(jnp.float32), (1, c))
    return pl.pallas_call(
        _eval_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((1, c), lambda i: (0, 0)),
            pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, w, c), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, w, c), out_dtype),
        interpret=interpret,
    )(mean, inv_std, x)
