"""Pallas TPU kernels for the perf-critical hot spots:
  fused_update.py      paper's hybrid optimizer, one HBM pass (A.1)
  flash_attention.py   tiled online-softmax attention (GQA/causal/SWA)
ops.py has the jit'd wrappers; ref.py the pure-jnp oracles.
"""
from repro.kernels import ops, ref  # noqa: F401
