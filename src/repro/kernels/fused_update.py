"""Pallas TPU kernel: fused RMSprop-warm-up hybrid update (paper A.1).

The update reads 4 streams (g, theta, Delta, m) and writes 3 — pure
elementwise, so it is HBM-bandwidth-bound. Unfused, XLA may materialize
m_new and the coefficient as separate HBM round-trips; the kernel does the
whole update in one pass per VMEM tile.

Tiling: params are flattened and reshaped to (rows, 128) — the last dim
matches the VPU lane width; BLOCK_ROWS x 128 fp32 tiles keep the 7
resident streams under ~2 MB of VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 512  # 512*128*4B = 256 KiB per stream; 7 streams ~ 1.8 MiB


def _kernel(scalars_ref, g_ref, p_ref, d_ref, m_ref,
            p_out, d_out, m_out, *, mu1, mu2, eps, eta_rmsprop,
            weight_decay):
    eta = scalars_ref[0, 0]
    a_sgd = scalars_ref[0, 1]
    g = g_ref[...]
    p = p_ref[...]
    d = d_ref[...]
    m = m_ref[...]
    if weight_decay:
        g = g + weight_decay * p
    m_new = mu2 * m + (1.0 - mu2) * g * g
    a_rms = (1.0 - a_sgd) * eta_rmsprop / eta
    coef = a_sgd + a_rms / (jnp.sqrt(m_new) + eps)
    d_new = mu1 * d - coef * g
    p_out[...] = p + eta * d_new
    d_out[...] = d_new
    m_out[...] = m_new


def _kernel_wd(scalars_ref, g_ref, p_ref, d_ref, m_ref, wd_ref,
               p_out, d_out, m_out, *, mu1, mu2, eps, eta_rmsprop):
    """Per-element weight-decay variant: the ZeRO packed shard spans
    decayed and no-decay leaves, so wd rides in as a 5th stream (0.0
    where the leaf is exempt) instead of a compile-time scalar."""
    eta = scalars_ref[0, 0]
    a_sgd = scalars_ref[0, 1]
    g = g_ref[...]
    p = p_ref[...]
    d = d_ref[...]
    m = m_ref[...]
    g = g + wd_ref[...] * p
    m_new = mu2 * m + (1.0 - mu2) * g * g
    a_rms = (1.0 - a_sgd) * eta_rmsprop / eta
    coef = a_sgd + a_rms / (jnp.sqrt(m_new) + eps)
    d_new = mu1 * d - coef * g
    p_out[...] = p + eta * d_new
    d_out[...] = d_new
    m_out[...] = m_new


def fused_update_2d(g, p, d, m, scalars, *, mu1, mu2, eps, eta_rmsprop,
                    weight_decay, interpret=True, block_rows=BLOCK_ROWS):
    """g/p/d/m: (rows, 128) fp32; scalars: (1, 2) [eta, alpha_sgd].

    ``weight_decay`` is either a python float (baked into the kernel, the
    per-leaf tree-update path) or a (rows, 128) fp32 array of per-element
    decay factors (the ZeRO packed-shard path, DESIGN.md §9).

    Arbitrary row counts are supported: the streams are zero-padded (m
    with ones, so sqrt/eps stays benign) up to a ``block_rows`` multiple
    and the outputs sliced back — full-width tiles for any parameter
    count instead of degrading to tiny blocks or asserting.
    """
    wd_arr = None if isinstance(weight_decay, (int, float)) \
        else weight_decay
    rows = g.shape[0]
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        zrow = ((0, pad), (0, 0))
        g = jnp.pad(g, zrow)
        p = jnp.pad(p, zrow)
        d = jnp.pad(d, zrow)
        m = jnp.pad(m, zrow, constant_values=1.0)
        if wd_arr is not None:
            wd_arr = jnp.pad(wd_arr, zrow)
    padded_rows = rows + pad
    grid = (padded_rows // block_rows,)
    tile = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((1, 2), lambda i: (0, 0))
    out_shape = [jax.ShapeDtypeStruct((padded_rows, LANES),
                                      jnp.float32)] * 3
    if wd_arr is None:
        kernel = functools.partial(
            _kernel, mu1=mu1, mu2=mu2, eps=eps, eta_rmsprop=eta_rmsprop,
            weight_decay=weight_decay)
        in_specs = [scalar_spec, tile, tile, tile, tile]
        args = (scalars, g, p, d, m)
    else:
        kernel = functools.partial(
            _kernel_wd, mu1=mu1, mu2=mu2, eps=eps,
            eta_rmsprop=eta_rmsprop)
        in_specs = [scalar_spec, tile, tile, tile, tile, tile]
        args = (scalars, g, p, d, m, wd_arr)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[tile, tile, tile],
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    if pad:
        outs = [o[:rows] for o in outs]
    return tuple(outs)


# ---------------------------------------------------------------------------
# Stream-LARS kernels (DESIGN.md §11): per-segment squared norms over the
# packed stream, and the trust-scaled momentum update.
# ---------------------------------------------------------------------------

SEG_BLOCK_ROWS = 8  # one-hot tile (8*128 elems x padded segment count)


def _seg_sq_kernel(g_ref, p_ref, wd_ref, seg_ref, out_ref):
    """Accumulate per-segment sums of p^2 and (g + wd*p)^2 into rows 0/1
    of an (8, n_seg_padded) f32 output block revisited by every grid
    step (rows 2..7 are min-tile padding and stay zero). The per-segment
    scatter is a one-hot matmul: (1, bm*128) @ (bm*128, n_seg)."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    g = g_ref[...]
    p = p_ref[...]
    ge = g + wd_ref[...] * p
    seg = seg_ref[...]
    bm, lanes = seg.shape
    n_seg = out_ref.shape[1]
    onehot = (seg.reshape(bm * lanes, 1) ==
              jax.lax.broadcasted_iota(jnp.int32, (1, n_seg), 1)
              ).astype(jnp.float32)
    p_row = jnp.dot((p * p).reshape(1, bm * lanes), onehot,
                    preferred_element_type=jnp.float32)
    g_row = jnp.dot((ge * ge).reshape(1, bm * lanes), onehot,
                    preferred_element_type=jnp.float32)
    zeros = jnp.zeros((out_ref.shape[0] - 2, n_seg), jnp.float32)
    out_ref[...] = out_ref[...] + jnp.concatenate([p_row, g_row, zeros], 0)


def seg_sq_partials_2d(g, p, wd, seg, n_seg_padded, *, interpret=True,
                       block_rows=SEG_BLOCK_ROWS):
    """g/p/wd: (rows, 128) fp32; seg: (rows, 128) int32 segment ids.
    Returns (2, n_seg_padded) f32: per-segment sums of [p^2, (g+wd*p)^2].

    ``n_seg_padded`` must be a lane multiple (the wrapper in
    kernels/ops.py pads and slices). Row padding points the pad elements
    at segment ``n_seg_padded - 1`` with zero values — an exact +0.0."""
    rows = g.shape[0]
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        zrow = ((0, pad), (0, 0))
        g = jnp.pad(g, zrow)
        p = jnp.pad(p, zrow)
        wd = jnp.pad(wd, zrow)
        seg = jnp.pad(seg, zrow, constant_values=n_seg_padded - 1)
    padded_rows = rows + pad
    grid = (padded_rows // block_rows,)
    tile = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    out = pl.pallas_call(
        _seg_sq_kernel,
        grid=grid,
        in_specs=[tile, tile, tile, tile],
        out_specs=pl.BlockSpec((8, n_seg_padded), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((8, n_seg_padded), jnp.float32),
        interpret=interpret,
    )(g, p, wd, seg)
    return out[:2]


def _lars_update_kernel(scalars_ref, trust_ref, g_ref, p_ref, d_ref,
                        wd_ref, seg_ref, p_out, d_out, *, mu1):
    """Trust-scaled momentum step. Per-element trust is looked up from
    the (1, n_seg) trust row by an exact one-hot dot — a single 1.0
    coefficient plus zeros, so the gather adds no rounding."""
    eta = scalars_ref[0, 0]
    g = g_ref[...]
    p = p_ref[...]
    d = d_ref[...]
    ge = g + wd_ref[...] * p
    seg = seg_ref[...]
    bm, lanes = seg.shape
    n_seg = trust_ref.shape[1]
    onehot = (seg.reshape(bm * lanes, 1) ==
              jax.lax.broadcasted_iota(jnp.int32, (1, n_seg), 1)
              ).astype(jnp.float32)
    t = jnp.dot(onehot, trust_ref[...].reshape(n_seg, 1),
                preferred_element_type=jnp.float32).reshape(bm, lanes)
    d_new = mu1 * d - t * ge
    p_out[...] = p + eta * d_new
    d_out[...] = d_new


def lars_update_2d(g, p, d, wd, seg, trust_row, scalars, *, mu1,
                   interpret=True, block_rows=SEG_BLOCK_ROWS):
    """g/p/d/wd: (rows, 128) fp32; seg: (rows, 128) int32; trust_row:
    (1, n_seg_padded) fp32 (1.0 in the padding columns); scalars: (1, 2)
    [eta, unused]. Returns (p', d')."""
    rows = g.shape[0]
    n_seg = trust_row.shape[1]
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        zrow = ((0, pad), (0, 0))
        g = jnp.pad(g, zrow)
        p = jnp.pad(p, zrow)
        d = jnp.pad(d, zrow)
        wd = jnp.pad(wd, zrow)
        seg = jnp.pad(seg, zrow, constant_values=n_seg - 1)
    padded_rows = rows + pad
    grid = (padded_rows // block_rows,)
    tile = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    outs = pl.pallas_call(
        functools.partial(_lars_update_kernel, mu1=mu1),
        grid=grid,
        in_specs=[pl.BlockSpec((1, 2), lambda i: (0, 0)),
                  pl.BlockSpec((1, n_seg), lambda i: (0, 0)),
                  tile, tile, tile, tile, tile],
        out_specs=[tile, tile],
        out_shape=[jax.ShapeDtypeStruct((padded_rows, LANES),
                                        jnp.float32)] * 2,
        interpret=interpret,
    )(scalars, trust_row, g, p, d, wd, seg)
    if pad:
        outs = [o[:rows] for o in outs]
    return tuple(outs)
