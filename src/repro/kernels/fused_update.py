"""Pallas TPU kernel: fused RMSprop-warm-up hybrid update (paper A.1).

The update reads 4 streams (g, theta, Delta, m) and writes 3 — pure
elementwise, so it is HBM-bandwidth-bound. Unfused, XLA may materialize
m_new and the coefficient as separate HBM round-trips; the kernel does the
whole update in one pass per VMEM tile.

Tiling: params are flattened and reshaped to (rows, 128) — the last dim
matches the VPU lane width; BLOCK_ROWS x 128 fp32 tiles keep the 7
resident streams under ~2 MB of VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 512  # 512*128*4B = 256 KiB per stream; 7 streams ~ 1.8 MiB


def _kernel(scalars_ref, g_ref, p_ref, d_ref, m_ref,
            p_out, d_out, m_out, *, mu1, mu2, eps, eta_rmsprop,
            weight_decay):
    eta = scalars_ref[0, 0]
    a_sgd = scalars_ref[0, 1]
    g = g_ref[...]
    p = p_ref[...]
    d = d_ref[...]
    m = m_ref[...]
    if weight_decay:
        g = g + weight_decay * p
    m_new = mu2 * m + (1.0 - mu2) * g * g
    a_rms = (1.0 - a_sgd) * eta_rmsprop / eta
    coef = a_sgd + a_rms / (jnp.sqrt(m_new) + eps)
    d_new = mu1 * d - coef * g
    p_out[...] = p + eta * d_new
    d_out[...] = d_new
    m_out[...] = m_new


def _kernel_wd(scalars_ref, g_ref, p_ref, d_ref, m_ref, wd_ref,
               p_out, d_out, m_out, *, mu1, mu2, eps, eta_rmsprop):
    """Per-element weight-decay variant: the ZeRO packed shard spans
    decayed and no-decay leaves, so wd rides in as a 5th stream (0.0
    where the leaf is exempt) instead of a compile-time scalar."""
    eta = scalars_ref[0, 0]
    a_sgd = scalars_ref[0, 1]
    g = g_ref[...]
    p = p_ref[...]
    d = d_ref[...]
    m = m_ref[...]
    g = g + wd_ref[...] * p
    m_new = mu2 * m + (1.0 - mu2) * g * g
    a_rms = (1.0 - a_sgd) * eta_rmsprop / eta
    coef = a_sgd + a_rms / (jnp.sqrt(m_new) + eps)
    d_new = mu1 * d - coef * g
    p_out[...] = p + eta * d_new
    d_out[...] = d_new
    m_out[...] = m_new


def fused_update_2d(g, p, d, m, scalars, *, mu1, mu2, eps, eta_rmsprop,
                    weight_decay, interpret=True, block_rows=BLOCK_ROWS):
    """g/p/d/m: (rows, 128) fp32; scalars: (1, 2) [eta, alpha_sgd].

    ``weight_decay`` is either a python float (baked into the kernel, the
    per-leaf tree-update path) or a (rows, 128) fp32 array of per-element
    decay factors (the ZeRO packed-shard path, DESIGN.md §9).

    Arbitrary row counts are supported: the streams are zero-padded (m
    with ones, so sqrt/eps stays benign) up to a ``block_rows`` multiple
    and the outputs sliced back — full-width tiles for any parameter
    count instead of degrading to tiny blocks or asserting.
    """
    wd_arr = None if isinstance(weight_decay, (int, float)) \
        else weight_decay
    rows = g.shape[0]
    block_rows = min(block_rows, rows)
    pad = (-rows) % block_rows
    if pad:
        zrow = ((0, pad), (0, 0))
        g = jnp.pad(g, zrow)
        p = jnp.pad(p, zrow)
        d = jnp.pad(d, zrow)
        m = jnp.pad(m, zrow, constant_values=1.0)
        if wd_arr is not None:
            wd_arr = jnp.pad(wd_arr, zrow)
    padded_rows = rows + pad
    grid = (padded_rows // block_rows,)
    tile = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    scalar_spec = pl.BlockSpec((1, 2), lambda i: (0, 0))
    out_shape = [jax.ShapeDtypeStruct((padded_rows, LANES),
                                      jnp.float32)] * 3
    if wd_arr is None:
        kernel = functools.partial(
            _kernel, mu1=mu1, mu2=mu2, eps=eps, eta_rmsprop=eta_rmsprop,
            weight_decay=weight_decay)
        in_specs = [scalar_spec, tile, tile, tile, tile]
        args = (scalars, g, p, d, m)
    else:
        kernel = functools.partial(
            _kernel_wd, mu1=mu1, mu2=mu2, eps=eps,
            eta_rmsprop=eta_rmsprop)
        in_specs = [scalar_spec, tile, tile, tile, tile, tile]
        args = (scalars, g, p, d, m, wd_arr)
    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=[tile, tile, tile],
        out_shape=out_shape,
        interpret=interpret,
    )(*args)
    if pad:
        outs = [o[:rows] for o in outs]
    return tuple(outs)
