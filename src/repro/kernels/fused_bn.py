"""Pallas TPU kernel family: fused batch norm for the paper's §2 BN
variant (no moving averages) — the ResNet-50 per-step hot path.

Unfused, every BN site is three+ passes over an activation-sized tensor:
the ``bn_batch_stats`` reduction, the ``bn_apply_stats`` normalize, the
``jax.nn.relu`` (and for the block-output sites a residual add) — the
classic memory-bound term of conv nets, and a first-order cost at the
paper's 8k-32k batches (Goyal et al., You et al.; PAPERS.md). Fused:

  forward   one reduction pass emits per-channel sum and block-centered
            second moment (fp32 accumulation, Chan combine across
            blocks, C on the lane dim — the same cancellation-free
            variance as bn_batch_stats), then one normalize pass folds
            scale/bias and the optional ReLU and residual-add epilogue
            into the single output write.
  backward  a ``jax.custom_vjp`` replaces XLA's multi-kernel AD chain:
            one dy+x-hat reduction pass produces S1 = sum(dy_masked)
            and S2 = sum(dy_masked * x_hat) — which ARE dbias/dscale —
            and one elementwise pass emits
            dx = gamma*rstd * (dy_m - S1/m - x_hat * S2/m)
            with the ReLU mask (recovered from the saved output) and
            the residual gradient (dres = dy_m) folded in.

Cross-replica (sync-BN) composes exactly as ``core.batchnorm``: the
kernel emits *local* moments, the wrapper ``pmean``s them over the DP
axes (the moment-correct E[x^2] combine), and the backward ``psum``s
S1/S2 and scales by the global count — the textbook sync-BN VJP, equal
to autodiff of the pmean'd jnp path (DESIGN.md §10).

The pure-jnp path in ``core/batchnorm.py`` stays the oracle; the
analytic reference fwd/bwd lives in ``kernels/ref.py``. On TPU the
kernels run compiled with ``ROW_BLOCK`` tiles; on CPU they run in
interpret mode with a single whole-array block (grid tracing cost, not
VMEM, is the binding constraint there) — how this container validates
them (tests/test_fused_bn.py).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 256  # rows x C fp32 tiles; C <= 2048 keeps ~2 MB in VMEM


# ---------------------------------------------------------------------------
# kernels (row-blocked over a (rows, C) view; C on the lane dim)
# ---------------------------------------------------------------------------


def _stats_kernel(x_ref, s_ref, q_ref, *, n_rows, rb):
    """One-pass per-channel sum and **centered** second moment
    M2 = sum((x - mu)^2), fp32 accumulation: each block computes its sum
    and its moment about the block mean, and grid steps merge via
    Chan's parallel-variance combine into the (1, C) accumulators
    (init on step 0). Centered-per-block keeps the E[x^2] - mu^2
    cancellation out of the kernel — the same fix bn_batch_stats got —
    at zero extra HBM traffic (the block is already VMEM-resident).
    Zero-padded tail rows (block index >= ``n_rows``) are masked out of
    both moments."""
    i = pl.program_id(0)
    x = x_ref[...].astype(jnp.float32)
    ridx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0) + i * rb
    valid = ridx < n_rows
    x = jnp.where(valid, x, 0.0)
    bn = jnp.clip(n_rows - i * rb, 1, rb).astype(jnp.float32)
    bsum = jnp.sum(x, axis=0, keepdims=True)
    bmean = bsum / bn
    d = jnp.where(valid, x - bmean, 0.0)
    bm2 = jnp.sum(d * d, axis=0, keepdims=True)

    @pl.when(i == 0)
    def _init():
        s_ref[...] = bsum
        q_ref[...] = bm2

    @pl.when(i > 0)
    def _acc():
        n_prev = (i * rb) * 1.0  # every earlier block is full
        s_prev = s_ref[...]
        delta = s_prev / n_prev - bmean
        q_ref[...] += bm2 + (delta * delta) * (n_prev * bn
                                               / (n_prev + bn))
        s_ref[...] += bsum


def _apply_kernel(x_ref, a_ref, o_ref, y_ref, *, relu):
    """Normalize + epilogue: y = epi(x * a + o), a = rstd*scale (fp32),
    o = bias - mean*a. One activation read, one write."""
    y = x_ref[...].astype(jnp.float32) * a_ref[...] + o_ref[...]
    if relu:
        y = jnp.maximum(y, 0.0)
    y_ref[...] = y.astype(y_ref.dtype)


def _apply_res_kernel(x_ref, r_ref, a_ref, o_ref, y_ref, *, relu):
    """Residual-add epilogue variant (the ResNet block-output sites)."""
    y = x_ref[...].astype(jnp.float32) * a_ref[...] + o_ref[...] \
        + r_ref[...].astype(jnp.float32)
    if relu:
        y = jnp.maximum(y, 0.0)
    y_ref[...] = y.astype(y_ref.dtype)


def _bwd_sums_kernel(dy_ref, x_ref, y_ref, mu_ref, rstd_ref,
                     s1_ref, s2_ref, *, relu):
    """The single backward reduction pass: S1 = sum(dy_m),
    S2 = sum(dy_m * x_hat), with the ReLU mask recovered from the saved
    forward output (y > 0). These are dbias and dscale directly."""
    i = pl.program_id(0)
    dy = dy_ref[...].astype(jnp.float32)
    if relu:
        dy = jnp.where(y_ref[...] > 0, dy, 0.0)
    xhat = (x_ref[...].astype(jnp.float32) - mu_ref[...]) * rstd_ref[...]
    s1 = jnp.sum(dy, axis=0, keepdims=True)
    s2 = jnp.sum(dy * xhat, axis=0, keepdims=True)

    @pl.when(i == 0)
    def _init():
        s1_ref[...] = s1
        s2_ref[...] = s2

    @pl.when(i > 0)
    def _acc():
        s1_ref[...] += s1
        s2_ref[...] += s2


def _bwd_dx_kernel(dy_ref, x_ref, y_ref, mu_ref, rstd_ref,
                   a_ref, b_ref, c_ref, dx_ref, *, relu):
    """The single backward elementwise pass:
    dx = A*dy_m - B - x_hat*C with per-channel A = gamma*rstd,
    B = A*S1/m (- stats-cotangent terms), C = A*S2/m (- dvar term).
    The eval (given-stats) variant is the same kernel with B = C = 0."""
    dy = dy_ref[...].astype(jnp.float32)
    if relu:
        dy = jnp.where(y_ref[...] > 0, dy, 0.0)
    xhat = (x_ref[...].astype(jnp.float32) - mu_ref[...]) * rstd_ref[...]
    dx = a_ref[...] * dy - b_ref[...] - xhat * c_ref[...]
    dx_ref[...] = dx.astype(dx_ref.dtype)


def _bwd_dx_res_kernel(dy_ref, x_ref, y_ref, mu_ref, rstd_ref,
                       a_ref, b_ref, c_ref, dx_ref, dr_ref, *, relu):
    """dx pass with the residual gradient folded in (dres = dy_m) —
    no extra pass for the shortcut branch."""
    dy = dy_ref[...].astype(jnp.float32)
    if relu:
        dy = jnp.where(y_ref[...] > 0, dy, 0.0)
    xhat = (x_ref[...].astype(jnp.float32) - mu_ref[...]) * rstd_ref[...]
    dx = a_ref[...] * dy - b_ref[...] - xhat * c_ref[...]
    dx_ref[...] = dx.astype(dx_ref.dtype)
    dr_ref[...] = dy.astype(dr_ref.dtype)


# ---------------------------------------------------------------------------
# (rows, C) plumbing
# ---------------------------------------------------------------------------


def _row_view(x, row_block: Optional[int]) -> Tuple[jax.Array, int, int]:
    """(..., C) -> zero-padded (rows_padded, C); returns (x2d, rows, rb).

    ``row_block=None`` (the default off-TPU) uses one whole-array block:
    in interpret mode the grid is traced in Python, so a single block is
    both the cheapest and the exact semantics; compiled TPU runs block
    by ``ROW_BLOCK`` to bound VMEM."""
    c = x.shape[-1]
    rows = x.size // c
    x2 = x.reshape(rows, c)
    rb = rows if row_block is None else min(row_block, rows)
    pad = (-rows) % rb
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    return x2, rows, rb


def _blocked(kernel, n_in: int, n_out: int, rb: int, rows_p: int, c: int,
             out_dtypes, interpret: bool, per_channel_in: int = 0):
    """pallas_call builder: ``n_in`` (rows, C) streams + ``per_channel_in``
    (1, C) broadcast inputs -> ``n_out`` outputs ((1, C) accumulators for
    reduction kernels, (rows, C) streams otherwise)."""
    grid = (rows_p // rb,)
    row_spec = pl.BlockSpec((rb, c), lambda i: (i, 0))
    ch_spec = pl.BlockSpec((1, c), lambda i: (0, 0))
    in_specs = [row_spec] * n_in + [ch_spec] * per_channel_in
    out_specs = []
    out_shape = []
    for dt, shape in out_dtypes:
        if shape == "channel":
            out_specs.append(ch_spec)
            out_shape.append(jax.ShapeDtypeStruct((1, c), dt))
        else:
            out_specs.append(row_spec)
            out_shape.append(jax.ShapeDtypeStruct((rows_p, c), dt))
    if n_out == 1:
        out_specs, out_shape = out_specs[0], out_shape[0]
    return pl.pallas_call(kernel, grid=grid, in_specs=in_specs,
                          out_specs=out_specs, out_shape=out_shape,
                          interpret=interpret)


def _moments_2d(x2, n_rows, rb, interpret):
    """Returns per-channel (sum, centered M2) over the ``n_rows`` true
    rows of the padded (rows_p, C) view."""
    rows_p, c = x2.shape
    kernel = functools.partial(_stats_kernel, n_rows=n_rows, rb=rb)
    s, q = _blocked(kernel, 1, 2, rb, rows_p, c,
                    [(jnp.float32, "channel")] * 2, interpret)(x2)
    return s[0], q[0]


def _ch(v, c):
    return jnp.asarray(v, jnp.float32).reshape(1, c)


# ---------------------------------------------------------------------------
# custom-VJP entry points
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=None)
def _train_fn(relu: bool, has_res: bool, res_dtype: Optional[str],
              axes: Optional[Tuple[str, ...]], eps: float,
              interpret: bool, row_block: Optional[int]):
    """Cached per static-config custom_vjp function for the train-mode
    (batch-stats) fused BN. Returns f(x, scale, bias[, residual]) ->
    (y, mean, var)."""

    def fwd_impl(x, scale, bias, residual):
        c = x.shape[-1]
        x2, rows, rb = _row_view(x, row_block)
        s, m2 = _moments_2d(x2, rows, rb, interpret)
        m = float(rows)
        mean = s / m
        var = m2 / m  # centered: >= 0 by construction
        if axes:
            # moment-correct sync-BN combine: global mean, then each
            # worker's second moment re-centered about it (Chan again)
            local_mean = mean
            mean = jax.lax.pmean(mean, axes)
            var = jax.lax.pmean(
                var + jnp.square(local_mean - mean), axes)
        rstd = jax.lax.rsqrt(var + eps)
        a = rstd * scale.astype(jnp.float32)
        off = bias.astype(jnp.float32) - mean * a
        if has_res:
            r2, _, _ = _row_view(residual, row_block)
            y2 = _blocked(functools.partial(_apply_res_kernel, relu=relu),
                          2, 1, rb, x2.shape[0], c, [(x.dtype, "rows")],
                          interpret, per_channel_in=2)(
                x2, r2, _ch(a, c), _ch(off, c))
        else:
            y2 = _blocked(functools.partial(_apply_kernel, relu=relu),
                          1, 1, rb, x2.shape[0], c, [(x.dtype, "rows")],
                          interpret, per_channel_in=2)(
                x2, _ch(a, c), _ch(off, c))
        y = y2[:rows].reshape(x.shape)
        return y, mean, var

    def bwd_impl(res, cts):
        x, y, mean, var, scale = res
        dy, dmean_ct, dvar_ct = cts
        c = x.shape[-1]
        x2, rows, rb = _row_view(x, row_block)
        y2, _, _ = _row_view(y, row_block)
        dy2, _, _ = _row_view(dy, row_block)
        rstd = jax.lax.rsqrt(var + eps)
        s1, s2 = _blocked(
            functools.partial(_bwd_sums_kernel, relu=relu), 3, 2, rb,
            x2.shape[0], c, [(jnp.float32, "channel")] * 2, interpret,
            per_channel_in=2)(dy2, x2, y2, _ch(mean, c), _ch(rstd, c))
        s1, s2 = s1[0], s2[0]
        m = float(rows)
        if axes:
            # global sums / count: the textbook sync-BN backward, equal
            # to autodiff through the pmean'd statistics
            n = jax.lax.psum(jnp.ones((), jnp.float32), axes)
            big_m = m * n
            s1g = jax.lax.psum(s1, axes)
            s2g = jax.lax.psum(s2, axes)
            dm = jax.lax.psum(dmean_ct, axes)
            dv = jax.lax.psum(dvar_ct, axes)
        else:
            big_m = m
            s1g, s2g, dm, dv = s1, s2, dmean_ct, dvar_ct
        g32 = scale.astype(jnp.float32)
        a_coef = g32 * rstd
        # stats-output cotangents (zero in the training step, where the
        # new BN state is value_and_grad aux) fold into the same two
        # per-channel offsets: dmean adds dm/M, dvar adds
        # 2*dv*(x-mu)/M = (2*dv/(M*rstd)) * x_hat
        b_coef = a_coef * s1g / big_m - dm / big_m
        c_coef = a_coef * s2g / big_m - 2.0 * dv / (big_m * rstd)
        ch = [_ch(mean, c), _ch(rstd, c), _ch(a_coef, c), _ch(b_coef, c),
              _ch(c_coef, c)]
        if has_res:
            dx2, dr2 = _blocked(
                functools.partial(_bwd_dx_res_kernel, relu=relu), 3, 2,
                rb, x2.shape[0], c,
                [(x.dtype, "rows"), (jnp.dtype(res_dtype), "rows")],
                interpret, per_channel_in=5)(dy2, x2, y2, *ch)
            dres = dr2[:rows].reshape(x.shape)
        else:
            dx2 = _blocked(
                functools.partial(_bwd_dx_kernel, relu=relu), 3, 1, rb,
                x2.shape[0], c, [(x.dtype, "rows")], interpret,
                per_channel_in=5)(dy2, x2, y2, *ch)
            dres = None
        dx = dx2[:rows].reshape(x.shape)
        dscale = s2.astype(scale.dtype)  # local sums: DP sync happens
        dbias = s1.astype(scale.dtype)   # downstream, like any leaf grad
        return dx, dscale, dbias, dres

    if has_res:
        @jax.custom_vjp
        def fused(x, scale, bias, residual):
            return fwd_impl(x, scale, bias, residual)

        def fused_fwd(x, scale, bias, residual):
            out = fwd_impl(x, scale, bias, residual)
            y, mean, var = out
            return out, (x, y, mean, var, scale)

        def fused_bwd(res, cts):
            return bwd_impl(res, cts)
    else:
        @jax.custom_vjp
        def fused(x, scale, bias):
            return fwd_impl(x, scale, bias, None)

        def fused_fwd(x, scale, bias):
            out = fwd_impl(x, scale, bias, None)
            y, mean, var = out
            return out, (x, y, mean, var, scale)

        def fused_bwd(res, cts):
            return bwd_impl(res, cts)[:3]

    fused.defvjp(fused_fwd, fused_bwd)
    return fused


@functools.lru_cache(maxsize=None)
def _apply_fn(relu: bool, has_res: bool, res_dtype: Optional[str],
              eps: float, interpret: bool, row_block: Optional[int]):
    """Given-stats (eval / finalized-statistics) fused BN:
    f(x, mean, var, scale, bias[, residual]) -> y, with full cotangents
    for mean/var so the op stays differentiable everywhere."""

    def fwd_impl(x, mean, var, scale, bias, residual):
        c = x.shape[-1]
        x2, rows, rb = _row_view(x, row_block)
        rstd = jax.lax.rsqrt(var.astype(jnp.float32) + eps)
        a = rstd * scale.astype(jnp.float32)
        off = bias.astype(jnp.float32) - mean.astype(jnp.float32) * a
        if has_res:
            r2, _, _ = _row_view(residual, row_block)
            y2 = _blocked(functools.partial(_apply_res_kernel, relu=relu),
                          2, 1, rb, x2.shape[0], c, [(x.dtype, "rows")],
                          interpret, per_channel_in=2)(
                x2, r2, _ch(a, c), _ch(off, c))
        else:
            y2 = _blocked(functools.partial(_apply_kernel, relu=relu),
                          1, 1, rb, x2.shape[0], c, [(x.dtype, "rows")],
                          interpret, per_channel_in=2)(
                x2, _ch(a, c), _ch(off, c))
        return y2[:rows].reshape(x.shape)

    def bwd_impl(res, dy):
        x, y, mean, var, scale = res
        c = x.shape[-1]
        x2, rows, rb = _row_view(x, row_block)
        y2, _, _ = _row_view(y, row_block)
        dy2, _, _ = _row_view(dy, row_block)
        mean32 = mean.astype(jnp.float32)
        rstd = jax.lax.rsqrt(var.astype(jnp.float32) + eps)
        s1, s2 = _blocked(
            functools.partial(_bwd_sums_kernel, relu=relu), 3, 2, rb,
            x2.shape[0], c, [(jnp.float32, "channel")] * 2, interpret,
            per_channel_in=2)(dy2, x2, y2, _ch(mean32, c), _ch(rstd, c))
        s1, s2 = s1[0], s2[0]
        g32 = scale.astype(jnp.float32)
        a_coef = g32 * rstd
        zero = jnp.zeros_like(a_coef)
        ch = [_ch(mean32, c), _ch(rstd, c), _ch(a_coef, c), _ch(zero, c),
              _ch(zero, c)]
        if has_res:
            dx2, dr2 = _blocked(
                functools.partial(_bwd_dx_res_kernel, relu=relu), 3, 2,
                rb, x2.shape[0], c,
                [(x.dtype, "rows"), (jnp.dtype(res_dtype), "rows")],
                interpret, per_channel_in=5)(dy2, x2, y2, *ch)
            dres = dr2[:rows].reshape(x.shape)
        else:
            dx2 = _blocked(
                functools.partial(_bwd_dx_kernel, relu=relu), 3, 1, rb,
                x2.shape[0], c, [(x.dtype, "rows")], interpret,
                per_channel_in=5)(dy2, x2, y2, *ch)
            dres = None
        dx = dx2[:rows].reshape(x.shape)
        dmean = (-a_coef * s1).astype(mean.dtype)
        dvar = (-0.5 * g32 * jnp.square(rstd) * s2).astype(var.dtype)
        dscale = s2.astype(scale.dtype)
        dbias = s1.astype(scale.dtype)
        return dx, dmean, dvar, dscale, dbias, dres

    if has_res:
        @jax.custom_vjp
        def fused(x, mean, var, scale, bias, residual):
            return fwd_impl(x, mean, var, scale, bias, residual)

        def fused_fwd(x, mean, var, scale, bias, residual):
            y = fwd_impl(x, mean, var, scale, bias, residual)
            return y, (x, y, mean, var, scale)

        def fused_bwd(res, dy):
            return bwd_impl(res, dy)
    else:
        @jax.custom_vjp
        def fused(x, mean, var, scale, bias):
            return fwd_impl(x, mean, var, scale, bias, None)

        def fused_fwd(x, mean, var, scale, bias):
            y = fwd_impl(x, mean, var, scale, bias, None)
            return y, (x, y, mean, var, scale)

        def fused_bwd(res, dy):
            return bwd_impl(res, dy)[:5]

    fused.defvjp(fused_fwd, fused_bwd)
    return fused


def fused_bn_train(x, scale, bias, *, residual=None, relu: bool = False,
                   eps: float = 1e-5,
                   cross_replica: Optional[Sequence[str]] = None,
                   interpret: bool = True,
                   row_block: Optional[int] = None):
    """Train-mode fused BN: (y, mean, var) from one stats pass + one
    normalize/epilogue pass; fused custom-VJP backward (module
    docstring). ``cross_replica``: DP axis names for sync-BN under
    shard_map (local moments are pmean'd, the backward psums S1/S2).
    ``row_block=None``: single block off-TPU, ``ROW_BLOCK`` tiles when
    compiled."""
    axes = tuple(cross_replica) if cross_replica else None
    if row_block is None and not interpret:
        row_block = ROW_BLOCK
    has_res = residual is not None
    res_dtype = jnp.dtype(residual.dtype).name if has_res else None
    f = _train_fn(bool(relu), has_res, res_dtype, axes, float(eps),
                  bool(interpret), row_block)
    if has_res:
        return f(x, scale, bias, residual)
    return f(x, scale, bias)


def fused_bn_apply(x, mean, var, scale, bias, *, residual=None,
                   relu: bool = False, eps: float = 1e-5,
                   interpret: bool = True,
                   row_block: Optional[int] = None):
    """Given-stats fused BN (eval / finalized statistics): normalize +
    epilogue in one pass, differentiable (full mean/var cotangents)."""
    if row_block is None and not interpret:
        row_block = ROW_BLOCK
    has_res = residual is not None
    res_dtype = jnp.dtype(residual.dtype).name if has_res else None
    f = _apply_fn(bool(relu), has_res, res_dtype, float(eps),
                  bool(interpret), row_block)
    if has_res:
        return f(x, mean, var, scale, bias, residual)
    return f(x, mean, var, scale, bias)
