"""Pallas TPU kernel: fused RMSNorm (fp32 statistics, compute-dtype IO).

§Perf iteration 1 measured the unfused norm's fp32 upcast as ~11% of
ResNet's memory term and a similar share per transformer layer; the
fused kernel reads x once, keeps the fp32 square-sum in VMEM, and writes
one output stream. Tiling: rows x d_model blocks, d padded to the lane
width by the wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROW_BLOCK = 256


def _kernel(x_ref, scale_ref, o_ref, *, eps):
    x = x_ref[...]
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    o_ref[...] = (x32 * inv).astype(x.dtype) * scale_ref[...]


def rmsnorm(x, scale, *, eps: float = 1e-5, interpret: bool = True,
            row_block: int = ROW_BLOCK):
    """x: (..., d); scale: (d,). Returns RMS-normalized x * scale."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = x.size // d
    xr = x.reshape(rows, d)
    rb = min(row_block, rows)
    pad = (-rows) % rb
    if pad:
        xr = jnp.pad(xr, ((0, pad), (0, 0)))
    grid = (xr.shape[0] // rb,)
    out = pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=grid,
        in_specs=[pl.BlockSpec((rb, d), lambda i: (i, 0)),
                  pl.BlockSpec((1, d), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((rb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xr.shape, x.dtype),
        interpret=interpret,
    )(xr, scale.reshape(1, d).astype(x.dtype))
    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)
