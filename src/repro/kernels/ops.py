"""jit'd public wrappers around the Pallas kernels.

On TPU the kernels run compiled; on CPU they run in interpret mode
(Python-executed kernel body) — which is how this container validates
them. The pure-jnp oracles live in ref.py.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import fused_update as _fu

LANES = _fu.LANES


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# fused hybrid optimizer update
# ---------------------------------------------------------------------------


def fused_hybrid_update(g, p, d, m, h, weight_decay=0.0) -> Tuple:
    """Drop-in for core.optimizer.hybrid_update: (theta', delta', m').

    Flattens the leaf to (rows, 128) fp32 tiles, pads the tail, runs the
    one-pass Pallas update, unpads. ``weight_decay`` may be a scalar
    (per-leaf tree update) or an array shaped like the leaf (ZeRO
    packed-shard update with per-element decay, DESIGN.md §9).
    """
    orig_shape = p.shape
    orig_dtype = p.dtype
    n = p.size
    rows = max(1, -(-n // LANES))
    pad = rows * LANES - n

    def flat(x):
        x = x.astype(jnp.float32).reshape(-1)
        if pad:
            x = jnp.concatenate([x, jnp.zeros((pad,), jnp.float32)])
        return x.reshape(rows, LANES)

    scalars = jnp.stack([jnp.asarray(h.eta, jnp.float32),
                         jnp.asarray(h.alpha_sgd, jnp.float32)]).reshape(1, 2)
    if not isinstance(weight_decay, (int, float)):
        weight_decay = flat(weight_decay)
    # fused_update_2d pads the row stream to a block multiple internally,
    # so any row count gets full-width tiles (no divisor search needed)
    p_new, d_new, m_new = _fu.fused_update_2d(
        flat(g), flat(p), flat(d), flat(m), scalars,
        mu1=h.mu1, mu2=h.mu2, eps=h.eps, eta_rmsprop=h.eta_rmsprop,
        weight_decay=weight_decay, interpret=_interpret())

    def unflat(x, dtype):
        return x.reshape(-1)[:n].reshape(orig_shape).astype(dtype)

    return (unflat(p_new, orig_dtype), unflat(d_new, jnp.float32),
            unflat(m_new, jnp.float32))


def _lars_flat(n, rows, pad):
    """(flatten-to-(rows, 128)) helper shared by the stream-LARS wrappers
    below; mirrors fused_hybrid_update's tiling."""
    def flat(x, fill=0.0, dtype=jnp.float32):
        x = x.astype(dtype).reshape(-1)
        if pad:
            x = jnp.concatenate(
                [x, jnp.full((pad,), fill, dtype)])
        return x.reshape(rows, LANES)
    return flat


def fused_segment_sq_partials(p, g, wd, seg, num_segments):
    """(2, num_segments) f32 per-segment sums of [p^2, (g+wd*p)^2] over a
    flat stream — the Pallas twin of stacking two
    ``bucketing.segment_sq_partials`` calls (stream-LARS trust norms,
    DESIGN.md §11). The one-hot-matmul fold order differs from
    segment_sum's, so this path is allclose- (not bitwise-) parity
    tested and excluded from the bitwise parity matrix."""
    n = p.size
    rows = max(1, -(-n // LANES))
    pad = rows * LANES - n
    flat = _lars_flat(n, rows, pad)
    n_seg_padded = -(-num_segments // LANES) * LANES
    out = _fu.seg_sq_partials_2d(
        flat(g), flat(p), flat(wd),
        flat(seg, fill=num_segments - 1, dtype=jnp.int32),
        n_seg_padded, interpret=_interpret())
    return out[:, :num_segments]


def fused_lars_update(g, p, d, wd, seg, trust, eta, mu1):
    """(p', d') trust-scaled momentum update on a flat stream: one fused
    pass over 5 streams with the per-segment trust row resident in VMEM
    (stream-LARS fused path, DESIGN.md §11)."""
    orig_dtype = p.dtype
    n = p.size
    rows = max(1, -(-n // LANES))
    pad = rows * LANES - n
    flat = _lars_flat(n, rows, pad)
    num_segments = trust.shape[0]
    n_seg_padded = -(-num_segments // LANES) * LANES
    trust_row = jnp.concatenate(
        [trust.astype(jnp.float32),
         jnp.ones((n_seg_padded - num_segments,), jnp.float32)]
    ).reshape(1, n_seg_padded)
    scalars = jnp.stack([jnp.asarray(eta, jnp.float32),
                         jnp.zeros((), jnp.float32)]).reshape(1, 2)
    p_new, d_new = _fu.lars_update_2d(
        flat(g), flat(p), flat(d), flat(wd),
        flat(seg, fill=n_seg_padded - 1, dtype=jnp.int32),
        trust_row, scalars, mu1=mu1, interpret=_interpret())

    def unflat(x, dtype):
        return x.reshape(-1)[:n].astype(dtype)

    return unflat(p_new, orig_dtype), unflat(d_new, jnp.float32)


# ---------------------------------------------------------------------------
# bucket pack/unpack (bucketed gradient all-reduce, DESIGN.md §6)
# ---------------------------------------------------------------------------


def pack_cast(flat, wire_dtype):
    """Fused cast+copy of a flat fp32 stream to the wire dtype
    (padding-aware). See ref.cast_copy."""
    from repro.kernels import bucket_ops as _bo
    return _bo.pack_cast(flat, wire_dtype, interpret=_interpret())


def unpack_cast(flat, acc_dtype):
    """Inverse of pack_cast: wire stream back to the accumulation dtype."""
    from repro.kernels import bucket_ops as _bo
    return _bo.unpack_cast(flat, acc_dtype, interpret=_interpret())


# ---------------------------------------------------------------------------
# fused batch norm (forward stats+normalize+epilogue, fused VJP)
# ---------------------------------------------------------------------------


def fused_bn_train(x, scale, bias, *, residual=None, relu=False,
                   eps=1e-5, cross_replica=None):
    """Train-mode fused BN: (y, mean, var) in one stats pass + one
    normalize/epilogue pass, with the fused custom-VJP backward
    (DESIGN.md §10). Oracle: core.batchnorm + epilogue (ref.bn_forward /
    ref.bn_backward)."""
    from repro.kernels import fused_bn as _fb
    return _fb.fused_bn_train(x, scale, bias, residual=residual,
                              relu=relu, eps=eps,
                              cross_replica=cross_replica,
                              interpret=_interpret())


def fused_bn_apply(x, mean, var, scale, bias, *, residual=None,
                   relu=False, eps=1e-5):
    """Given-stats fused BN (eval / finalized statistics)."""
    from repro.kernels import fused_bn as _fb
    return _fb.fused_bn_apply(x, mean, var, scale, bias,
                              residual=residual, relu=relu, eps=eps,
                              interpret=_interpret())


# ---------------------------------------------------------------------------
# fused input (augment + normalize + cast, DESIGN.md §15)
# ---------------------------------------------------------------------------


def input_augment_params(seed, step, total, *, max_shift: int = 4):
    """(total, 4) int32 per-sample augmentation parameters
    ``[flip, dy, dx, reserved]`` for ``step``, derived from the
    counter-based threefry stream keyed ``fold_in(PRNGKey(seed), step)``.

    threefry is backend- and trace-invariant, so the host feed workers
    (eager, pipeline.AugmentedSource) and the on-device fused path
    (traced ``step`` inside the train step) draw bitwise-identical
    parameters — but NOT prefix-stable across draw sizes, so ``total``
    must always be the *global* batch; shards slice their rows.
    ``step`` may be a traced scalar."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), step)
    kf, ks = jax.random.split(key)
    flip = jax.random.bernoulli(kf, 0.5, (total,)).astype(jnp.int32)
    shifts = jax.random.randint(ks, (total, 2), -max_shift, max_shift + 1,
                                dtype=jnp.int32)
    zeros = jnp.zeros((total, 1), jnp.int32)
    return jnp.concatenate([flip[:, None], shifts, zeros], axis=1)


def fused_input_train(x, params, mean, inv_std, *, out_dtype):
    """One-pass augment+normalize+cast (train). See ref.input_forward."""
    from repro.kernels import fused_input as _fi
    return _fi.fused_input_train(x, params, mean, inv_std,
                                 out_dtype=out_dtype,
                                 interpret=_interpret())


def fused_input_eval(x, mean, inv_std, *, out_dtype):
    """Normalize+cast only (eval variant)."""
    from repro.kernels import fused_input as _fi
    return _fi.fused_input_eval(x, mean, inv_std, out_dtype=out_dtype,
                                interpret=_interpret())


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------


def attention(q, k, v, *, causal: bool = True, window=None,
              block_q: int = 128, block_k: int = 128):
    """Tiled online-softmax attention (GQA-aware). See ref.attention."""
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               block_q=block_q, block_k=block_k,
                               interpret=_interpret())


# ---------------------------------------------------------------------------
# fused RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm(x, scale, *, eps: float = 1e-5):
    """One-pass RMSNorm (fp32 stats in VMEM). See ref.rmsnorm."""
    from repro.kernels import rmsnorm as _rn
    return _rn.rmsnorm(x, scale, eps=eps, interpret=_interpret())
