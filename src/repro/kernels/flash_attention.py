"""Pallas TPU kernel: tiled online-softmax (flash) attention with GQA,
causal and sliding-window masking.

Grid: (batch, q_heads, Sq/BQ, Sk/BK) — the kv dim is innermost, so the
(m, l, acc) running statistics live in VMEM scratch across kv steps and
the output block is written once on the last kv step (standard TPU
revisiting-grid pattern; MXU-aligned 128x128 tiles).

GQA is handled in the BlockSpec index maps: kv blocks for query head h
come from kv head h // (Hq // Hkv) — no materialized head repetition
(the jnp reference path pays that copy; the kernel does not).

Block-level masking: fully-masked (future / out-of-window) kv blocks are
skipped with pl.when, so causal attention does ~half the work and sliding
windows touch only O(window) tiles per query block.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, bq, bk, n_k):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_lo = iq * bq
    k_lo = ik * bk
    # block-level skip: entire kv block in the future / outside the window
    live = True
    if causal:
        live = k_lo <= q_lo + bq - 1
    if window is not None:
        live = jnp.logical_and(live, q_lo - (k_lo + bk - 1) < window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, dh)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, dh)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bk)
        qpos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, kpos <= qpos)
        if window is not None:
            mask = jnp.logical_and(mask, qpos - kpos < window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention(q, k, v, *, causal=True, window=None,
                    block_q=128, block_k=128, interpret=True):
    """q: (B, Sq, Hq, Dh); k/v: (B, Sk, Hkv, Dh) -> (B, Sq, Hq, Dh).

    Layout inside the kernel is (B, H, S, Dh) for MXU-friendly tiles.
    """
    b, sq, hq, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, sk)
    assert sq % bq == 0 and sk % bk == 0, (sq, bq, sk, bk)
    n_q, n_k = sq // bq, sk // bk
    scale = 1.0 / math.sqrt(dh)

    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    grid = (b, hq, n_q, n_k)
    q_spec = pl.BlockSpec((1, 1, bq, dh), lambda ib, ih, iq, ik: (ib, ih, iq, 0))
    kv_spec = pl.BlockSpec(
        (1, 1, bk, dh), lambda ib, ih, iq, ik: (ib, ih // group, ik, 0))
    o_spec = pl.BlockSpec((1, 1, bq, dh), lambda ib, ih, iq, ik: (ib, ih, iq, 0))

    from jax.experimental.pallas import tpu as pltpu
    scratch = [
        pltpu.VMEM((bq,), jnp.float32),
        pltpu.VMEM((bq,), jnp.float32),
        pltpu.VMEM((bq, dh), jnp.float32),
    ]
    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, bq=bq, bk=bk, n_k=n_k)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=o_spec,
        out_shape=jax.ShapeDtypeStruct((b, hq, sq, dh), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
