"""Pallas TPU kernel pair for bucketed gradient communication
(DESIGN.md §6): fused cast+copy between the fp32 accumulation stream and
the wire-dtype bucket.

Packing a gradient bucket is two logical ops — a dtype cast (fp32 ->
bf16/f16) and a copy into the contiguous bucket buffer. Left to XLA these
can materialize as separate HBM round-trips per leaf; the kernel fuses
them into one pass per VMEM tile, so each bucket element is read once and
written once at the wire width. Unpack is the mirror image (wire -> fp32).

Tiling follows fused_update.py: the flat stream is reshaped to
(rows, 128) — the last dim matches the VPU lane width — and processed in
BLOCK_ROWS x 128 tiles. Padding-awareness lives in the wrappers: an
arbitrary-length stream is zero-padded to a whole number of lanes (and
trimmed after), so odd leaf sizes never reach the kernel.

On TPU the kernels run compiled; on CPU in interpret mode (how this
container validates them). Pure-jnp oracles: ref.cast_copy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
BLOCK_ROWS = 1024  # 1024*128 elems: 512 KiB fp32 + 256 KiB bf16 per tile


def _cast_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].astype(o_ref.dtype)


def cast_copy_2d(x, out_dtype, *, interpret=True, block_rows=BLOCK_ROWS):
    """x: (rows, 128) with rows a multiple of block_rows; returns x cast
    to out_dtype, one fused pass."""
    rows = x.shape[0]
    block_rows = min(block_rows, rows)
    assert rows % block_rows == 0, (rows, block_rows)
    grid = (rows // block_rows,)
    tile_in = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    tile_out = pl.BlockSpec((block_rows, LANES), lambda i: (i, 0))
    return pl.pallas_call(
        _cast_kernel,
        grid=grid,
        in_specs=[tile_in],
        out_specs=tile_out,
        out_shape=jax.ShapeDtypeStruct(x.shape, out_dtype),
        interpret=interpret,
    )(x)


def _to_lanes(flat, block_rows=BLOCK_ROWS):
    """Pad a 1-D stream to a whole (rows, LANES) tile grid whose row
    count divides into block_rows tiles — padding a few extra zero rows
    is far cheaper than the degenerate (1, LANES) grid a prime row
    count would otherwise force."""
    n = flat.shape[0]
    rows = max(1, -(-n // LANES))
    block = min(block_rows, rows)
    rows = -(-rows // block) * block
    pad = rows * LANES - n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(rows, LANES), n


def pack_cast(flat, wire_dtype, *, interpret=True):
    """Fused cast+copy of a 1-D fp32 stream into the wire dtype.

    Padding-aware: any length is accepted; the tail is zero-padded to a
    whole tile grid for the kernel and trimmed from the result.
    """
    x2d, n = _to_lanes(flat)
    out = cast_copy_2d(x2d, wire_dtype, interpret=interpret)
    return out.reshape(-1)[:n]


def unpack_cast(flat, acc_dtype, *, interpret=True):
    """Inverse of pack_cast: wire-dtype stream -> accumulation dtype."""
    x2d, n = _to_lanes(flat)
    out = cast_copy_2d(x2d, acc_dtype, interpret=interpret)
    return out.reshape(-1)[:n]
