"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention(q, k, v, *, causal=True, window=None):
    """Naive full-materialization attention with GQA head repetition."""
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    if hkv != hq:
        k = jnp.repeat(k, hq // hkv, axis=2)
        v = jnp.repeat(v, hq // hkv, axis=2)
    scale = 1.0 / math.sqrt(dh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qi = jnp.arange(sq)[:, None]
    kj = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= qi - kj < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype), v)


def rmsnorm(x, scale, *, eps=1e-5):
    """Pure-jnp RMSNorm oracle (fp32 stats, compute-dtype output)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype)
    return y * scale.astype(x.dtype)


def cast_copy(flat, out_dtype):
    """Pure-jnp oracle for the bucket pack/unpack kernels: a dtype cast
    of the flat stream (the fused kernel's semantics are exactly this;
    fusion only changes where the HBM round-trips happen)."""
    return flat.astype(out_dtype)


def hybrid_update(g, p, d, m, *, eta, alpha_sgd, mu1=0.9, mu2=0.99,
                  eps=1e-8, eta_rmsprop=3e-4, weight_decay=0.0):
    """Paper A.1 update, fp32 (the fused kernel's oracle)."""
    g = g.astype(jnp.float32)
    p32 = p.astype(jnp.float32)
    if weight_decay:
        g = g + weight_decay * p32
    m_new = mu2 * m + (1.0 - mu2) * jnp.square(g)
    a_rms = (1.0 - alpha_sgd) * eta_rmsprop / eta
    coef = alpha_sgd + a_rms / (jnp.sqrt(m_new) + eps)
    d_new = mu1 * d - coef * g
    return p32 + eta * d_new, d_new, m_new
