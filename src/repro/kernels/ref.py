"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention(q, k, v, *, causal=True, window=None):
    """Naive full-materialization attention with GQA head repetition."""
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    if hkv != hq:
        k = jnp.repeat(k, hq // hkv, axis=2)
        v = jnp.repeat(v, hq // hkv, axis=2)
    scale = 1.0 / math.sqrt(dh)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
    qi = jnp.arange(sq)[:, None]
    kj = jnp.arange(k.shape[1])[None, :]
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kj <= qi
    if window is not None:
        mask &= qi - kj < window
    s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(q.dtype), v)


def rmsnorm(x, scale, *, eps=1e-5):
    """Pure-jnp RMSNorm oracle (fp32 stats, compute-dtype output)."""
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype)
    return y * scale.astype(x.dtype)


def cast_copy(flat, out_dtype):
    """Pure-jnp oracle for the bucket pack/unpack kernels: a dtype cast
    of the flat stream (the fused kernel's semantics are exactly this;
    fusion only changes where the HBM round-trips happen)."""
    return flat.astype(out_dtype)


def bn_forward(x, scale, bias, *, residual=None, relu=False, eps=1e-5):
    """Reference for the fused train-mode BN (kernels/fused_bn.py):
    batch stats + normalize + epilogue via the core/batchnorm.py oracle
    path, exactly the unfused ResNet site. Returns (y, mean, var)."""
    from repro.core.batchnorm import bn_apply_stats, bn_batch_stats

    mean, var = bn_batch_stats(x)
    y = bn_apply_stats(x, mean, var, scale, bias, eps=eps)
    if residual is not None:
        y = y + residual
    if relu:
        y = jax.nn.relu(y)
    return y, mean, var


def bn_backward(x, y, mean, var, scale, dy, *, relu=False, eps=1e-5):
    """Analytic train-mode BN backward (the fused VJP's reference):
    given the saved forward residuals and the output cotangent, returns
    (dx, dscale, dbias, dres) from the textbook batch-stats formulas:

        dy_m   = dy * (y > 0)                       (ReLU mask)
        S1     = sum(dy_m), S2 = sum(dy_m * x_hat)  (= dbias, dscale)
        dx     = gamma*rstd * (dy_m - S1/m - x_hat*S2/m)
        dres   = dy_m
    """
    axes = tuple(range(x.ndim - 1))
    m = 1.0
    for a in axes:
        m *= x.shape[a]
    dy32 = dy.astype(jnp.float32)
    if relu:
        dy32 = jnp.where(y > 0, dy32, 0.0)
    rstd = jax.lax.rsqrt(var.astype(jnp.float32) + eps)
    xhat = (x.astype(jnp.float32) - mean.astype(jnp.float32)) * rstd
    s1 = jnp.sum(dy32, axis=axes)
    s2 = jnp.sum(dy32 * xhat, axis=axes)
    dx = (scale.astype(jnp.float32) * rstd
          * (dy32 - s1 / m - xhat * s2 / m)).astype(x.dtype)
    return dx, s2.astype(scale.dtype), s1.astype(scale.dtype), \
        dy32.astype(x.dtype)


def input_forward(x, params, mean, std, *, train, out_dtype):
    """Reference for the fused input kernel (kernels/fused_input.py):
    per-sample flip + cyclic translation (train only) + per-channel
    ``(x - mean) * (1/std)`` + cast, vmapped over the batch. Uses the
    same op order as the kernel (subtract-then-multiply by the
    precomputed reciprocal) so f32 parity is exact."""
    x32 = x.astype(jnp.float32)
    if train:
        def one(img, p):
            img = jnp.where(p[0] > 0, img[:, ::-1, :], img)
            return jnp.roll(img, (p[1], p[2]), axis=(0, 1))
        x32 = jax.vmap(one)(x32, params.astype(jnp.int32))
    mean = jnp.asarray(mean, jnp.float32)
    inv_std = 1.0 / jnp.asarray(std, jnp.float32)
    return ((x32 - mean) * inv_std).astype(out_dtype)


def hybrid_update(g, p, d, m, *, eta, alpha_sgd, mu1=0.9, mu2=0.99,
                  eps=1e-8, eta_rmsprop=3e-4, weight_decay=0.0):
    """Paper A.1 update, fp32 (the fused kernel's oracle)."""
    g = g.astype(jnp.float32)
    p32 = p.astype(jnp.float32)
    if weight_decay:
        g = g + weight_decay * p32
    m_new = mu2 * m + (1.0 - mu2) * jnp.square(g)
    a_rms = (1.0 - alpha_sgd) * eta_rmsprop / eta
    coef = alpha_sgd + a_rms / (jnp.sqrt(m_new) + eps)
    d_new = mu1 * d - coef * g
    return p32 + eta * d_new, d_new, m_new
