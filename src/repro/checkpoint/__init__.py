from repro.checkpoint.checkpointer import (  # noqa: F401
    AsyncCheckpointer,
    list_checkpoints,
    restore,
    restore_best,
    save,
    save_best,
)
