"""Fault-tolerant checkpointer: atomic, async, topology-elastic.

Layout:  <dir>/step_<n>/
            arrays.npz        flattened state tree (keystr -> array)
            manifest.json     step, tree structure hash, metadata
Manifest is written LAST and fsync'd; restore ignores directories without
a valid manifest, so a crash mid-save can never corrupt resume (tested).

Elasticity: arrays are saved as *full logical* arrays (gathered from the
addressable shards), so a restore may re-shard onto any mesh/DP degree —
the elastic-restart path of DESIGN.md §5.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import threading
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any

MANIFEST = "manifest.json"
ARRAYS = "arrays.npz"


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        out[jax.tree_util.keystr(path)] = np.asarray(jax.device_get(leaf))
    return out


def save(directory: str, step: int, state: PyTree,
         metadata: Optional[Dict] = None) -> str:
    """Atomic synchronous save. Returns the checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(prefix=".tmp_ckpt_", dir=directory)
    try:
        arrays = _flatten(state)
        np.savez(os.path.join(tmp, ARRAYS), **arrays)
        manifest = {
            "step": int(step),
            "keys": sorted(arrays.keys()),
            "metadata": metadata or {},
        }
        mpath = os.path.join(tmp, MANIFEST)
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


class AsyncCheckpointer:
    """Background-thread checkpointing; at most one save in flight.

    The state is snapshotted (device_get) on the caller thread so the
    training loop can donate/overwrite buffers immediately; serialization
    and fsync happen off-thread.
    """

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    def save(self, step: int, state: PyTree, metadata=None,
             block: bool = False):
        self.wait()
        host_state = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                  state)

        def _worker():
            try:
                save(self.directory, step, host_state, metadata)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_worker, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        _gc_dir(self.directory, self.keep)


def _gc_dir(directory: str, keep: int):
    """Drop all but the newest ``keep`` checkpoints in ``directory`` —
    the single retention policy, shared by the rotating window and the
    best-checkpoint dir (keep=1)."""
    steps = list_checkpoints(directory)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:010d}"),
                      ignore_errors=True)


BEST_DIR = "best"


def save_best(directory: str, step: int, state: PyTree,
              metadata: Optional[Dict] = None) -> str:
    """Retain ``state`` as the best checkpoint so far.

    Lives under ``<directory>/best/step_<n>`` — outside the rotating
    ``keep`` window, so the best-accuracy state survives GC no matter
    how much later training runs (DESIGN.md §7). At most one best
    checkpoint exists (same keep=1 policy as the async path the Trainer
    uses); the previous one is removed after the new one is atomically
    in place.
    """
    bdir = os.path.join(directory, BEST_DIR)
    path = save(bdir, step, state, metadata=metadata)
    _gc_dir(bdir, keep=1)
    return path


def restore_best(directory: str, target: Optional[PyTree] = None,
                 shardings: Optional[PyTree] = None,
                 transform=None) -> Tuple[PyTree, Dict]:
    """Restore the retained best checkpoint (see ``save_best``)."""
    return restore(os.path.join(directory, BEST_DIR), target=target,
                   shardings=shardings, transform=transform)


def list_checkpoints(directory: str):
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if not m:
            continue
        if os.path.exists(os.path.join(directory, name, MANIFEST)):
            try:
                with open(os.path.join(directory, name, MANIFEST)) as f:
                    json.load(f)
            except (json.JSONDecodeError, OSError):
                continue  # partial/corrupt save: skip
            out.append(int(m.group(1)))
    return sorted(out)


def restore(directory: str, step: Optional[int] = None,
            target: Optional[PyTree] = None,
            shardings: Optional[PyTree] = None,
            transform=None) -> Tuple[PyTree, Dict]:
    """Restore ``step`` (default: newest valid). If ``target`` is given,
    arrays are unflattened into its structure; with ``shardings`` each
    leaf is device_put with its (possibly new-topology) sharding —
    the elastic-restart path.

    ``transform(arrays, manifest) -> arrays`` rewrites the loaded array
    dict before key matching — the resharding hook that lets a --zero
    run restore a tree-layout checkpoint and vice versa
    (``optim/stream.py:make_zero_restore_transform``, DESIGN.md §9)."""
    steps = list_checkpoints(directory)
    if not steps:
        raise FileNotFoundError(f"no valid checkpoint under {directory}")
    step = steps[-1] if step is None else step
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, MANIFEST)) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, ARRAYS)) as z:
        arrays = {k: z[k] for k in z.files}
    if transform is not None:
        arrays = transform(arrays, manifest)
    if target is None:
        return arrays, manifest
    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    leaves = []
    shard_leaves = (jax.tree.leaves(shardings,
                                    is_leaf=lambda x: hasattr(x, "spec"))
                    if shardings is not None else [None] * len(flat))
    if len(shard_leaves) != len(flat):
        # strict zip: a mis-shaped shardings tree must error, not
        # silently device_put the tail of the state unsharded
        raise ValueError(
            f"shardings tree has {len(shard_leaves)} leaves but target "
            f"has {len(flat)}; pass a shardings tree congruent with the "
            "state (or None)")
    for (path_k, leaf), shard in zip(flat, shard_leaves):
        key = jax.tree_util.keystr(path_k)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"target {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        leaves.append(jax.device_put(arr, shard) if shard is not None
                      else jax.device_put(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target), leaves)
    return tree, manifest
