"""Fault-tolerant checkpointer: atomic, async, integrity-checked,
topology-elastic.

Layout:  <dir>/step_<n>/
            arrays.npz        flattened state tree (keystr -> array)
            manifest.json     step, key list, per-array crc32, metadata
Manifest is written LAST and fsync'd; restore ignores directories without
a valid manifest, so a crash mid-save can never corrupt resume (tested).

Atomic replace (DESIGN.md §13): a re-save of an existing step never
destroys the old data before the new data is in place — the old
directory is *moved aside*, the tmp directory renamed in, the parent
directory fsync'd, and only then is the old copy deleted. A crash in
the window loses at most the directory *listing* for that one step
(the bytes survive under an aside name and every other checkpoint is
untouched); an exception moves the old copy straight back. Stale
``.tmp_ckpt_*`` / aside directories left by killed runs are GC'd when a
new ``AsyncCheckpointer`` opens the directory.

Integrity: the manifest carries a crc32 per array. ``restore`` verifies
the payload (zip structure, key coverage, checksums) and — when asked
for the newest checkpoint — falls back to the next-newest intact one
instead of raising, reporting each corrupt candidate via ``on_corrupt``
(the recovery state machine logs these as events).

Elasticity: arrays are saved as *full logical* arrays (gathered from the
addressable shards), so a restore may re-shard onto any mesh/DP degree —
the elastic-restart path of DESIGN.md §5.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
import threading
import zlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any

MANIFEST = "manifest.json"
ARRAYS = "arrays.npz"
_TMP_PREFIX = ".tmp_ckpt_"
_ASIDE_PREFIX = ".old_ckpt_"


class CheckpointCorruptError(RuntimeError):
    """The checkpoint's payload failed validation (torn/bit-flipped
    arrays.npz, missing keys, or a crc32 mismatch)."""


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        out[jax.tree_util.keystr(path)] = np.asarray(jax.device_get(leaf))
    return out


def _crc32(arr: np.ndarray) -> int:
    return zlib.crc32(np.ascontiguousarray(arr).tobytes()) & 0xFFFFFFFF


def _fsync_dir(path: str):
    """Durably record a rename in the parent directory (best effort:
    some filesystems reject O_RDONLY fsync on directories)."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def gc_stale_tmpdirs(directory: str) -> int:
    """Remove ``.tmp_ckpt_*`` / aside directories left behind by killed
    runs. Call only when no save can be in flight in ``directory`` (a
    fresh ``AsyncCheckpointer`` does, at open). Returns the count."""
    if not os.path.isdir(directory):
        return 0
    n = 0
    for name in os.listdir(directory):
        if name.startswith((_TMP_PREFIX, _ASIDE_PREFIX)):
            shutil.rmtree(os.path.join(directory, name),
                          ignore_errors=True)
            n += 1
    return n


def _write_checkpoint(directory: str, step: int,
                      arrays: Dict[str, np.ndarray],
                      metadata: Optional[Dict] = None) -> str:
    """Write already-flattened host arrays as ``step_<n>`` atomically."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(prefix=_TMP_PREFIX, dir=directory)
    aside = None
    try:
        np.savez(os.path.join(tmp, ARRAYS), **arrays)
        manifest = {
            "step": int(step),
            "keys": sorted(arrays.keys()),
            "crc32": {k: _crc32(v) for k, v in arrays.items()},
            "metadata": metadata or {},
        }
        mpath = os.path.join(tmp, MANIFEST)
        with open(mpath, "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            # move the existing good checkpoint ASIDE, never rmtree it
            # before its replacement is in place: a crash here leaves
            # the data recoverable and all other checkpoints intact
            aside = tempfile.mkdtemp(prefix=_ASIDE_PREFIX, dir=directory)
            os.rmdir(aside)
            os.rename(final, aside)
        os.rename(tmp, final)
        _fsync_dir(directory)
        if aside is not None:
            shutil.rmtree(aside, ignore_errors=True)
            aside = None
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        if aside is not None and not os.path.exists(final):
            os.rename(aside, final)  # restore the previous good copy
        elif aside is not None:
            shutil.rmtree(aside, ignore_errors=True)
        raise
    return final


def save(directory: str, step: int, state: PyTree,
         metadata: Optional[Dict] = None) -> str:
    """Atomic synchronous save. Returns the checkpoint path."""
    return _write_checkpoint(directory, step, _flatten(state), metadata)


class AsyncCheckpointer:
    """Background-thread checkpointing; at most one save in flight.

    The state is snapshotted to host arrays **once**, on the caller
    thread (``_flatten``), so the training loop can donate/overwrite
    device buffers immediately; the worker thread serializes that same
    dict — no second host copy, halving the host-memory spike of a
    save. Opening a directory GC's stale tmp dirs from killed runs.
    """

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None
        gc_stale_tmpdirs(directory)

    def save(self, step: int, state: PyTree, metadata=None,
             block: bool = False):
        self.wait()
        arrays = _flatten(state)  # the ONE host snapshot

        def _worker():
            try:
                _write_checkpoint(self.directory, step, arrays, metadata)
                self._gc()
            except BaseException as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_worker, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self):
        _gc_dir(self.directory, self.keep)


def _gc_dir(directory: str, keep: int):
    """Drop all but the newest ``keep`` checkpoints in ``directory`` —
    the single retention policy, shared by the rotating window and the
    best-checkpoint dir (keep=1)."""
    steps = list_checkpoints(directory)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:010d}"),
                      ignore_errors=True)


BEST_DIR = "best"


def save_best(directory: str, step: int, state: PyTree,
              metadata: Optional[Dict] = None) -> str:
    """Retain ``state`` as the best checkpoint so far.

    Lives under ``<directory>/best/step_<n>`` — outside the rotating
    ``keep`` window, so the best-accuracy state survives GC no matter
    how much later training runs (DESIGN.md §7). At most one best
    checkpoint exists (same keep=1 policy as the async path the Trainer
    uses); the previous one is removed after the new one is atomically
    in place.
    """
    bdir = os.path.join(directory, BEST_DIR)
    path = save(bdir, step, state, metadata=metadata)
    _gc_dir(bdir, keep=1)
    return path


def restore_best(directory: str, target: Optional[PyTree] = None,
                 shardings: Optional[PyTree] = None,
                 transform=None) -> Tuple[PyTree, Dict]:
    """Restore the retained best checkpoint (see ``save_best``)."""
    return restore(os.path.join(directory, BEST_DIR), target=target,
                   shardings=shardings, transform=transform)


def list_checkpoints(directory: str):
    """Steps with a parseable manifest AND a present payload — a torn
    save missing ``arrays.npz`` must not be offered for resume (deep
    payload validation happens in ``restore``)."""
    if not os.path.isdir(directory):
        return []
    out = []
    for name in os.listdir(directory):
        m = re.fullmatch(r"step_(\d+)", name)
        if not m:
            continue
        if not os.path.exists(os.path.join(directory, name, ARRAYS)):
            continue  # payload never landed: skip
        if os.path.exists(os.path.join(directory, name, MANIFEST)):
            try:
                with open(os.path.join(directory, name, MANIFEST)) as f:
                    json.load(f)
            except (json.JSONDecodeError, OSError):
                continue  # partial/corrupt save: skip
            out.append(int(m.group(1)))
    return sorted(out)


def _load_arrays(path: str, manifest: Dict) -> Dict[str, np.ndarray]:
    """Load + validate one checkpoint's payload against its manifest.
    Raises ``CheckpointCorruptError`` on any integrity failure."""
    try:
        with np.load(os.path.join(path, ARRAYS)) as z:
            arrays = {k: z[k] for k in z.files}
    except Exception as e:  # zipfile/np errors on torn or flipped bytes
        raise CheckpointCorruptError(
            f"unreadable {ARRAYS} under {path}: {e}") from e
    missing = [k for k in manifest.get("keys", []) if k not in arrays]
    if missing:
        raise CheckpointCorruptError(
            f"{path} payload lost {len(missing)} arrays "
            f"(first: {missing[0]!r})")
    crcs = manifest.get("crc32")
    if crcs:  # absent in pre-integrity checkpoints: skip verification
        for k, want in crcs.items():
            if k in arrays and _crc32(arrays[k]) != want:
                raise CheckpointCorruptError(
                    f"crc32 mismatch for {k!r} under {path}")
    return arrays


def restore(directory: str, step: Optional[int] = None,
            target: Optional[PyTree] = None,
            shardings: Optional[PyTree] = None,
            transform=None,
            on_corrupt: Optional[Callable[[int, Exception], None]] = None
            ) -> Tuple[PyTree, Dict]:
    """Restore ``step`` (default: newest intact). If ``target`` is given,
    arrays are unflattened into its structure; with ``shardings`` each
    leaf is device_put with its (possibly new-topology) sharding —
    the elastic-restart path.

    With ``step=None`` the candidates are tried newest-first and a
    corrupt payload (torn write, flipped bytes, crc mismatch) makes the
    restore *fall back to the next-newest intact checkpoint* instead of
    raising — losing a checkpoint interval, not the run. Each skipped
    candidate is reported through ``on_corrupt(step, error)``. An
    explicitly requested ``step`` still raises on corruption.

    ``transform(arrays, manifest) -> arrays`` rewrites the loaded array
    dict before key matching — the resharding hook that lets a --zero
    run restore a tree-layout checkpoint and vice versa
    (``optim/stream.py:make_zero_restore_transform``, DESIGN.md §9)."""
    steps = list_checkpoints(directory)
    if not steps:
        raise FileNotFoundError(f"no valid checkpoint under {directory}")
    candidates = [step] if step is not None else list(reversed(steps))
    arrays = manifest = None
    last_err: Optional[Exception] = None
    for s in candidates:
        path = os.path.join(directory, f"step_{s:010d}")
        with open(os.path.join(path, MANIFEST)) as f:
            manifest = json.load(f)
        try:
            arrays = _load_arrays(path, manifest)
            break
        except CheckpointCorruptError as e:
            if step is not None:
                raise
            last_err = e
            if on_corrupt is not None:
                on_corrupt(s, e)
    else:
        raise CheckpointCorruptError(
            f"no intact checkpoint under {directory}: every candidate "
            f"failed validation (last: {last_err})")
    if transform is not None:
        arrays = transform(arrays, manifest)
    if target is None:
        return arrays, manifest
    flat, treedef = jax.tree_util.tree_flatten_with_path(target)
    leaves = []
    shard_leaves = (jax.tree.leaves(shardings,
                                    is_leaf=lambda x: hasattr(x, "spec"))
                    if shardings is not None else [None] * len(flat))
    if len(shard_leaves) != len(flat):
        # strict zip: a mis-shaped shardings tree must error, not
        # silently device_put the tail of the state unsharded
        raise ValueError(
            f"shardings tree has {len(shard_leaves)} leaves but target "
            f"has {len(flat)}; pass a shardings tree congruent with the "
            "state (or None)")
    for (path_k, leaf), shard in zip(flat, shard_leaves):
        key = jax.tree_util.keystr(path_k)
        if key not in arrays:
            raise KeyError(f"checkpoint missing {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs "
                f"target {leaf.shape}")
        arr = arr.astype(leaf.dtype)
        leaves.append(jax.device_put(arr, shard) if shard is not None
                      else jax.device_put(arr))
    tree = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(target), leaves)
    return tree, manifest
