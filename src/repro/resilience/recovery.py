"""Recovery state machine: skip -> rollback -> bounded retries
(DESIGN.md §13).

The sentinel (``sentinel.py``) already suppressed the bad update inside
the jitted step; this module is the host-side policy that decides what
happens *next*. It is deliberately a plain state machine driven by the
training loop (``training/loop.py:Trainer``):

    good step     -> feed the EMA spike detector, reset the bad streak
    bad step      -> emit ``step_skipped``; the state was carried over
                     unchanged, the batch is abandoned (a transient
                     fault costs exactly one minibatch)
    K bad in a row-> ``rollback``: the loop restores the last good
                     checkpoint (falling back past corrupt ones,
                     checkpoint/checkpointer.py), rewinds the data
                     pipeline to the restored step, and re-enters with
                     the LR damped by ``lr_backoff**n_rollbacks`` for
                     ``backoff_steps`` steps
    budget spent  -> ``abort``: after ``max_rollbacks`` restores the
                     run raises instead of looping forever

The EMA spike detector arms after ``warmup_steps`` good steps and flags
``grad_norm > spike_factor * ema`` — the "loss blew up but is still
finite" divergence mode that non-finite checks alone miss. Thresholds
ride into the jitted step as inputs (``sentinel.sentinel_controls``),
so tightening them never recompiles.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Dict, Optional

from repro.resilience.events import EventLog
from repro.resilience.sentinel import sentinel_controls


class Action(enum.Enum):
    CONTINUE = "continue"
    SKIPPED = "skipped"
    ROLLBACK = "rollback"
    ABORT = "abort"


@dataclasses.dataclass
class ResilienceConfig:
    """Policy knobs for the sentinel + recovery state machine."""

    max_consecutive_bad: int = 3  # K bad steps before a rollback
    max_rollbacks: int = 3  # bounded retries; exceeded -> abort
    lr_backoff: float = 0.5  # LR scale multiplier per rollback
    backoff_steps: int = 10  # damped steps after each rollback
    spike_factor: float = 0.0  # grad_norm > factor*EMA flags a spike
    #                            (0 disables spike detection)
    ema_decay: float = 0.9  # grad-norm EMA decay (good steps only)
    warmup_steps: int = 10  # good steps before the spike check arms
    data_retries: int = 2  # prefetcher crash restarts per step
    event_log: Optional[str] = None  # JSONL path (None: in-memory only)


class RecoveryManager:
    """Drives one training run's recovery decisions.

    The Trainer calls ``controls()`` before each step (device inputs
    for the sentinel gate), ``observe(step, metrics)`` after it (the
    decision), and ``on_rollback(from_step, to_step)`` when it has
    actually restored a checkpoint."""

    def __init__(self, cfg: ResilienceConfig, events: EventLog):
        self.cfg = cfg
        self.events = events
        self.consecutive_bad = 0
        self.n_rollbacks = 0
        self.n_skipped = 0
        self._ema: Optional[float] = None
        self._good_steps = 0
        self._damped_until = -1  # step index the LR damping expires at

    # ---------------------------------------------------------- inputs
    def spike_threshold(self) -> float:
        if (self.cfg.spike_factor <= 0.0 or self._ema is None
                or self._good_steps < self.cfg.warmup_steps):
            return float("inf")
        return self.cfg.spike_factor * self._ema

    def lr_scale(self, step: int) -> float:
        if step < self._damped_until and self.n_rollbacks:
            return self.cfg.lr_backoff ** self.n_rollbacks
        return 1.0

    def controls(self, step: int) -> Dict:
        return sentinel_controls(spike_threshold=self.spike_threshold(),
                                 lr_scale=self.lr_scale(step))

    # -------------------------------------------------------- decision
    def observe(self, step: int, metrics: Dict) -> Action:
        """``metrics`` are host-side floats for this completed step
        (must contain ``bad_step``; ``loss``/``grad_norm``/
        ``nonfinite_step``/``grad_spike`` are used when present)."""
        bad = bool(metrics.get("bad_step", 0.0))
        if not bad:
            self.consecutive_bad = 0
            self._good_steps += 1
            gnorm = metrics.get("grad_norm")
            if gnorm is not None and _finite(gnorm):
                d = self.cfg.ema_decay
                self._ema = (float(gnorm) if self._ema is None
                             else d * self._ema + (1.0 - d) * float(gnorm))
            return Action.CONTINUE
        self.consecutive_bad += 1
        self.n_skipped += 1
        self.events.emit(
            "step_skipped", step=step,
            consecutive_bad=self.consecutive_bad,
            nonfinite=bool(metrics.get("nonfinite_step", 0.0)),
            spike=bool(metrics.get("grad_spike", 0.0)),
            loss=_as_float(metrics.get("loss")),
            grad_norm=_as_float(metrics.get("grad_norm")),
            spike_threshold=self.spike_threshold())
        if self.consecutive_bad < self.cfg.max_consecutive_bad:
            return Action.SKIPPED
        if self.n_rollbacks >= self.cfg.max_rollbacks:
            self.events.emit("abort", step=step,
                             rollbacks=self.n_rollbacks,
                             max_rollbacks=self.cfg.max_rollbacks)
            return Action.ABORT
        return Action.ROLLBACK

    def on_rollback(self, from_step: int, to_step: int):
        self.n_rollbacks += 1
        self.consecutive_bad = 0
        # the restored regime may have a very different gradient scale;
        # re-learn the EMA before re-arming the spike check
        self._ema = None
        self._good_steps = 0
        self._damped_until = to_step + self.cfg.backoff_steps
        self.events.emit("rollback", from_step=from_step, to_step=to_step,
                         n_rollbacks=self.n_rollbacks,
                         wasted_steps=from_step - to_step,
                         lr_scale=self.cfg.lr_backoff ** self.n_rollbacks,
                         backoff_steps=self.cfg.backoff_steps)


def _as_float(v) -> Optional[float]:
    return None if v is None else float(v)


def _finite(v) -> bool:
    import math

    return math.isfinite(float(v))
