"""Divergence sentinel: on-device bad-step detection + in-jit skip gate
(DESIGN.md §13).

Large-minibatch SGD is known to be fragile early (the warm-up schedules
of Goyal et al. and Akiba et al. exist precisely because 32k-batch
training diverges in the first epochs), and at 1024 workers a single
flipped bit turns one gradient bucket into NaNs that poison every
replica within one all-reduce. The sentinel makes each train step
self-checking at ~zero cost:

* **Non-finite flags come free from the packed gradient stream.** All
  explicit sync modes already reduce the synced stream to a squared L2
  norm in one fused pass (``distributed/bucketing.py:unpack(
  with_sq_norm=True)``, the ZeRO paths' ``grad_sq_local`` psum) and
  report it as ``metrics["grad_norm"]``. A NaN/Inf *anywhere* in the
  gradient makes that scalar non-finite, so ``isfinite(grad_norm)`` is
  a whole-gradient health check with no extra reduction. The loss is
  checked the same way. (GSPMD has no packed stream; the launcher
  forces ``log_grad_norm`` on when the sentinel is enabled, paying the
  one documented extra tree reduction.)

* **Spike detection** compares ``grad_norm`` against a threshold that
  rides in as a step *input* (``controls["spike_threshold"]``), so the
  host-side EMA detector (``recovery.RecoveryManager``) can tighten it
  every step without recompiling. ``inf`` disables the check.

* **The skip gate is inside the jitted program.** The step builders all
  donate the input state (``training/step.py:jit_train_step``), so by
  the time the host learns a step was bad the input buffers are gone —
  a bad step cannot be "not applied" after the fact. Instead the
  wrapped step computes the update unconditionally and selects
  ``jnp.where(bad, old, new)`` per leaf: on a good step the select
  passes ``new`` through bitwise-unchanged (the no-fault parity
  contract, tests/test_resilience.py), on a bad step the state —
  params, optimizer (including its step counter), BN statistics, EF
  residuals — is carried over untouched, as if the step never ran.
  Every worker computes the same flag from all-reduced scalars, so the
  gate can never desynchronize replicas.

* **LR backoff** (``controls["lr_scale"]``) damps re-entry after a
  rollback: params take ``old + scale * (new - old)`` — exactly an
  LR-scaled parameter step for SGD-family updates (``p' = p + eta*d``),
  with the optimizer state advancing normally. ``scale >= 1`` selects
  the untouched ``new`` (no float blend), keeping the parity bitwise.
"""
from __future__ import annotations

from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

PyTree = Any

#: metric keys every sentinel-wrapped step adds (bool scalars).
SENTINEL_METRICS = ("bad_step", "nonfinite_step", "grad_spike")


def sentinel_controls(spike_threshold: float = float("inf"),
                      lr_scale: float = 1.0) -> Dict[str, jax.Array]:
    """The per-step host->device control inputs of a wrapped step."""
    return {"spike_threshold": jnp.float32(spike_threshold),
            "lr_scale": jnp.float32(lr_scale)}


def _flags(metrics: Dict, threshold: jax.Array):
    """(bad, nonfinite, spike) bool scalars from the step's metrics.

    Keys are inspected at trace time (dict membership is static), so a
    mode without ``grad_norm`` simply traces a loss-only check."""
    nonfinite = jnp.zeros((), bool)
    loss = metrics.get("loss")
    if loss is not None:
        nonfinite |= ~jnp.isfinite(jnp.asarray(loss, jnp.float32))
    spike = jnp.zeros((), bool)
    gnorm = metrics.get("grad_norm")
    if gnorm is not None:
        g32 = jnp.asarray(gnorm, jnp.float32)
        nonfinite |= ~jnp.isfinite(g32)
        spike = jnp.isfinite(g32) & (g32 > threshold)
    return nonfinite | spike, nonfinite, spike


def wrap_step_with_sentinel(step: Callable) -> Callable:
    """Wrap a ``(state, batch) -> (state', metrics)`` train step into a
    ``(state, batch, controls) -> (state', metrics)`` resilient step.

    Works on any of the six sync-mode builders — the wrapper runs
    *outside* shard_map on replicated scalars, so it composes with
    GSPMD, per-leaf, bucketed, overlap, zero and zero-overlap steps
    unchanged, and ``jit_train_step`` donation stays valid (state in /
    state out, same treedef). ``controls`` is ``sentinel_controls()``.
    """

    def resilient_step(state: PyTree, batch: PyTree,
                       controls: Dict[str, jax.Array]):
        new_state, metrics = step(state, batch)
        bad, nonfinite, spike = _flags(metrics,
                                       controls["spike_threshold"])
        scale = controls["lr_scale"]

        def keep(old, new):
            return jnp.where(bad, old, new)

        def keep_param(old, new):
            if not jnp.issubdtype(old.dtype, jnp.floating):
                return keep(old, new)
            o32 = old.astype(jnp.float32)
            damped = (o32 + scale * (new.astype(jnp.float32) - o32)
                      ).astype(old.dtype)
            # scale >= 1 must select `new` itself: old + 1.0*(new-old)
            # is NOT bitwise new in floating point
            return jnp.where(bad, old, jnp.where(scale >= 1.0, new,
                                                 damped))

        gated = {}
        for key, new_sub in new_state.items():
            gate = keep_param if key == "params" else keep
            gated[key] = jax.tree.map(gate, state[key], new_sub)
        metrics = dict(metrics)
        metrics["bad_step"] = bad
        metrics["nonfinite_step"] = nonfinite
        metrics["grad_spike"] = spike
        return gated, metrics

    return resilient_step
