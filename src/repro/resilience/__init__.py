"""Fault-tolerant training (DESIGN.md §13).

At the paper's scale — 1024 GPUs, 15 minutes — a single NaN'd gradient
bucket, a torn checkpoint, or a dead input worker destroys the whole
run. This package is the resilience layer under the training loop:

* ``sentinel``      — on-device divergence detection: non-finite flags
                      piggy-backed on the packed-stream grad norm plus
                      an EMA spike threshold, and a ``jnp.where`` gate
                      that suppresses a bad step's update inside the
                      jitted program (donation-safe skip).
* ``recovery``      — host-side state machine: skip, then after K
                      consecutive bad steps restore-from-last-good
                      checkpoint with LR backoff and bounded retries.
* ``events``        — structured JSON-lines event log every recovery
                      action is emitted to.
* ``chaos``         — deterministic, seed-driven fault injection
                      (``--chaos`` in launch/train.py) for testing and
                      the ``benchmarks/resilience_bench.py`` soak.
"""
from repro.resilience.chaos import ChaosEngine, ChaosError, parse_chaos
from repro.resilience.events import EventLog
from repro.resilience.recovery import (
    Action,
    RecoveryManager,
    ResilienceConfig,
)
from repro.resilience.sentinel import (
    SENTINEL_METRICS,
    sentinel_controls,
    wrap_step_with_sentinel,
)

__all__ = [
    "Action",
    "ChaosEngine",
    "ChaosError",
    "EventLog",
    "RecoveryManager",
    "ResilienceConfig",
    "SENTINEL_METRICS",
    "parse_chaos",
    "sentinel_controls",
    "wrap_step_with_sentinel",
]
