"""Deterministic chaos harness: seed-driven fault injection
(DESIGN.md §13).

Testing a recovery path that only triggers on 1000-node hardware faults
needs faults on demand: this module injects them *deterministically*,
keyed by (spec, seed, step), so a failing soak reproduces bit-for-bit.
Faults are injected at the system's real boundaries — the batch the
data pipeline hands over, the checkpoint bytes on disk, the host-side
step dispatch — never by patching the jitted program, so the detection
path being exercised is exactly the production one.

Spec grammar (``--chaos`` in launch/train.py)::

    spec    := clause (',' clause)*
    clause  := 'seed=' INT
             | KIND '@' STEP ['-' STEP] [':' FLOAT]
    KIND    := nan_grad | data_crash | data_stall | straggler
             | ckpt_truncate | ckpt_bitflip

Fault classes (every trigger fires **once** — a transient fault, so a
post-rollback replay of the same step is clean):

* ``nan_grad@S[-E]``    — poison one seed-chosen element of the batch's
                          first float leaf with NaN at step S (..E).
                          The NaN flows through loss and backward into
                          every gradient bucket — the real
                          NaN-poisoned-bucket failure mode, detected by
                          the packed-stream sentinel flags.
* ``data_crash@S``      — ``batch_at(S)`` raises ``ChaosError`` once:
                          a dead input worker. Propagates through the
                          Prefetcher's error contract; the Trainer's
                          bounded data-retry path restarts the
                          pipeline.
* ``data_stall@S[:sec]``— ``batch_at(S)`` sleeps (default 1.0 s): a
                          stalled input worker, surfacing as a
                          straggler step.
* ``straggler@S[:sec]`` — host-side sleep before dispatching step S
                          (default 0.5 s): a slow worker.
* ``ckpt_truncate@S``   — after the first checkpoint save completing at
                          step >= S, truncate the newest checkpoint's
                          ``arrays.npz`` to half: a torn write. The
                          integrity-checked restore must fall back to
                          the next-newest checkpoint.
* ``ckpt_bitflip@S``    — flip one seed-chosen byte instead: silent
                          media corruption, caught by the zip/crc32
                          validation on restore.
"""
from __future__ import annotations

import dataclasses
import os
import re
import time
from typing import Any, Dict, List, Optional

import numpy as np

PyTree = Any

KINDS = ("nan_grad", "data_crash", "data_stall", "straggler",
         "ckpt_truncate", "ckpt_bitflip")
_DATA_KINDS = ("nan_grad", "data_crash", "data_stall")
_CKPT_KINDS = ("ckpt_truncate", "ckpt_bitflip")

_CLAUSE = re.compile(
    r"^(?P<kind>[a-z_]+)@(?P<lo>\d+)(?:-(?P<hi>\d+))?(?::(?P<arg>[\d.]+))?$")

_DEFAULT_ARG = {"data_stall": 1.0, "straggler": 0.5}


class ChaosError(RuntimeError):
    """The injected data-pipeline fault (a 'dead input worker')."""


@dataclasses.dataclass
class Trigger:
    kind: str
    step: int
    arg: Optional[float] = None
    fired: bool = False


def parse_chaos(spec: str, seed: int = 0,
                events=None) -> "ChaosEngine":
    """Parse a ``--chaos`` spec string into an engine. Raises
    ``ValueError`` on unknown kinds or malformed clauses."""
    triggers: List[Trigger] = []
    for raw in spec.split(","):
        clause = raw.strip()
        if not clause:
            continue
        if clause.startswith("seed="):
            seed = int(clause[len("seed="):])
            continue
        m = _CLAUSE.match(clause)
        if not m:
            raise ValueError(
                f"bad chaos clause {clause!r}: expected "
                "kind@step[-end][:arg] or seed=<int> "
                f"(kinds: {', '.join(KINDS)})")
        kind = m.group("kind")
        if kind not in KINDS:
            raise ValueError(f"unknown chaos kind {kind!r} in {clause!r} "
                             f"(kinds: {', '.join(KINDS)})")
        lo = int(m.group("lo"))
        hi = int(m.group("hi")) if m.group("hi") else lo
        if hi < lo:
            raise ValueError(f"bad chaos range in {clause!r}: {hi} < {lo}")
        arg = (float(m.group("arg")) if m.group("arg")
               else _DEFAULT_ARG.get(kind))
        for s in range(lo, hi + 1):
            triggers.append(Trigger(kind=kind, step=s, arg=arg))
    return ChaosEngine(triggers, seed=seed, events=events)


class ChaosEngine:
    """Holds the trigger table and injects at the three hook points the
    Trainer exposes: the data source (``wrap_source``), the host step
    dispatch (``on_step_start``), and completed checkpoint saves
    (``after_save``)."""

    def __init__(self, triggers: List[Trigger], seed: int = 0,
                 events=None):
        self.triggers = list(triggers)
        self.seed = seed
        self.events = events
        self.injected: List[Dict] = []

    # ------------------------------------------------------------ util
    def _fire(self, trig: Trigger, **fields):
        trig.fired = True
        rec = {"kind": trig.kind, "step": trig.step, **fields}
        self.injected.append(rec)
        if self.events is not None:
            # the event's own kind is "chaos_injected"; the fault class
            # rides along as the `fault` field
            self.events.emit("chaos_injected", fault=trig.kind,
                             step=trig.step, **fields)

    def _pending(self, kinds, step=None):
        return [t for t in self.triggers
                if t.kind in kinds and not t.fired
                and (step is None or t.step == step)]

    def _rng(self, trig: Trigger) -> np.random.RandomState:
        return np.random.RandomState(
            (self.seed * 9_999_991 + trig.step * 101
             + KINDS.index(trig.kind)) % (2 ** 31 - 1))

    # ------------------------------------------------------ data hooks
    def wrap_source(self, source):
        """Wrap a ``batch_at(step)`` data source with the data-class
        faults (nan_grad / data_crash / data_stall)."""
        return _ChaosSource(self, source)

    def inject_batch(self, step: int, batch: Dict[str, np.ndarray]):
        for trig in self._pending(("data_crash",), step):
            self._fire(trig)
            raise ChaosError(
                f"chaos: injected input-worker crash at step {step}")
        for trig in self._pending(("data_stall",), step):
            self._fire(trig, seconds=trig.arg)
            time.sleep(trig.arg)
        for trig in self._pending(("nan_grad",), step):
            key = next((k for k in sorted(batch)
                        if np.issubdtype(np.asarray(batch[k]).dtype,
                                         np.floating)), None)
            if key is None:
                raise ValueError(
                    "chaos nan_grad needs a float batch leaf to poison; "
                    f"batch has only {sorted(batch)} "
                    "(integer token pipelines are not supported)")
            arr = np.array(batch[key])  # poison a copy, never the source
            flat = arr.reshape(-1)
            pos = int(self._rng(trig).randint(flat.size))
            flat[pos] = np.nan
            batch = dict(batch)
            batch[key] = arr
            self._fire(trig, leaf=key, position=pos)
        return batch

    # ------------------------------------------------------ host hooks
    def on_step_start(self, step: int):
        for trig in self._pending(("straggler",), step):
            self._fire(trig, seconds=trig.arg)
            time.sleep(trig.arg)

    def has_pending_ckpt_fault(self, step: int) -> bool:
        return any(t.step <= step
                   for t in self._pending(_CKPT_KINDS))

    def after_save(self, directory: str, step: int):
        """Corrupt the newest checkpoint for every armed ckpt trigger
        whose step has passed. The caller must have flushed any async
        save first (the Trainer does ``ckpt.wait()``)."""
        from repro.checkpoint.checkpointer import ARRAYS, list_checkpoints

        for trig in [t for t in self._pending(_CKPT_KINDS)
                     if t.step <= step]:
            steps = list_checkpoints(directory)
            if not steps:
                continue  # stays armed for the next save
            newest = steps[-1]
            path = os.path.join(directory, f"step_{newest:010d}", ARRAYS)
            size = os.path.getsize(path)
            if trig.kind == "ckpt_truncate":
                with open(path, "r+b") as f:
                    f.truncate(size // 2)
                self._fire(trig, target_step=newest, truncated_to=size // 2)
            else:
                pos = int(self._rng(trig).randint(size))
                with open(path, "r+b") as f:
                    f.seek(pos)
                    byte = f.read(1)
                    f.seek(pos)
                    f.write(bytes([byte[0] ^ 0xFF]))
                self._fire(trig, target_step=newest, flipped_byte=pos)

    def summary(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for rec in self.injected:
            out[rec["kind"]] = out.get(rec["kind"], 0) + 1
        return out


class _ChaosSource:
    """A ``batch_at`` source with the engine's data faults applied."""

    def __init__(self, engine: ChaosEngine, source):
        self._engine = engine
        self._source = source

    def __getattr__(self, name):
        return getattr(self._source, name)

    def batch_at(self, step: int):
        return self._engine.inject_batch(step, self._source.batch_at(step))
