"""Structured JSON-lines event log for recovery actions (DESIGN.md §13).

Every action the resilience layer takes — a skipped step, a rollback, a
corrupt checkpoint skipped during restore, a chaos injection — is
emitted as one JSON object per line, so a post-mortem of a 1000-node run
is a ``jq`` query, not a grep over interleaved stdout. The log is
append-only and flushed per record (a crash loses at most the record
being written); records are also kept in memory so tests and the
resilience bench can assert on them without re-parsing the file.
"""
from __future__ import annotations

import json
import time
from typing import Any, Dict, List, Optional


class EventLog:
    """Append-only recovery event log.

    ``path=None`` keeps records in memory only (the default for tests
    and library use); with a path every record is also written as one
    JSON line and flushed immediately.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self.records: List[Dict[str, Any]] = []
        self._fh = open(path, "a") if path else None
        self._seq = 0

    def emit(self, kind: str, **fields) -> Dict[str, Any]:
        rec = {"seq": self._seq, "time": time.time(), "kind": kind}
        rec.update(fields)
        self._seq += 1
        self.records.append(rec)
        if self._fh is not None:
            json.dump(rec, self._fh, default=_json_default)
            self._fh.write("\n")
            self._fh.flush()
        return rec

    def kinds(self) -> List[str]:
        return [r["kind"] for r in self.records]

    def of_kind(self, kind: str) -> List[Dict[str, Any]]:
        return [r for r in self.records if r["kind"] == kind]

    def close(self):
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def _json_default(obj):
    """Numpy / jax scalars arrive in metrics dicts; log them as plain
    python numbers rather than crashing the event path mid-recovery."""
    for attr in ("item",):
        fn = getattr(obj, attr, None)
        if callable(fn):
            try:
                return fn()
            except Exception:
                pass
    return repr(obj)
