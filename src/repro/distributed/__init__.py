"""Distribution: logical-axis sharding rules, compressed collectives,
fault tolerance orchestration."""
from repro.distributed.sharding import (  # noqa: F401
    activation_sharding,
    constrain,
    make_rules,
    spec_for,
    tree_shardings,
    tree_specs,
)
