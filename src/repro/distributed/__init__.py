"""Distribution layer: logical-axis sharding rules, compressed
collectives, and the bucketed gradient all-reduce subsystem.

Sync modes (see DESIGN.md §2 for the wire format, §6 for bucketing):
  * GSPMD — sharding rules here + XLA-placed collectives; wire
    compression is simulated at the sync boundary (core/compression.py).
  * shard_map DP per-leaf — explicit half-precision psum per gradient
    leaf (the paper's mechanism).
  * shard_map DP bucketed — ``bucketing.py`` packs the gradient stream
    into fixed-size contiguous buckets and issues one collective per
    bucket; numerically identical to per-leaf.
Fault-tolerance orchestration (elastic restart, deterministic data
sharding) is specified in DESIGN.md §5.
"""
from repro.distributed.bucketing import (  # noqa: F401
    BucketPlan,
    bucketed_psum,
    bucketed_psum_ef,
    pack,
    plan_buckets,
    unpack,
)
from repro.distributed.sharding import (  # noqa: F401
    activation_sharding,
    constrain,
    make_rules,
    spec_for,
    tree_shardings,
    tree_specs,
)
