"""Bucketed gradient all-reduce (DESIGN.md §6).

The paper's 15-minute result depends on the interconnect seeing a few
large transfers, not hundreds of small ones: gradients are chunked and
all-reduced in half precision so latency/launch overhead is amortized
(§3; the same fused all-reduce is the core of Yamazaki et al.'s 74.7 s
follow-up). ``compressed_psum`` already casts to the wire dtype but still
issues one collective per parameter leaf — 161 all-reduces per step for
ResNet-50. This module flattens the gradient pytree into one contiguous
wire-dtype stream, splits it into fixed-size buckets (default 64 MiB),
runs **one psum per bucket**, and scatters the result back to leaves.

Leaves may span bucket boundaries (the stream is split at fixed byte
offsets, not at leaf edges), so the collective count is exactly
``ceil(total_wire_bytes / bucket_bytes)`` with no fragmentation waste.

Numerics are bitwise-identical to the per-leaf path: cast-to-wire,
elementwise sum over workers, cast-back, divide — packing only changes
*where* element i sits during the reduction, never its value. The
bucketing tests assert this on a multi-device host mesh.

The cast+copy into/out of the bucket is the Pallas kernel pair in
``kernels/bucket_ops.py`` (fused, padding-aware) when ``use_kernel`` is
on (default on TPU); the pure-JAX path is the reference and the CPU
default (interpret-mode Pallas is Python-speed).
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.compression import _wire, apply_error_feedback

PyTree = Any

DEFAULT_BUCKET_BYTES = 64 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Where one gradient leaf lives in the packed stream."""

    offset: int  # element offset into the global flat stream
    size: int
    shape: Tuple[int, ...]
    dtype: Any  # original (accumulation) dtype, restored on unpack


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Static layout of a gradient pytree packed into fixed buckets.

    Derived from shapes only, so one plan serves every step (it is
    closed over by the jitted train step, like the tree structure
    itself).
    """

    treedef: Any
    slots: Tuple[LeafSlot, ...]
    total_elems: int
    bucket_elems: int  # elements per bucket (fixed; last one truncated)
    n_buckets: int
    wire: Optional[str]  # wire dtype name, None = no cast
    stream_dtype: Any  # wire dtype, or the (uniform) leaf dtype if None

    def bucket_bounds(self, i: int) -> Tuple[int, int]:
        """Element range of bucket ``i``. All buckets are ``bucket_elems``
        long except the last, which is truncated to the stream end — a
        tail of zero-padding would be reduced over the wire for nothing."""
        lo = i * self.bucket_elems
        return lo, min(lo + self.bucket_elems, self.total_elems)

    @property
    def bucket_bytes(self) -> int:
        return self.bucket_elems * jnp.dtype(self.stream_dtype).itemsize

    def describe(self) -> str:
        itemsize = jnp.dtype(self.stream_dtype).itemsize
        total_mib = self.total_elems * itemsize / 2 ** 20
        return (f"{len(self.slots)} leaves / {total_mib:.1f} MiB wire "
                f"-> {self.n_buckets} bucket(s) of "
                f"<= {self.bucket_bytes / 2**20:.0f} MiB "
                f"({self.wire or 'f32'} wire)")


def plan_buckets(grads: PyTree,
                 bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                 wire: Optional[str] = "bf16") -> BucketPlan:
    """Lay out the gradient pytree as a contiguous wire-dtype stream cut
    into fixed-size buckets. Works on arrays or ShapeDtypeStructs."""
    leaves, treedef = jax.tree.flatten(grads)
    if not leaves:
        raise ValueError("cannot plan buckets for an empty gradient tree")
    wdt = _wire(wire)
    if wdt is None:
        # no wire cast: the stream keeps the leaves' own dtype, so the
        # psum runs in the same precision as per-leaf wire=None sync
        leaf_dtypes = {jnp.dtype(l.dtype) for l in leaves}
        if len(leaf_dtypes) > 1:
            raise ValueError(
                "bucketing without a wire dtype needs uniform leaf "
                f"dtypes, got {sorted(d.name for d in leaf_dtypes)}; "
                "set a wire dtype (e.g. 'bf16+bucketed')")
        sdt = next(iter(leaf_dtypes))
    else:
        sdt = jnp.dtype(wdt)
    bucket_elems = max(1, int(bucket_bytes) // sdt.itemsize)
    slots: List[LeafSlot] = []
    offset = 0
    for leaf in leaves:
        size = 1
        for d in leaf.shape:
            size *= d
        slots.append(LeafSlot(offset=offset, size=size,
                              shape=tuple(leaf.shape), dtype=leaf.dtype))
        offset += size
    n_buckets = max(1, -(-offset // bucket_elems))
    return BucketPlan(treedef=treedef, slots=tuple(slots),
                      total_elems=offset, bucket_elems=bucket_elems,
                      n_buckets=n_buckets, wire=wire, stream_dtype=sdt)


def _kernel_on(use_kernel: Optional[bool]) -> bool:
    if use_kernel is None:
        return jax.default_backend() == "tpu"
    return use_kernel


def pack(grads: PyTree, plan: BucketPlan,
         use_kernel: Optional[bool] = None) -> List[jax.Array]:
    """Gradient pytree -> list of ``n_buckets`` wire-dtype bucket arrays.

    Cast happens on the whole stream (fused Pallas cast+copy when
    ``use_kernel``), which is elementwise-identical to casting each leaf
    before concatenation — the bitwise guarantee the tests pin down.
    """
    leaves = plan.treedef.flatten_up_to(grads)
    sdt = plan.stream_dtype
    same_dtype = all(l.dtype == leaves[0].dtype for l in leaves)
    if same_dtype:
        stream = jnp.concatenate([l.reshape(-1) for l in leaves])
        if stream.dtype != sdt:
            if _kernel_on(use_kernel):
                from repro.kernels.ops import pack_cast
                stream = pack_cast(stream, sdt)
            else:
                stream = stream.astype(sdt)
    else:
        stream = jnp.concatenate(
            [l.reshape(-1).astype(sdt) for l in leaves])
    bounds = [plan.bucket_bounds(i) for i in range(plan.n_buckets)]
    return [jax.lax.slice(stream, (lo,), (hi,)) for lo, hi in bounds]


def unpack(buckets: Sequence[jax.Array], plan: BucketPlan,
           use_kernel: Optional[bool] = None,
           denom: Optional[int] = None) -> PyTree:
    """Bucket arrays -> gradient pytree (original shapes/dtypes).

    ``denom`` (the worker count for the mean) divides after the cast back
    to the accumulation dtype — the same cast-then-divide order (and the
    same division, not a reciprocal multiply) as ``compressed_psum``, so
    the two paths agree bitwise.
    """
    stream = jnp.concatenate(list(buckets))
    acc_dtypes = {s.dtype for s in plan.slots}
    if len(acc_dtypes) == 1:
        acc = next(iter(acc_dtypes))
        if stream.dtype != acc:
            if _kernel_on(use_kernel):
                from repro.kernels.ops import unpack_cast
                stream = unpack_cast(stream, acc)
            else:
                stream = stream.astype(acc)
        if denom is not None:
            stream = stream / denom
        leaves = [jax.lax.slice(stream, (s.offset,),
                                (s.offset + s.size,)).reshape(s.shape)
                  for s in plan.slots]
    else:
        leaves = []
        for s in plan.slots:
            leaf = jax.lax.slice(stream, (s.offset,),
                                 (s.offset + s.size,))
            leaf = leaf.astype(s.dtype)
            if denom is not None:
                leaf = leaf / denom
            leaves.append(leaf.reshape(s.shape))
    return jax.tree.unflatten(plan.treedef, leaves)


def bucketed_psum(grads: PyTree, axis_names: Sequence[str],
                  wire: Optional[str] = "bf16",
                  bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                  mean: bool = True,
                  plan: Optional[BucketPlan] = None,
                  use_kernel: Optional[bool] = None) -> PyTree:
    """Drop-in for ``compressed_psum`` issuing one psum per bucket.

    Same contract: cast each gradient element to the wire dtype, sum over
    the data axes, cast back, optionally divide by the worker count —
    but the interconnect sees ``plan.n_buckets`` large collectives
    instead of one per leaf.
    """
    if plan is None:
        plan = plan_buckets(grads, bucket_bytes, wire)
    # psum of a python constant folds to the static axis-size product
    n = jax.lax.psum(1, tuple(axis_names))
    buckets = pack(grads, plan, use_kernel=use_kernel)
    synced = [jax.lax.psum(b, tuple(axis_names)) for b in buckets]
    return unpack(synced, plan, use_kernel=use_kernel,
                  denom=n if mean else None)


def bucketed_psum_ef(grads: PyTree, residual: PyTree,
                     axis_names: Sequence[str],
                     wire: str = "bf16",
                     bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                     mean: bool = True,
                     plan: Optional[BucketPlan] = None,
                     use_kernel: Optional[bool] = None
                     ) -> Tuple[PyTree, PyTree]:
    """Bucketed psum with error feedback (core/compression.py) threaded
    through: q = Q(g + r) is what gets packed and reduced; r' stays
    worker-local. The residual update is identical to the per-leaf
    ``compressed_psum_ef`` path — EF happens before packing, so bucketing
    cannot change it (asserted by the bucketing tests)."""
    quant, new_residual = apply_error_feedback(grads, residual, wire)
    synced = bucketed_psum(quant, axis_names, wire=wire,
                           bucket_bytes=bucket_bytes, mean=mean,
                           plan=plan, use_kernel=use_kernel)
    return synced, new_residual
