"""Bucketed gradient all-reduce (DESIGN.md §6).

The paper's 15-minute result depends on the interconnect seeing a few
large transfers, not hundreds of small ones: gradients are chunked and
all-reduced in half precision so latency/launch overhead is amortized
(§3; the same fused all-reduce is the core of Yamazaki et al.'s 74.7 s
follow-up). ``compressed_psum`` already casts to the wire dtype but still
issues one collective per parameter leaf — 161 all-reduces per step for
ResNet-50. This module flattens the gradient pytree into one contiguous
wire-dtype stream, splits it into fixed-size buckets (default 64 MiB),
runs **one psum per bucket**, and scatters the result back to leaves.

Leaves may span bucket boundaries (the stream is split at fixed byte
offsets, not at leaf edges), so the collective count is exactly
``ceil(total_wire_bytes / bucket_bytes)`` with no fragmentation waste.

Numerics are bitwise-identical to the per-leaf path: cast-to-wire,
elementwise sum over workers, cast-back, divide — packing only changes
*where* element i sits during the reduction, never its value. The
bucketing tests assert this on a multi-device host mesh.

The cast+copy into/out of the bucket is the Pallas kernel pair in
``kernels/bucket_ops.py`` (fused, padding-aware) when ``use_kernel`` is
on (default on TPU); the pure-JAX path is the reference and the CPU
default (interpret-mode Pallas is Python-speed).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.compression import _wire, apply_error_feedback

PyTree = Any

DEFAULT_BUCKET_BYTES = 64 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class LeafSlot:
    """Where one gradient leaf lives in the packed stream."""

    offset: int  # element offset into the global flat stream
    size: int
    shape: Tuple[int, ...]
    dtype: Any  # original (accumulation) dtype, restored on unpack


@dataclasses.dataclass(frozen=True)
class BucketPlan:
    """Static layout of a gradient pytree packed into fixed buckets.

    Derived from shapes only, so one plan serves every step (it is
    closed over by the jitted train step, like the tree structure
    itself).

    ``pad_elems`` is the zero tail appended after the last leaf so the
    final bucket's length is a multiple of ``align`` — the shard-aligned
    layout the ZeRO sync mode needs (every bucket must split evenly
    across the DP ranks for ``psum_scatter``, DESIGN.md §9). The default
    ``align=1`` keeps the historical truncated-last-bucket layout
    (``pad_elems == 0``): a pad reduced over the wire for nothing.
    """

    treedef: Any
    slots: Tuple[LeafSlot, ...]
    total_elems: int
    bucket_elems: int  # elements per bucket (fixed; last one truncated)
    n_buckets: int
    wire: Optional[str]  # wire dtype name, None = no cast
    stream_dtype: Any  # wire dtype, or the (uniform) leaf dtype if None
    align: int = 1  # every bucket length is a multiple of this
    pad_elems: int = 0  # zero tail making the last bucket align-even

    @property
    def padded_total(self) -> int:
        return self.total_elems + self.pad_elems

    def bucket_bounds(self, i: int) -> Tuple[int, int]:
        """Element range of bucket ``i`` within the (padded) stream. All
        buckets are ``bucket_elems`` long except the last, which ends at
        the padded stream end (== ``total_elems`` when ``align == 1``)."""
        lo = i * self.bucket_elems
        return lo, min(lo + self.bucket_elems, self.padded_total)

    @property
    def bucket_bytes(self) -> int:
        return self.bucket_elems * jnp.dtype(self.stream_dtype).itemsize

    def describe(self) -> str:
        itemsize = jnp.dtype(self.stream_dtype).itemsize
        total_mib = self.total_elems * itemsize / 2 ** 20
        pad = f" +{self.pad_elems}pad" if self.pad_elems else ""
        return (f"{len(self.slots)} leaves / {total_mib:.1f} MiB wire "
                f"-> {self.n_buckets} bucket(s) of "
                f"<= {self.bucket_bytes / 2**20:.0f} MiB "
                f"({self.wire or 'f32'} wire{pad})")


def stream_layout(total_elems: int, bucket_bytes: int, itemsize: int,
                  align: int = 1) -> Tuple[int, int, int]:
    """The pure bucket arithmetic shared by every plan flavor: returns
    ``(bucket_elems, n_buckets, pad_elems)`` for a stream of
    ``total_elems``. Layout depends only on these scalars — never on
    leaf order — which is why the plain (pytree-order) and ready-order
    plans of the same tree have identical padded lengths and the ZeRO
    optimizer-state size can be computed without a plan."""
    if align < 1:
        raise ValueError(f"align must be >= 1, got {align}")
    bucket_elems = max(1, int(bucket_bytes) // itemsize)
    bucket_elems = -(-bucket_elems // align) * align  # round UP to align
    n_buckets = max(1, -(-total_elems // bucket_elems))
    last = total_elems - (n_buckets - 1) * bucket_elems
    pad_elems = (-last) % align
    return bucket_elems, n_buckets, pad_elems


def plan_buckets(grads: PyTree,
                 bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                 wire: Optional[str] = "bf16",
                 align: int = 1) -> BucketPlan:
    """Lay out the gradient pytree as a contiguous wire-dtype stream cut
    into fixed-size buckets. Works on arrays or ShapeDtypeStructs.
    ``align > 1`` pads every bucket to an ``align`` multiple (ZeRO)."""
    leaves, treedef = jax.tree.flatten(grads)
    if not leaves:
        raise ValueError("cannot plan buckets for an empty gradient tree")
    wdt = _wire(wire)
    if wdt is None:
        # no wire cast: the stream keeps the leaves' own dtype, so the
        # psum runs in the same precision as per-leaf wire=None sync
        leaf_dtypes = {jnp.dtype(l.dtype) for l in leaves}
        if len(leaf_dtypes) > 1:
            raise ValueError(
                "bucketing without a wire dtype needs uniform leaf "
                f"dtypes, got {sorted(d.name for d in leaf_dtypes)}; "
                "set a wire dtype (e.g. 'bf16+bucketed')")
        sdt = next(iter(leaf_dtypes))
    else:
        sdt = jnp.dtype(wdt)
    slots: List[LeafSlot] = []
    offset = 0
    for leaf in leaves:
        size = math.prod(leaf.shape)
        slots.append(LeafSlot(offset=offset, size=size,
                              shape=tuple(leaf.shape), dtype=leaf.dtype))
        offset += size
    bucket_elems, n_buckets, pad_elems = stream_layout(
        offset, bucket_bytes, sdt.itemsize, align)
    return BucketPlan(treedef=treedef, slots=tuple(slots),
                      total_elems=offset, bucket_elems=bucket_elems,
                      n_buckets=n_buckets, wire=wire, stream_dtype=sdt,
                      align=align, pad_elems=pad_elems)


def _kernel_on(use_kernel: Optional[bool]) -> bool:
    if use_kernel is None:
        return jax.default_backend() == "tpu"
    return use_kernel


def _cast_stream(leaves: List[jax.Array], sdt,
                 use_kernel: Optional[bool]) -> jax.Array:
    """Flatten leaves into one wire-dtype stream. The cast happens on
    the whole stream (fused Pallas cast+copy when ``use_kernel``),
    which is elementwise-identical to casting each leaf before
    concatenation — the bitwise guarantee the tests pin down. Shared by
    ``pack`` (full tree) and ``pack_bucket`` (one stage), so the two
    paths can never drift apart."""
    if not leaves:
        return jnp.zeros((0,), sdt)
    same_dtype = all(l.dtype == leaves[0].dtype for l in leaves)
    if same_dtype:
        stream = jnp.concatenate([l.reshape(-1) for l in leaves])
        if stream.dtype != sdt:
            if _kernel_on(use_kernel):
                from repro.kernels.ops import pack_cast
                stream = pack_cast(stream, sdt)
            else:
                stream = stream.astype(sdt)
        return stream
    return jnp.concatenate([l.reshape(-1).astype(sdt) for l in leaves])


def pack(grads: PyTree, plan: BucketPlan,
         use_kernel: Optional[bool] = None) -> List[jax.Array]:
    """Gradient pytree -> list of ``n_buckets`` wire-dtype bucket arrays
    (``_cast_stream`` + fixed-offset slicing; shard-aligned plans get
    their zero tail here)."""
    leaves = plan.treedef.flatten_up_to(grads)
    stream = _cast_stream(leaves, plan.stream_dtype, use_kernel)
    if plan.pad_elems:
        stream = jnp.concatenate(
            [stream, jnp.zeros((plan.pad_elems,), plan.stream_dtype)])
    bounds = [plan.bucket_bounds(i) for i in range(plan.n_buckets)]
    return [jax.lax.slice(stream, (lo,), (hi,)) for lo, hi in bounds]


def unpack(buckets: Sequence[jax.Array], plan: BucketPlan,
           use_kernel: Optional[bool] = None,
           denom: Optional[int] = None,
           with_sq_norm: bool = False):
    """Bucket arrays -> gradient pytree (original shapes/dtypes).

    ``denom`` (the worker count for the mean) divides after the cast back
    to the accumulation dtype — the same cast-then-divide order (and the
    same division, not a reciprocal multiply) as ``compressed_psum``, so
    the two paths agree bitwise.

    ``with_sq_norm=True`` additionally returns the squared L2 norm of
    the whole (cast-back, divided) gradient stream, computed in one
    fused pass over the contiguous stream — this is how the sync paths
    report ``grad_norm`` without a second full-tree reduction
    (DESIGN.md §8).
    """
    stream = jnp.concatenate(list(buckets))
    sq_norm = None
    acc_dtypes = {s.dtype for s in plan.slots}
    if len(acc_dtypes) == 1:
        acc = next(iter(acc_dtypes))
        if stream.dtype != acc:
            if _kernel_on(use_kernel):
                from repro.kernels.ops import unpack_cast
                stream = unpack_cast(stream, acc)
            else:
                stream = stream.astype(acc)
        if denom is not None:
            stream = stream / denom
        if with_sq_norm:
            sq_norm = jnp.sum(jnp.square(stream.astype(jnp.float32)))
        leaves = [jax.lax.slice(stream, (s.offset,),
                                (s.offset + s.size,)).reshape(s.shape)
                  for s in plan.slots]
    else:
        leaves = []
        for s in plan.slots:
            leaf = jax.lax.slice(stream, (s.offset,),
                                 (s.offset + s.size,))
            leaf = leaf.astype(s.dtype)
            if denom is not None:
                leaf = leaf / denom
            leaves.append(leaf.reshape(s.shape))
        if with_sq_norm:
            sq_norm = sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                          for l in leaves)
    tree = jax.tree.unflatten(plan.treedef, leaves)
    return (tree, sq_norm) if with_sq_norm else tree


def bucketed_psum(grads: PyTree, axis_names: Sequence[str],
                  wire: Optional[str] = "bf16",
                  bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                  mean: bool = True,
                  plan: Optional[BucketPlan] = None,
                  use_kernel: Optional[bool] = None,
                  with_sq_norm: bool = False,
                  hierarchy: Optional["Hierarchy"] = None):
    """Drop-in for ``compressed_psum`` issuing one psum per bucket.

    Same contract: cast each gradient element to the wire dtype, sum over
    the data axes, cast back, optionally divide by the worker count —
    but the interconnect sees ``plan.n_buckets`` large collectives
    instead of one per leaf. ``with_sq_norm=True`` returns
    ``(grads, sq_norm)`` with the synced gradients' squared L2 norm from
    one pass over the stream (see ``unpack``).

    ``hierarchy`` replaces each bucket's flat psum with the two-level
    reduce-scatter → all-reduce → all-gather schedule of DESIGN.md §14
    (``hierarchical_psum``); the plan is then laid out shard-aligned
    (``align = hierarchy.n_workers``) so every bucket splits evenly
    across the inner axis.
    """
    if plan is None:
        align = hierarchy.n_workers if hierarchy is not None else 1
        plan = plan_buckets(grads, bucket_bytes, wire, align=align)
    # psum of a python constant folds to the static axis-size product
    n = jax.lax.psum(1, tuple(axis_names))
    buckets = pack(grads, plan, use_kernel=use_kernel)
    if hierarchy is not None:
        synced = [hierarchical_psum(b, hierarchy) for b in buckets]
    else:
        synced = [jax.lax.psum(b, tuple(axis_names)) for b in buckets]
    return unpack(synced, plan, use_kernel=use_kernel,
                  denom=n if mean else None, with_sq_norm=with_sq_norm)


def bucketed_psum_ef(grads: PyTree, residual: PyTree,
                     axis_names: Sequence[str],
                     wire: str = "bf16",
                     bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                     mean: bool = True,
                     plan: Optional[BucketPlan] = None,
                     use_kernel: Optional[bool] = None,
                     with_sq_norm: bool = False,
                     hierarchy: Optional["Hierarchy"] = None):
    """Bucketed psum with error feedback (core/compression.py) threaded
    through: q = Q(g + r) is what gets packed and reduced; r' stays
    worker-local. The residual update is identical to the per-leaf
    ``compressed_psum_ef`` path — EF happens before packing, so bucketing
    cannot change it (asserted by the bucketing tests). With
    ``with_sq_norm`` returns ``(synced, new_residual, sq_norm)``."""
    quant, new_residual = apply_error_feedback(grads, residual, wire)
    out = bucketed_psum(quant, axis_names, wire=wire,
                        bucket_bytes=bucket_bytes, mean=mean,
                        plan=plan, use_kernel=use_kernel,
                        with_sq_norm=with_sq_norm, hierarchy=hierarchy)
    if with_sq_norm:
        synced, sq_norm = out
        return synced, new_residual, sq_norm
    return out, new_residual


# ---------------------------------------------------------------------------
# Ready-order bucketing (backward-overlapped sync, DESIGN.md §8)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReadyBucketPlan:
    """A ``BucketPlan`` whose stream is laid out in backward-completion
    order: the stage trees are given in the order the backward pass
    *produces* them (last forward segment first), so every bucket's
    element range is a contiguous run of already-materialized gradients
    and the bucket closes the moment its completing stage's VJP finishes
    — not when the full backward ends.

    ``ready_stage[b]`` is the index (into the ready-ordered stage list)
    of the stage whose gradients complete bucket ``b``; it is
    non-decreasing in ``b`` by construction.
    """

    base: BucketPlan  # treedef = tuple(stage trees, ready order)
    stage_ends: Tuple[int, ...]  # cumulative element end offset per stage
    ready_stage: Tuple[int, ...]  # per bucket

    @property
    def n_buckets(self) -> int:
        return self.base.n_buckets

    @property
    def n_stages(self) -> int:
        return len(self.stage_ends)

    def buckets_ready_at(self, stage_idx: int) -> Tuple[int, ...]:
        return tuple(b for b, s in enumerate(self.ready_stage)
                     if s == stage_idx)

    def describe(self) -> str:
        return (f"{self.base.describe()} over {self.n_stages} stages, "
                f"ready stages {list(self.ready_stage)}")


def plan_ready_buckets(stage_trees: Sequence[PyTree],
                       bucket_bytes: int = DEFAULT_BUCKET_BYTES,
                       wire: Optional[str] = "bf16",
                       align: int = 1) -> ReadyBucketPlan:
    """Lay out per-stage gradient trees (given in backward-completion
    order) as one contiguous stream cut into fixed-size buckets.

    The element values and the per-bucket psum contract are identical to
    ``plan_buckets`` — only *where* each leaf sits in the stream changes
    (completion order instead of pytree order), which is exactly what
    makes overlap possible and exactly what cannot change numerics
    (elementwise cast/sum/cast/divide is position-independent). The
    shard-aligned tail (``align > 1``, ZeRO) belongs to the last bucket,
    so it closes at the same stage as the last real gradient element."""
    stage_trees = tuple(stage_trees)
    if not stage_trees:
        raise ValueError("need at least one stage tree")
    base = plan_buckets(stage_trees, bucket_bytes, wire, align=align)
    ends: List[int] = []
    off = 0
    for t in stage_trees:
        off += sum(math.prod(l.shape) for l in jax.tree.leaves(t))
        ends.append(off)
    assert off == base.total_elems
    ready = []
    for b in range(base.n_buckets):
        _, hi = base.bucket_bounds(b)
        # first stage whose cumulative end covers the bucket's last REAL
        # element (the zero tail of a shard-aligned plan needs no stage)
        hi_real = min(hi, base.total_elems)
        stage = next(i for i, e in enumerate(ends) if e >= hi_real)
        ready.append(stage)
    return ReadyBucketPlan(base=base, stage_ends=tuple(ends),
                           ready_stage=tuple(ready))


def pack_bucket(plan: ReadyBucketPlan, stage_idx: int,
                stage_tree: PyTree, carry: Optional[jax.Array] = None,
                use_kernel: Optional[bool] = None
                ) -> Tuple[List[Tuple[int, jax.Array]], jax.Array]:
    """Feed stage ``stage_idx``'s just-materialized gradients; returns
    ``(ready, carry')`` where ``ready`` is the list of
    ``(bucket_id, wire_array)`` buckets that *closed* at this stage (its
    gradients were their last missing elements) and ``carry'`` is the
    unemitted tail awaiting later stages.

    Stages must be fed in ready order (0, 1, ...). All shapes are static
    — the carry length after each stage is a plan constant — so the
    emission loop unrolls cleanly under jit inside the backward chain
    (training/step.py:make_dp_overlap_train_step, DESIGN.md §8)."""
    flat = _cast_stream(jax.tree.leaves(stage_tree),
                        plan.base.stream_dtype, use_kernel)
    carry_len = 0 if carry is None else carry.shape[0]
    fed_end = plan.stage_ends[stage_idx]
    flat_start = fed_end - flat.shape[0]
    stream_start = flat_start - carry_len

    # lazily materialize carry++flat only for carry-spanning buckets;
    # buckets interior to this stage slice straight out of ``flat``
    joined = None

    def view(lo, hi):
        nonlocal joined
        if lo >= flat_start:
            return jax.lax.slice(flat, (lo - flat_start,),
                                 (hi - flat_start,))
        if joined is None:
            joined = jnp.concatenate([carry, flat])
        return jax.lax.slice(joined, (lo - stream_start,),
                             (hi - stream_start,))

    ready = []
    emitted_end = stream_start
    for b in plan.buckets_ready_at(stage_idx):
        lo, hi = plan.base.bucket_bounds(b)
        # a shard-aligned plan's final bucket extends past the last real
        # element; ONLY that alignment tail may be zero-filled here — a
        # bucket marked ready before its last real element is fed must
        # still trip the assert, never sync zeros in its place
        hi_real = min(hi, plan.base.total_elems)
        assert lo >= stream_start and hi_real <= fed_end, (b, lo, hi)
        arr = view(lo, hi_real)
        if hi > hi_real:
            arr = jnp.concatenate(
                [arr, jnp.zeros((hi - hi_real,), plan.base.stream_dtype)])
        ready.append((b, arr))
        emitted_end = hi_real
    new_carry = view(emitted_end, fed_end)
    return ready, new_carry


# ---------------------------------------------------------------------------
# ZeRO shard layout (reduce-scatter sync mode, DESIGN.md §9)
# ---------------------------------------------------------------------------
#
# With a shard-aligned plan (``align = n_shards``) every bucket splits
# evenly across the DP ranks, so ``psum_scatter`` hands worker ``w`` the
# contiguous chunk ``[lo_b + w*c_b, lo_b + (w+1)*c_b)`` of each reduced
# bucket. A worker's *shard* is the concatenation of its per-bucket
# chunks (bucket order), and the *shard layout* of the whole stream is
# the worker-major concatenation of all shards — the layout the sharded
# optimizer state (delta/m) lives in, and the layout the checkpoint
# resharding path (optim/stream.py) converts from/to.


def shard_chunks(plan: BucketPlan, n_shards: int) -> Tuple[int, ...]:
    """Per-bucket chunk length owned by each of ``n_shards`` workers."""
    sizes = []
    for b in range(plan.n_buckets):
        lo, hi = plan.bucket_bounds(b)
        if (hi - lo) % n_shards:
            raise ValueError(
                f"bucket {b} has {hi - lo} elements, not divisible by "
                f"{n_shards} shards; plan with align={n_shards}")
        sizes.append((hi - lo) // n_shards)
    return tuple(sizes)


def shard_size(plan: BucketPlan, n_shards: int) -> int:
    """Elements per worker shard (== padded_total / n_shards)."""
    return sum(shard_chunks(plan, n_shards))


def local_shard(stream: jax.Array, plan: BucketPlan, n_shards: int,
                shard_idx) -> jax.Array:
    """Worker ``shard_idx``'s shard of a full packed (padded) stream —
    the concatenation of its per-bucket chunks. ``shard_idx`` may be a
    traced scalar (``jax.lax.axis_index`` inside shard_map)."""
    parts = []
    for b, c in enumerate(shard_chunks(plan, n_shards)):
        lo, _ = plan.bucket_bounds(b)
        parts.append(jax.lax.dynamic_slice(stream, (lo + shard_idx * c,),
                                           (c,)))
    return jnp.concatenate(parts) if len(parts) > 1 else parts[0]


def split_shard(shard: jax.Array, plan: BucketPlan,
                n_shards: int) -> List[jax.Array]:
    """Inverse bookkeeping of ``local_shard``: cut a worker shard back
    into its per-bucket chunks (static offsets)."""
    chunks = shard_chunks(plan, n_shards)
    out, off = [], 0
    for c in chunks:
        out.append(jax.lax.slice(shard, (off,), (off + c,)))
        off += c
    return out


def shard_perm(plan: BucketPlan, n_shards: int):
    """Gather indices ``perm`` with ``shard_layout = stream[perm]``:
    worker-major, bucket order within each worker. A plain numpy array —
    the permutation is a plan constant used host-side by the checkpoint
    resharding path."""
    import numpy as np

    idx = []
    chunks = shard_chunks(plan, n_shards)
    for w in range(n_shards):
        for b, c in enumerate(chunks):
            lo, _ = plan.bucket_bounds(b)
            idx.append(np.arange(lo + w * c, lo + (w + 1) * c))
    return np.concatenate(idx)


def stream_to_shard_layout(arr, plan: BucketPlan, n_shards: int):
    """Reorder a padded-stream-order array into shard layout."""
    return arr[shard_perm(plan, n_shards)]


def shard_layout_to_stream(arr, plan: BucketPlan, n_shards: int):
    """Inverse of ``stream_to_shard_layout``."""
    import numpy as np

    return arr[np.argsort(shard_perm(plan, n_shards), kind="stable")]


# ---------------------------------------------------------------------------
# Hierarchical collective schedules (topology-aware sync, DESIGN.md §14)
# ---------------------------------------------------------------------------
#
# Over a multi-axis DP mesh (e.g. ("node", "device")) a flat psum makes
# every transfer cross the slowest link. The 2D-torus schedule of
# Yamazaki et al. (arXiv:1903.12650) — and the host-level reduction of
# Goyal et al. (arXiv:1706.02677) — instead runs, per bucket:
#
#   intra-axis reduce-scatter  (cheap links, full bucket)
#   inter-axis all-reduce      (expensive links, 1/inner_size shard)
#   intra-axis all-gather      (cheap links, full bucket)
#
# so the expensive inter-node link carries ``1/inner_size`` of the bucket
# instead of all of it. Ranks are linearized row-major over the DP axis
# tuple — ``w = outer_lin * inner_size + inner_lin`` — exactly the
# ``_dp_linear_index`` order (training/step.py), which is what lets the
# ZeRO double-scatter below hand every worker the *same* chunk the flat
# ``psum_scatter`` would (after the ``inner_major_perm`` pre-permutation)
# and keeps param slicing, optimizer-state layout and checkpoint
# resharding untouched.
#
# Numerics: the bucket is accumulated in f32 throughout both stages and
# rounded to the wire dtype exactly once ("round-once"), so the result is
# association-stable at wire precision — equal to the flat collective
# bitwise whenever the additions are order-exact (the property tests and
# the slow collective battery pin this with exponent-bounded data), to
# last-ulp otherwise. A reassociated reduction can never be
# *unconditionally* bitwise-identical to the flat fold (DESIGN.md §14);
# the f32 accumulator is what pins the difference to rounding-boundary
# ulps instead of wire-precision drift.


@dataclasses.dataclass(frozen=True)
class Hierarchy:
    """Static spec of a two-level collective schedule over the DP axes.

    ``outer`` are the inter-node (expensive) mesh axes, ``inner`` the
    intra-node (cheap) ones; the flat DP rank is the row-major
    linearization ``w = outer_lin * inner_size + inner_lin`` — the same
    order ``_dp_linear_index`` and a flat ``psum_scatter`` over the full
    axis tuple use.
    """

    outer: Tuple[str, ...]
    inner: Tuple[str, ...]
    outer_size: int
    inner_size: int

    @property
    def n_workers(self) -> int:
        return self.outer_size * self.inner_size

    def describe(self) -> str:
        return (f"hier[{'x'.join(self.outer)}({self.outer_size}) | "
                f"{'x'.join(self.inner)}({self.inner_size})]")


def make_hierarchy(dp_axes: Sequence[str], mesh_shape,
                   split: int) -> Hierarchy:
    """Split ``dp_axes`` into outer ``dp_axes[:split]`` / inner
    ``dp_axes[split:]``. ``mesh_shape`` maps axis name -> size (a
    ``Mesh.shape`` mapping works as-is). Both factors must be real
    (size >= 2): a size-1 stage is a flat collective wearing a costume —
    callers should fall back to flat instead (comm_plan.py does)."""
    dp_axes = tuple(dp_axes)
    if not 1 <= split < len(dp_axes):
        raise ValueError(
            f"hier_split must be in [1, {len(dp_axes) - 1}] for dp_axes "
            f"{dp_axes}, got {split}")
    outer, inner = dp_axes[:split], dp_axes[split:]
    outer_size = math.prod(int(mesh_shape[a]) for a in outer)
    inner_size = math.prod(int(mesh_shape[a]) for a in inner)
    if outer_size < 2 or inner_size < 2:
        raise ValueError(
            f"hierarchical schedule needs both stages >= 2 ranks, got "
            f"outer={outer}:{outer_size} inner={inner}:{inner_size}; "
            "use the flat schedule on this mesh")
    return Hierarchy(outer=outer, inner=inner,
                     outer_size=outer_size, inner_size=inner_size)


def inner_major_perm(x, outer_size: int, inner_size: int):
    """Reorder a flat stream so the hierarchical double reduce-scatter
    (inner stage first) hands rank ``w = n*inner_size + d`` exactly the
    chunk the flat ``psum_scatter`` would: viewing the stream as
    ``n_workers`` chunks, chunk ``w = n*b + d`` must land in inner
    position ``d``, outer position ``n`` — i.e. the stream is re-laid
    inner-major. Works on numpy and jax arrays (pure reshape/transpose),
    so the Hypothesis property tests reuse it verbatim."""
    a, b = outer_size, inner_size
    c = x.shape[0] // (a * b)
    return x.reshape(a, b, c).transpose(1, 0, 2).reshape(-1)


def inner_major_unperm(x, outer_size: int, inner_size: int):
    """Inverse of ``inner_major_perm`` (used after the two-level
    all-gather to restore stream order)."""
    a, b = outer_size, inner_size
    c = x.shape[0] // (a * b)
    return x.reshape(b, a, c).transpose(1, 0, 2).reshape(-1)


def hierarchical_psum(bucket: jax.Array, hier: Hierarchy) -> jax.Array:
    """Two-level all-reduce of one packed bucket: f32 reduce-scatter over
    the inner axes, f32 all-reduce over the outer axes on the
    ``1/inner_size`` shard, one rounding to the bucket dtype, all-gather
    back over the inner axes. The bucket length must be a multiple of
    ``inner_size`` (a plan with ``align = hier.n_workers`` guarantees
    it)."""
    if bucket.shape[0] % hier.inner_size:
        raise ValueError(
            f"bucket of {bucket.shape[0]} elements does not split over "
            f"{hier.inner_size} inner ranks; plan with "
            f"align={hier.n_workers}")
    wire_dt = bucket.dtype
    shard = jax.lax.psum_scatter(bucket.astype(jnp.float32), hier.inner,
                                 scatter_dimension=0, tiled=True)
    shard = jax.lax.psum(shard, hier.outer)
    return jax.lax.all_gather(shard.astype(wire_dt), hier.inner,
                              axis=0, tiled=True)


def hierarchical_psum_scatter(bucket: jax.Array,
                              hier: Hierarchy) -> jax.Array:
    """Two-level reduce-scatter of one packed bucket (ZeRO sync): after
    the ``inner_major_perm`` pre-permutation, the inner then outer f32
    reduce-scatters leave rank ``w = n*inner_size + d`` holding exactly
    the flat ``psum_scatter`` chunk ``w`` — shard ownership, and with it
    ``_dp_linear_index`` param slicing and the sharded optimizer-state
    layout, are unchanged by the hierarchy. Rounds to the bucket dtype
    once, after both reduction stages."""
    if bucket.shape[0] % hier.n_workers:
        raise ValueError(
            f"bucket of {bucket.shape[0]} elements does not split over "
            f"{hier.n_workers} ranks; plan with align={hier.n_workers}")
    f = inner_major_perm(bucket.astype(jnp.float32),
                         hier.outer_size, hier.inner_size)
    s = jax.lax.psum_scatter(f, hier.inner, scatter_dimension=0,
                             tiled=True)
    s = jax.lax.psum_scatter(s, hier.outer, scatter_dimension=0,
                             tiled=True)
    return s.astype(bucket.dtype)


def hierarchical_all_gather(shard: jax.Array,
                            hier: Hierarchy) -> jax.Array:
    """Two-level inverse of the flat ``all_gather`` over all DP axes:
    gather over the outer axes, then the inner axes, then undo the
    inner-major layout. Pure data movement (dtype-preserving), so it is
    bitwise-identical to the flat gather for any input."""
    g = jax.lax.all_gather(shard, hier.outer, axis=0, tiled=True)
    g = jax.lax.all_gather(g, hier.inner, axis=0, tiled=True)
    return inner_major_unperm(g, hier.outer_size, hier.inner_size)


# ---------------------------------------------------------------------------
# Leaf-segment map (stream-layout LARS trust ratios, DESIGN.md §11)
# ---------------------------------------------------------------------------
#
# LARS needs per-leaf ||p||/||g|| over the *packed* stream: segment id i
# marks every element of plan.slots[i]; the shard-alignment pad gets its
# own trailing id len(slots), so it can never contaminate a real leaf's
# norm. Per-segment squared norms are ``jax.ops.segment_sum`` reductions
# — the one reduction primitive shared by the per-leaf reference
# optimizer (optim/lars.py) and every stream path, which is what keeps
# the two bitwise in lockstep on identical operands (CPU/TPU sums are
# fold-order-sensitive; tests/test_lars_stream.py pins the equality).


def segment_ids_stream(plan: BucketPlan):
    """int32[padded_total] mapping each stream position to its leaf index
    in ``plan.slots`` order; the alignment pad maps to the extra trailing
    segment ``len(plan.slots)`` (never trusted, never decayed)."""
    import numpy as np

    ids = np.full((plan.padded_total,), len(plan.slots), np.int32)
    for i, s in enumerate(plan.slots):
        ids[s.offset:s.offset + s.size] = i
    return ids


def segment_sq_partials(x: jax.Array, seg_ids, num_segments: int
                        ) -> jax.Array:
    """f32[num_segments] per-segment sums of squares of flat ``x``.

    ``x``/``seg_ids`` may be the full padded stream or any sub-slice of
    it (a ZeRO worker shard): segment_sum accumulates each segment
    independently of where its elements sit, so psum'ing per-shard
    partials over the DP axes recovers the full-stream per-leaf norms —
    exactly when the additions are order-exact (the Hypothesis property
    test pins this with power-of-two data), to last-ulp otherwise
    (which is why cross-decomposition parity is allclose, not bitwise;
    DESIGN.md §11)."""
    return jax.ops.segment_sum(
        jnp.square(x.astype(jnp.float32)),
        jnp.asarray(seg_ids), num_segments=num_segments)
