"""Logical-axis sharding rules (MaxText-style) + activation constraints.

This is the GSPMD sync mode's half of the distribution layer
(DESIGN.md §3): collectives are placed by XLA from these shardings,
with the gradient wire dtype simulated at the sync boundary
(core/compression.py, DESIGN.md §2). The explicit shard_map modes
(per-leaf and bucketed psum, DESIGN.md §6) live in training/step.py and
distributed/bucketing.py.

Models tag every parameter dim and activation with *logical* axis names
("embed", "heads", "ffn", "experts", "vocab", "batch", "seq", ...). This
module maps logical names onto physical mesh axes with divisibility-aware
fallbacks, producing NamedShardings for params and
``with_sharding_constraint`` hooks for activations.

The mapping is where the parallelism design lives:
  DP   : "batch"  -> ("pod", "data")
  TP   : "heads"/"ffn"/"vocab" -> "model" (Megatron-style)
  EP   : "experts" -> "model" when n_experts % model == 0, else experts
         stay local and "ffn" carries the model axis (TP inside experts)
  FSDP : "embed" -> "data" (ZeRO-3-style weight sharding, beyond paper)
  SP   : "seq" -> "model" for long-context activations (optional)
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ParallelConfig

PyTree = Any

_STATE = threading.local()


def _axis_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n


def make_rules(cfg: ModelConfig, mesh: Mesh,
               parallel: ParallelConfig) -> Dict[str, Any]:
    """Logical-axis -> mesh-axis rules for one (arch, mesh, parallel) cell.

    Values are mesh-axis names (or tuples). Divisibility fallbacks are
    resolved here, per architecture, so the model code stays generic.
    """
    tp = parallel.tp_axis
    tp_size = _axis_size(mesh, tp) if tp else 1
    dp_axes = tuple(a for a in parallel.dp_axes if a in mesh.shape)
    if "pod" in mesh.shape and "pod" not in dp_axes:
        dp_axes = ("pod",) + dp_axes

    rules: Dict[str, Any] = {
        "batch": dp_axes,
        "layers": None,
        "head_dim": None,
        "seq": None,
        "kv_seq": None,
        "conv_spatial": None,
        "stats": None,
    }

    def divisible(n: int) -> bool:
        return tp_size > 1 and n > 0 and n % tp_size == 0

    rules["vocab"] = tp if divisible(cfg.vocab_size) else None
    rules["heads"] = tp if divisible(cfg.n_heads) else None
    rules["kv_heads"] = tp if divisible(cfg.n_kv_heads) else None
    rules["ffn"] = tp if divisible(cfg.d_ff) else None

    if cfg.n_experts:
        if divisible(cfg.n_experts):
            rules["experts"] = tp  # EP: expert dim over model axis
        else:
            rules["experts"] = None  # TP inside each expert instead
        # "ffn" keeps tp too; duplicate mesh axes are dropped per-tensor
        # (experts wins on the expert weights, ffn wins elsewhere).

    # FSDP / ZeRO-3-style parameter sharding over the data axes.
    if parallel.fsdp_params:
        fsdp = dp_axes
        rules["embed"] = fsdp if cfg.d_model % _axis_size(mesh, fsdp) == 0 else None
    else:
        rules["embed"] = None

    # Fallback for archs whose head count does not divide tp (llama4: 40H):
    # shard attention weights' embed dim on the model axis instead, so the
    # attention params still get TP-sharded (FSDP-over-model style gather),
    # and run the attention *computation* batch-parallel over the
    # otherwise-idle model axis ("attn_batch"): attention has no
    # cross-batch interaction, so the batch dim can absorb the model axis
    # — 16x less redundant score compute/memory at the cost of one
    # resharding per attention in/out (§Perf llama4 iteration 3; the
    # seq-sharding variant was refuted — it fights the chunked scan).
    # NOTE (§Perf llama4 iterations 2-3, both refuted): sharding the
    # replicated attention over seq ("context parallel") or folding the
    # model axis into the batch dim both lower to catastrophic
    # gather-based reshardings in this XLA SPMD version ("Involuntary
    # full rematerialization"). The effective fix is a (data=32, model=8)
    # re-mesh so 40 heads shard evenly — see mesh.py:preferred_mesh.
    rules["attn_batch"] = rules["batch"]
    if cfg.n_heads and not divisible(cfg.n_heads) and cfg.d_model and \
            divisible(cfg.d_model):
        emb = rules["embed"]
        if emb is None:
            rules["embed"] = tp
        elif isinstance(emb, tuple) and tp not in emb:
            rules["embed"] = emb + (tp,)

    # Sequence parallelism for activations (long-context cells).
    if parallel.sequence_sharding and tp:
        rules["seq"] = tp

    # Serve cells: shard the KV-cache sequence dim on the model axis when
    # kv heads can't shard (GQA kv < tp) — the decode scores then reduce
    # over the model axis (sequence-sharded KV decode).
    if parallel.kv_seq_sharding:
        target = tp if tp else ("model" if "model" in mesh.shape else None)
        kv_ok = cfg.n_kv_heads and tp and cfg.n_kv_heads % tp_size == 0
        if target and not kv_ok:
            rules["kv_seq"] = target

    # Conv nets (ResNet-50, the paper's own arch): pure DP — the paper's
    # regime. Channels stay replicated unless fsdp_params.
    rules["conv_in"] = None
    rules["conv_out"] = dp_axes if parallel.fsdp_params else None

    # xLSTM / Mamba inner dims.
    rules["inner"] = tp if divisible(cfg.ssm_expand * cfg.d_model) else None
    rules["ssm_state"] = None
    rules["ssm_heads"] = None

    return rules


def spec_for(axes: Sequence[Optional[str]], rules: Dict[str, Any]) -> P:
    """Build a PartitionSpec, dropping mesh axes already used upstream."""
    used = set()
    out = []
    for name in axes:
        mesh_axes = rules.get(name) if name else None
        if mesh_axes is None:
            out.append(None)
            continue
        if isinstance(mesh_axes, str):
            mesh_axes = (mesh_axes,)
        fresh = tuple(a for a in mesh_axes if a not in used)
        used.update(fresh)
        out.append(fresh if len(fresh) > 1 else (fresh[0] if fresh else None))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def prune_spec(shape, spec: P, mesh: Mesh) -> P:
    """Per-dim divisibility pruning: trim mesh axes from each dim's spec
    entry (right-to-left) until the dim divides evenly; never replicates
    more than necessary."""
    entries = list(tuple(spec)) + [None] * (len(shape) - len(tuple(spec)))
    out = []
    for dim, e in zip(shape, entries):
        if e is None:
            out.append(None)
            continue
        axes = (e,) if isinstance(e, str) else tuple(e)
        while axes:
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            if dim % size == 0:
                break
            axes = axes[:-1]
        out.append(None if not axes else
                   (axes[0] if len(axes) == 1 else axes))
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_specs(axes_tree: PyTree, rules: Dict[str, Any]) -> PyTree:
    is_axes = lambda x: isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x
    )
    return jax.tree.map(lambda a: spec_for(a, rules), axes_tree, is_leaf=is_axes)


def tree_shardings(axes_tree: PyTree, mesh: Mesh, rules: Dict[str, Any]) -> PyTree:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs(axes_tree, rules)
    )


# ---------------------------------------------------------------------------
# Activation constraint context (used inside model code)
# ---------------------------------------------------------------------------


@contextlib.contextmanager
def activation_sharding(mesh: Mesh, rules: Dict[str, Any]):
    """While active, ``constrain(x, axes)`` pins activation shardings."""
    prev = getattr(_STATE, "ctx", None)
    _STATE.ctx = (mesh, rules)
    try:
        yield
    finally:
        _STATE.ctx = prev


def constrain(x: jax.Array, axes: Sequence[Optional[str]]) -> jax.Array:
    """Apply with_sharding_constraint if a sharding context is active.

    Divisibility guard: per-dim axis pruning (prune_spec) — a dim that
    doesn't divide the full axis product keeps the largest divisible
    prefix instead of collapsing to replicated (which would make XLA
    all-gather the tensor). Keeps one model code path valid for smoke
    tests (1 device) and production meshes.
    """
    ctx = getattr(_STATE, "ctx", None)
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = prune_spec(x.shape, spec_for(axes, rules), mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec)
    )


def current_rules() -> Optional[Dict[str, Any]]:
    ctx = getattr(_STATE, "ctx", None)
    return ctx[1] if ctx else None
