"""Persisted communication plans (DESIGN.md §14).

The comm autotuner (benchmarks/comm_bench.py) sweeps bucket size x wire
dtype x sync mode x hierarchy split on a host-device mesh and persists
the winning configuration as a small JSON plan. The training CLI picks
it up with ``--comm-plan``:

    --comm-plan flat        force the flat single-stage schedule
    --comm-plan hier[:k]    hierarchical schedule, split dp_axes at k
                            (default 1) without consulting any file
    --comm-plan auto        load results/comm_plan_{arch}_{AxB}.json for
                            the current mesh; fall back to flat (with a
                            warning) when the plan is missing, stale, or
                            was tuned for a different mesh
    --comm-plan <path>      load an explicit plan file; same fallback

A loaded plan carries the full wire configuration (sync mode, wire
dtype, bucket size, hierarchy split), so ``auto`` reproduces exactly
what the autotuner measured. The grammar forms ``flat``/``hier[:k]``
only reschedule the collectives and leave the rest of the CLI flags
alone.
"""
from __future__ import annotations

import dataclasses
import json
import os
import warnings
from typing import Optional, Tuple

PLAN_VERSION = 1

#: sync modes a plan may name; mirrors the train CLI flag combinations
#: (overlap_comm / zero_dp), see benchmarks/comm_bench.py
SYNC_MODES = ("bucketed", "overlap", "zero", "zero_overlap")


@dataclasses.dataclass(frozen=True)
class CommPlan:
    """One persisted gradient-sync configuration for one mesh."""

    mesh_shape: Tuple[int, ...]      # device count per mesh axis
    dp_axes: Tuple[str, ...]         # DP axis names, mesh order
    sync_mode: str                   # one of SYNC_MODES
    wire: str                        # wire dtype short name: bf16 | f16
    bucket_bytes: int
    hier_split: Optional[int]        # None = flat schedule
    source: str = "manual"           # "autotuner" | "manual"
    version: int = PLAN_VERSION

    def __post_init__(self):
        if self.sync_mode not in SYNC_MODES:
            raise ValueError(
                f"sync_mode {self.sync_mode!r} not in {SYNC_MODES}")
        if self.hier_split is not None:
            if not 1 <= self.hier_split < len(self.dp_axes):
                raise ValueError(
                    f"hier_split={self.hier_split} must split "
                    f"dp_axes={self.dp_axes} into two non-empty stages")

    @property
    def compression(self) -> str:
        """The --compression string this plan implies."""
        return self.wire + "+bucketed"

    def describe(self) -> str:
        mesh = "x".join(str(s) for s in self.mesh_shape)
        sched = ("flat" if self.hier_split is None
                 else f"hier:{self.hier_split}")
        return (f"{self.sync_mode} {self.wire} "
                f"{self.bucket_bytes // 1024}KiB {sched} on {mesh}")


def plan_path(arch: str, mesh_shape: Tuple[int, ...],
              out_dir: str = "results") -> str:
    """Canonical persistence path: results/comm_plan_{arch}_{AxB}.json."""
    mesh = "x".join(str(s) for s in mesh_shape)
    return os.path.join(out_dir, f"comm_plan_{arch}_{mesh}.json")


def save_plan(plan: CommPlan, path: str) -> str:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(dataclasses.asdict(plan), f, indent=1)
    return path


def load_plan(path: str) -> CommPlan:
    with open(path) as f:
        raw = json.load(f)
    version = raw.get("version")
    if version != PLAN_VERSION:
        raise StaleCommPlan(
            f"comm plan {path} has version {version!r}, "
            f"expected {PLAN_VERSION}")
    try:
        return CommPlan(
            mesh_shape=tuple(raw["mesh_shape"]),
            dp_axes=tuple(raw["dp_axes"]),
            sync_mode=raw["sync_mode"],
            wire=raw["wire"],
            bucket_bytes=int(raw["bucket_bytes"]),
            hier_split=raw["hier_split"],
            source=raw.get("source", "manual"),
            version=version,
        )
    except (KeyError, TypeError, ValueError) as e:
        raise StaleCommPlan(f"comm plan {path} is malformed: {e}") from e


class StaleCommPlan(Exception):
    """Plan file exists but cannot be used (old schema / malformed)."""


class CommPlanWarning(UserWarning):
    """A comm plan was requested but could not be applied; fell back
    to the flat schedule."""


def _check_mesh(plan: CommPlan, mesh_shape: Tuple[int, ...],
                dp_axes: Tuple[str, ...]) -> Optional[str]:
    """None if the plan matches this run's topology, else the reason."""
    if tuple(plan.mesh_shape) != tuple(mesh_shape):
        return (f"plan was tuned for mesh "
                f"{'x'.join(map(str, plan.mesh_shape))}, this run has "
                f"{'x'.join(map(str, mesh_shape))}")
    if tuple(plan.dp_axes) != tuple(dp_axes):
        return (f"plan DP axes {plan.dp_axes} != run DP axes {dp_axes}")
    return None


def resolve_comm_plan(spec: str, *, arch: str,
                      mesh_shape: Tuple[int, ...],
                      dp_axes: Tuple[str, ...],
                      out_dir: str = "results") -> Optional[CommPlan]:
    """Resolve a --comm-plan CLI spec to a plan (None = flat).

    Grammar: ``flat`` | ``hier[:k]`` | ``auto`` | ``<path>``.

    ``auto`` and ``<path>`` fall back to flat with a CommPlanWarning
    when the plan is missing, stale (old schema), or was tuned for a
    different mesh — a wrong plan silently applied would reshape every
    collective in the compiled program. Explicit ``hier[:k]`` raises
    instead: the user asked for that exact schedule.
    """
    spec = spec.strip()
    if spec == "flat":
        return None
    if spec == "hier" or spec.startswith("hier:"):
        split = int(spec.split(":", 1)[1]) if ":" in spec else 1
        # validated for real in make_hierarchy at step-build time; the
        # dataclass check catches the out-of-range split early
        return CommPlan(mesh_shape=tuple(mesh_shape),
                        dp_axes=tuple(dp_axes), sync_mode="bucketed",
                        wire="bf16", bucket_bytes=0, hier_split=split,
                        source="manual")
    path = (plan_path(arch, mesh_shape, out_dir) if spec == "auto"
            else spec)
    try:
        plan = load_plan(path)
    except FileNotFoundError:
        warnings.warn(
            f"--comm-plan {spec}: no plan at {path}; using the flat "
            "schedule (run benchmarks/comm_bench.py --plan-out to tune)",
            CommPlanWarning, stacklevel=2)
        return None
    except StaleCommPlan as e:
        warnings.warn(f"--comm-plan {spec}: {e}; using the flat "
                      "schedule", CommPlanWarning, stacklevel=2)
        return None
    reason = _check_mesh(plan, mesh_shape, dp_axes)
    if reason is not None:
        warnings.warn(
            f"--comm-plan {spec}: {reason}; using the flat schedule",
            CommPlanWarning, stacklevel=2)
        return None
    return plan
