"""Compiled-program audit subsystem (DESIGN.md §12).

Static analysis of XLA compiled-HLO text, grown out of
``launch/hlo_analysis.py`` (which remains as a thin re-export shim):

- ``hlo_ir``      typed IR: parser, renderer, trip-count multipliers
- ``cost``        loop-aware FLOPs / bytes / collective accounting
- ``passes``      the pass framework + the audit passes (comm,
                  interleave, precision, donation, memory, collectives,
                  determinism) and the fusion comparison report
- ``contracts``   declarative per-(model, sync-mode) contracts
- ``audit``       the driver: lowers the real train step in every sync
                  mode on the local mesh and gates the contracts
                  (``python -m repro.analysis.audit``)
"""
from repro.analysis.hlo_ir import (  # noqa: F401
    COLLECTIVES,
    DTYPE_BYTES,
    HloModule,
    Op,
    compute_multipliers,
    parse_computations,
    parse_module,
    render_op,
    type_bytes,
    type_shape,
)
from repro.analysis.cost import (  # noqa: F401
    Analysis,
    analyze_hlo,
    gradient_sync_mode,
)
from repro.analysis.passes import (  # noqa: F401
    AuditContext,
    Finding,
    PassResult,
    available_passes,
    run_pass,
)


def quick_audit(hlo_text: str, total_devices: int = 1,
                n_batch_params=None):
    """Run the context-free audit passes on one compiled program and
    return a JSON-able record — what ``launch/dryrun.py`` embeds in its
    per-cell records. ``n_batch_params`` (the number of trailing batch
    leaves in the jit flattening — everything before them is donated
    state) arms the donation audit's coverage gate; without it the pass
    only reports what it sees."""
    ctx = AuditContext(hlo_text=hlo_text, total_devices=total_devices)
    if n_batch_params is not None:
        ctx.expectations["n_batch_params"] = int(n_batch_params)
    record = {}
    errors = 0
    for name in ("precision", "donation", "determinism", "collectives"):
        res = run_pass(name, ctx)
        record[name] = res.as_dict()
        errors += len(res.errors)
    record["ok"] = errors == 0
    return record
