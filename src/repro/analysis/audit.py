"""Audit driver: lower the real train step in every sync mode and gate
the per-(model, mode) contracts (DESIGN.md §12).

    PYTHONPATH=src python -m repro.analysis.audit \
        --model resnet50 --modes all            # reduced config, ~2 min

For each cell of {gspmd, perleaf, bucketed, overlap, zero,
zero_overlap, hier, hier_overlap, hier_zero, hier_zero_overlap} x
{sgd, lars} the driver AOT-lowers the real
``training/step.py`` train step on the local 8-virtual-device mesh
(flat cells on (8,1); hierarchical cells on the 2-axis DP mesh (2,4)
with hier_split=1, DESIGN.md §14)
(ShapeDtypeStructs only — nothing is allocated, no data pipeline),
runs every audit pass on the compiled HLO, and evaluates the mode's
contract (``analysis/contracts.py``). Facts the HLO cannot know —
how many state leaves are donated, how many buckets the plan cuts,
the wire itemsize — are computed here from the same planning code the
training step uses (``distributed/bucketing.py:stream_layout``) and
handed to the contracts as ``$``-expectations.

The result is ``AUDIT.json``: per-cell pass records + violations,
cross-cell relations (ZeRO must shrink resident optimizer state by
~(N-1)/N vs the replicated-stream cell), and a top-level ``ok`` that CI
gates on (exit code 1 on any violation).

Cells use f32 compute (the CPU backend's bf16->f32 promotions would
drown the precision lint in backend artifacts — see the gotcha in
launch/hlo_analysis.py) and an f16 wire (f16 collectives survive CPU
lowering at their true dtype). Bucket bytes default small enough that
the reduced config still cuts >= 2 buckets per step.
"""
import os

if "--xla_force_host_platform_device_count" not in \
        os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               " --xla_force_host_platform_device_count=8")

import argparse
import json
import math
import sys
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis.contracts import Contract, contract_for, evaluate, resolve
from repro.analysis.passes import AuditContext, run_pass
from repro.configs import (
    OptimizerConfig,
    ParallelConfig,
    ShapeConfig,
    TrainConfig,
    get_config,
    reduced_config,
)
from repro.distributed.bucketing import stream_layout
from repro.distributed.sharding import make_rules, tree_shardings
from repro.models import build_model, init_model_state
from repro.optim import make_optimizer
from repro.training.specs import input_specs, param_specs

MODES: Dict[str, Dict[str, Any]] = {
    # wire: f16 survives CPU lowering at its true dtype (bf16 would be
    # promoted to f32 and confuse byte accounting)
    "gspmd": dict(dp_mode="gspmd", compression="f16",
                  overlap=False, zero=False),
    "perleaf": dict(dp_mode="shardmap", compression="f16",
                    overlap=False, zero=False),
    "bucketed": dict(dp_mode="shardmap", compression="f16+bucketed",
                     overlap=False, zero=False),
    "overlap": dict(dp_mode="shardmap", compression="f16+bucketed",
                    overlap=True, zero=False),
    "zero": dict(dp_mode="shardmap", compression="f16+bucketed",
                 overlap=False, zero=True),
    "zero_overlap": dict(dp_mode="shardmap", compression="f16+bucketed",
                         overlap=True, zero=True),
    # hierarchical schedules (DESIGN.md §14) lower on a 2-axis DP mesh
    # (2, 4) with hier_split=1: outer=("data",) size 2, inner=("model",)
    # size 4 — inner > outer so the shard-level inter-axis all-reduce is
    # strictly smaller than a flat full-bucket all-reduce would be,
    # which lets the byte ceilings prove the flat sync is gone
    "hier": dict(dp_mode="shardmap", compression="f16+bucketed",
                 overlap=False, zero=False, hier=1),
    "hier_overlap": dict(dp_mode="shardmap", compression="f16+bucketed",
                         overlap=True, zero=False, hier=1),
    "hier_zero": dict(dp_mode="shardmap", compression="f16+bucketed",
                      overlap=False, zero=True, hier=1),
    "hier_zero_overlap": dict(dp_mode="shardmap",
                              compression="f16+bucketed",
                              overlap=True, zero=True, hier=1),
}

#: mesh shape for the hierarchical cells; flat cells use (8, 1)
HIER_MESH_SHAPE = (2, 4)

OPTIMIZERS = {"sgd": "momentum_sgd", "lars": "lars"}

AUDIT_PASSES = ("comm", "interleave", "precision", "donation", "memory",
                "collectives", "determinism")


def _lower_cell(cfg, mode: str, opt_kind: str, mesh: Mesh, *,
                global_batch: int, bucket_bytes: int,
                steps_per_epoch: int = 40
                ) -> Tuple[str, Dict[str, Any]]:
    """AOT-lower one (mode, optimizer) train cell; returns
    ``(compiled_hlo_text, info)`` where ``info`` carries the
    spec-derived facts the contracts need. Mirrors
    launch/dryrun.py:lower_cell, minus the data pipeline and with f32
    compute."""
    spec = MODES[mode]
    hier = spec.get("hier")
    # hierarchical cells run pure DP over both mesh axes (the paper's
    # ResNet regime); flat cells keep the single "data" DP axis
    dp_axes = ("data", "model") if hier is not None else ("data",)
    shp = ShapeConfig("audit", cfg.image_size, global_batch, "train")
    parallel = ParallelConfig(
        dp_axes=dp_axes,
        tp_axis=None if hier is not None else "model", zero_1=False,
        compression=spec["compression"], bucket_bytes=bucket_bytes,
        overlap_comm=spec["overlap"], zero_dp=spec["zero"],
        hier_split=hier)
    opt_cfg = OptimizerConfig(kind=OPTIMIZERS[opt_kind])
    train_cfg = TrainConfig(optimizer=opt_cfg, parallel=parallel)
    compute_dtype = jnp.float32

    model = build_model(cfg, compute_dtype=compute_dtype)
    p_shapes, p_axes = param_specs(model, jnp.float32)
    leaves = jax.tree.leaves(p_shapes)
    total_elems = sum(math.prod(l.shape) for l in leaves)
    repl = NamedSharding(mesh, P())
    n_workers = 1
    for a in dp_axes:
        n_workers *= mesh.shape[a]
    batch = input_specs(cfg, shp, compute_dtype)

    info: Dict[str, Any] = {
        "total_param_elems": total_elems,
        "n_param_leaves": len(leaves),
        "n_workers": n_workers,
    }
    if hier is not None:
        from repro.distributed.bucketing import make_hierarchy
        h = make_hierarchy(dp_axes, mesh.shape, hier)
        info["hier_outer"] = h.outer_size
        info["hier_inner"] = h.inner_size

    if spec["dp_mode"] == "gspmd":
        from repro.training.step import make_train_step
        rules = make_rules(cfg, mesh, parallel)
        p_shard = tree_shardings(p_axes, mesh, rules)
        optimizer = make_optimizer(opt_cfg, steps_per_epoch=steps_per_epoch,
                                   global_batch=global_batch)
        opt_shapes = jax.eval_shape(optimizer.init, p_shapes)
        opt_shard = {"step": repl,
                     **{f: p_shard for f in optimizer.state_fields}}
        mstate_shapes = jax.eval_shape(lambda: init_model_state(model))
        state_shapes = {"params": p_shapes, "opt": opt_shapes,
                        "model_state": mstate_shapes}
        state_shard = {
            "params": p_shard, "opt": opt_shard,
            "model_state": jax.tree.map(lambda _: repl, mstate_shapes)}
        b_shard = jax.tree.map(
            lambda v: NamedSharding(mesh, P("data")) if v.ndim else repl,
            batch)
        step = make_train_step(model, optimizer, train_cfg, mesh, rules,
                               None, param_shardings=p_shard)
        opt_bytes_per_device = sum(
            l.size * l.dtype.itemsize
            for l in jax.tree.leaves(opt_shapes))
    else:
        from repro.training.step import (
            make_dp_overlap_train_step,
            make_dp_shardmap_train_step,
            replicate_model_state,
        )
        dp_shard = NamedSharding(mesh, P(dp_axes))
        # stream layout: always under zero; also LARS on the bucketed
        # explicit-DP paths (stream-LARS, DESIGN.md §11) — same rule as
        # launch/train.py:build_train_setup
        use_stream = spec["zero"] or (
            opt_cfg.kind == "lars" and
            "bucketed" in (spec["compression"] or ""))
        if use_stream:
            from repro.optim.stream import (
                make_stream_optimizer,
                zero_padded_total,
            )
            optimizer = make_stream_optimizer(
                opt_cfg, steps_per_epoch=steps_per_epoch,
                global_batch=global_batch)
            padded_total = zero_padded_total(
                p_shapes, parallel.compression, bucket_bytes, n_workers)
            opt_shapes = jax.eval_shape(
                lambda: optimizer.init(padded_total))
            field_shard = dp_shard if spec["zero"] else repl
            opt_shard = {"step": repl,
                         **{f: field_shard
                            for f in optimizer.state_fields}}
            shard_div = n_workers if spec["zero"] else 1
            opt_bytes_per_device = 4 + sum(
                padded_total * 4 // shard_div
                for _ in optimizer.state_fields)
            info["padded_total"] = padded_total
        else:
            optimizer = make_optimizer(
                opt_cfg, steps_per_epoch=steps_per_epoch,
                global_batch=global_batch)
            opt_shapes = jax.eval_shape(optimizer.init, p_shapes)
            opt_shard = jax.tree.map(lambda _: repl, opt_shapes)
            opt_bytes_per_device = sum(
                l.size * l.dtype.itemsize
                for l in jax.tree.leaves(opt_shapes))
        mstate_shapes = jax.eval_shape(
            lambda: replicate_model_state(init_model_state(model),
                                          n_workers))
        state_shapes = {"params": p_shapes, "opt": opt_shapes,
                        "model_state": mstate_shapes}
        state_shard = {
            "params": jax.tree.map(lambda _: repl, p_shapes),
            "opt": opt_shard,
            "model_state": jax.tree.map(lambda _: dp_shard,
                                        mstate_shapes)}
        b_shard = jax.tree.map(
            lambda v: dp_shard if v.ndim else repl, batch)
        step_builder = (make_dp_overlap_train_step if spec["overlap"]
                        else make_dp_shardmap_train_step)
        step = step_builder(model, optimizer, train_cfg, mesh, dp_axes)

    jitted = jax.jit(step, in_shardings=(state_shard, b_shard),
                     out_shardings=(state_shard, None),
                     donate_argnums=(0,))
    compiled = jitted.lower(state_shapes, batch).compile()
    info["n_state_leaves"] = len(jax.tree.leaves(state_shapes))
    info["n_batch_params"] = len(jax.tree.leaves(batch))
    info["opt_bytes_per_device"] = opt_bytes_per_device
    return compiled.as_text(), info


def _cell_expectations(info: Dict[str, Any], mode: str, opt_kind: str,
                       bucket_bytes: int) -> Dict[str, Any]:
    """The ``$``-facts the contracts resolve against, computed from the
    same bucket arithmetic the training step uses."""
    spec = MODES[mode]
    hier = spec.get("hier")
    wire_itemsize = 2  # f16 wire in every audit cell
    n = info["n_workers"]
    # align mirrors training/step.py: shard-aligned under zero; the
    # stream-LARS non-zero paths align too (identical layout to zero,
    # DESIGN.md §11); hierarchical schedules always align to the full
    # DP size (the double scatter needs n_workers-divisible buckets);
    # plain bucketed/overlap sgd uses the tree update with align=1
    if hier is not None or spec["zero"] or (
            opt_kind == "lars" and
            "bucketed" in (spec["compression"] or "")):
        align = n
    else:
        align = 1
    bucket_elems, n_buckets, pad = stream_layout(
        info["total_param_elems"], bucket_bytes, wire_itemsize, align)
    # the tail bucket can be tiny (the stream is cut at fixed offsets);
    # contracts count *qualifying* collectives, so drop it from the
    # expected count when it falls under the schedule byte floor
    tail_elems = (info["total_param_elems"] + pad -
                  (n_buckets - 1) * bucket_elems)
    schedule_min_bytes = 2048
    n_qualifying = (n_buckets - 1) + int(
        tail_elems * wire_itemsize >= schedule_min_bytes)
    exp: Dict[str, Any] = {
        "n_state_params": info["n_state_leaves"],
        "n_batch_params": info["n_batch_params"],
        "n_buckets_planned": n_buckets,
        "n_buckets": n_qualifying,
        # slack: the stacked-metrics pmean and (LARS) trust psum also
        # execute, but they sit under schedule_min_bytes; +2 headroom
        # for a backend-materialized -start/-done splitting artifact.
        # zero runs TWO collectives per bucket (reduce-scatter in,
        # all-gather out)
        "collective_budget":
            (2 * n_qualifying if spec["zero"] else n_qualifying) + 2,
        "metric_bytes_floor": 2048,
        "schedule_min_bytes": schedule_min_bytes,
        # per-leaf wire floor: every big leaf crosses the ring once
        # (2 * bytes * (n-1)/n per all-reduce, cost.py:_wire_bytes)
        "min_gradient_wire_bytes":
            2 * (info["total_param_elems"] * wire_itemsize) *
            (n - 1) / n * 0.9,
    }
    if hier is not None:
        # per-op qualifying counts + byte ceilings for the hierarchical
        # pipeline (DESIGN.md §14). Buckets travel as f32 between the
        # inner reduce-scatter and the final cast (round-once
        # semantics), so intermediates are 4 B/elem; only the non-zero
        # modes' final all-gather is wire-dtype (2 B/elem). Sized like
        # the collectives pass: max(input, output) bytes per execution.
        inner = info["hier_inner"]
        sizes = [bucket_elems] * (n_buckets - 1) + [tail_elems]
        fl = schedule_min_bytes
        if spec["zero"]:
            # inner RS (4E) + outer RS (4E/inner) in; outer AG
            # (4E/inner) + inner AG (4E, f32 param stream) out
            rs_b = [b for e in sizes for b in (4 * e, 4 * e // inner)]
            ag_b = [b for e in sizes for b in (4 * e // inner, 4 * e)]
            n_rs = sum(b >= fl for b in rs_b)
            n_ar = 0
            n_ag = sum(b >= fl for b in ag_b)
            rs_ceil, ag_ceil = max(rs_b), max(ag_b)
            ar_ceil = exp["metric_bytes_floor"]
        else:
            n_rs = sum(4 * e >= fl for e in sizes)
            n_ar = sum(4 * e // inner >= fl for e in sizes)
            n_ag = sum(2 * e >= fl for e in sizes)
            rs_ceil = 4 * max(sizes)
            ar_ceil = 4 * max(sizes) // inner
            ag_ceil = 2 * max(sizes)
        exp.update({
            "n_rs": n_rs, "n_ar": n_ar, "n_ag": n_ag,
            "rs_bytes_ceiling": rs_ceil,
            "ar_bytes_ceiling": ar_ceil,
            "ag_bytes_ceiling": ag_ceil,
            "collective_budget": n_rs + n_ar + n_ag + 2,
        })
    return exp


def audit_cell(cfg, model: str, mode: str, opt_kind: str, mesh: Mesh, *,
               global_batch: int, bucket_bytes: int) -> Dict[str, Any]:
    """Lower + analyze + contract-check one cell; returns its record."""
    hlo, info = _lower_cell(cfg, mode, opt_kind, mesh,
                            global_batch=global_batch,
                            bucket_bytes=bucket_bytes)
    expectations = _cell_expectations(info, mode, opt_kind, bucket_bytes)
    contract = contract_for(model, mode, opt_kind)
    gates = {k: resolve(v, expectations)
             for k, v in contract.expectations.items()}
    ctx = AuditContext(hlo_text=hlo,
                       total_devices=math.prod(mesh.devices.shape),
                       expectations={**expectations, **gates})
    record = {name: run_pass(name, ctx).as_dict()
              for name in contract.passes}
    violations = evaluate(contract, record, expectations)
    return {
        "mode": mode,
        "optimizer": opt_kind,
        "contract": contract.name,
        "ok": not violations,
        "violations": violations,
        "expectations": expectations,
        "info": info,
        "passes": record,
    }


def _zero_relations(cells: List[Dict[str, Any]],
                    n_workers: int) -> List[Dict[str, Any]]:
    """Cross-cell memory relation: for each optimizer with both a
    ``bucketed`` and a ``zero`` cell, the resident entry-parameter bytes
    must drop by ~the sharded slice of the optimizer state —
    ``opt_bytes(bucketed) - opt_bytes(zero)``, i.e. ~(N-1)/N of the
    stream state (DESIGN.md §9). Params/model-state/batch are identical
    between the cells, so the entry-param delta isolates optimizer
    residency."""
    by_key = {(c["mode"], c["optimizer"]): c for c in cells}
    relations = []
    for opt in sorted({c["optimizer"] for c in cells}):
        a = by_key.get(("bucketed", opt))
        b = by_key.get(("zero", opt))
        if a is None or b is None:
            continue
        try:
            mem_a = a["passes"]["memory"]["summary"]["entry_param_bytes"]
            mem_b = b["passes"]["memory"]["summary"]["entry_param_bytes"]
        except KeyError:
            continue
        expected = (a["info"]["opt_bytes_per_device"] -
                    b["info"]["opt_bytes_per_device"])
        actual = mem_a - mem_b
        ok = expected > 0 and 0.5 * expected <= actual <= 1.5 * expected
        relations.append({
            "relation": "zero_shrinks_optimizer_residency",
            "optimizer": opt,
            "n_workers": n_workers,
            "entry_param_bytes": {"bucketed": mem_a, "zero": mem_b},
            "actual_shrink_bytes": actual,
            "expected_shrink_bytes": expected,
            "ok": ok,
        })
    return relations


def run_audit(model: str = "resnet50", modes: Optional[List[str]] = None,
              optimizers: Optional[List[str]] = None, full: bool = False,
              global_batch: int = 16,
              bucket_bytes: Optional[int] = None,
              verbose: bool = True) -> Dict[str, Any]:
    modes = list(modes or MODES)
    optimizers = list(optimizers or OPTIMIZERS)
    cfg = get_config(model)
    if not full:
        cfg = reduced_config(cfg)
    if bucket_bytes is None:
        # small enough that even the reduced param stream cuts >1 bucket
        bucket_bytes = 4 * 2 ** 20 if full else 8 * 2 ** 10
    mesh = jax.make_mesh((8, 1), ("data", "model"))
    # hierarchical cells need a genuinely 2-axis DP mesh (outer x inner)
    hier_mesh = jax.make_mesh(HIER_MESH_SHAPE, ("data", "model"))

    cells = []
    for mode in modes:
        for opt in optimizers:
            cell_mesh = (hier_mesh if MODES[mode].get("hier") is not None
                         else mesh)
            if verbose:
                print(f"[audit] {model}/{mode}/{opt} ...",
                      flush=True)
            try:
                cell = audit_cell(cfg, model, mode, opt, cell_mesh,
                                  global_batch=global_batch,
                                  bucket_bytes=bucket_bytes)
            except Exception as e:  # lowering itself failed the cell
                cell = {"mode": mode, "optimizer": opt, "ok": False,
                        "violations": [{
                            "kind": "lowering_failed",
                            "message": f"{type(e).__name__}: {e}"}],
                        "passes": {}}
            if verbose:
                status = "ok" if cell["ok"] else "FAIL"
                print(f"[audit] {model}/{mode}/{opt}: {status}",
                      flush=True)
                for v in cell["violations"]:
                    print(f"  violation: {v}", flush=True)
            cells.append(cell)

    relations = _zero_relations(cells, mesh.shape["data"])
    report = {
        "model": model,
        "config": "full" if full else "reduced",
        "mesh": list(mesh.devices.shape),
        "hier_mesh": list(HIER_MESH_SHAPE),
        "global_batch": global_batch,
        "bucket_bytes": bucket_bytes,
        "modes": modes,
        "optimizers": optimizers,
        "cells": cells,
        "relations": relations,
        "ok": (all(c["ok"] for c in cells) and
               all(r["ok"] for r in relations)),
    }
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Static-analysis audit of the compiled train step "
                    "across sync modes (DESIGN.md §12)")
    ap.add_argument("--model", default="resnet50")
    ap.add_argument("--modes", default="all",
                    help=f"comma list of {sorted(MODES)} or 'all'")
    ap.add_argument("--optimizers", default="all",
                    help=f"comma list of {sorted(OPTIMIZERS)} or 'all'")
    ap.add_argument("--quick", action="store_true",
                    help="reduced config (the default; alias for CI)")
    ap.add_argument("--full", action="store_true",
                    help="full model config (slow: ~2 min compile/cell "
                         "on CPU)")
    ap.add_argument("--global-batch", type=int, default=16)
    ap.add_argument("--bucket-bytes", type=int, default=None)
    ap.add_argument("--out", default="AUDIT.json")
    args = ap.parse_args(argv)

    modes = list(MODES) if args.modes == "all" else [
        m.strip() for m in args.modes.split(",") if m.strip()]
    for m in modes:
        if m not in MODES:
            ap.error(f"unknown mode {m!r}; pick from {sorted(MODES)}")
    opts = list(OPTIMIZERS) if args.optimizers == "all" else [
        o.strip() for o in args.optimizers.split(",") if o.strip()]
    for o in opts:
        if o not in OPTIMIZERS:
            ap.error(f"unknown optimizer {o!r}; pick from "
                     f"{sorted(OPTIMIZERS)}")

    report = run_audit(args.model, modes, opts, full=args.full,
                       global_batch=args.global_batch,
                       bucket_bytes=args.bucket_bytes)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
        f.write("\n")
    n_bad = sum(not c["ok"] for c in report["cells"]) + \
        sum(not r["ok"] for r in report["relations"])
    print(f"[audit] wrote {args.out}: "
          f"{len(report['cells'])} cells, "
          f"{len(report['relations'])} relations, "
          f"{n_bad} failing")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
