"""Typed HLO IR: parse XLA's compiled-program text into computations and
ops, and back.

XLA emits the optimized module in *scheduled program order*: column-0
lines open computations (headers may wrap), indented lines are ops, a
column-0 ``}`` closes. This module owns the grammar — every analysis
pass (DESIGN.md §12) reads the IR built here rather than regexing raw
text itself:

- ``parse_computations``  name -> [Op] (plus an ``__entry__`` alias)
- ``parse_module``        adds the header facts: entry name, the
                          ``input_output_alias`` map (buffer donation),
                          lazy per-computation defs and trip-count
                          multipliers
- ``render_op``           one op back to canonical text; parse -> render
                          -> parse is identity on the structured fields
                          (property-tested in tests/test_properties.py)
- ``compute_multipliers`` trip-count weighting through (possibly nested)
                          while loops — XLA's own cost_analysis counts
                          loop bodies ONCE (verified in this container)

The type table is deliberately strict-able: ``type_bytes(..., strict=
True)`` raises on a dtype token it does not know instead of silently
sizing it as 0 bytes (the seed-era bug for ``f8e4m3[...]``).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

# Bytes per element. Sub-byte types (s4/u4/f4) are fractional — XLA
# packs two per byte — so ``type_bytes`` returns a float. ``token`` and
# ``opaque`` occupy no HBM.
DTYPE_BYTES = {
    "pred": 1, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
    # the full f8/f4 family (StableHLO names); the seed table knew only
    # f8e4m3fn/f8e5m2 and silently sized the rest as 0 bytes
    "f8e4m3": 1, "f8e4m3fn": 1, "f8e4m3fnuz": 1, "f8e4m3b11fnuz": 1,
    "f8e5m2": 1, "f8e5m2fnuz": 1, "f8e3m4": 1, "f8e8m0fnu": 1,
    "f4e2m1fn": 0.5,
    "token": 0, "opaque": 0,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "collective-broadcast")


@dataclasses.dataclass
class Op:
    name: str
    opcode: str
    result: str  # raw type string
    operands: List[str]
    attrs: str  # everything after "opcode(" (operands + attributes)
    root: bool = False
    # structured split of ``attrs`` (renderer inputs): the operand list
    # up to the matching close paren, and the raw attribute tail after it
    args_raw: str = ""
    suffix: str = ""


_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def type_bytes(type_str: str, strict: bool = False) -> float:
    """Bytes of a (possibly tuple) HLO type string.

    ``strict=True`` raises ValueError on a dtype token missing from
    ``DTYPE_BYTES`` instead of skipping it — silently sizing an unknown
    dtype as 0 bytes is exactly how mixed-precision regressions hide.
    """
    total = 0.0
    for dtype, dims in _TYPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            if strict:
                raise ValueError(
                    f"unknown HLO dtype {dtype!r} in {type_str!r}; add it "
                    "to repro.analysis.hlo_ir.DTYPE_BYTES")
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def type_shape(type_str: str) -> Tuple[str, Tuple[int, ...]]:
    m = _TYPE_RE.search(type_str)
    if not m:
        return ("", ())
    dims = tuple(int(d) for d in m.group(2).split(",")) if m.group(2) else ()
    return m.group(1), dims


_OP_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"((?:\([^()]*\))|(?:[\w\[\],{}.]+))\s+"
    r"([\w\-]+)\((.*)$"
)


_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")


def _split_args(rest: str) -> Tuple[str, str]:
    """Split the text after ``opcode(`` into (args, suffix): args is the
    operand list up to the matching close paren, suffix the raw tail
    after it (leading ``, `` kept). Falls back to ``(rest, "")`` when the
    parens never balance (string literals inside constants)."""
    depth = 1
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                return rest[:i], rest[i + 1:]
    return rest, ""


def parse_op_line(line: str) -> Optional[Op]:
    """One indented op line -> Op, or None if the line is not an op."""
    m = _OP_RE.match(line)
    if not m:
        return None
    root, name, rtype, opcode, rest = m.groups()
    args_raw, suffix = _split_args(rest)
    # operands: the %names inside the argument list
    operands = re.findall(r"%([\w.\-]+)", args_raw)
    return Op(name=name, opcode=opcode, result=rtype, operands=operands,
              attrs=rest, root=bool(root), args_raw=args_raw,
              suffix=suffix)


def render_op(op: Op) -> str:
    """Canonical text of one op; ``parse_op_line(render_op(op))``
    reproduces every structured field (the roundtrip property test)."""
    head = "ROOT " if op.root else ""
    return (f"  {head}%{op.name} = {op.result} "
            f"{op.opcode}({op.args_raw}){op.suffix}")


def parse_computations(text: str) -> Dict[str, List[Op]]:
    """Column-0 lines open computations (headers may wrap over several
    lines); indented lines are ops; a column-0 '}' closes. The ENTRY
    computation is additionally aliased as ``"__entry__"``."""
    comps: Dict[str, List[Op]] = {}
    current: Optional[str] = None
    entry_marked: Optional[str] = None
    for line in text.splitlines():
        if line.startswith("}"):
            current = None
            continue
        if line and not line[0].isspace():
            m = _HEADER_RE.match(line)
            if m:
                current = m.group(2)
                comps[current] = []
                if m.group(1):
                    entry_marked = current
            continue
        if current is None:
            continue
        op = parse_op_line(line)
        if op is not None:
            comps[current].append(op)
    if entry_marked:
        comps["__entry__"] = comps[entry_marked]
    return comps


def _op_defs(ops: List[Op]) -> Dict[str, Op]:
    return {o.name: o for o in ops}


def op_consumers(ops: List[Op]) -> Dict[str, List[Op]]:
    """name -> the ops (same computation) that consume it as an operand."""
    users: Dict[str, List[Op]] = defaultdict(list)
    for op in ops:
        for o in op.operands:
            users[o].append(op)
    return dict(users)


def _trip_count(cond_ops: List[Op]) -> int:
    """Trip count heuristic: the max scalar s32/u32/s64 constant in the
    loop-condition computation (jax scans compare a counter against the
    length constant)."""
    best = 1
    for o in cond_ops:
        if o.opcode != "constant":
            continue
        dtype, dims = type_shape(o.result)
        if dims != () or dtype not in ("s32", "u32", "s64", "u64"):
            continue
        m = re.search(r"constant\((-?\d+)\)", "constant(" + o.attrs)
        if m:
            best = max(best, int(m.group(1)))
    return best


_CALLED_RE = re.compile(r"(?:calls|body|condition|to_apply)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")


def compute_multipliers(comps: Dict[str, List[Op]]
                        ) -> Tuple[Dict[str, float], Dict[str, int]]:
    entry = comps.get("__entry__")
    if entry is None:  # fall back: last computation is usually ENTRY
        entry_name = list(comps)[-1]
    else:
        entry_name = [k for k, v in comps.items()
                      if v is entry and k != "__entry__"][0]
    mult: Dict[str, float] = defaultdict(float)
    mult[entry_name] = 1.0
    trips: Dict[str, int] = {}

    # iterate to fixpoint (call graph is a DAG; few passes suffice)
    for _ in range(20):
        changed = False
        new_mult = defaultdict(float)
        new_mult[entry_name] = 1.0
        for cname, ops in comps.items():
            if cname == "__entry__" or mult.get(cname, 0) == 0:
                continue
            m_c = mult[cname]
            for op in ops:
                if op.opcode == "while":
                    body = cond = None
                    bm = re.search(r"body=%?([\w.\-]+)", op.attrs)
                    cm = re.search(r"condition=%?([\w.\-]+)", op.attrs)
                    if bm:
                        body = bm.group(1)
                    if cm:
                        cond = cm.group(1)
                    trip = _trip_count(comps.get(cond, [])) if cond else 1
                    if body:
                        trips[body] = trip
                        new_mult[body] += m_c * trip
                    if cond:
                        new_mult[cond] += m_c * (trip + 1)
                elif op.opcode == "conditional":
                    bs = _BRANCHES_RE.search(op.attrs)
                    names = []
                    if bs:
                        names = re.findall(r"%?([\w.\-]+)", bs.group(1))
                    for nm in names:
                        new_mult[nm] += m_c  # upper bound: every branch
                else:
                    for target in _CALLED_RE.findall(op.attrs):
                        if target in comps and target != cname:
                            new_mult[target] += m_c
        if dict(new_mult) != {k: v for k, v in mult.items() if v}:
            changed = True
        mult = new_mult
        if not changed:
            break
    return dict(mult), trips


# ---------------------------------------------------------------------------
# Module-level facts (header + entry computation)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AliasEntry:
    """One ``input_output_alias`` record: output tuple index <- (param
    number, param tuple index), may- or must-alias."""

    output_index: Tuple[int, ...]
    param_number: int
    param_index: Tuple[int, ...]
    kind: str


_ALIAS_ENTRY_RE = re.compile(
    r"\{([\d,\s]*)\}:\s*\(\s*(\d+)\s*,\s*\{([\d,\s]*)\}\s*,"
    r"\s*(may-alias|must-alias)\s*\)")


def _index_tuple(s: str) -> Tuple[int, ...]:
    return tuple(int(x) for x in s.replace(" ", "").split(",") if x)


def parse_input_output_alias(text: str) -> List[AliasEntry]:
    """The module header's donation map. Post-SPMD compiled text carries
    it as ``input_output_alias={ {0}: (0, {}, may-alias), ... }`` on the
    ``HloModule`` line; absent entirely when nothing was donated."""
    start = text.find("input_output_alias={")
    if start < 0:
        return []
    body = text[start + len("input_output_alias={"):]
    depth = 1
    for i, ch in enumerate(body):
        if ch == "{":
            depth += 1
        elif ch == "}":
            depth -= 1
            if depth == 0:
                body = body[:i]
                break
    return [AliasEntry(output_index=_index_tuple(out),
                       param_number=int(pnum),
                       param_index=_index_tuple(pidx), kind=kind)
            for out, pnum, pidx, kind in _ALIAS_ENTRY_RE.findall(body)]


@dataclasses.dataclass
class HloModule:
    """Parsed module: computations + the header facts the passes need.

    ``multipliers``/``trip_counts`` are computed lazily once (they walk
    the call graph to fixpoint)."""

    text: str
    computations: Dict[str, List[Op]]  # no "__entry__" alias key
    entry_name: str
    input_output_alias: List[AliasEntry]
    _mult: Optional[Dict[str, float]] = None
    _trips: Optional[Dict[str, int]] = None

    @property
    def entry_ops(self) -> List[Op]:
        return self.computations[self.entry_name]

    @property
    def multipliers(self) -> Dict[str, float]:
        if self._mult is None:
            comps = dict(self.computations)
            comps["__entry__"] = comps[self.entry_name]
            self._mult, self._trips = compute_multipliers(comps)
        return self._mult

    @property
    def trip_counts(self) -> Dict[str, int]:
        self.multipliers
        return self._trips

    def defs(self, cname: str) -> Dict[str, Op]:
        return _op_defs(self.computations[cname])

    def entry_params(self) -> List[Tuple[int, Op]]:
        """(parameter number, op) for the entry computation, sorted by
        number — jax numbers them in flattened (state, batch) argument
        order, which is what the donation audit keys on."""
        out = []
        for op in self.entry_ops:
            if op.opcode != "parameter":
                continue
            m = re.match(r"\s*(\d+)", op.args_raw)
            if m:
                out.append((int(m.group(1)), op))
        out.sort(key=lambda t: t[0])
        return out


def parse_module(text: str) -> HloModule:
    comps = parse_computations(text)
    entry = comps.pop("__entry__", None)
    if entry is not None:
        entry_name = next(k for k, v in comps.items() if v is entry)
    elif comps:
        entry_name = list(comps)[-1]
    else:
        raise ValueError("no computations found in HLO text")
    return HloModule(text=text, computations=comps, entry_name=entry_name,
                     input_output_alias=parse_input_output_alias(text))
