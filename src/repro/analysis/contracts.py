"""Declarative per-(model, sync-mode) contracts over audit-pass output
(DESIGN.md §12).

A :class:`Contract` says what the compiled train step of one
(model, dp/sync mode, optimizer) cell must look like: which passes run,
which pass-level gates are armed (via expectation knobs the passes
understand), and a list of :class:`Check` assertions over the passes'
summary fields. Checks reference driver-computed facts symbolically —
``value="$n_buckets"`` resolves against the expectations dict at
evaluation time — so the same contract text covers the reduced and full
configs, any bucket size, and any mesh.

Field paths are dotted into the pass summaries:
``"collectives.per_op.all-reduce.execs"`` means
``record["collectives"]["summary"]["per_op"]["all-reduce"]["execs"]``.

The contract table below encodes the repo's sync-mode claims
(DESIGN.md §5–§9) as machine-checked invariants:

========== ==========================================================
mode       must hold in the compiled step
========== ==========================================================
gspmd      gradient sync is all-reduce; ≥1 qualifying all-reduce
perleaf    all-reduce per big leaf (≥ the big-leaf count unless XLA's
           combiner merged them — gated by total wire bytes instead)
bucketed   exactly ``n_buckets`` qualifying all-reduces; total
           qualifying collectives ≤ the mode's launch budget; no
           reduce-scatter/all-gather above metric size (flat schedule)
overlap    bucketed + collectives interleaved with backward compute
zero       reduce-scatter+all-gather carry the gradient;
           ``n_buckets`` of each; NO all-reduce above metric size
zero_ovl   zero + interleaved
hier       every bucket lowers to intra-axis reduce-scatter +
           inter-axis all-reduce + intra-axis all-gather: exact
           per-op execution counts, per-op byte ceilings, and NO
           all-reduce above the shard size (the flat full-bucket
           all-reduce is gone, DESIGN.md §14)
hier_ovl   hier + interleaved
hier_zero  double reduce-scatter in, double all-gather out per
           bucket; NO all-reduce above metric size; byte ceilings
hier_z_ovl hier_zero + interleaved
all        no precision / donation / determinism errors
========== ==========================================================
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

ALL_PASSES: Tuple[str, ...] = (
    "comm", "interleave", "precision", "donation", "memory",
    "collectives", "determinism")

# passes whose error findings fail every contract
BASE_FORBID: Tuple[str, ...] = (
    "precision", "donation", "determinism", "collectives", "interleave",
    "memory")


@dataclasses.dataclass(frozen=True)
class Check:
    field: str            # dotted path, first segment = pass name
    op: str               # == != >= <= > < is_true is_false
    value: Any = None     # literal, or "$key" into expectations
    label: str = ""

    def describe(self) -> str:
        return self.label or f"{self.field} {self.op} {self.value}"


@dataclasses.dataclass
class Contract:
    name: str
    passes: Tuple[str, ...] = ALL_PASSES
    # pass-gate knobs, merged into AuditContext.expectations ("$"-refs
    # resolved first)
    expectations: Dict[str, Any] = dataclasses.field(default_factory=dict)
    checks: Tuple[Check, ...] = ()
    forbid_errors: Tuple[str, ...] = BASE_FORBID


def resolve(value: Any, expectations: Dict[str, Any]) -> Any:
    if isinstance(value, str) and value.startswith("$"):
        key = value[1:]
        if key not in expectations:
            raise KeyError(
                f"contract references ${key} but the driver did not "
                f"compute it; have {sorted(expectations)}")
        return expectations[key]
    return value


def lookup(record: Dict[str, Any], field: str) -> Any:
    parts = field.split(".")
    if parts[0] not in record:
        raise KeyError(f"no pass record {parts[0]!r} for field {field!r}")
    node: Any = record[parts[0]].get("summary", {})
    for p in parts[1:]:
        if not isinstance(node, dict) or p not in node:
            raise KeyError(f"field {field!r}: missing {p!r}")
        node = node[p]
    return node


_OPS = {
    "==": lambda a, b: a == b,
    "!=": lambda a, b: a != b,
    ">=": lambda a, b: a >= b,
    "<=": lambda a, b: a <= b,
    ">": lambda a, b: a > b,
    "<": lambda a, b: a < b,
    "is_true": lambda a, b: bool(a),
    "is_false": lambda a, b: not a,
}


def evaluate(contract: Contract, record: Dict[str, Any],
             expectations: Dict[str, Any]) -> List[Dict[str, Any]]:
    """Return the list of violations (empty = contract holds).
    ``record`` maps pass name -> ``PassResult.as_dict()``."""
    violations: List[Dict[str, Any]] = []
    for pname in contract.forbid_errors:
        rec = record.get(pname)
        if rec is None:
            violations.append({"kind": "missing_pass", "pass": pname,
                               "message": f"pass {pname!r} did not run"})
            continue
        for f in rec.get("findings", []):
            if f.get("severity") == "error":
                violations.append({"kind": "pass_error", "pass": pname,
                                   "message": f.get("message", ""),
                                   "finding": f})
    for chk in contract.checks:
        try:
            actual = lookup(record, chk.field)
            expected = resolve(chk.value, expectations)
            ok = _OPS[chk.op](actual, expected)
        except KeyError as e:
            violations.append({"kind": "check_error",
                               "check": chk.describe(),
                               "message": str(e)})
            continue
        if not ok:
            violations.append({
                "kind": "check_failed", "check": chk.describe(),
                "field": chk.field, "op": chk.op,
                "expected": expected, "actual": actual,
            })
    return violations


def contract_for(model: str, mode: str, optimizer: str) -> Contract:
    """The contract table. ``model`` is currently informational (every
    registered model makes the same per-mode promises); ``mode`` is one
    of gspmd / perleaf / bucketed / overlap / zero / zero_overlap."""
    common = (
        Check("collectives.qualifying_execs_total", ">=", 1,
              label="step has at least one substantial collective"),
    )
    exp: Dict[str, Any] = {}
    checks: Tuple[Check, ...] = common

    if mode == "gspmd":
        checks += (
            Check("collectives.gradient_sync", "==", "all_reduce"),
            Check("collectives.per_op.all-reduce.execs", ">=", 1),
        )
    elif mode == "perleaf":
        # XLA's all-reduce combiner may merge per-leaf syncs, so the
        # launch count is a floor of 1; the per-leaf promise that
        # survives compilation is the wire volume: every big leaf's
        # bytes cross the wire via all-reduce.
        checks += (
            Check("collectives.gradient_sync", "==", "all_reduce"),
            Check("collectives.per_op.all-reduce.execs", ">=", 1),
            Check("comm.per_op.all-reduce.wire_bytes_per_device", ">=",
                  "$min_gradient_wire_bytes",
                  label="all-reduce carries the full gradient volume"),
        )
    elif mode in ("bucketed", "overlap"):
        exp["max_collectives_per_step"] = "$collective_budget"
        # flat schedule: a reduce-scatter or all-gather above metric
        # size would mean a hierarchical stage leaked in (DESIGN.md §14)
        exp["forbid_reduce_scatter_above_bytes"] = "$metric_bytes_floor"
        exp["forbid_allgather_above_bytes"] = "$metric_bytes_floor"
        checks += (
            Check("collectives.gradient_sync", "==", "all_reduce"),
            Check("collectives.per_op.all-reduce.execs", "==",
                  "$n_buckets",
                  label="exactly one all-reduce per gradient bucket"),
        )
        if mode == "overlap":
            exp["require_interleaved"] = True
            checks += (Check("interleave.interleaved", "is_true"),)
    elif mode in ("hier", "hier_overlap"):
        exp["max_collectives_per_step"] = "$collective_budget"
        # the inter-axis all-reduce runs on the 1/inner shard: any
        # all-reduce above that ceiling is a surviving flat big sync
        exp["forbid_allreduce_above_bytes"] = "$ar_bytes_ceiling"
        checks += (
            Check("collectives.gradient_sync", "==", "hierarchical"),
            Check("collectives.per_op.reduce-scatter.execs", "==",
                  "$n_rs",
                  label="one intra-axis reduce-scatter per bucket"),
            Check("collectives.per_op.all-reduce.execs", "==", "$n_ar",
                  label="one inter-axis all-reduce per bucket shard"),
            Check("collectives.per_op.all-gather.execs", "==", "$n_ag",
                  label="one intra-axis all-gather per bucket"),
            Check("collectives.per_op.reduce-scatter.max_bytes", "<=",
                  "$rs_bytes_ceiling",
                  label="reduce-scatter stays bucket-sized (f32)"),
            Check("collectives.per_op.all-reduce.max_bytes", "<=",
                  "$ar_bytes_ceiling",
                  label="all-reduce stays 1/inner shard-sized"),
            Check("collectives.per_op.all-gather.max_bytes", "<=",
                  "$ag_bytes_ceiling",
                  label="all-gather stays bucket-sized (wire dtype)"),
        )
        if mode == "hier_overlap":
            exp["require_interleaved"] = True
            checks += (Check("interleave.interleaved", "is_true"),)
    elif mode in ("hier_zero", "hier_zero_overlap"):
        exp["max_collectives_per_step"] = "$collective_budget"
        exp["forbid_allreduce_above_bytes"] = "$metric_bytes_floor"
        checks += (
            Check("collectives.gradient_sync", "==",
                  "reduce_scatter+all_gather"),
            Check("collectives.per_op.reduce-scatter.execs", "==",
                  "$n_rs",
                  label="inner + outer reduce-scatter per bucket"),
            Check("collectives.per_op.all-gather.execs", "==", "$n_ag",
                  label="outer + inner all-gather per param bucket"),
            Check("collectives.per_op.reduce-scatter.max_bytes", "<=",
                  "$rs_bytes_ceiling",
                  label="reduce-scatter stays bucket-sized (f32)"),
            Check("collectives.per_op.all-gather.max_bytes", "<=",
                  "$ag_bytes_ceiling",
                  label="all-gather stays bucket-sized (f32 stream)"),
        )
        if mode == "hier_zero_overlap":
            exp["require_interleaved"] = True
            checks += (Check("interleave.interleaved", "is_true"),)
    elif mode in ("zero", "zero_overlap"):
        exp["max_collectives_per_step"] = "$collective_budget"
        exp["forbid_allreduce_above_bytes"] = "$metric_bytes_floor"
        checks += (
            Check("collectives.gradient_sync", "==",
                  "reduce_scatter+all_gather"),
            Check("collectives.per_op.reduce-scatter.execs", "==",
                  "$n_buckets",
                  label="one reduce-scatter per gradient bucket"),
            Check("collectives.per_op.all-gather.execs", "==",
                  "$n_buckets",
                  label="one all-gather per updated-param bucket"),
        )
        if mode == "zero_overlap":
            exp["require_interleaved"] = True
            checks += (Check("interleave.interleaved", "is_true"),)
    else:
        raise ValueError(f"no contract for mode {mode!r}")

    return Contract(name=f"{model}/{mode}/{optimizer}",
                    expectations=exp, checks=checks)
