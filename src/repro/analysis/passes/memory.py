"""Live-range peak-memory estimator (``memory`` pass).

Linear-scan liveness over the *entry* computation in scheduled program
order (the order XLA emits): each materializing op's buffer is live
from its definition to its last use; peak temp footprint is the max
over program points of the live-set byte sum. Entry parameters (params,
optimizer state, batch) are resident for the whole step and accounted
separately — their sum is what the ZeRO relation in the audit driver
checks shrinks by ~1/N for the optimizer-state slice (DESIGN.md §9).

This is an estimate, not bit-exact XLA buffer assignment: it ignores
in-place sharing beyond trivial aliases (tuple/GTE/bitcast) and
sub-computation temporaries. It is stable across runs of the same
program, which is what a contract needs.
"""
from __future__ import annotations

from typing import Dict

from repro.analysis.hlo_ir import type_bytes
from repro.analysis.passes import AuditContext, PassResult, register_pass

# alias-ish / non-materializing at entry level
_SKIP = {"parameter", "tuple", "get-tuple-element", "bitcast"}


@register_pass("memory")
def memory_pass(ctx: AuditContext) -> PassResult:
    res = PassResult(name="memory")
    ops = ctx.module.entry_ops
    n = len(ops)

    last_use: Dict[str, int] = {}
    for i, op in enumerate(ops):
        for o in op.operands:
            last_use[o] = i

    param_bytes = 0.0
    events = [0.0] * (n + 1)  # delta at each program point
    buffers = []
    for i, op in enumerate(ops):
        if op.opcode == "parameter":
            param_bytes += type_bytes(op.result)
            continue
        if op.opcode in _SKIP:
            continue
        b = type_bytes(op.result)
        if b <= 0:
            continue
        end = n - 1 if op.root else last_use.get(op.name, i)
        events[i] += b
        events[end + 1] -= b
        buffers.append((b, op.opcode, op.name[:40]))

    live = 0.0
    temp_peak = 0.0
    peak_at = 0
    for i in range(n):
        live += events[i]
        if live > temp_peak:
            temp_peak, peak_at = live, i

    buffers.sort(reverse=True)
    res.summary.update({
        "entry_param_bytes": param_bytes,
        "temp_peak_bytes": temp_peak,
        "peak_bytes": param_bytes + temp_peak,
        "peak_at_op_index": peak_at,
        "n_buffers": len(buffers),
        "top_buffers": [
            {"bytes": b, "opcode": oc, "op": nm}
            for b, oc, nm in buffers[:10]
        ],
    })

    cap = ctx.expectations.get("max_peak_bytes")
    if cap is not None and param_bytes + temp_peak > float(cap):
        res.add("error",
                f"estimated per-device peak {param_bytes + temp_peak:.0f} "
                f"B exceeds contract cap {float(cap):.0f} B",
                peak_bytes=param_bytes + temp_peak, cap=float(cap))
    return res
