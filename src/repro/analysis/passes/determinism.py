"""Determinism lint (``determinism`` pass).

The repo's parity rails (tests/test_parity.py, the ZeRO/bucketed
bitwise-equality tests) assume the compiled step is a pure function of
its inputs. Three op families can silently break that:

- ``rng*`` ops (rng, rng-bit-generator, rng-get-and-update-state):
  hidden state / backend-dependent streams → **error** unless the
  driver sets ``expectations["allow_rng"]`` (a model that legitimately
  uses dropout would).
- ``scatter`` with overlapping indices: XLA's combine order is
  unspecified on some backends → **warn** by default, **error** when
  the contract sets ``expectations["forbid_scatter"]``.
- ``select-and-scatter`` is *excluded*: it is max-pool's backward,
  deterministic, and present in every ResNet program.

Atomics never appear in CPU/TPU HLO text (they are a GPU lowering
detail), so scatter is the textual proxy the lint can see.
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict

from repro.analysis.hlo_ir import compute_multipliers, parse_computations
from repro.analysis.passes import AuditContext, PassResult, register_pass

_RNG_OPS = {"rng", "rng-bit-generator", "rng-get-and-update-state"}


@register_pass("determinism")
def determinism_pass(ctx: AuditContext) -> PassResult:
    res = PassResult(name="determinism")
    comps = parse_computations(ctx.hlo_text)
    comps.pop("__entry__", None)
    mult, _ = compute_multipliers(comps)

    counts: Dict[str, float] = defaultdict(float)
    for cname, ops in comps.items():
        m_c = mult.get(cname, 0.0)
        if not m_c:
            continue
        for op in ops:
            if op.opcode in _RNG_OPS or op.opcode == "scatter":
                counts[op.opcode] += m_c
                if op.opcode in _RNG_OPS:
                    if not ctx.expectations.get("allow_rng"):
                        res.add("error",
                                f"{op.opcode} op breaks bitwise parity "
                                f"(hidden rng state in the compiled "
                                f"step)",
                                op=op.name, computation=cname)
                else:
                    sev = ("error"
                           if ctx.expectations.get("forbid_scatter")
                           else "warn")
                    res.add(sev,
                            "scatter combine order is unspecified with "
                            "overlapping indices; bitwise parity is "
                            "backend-dependent",
                            op=op.name, computation=cname)

    res.summary.update({
        "op_counts": {k: round(v, 2) for k, v in sorted(counts.items())},
        "clean": not counts,
    })
    return res
