"""Pass framework for the compiled-program audit (DESIGN.md §12).

A pass is a function ``(AuditContext) -> PassResult`` registered under a
short name. Passes are pure: they read the parsed module / cost
analysis off the context (both lazily computed and cached) plus any
driver-supplied expectations, and return findings + a JSON-able summary.
They never raise on ugly input — a parse-level surprise becomes an
``error`` finding so the audit driver can gate on it.

Adding a pass (the short version; DESIGN.md §12 has the full recipe):

    from repro.analysis.passes import AuditContext, PassResult, \
        register_pass

    @register_pass("my_pass")
    def my_pass(ctx: AuditContext) -> PassResult:
        res = PassResult(name="my_pass")
        for op in ctx.module.entry_ops:
            ...
            res.add("error", "what is wrong", op=op.name)
        res.summary["whatever"] = 42
        return res

then drive it from a contract (``analysis/contracts.py``) or directly
via ``run_pass("my_pass", ctx)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional

from repro.analysis.cost import Analysis, analyze_hlo
from repro.analysis.hlo_ir import HloModule, parse_module

SEVERITIES = ("error", "warn", "info")


@dataclasses.dataclass
class Finding:
    """One thing a pass noticed about the program."""
    severity: str            # "error" | "warn" | "info"
    message: str
    op: str = ""             # op or computation name, when localizable
    data: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def as_dict(self) -> Dict[str, Any]:
        d = {"severity": self.severity, "message": self.message}
        if self.op:
            d["op"] = self.op
        if self.data:
            d["data"] = self.data
        return d


@dataclasses.dataclass
class PassResult:
    name: str
    findings: List[Finding] = dataclasses.field(default_factory=list)
    summary: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def add(self, severity: str, message: str, op: str = "",
            **data: Any) -> None:
        assert severity in SEVERITIES, severity
        self.findings.append(Finding(severity, message, op, dict(data)))

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def warnings(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "warn"]

    def as_dict(self) -> Dict[str, Any]:
        return {
            "pass": self.name,
            "ok": not self.errors,
            "findings": [f.as_dict() for f in self.findings],
            "summary": self.summary,
        }


@dataclasses.dataclass
class AuditContext:
    """Everything a pass may look at for one compiled program.

    ``expectations`` carries driver-computed facts the HLO alone cannot
    know (number of donated state leaves, expected bucket count, wire
    itemsize, ...) — passes and contracts reference them by key.
    """
    hlo_text: str
    total_devices: int = 1
    expectations: Dict[str, Any] = dataclasses.field(default_factory=dict)
    _module: Optional[HloModule] = dataclasses.field(
        default=None, repr=False)
    _analysis: Optional[Analysis] = dataclasses.field(
        default=None, repr=False)

    @property
    def module(self) -> HloModule:
        if self._module is None:
            self._module = parse_module(self.hlo_text)
        return self._module

    @property
    def analysis(self) -> Analysis:
        if self._analysis is None:
            self._analysis = analyze_hlo(
                self.hlo_text, total_devices=self.total_devices)
        return self._analysis


_REGISTRY: Dict[str, Callable[[AuditContext], PassResult]] = {}


def register_pass(name: str):
    def deco(fn: Callable[[AuditContext], PassResult]):
        _REGISTRY[name] = fn
        return fn
    return deco


def get_pass(name: str) -> Callable[[AuditContext], PassResult]:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown audit pass {name!r}; available: "
            f"{sorted(_REGISTRY)}")
    return _REGISTRY[name]


def available_passes() -> List[str]:
    return sorted(_REGISTRY)


def run_pass(name: str, ctx: AuditContext) -> PassResult:
    """Run one pass; an unexpected exception becomes an error finding
    rather than killing the audit."""
    fn = get_pass(name)
    try:
        return fn(ctx)
    except Exception as e:  # noqa: BLE001 — audit must not die mid-run
        res = PassResult(name=name)
        res.add("error", f"pass crashed: {type(e).__name__}: {e}")
        return res


# Register the built-in passes (import side effect, bottom of module to
# avoid circularity: pass modules import the framework names above).
from repro.analysis.passes import comm  # noqa: E402,F401
from repro.analysis.passes import determinism  # noqa: E402,F401
from repro.analysis.passes import donation  # noqa: E402,F401
from repro.analysis.passes import fusion  # noqa: E402,F401
from repro.analysis.passes import interleave  # noqa: E402,F401
from repro.analysis.passes import memory  # noqa: E402,F401
from repro.analysis.passes import precision  # noqa: E402,F401
from repro.analysis.passes import schedule  # noqa: E402,F401
