"""Collective-schedule linter (``collectives`` pass).

Generalizes ``gradient_sync_mode`` into contract-checkable facts about
the step's collective schedule:

- per-opcode *qualifying* execution counts (trip-weighted, sized by
  ``max(input, output)`` bytes so an all-gather's big output counts),
  with a byte floor that drops metric pmeans / LARS trust-ratio psums
  out of the gradient accounting;
- the largest single execution per opcode (what "zero has no all-reduce
  above metric size" pins down);
- optional expectation-driven gates: ``max_collectives_per_step``
  (bucketed modes: the whole point of bucketing is a *bounded* number
  of launches) and per-opcode byte caps —
  ``forbid_allreduce_above_bytes`` (ZeRO: the full-gradient all-reduce
  is gone; hierarchical: only the shard-sized inter-axis all-reduce
  survives), ``forbid_reduce_scatter_above_bytes`` /
  ``forbid_allgather_above_bytes`` (flat modes: no stray hierarchical
  stages, DESIGN.md §14).
"""
from __future__ import annotations

from collections import defaultdict
from typing import Dict

from repro.analysis.cost import gradient_sync_mode
from repro.analysis.hlo_ir import (
    COLLECTIVES,
    _op_defs,
    compute_multipliers,
    parse_computations,
    type_bytes,
)
from repro.analysis.passes import AuditContext, PassResult, register_pass


@register_pass("collectives")
def schedule_pass(ctx: AuditContext) -> PassResult:
    res = PassResult(name="collectives")
    floor = float(ctx.expectations.get("schedule_min_bytes", 2048))
    comps = parse_computations(ctx.hlo_text)
    comps.pop("__entry__", None)
    mult, _ = compute_multipliers(comps)

    execs: Dict[str, float] = defaultdict(float)
    max_bytes: Dict[str, float] = defaultdict(float)
    small_execs = 0.0
    for cname, ops in comps.items():
        m_c = mult.get(cname, 0.0)
        if not m_c:
            continue
        defs = _op_defs(ops)
        for op in ops:
            base = op.opcode[:-6] if op.opcode.endswith("-start") \
                else op.opcode
            if base not in COLLECTIVES:
                continue
            in_b = sum(type_bytes(defs[o].result)
                       for o in op.operands if o in defs)
            b = max(type_bytes(op.result), in_b)
            max_bytes[base] = max(max_bytes[base], b)
            if b >= floor:
                execs[base] += m_c
            else:
                small_execs += m_c

    total = sum(execs.values())
    res.summary.update({
        "per_op": {
            k: {"execs": round(v, 2), "max_bytes": max_bytes[k]}
            for k, v in sorted(execs.items())
        },
        "qualifying_execs_total": round(total, 2),
        "small_execs_total": round(small_execs, 2),
        "schedule_min_bytes": floor,
        # metric floor is driver-tunable: the LARS trust-ratio psum is
        # (2, L+1) f32 ≈ 1.3 KiB on full ResNet-50, still "metric-sized"
        "gradient_sync": gradient_sync_mode(
            ctx.analysis,
            metric_bytes_floor=int(
                ctx.expectations.get("metric_bytes_floor", 1024))),
        "allreduce_max_bytes": max_bytes.get("all-reduce", 0.0),
        "reduce_scatter_max_bytes": max_bytes.get("reduce-scatter", 0.0),
        "allgather_max_bytes": max_bytes.get("all-gather", 0.0),
    })

    cap = ctx.expectations.get("max_collectives_per_step")
    if cap is not None and total > float(cap):
        res.add("error",
                f"{total:.1f} qualifying collectives/step exceeds the "
                f"contract cap of {float(cap):.0f} (bucketing is "
                f"supposed to bound launches)",
                qualifying_execs_total=total, cap=float(cap))
    for opname, key in (
            ("all-reduce", "forbid_allreduce_above_bytes"),
            ("reduce-scatter", "forbid_reduce_scatter_above_bytes"),
            ("all-gather", "forbid_allgather_above_bytes")):
        op_cap = ctx.expectations.get(key)
        if op_cap is not None and \
                max_bytes.get(opname, 0.0) > float(op_cap):
            res.add("error",
                    f"{opname} moving {max_bytes[opname]:.0f} B "
                    f"survives; this mode promises none above "
                    f"{float(op_cap):.0f} B",
                    **{f"{opname.replace('-', '_')}_max_bytes":
                       max_bytes[opname], "cap": float(op_cap)})
    return res
