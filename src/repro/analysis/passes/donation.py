"""Donation/aliasing audit (``donation`` pass).

``jit_train_step`` donates the state argument (``donate_argnums=(0,)``)
so XLA can update params + optimizer state in place; if the aliasing is
silently lost (a sharding mismatch, a dtype change, a new jit site
without donation) every step pays a full extra copy of the state in
HBM — invisible from Python, obvious in ``input_output_alias``.

jax flattens the ``(state, batch)`` arguments state-first, and XLA
prunes unused leaves from the entry, so the *donated* entry parameters
are everything except the trailing batch leaves. The driver says how
many batch leaves there are (``expectations["n_batch_params"]``); the
pass checks every remaining (state) parameter appears in the module's
``input_output_alias`` table and estimates the wasted bytes when not.
Without the expectation it reports coverage at info level only.
"""
from __future__ import annotations

from repro.analysis.hlo_ir import type_bytes
from repro.analysis.passes import AuditContext, PassResult, register_pass


@register_pass("donation")
def donation_pass(ctx: AuditContext) -> PassResult:
    res = PassResult(name="donation")
    mod = ctx.module
    params = mod.entry_params()
    aliased_numbers = {e.param_number for e in mod.input_output_alias}

    n_batch = ctx.expectations.get("n_batch_params")
    gated = n_batch is not None
    if gated:
        n_batch = int(n_batch)
        if n_batch > len(params):
            res.add("warn",
                    f"expected {n_batch} trailing batch parameters but "
                    f"entry only has {len(params)}")
            n_batch = len(params)
        state = params[:len(params) - n_batch] if n_batch else params
    else:
        state = params

    total_state_bytes = 0.0
    wasted = 0.0
    n_aliased = 0
    for num, op in state:
        b = type_bytes(op.result)
        total_state_bytes += b
        if num in aliased_numbers:
            n_aliased += 1
        else:
            wasted += b
            if gated and b >= 1024:
                res.add("warn",
                        f"state parameter {num} ({op.result[:40]}) is "
                        f"not donated (no input_output_alias entry)",
                        op=op.name, param_number=num, bytes=b)

    # XLA prunes unused leaves entirely, so the flattened-leaf count
    # from the driver is an upper bound, reported for context only
    expected_leaves = ctx.expectations.get("n_state_params")
    frac = n_aliased / len(state) if state else 1.0
    res.summary.update({
        "n_entry_params": len(params),
        "n_state_params": len(state),
        "n_state_leaves_declared": expected_leaves,
        "n_aliased": n_aliased,
        "n_alias_entries": len(mod.input_output_alias),
        "state_alias_fraction": round(frac, 4),
        "state_bytes": total_state_bytes,
        "wasted_bytes": wasted,
    })
    if not gated:
        res.add("info",
                f"{n_aliased}/{len(state)} entry params aliased "
                f"(no n_batch_params expectation; coverage not gated)")
        return res

    # XLA may legitimately decline an alias on a scalar (the step
    # counter) or reshard a leaf; gate on bulk coverage, not perfection.
    if wasted >= 4096 or frac < 0.95:
        res.add(
            "error",
            f"donation lost: only {n_aliased}/{len(state)} state "
            f"parameters aliased ({wasted:.0f} wasted bytes/device of "
            f"extra HBM residency per step)",
            wasted_bytes=wasted, state_alias_fraction=round(frac, 4))
    return res
