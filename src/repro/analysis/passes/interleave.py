"""Collective/compute interleaving (backward-overlapped sync,
DESIGN.md §8) — migrated from ``launch/hlo_analysis.py`` and wrapped as
the ``interleave`` audit pass."""
from __future__ import annotations

import re
from typing import Dict, List

from repro.analysis.hlo_ir import (
    COLLECTIVES,
    Op,
    _BRANCHES_RE,
    _CALLED_RE,
    _op_defs,
    parse_computations,
    type_bytes,
)
from repro.analysis.passes import AuditContext, PassResult, register_pass

_COMPUTE_OPS = ("convolution", "dot")
_CALLING_OPS = ("call", "fusion", "while", "conditional")


def _transitive_compute_counts(comps: Dict[str, List[Op]]) -> Dict[str, int]:
    """conv+dot ops per computation, following call/fusion/while bodies
    (counted once, not trip-weighted — presence is what the interleave
    check needs)."""
    memo: Dict[str, int] = {}

    def count(cname: str, seen) -> int:
        if cname in memo:
            return memo[cname]
        if cname in seen:
            return 0
        seen = seen | {cname}
        total = 0
        for op in comps.get(cname, []):
            if op.opcode in _COMPUTE_OPS:
                total += 1
            elif op.opcode in _CALLING_OPS:
                for target in _CALLED_RE.findall(op.attrs):
                    if target in comps:
                        total += count(target, seen)
                bs = _BRANCHES_RE.search(op.attrs)
                if bs:
                    for nm in re.findall(r"%?([\w.\-]+)", bs.group(1)):
                        if nm in comps:
                            total += count(nm, seen)
        memo[cname] = total
        return total

    for cname in comps:
        count(cname, frozenset())
    return memo


def _op_compute_weight(op: Op, memo: Dict[str, int]) -> int:
    if op.opcode in _COMPUTE_OPS:
        return 1
    if op.opcode in _CALLING_OPS:
        total = 0
        for target in _CALLED_RE.findall(op.attrs):
            total += memo.get(target, 0)
        bs = _BRANCHES_RE.search(op.attrs)
        if bs:
            for nm in re.findall(r"%?([\w.\-]+)", bs.group(1)):
                total += memo.get(nm, 0)
        return total
    return 0


def _collective_bytes_of(op: Op, defs: Dict[str, Op]) -> float:
    in_b = sum(type_bytes(defs[o].result) for o in op.operands if o in defs)
    return max(type_bytes(op.result), in_b)


def interleave_report(text: str,
                      min_collective_bytes: int = 512) -> Dict[str, object]:
    """Verify from the *scheduled* HLO whether the gradient collectives
    are interleaved with backward compute or clustered at the tail.

    The XLA text is emitted in scheduled program order, so position is
    evidence: in the non-overlapped step every gradient all-reduce
    depends on the full backward and must sit after the last backward
    convolution/dot; in the overlapped step (DESIGN.md §8) the
    ``optimization_barrier`` pipeline pins each bucket's collective
    between backward segments, so substantial conv/dot compute appears
    between the first and last collective and after the first one.

    A program counts as ``interleaved`` when it has >= 2 qualifying
    (>= ``min_collective_bytes``) collectives, at least one conv/dot
    between the first and the last of them, and at least one conv/dot
    after the first one. Tiny metric pmeans fall under the byte floor.
    """
    comps = parse_computations(text)
    comps.pop("__entry__", None)
    memo = _transitive_compute_counts(comps)

    # the computation carrying the gradient sync = the one with the most
    # qualifying collective bytes
    best_name = None
    best_bytes = -1.0
    for cname, ops in comps.items():
        defs = _op_defs(ops)
        tot = 0.0
        for op in ops:
            base = op.opcode[:-6] if op.opcode.endswith("-start") \
                else op.opcode
            if base in COLLECTIVES:
                b = _collective_bytes_of(op, defs)
                if b >= min_collective_bytes:
                    tot += b
        if tot > best_bytes:
            best_bytes, best_name = tot, cname

    if best_name is None or best_bytes <= 0:
        return {"n_collectives": 0, "interleaved": False,
                "reason": "no qualifying collectives"}

    ops = comps[best_name]
    defs = _op_defs(ops)
    coll_pos: List[int] = []
    weights: List[int] = []
    for idx, op in enumerate(ops):
        weights.append(_op_compute_weight(op, memo))
        base = op.opcode[:-6] if op.opcode.endswith("-start") else op.opcode
        if base in COLLECTIVES and \
                _collective_bytes_of(op, defs) >= min_collective_bytes:
            coll_pos.append(idx)

    total = sum(weights)
    first, last = coll_pos[0], coll_pos[-1]
    after_first = sum(weights[first + 1:])
    between = sum(weights[first + 1:last])
    gaps_with_compute = sum(
        1 for lo, hi in zip(coll_pos, coll_pos[1:])
        if sum(weights[lo + 1:hi]) > 0)
    n = len(coll_pos)
    interleaved = n >= 2 and between >= 1 and after_first >= 1
    return {
        "computation": best_name,
        "n_collectives": n,
        "compute_ops_total": total,
        "compute_ops_before_first": sum(weights[:first]),
        "compute_ops_after_first": after_first,
        "compute_ops_between_first_last": between,
        "gaps_with_compute": gaps_with_compute,
        "interleaved": interleaved,
    }


@register_pass("interleave")
def interleave_pass(ctx: AuditContext) -> PassResult:
    """Pass wrapper: summary = ``interleave_report``; when the driver
    sets ``expectations["require_interleaved"]`` a non-interleaved
    schedule is an error (the overlap modes' contract)."""
    res = PassResult(name="interleave")
    floor = int(ctx.expectations.get("min_collective_bytes", 512))
    rep = interleave_report(ctx.hlo_text, min_collective_bytes=floor)
    res.summary.update(rep)
    if ctx.expectations.get("require_interleaved") and \
            not rep.get("interleaved"):
        res.add("error",
                "gradient collectives are clustered at the tail, not "
                "interleaved with backward compute",
                op=str(rep.get("computation", "")),
                n_collectives=rep.get("n_collectives", 0),
                compute_ops_between_first_last=rep.get(
                    "compute_ops_between_first_last", 0))
    return res
