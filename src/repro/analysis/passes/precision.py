"""Precision-policy lint (``precision`` pass).

Two rails from the large-batch literature (Goyal et al. 1706.02677,
Yamazaki et al. 1903.12650 — wrong-dtype accumulations are where
large-minibatch regressions hide):

1. Every *big* reduction — BN statistics, LARS segment norms, loss
   means: anything consuming an activation/param-sized operand — must
   accumulate in a >= 4-byte float. An HLO ``reduce``/``reduce-window``
   accumulates at its result dtype, so a bf16/f16/f8 result on a big
   reduction is an **error**.
2. Narrow round-trips (f32 -> bf16 -> f32 double casts) on the value
   wire silently truncate mantissa. They are a **warn** (the bucketed
   wire compression does this *on purpose*, with error feedback), and
   round-trips whose outer convert only exists to feed a collective are
   suppressed entirely — the CPU backend promotes bf16 collectives to
   f32 and that inserted cast is a backend artifact, not a policy
   violation.
"""
from __future__ import annotations

import math

from repro.analysis.hlo_ir import (
    COLLECTIVES,
    DTYPE_BYTES,
    _op_defs,
    compute_multipliers,
    op_consumers,
    parse_computations,
    type_shape,
)
from repro.analysis.passes import AuditContext, PassResult, register_pass

_FLOAT_PREFIXES = ("f", "bf")


def _is_narrow_float(dtype: str) -> bool:
    return (dtype.startswith(_FLOAT_PREFIXES)
            and DTYPE_BYTES.get(dtype, 4) < 4)


def _elems(result: str) -> int:
    _, dims = type_shape(result)
    return math.prod(dims) if dims else 1


@register_pass("precision")
def precision_pass(ctx: AuditContext) -> PassResult:
    res = PassResult(name="precision")
    floor = int(ctx.expectations.get("reduction_elems_floor", 2048))
    comps = parse_computations(ctx.hlo_text)
    comps.pop("__entry__", None)
    mult, _ = compute_multipliers(comps)

    n_checked = n_narrow = n_roundtrip = n_suppressed = 0
    for cname, ops in comps.items():
        if not mult.get(cname, 0.0):
            continue
        defs = _op_defs(ops)
        consumers = op_consumers(ops)
        for op in ops:
            if op.opcode in ("reduce", "reduce-window"):
                big = max((_elems(d.result) for o in op.operands
                           if (d := defs.get(o)) is not None),
                          default=0)
                if big < floor:
                    continue
                n_checked += 1
                acc_dtype, _ = type_shape(op.result)
                if _is_narrow_float(acc_dtype):
                    n_narrow += 1
                    res.add(
                        "error",
                        f"big reduction ({big} elems) accumulates in "
                        f"{acc_dtype}; activation-sized reductions must "
                        f"accumulate f32",
                        op=op.name, computation=cname, elems=big,
                        dtype=acc_dtype)
            elif op.opcode == "convert" and op.operands:
                out_dt, _ = type_shape(op.result)
                src = defs.get(op.operands[0])
                if src is None or src.opcode != "convert" \
                        or not src.operands:
                    continue
                mid_dt, _ = type_shape(src.result)
                orig = defs.get(src.operands[0])
                if orig is None:
                    continue
                orig_dt, _ = type_shape(orig.result)
                if orig_dt != out_dt or not _is_narrow_float(mid_dt) \
                        or DTYPE_BYTES.get(out_dt, 0) <= \
                        DTYPE_BYTES.get(mid_dt, 0):
                    continue
                if _elems(op.result) < floor:
                    continue  # scalar/metric casts are noise
                # outer convert feeding only collectives = the CPU
                # backend's bf16-collective promotion, not a policy bug
                cons = consumers.get(op.name, [])
                if cons and all(
                        c.opcode in COLLECTIVES
                        or (c.opcode.endswith("-start")
                            and c.opcode[:-6] in COLLECTIVES)
                        for c in cons):
                    n_suppressed += 1
                    continue
                n_roundtrip += 1
                res.add(
                    "warn",
                    f"{orig_dt} -> {mid_dt} -> {out_dt} round-trip on a "
                    f"{_elems(op.result)}-elem value (mantissa "
                    f"truncation outside the error-feedback wire)",
                    op=op.name, computation=cname,
                    narrow_dtype=mid_dt)

    res.summary.update({
        "big_reductions_checked": n_checked,
        "narrow_reductions": n_narrow,
        "roundtrips": n_roundtrip,
        "roundtrips_suppressed_collective": n_suppressed,
        "reduction_elems_floor": floor,
    })
    return res
