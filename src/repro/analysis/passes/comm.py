"""Communication summary (bucketed sync verification, DESIGN.md §6) —
migrated from ``launch/hlo_analysis.py`` and wrapped as the ``comm``
audit pass."""
from __future__ import annotations

from typing import Dict, Optional

from repro.analysis.cost import Analysis, gradient_sync_mode
from repro.analysis.passes import AuditContext, PassResult, register_pass
from repro.analysis.passes.interleave import interleave_report


def comm_report(a: Analysis, hlo_text: Optional[str] = None,
                min_collective_bytes: int = 512) -> Dict[str, object]:
    """Communication summary for one compiled program — the numbers the
    bucketed sync mode (DESIGN.md §6) is *verified* by, rather than
    assumed: how many collectives actually execute per step, how many
    wire bytes each one moves, and in which dtype.

    When ``hlo_text`` is given, the report also carries an
    ``interleave`` section (``interleave_report``) proving — or
    refuting — that the collectives overlap the backward compute in the
    scheduled program order (DESIGN.md §8).
    """
    per_op = {}
    for op, execs in sorted(a.collective_exec_counts.items()):
        byts = a.collective_bytes.get(op, 0.0)
        per_op[op] = {
            "executions_per_step": round(execs, 2),
            "wire_bytes_per_device": byts,
            "bytes_per_collective": byts / execs if execs else 0.0,
            "max_bytes_per_collective": a.collective_max_exec_bytes.get(
                op, 0.0),
            "dtype_bytes": dict(a.collective_dtypes.get(op, {})),
        }
    total_execs = sum(a.collective_exec_counts.values())
    total_bytes = a.total_collective_bytes
    report: Dict[str, object] = {
        "per_op": per_op,
        "total_executions_per_step": round(total_execs, 2),
        "total_wire_bytes_per_device": total_bytes,
        "mean_bytes_per_collective": (total_bytes / total_execs
                                      if total_execs else 0.0),
        # the claim the --zero acceptance test pins down: a ZeRO step
        # must classify as reduce_scatter+all_gather, i.e. no all-reduce
        # above metric size survives (DESIGN.md §9)
        "gradient_sync": gradient_sync_mode(a),
    }
    if hlo_text is not None:
        report["interleave"] = interleave_report(
            hlo_text, min_collective_bytes=min_collective_bytes)
    return report


@register_pass("comm")
def comm_pass(ctx: AuditContext) -> PassResult:
    """Pass wrapper: summary = ``comm_report`` (with the interleave
    section). Purely informational — the gating checks live in the
    ``collectives`` schedule linter and the per-mode contracts."""
    res = PassResult(name="comm")
    floor = int(ctx.expectations.get("min_collective_bytes", 512))
    res.summary.update(comm_report(
        ctx.analysis, hlo_text=ctx.hlo_text,
        min_collective_bytes=floor))
    return res
