"""BN fusion accounting (fused Pallas batch norm, DESIGN.md §10) —
migrated from ``launch/hlo_analysis.py``. This is a two-program
*comparison* report, not a single-program pass, so it is not in the
pass registry; ``tests/test_fused_bn.py`` and ``benchmarks/bn_bench.py``
drive it directly."""
from __future__ import annotations

import math
import re
from typing import Dict

from repro.analysis.hlo_ir import (
    _op_defs,
    compute_multipliers,
    parse_computations,
    type_shape,
)

_BN_SKIP_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                "bitcast", "convolution", "dot", "while", "call",
                "conditional", "iota", "rng", "rng-bit-generator"}


def bn_pass_counts(text: str, act_elems: int) -> Dict[str, float]:
    """Count the passes one lowered BN-site program makes over its
    activation: trip-weighted ``reduction_ops`` — reduce/reduce-window
    ops that consume an activation-sized (>= ``act_elems``) operand,
    fusion bodies included; counting only the activation-sized stage
    makes a backend's hierarchical reduce-window -> reduce chain one
    logical reduction, not several — and ``activation_writes``
    (top-level materializing ops whose result is at least
    ``act_elems`` elements — the elementwise normalize/ReLU/residual/
    mask chains). Convolutions/dots are excluded: they are the useful
    compute, identical on the fused and unfused paths."""
    comps = parse_computations(text)
    comps.pop("__entry__", None)
    mult, _ = compute_multipliers(comps)
    fusion_bodies = set()
    for ops in comps.values():
        for op in ops:
            if op.opcode == "fusion":
                m = re.search(r"calls=%?([\w.\-]+)", op.attrs)
                if m:
                    fusion_bodies.add(m.group(1))
    reduction = 0.0
    writes = 0.0
    for cname, ops in comps.items():
        m_c = mult.get(cname, 0.0)
        if not m_c:
            continue
        in_fusion = cname in fusion_bodies
        defs = _op_defs(ops)
        for op in ops:
            if op.opcode in ("reduce", "reduce-window"):
                big_in = False
                for o in op.operands:
                    d = defs.get(o)
                    if d is None:
                        continue
                    _, dims = type_shape(d.result)
                    if dims and math.prod(dims) >= act_elems:
                        big_in = True
                if big_in:
                    reduction += m_c
                continue
            if in_fusion or op.opcode in _BN_SKIP_OPS:
                continue
            _, dims = type_shape(op.result)
            if dims and math.prod(dims) >= act_elems:
                writes += m_c
    return {"reduction_ops": reduction, "activation_writes": writes}


def fusion_report(fused_text: str, unfused_text: str, act_elems: int,
                  n_sites: int = 1) -> Dict[str, object]:
    """Per-BN-site op-count comparison the fused-BN claim
    (DESIGN.md §10) is *verified* by, rather than assumed: the fused
    fwd+bwd must
    perform strictly fewer reduction ops than the unfused jnp path
    (one stats pass + one dy/x-hat pass vs XLA's
    mean/var/dscale/dbias/dmean/dvar chain) and no more activation-sized
    materializing writes. Feed it the compiled HLO of the same
    fwd(+vjp) program lowered both ways; the booleans are what
    tests/test_fused_bn.py and benchmarks/bn_bench.py assert."""
    fused = bn_pass_counts(fused_text, act_elems)
    unfused = bn_pass_counts(unfused_text, act_elems)
    n = max(n_sites, 1)
    report: Dict[str, object] = {
        "act_elems": act_elems,
        "n_sites": n_sites,
        "fused": fused,
        "unfused": unfused,
        "reduction_ops_per_site": {
            "fused": fused["reduction_ops"] / n,
            "unfused": unfused["reduction_ops"] / n,
        },
        "activation_writes_per_site": {
            "fused": fused["activation_writes"] / n,
            "unfused": unfused["activation_writes"] / n,
        },
        "reduction_collapse":
            fused["reduction_ops"] < unfused["reduction_ops"],
        "elementwise_collapse":
            fused["activation_writes"] <= unfused["activation_writes"],
    }
    report["collapsed"] = bool(report["reduction_collapse"]
                               and report["elementwise_collapse"])
    return report
